// Probe the rustc version to gate the AVX-512 kernel bodies: the
// `_mm512_*` intrinsics used by `rust/src/zkernel/simd.rs` and
// `rust/src/rng.rs` were stabilized in Rust 1.89, and the build must keep
// working on older toolchains — there the `mezo_avx512` cfg is simply not
// set, the AVX-512 bodies are compiled out, and the AVX-512 SIMD tier
// reports itself unsupported at runtime (forcing `MEZO_SIMD=avx512` then
// fails loudly, by design).

use std::process::Command;

fn main() {
    // Declare the custom cfg so `-D warnings` builds don't trip the
    // `unexpected_cfgs` lint on toolchains where it is left unset.
    println!("cargo::rustc-check-cfg=cfg(mezo_avx512)");
    if rustc_minor().is_some_and(|minor| minor >= 89) {
        println!("cargo::rustc-cfg=mezo_avx512");
    }
    println!("cargo::rerun-if-changed=build.rs");
}

/// Minor version of the active rustc ("rustc 1.89.0 (…)" → 89), saturated
/// to `u32::MAX` for a hypothetical major > 1. `None` (probe failed) is
/// treated as "too old": the scalar/AVX2/NEON tiers never need the probe.
fn rustc_minor() -> Option<u32> {
    let rustc = std::env::var_os("RUSTC")?;
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    let ver = text.split_whitespace().nth(1)?;
    let mut parts = ver.split('.');
    let major: u32 = parts.next()?.parse().ok()?;
    // "-nightly"/"-beta" suffixes live on the patch component; the minor
    // component is always a bare integer.
    let minor: u32 = parts.next()?.parse().ok()?;
    match major {
        0 => None,
        1 => Some(minor),
        _ => Some(u32::MAX),
    }
}
