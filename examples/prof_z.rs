//! Profile the z-generation ladder: scalar z() per coordinate, blocked
//! fill, and the threaded zkernel fill (MEZO_THREADS to override).
use mezo::rng::GaussianStream;
use mezo::zkernel::ZEngine;
use std::time::Instant;

fn main() {
    let g = GaussianStream::new(7);
    let n = 20_000_000usize;

    let t = Instant::now();
    let mut acc = 0.0f32;
    for i in 0..n as u64 {
        acc += g.z(i);
    }
    let dt = t.elapsed().as_secs_f64();
    println!(
        "scalar z():      {:>7.1} M/s ({:.1} ns each) acc={}",
        n as f64 / dt / 1e6,
        dt * 1e9 / n as f64,
        acc
    );

    let mut buf = vec![0.0f32; n];
    let t = Instant::now();
    g.fill(&mut buf, 0);
    let dt = t.elapsed().as_secs_f64();
    println!(
        "blocked fill:    {:>7.1} M/s ({:.1} ns each)",
        n as f64 / dt / 1e6,
        dt * 1e9 / n as f64
    );

    for threads in [1, 2, 4, 8] {
        let eng = ZEngine::with_threads(threads);
        let t = Instant::now();
        eng.fill_z(g, 0, &mut buf);
        let dt = t.elapsed().as_secs_f64();
        println!(
            "zkernel fill x{}: {:>7.1} M/s ({:.1} ns each)",
            threads,
            n as f64 / dt / 1e6,
            dt * 1e9 / n as f64
        );
    }
    assert_eq!(buf[12_345], g.z(12_345)); // blocked == scalar, bitwise
}
