use mezo::rng::GaussianStream;
use std::time::Instant;
fn main() {
    let g = GaussianStream::new(7);
    let n = 20_000_000u64;
    let t = Instant::now();
    let mut acc = 0.0f32;
    for i in 0..n { acc += g.z(i); }
    let dt = t.elapsed().as_secs_f64();
    println!("z(): {:.1} M/s ({:.1} ns each) acc={}", n as f64/dt/1e6, dt*1e9/n as f64, acc);
}
