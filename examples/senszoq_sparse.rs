//! Sparse SensZOQ fine-tuning over the masked z-kernels: select a static
//! sensitive-weight set, step FZOO on just that set, and replay the run
//! from its (seed, grad, lr) log + mask digest — fully offline (no pjrt
//! feature, no artifacts).
//!
//!     cargo run --release --example senszoq_sparse
//!     cargo run --release --example senszoq_sparse -- --budget 8192 --topk 16
//!
//! SensZOQ (Wang et al., 2024) picks the sensitive set with a
//! gradient-based score; here a short dense-MeZO warmup accumulates the
//! ZO estimate of the empirical-Fisher diagonal, Σ (g·z(i))², which
//! `SparseMask::top_k(…, Sensitivity::Scores)` turns into the mask. The
//! sparse run then perturbs/updates ONLY the masked coordinates (the
//! dense run walks all of them), and the storage story extends to masks:
//! the trajectory carries the mask digest, masked batched replay
//! reconstructs the run, and replaying under the wrong mask fails loudly.

use anyhow::Result;
use mezo::model::meta::TensorDesc;
use mezo::model::params::ParamStore;
use mezo::optim::fzoo::{Fzoo, FzooConfig};
use mezo::optim::mezo::{MezoConfig, MezoSgd};
use mezo::rng::{GaussianStream, Pcg};
use mezo::storage::Trajectory;
use mezo::util::args::Args;
use mezo::zkernel::{Sensitivity, SparseMask};

const DIM: usize = 64;

fn fresh_params() -> ParamStore {
    let mut p = ParamStore::from_specs(vec![
        TensorDesc { name: "lin.w".into(), shape: vec![DIM], dtype: "f32".into() },
        TensorDesc { name: "lin.b".into(), shape: vec![1], dtype: "f32".into() },
    ]);
    p.init(0);
    p
}

/// mean binary cross-entropy, numerically stable form
fn bce(p: &ParamStore, xs: &[Vec<f32>], ys: &[f32]) -> f32 {
    let w = p.get("lin.w");
    let b = p.get("lin.b")[0];
    let mut acc = 0.0f32;
    for (x, &y) in xs.iter().zip(ys) {
        let z = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() + b;
        acc += z.max(0.0) - z * y + (1.0 + (-z.abs()).exp()).ln();
    }
    acc / xs.len() as f32
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let budget = args.usize("budget", 4096);
    // ~12% of the weights by default; clamped below DIM so the "wrong
    // mask" demo at the end is always structurally different
    let topk = args.usize("topk", DIM / 8).clamp(1, DIM - 1);
    let warmup = args.usize("warmup", 32);
    let fzoo_n = args.usize("fzoo-n", 7).max(1);
    let lr = args.f32("lr", 0.05);
    let eps = args.f32("eps", 1e-3);
    let seed = args.u64("seed", 17);

    // synthetic task: y = [x · w* > 0], but only a few features matter —
    // exactly the regime where a sensitive-weight subset suffices
    let mut rng = Pcg::new(seed);
    let mut w_true = vec![0.0f32; DIM];
    for i in 0..DIM / 8 {
        w_true[i * 8] = rng.normal_f32(0.0, 2.0);
    }
    let n_train = 256;
    let mut xs = Vec::with_capacity(n_train);
    let mut ys = Vec::with_capacity(n_train);
    for _ in 0..n_train {
        let x: Vec<f32> = (0..DIM).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let dot: f32 = x.iter().zip(&w_true).map(|(a, b)| a * b).sum();
        xs.push(x);
        ys.push(if dot > 0.0 { 1.0 } else { 0.0 });
    }
    println!("budget: {} forward passes   initial loss {:.4}", budget, bce(&fresh_params(), &xs, &ys));

    // --- warmup: dense MeZO accumulates the ZO Fisher-diagonal estimate --
    let mut p_warm = fresh_params();
    let cfg = MezoConfig { lr, eps, ..Default::default() };
    let mut warm = MezoSgd::new(cfg, vec![0, 1], seed);
    let mut spent = 0usize;
    for _ in 0..warmup {
        let info = warm.step(&mut p_warm, |p| Ok(bce(p, &xs, &ys)))?;
        spent += info.forward_passes;
    }
    // score[i] = Σ_records (pgrad · z(offset + i))² — the empirical-Fisher
    // estimate SensZOQ selects with, recomputed from the (seed, g) log
    let mut scores: Vec<Vec<f32>> = vec![vec![0.0; DIM], vec![0.0; 1]];
    for r in &warm.history {
        let stream = GaussianStream::new(r.seed);
        for (slot, &ti) in [0usize, 1].iter().enumerate() {
            let off = p_warm.offsets[ti];
            for (j, s) in scores[slot].iter_mut().enumerate() {
                let gi = r.pgrad * stream.z(off + j as u64);
                *s += gi * gi;
            }
        }
    }
    let mask = SparseMask::top_k(&p_warm, &[0, 1], topk, Sensitivity::Scores(&scores))?;
    println!(
        "warmup: {} dense MeZO steps ({} fwd) -> top-{} sensitive set, density {:.1}%, digest {:#018x}",
        warmup,
        spent,
        mask.n_selected(),
        100.0 * mask.density(&p_warm),
        mask.digest()
    );

    // --- dense FZOO vs sparse (masked) FZOO at the remaining budget ------
    let remaining = budget.saturating_sub(spent);
    let run = |mask: Option<SparseMask>| -> Result<(ParamStore, Fzoo)> {
        let mut p = fresh_params();
        let cfg = FzooConfig { lr, eps, n: fzoo_n, ..Default::default() };
        let mut opt = Fzoo::new(cfg, vec![0, 1], seed ^ 0xF0);
        opt.mask = mask;
        let mut fwd = 0usize;
        while fwd + fzoo_n + 1 <= remaining {
            let info = opt.step(&mut p, |p| Ok(bce(p, &xs, &ys)))?;
            fwd += info.forward_passes;
        }
        Ok((p, opt))
    };
    let (p_dense, _) = run(None)?;
    println!(
        "FZOO dense  (all {} coords): loss {:.4}",
        DIM + 1,
        bce(&p_dense, &xs, &ys)
    );
    let (p_sparse, sparse) = run(Some(mask.clone()))?;
    println!(
        "FZOO sparse ({:>3} coords   ): loss {:.4}   (same seeds, {}x less update traffic)",
        mask.n_selected(),
        bce(&p_sparse, &xs, &ys),
        (DIM + 1) / mask.n_selected().max(1)
    );

    // --- storage: sparse runs replay from the log + mask digest ----------
    let traj = Trajectory::from_run(vec!["lin.w".into(), "lin.b".into()], &sparse.history)
        .with_mask_digest(mask.digest());
    let mut replayed = fresh_params();
    traj.replay_batched_masked(&mut replayed, &mask, fzoo_n)?;
    let max_dev = p_sparse
        .data
        .iter()
        .flatten()
        .zip(replayed.data.iter().flatten())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "masked replay_batched(n={}) from {} records + digest: max |Δθ| {:.2e}",
        fzoo_n,
        traj.records.len(),
        max_dev
    );
    assert!(max_dev < 1e-4, "masked batched replay diverged: {}", max_dev);
    // the digest guard: a different sensitive set cannot silently replay
    // (all DIM coords of lin.w — strictly more than the top-k mask holds)
    let wrong = SparseMask::full(&p_warm, &[0]);
    let err = traj
        .replay_batched_masked(&mut fresh_params(), &wrong, fzoo_n)
        .expect_err("wrong mask must not replay");
    println!("wrong mask errors as expected: {}", err);
    Ok(())
}
