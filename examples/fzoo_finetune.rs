//! FZOO vs MeZO at a matched forward-pass budget, on a synthetic
//! logistic-regression "fine-tune" — runs fully offline (no pjrt feature,
//! no artifacts).
//!
//!     cargo run --release --example fzoo_finetune
//!     cargo run --release --example fzoo_finetune -- --budget 8192 --fzoo-n 15
//!
//! Both optimizers draw from the same forward-pass budget B: MeZO (n = 1,
//! two-point) takes B/2 steps at 2 forwards each; FZOO takes B/(n+1) steps
//! at n + 1 forwards each (one unperturbed anchor + n one-sided seeds) and
//! normalizes each step by the loss-difference std. The run ends with the
//! storage story: the FZOO history replays batched onto fresh parameters,
//! and a non-dividing seed-batch size is shown to error (the integrity
//! guard against truncated or mislabeled logs).

use anyhow::Result;
use mezo::model::meta::TensorDesc;
use mezo::model::params::ParamStore;
use mezo::optim::fzoo::{Fzoo, FzooConfig};
use mezo::optim::mezo::{MezoConfig, MezoSgd};
use mezo::rng::Pcg;
use mezo::storage::Trajectory;
use mezo::util::args::Args;

const DIM: usize = 64;

fn fresh_params() -> ParamStore {
    let mut p = ParamStore::from_specs(vec![
        TensorDesc { name: "lin.w".into(), shape: vec![DIM], dtype: "f32".into() },
        TensorDesc { name: "lin.b".into(), shape: vec![1], dtype: "f32".into() },
    ]);
    p.init(0);
    p
}

/// mean binary cross-entropy, numerically stable form
fn bce(p: &ParamStore, xs: &[Vec<f32>], ys: &[f32]) -> f32 {
    let w = p.get("lin.w");
    let b = p.get("lin.b")[0];
    let mut acc = 0.0f32;
    for (x, &y) in xs.iter().zip(ys) {
        let z = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() + b;
        acc += z.max(0.0) - z * y + (1.0 + (-z.abs()).exp()).ln();
    }
    acc / xs.len() as f32
}

fn accuracy(p: &ParamStore, xs: &[Vec<f32>], ys: &[f32]) -> f32 {
    let w = p.get("lin.w");
    let b = p.get("lin.b")[0];
    let hits = xs
        .iter()
        .zip(ys)
        .filter(|(x, &y)| {
            let z = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() + b;
            (z > 0.0) == (y > 0.5)
        })
        .count();
    hits as f32 / xs.len() as f32
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let budget = args.usize("budget", 4096);
    let fzoo_n = args.usize("fzoo-n", 7).max(1);
    let lr = args.f32("lr", 0.05);
    let eps = args.f32("eps", 1e-3);
    let seed = args.u64("seed", 17);

    // synthetic task: y = [x · w* > 0] on gaussian features
    let mut rng = Pcg::new(seed);
    let w_true: Vec<f32> = (0..DIM).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let n_train = 256;
    let mut xs = Vec::with_capacity(n_train);
    let mut ys = Vec::with_capacity(n_train);
    for _ in 0..n_train {
        let x: Vec<f32> = (0..DIM).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let dot: f32 = x.iter().zip(&w_true).map(|(a, b)| a * b).sum();
        xs.push(x);
        ys.push(if dot > 0.0 { 1.0 } else { 0.0 });
    }

    let l0 = bce(&fresh_params(), &xs, &ys);
    println!("budget: {} forward passes   initial loss {:.4}", budget, l0);

    // --- MeZO two-point, n = 1: 2 forwards per step -----------------------
    let mut p_mezo = fresh_params();
    let cfg = MezoConfig { lr, eps, ..Default::default() };
    let mut mezo = MezoSgd::new(cfg, vec![0, 1], seed);
    let mut fwd = 0usize;
    let mut steps = 0usize;
    while fwd + 2 <= budget {
        let info = mezo.step(&mut p_mezo, |p| Ok(bce(p, &xs, &ys)))?;
        fwd += info.forward_passes;
        steps += 1;
    }
    println!(
        "MeZO  (n=1, 2-point): {:>5} steps, {:>5} fwd -> loss {:.4}, acc {:.3}",
        steps,
        fwd,
        bce(&p_mezo, &xs, &ys),
        accuracy(&p_mezo, &xs, &ys)
    );

    // --- FZOO batched one-sided, n seeds: n + 1 forwards per step ---------
    let mut p_fzoo = fresh_params();
    let cfg = FzooConfig { lr, eps, n: fzoo_n, ..Default::default() };
    let mut fzoo = Fzoo::new(cfg, vec![0, 1], seed);
    let mut fwd = 0usize;
    let mut steps = 0usize;
    while fwd + fzoo_n + 1 <= budget {
        let info = fzoo.step(&mut p_fzoo, |p| Ok(bce(p, &xs, &ys)))?;
        fwd += info.forward_passes;
        steps += 1;
    }
    assert!(steps > 0, "--budget {} too small for one FZOO step (needs n+1 = {})", budget, fzoo_n + 1);
    println!(
        "FZOO  (n={}, 1-sided): {:>5} steps, {:>5} fwd -> loss {:.4}, acc {:.3}",
        fzoo_n,
        steps,
        fwd,
        bce(&p_fzoo, &xs, &ys),
        accuracy(&p_fzoo, &xs, &ys)
    );

    // --- storage: the run is reconstructible from the (seed, g, lr) log ---
    let traj = Trajectory::from_run(vec!["lin.w".into(), "lin.b".into()], &fzoo.history);
    println!(
        "trajectory: {} records ({} bytes f32, {} bytes quantized)",
        traj.records.len(),
        traj.bytes_f32(),
        traj.bytes_quantized()
    );
    let mut replayed = fresh_params();
    traj.replay_batched(&mut replayed, fzoo_n)?;
    let max_dev = p_fzoo
        .data
        .iter()
        .flatten()
        .zip(replayed.data.iter().flatten())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("replay_batched(n={}) max |Δθ| vs trained: {:.2e}", fzoo_n, max_dev);
    assert!(max_dev < 1e-4, "batched replay diverged: {}", max_dev);
    // a non-dividing seed-batch size flags a truncated/mislabeled log
    // (records.len() + 1 never divides a non-empty record count)
    let err = traj
        .replay_batched(&mut fresh_params(), traj.records.len() + 1)
        .expect_err("mismatched seed-batch size must error");
    println!("mismatched batch size errors as expected: {}", err);
    Ok(())
}
