//! Compare zero-shot → FT(Adam, backprop) → MeZO on one task.
use anyhow::Result;
use mezo::data::tasks::{generate, GenOpts, Task};
use mezo::eval::Evaluator;
use mezo::optim::ft::{FtConfig, FtFlavor, FtOptimizer};
use mezo::optim::mezo::{MezoConfig, MezoSgd};
use mezo::optim::MezoStepper;
use mezo::train::pretrain::{artifact_name, pretrained, params_for, PretrainCfg};
use mezo::train::{train_ft, train_zo, TrainCfg};
use mezo::runtime::Runtime;
use mezo::tokenizer::Vocab;
use mezo::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let task = Task::from_name(&args.str("task", "sst2")).expect("unknown task");
    let family = args.str("family", "ar");
    let size = args.str("size", "tiny");
    let rt = Runtime::from_env()?;
    let vocab = Vocab::standard();
    pretrained(&rt, &family, &size, &PretrainCfg::default())?;
    let loss_art = rt.load(&artifact_name(&family, &size, "loss", "full"))?;
    let grad_art = rt.load(&artifact_name(&family, &size, "grad", "full"))?;
    let logits_art = rt.load(&artifact_name(&family, &size, "logits", "full"))?;
    let ev = Evaluator::new(loss_art.clone(), Some(logits_art), family == "mlm");
    let n_train = args.usize("n-train", 256);
    let data = generate(task, &vocab, GenOpts { n_train, n_val: 96, n_test: 192, ..Default::default() });

    let params0 = params_for(&rt, &loss_art.meta.name, &family, &size, 0)?;
    let zs = ev.evaluate(&params0, task, &data.test)?.score;
    println!("zero-shot: {:.3}", zs);

    // FT
    let ft_steps = args.usize("ft-steps", 200);
    let mut p_ft = params_for(&rt, &loss_art.meta.name, &family, &size, 0)?;
    let tr = p_ft.indices_of(&grad_art.meta.trainable);
    let mut ft = FtOptimizer::new(FtConfig { lr: args.f32("ft-lr", 1e-4), total_steps: ft_steps,
        flavor: FtFlavor::Adam, ..Default::default() }, tr, &p_ft);
    let r = train_ft(&mut ft, &mut p_ft, &grad_art, &ev, task, &data.train, &data.val,
        &TrainCfg { steps: ft_steps, eval_every: ft_steps/4, ..Default::default() })?;
    println!("FT: test {:.3} (best val {:.3}, losses {:?})",
             ev.evaluate(&p_ft, task, &data.test)?.score, r.best_val,
             r.curve.iter().map(|x| (x.1*100.0).round()/100.0).collect::<Vec<_>>());

    // MeZO
    let steps = args.usize("steps", 2000);
    let mut p_zo = params_for(&rt, &loss_art.meta.name, &family, &size, 0)?;
    let tr = p_zo.indices_of(&loss_art.meta.trainable);
    let cfg = MezoConfig { lr: args.f32("lr", 3e-4), eps: args.f32("eps", 1e-3),
        total_steps: steps, ..Default::default() };
    let mut opt = MezoStepper::new(MezoSgd::new(cfg, tr, 7));
    let r = train_zo(&mut opt, &mut p_zo, &loss_art, &ev, task, &data.train, &data.val,
        &TrainCfg { steps, eval_every: steps/5, ..Default::default() })?;
    println!("MeZO: test {:.3} (best val {:.3}, fwd {})",
             ev.evaluate(&p_zo, task, &data.test)?.score, r.best_val, r.forward_passes);
    println!("  val curve: {:?}", r.val_curve);
    Ok(())
}
