//! Synthetic multi-tenant serving load: the ROADMAP "million users"
//! scenario made measurable.
//!
//! Builds one dense base store plus N per-user trajectory logs (a mix of
//! dense, seed-batched, sparse SensZOQ, and shard-decomposed users),
//! drives Zipf-distributed request traffic through `serve::ServeStore`
//! across a cache-capacity sweep, and writes materializations/sec, cache
//! hit rate, and p50/p99 latency per capacity into `BENCH_serving.json`
//! (distilled into the committed trajectory by
//! `scripts/bench_summary.py`).
//!
//! The run doubles as a correctness smoke: for a sample of users it pins
//! the served parameters — cache on AND cache off — bitwise against a
//! fresh dense replay, and exits non-zero on any mismatch, which is how
//! `scripts/verify.sh` drives it under the `MEZO_THREADS` matrix.
//!
//! Knobs: `MEZO_BENCH_QUICK=1` shrinks the grid for CI smoke runs;
//! `MEZO_SERVE_USERS` / `MEZO_SERVE_REQS` override the population and
//! request count (verify.sh uses tiny values).

use mezo::model::meta::TensorDesc;
use mezo::model::params::ParamStore;
use mezo::obs::Histo;
use mezo::optim::mezo::StepRecord;
use mezo::rng::Pcg;
use mezo::serve::{ServeConfig, ServeStore, UserLog};
use mezo::shard::ShardPlan;
use mezo::storage::Trajectory;
use mezo::util::json::{obj, Json};
use mezo::util::stats::{summarize, Timer};
use mezo::zkernel::{Sensitivity, SparseMask};
use std::sync::Arc;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Zipf(s) sampler over ranks 1..=n via inverse-CDF binary search.
struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Zipf {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    fn sample(&self, rng: &mut Pcg) -> usize {
        let u = rng.next_f64();
        // first rank whose CDF covers u
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

fn base_store(d_per_tensor: usize) -> ParamStore {
    let specs = vec![
        TensorDesc { name: "emb".into(), shape: vec![d_per_tensor], dtype: "f32".into() },
        TensorDesc { name: "w1".into(), shape: vec![d_per_tensor], dtype: "f32".into() },
        TensorDesc { name: "w2".into(), shape: vec![d_per_tensor / 2], dtype: "f32".into() },
    ];
    let mut p = ParamStore::from_specs(specs);
    p.init(0xBA5E);
    p
}

fn random_records(rng: &mut Pcg, n: usize) -> Vec<StepRecord> {
    (0..n)
        .map(|_| StepRecord {
            seed: rng.next_u64(),
            pgrad: (rng.next_f32() - 0.5) * 0.2,
            lr: 1e-3,
        })
        .collect()
}

/// Build the tenant population: Zipf rank r maps to user id r. Mix of
/// replay modes — the cache must be bitwise-transparent to all of them.
fn admit_users(
    serve: &mut ServeStore,
    rng: &mut Pcg,
    n_users: usize,
    trainable: &[&str],
) -> anyhow::Result<()> {
    let base = Arc::clone(serve.base());
    let mask = Arc::new(
        SparseMask::top_k(&base, &[0, 1, 2], base.n_params() / 8, Sensitivity::Magnitude)
            .expect("top_k on the base store"),
    );
    let plan = Arc::new(ShardPlan::new(&base, 4).expect("4-way plan on the base store"));
    let names: Vec<String> = trainable.iter().map(|s| s.to_string()).collect();
    for user in 0..n_users as u64 {
        // log length 2..=8, a few KB per tenant — the whole point
        let n_recs = 2 + rng.below(7);
        let recs = random_records(rng, n_recs);
        let ulog = match rng.below(10) {
            // 60%: dense sequential
            0..=5 => UserLog::dense(Trajectory::from_run(names.clone(), &recs)),
            // 20%: dense, fused seed batches (an FZOO-style log)
            6..=7 => {
                let sps = if n_recs % 2 == 0 { 2 } else { 1 };
                UserLog::dense_batched(Trajectory::from_run(names.clone(), &recs), sps)
            }
            // 10%: sparse SensZOQ log + its mask
            8 => UserLog::masked(
                Trajectory::from_run(names.clone(), &recs).with_mask_digest(mask.digest()),
                Arc::clone(&mask),
            ),
            // 10%: shard-decomposed materialization
            _ => UserLog::sharded(Trajectory::from_run(names.clone(), &recs), Arc::clone(&plan)),
        };
        serve.admit(user, ulog)?;
    }
    Ok(())
}

/// Bitwise gate: served params (hit or miss path alike) == fresh dense
/// replay for a user sample. Returns false on any mismatch.
fn bitwise_gate(serve: &mut ServeStore, rng: &mut Pcg, n_users: usize, samples: usize) -> bool {
    for _ in 0..samples {
        let user = rng.below(n_users) as u64;
        let served = match serve.get(user) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve_scale: get({}) failed: {}", user, e);
                return false;
            }
        };
        let fresh = match serve.materialize_fresh(user) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve_scale: fresh({}) failed: {}", user, e);
                return false;
            }
        };
        let same = served
            .data
            .iter()
            .flatten()
            .map(|x| x.to_bits())
            .eq(fresh.data.iter().flatten().map(|x| x.to_bits()));
        if !same {
            eprintln!("serve_scale: user {} served bits != fresh dense replay", user);
            return false;
        }
    }
    true
}

fn main() {
    let quick = std::env::var("MEZO_BENCH_QUICK").is_ok_and(|v| v == "1");
    let n_users = env_usize("MEZO_SERVE_USERS", if quick { 2_000 } else { 20_000 });
    let n_reqs = env_usize("MEZO_SERVE_REQS", if quick { 8_000 } else { 60_000 });
    let d = if quick { 4_096 } else { 16_384 };
    let zipf_s = 1.1;
    let trainable = ["emb", "w1", "w2"];
    // capacity sweep: off, tight, and a working-set-sized cache
    let capacities = [0usize, (n_users / 64).max(1), (n_users / 8).max(2)];

    let mut rows: Vec<Json> = Vec::new();
    let mut bitwise_ok = true;
    let zipf = Zipf::new(n_users, zipf_s);

    for &cap in &capacities {
        let mut rng = Pcg::new(0x5E21E + cap as u64);
        let mut serve =
            ServeStore::new(base_store(d), ServeConfig { cache_capacity: cap });
        admit_users(&mut serve, &mut rng, n_users, &trainable).expect("admit population");

        // one Timer per request: the exact ns reading feeds BOTH the
        // float summary (the committed JSON keys) and an obs-layer
        // log2 histogram (the same type the serving spans feed), whose
        // coarse tail is reported alongside as hist_p99_ns
        let mut lat_ms: Vec<f64> = Vec::with_capacity(n_reqs);
        let lat_hist = Histo::new();
        let wall = Timer::start();
        for _ in 0..n_reqs {
            let user = zipf.sample(&mut rng) as u64;
            let t = Timer::start();
            serve.get(user).expect("serve a registered user");
            let ns = t.ns();
            lat_hist.record(ns);
            lat_ms.push(ns as f64 / 1e6);
        }
        let total_s = wall.secs();
        let st = serve.stats();
        let lat = summarize(&lat_ms);
        println!(
            "cap {:>6}: {:>8} reqs in {:>6.2}s | hit {:.3} | mats/s {:>9.1} | p50 {:.4}ms p99 {:.4}ms",
            cap,
            n_reqs,
            total_s,
            st.hit_rate(),
            st.materializations as f64 / total_s,
            lat.p50,
            lat.p99,
        );
        bitwise_ok &= bitwise_gate(&mut serve, &mut rng, n_users, if quick { 16 } else { 32 });
        rows.push(obj(vec![
            ("capacity", Json::from(cap)),
            ("requests", Json::from(n_reqs)),
            ("hit_rate", Json::from(st.hit_rate())),
            ("hits", Json::from(st.hits)),
            ("misses", Json::from(st.misses)),
            ("stale_refreshes", Json::from(st.stale)),
            ("evictions", Json::from(st.evictions)),
            ("base_served", Json::from(st.base_served)),
            ("materializations", Json::from(st.materializations)),
            ("materializations_per_sec", Json::from(st.materializations as f64 / total_s)),
            ("requests_per_sec", Json::from(n_reqs as f64 / total_s)),
            ("p50_ms", Json::from(lat.p50)),
            ("p90_ms", Json::from(lat.p90)),
            ("p99_ms", Json::from(lat.p99)),
            ("mean_ms", Json::from(lat.mean)),
            ("hist_p50_ns", Json::from(lat_hist.snapshot().p50() as f64)),
            ("hist_p99_ns", Json::from(lat_hist.snapshot().p99() as f64)),
        ]));
    }

    let report = obj(vec![
        ("source", Json::from("examples/serve_scale.rs")),
        ("quick_mode", Json::from(quick)),
        ("n_users", Json::from(n_users)),
        ("n_requests", Json::from(n_reqs)),
        ("base_params", Json::from(base_store(d).n_params())),
        ("zipf_s", Json::from(zipf_s)),
        (
            "hardware_threads",
            Json::from(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)),
        ),
        ("bitwise_ok", Json::from(bitwise_ok)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_serving.json", report.to_string()).expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json ({} capacities)", capacities.len());
    if !bitwise_ok {
        eprintln!("serve_scale: BITWISE GATE FAILED — served params drifted from fresh replay");
        std::process::exit(1);
    }
}
