//! K-way sharded replay: partition a trained model's parameter space,
//! hand each shard (plus the trajectory and the MZT3 manifest) to an
//! independent "worker", replay every shard separately, and gather a
//! model that is bit-for-bit the dense replay — fully offline (no pjrt
//! feature, no artifacts).
//!
//!     cargo run --release --example sharded_replay
//!     cargo run --release --example sharded_replay -- --shards 8 --steps 40
//!
//! This is the storage story of §2.1 scaled out: a fine-tune is a
//! (seed, pgrad, lr) log, and because every z-kernel reads z at global
//! counters, a worker holding only the coordinates in [start, end) can
//! reconstruct exactly its slice of every update. The MZT3 manifest
//! (plan digest + per-shard digests) guards the partition: a worker with
//! a different plan refuses to replay instead of silently scattering
//! updates onto the wrong coordinates.

use anyhow::Result;
use mezo::model::meta::TensorDesc;
use mezo::model::params::ParamStore;
use mezo::optim::mezo::{MezoConfig, MezoSgd};
use mezo::shard::{ShardManifest, ShardedStore};
use mezo::storage::Trajectory;
use mezo::util::args::Args;
use mezo::zkernel::ZEngine;

fn fresh_params() -> ParamStore {
    let mut p = ParamStore::from_specs(vec![
        TensorDesc { name: "embed".into(), shape: vec![96, 64], dtype: "f32".into() },
        TensorDesc { name: "w1".into(), shape: vec![64, 64], dtype: "f32".into() },
        TensorDesc { name: "w2".into(), shape: vec![777], dtype: "f32".into() },
    ]);
    p.init(0);
    p
}

fn quad(p: &ParamStore) -> f32 {
    p.data.iter().flatten().map(|&x| (x - 0.25) * (x - 0.25)).sum()
}

fn n_differing_coords(a: &ParamStore, b: &ParamStore) -> usize {
    a.data
        .iter()
        .flatten()
        .zip(b.data.iter().flatten())
        .filter(|(x, y)| x.to_bits() != y.to_bits())
        .count()
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let shards = args.usize("shards", 4).max(1);
    let steps = args.usize("steps", 25);
    let seed = args.u64("seed", 11);

    // --- train: a short dense MeZO run is the "published" fine-tune -----
    let mut trained = fresh_params();
    let names: Vec<String> = trained.specs.iter().map(|s| s.name.clone()).collect();
    let cfg = MezoConfig { lr: 5e-3, eps: 1e-3, n: 2, ..Default::default() };
    let mut opt = MezoSgd::new(cfg, vec![0, 1, 2], seed);
    for _ in 0..steps {
        opt.step(&mut trained, |p| Ok(quad(p)))?;
    }
    let traj = Trajectory::from_run(names, &opt.history);
    println!(
        "trained {} steps -> {} records ({} bytes quantized); publishing log + manifest",
        steps,
        traj.records.len(),
        traj.bytes_quantized()
    );

    // --- partition: the plan + its MZT3 manifest -----------------------
    let init = fresh_params();
    let plan = init.shard_plan(shards)?;
    let manifest_path = std::env::temp_dir().join("mezo_sharded_replay.mzt3");
    plan.manifest().save(&manifest_path)?;
    let manifest = ShardManifest::load(&manifest_path)?;
    std::fs::remove_file(&manifest_path).ok();
    println!("plan digest {:#018x}, {} shards:", plan.digest(), plan.n_shards());
    for (k, s) in plan.shards().iter().enumerate() {
        let segs: Vec<String> = s
            .segments
            .iter()
            .map(|g| format!("{}[{}..{}]", init.specs[g.tensor].name, g.lo, g.hi))
            .collect();
        println!(
            "  shard {}: coords {:>6}..{:<6} digest {:#018x}  {}",
            k,
            s.start,
            s.end,
            plan.shard_digest(k),
            segs.join(" + ")
        );
    }

    // --- replay: every shard independently, then gather ----------------
    let mut dense = fresh_params();
    traj.replay(&mut dense);
    let mut sharded = ShardedStore::scatter(&plan, &init)?;
    let engine = ZEngine::default();
    for k in 0..plan.n_shards() {
        // each iteration is one worker's whole job: log + manifest +
        // its own slice, nothing else
        traj.replay_shard_with(&engine, &mut sharded, &manifest, k)?;
    }
    let mut gathered = fresh_params();
    sharded.gather_into(&mut gathered)?;
    let diff = n_differing_coords(&dense, &gathered);
    println!(
        "gather after {}-way sharded replay vs dense replay: {} differing coordinates",
        shards, diff
    );
    assert_eq!(diff, 0, "sharded replay must be bitwise the dense replay");

    // seed-batched flavor: one fused pass per step per segment
    let mut sharded_b = ShardedStore::scatter(&plan, &init)?;
    traj.replay_sharded_batched(&mut sharded_b, &manifest, 2)?;
    let mut gathered_b = fresh_params();
    sharded_b.gather_into(&mut gathered_b)?;
    assert_eq!(n_differing_coords(&dense, &gathered_b), 0, "batched sharded replay diverged");
    println!("seed-batched sharded replay (n=2): bitwise identical too");

    // --- the guard: a wrong partition refuses loudly -------------------
    let wrong = init.shard_plan(shards + 1)?;
    let err = traj
        .replay_sharded(&mut ShardedStore::scatter(&wrong, &init)?, &manifest)
        .expect_err("a mismatched plan must not replay");
    println!("wrong plan errors as expected: {}", err);
    Ok(())
}
