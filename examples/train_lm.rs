//! End-to-end driver (DESIGN.md deliverable (b)): pre-train a transformer
//! LM on the synthetic corpus for a few hundred steps through the full
//! three-layer stack — rust coordinator → PJRT-compiled AOT artifact
//! (JAX model + Pallas kernels) — and log the loss curve, then show the
//! pre-trained model transferring zero-shot to a downstream prompt task.
//!
//!     cargo run --release --example train_lm -- --size small --steps 400
//!
//! Sizes: tiny (~0.14M params), small (~0.87M), base (~4.9M), large (~26M).
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use mezo::data::tasks::{generate, GenOpts, Task};
use mezo::eval::Evaluator;
use mezo::model::params::ParamStore;
use mezo::runtime::Runtime;
use mezo::tokenizer::Vocab;
use mezo::train::pretrain::{artifact_name, pretrain_into, PretrainCfg};
use mezo::util::args::Args;
use mezo::util::stats::Timer;

fn main() -> Result<()> {
    let args = Args::from_env();
    let family = args.str("family", "ar");
    let size = args.str("size", "small");
    let steps = args.usize("steps", 400);
    let lr = args.f32("lr", 3e-3);

    let rt = Runtime::from_env()?;
    let vocab = Vocab::standard();
    let grad_name = artifact_name(&family, &size, "grad", "full");
    let art = rt.load(&grad_name)?;
    println!(
        "model: {}-{}  ({} tensors, {:.2}M params)  artifact {}",
        family, size, art.meta.params.len(),
        art.meta.n_params as f64 / 1e6, grad_name
    );

    let mut params = ParamStore::from_meta(&art.meta);
    params.init(args.u64("seed", 42));
    let cfg = PretrainCfg { steps, lr, corpus_seqs: 2048, seed: args.u64("seed", 42) };
    let timer = Timer::start();
    let curve = pretrain_into(&rt, &family, &size, &mut params, &cfg)?;
    let secs = timer.secs();

    println!("\nloss curve ({} steps, {:.1}s, {:.1} ms/step):", steps, secs,
             1e3 * secs / steps as f64);
    for (s, l) in &curve {
        println!("  step {:>5}  lm loss {:.4}", s, l);
    }
    let first = curve.first().map(|x| x.1).unwrap_or(0.0);
    let last = curve.last().map(|x| x.1).unwrap_or(0.0);
    println!("final: {:.3} -> {:.3} (Δ {:.3})", first, last, first - last);

    // transfer check: zero-shot on the sentiment prompt
    let loss_art = rt.load(&artifact_name(&family, &size, "loss", "full"))?;
    let ev = Evaluator::new(loss_art, None, family == "mlm");
    let data = generate(Task::Sst2, &vocab,
                        GenOpts { n_test: 96, ..Default::default() });
    let zs = ev.evaluate(&params, Task::Sst2, &data.test)?.score;
    println!("zero-shot sst2 after pre-training: {:.3} (chance 0.5)", zs);

    if let Some(out) = args.opt("save") {
        params.save(std::path::Path::new(out))?;
        println!("checkpoint saved to {}", out);
    }
    Ok(())
}
