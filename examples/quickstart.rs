//! Quickstart: load a pre-trained tiny LM, evaluate zero-shot on the SST-2
//! analog, fine-tune with MeZO for a few hundred forward-pass-only steps,
//! and evaluate again — the paper's headline claim in one binary.
//!
//!     cargo run --release --example quickstart -- [--steps 400] [--task sst2]

use anyhow::Result;
use mezo::data::tasks::{generate, GenOpts, Task};
use mezo::eval::Evaluator;
use mezo::optim::mezo::{MezoConfig, MezoSgd};
use mezo::optim::{MezoStepper, ZoStepper};
use mezo::train::pretrain::{artifact_name, pretrained, params_for, PretrainCfg};
use mezo::train::{train_zo, TrainCfg};
use mezo::runtime::Runtime;
use mezo::tokenizer::Vocab;
use mezo::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.usize("steps", 400);
    let task = Task::from_name(&args.str("task", "sst2")).expect("unknown task");
    let family = args.str("family", "ar");
    let size = args.str("size", "tiny");
    let lr = args.f32("lr", 2e-3);
    let pre_steps = args.usize("pretrain-steps", 800);

    let rt = Runtime::from_env()?;
    let vocab = Vocab::standard();
    println!("== pre-training {}/{} on the synthetic corpus (cached) ==", family, size);
    let (_params, curve) = pretrained(&rt, &family, &size,
        &PretrainCfg { steps: pre_steps, ..Default::default() })?;
    if let Some(last) = curve.last() {
        println!("pretrain loss: {:.3} -> {:.3}", curve[0].1, last.1);
    } else {
        println!("(loaded cached checkpoint)");
    }

    let loss_art = rt.load(&artifact_name(&family, &size, "loss", "full"))?;
    let logits_art = rt.load(&artifact_name(&family, &size, "logits", "full"))?;
    let mut params = params_for(&rt, &loss_art.meta.name, &family, &size, 0)?;
    let evaluator = Evaluator::new(loss_art.clone(), Some(logits_art), family == "mlm");

    let data = generate(task, &vocab, GenOpts { n_train: 64, n_val: 64, n_test: 128, ..Default::default() });
    let zs = evaluator.evaluate(&params, task, &data.test)?.score;
    println!("zero-shot {}: {:.3}", task.name(), zs);

    println!("== MeZO fine-tuning: {} steps, 2 forward passes each, no backprop ==", steps);
    let trainable = params.indices_of(&loss_art.meta.trainable);
    let cfg = MezoConfig { lr, eps: 1e-3, total_steps: steps, ..Default::default() };
    let mut opt = MezoStepper::new(MezoSgd::new(cfg, trainable, 7));
    let tcfg = TrainCfg { steps, eval_every: steps / 4, seed: 1, ..Default::default() };
    let res = train_zo(&mut opt, &mut params, &loss_art, &evaluator, task,
                       &data.train, &data.val, &tcfg)?;
    for (s, l) in res.curve.iter().step_by(4) {
        println!("  step {:>5}  train loss {:.4}", s, l);
    }
    let ft = evaluator.evaluate(&params, task, &data.test)?.score;
    println!("MeZO {}: {:.3}  (zero-shot was {:.3}; {} forward passes)",
             task.name(), ft, zs, res.forward_passes);
    Ok(())
}
