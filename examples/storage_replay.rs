//! §2.1 "Storage Efficiency of MeZO": a fine-tuning run is reconstructible
//! from (initial checkpoint, one (seed, grad) pair per step) — kilobytes
//! instead of a full model checkpoint, with NO forward passes and NO access
//! to the training data at replay time.
//!
//!     cargo run --release --example storage_replay -- --steps 300

use anyhow::Result;
use mezo::data::tasks::{generate, GenOpts, Task};
use mezo::eval::Evaluator;
use mezo::optim::mezo::{MezoConfig, MezoSgd};
use mezo::runtime::Runtime;
use mezo::storage::Trajectory;
use mezo::tokenizer::Vocab;
use mezo::train::pretrain::{artifact_name, params_for, pretrained, PretrainCfg};
use mezo::train::batch_loss;
use mezo::data::batch::sample_batch;
use mezo::rng::Pcg;
use mezo::util::args::Args;
use mezo::util::stats::Timer;

fn main() -> Result<()> {
    let args = Args::from_env();
    let (family, size) = ("ar", "tiny");
    let steps = args.usize("steps", 300);
    let rt = Runtime::from_env()?;
    let vocab = Vocab::standard();
    pretrained(&rt, family, size, &PretrainCfg::default())?;
    let loss_art = rt.load(&artifact_name(family, size, "loss", "full"))?;
    let task = Task::Sst2;
    let data = generate(task, &vocab, GenOpts { n_train: 128, ..Default::default() });

    // --- train with MeZO, logging the trajectory -------------------------
    let mut params = params_for(&rt, &loss_art.meta.name, family, size, 0)?;
    let trainable = params.indices_of(&loss_art.meta.trainable);
    let cfg = MezoConfig { lr: 1e-4, eps: 1e-3, total_steps: steps, ..Default::default() };
    let mut opt = MezoSgd::new(cfg, trainable, 21);
    let mut rng = Pcg::new(3);
    let (b, s) = (loss_art.meta.batch, loss_art.meta.seq);
    let t = Timer::start();
    for _ in 0..steps {
        let batch = sample_batch(&data.train, &mut rng, b, s, false);
        opt.step(&mut params, |p| batch_loss(&loss_art, p, &batch))?;
    }
    println!("trained {} MeZO steps in {:.1}s ({} forward passes)",
             steps, t.secs(), 2 * steps);

    // --- persist the trajectory -----------------------------------------
    let traj = Trajectory::from_run(loss_art.meta.trainable.clone(), &opt.history);
    let path = std::path::PathBuf::from("runs").join("demo_trajectory.bin");
    traj.save(&path)?;
    let ckpt_bytes = 4 * params.n_params();
    println!(
        "trajectory: {} records, {} bytes on disk (f32) / {} bytes quantized — vs {} bytes for a full checkpoint ({}x smaller)",
        traj.records.len(),
        traj.bytes_f32(),
        traj.bytes_quantized(),
        ckpt_bytes,
        ckpt_bytes / traj.bytes_quantized().max(1)
    );

    // --- replay from the initial checkpoint, data-free -------------------
    let loaded = Trajectory::load(&path)?;
    let mut replayed = params_for(&rt, &loss_art.meta.name, family, size, 0)?;
    let t = Timer::start();
    loaded.replay(&mut replayed);
    println!("replayed {} updates in {:.2}s (0 forward passes, 0 data reads)",
             loaded.records.len(), t.secs());

    // --- verify -----------------------------------------------------------
    let mut max_diff = 0.0f32;
    for (a, b) in params.data.iter().flatten().zip(replayed.data.iter().flatten()) {
        max_diff = max_diff.max((a - b).abs());
    }
    println!("max |trained - replayed| = {:.2e}  (float rounding of the ±ε passes)", max_diff);
    let ev = Evaluator::new(loss_art.clone(), None, false);
    let acc_trained = ev.evaluate(&params, task, &data.test)?.score;
    let acc_replayed = ev.evaluate(&replayed, task, &data.test)?.score;
    println!("test accuracy: trained {:.4} vs replayed {:.4}", acc_trained, acc_replayed);
    assert!(max_diff < 1e-3, "replay deviated");
    assert!((acc_trained - acc_replayed).abs() < 1e-6);
    println!("OK: the checkpoint was reconstructed from {} bytes", traj.bytes_f32());
    Ok(())
}
