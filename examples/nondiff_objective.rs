//! §3.3: optimize a NON-DIFFERENTIABLE objective with MeZO.
//!
//! Backpropagation cannot minimize "1 − accuracy" — there is no gradient.
//! MeZO only needs two evaluations of the objective per step, so it can.
//! This example fine-tunes the tiny AR model on the SST-2 analog by
//! directly maximizing minibatch accuracy, then (optionally) token-F1 on
//! the SQuAD analog.
//!
//!     cargo run --release --example nondiff_objective -- --steps 600

use anyhow::Result;
use mezo::data::tasks::{generate, GenOpts, Task};
use mezo::eval::Evaluator;
use mezo::optim::mezo::{MezoConfig, MezoSgd};
use mezo::optim::MezoStepper;
use mezo::runtime::Runtime;
use mezo::tokenizer::Vocab;
use mezo::train::pretrain::{artifact_name, params_for, pretrained, PretrainCfg};
use mezo::train::{train_zo, Objective, TrainCfg};
use mezo::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let family = args.str("family", "ar");
    let size = args.str("size", "tiny");
    let steps = args.usize("steps", 600);
    let rt = Runtime::from_env()?;
    let vocab = Vocab::standard();
    pretrained(&rt, &family, &size, &PretrainCfg::default())?;

    let loss_art = rt.load(&artifact_name(&family, &size, "loss", "full"))?;
    let logits_art = rt.load(&artifact_name(&family, &size, "logits", "full"))?;
    let ev = Evaluator::new(loss_art.clone(), Some(logits_art), family == "mlm");

    for (task, objective, label) in [
        (Task::Sst2, Objective::NegAccuracy, "accuracy"),
        (Task::Squad, Objective::NegF1, "token-F1"),
    ] {
        let data = generate(task, &vocab,
                            GenOpts { n_train: 128, n_val: 64, n_test: 96, ..Default::default() });
        let mut params = params_for(&rt, &loss_art.meta.name, &family, &size, 0)?;
        let before = ev.evaluate(&params, task, &data.test)?.score;
        let trainable = params.indices_of(&loss_art.meta.trainable);
        let cfg = MezoConfig {
            lr: args.f32("lr", 1e-4),
            eps: args.f32("eps", 1e-2), // accuracy is flat at tiny eps
            total_steps: steps,
            ..Default::default()
        };
        let mut opt = MezoStepper::new(MezoSgd::new(cfg, trainable, 11));
        let tcfg = TrainCfg {
            steps,
            eval_every: (steps / 4).max(1),
            objective,
            nondiff_batch: 16,
            ..Default::default()
        };
        train_zo(&mut opt, &mut params, &loss_art, &ev, task,
                 &data.train, &data.val, &tcfg)?;
        let after = ev.evaluate(&params, task, &data.test)?.score;
        println!(
            "{:>6} | objective = 1 - {}: test {:.3} -> {:.3} (no gradients were computed)",
            task.name(), label, before, after
        );
    }
    Ok(())
}
