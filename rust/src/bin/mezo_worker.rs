//! `mezo-worker`: one shard worker of the MZW1 fleet, on a TCP socket.
//!
//! A thin process wrapper around [`mezo::wire::ShardWorker`]: it moves
//! frames, the library moves coordinates. Two modes:
//!
//! * `mezo-worker --connect HOST:PORT` — dial the coordinator, serve
//!   one session, exit when the coordinator disconnects or sends
//!   Shutdown. This is what `wire::Fleet`-driven process fleets (and
//!   the churn tests) spawn per shard.
//! * `mezo-worker --listen HOST:PORT` — bind and serve inbound
//!   coordinator sessions one at a time, forever (a long-lived worker
//!   host; each session gets a fresh worker state).
//!
//! `--timeout-ms N` bounds each frame read (default: block forever);
//! on expiry the worker exits nonzero, so an orphaned worker whose
//! coordinator died mid-command does not linger.
//!
//! `--metrics-dump` prints a `Registry::render_text` Prometheus
//! snapshot to stderr on the orderly shutdown path: after the session
//! in `--connect` mode, after EVERY completed session in `--listen`
//! mode. (The substrate is pure stdlib, so there is no SIGTERM handler
//! to hook — a supervisor that wants a final scrape sends Shutdown or
//! closes the connection rather than SIGKILL.) Metric levels come from
//! `MEZO_OBS` as everywhere else.
//!
//! Thread count / SIMD tier come from the usual `MEZO_THREADS` /
//! `MEZO_SIMD` environment, so a fleet inherits the verify matrix.

use anyhow::{bail, Result};
use mezo::obs;
use mezo::util::args::Args;
use mezo::wire::{ShardWorker, TcpTransport};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

fn main() -> Result<()> {
    let args = Args::from_env();
    let timeout = args
        .flags
        .get("timeout-ms")
        .map(|s| {
            s.parse::<u64>()
                .map(Duration::from_millis)
                .map_err(|_| anyhow::anyhow!("--timeout-ms takes an integer, got '{}'", s))
        })
        .transpose()?;
    let metrics_dump = args.bool("metrics-dump", false);

    match (args.flags.get("connect"), args.flags.get("listen")) {
        (Some(addr), None) => {
            let stream = TcpStream::connect(addr.as_str())
                .map_err(|e| anyhow::anyhow!("mezo-worker: connect {}: {}", addr, e))?;
            let mut transport = TcpTransport::new(stream, timeout)?;
            let served = ShardWorker::new().serve(&mut transport);
            if metrics_dump {
                eprint!("{}", obs::Registry::render_text());
            }
            served?;
            Ok(())
        }
        (None, Some(addr)) => {
            let listener = TcpListener::bind(addr.as_str())
                .map_err(|e| anyhow::anyhow!("mezo-worker: bind {}: {}", addr, e))?;
            // the bound address on stdout lets a spawner use port 0
            println!("mezo-worker: listening on {}", listener.local_addr()?);
            for stream in listener.incoming() {
                let mut transport = TcpTransport::new(stream?, timeout)?;
                if let Err(e) = ShardWorker::new().serve(&mut transport) {
                    obs::event::warn(
                        "mezo-worker",
                        &format!("mezo-worker: session ended: {}", e),
                    );
                }
                if metrics_dump {
                    eprint!("{}", obs::Registry::render_text());
                }
            }
            Ok(())
        }
        _ => bail!(
            "usage: mezo-worker (--connect HOST:PORT | --listen HOST:PORT) \
             [--timeout-ms N] [--metrics-dump]"
        ),
    }
}
