//! Pre-training on the synthetic corpus (DESIGN.md §2.1).
//!
//! The paper fine-tunes LMs that were already pre-trained at web scale; we
//! reproduce the *mechanism* by pre-training each model family on the
//! structured corpus with backprop Adam (via the AOT grad artifact), then
//! caching the checkpoint. Every downstream experiment starts from this
//! checkpoint — including the prompt/no-prompt ablation that shows why
//! pre-training + prompts is what makes MeZO work.

use crate::data::batch::lm_batch;
use crate::data::corpus::pack_sequences;
use crate::model::params::ParamStore;
use crate::optim::ft::{FtConfig, FtFlavor, FtOptimizer};
use crate::rng::Pcg;
use crate::runtime::{scalar_f32, vec_f32, Runtime};
use crate::tokenizer::Vocab;
use anyhow::Result;
use std::path::PathBuf;

/// Pre-training schedule: backprop Adam on the synthetic corpus.
#[derive(Debug, Clone)]
pub struct PretrainCfg {
    /// Adam steps over the packed corpus
    pub steps: usize,
    /// peak learning rate (linear decay to 0 over `steps`)
    pub lr: f32,
    /// sequences to pack from the synthetic corpus
    pub corpus_seqs: usize,
    /// seed for init, corpus generation, and batch sampling
    pub seed: u64,
}

impl Default for PretrainCfg {
    fn default() -> Self {
        PretrainCfg { steps: 1200, lr: 3e-3, corpus_seqs: 2048, seed: 42 }
    }
}

/// Canonical AOT artifact name for a (family, size, mode, tuning) cell,
/// e.g. `ar_small_full_loss_b8_s64`.
pub fn artifact_name(family: &str, size: &str, mode: &str, tuning: &str) -> String {
    format!("{}_{}_{}_{}_b8_s64", family, size, tuning, mode)
}

/// Where the cached pre-trained checkpoint for `family`/`size` lives
/// (under `$MEZO_RUNS`, default `runs/`).
pub fn checkpoint_path(family: &str, size: &str) -> PathBuf {
    let dir = std::env::var("MEZO_RUNS").unwrap_or_else(|_| "runs".to_string());
    PathBuf::from(dir).join(format!("pretrained_{}_{}.ckpt", family, size))
}

/// Pre-train (or load the cached checkpoint for) `family`/`size`.
/// Returns (params-of-the-full-model, final LM loss curve if trained).
pub fn pretrained(
    rt: &Runtime,
    family: &str,
    size: &str,
    cfg: &PretrainCfg,
) -> Result<(ParamStore, Vec<(usize, f32)>)> {
    let grad_name = artifact_name(family, size, "grad", "full");
    let art = rt.load(&grad_name)?;
    let mut params = ParamStore::from_meta(&art.meta);
    params.init(cfg.seed);

    let ckpt = checkpoint_path(family, size);
    if ckpt.exists() {
        params.load_into(&ckpt)?;
        return Ok((params, Vec::new()));
    }

    let curve = pretrain_into(rt, family, size, &mut params, cfg)?;
    params.save(&ckpt)?;
    Ok((params, curve))
}

/// Run the pre-training loop into an existing store (used by train_lm
/// example with custom sizes and by tests).
pub fn pretrain_into(
    rt: &Runtime,
    family: &str,
    size: &str,
    params: &mut ParamStore,
    cfg: &PretrainCfg,
) -> Result<Vec<(usize, f32)>> {
    let grad_name = artifact_name(family, size, "grad", "full");
    let art = rt.load(&grad_name)?;
    let (b, s) = (art.meta.batch, art.meta.seq);
    let mlm = family == "mlm";
    let vocab = Vocab::standard();
    let mut corpus_rng = Pcg::new(cfg.seed ^ 0xC0FFEE);
    let seqs = pack_sequences(&mut corpus_rng, &vocab, cfg.corpus_seqs, s);

    let trainable = params.indices_of(&art.meta.trainable);
    let ft_cfg = FtConfig {
        lr: cfg.lr,
        flavor: FtFlavor::Adam,
        linear_decay: true,
        total_steps: cfg.steps,
        weight_decay: 0.0,
        ..Default::default()
    };
    let mut opt = FtOptimizer::new(ft_cfg, trainable, params);
    let mut batch_rng = Pcg::new(cfg.seed ^ 0xBA7C4);
    let mut curve = Vec::new();
    for step in 0..cfg.steps {
        let batch = lm_batch(&seqs, &mut batch_rng, b, s, mlm);
        let out = art.run(params, Some(&batch), &[])?;
        let loss = scalar_f32(&out[0])?;
        let grads: Vec<Vec<f32>> =
            out[1..].iter().map(vec_f32).collect::<Result<Vec<_>>>()?;
        opt.apply(params, &grads)?;
        if step % 25 == 0 || step + 1 == cfg.steps {
            curve.push((step, loss));
        }
    }
    Ok(curve)
}

/// Copy a pretrained full-model checkpoint into a (possibly PEFT-extended)
/// store built from another artifact's meta, initialising any extra tensors.
pub fn params_for(
    rt: &Runtime,
    art_name: &str,
    family: &str,
    size: &str,
    seed: u64,
) -> Result<ParamStore> {
    let art = rt.load(art_name)?;
    let mut params = ParamStore::from_meta(&art.meta);
    params.init(seed);
    let ckpt = checkpoint_path(family, size);
    if ckpt.exists() {
        params.load_into(&ckpt)?;
    }
    Ok(params)
}
