//! Training orchestration (the L3 coordinator loop).
//!
//! `train_zo` drives any [`ZoStepper`] (MeZO and all its variants) against
//! an objective evaluated *only through forward passes*; `train_ft` drives
//! the backprop baseline through the AOT grad artifact. Both share batch
//! sampling, periodic validation, and best-checkpoint tracking, matching
//! the paper's protocol (Appendix E.3: constant LR + best-val checkpoint
//! for MeZO; linear-decay LR for FT).

pub mod pretrain;

use crate::data::batch::{sample_batch, Batch};
use crate::data::tasks::{Example, Task};
use crate::eval::Evaluator;
use crate::model::params::ParamStore;
use crate::optim::ft::FtOptimizer;
use crate::optim::ZoStepper;
use crate::rng::Pcg;
use crate::runtime::{scalar_f32, vec_f32, Artifact};
use anyhow::Result;
use std::rc::Rc;

/// What MeZO minimizes. CrossEntropy is the standard objective; the other
/// two are the paper's §3.3 *non-differentiable* objectives, computable
/// only because MeZO never needs a gradient.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Minibatch cross-entropy loss (the standard differentiable objective)
    CrossEntropy,
    /// 1 − accuracy on the sampled minibatch (classification)
    NegAccuracy,
    /// 1 − token-F1 on the sampled minibatch (generation)
    NegF1,
}

/// Knobs shared by [`train_zo`] and [`train_ft`]: how long to run, how
/// often to validate, and what to minimize.
#[derive(Debug, Clone)]
pub struct TrainCfg {
    /// optimizer steps to run
    pub steps: usize,
    /// validate (and best-checkpoint) every this many steps; 0 = final only
    pub eval_every: usize,
    /// base seed for batch sampling (independent of the optimizer's z seeds)
    pub seed: u64,
    /// what the run minimizes — see [`Objective`]
    pub objective: Objective,
    /// examples per accuracy/F1 objective evaluation
    pub nondiff_batch: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 400,
            eval_every: 100,
            seed: 0,
            objective: Objective::CrossEntropy,
            nondiff_batch: 16,
        }
    }
}

/// What a training run produced: curves, the best validation score (whose
/// checkpoint is restored into `params` on return), and the forward-pass
/// count — the paper's cost axis.
#[derive(Debug, Clone, Default)]
pub struct TrainResult {
    /// (step, train loss) curve
    pub curve: Vec<(usize, f32)>,
    /// (step, val score) curve
    pub val_curve: Vec<(usize, f64)>,
    /// best validation score seen; its parameters are restored on return
    pub best_val: f64,
    /// total forward passes spent (FT counts each grad step as one)
    pub forward_passes: usize,
}

/// Loss of the current parameters on one batch via the loss artifact.
pub fn batch_loss(art: &Artifact, params: &ParamStore, batch: &Batch) -> Result<f32> {
    let out = art.run(params, Some(batch), &[])?;
    scalar_f32(&out[0])
}

/// Train with a zeroth-order optimizer. Restores the best-validation
/// parameters into `params` before returning (paper's early-stop protocol).
#[allow(clippy::too_many_arguments)]
pub fn train_zo(
    opt: &mut dyn ZoStepper,
    params: &mut ParamStore,
    loss_art: &Rc<Artifact>,
    evaluator: &Evaluator,
    task: Task,
    train: &[Example],
    val: &[Example],
    cfg: &TrainCfg,
) -> Result<TrainResult> {
    let mlm = evaluator.mlm;
    let (b, s) = (loss_art.meta.batch, loss_art.meta.seq);
    let mut rng = Pcg::new(cfg.seed ^ 0xBEEF);
    let mut res = TrainResult { best_val: f64::NEG_INFINITY, ..Default::default() };
    let mut best_params: Option<ParamStore> = None;

    for step in 0..cfg.steps {
        let loss = match cfg.objective {
            Objective::CrossEntropy => {
                let batch = sample_batch(train, &mut rng, b, s, mlm);
                // prefer the fused perturb-on-upload fast path (§Perf L3)
                match opt.zo_step_artifact(params, loss_art, &batch) {
                    Some(r) => r?,
                    None => {
                        let mut f = |p: &ParamStore| batch_loss(loss_art, p, &batch);
                        opt.zo_step(params, &mut f)?
                    }
                }
            }
            Objective::NegAccuracy | Objective::NegF1 => {
                // sample a fixed minibatch of examples for this step
                let idxs = rng.sample_indices(train.len(), cfg.nondiff_batch.min(train.len()));
                let exs: Vec<Example> = idxs.iter().map(|&i| train[i].clone()).collect();
                let objective = cfg.objective;
                let mut f = |p: &ParamStore| -> Result<f32> {
                    let r = evaluate_subset(evaluator, p, task, &exs, objective)?;
                    Ok(1.0 - r as f32)
                };
                opt.zo_step(params, &mut f)?
            }
        };
        if step % 20 == 0 || step + 1 == cfg.steps {
            res.curve.push((step, loss));
        }
        if (cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0) || step + 1 == cfg.steps {
            let v = evaluator.evaluate(params, task, val)?.score;
            res.val_curve.push((step + 1, v));
            if v > res.best_val {
                res.best_val = v;
                let mut copy = ParamStore::from_specs(params.specs.clone());
                copy.copy_from(params);
                best_params = Some(copy);
            }
        }
    }
    if let Some(bp) = best_params {
        params.copy_from(&bp);
    }
    res.forward_passes = opt.forward_passes();
    Ok(res)
}

fn evaluate_subset(
    evaluator: &Evaluator,
    params: &ParamStore,
    task: Task,
    exs: &[Example],
    objective: Objective,
) -> Result<f64> {
    match objective {
        Objective::NegF1 => {
            let r = evaluator.evaluate(params, task, exs)?;
            Ok(r.score)
        }
        _ => {
            let refs: Vec<&Example> = exs.iter().collect();
            let preds = evaluator.predict(params, &refs)?;
            let golds: Vec<usize> = exs.iter().map(|e| e.label).collect();
            Ok(crate::eval::metrics::accuracy(&preds, &golds))
        }
    }
}

/// Train with backpropagation via the grad artifact (the FT baseline).
#[allow(clippy::too_many_arguments)]
pub fn train_ft(
    opt: &mut FtOptimizer,
    params: &mut ParamStore,
    grad_art: &Rc<Artifact>,
    evaluator: &Evaluator,
    task: Task,
    train: &[Example],
    val: &[Example],
    cfg: &TrainCfg,
) -> Result<TrainResult> {
    let mlm = evaluator.mlm;
    let (b, s) = (grad_art.meta.batch, grad_art.meta.seq);
    let mut rng = Pcg::new(cfg.seed ^ 0xFEED);
    let mut res = TrainResult { best_val: f64::NEG_INFINITY, ..Default::default() };
    let mut best_params: Option<ParamStore> = None;

    for step in 0..cfg.steps {
        let batch = sample_batch(train, &mut rng, b, s, mlm);
        let out = grad_art.run(params, Some(&batch), &[])?;
        let loss = scalar_f32(&out[0])?;
        let grads: Vec<Vec<f32>> =
            out[1..].iter().map(vec_f32).collect::<Result<Vec<_>>>()?;
        opt.apply(params, &grads)?;
        if step % 20 == 0 || step + 1 == cfg.steps {
            res.curve.push((step, loss));
        }
        if (cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0) || step + 1 == cfg.steps {
            let v = evaluator.evaluate(params, task, val)?.score;
            res.val_curve.push((step + 1, v));
            if v > res.best_val {
                res.best_val = v;
                let mut copy = ParamStore::from_specs(params.specs.clone());
                copy.copy_from(params);
                best_params = Some(copy);
            }
        }
    }
    if let Some(bp) = best_params {
        params.copy_from(&bp);
    }
    res.forward_passes = cfg.steps; // each grad step ≈ fwd+bwd
    Ok(res)
}
