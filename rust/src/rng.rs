//! Deterministic RNG substrate (no `rand` crate in the offline set).
//!
//! Two generators:
//!  * [`Pcg`] — splitmix64-seeded xorshift-multiply stream for data
//!    generation, sampling, shuffling.
//!  * [`GaussianStream`] — a **counter-based** standard-normal stream keyed
//!    by `(seed, index)`. This is the core device of MeZO (Algorithm 1):
//!    the perturbation `z ~ N(0, I_d)` is never stored; each of its four
//!    uses re-generates the same coordinates from the same seed, and because
//!    the stream is counter-based (random access by index) the perturb /
//!    restore / update passes can walk parameter tensors independently and
//!    in parallel while remaining bit-identical.

/// splitmix64 — used for seeding and as the per-counter mixing function.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Sequential PRNG (xoshiro256++-style quality is unnecessary here; a
/// splitmix64 walk passes the statistical needs of data generation).
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
}

impl Pcg {
    /// New generator; distinct seeds give decorrelated streams.
    pub fn new(seed: u64) -> Pcg {
        Pcg { state: splitmix64(seed ^ 0xD1B54A32D192ED03) }
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) at f32 precision.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n), bias-free: `next_u64() % n` alone favors
    /// small residues once n doesn't divide 2^64, so draws outside the
    /// largest multiple of n are rejected and redrawn (expected < 2 draws
    /// for any n; exactly 1 for powers of two up to a 2^-63 sliver).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n; // largest multiple of n <= 2^64
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal draw with the given mean and standard deviation.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Uniformly chosen element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

// ---------------------------------------------------------------------
// Ziggurat tables (Doornik's ZIGNOR, 128 layers) — §Perf L3 iteration 1:
// the Box–Muller stream cost 65ns/coordinate (ln+sqrt+cos) and dominated
// the MeZO step at large sizes (4 passes over d). The ziggurat takes the
// no-transcendental fast path ~98.5% of the time.
// ---------------------------------------------------------------------

const ZIG_C: usize = 128;
const ZIG_R: f64 = 3.442619855899;
const ZIG_V: f64 = 9.91256303526217e-3;

struct ZigTables {
    x: [f64; ZIG_C + 1],
    r: [f64; ZIG_C],
}

fn zig_tables() -> &'static ZigTables {
    use std::sync::OnceLock;
    static TABLES: OnceLock<ZigTables> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut x = [0.0f64; ZIG_C + 1];
        let f = (-0.5 * ZIG_R * ZIG_R).exp();
        x[0] = ZIG_V / f;
        x[1] = ZIG_R;
        x[ZIG_C] = 0.0;
        for i in 2..ZIG_C {
            x[i] = (-2.0 * (ZIG_V / x[i - 1] + (-0.5 * x[i - 1] * x[i - 1]).exp()).ln()).sqrt();
        }
        let mut r = [0.0f64; ZIG_C];
        for i in 0..ZIG_C {
            r[i] = x[i + 1] / x[i];
        }
        ZigTables { x, r }
    })
}

#[inline]
fn unit_open(v: u64) -> f64 {
    // uniform in (0, 1)
    ((v >> 11) as f64 + 0.5) * (1.0 / (1u64 << 53) as f64)
}

#[inline]
fn signed_unit(v: u64) -> f64 {
    // uniform in (-1, 1)
    unit_open(v) * 2.0 - 1.0
}

/// Counter-based standard-normal stream: `z(i)` is a pure function of
/// `(seed, i)` — random access, so MeZO's four uses of the same z
/// regenerate identical coordinates without ever storing the vector.
/// Sampling is ziggurat (ZIGNOR); rejection retries advance a
/// deterministic splitmix64 chain keyed by the counter, preserving purity.
#[derive(Debug, Clone, Copy)]
pub struct GaussianStream {
    seed: u64,
}

impl GaussianStream {
    /// New stream; the same seed always denotes the same z vector.
    pub fn new(seed: u64) -> GaussianStream {
        GaussianStream { seed: splitmix64(seed ^ 0xA0761D6478BD642F) }
    }

    /// i-th standard normal coordinate of z.
    #[inline]
    pub fn z(&self, i: u64) -> f32 {
        z_at(zig_tables(), self.seed, i)
    }

    /// Fill `out` with coordinates [offset, offset+len) of z — the blocked
    /// primitive under `zkernel`. The ziggurat tables are resolved ONCE per
    /// call instead of once per coordinate (the per-`z()` `OnceLock` load
    /// is the dispatch overhead the block amortizes), and the slow paths
    /// are kept out of the hot loop so it vectorizes.
    pub fn fill(&self, out: &mut [f32], offset: u64) {
        let t = zig_tables();
        let seed = self.seed;
        for (j, o) in out.iter_mut().enumerate() {
            *o = z_at(t, seed, offset + j as u64);
        }
    }

    /// As [`GaussianStream::fill`], with an opt-in SIMD body: when `simd`
    /// is set and the CPU/build can run it, the splitmix64 counter mixing
    /// and the `u ∈ (−1, 1)` candidate computation run 8 lanes wide under
    /// AVX-512 (the 64-bit lane multiplies need AVX-512DQ — there is no
    /// AVX2/NEON fill tier), with the per-lane ziggurat table finish kept
    /// scalar. Bit-identical to [`GaussianStream::fill`] in all cases:
    /// integer lane ops are exact, `u64→f64` conversion is exact below
    /// 2^53, and each `f64` vector op is the same single correctly-rounded
    /// IEEE operation the scalar path performs in the same order (pinned
    /// in this module's tests). Falls back to the scalar fill when the
    /// body can't run; the `simd` flag comes from the engine's SIMD tier
    /// (`zkernel::Tier::simd_fill`), so `MEZO_SIMD=scalar` benches the
    /// true scalar path.
    pub fn fill_dispatch(&self, out: &mut [f32], offset: u64, simd: bool) {
        #[cfg(all(target_arch = "x86_64", mezo_avx512))]
        {
            if simd
                && is_x86_feature_detected!("avx512f")
                && is_x86_feature_detected!("avx512dq")
            {
                // SAFETY: avx512f+avx512dq verified just above.
                unsafe { fill_avx512(zig_tables(), self.seed, out, offset) };
                return;
            }
        }
        let _ = simd;
        self.fill(out, offset);
    }
}

/// AVX-512 body of [`GaussianStream::fill_dispatch`]: 8 × u64 lanes of
/// counter mixing + uniform-candidate math, scalar ziggurat finish per
/// lane. Every lane performs exactly the scalar `z_at` fast-path ops in
/// the same order; slow-path lanes defer to the shared `z_slow`.
#[cfg(all(target_arch = "x86_64", mezo_avx512))]
#[target_feature(enable = "avx512f,avx512dq")]
unsafe fn fill_avx512(t: &ZigTables, seed: u64, out: &mut [f32], offset: u64) {
    use core::arch::x86_64::*;
    // counter multiplier of z_at + the splitmix64 constants
    const M: u64 = 0x8CB92BA72F3D8DD7;
    const S1: u64 = 0x9E3779B97F4A7C15;
    const M2: u64 = 0xBF58476D1CE4E5B9;
    const M3: u64 = 0x94D049BB133111EB;
    let seed_v = _mm512_set1_epi64(seed as i64);
    let m_v = _mm512_set1_epi64(M as i64);
    let s1_v = _mm512_set1_epi64(S1 as i64);
    let m2_v = _mm512_set1_epi64(M2 as i64);
    let m3_v = _mm512_set1_epi64(M3 as i64);
    let half = _mm512_set1_pd(0.5);
    let inv53 = _mm512_set1_pd(1.0 / (1u64 << 53) as f64);
    let two = _mm512_set1_pd(2.0);
    let one = _mm512_set1_pd(1.0);
    let n = out.len();
    let mut j = 0;
    while j + 8 <= n {
        let base = offset + j as u64;
        let idx: [u64; 8] =
            [base, base + 1, base + 2, base + 3, base + 4, base + 5, base + 6, base + 7];
        let i_v = core::mem::transmute::<[u64; 8], __m512i>(idx);
        // e = splitmix64(seed ^ i·M), lane-wise (wrapping by construction)
        let x = _mm512_add_epi64(_mm512_xor_epi64(seed_v, _mm512_mullo_epi64(i_v, m_v)), s1_v);
        let z = _mm512_mullo_epi64(_mm512_xor_epi64(x, _mm512_srli_epi64::<30>(x)), m2_v);
        let z = _mm512_mullo_epi64(_mm512_xor_epi64(z, _mm512_srli_epi64::<27>(z)), m3_v);
        let e_v = _mm512_xor_epi64(z, _mm512_srli_epi64::<31>(z));
        // u = ((e>>11) + 0.5)·2⁻⁵³·2 − 1 — signed_unit's exact op order;
        // the u64→f64 conversion is exact (operand < 2^53)
        let d = _mm512_cvtepu64_pd(_mm512_srli_epi64::<11>(e_v));
        let u_v = _mm512_sub_pd(_mm512_mul_pd(_mm512_mul_pd(_mm512_add_pd(d, half), inv53), two), one);
        let es = core::mem::transmute::<__m512i, [u64; 8]>(e_v);
        let us = core::mem::transmute::<__m512d, [f64; 8]>(u_v);
        for lane in 0..8 {
            let (e, u) = (es[lane], us[lane]);
            let layer = (e & 0x7F) as usize;
            out[j + lane] = if u.abs() < t.r[layer] {
                (u * t.x[layer]) as f32
            } else {
                z_slow(t, e, layer, u)
            };
        }
        j += 8;
    }
    while j < n {
        out[j] = z_at(t, seed, offset + j as u64);
        j += 1;
    }
}

/// Ziggurat sample for counter `i` of `seed`, with the tables hoisted by
/// the caller. Bit-for-bit the historical `GaussianStream::z`: same mixing,
/// same rejection chain, so blocked and scalar paths are interchangeable.
#[inline(always)]
fn z_at(t: &ZigTables, seed: u64, i: u64) -> f32 {
    let e = splitmix64(seed ^ i.wrapping_mul(0x8CB92BA72F3D8DD7));
    let v = e;
    let layer = (v & 0x7F) as usize;
    let u = signed_unit(v);
    // fast path (~98.5%): strictly inside the layer rectangle
    if u.abs() < t.r[layer] {
        return (u * t.x[layer]) as f32;
    }
    z_slow(t, e, layer, u)
}

/// Tail + wedge rejection chain, out of line to keep `z_at` small.
#[cold]
fn z_slow(t: &ZigTables, mut e: u64, mut layer: usize, mut u: f64) -> f32 {
    loop {
        e = splitmix64(e ^ 0x2545F4914F6CDD1D);
        if layer == 0 {
            // tail beyond R
            let neg = u < 0.0;
            loop {
                let a = unit_open(e);
                e = splitmix64(e ^ 0x9E3779B97F4A7C15);
                let b = unit_open(e);
                e = splitmix64(e ^ 0x9E3779B97F4A7C15);
                let x = a.ln() / ZIG_R;
                let y = b.ln();
                if -2.0 * y >= x * x {
                    return if neg { (x - ZIG_R) as f32 } else { (ZIG_R - x) as f32 };
                }
            }
        }
        // wedge: accept with the exact density
        let x = u * t.x[layer];
        let f0 = (-0.5 * (t.x[layer] * t.x[layer] - x * x)).exp();
        let f1 = (-0.5 * (t.x[layer + 1] * t.x[layer + 1] - x * x)).exp();
        let y = unit_open(e);
        e = splitmix64(e ^ 0x2545F4914F6CDD1D);
        if f1 + y * (f0 - f1) < 1.0 {
            return x as f32;
        }
        // retry: re-derive a fresh candidate from the advanced chain
        let v = e;
        layer = (v & 0x7F) as usize;
        u = signed_unit(v);
        if u.abs() < t.r[layer] {
            return (u * t.x[layer]) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_is_deterministic_and_seed_sensitive() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(1);
        let mut c = Pcg::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {}", mean);
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.03, "var {}", var);
    }

    #[test]
    fn gaussian_stream_is_random_access() {
        let g = GaussianStream::new(42);
        let seq: Vec<f32> = (0..100).map(|i| g.z(i)).collect();
        // random access matches sequential
        assert_eq!(g.z(57), seq[57]);
        let mut buf = vec![0.0; 10];
        g.fill(&mut buf, 90);
        assert_eq!(&buf[..], &seq[90..100]);
        // different seeds differ
        let g2 = GaussianStream::new(43);
        assert_ne!(g.z(0), g2.z(0));
    }

    #[test]
    fn gaussian_stream_moments_and_independence() {
        let g = GaussianStream::new(7);
        let n = 100_000u64;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        let mut lag1 = 0.0f64;
        let mut prev = g.z(0) as f64;
        sum += prev;
        sum2 += prev * prev;
        for i in 1..n {
            let v = g.z(i) as f64;
            sum += v;
            sum2 += v * v;
            lag1 += v * prev;
            prev = v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        let corr = lag1 / n as f64 / var;
        assert!(mean.abs() < 0.01, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.02, "var {}", var);
        assert!(corr.abs() < 0.02, "lag-1 corr {}", corr);
    }

    #[test]
    fn below_is_uniform_and_in_range() {
        // rejection sampling: every residue class equally likely, including
        // for n that don't divide 2^64 (the old `% n` path was biased)
        let mut r = Pcg::new(11);
        for n in [1usize, 2, 3, 6, 7, 100, 1000] {
            let draws = 6000 * n.min(10);
            let mut counts = vec![0usize; n];
            for _ in 0..draws {
                let v = r.below(n);
                assert!(v < n);
                counts[v] += 1;
            }
            if n <= 10 {
                let expect = draws as f64 / n as f64;
                for (v, &c) in counts.iter().enumerate() {
                    let dev = (c as f64 - expect).abs() / expect;
                    assert!(dev < 0.08, "n={} v={} count={} expect={}", n, v, c, expect);
                }
            }
        }
        // n = 1 never consumes more than it must and is always 0
        assert_eq!(Pcg::new(1).below(1), 0);
    }

    #[test]
    fn stream_matches_golden_values() {
        // Pin the historical stream against an INDEPENDENT reference (a
        // u64-exact simulation of the pre-refactor algorithm), so a future
        // rewrite of z_at/z_slow can't silently change the sequence while
        // the self-referential bit-equality tests keep passing.
        // The splitmix64 chain is pure integer — exact on every platform.
        assert_eq!(splitmix64(0), 0xE220A8397B1DCDAF);
        assert_eq!(splitmix64(1), 0x910A2DEC89025CC1);
        assert_eq!(splitmix64(0xDEADBEEF), 0x4ADFB90F68C9EB9B);
        // z values cross exp/ln (libm), which is not bit-standardized
        // across platforms — a loose tolerance still catches any
        // structural change (reordered advances redraw entirely different
        // values), while tolerating sub-ULP libm variance. Coordinates
        // cover all three sampling paths: fast (0-3), wedge (202),
        // tail (635).
        let g = GaussianStream::new(42);
        for (i, want) in [
            (0u64, -0.17022095620632172f32),
            (1, 0.22029227018356323),
            (2, 1.6747004985809326),
            (3, -1.1382853984832764),
            (202, -0.004617972299456596), // wedge path
            (635, 3.5719919204711914),    // tail path
        ] {
            let got = g.z(i);
            assert!(
                (got - want).abs() < 1e-5,
                "z({}) = {} drifted from golden {}",
                i, got, want
            );
        }
    }

    #[test]
    fn fill_matches_scalar_z_exactly() {
        // the blocked fill (hoisted tables + out-of-line slow path) must be
        // bit-identical to per-coordinate z(), slow paths included
        let g = GaussianStream::new(99);
        let n = 100_000usize;
        let mut buf = vec![0.0f32; n];
        g.fill(&mut buf, 5);
        for (j, &v) in buf.iter().enumerate() {
            let want = g.z(5 + j as u64);
            assert_eq!(v.to_bits(), want.to_bits(), "coord {}", j);
        }
    }

    #[test]
    fn fill_dispatch_matches_fill_exactly() {
        // Both flag values must produce the scalar bits — `simd: true`
        // engages the AVX-512 body where the CPU/build allows and is a
        // plain fallthrough everywhere else; either way, bit-identical.
        // Length/offset chosen to cross the 8-lane remainder and hit slow
        // paths (~1.5% of 100k coordinates).
        let g = GaussianStream::new(99);
        let n = 100_003usize;
        let mut want = vec![0.0f32; n];
        g.fill(&mut want, 5);
        for simd in [false, true] {
            let mut got = vec![0.0f32; n];
            g.fill_dispatch(&mut got, 5, simd);
            for (j, (&a, &b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "simd={} coord {}", simd, j);
            }
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
