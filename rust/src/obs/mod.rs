//! obs — process-wide, stdlib-only observability: counters, gauges,
//! latency histograms, span timing, structured events, and a
//! Prometheus-text snapshot.
//!
//! The system spans SIMD kernels, a TCP worker fleet with churn
//! recovery, a multi-tenant serving cache and quantized stores; its
//! runtime visibility used to be ad-hoc `eprintln!` lines plus offline
//! bench JSON. This module is the single home for live metrics:
//!
//! * [`Counter`] / [`Gauge`] — relaxed atomics, level-gated.
//! * [`Histo`] — fixed [`HISTO_BUCKETS`] log2-bucket latency histogram
//!   with p50/p90/p99 [`HistoSnapshot`]s; lock-free, allocation-free.
//! * [`Span`] — RAII timing guard that records elapsed nanoseconds into
//!   a histogram on drop (only when spans are enabled).
//! * [`event`] — the level-filtered structured event log (human text on
//!   stderr via `MEZO_LOG`, JSONL sink via `MEZO_OBS_JSONL`).
//! * [`metrics`] — the static metric registry for the instrumented hot
//!   seams (kernels, pool, wire fleet/worker, serving, optimizer) and
//!   [`Registry::render_text`], the Prometheus text exposition.
//!
//! # Environment knobs
//!
//! * `MEZO_OBS` — the metrics level: `0` off, `1` counters/gauges
//!   (the default when unset), `2` counters plus span timing (clock
//!   reads feeding the latency histograms). Unlike the `zkernel` knobs
//!   this one is NOT latched in a `OnceLock`: [`set_level`] lets tests
//!   and benches flip the level inside one process (the neutrality
//!   suite and the `obs_overhead` bench group depend on that). A bogus
//!   value panics, like `MEZO_SIMD`.
//! * `MEZO_LOG` — stderr event threshold: `error|warn|info|debug`
//!   (default `info`). See [`event`].
//! * `MEZO_OBS_JSONL` — path of an append-only JSONL file receiving
//!   every structured event. Unset: no structured sink.
//!
//! # Neutrality
//!
//! Observability must be invisible to the numerics — the crate's
//! bit-identity story is its crown jewel. Instrumentation therefore
//! only ever reads clocks and bumps atomics: it never touches an f32
//! buffer, never changes chunk carving or z-counter math, and never
//! allocates on the kernel hot path (metrics are `static`s; a disabled
//! level costs one relaxed load and a branch). `tests/obs.rs` pins
//! dense/masked/shard/quant stepping and replay `to_bits()`-identical
//! under `MEZO_OBS=0` vs `MEZO_OBS=2`, re-run by `scripts/verify.sh`
//! under the full `MEZO_THREADS` × `MEZO_SIMD` matrix, and the
//! `obs_overhead` bench group bounds the default-level step-time tax.

pub mod event;
pub mod metrics;

pub use metrics::Registry;

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::time::Instant;

/// The process observability level (`MEZO_OBS`). Ordered: each level
/// includes everything below it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Metrics fully disabled: counters, gauges and spans are no-ops.
    Off = 0,
    /// Counters and gauges on — the default. No clock reads.
    Counters = 1,
    /// Counters plus span timing: RAII guards read the clock and feed
    /// the latency histograms.
    Spans = 2,
}

/// Sentinel for "not read from the environment yet".
const LEVEL_UNINIT: u8 = 0xFF;

static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNINIT);

/// The current observability level: one relaxed atomic load on the
/// fast path; the first call per process reads `MEZO_OBS`.
#[inline]
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Off,
        1 => Level::Counters,
        2 => Level::Spans,
        _ => init_level(),
    }
}

/// Cold path of [`level`]: parse `MEZO_OBS` once and latch the result
/// (until a [`set_level`] override).
#[cold]
fn init_level() -> Level {
    let lv = match std::env::var("MEZO_OBS") {
        Err(_) => Level::Counters,
        Ok(s) => match s.trim() {
            "" | "1" => Level::Counters,
            "0" => Level::Off,
            "2" => Level::Spans,
            other => panic!(
                "MEZO_OBS={:?} is not a recognized level (use 0, 1 or 2)",
                other
            ),
        },
    };
    LEVEL.store(lv as u8, Ordering::Relaxed);
    lv
}

/// Override the process observability level, beating `MEZO_OBS`.
///
/// The hook the in-process neutrality tests and the `obs_overhead`
/// bench group use to compare levels without respawning; takes effect
/// for every subsequent metric call in the process. Never affects
/// numerics — only whether atomics are bumped and clocks read.
pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

/// Whether counters and gauges are live (`MEZO_OBS >= 1`).
#[inline]
pub fn counting() -> bool {
    level() >= Level::Counters
}

/// Whether span timing is live (`MEZO_OBS >= 2`).
#[inline]
pub fn spans() -> bool {
    level() >= Level::Spans
}

/// A monotonically increasing event count (relaxed atomic). Gated on
/// [`counting`]; construction is `const`, so counters live in statics
/// and the hot path never allocates.
#[derive(Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    /// A zeroed counter (`const`: usable in statics).
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add 1 (no-op below [`Level::Counters`]).
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n` (no-op below [`Level::Counters`]).
    #[inline]
    pub fn add(&self, n: u64) {
        if counting() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

/// A last-value-wins instantaneous measurement (f64 bits in a relaxed
/// atomic) — loss, live worker count. Gated on [`counting`].
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// A gauge reading 0.0 (`const`: usable in statics).
    pub const fn new() -> Gauge {
        // f64 0.0 is the all-zero bit pattern
        Gauge(AtomicU64::new(0))
    }

    /// Set the value (no-op below [`Level::Counters`]).
    #[inline]
    pub fn set(&self, v: f64) {
        if counting() {
            self.0.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

/// Buckets per [`Histo`]: one per power of two, covering the full u64
/// range (bucket `b` holds values with `floor(log2(v)) == b`; 0 and 1
/// both land in bucket 0).
pub const HISTO_BUCKETS: usize = 64;

/// A fixed log2-bucket histogram of u64 observations (latency in
/// nanoseconds, by convention). Lock-free and allocation-free: an
/// observation is two relaxed `fetch_add`s; bucket resolution is one
/// `leading_zeros`.
///
/// Unlike [`Counter`]/[`Gauge`], [`Histo::record`] is NOT level-gated:
/// gating belongs to whoever reads the clock (a [`Span`], or a caller
/// like `examples/serve_scale.rs` that always wants its sample).
#[derive(Debug)]
pub struct Histo {
    buckets: [AtomicU64; HISTO_BUCKETS],
    sum: AtomicU64,
}

impl Histo {
    /// An empty histogram (`const`: usable in statics).
    pub const fn new() -> Histo {
        // interior mutability is the whole point of an atomic cell; the
        // const is only the repeat seed for the bucket array
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histo { buckets: [ZERO; HISTO_BUCKETS], sum: AtomicU64::new(0) }
    }

    /// The bucket index holding `v`: `floor(log2(v))`, with 0 mapped to
    /// bucket 0. Always `< HISTO_BUCKETS`.
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (63 - v.leading_zeros()) as usize
        }
    }

    /// Inclusive upper bound of bucket `b` (`2^(b+1) − 1`; the last
    /// bucket saturates at `u64::MAX`).
    #[inline]
    pub fn bucket_upper(b: usize) -> u64 {
        if b >= HISTO_BUCKETS - 1 {
            u64::MAX
        } else {
            (2u64 << b) - 1
        }
    }

    /// Record one observation. Two relaxed atomic adds; never gated,
    /// never allocates.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Histo::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// A point-in-time copy of the buckets. Buckets are read
    /// individually (relaxed), so a snapshot taken under concurrent
    /// recording is a valid histogram of *some* subset of the
    /// observations — counts are never lost, only possibly not yet
    /// visible (pinned in `tests/obs.rs`).
    pub fn snapshot(&self) -> HistoSnapshot {
        let mut counts = [0u64; HISTO_BUCKETS];
        for (c, b) in counts.iter_mut().zip(self.buckets.iter()) {
            *c = b.load(Ordering::Relaxed);
        }
        HistoSnapshot { counts, sum: self.sum.load(Ordering::Relaxed) }
    }
}

impl Default for Histo {
    fn default() -> Histo {
        Histo::new()
    }
}

/// A point-in-time copy of a [`Histo`]'s buckets, with nearest-rank
/// percentile queries.
#[derive(Debug, Clone)]
pub struct HistoSnapshot {
    counts: [u64; HISTO_BUCKETS],
    sum: u64,
}

impl HistoSnapshot {
    /// Total observations in the snapshot.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Sum of all observed values (mean = `sum / count`).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Per-bucket counts (index by [`Histo::bucket_of`]).
    pub fn buckets(&self) -> &[u64; HISTO_BUCKETS] {
        &self.counts
    }

    /// The nearest-rank `q`-quantile (`0.0 ..= 1.0`), reported as the
    /// inclusive upper bound of the bucket containing that rank — a
    /// conservative (never under-reporting) log2-resolution estimate.
    /// 0 on an empty snapshot.
    pub fn percentile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = (((total - 1) as f64) * q.clamp(0.0, 1.0)).round() as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Histo::bucket_upper(b);
            }
        }
        Histo::bucket_upper(HISTO_BUCKETS - 1)
    }

    /// Median ([`HistoSnapshot::percentile`] at 0.5).
    pub fn p50(&self) -> u64 {
        self.percentile(0.5)
    }

    /// 90th percentile.
    pub fn p90(&self) -> u64 {
        self.percentile(0.9)
    }

    /// 99th percentile — the latency tail.
    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }
}

/// RAII span guard: started against a [`Histo`], records the elapsed
/// nanoseconds into it on drop. Reads the clock ONLY at
/// [`Level::Spans`]; below that, construction and drop are a relaxed
/// load and a branch each.
#[must_use = "a span records on drop; binding it to _ drops immediately"]
#[derive(Debug)]
pub struct Span<'a> {
    inner: Option<(Instant, &'a Histo)>,
}

impl<'a> Span<'a> {
    /// Start timing into `h` (inert below [`Level::Spans`]).
    #[inline]
    pub fn start(h: &'a Histo) -> Span<'a> {
        Span { inner: if spans() { Some((Instant::now(), h)) } else { None } }
    }
}

impl Drop for Span<'_> {
    #[inline]
    fn drop(&mut self) {
        if let Some((t0, h)) = self.inner.take() {
            h.record(u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX));
        }
    }
}

/// `Some(now)` iff spans are enabled — the manual-timing twin of
/// [`Span`] for paths where one measurement feeds one of several
/// histograms (serve hit vs. materialize).
#[inline]
pub fn clock() -> Option<Instant> {
    if spans() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Record the elapsed nanoseconds since a [`clock`] reading into `h`
/// (no-op on `None`).
#[inline]
pub fn record_since(t0: Option<Instant>, h: &Histo) {
    if let Some(t0) = t0 {
        h.record(u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX));
    }
}

/// Count one kernel dispatch for `family` and (at [`Level::Spans`])
/// start a span into the family's latency histogram. The single call
/// every instrumented `ZEngine` entry point makes:
///
/// ```
/// use mezo::obs::{self, metrics::KernelFamily};
/// let _span = obs::kernel_dispatch(KernelFamily::Axpy);
/// // ... kernel body runs; the span records on scope exit ...
/// ```
#[inline]
pub fn kernel_dispatch(family: metrics::KernelFamily) -> Span<'static> {
    metrics::KERNEL_DISPATCHES[family as usize].inc();
    Span::start(&metrics::KERNEL_NS[family as usize])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_exact_powers_of_two() {
        assert_eq!(Histo::bucket_of(0), 0);
        assert_eq!(Histo::bucket_of(1), 0);
        assert_eq!(Histo::bucket_of(2), 1);
        assert_eq!(Histo::bucket_of(3), 1);
        assert_eq!(Histo::bucket_of(4), 2);
        for b in 1..HISTO_BUCKETS {
            let lo = 1u64 << b;
            assert_eq!(Histo::bucket_of(lo), b);
            assert_eq!(Histo::bucket_of(lo - 1), b - 1);
        }
        assert_eq!(Histo::bucket_of(u64::MAX), HISTO_BUCKETS - 1);
        assert_eq!(Histo::bucket_upper(0), 1);
        assert_eq!(Histo::bucket_upper(10), 2047);
        assert_eq!(Histo::bucket_upper(HISTO_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn percentiles_report_bucket_upper_bounds() {
        let h = Histo::new();
        for v in [1u64, 2, 3, 100, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 5);
        assert_eq!(s.sum(), 1106);
        // ranks 0..=4: values 1,2,3,100,1000 → p50 is rank 2 (value 3,
        // bucket 1, upper 3); p99 is rank 4 (bucket 9, upper 1023)
        assert_eq!(s.p50(), 3);
        assert_eq!(s.p99(), 1023);
        assert_eq!(Histo::new().snapshot().percentile(0.5), 0);
    }
}
