//! The static metric registry: every counter, gauge and histogram the
//! instrumented hot seams feed, plus [`Registry::render_text`], the
//! Prometheus text exposition of the whole set.
//!
//! Metrics are `static`s constructed `const` — registration is the act
//! of adding a static here and a line to the renderer, so the hot path
//! never takes a lock, never hashes a name, and never allocates.
//! Label sets are fixed arrays indexed by small enums
//! ([`KernelFamily`]) or a closed name table ([`MSG_KINDS`]).

use super::{Counter, Gauge, Histo, HistoSnapshot};

/// The kernel families the `zkernel` engine dispatches, one dispatch
/// counter and latency histogram per family. Masked variants count
/// under their base family; shard wrappers delegate to the dense entry
/// points and are therefore counted there automatically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelFamily {
    /// `fill_z` — Gaussian stream materialization.
    Fill = 0,
    /// `axpy_z` (+ masked) — `theta += scale * z`.
    Axpy = 1,
    /// `perturb_into` (+ masked) — out-of-place perturbation.
    PerturbInto = 2,
    /// `sgd_update` (+ masked) — fused single-seed SGD step.
    Sgd = 3,
    /// `multi_sgd_update` (+ masked) — fused k-seed SGD step.
    MultiSgd = 4,
    /// `fzoo_update` (+ masked) — FZOO-normalized update.
    Fzoo = 5,
    /// `multi_axpy_z` (+ masked) — k-seed accumulated perturbation.
    MultiAxpy = 6,
    /// `momentum_update` — heavy-ball buffer + step.
    Momentum = 7,
    /// `adam_update` — Adam moments + step.
    Adam = 8,
    /// `ema_z` — exponential moving average toward the z stream.
    Ema = 9,
    /// `project_rows` — row-subset projection.
    Project = 10,
}

impl KernelFamily {
    /// Number of families (length of the per-family metric arrays).
    pub const COUNT: usize = 11;

    /// Every family, in index order.
    pub const ALL: [KernelFamily; KernelFamily::COUNT] = [
        KernelFamily::Fill,
        KernelFamily::Axpy,
        KernelFamily::PerturbInto,
        KernelFamily::Sgd,
        KernelFamily::MultiSgd,
        KernelFamily::Fzoo,
        KernelFamily::MultiAxpy,
        KernelFamily::Momentum,
        KernelFamily::Adam,
        KernelFamily::Ema,
        KernelFamily::Project,
    ];

    /// The `family=` label value in the exposition.
    pub fn name(self) -> &'static str {
        match self {
            KernelFamily::Fill => "fill",
            KernelFamily::Axpy => "axpy",
            KernelFamily::PerturbInto => "perturb_into",
            KernelFamily::Sgd => "sgd",
            KernelFamily::MultiSgd => "multi_sgd",
            KernelFamily::Fzoo => "fzoo",
            KernelFamily::MultiAxpy => "multi_axpy",
            KernelFamily::Momentum => "momentum",
            KernelFamily::Adam => "adam",
            KernelFamily::Ema => "ema",
            KernelFamily::Project => "project",
        }
    }
}

// const-item repeat seeds for the static arrays; the interior
// mutability is the point (see clippy::declare_interior_mutable_const)
#[allow(clippy::declare_interior_mutable_const)]
const C0: Counter = Counter::new();
#[allow(clippy::declare_interior_mutable_const)]
const H0: Histo = Histo::new();

/// Dispatch count per [`KernelFamily`] (`mezo_kernel_dispatches_total`).
pub static KERNEL_DISPATCHES: [Counter; KernelFamily::COUNT] =
    [C0; KernelFamily::COUNT];

/// Wall-clock nanoseconds per dispatch, per [`KernelFamily`]
/// (`mezo_kernel_ns`; populated only at span level).
pub static KERNEL_NS: [Histo; KernelFamily::COUNT] = [H0; KernelFamily::COUNT];

/// Helper jobs handed to the worker pool by `run_jobs`
/// (`mezo_pool_jobs_enqueued_total`; the caller's own slice is not a
/// job, so a k-way carve enqueues k − 1).
pub static POOL_JOBS_ENQUEUED: Counter = Counter::new();

/// Times the pool grew its worker set (`mezo_pool_grow_events_total`).
pub static POOL_GROW_EVENTS: Counter = Counter::new();

/// Live pool worker threads (`mezo_pool_workers`).
pub static POOL_WORKERS: Gauge = Gauge::new();

/// Slots in the per-message-kind metric arrays: the 13 MZW1 frame
/// kinds plus a trailing `other` catch-all.
pub const MSG_KIND_SLOTS: usize = 14;

/// The `kind=` label values, aligned with `Msg::kind_name()` (pinned
/// by wire tests); index 13 is the `other` catch-all.
pub static MSG_KINDS: [&str; MSG_KIND_SLOTS] = [
    "hello",
    "ack",
    "nack",
    "plan",
    "manifest",
    "log",
    "load_shard",
    "perturb",
    "update",
    "replay",
    "fetch_shard",
    "shard_slice",
    "shutdown",
    "other",
];

/// Metric-array slot for a `Msg::kind_name()` string (unknown names
/// land in the trailing `other` slot).
pub fn msg_kind_index(name: &str) -> usize {
    MSG_KINDS
        .iter()
        .position(|&k| k == name)
        .unwrap_or(MSG_KIND_SLOTS - 1)
}

/// Fleet-side RPC round-trip nanoseconds per request kind
/// (`mezo_fleet_rpc_ns`; includes retries and respawn time).
pub static FLEET_RPC_NS: [Histo; MSG_KIND_SLOTS] = [H0; MSG_KIND_SLOTS];

/// Fleet RPC attempts beyond the first (`mezo_fleet_retries_total`).
pub static FLEET_RETRIES: Counter = Counter::new();

/// Worker processes respawned after transport failure
/// (`mezo_fleet_respawns_total`).
pub static FLEET_RESPAWNS: Counter = Counter::new();

/// Nack frames received by the fleet (`mezo_fleet_nacks_total`).
pub static FLEET_NACKS: Counter = Counter::new();

/// Frames received by a `ShardWorker`, per kind
/// (`mezo_worker_frames_total`).
pub static WORKER_FRAMES: [Counter; MSG_KIND_SLOTS] = [C0; MSG_KIND_SLOTS];

/// Inbound frames rejected for a digest mismatch
/// (`mezo_worker_digest_failures_total`).
pub static WORKER_DIGEST_FAILURES: Counter = Counter::new();

/// Nack frames sent by a `ShardWorker` (`mezo_worker_nacks_total`).
pub static WORKER_NACKS: Counter = Counter::new();

/// Serving requests (`mezo_serve_requests_total`).
pub static SERVE_REQUESTS: Counter = Counter::new();

/// Requests answered from the materialization cache
/// (`mezo_serve_hits_total`).
pub static SERVE_HITS: Counter = Counter::new();

/// Requests that missed the cache (`mezo_serve_misses_total`).
pub static SERVE_MISSES: Counter = Counter::new();

/// Cache entries invalidated by trajectory growth
/// (`mezo_serve_stale_total`).
pub static SERVE_STALE: Counter = Counter::new();

/// Cache entries evicted for capacity (`mezo_serve_evictions_total`).
pub static SERVE_EVICTIONS: Counter = Counter::new();

/// Trajectory replays materialized (`mezo_serve_materializations_total`).
pub static SERVE_MATERIALIZATIONS: Counter = Counter::new();

/// Requests served straight from base weights
/// (`mezo_serve_base_served_total`).
pub static SERVE_BASE_SERVED: Counter = Counter::new();

/// Cache-hit service nanoseconds (`mezo_serve_hit_ns`).
pub static SERVE_HIT_NS: Histo = Histo::new();

/// Miss-path materialization nanoseconds (`mezo_serve_materialize_ns`).
pub static SERVE_MATERIALIZE_NS: Histo = Histo::new();

/// Optimizer steps completed (`mezo_opt_steps_total`).
pub static OPT_STEPS: Counter = Counter::new();

/// Forward passes consumed by stepping
/// (`mezo_opt_forward_passes_total`).
pub static OPT_FORWARD_PASSES: Counter = Counter::new();

/// Loss from the most recent optimizer step (`mezo_opt_loss`).
pub static OPT_LOSS: Gauge = Gauge::new();

/// Handle for whole-registry operations — currently
/// [`Registry::render_text`], the Prometheus snapshot.
#[derive(Debug, Clone, Copy)]
pub struct Registry;

impl Registry {
    /// Render every metric in Prometheus text exposition format.
    ///
    /// Counters and gauges become plain `name{labels} value` lines
    /// under a `# TYPE` header; histograms are rendered summary-style:
    /// `quantile="0.5|0.9|0.99"` lines (log2-resolution upper bounds,
    /// see [`HistoSnapshot::percentile`]) plus `_sum` and `_count`.
    /// Zero-valued series are included, so the output shape is
    /// deterministic (pinned in `tests/obs.rs`).
    pub fn render_text() -> String {
        let mut out = String::with_capacity(8 * 1024);

        out.push_str("# TYPE mezo_kernel_dispatches_total counter\n");
        for f in KernelFamily::ALL {
            push_labeled(
                &mut out,
                "mezo_kernel_dispatches_total",
                "family",
                f.name(),
                KERNEL_DISPATCHES[f as usize].get(),
            );
        }
        out.push_str("# TYPE mezo_kernel_ns summary\n");
        for f in KernelFamily::ALL {
            push_summary(
                &mut out,
                "mezo_kernel_ns",
                Some(("family", f.name())),
                &KERNEL_NS[f as usize].snapshot(),
            );
        }

        push_scalar_counter(&mut out, "mezo_pool_jobs_enqueued_total", &POOL_JOBS_ENQUEUED);
        push_scalar_counter(&mut out, "mezo_pool_grow_events_total", &POOL_GROW_EVENTS);
        push_gauge(&mut out, "mezo_pool_workers", &POOL_WORKERS);

        out.push_str("# TYPE mezo_fleet_rpc_ns summary\n");
        for (i, kind) in MSG_KINDS.iter().enumerate() {
            push_summary(
                &mut out,
                "mezo_fleet_rpc_ns",
                Some(("kind", kind)),
                &FLEET_RPC_NS[i].snapshot(),
            );
        }
        push_scalar_counter(&mut out, "mezo_fleet_retries_total", &FLEET_RETRIES);
        push_scalar_counter(&mut out, "mezo_fleet_respawns_total", &FLEET_RESPAWNS);
        push_scalar_counter(&mut out, "mezo_fleet_nacks_total", &FLEET_NACKS);

        out.push_str("# TYPE mezo_worker_frames_total counter\n");
        for (i, kind) in MSG_KINDS.iter().enumerate() {
            push_labeled(
                &mut out,
                "mezo_worker_frames_total",
                "kind",
                kind,
                WORKER_FRAMES[i].get(),
            );
        }
        push_scalar_counter(
            &mut out,
            "mezo_worker_digest_failures_total",
            &WORKER_DIGEST_FAILURES,
        );
        push_scalar_counter(&mut out, "mezo_worker_nacks_total", &WORKER_NACKS);

        push_scalar_counter(&mut out, "mezo_serve_requests_total", &SERVE_REQUESTS);
        push_scalar_counter(&mut out, "mezo_serve_hits_total", &SERVE_HITS);
        push_scalar_counter(&mut out, "mezo_serve_misses_total", &SERVE_MISSES);
        push_scalar_counter(&mut out, "mezo_serve_stale_total", &SERVE_STALE);
        push_scalar_counter(&mut out, "mezo_serve_evictions_total", &SERVE_EVICTIONS);
        push_scalar_counter(
            &mut out,
            "mezo_serve_materializations_total",
            &SERVE_MATERIALIZATIONS,
        );
        push_scalar_counter(&mut out, "mezo_serve_base_served_total", &SERVE_BASE_SERVED);
        out.push_str("# TYPE mezo_serve_hit_ns summary\n");
        push_summary(&mut out, "mezo_serve_hit_ns", None, &SERVE_HIT_NS.snapshot());
        out.push_str("# TYPE mezo_serve_materialize_ns summary\n");
        push_summary(
            &mut out,
            "mezo_serve_materialize_ns",
            None,
            &SERVE_MATERIALIZE_NS.snapshot(),
        );

        push_scalar_counter(&mut out, "mezo_opt_steps_total", &OPT_STEPS);
        push_scalar_counter(
            &mut out,
            "mezo_opt_forward_passes_total",
            &OPT_FORWARD_PASSES,
        );
        push_gauge(&mut out, "mezo_opt_loss", &OPT_LOSS);

        out
    }
}

fn push_labeled(out: &mut String, name: &str, key: &str, val: &str, v: u64) {
    out.push_str(name);
    out.push('{');
    out.push_str(key);
    out.push_str("=\"");
    out.push_str(val);
    out.push_str("\"} ");
    out.push_str(&v.to_string());
    out.push('\n');
}

fn push_scalar_counter(out: &mut String, name: &str, c: &Counter) {
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push_str(" counter\n");
    out.push_str(name);
    out.push(' ');
    out.push_str(&c.get().to_string());
    out.push('\n');
}

fn push_gauge(out: &mut String, name: &str, g: &Gauge) {
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push_str(" gauge\n");
    out.push_str(name);
    out.push(' ');
    out.push_str(&g.get().to_string());
    out.push('\n');
}

fn push_summary(
    out: &mut String,
    name: &str,
    label: Option<(&str, &str)>,
    s: &HistoSnapshot,
) {
    for (q, v) in [(0.5, s.p50()), (0.9, s.p90()), (0.99, s.p99())] {
        out.push_str(name);
        out.push('{');
        if let Some((k, val)) = label {
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(val);
            out.push_str("\",");
        }
        out.push_str("quantile=\"");
        out.push_str(&q.to_string());
        out.push_str("\"} ");
        out.push_str(&v.to_string());
        out.push('\n');
    }
    for (suffix, v) in [("_sum", s.sum()), ("_count", s.count())] {
        out.push_str(name);
        out.push_str(suffix);
        if let Some((k, val)) = label {
            out.push('{');
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(val);
            out.push_str("\"}");
        }
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_kind_index_covers_all_wire_kinds() {
        for (i, kind) in MSG_KINDS.iter().enumerate() {
            assert_eq!(msg_kind_index(kind), i);
        }
        assert_eq!(msg_kind_index("no_such_kind"), MSG_KIND_SLOTS - 1);
        assert_eq!(MSG_KINDS[MSG_KIND_SLOTS - 1], "other");
    }

    #[test]
    fn family_names_are_distinct() {
        for (i, a) in KernelFamily::ALL.iter().enumerate() {
            assert_eq!(*a as usize, i);
            for b in &KernelFamily::ALL[i + 1..] {
                assert_ne!(a.name(), b.name());
            }
        }
    }
}
