//! Level-filtered structured event log.
//!
//! Every event has a severity ([`EventLevel`]), a `target` (the
//! subsystem emitting it) and a message. Two sinks:
//!
//! * **stderr** — the human-readable message, printed verbatim when
//!   the event's level passes the `MEZO_LOG` threshold (default
//!   `info`). At the default threshold the text output is
//!   byte-identical to the `eprintln!` lines this module replaced, so
//!   existing CI greps keep working.
//! * **JSONL** — when `MEZO_OBS_JSONL` names a file, EVERY event is
//!   appended to it as one JSON object per line, regardless of the
//!   stderr threshold (`MEZO_LOG` filters what a human sees, not what
//!   the machine record keeps).
//!
//! Both knobs are read once per process. The event log is deliberately
//! independent of the `MEZO_OBS` metrics level: flipping metrics off
//! for a bit-identity run must not change what the program prints.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::sync::{Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

/// Event severity, most severe first (so `lv <= threshold` means
/// "at least as severe as the threshold allows").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventLevel {
    /// Operation failed; the program is giving up on something.
    Error = 0,
    /// Something went wrong but was recovered or tolerated.
    Warn = 1,
    /// Normal operational milestones — the default threshold.
    Info = 2,
    /// High-volume diagnostic detail, off by default.
    Debug = 3,
}

impl EventLevel {
    /// The `level` field value in the JSONL record.
    pub fn name(self) -> &'static str {
        match self {
            EventLevel::Error => "error",
            EventLevel::Warn => "warn",
            EventLevel::Info => "info",
            EventLevel::Debug => "debug",
        }
    }
}

/// The stderr threshold (`MEZO_LOG`), parsed once per process.
/// Accepts `error|warn|info|debug` (case-insensitive) or `0`–`3`;
/// unset or empty means [`EventLevel::Info`]; anything else panics
/// loudly, like the `zkernel` knobs.
pub fn threshold() -> EventLevel {
    static THRESHOLD: OnceLock<EventLevel> = OnceLock::new();
    *THRESHOLD.get_or_init(|| match std::env::var("MEZO_LOG") {
        Err(_) => EventLevel::Info,
        Ok(s) => match s.trim().to_ascii_lowercase().as_str() {
            "" | "info" | "2" => EventLevel::Info,
            "error" | "0" => EventLevel::Error,
            "warn" | "1" => EventLevel::Warn,
            "debug" | "3" => EventLevel::Debug,
            other => panic!(
                "MEZO_LOG={:?} is not a recognized level (use error, warn, info or debug)",
                other
            ),
        },
    })
}

/// Whether an event at `lv` would be printed to stderr.
#[inline]
pub fn enabled(lv: EventLevel) -> bool {
    lv <= threshold()
}

/// The JSONL sink: opened append/create from `MEZO_OBS_JSONL` once;
/// `None` when the knob is unset or the open fails (an event log must
/// never take the process down).
fn jsonl_sink() -> Option<&'static Mutex<File>> {
    static SINK: OnceLock<Option<Mutex<File>>> = OnceLock::new();
    SINK.get_or_init(|| {
        let path = std::env::var("MEZO_OBS_JSONL").ok()?;
        if path.is_empty() {
            return None;
        }
        let f = OpenOptions::new().create(true).append(true).open(&path);
        match f {
            Ok(f) => Some(Mutex::new(f)),
            Err(e) => {
                eprintln!("obs: cannot open MEZO_OBS_JSONL={:?}: {}", path, e);
                None
            }
        }
    })
    .as_ref()
}

/// Escape a string for a JSON string literal (quotes, backslashes,
/// control characters).
fn json_escape(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// Emit one event: verbatim `msg` to stderr when [`enabled`], and a
/// `{"ts_ms":…,"level":…,"target":…,"msg":…}` line to the JSONL sink
/// (always, when configured).
pub fn emit(lv: EventLevel, target: &str, msg: &str) {
    if enabled(lv) {
        eprintln!("{}", msg);
    }
    if let Some(sink) = jsonl_sink() {
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis())
            .unwrap_or(0);
        let mut line = String::with_capacity(msg.len() + target.len() + 64);
        line.push_str("{\"ts_ms\":");
        line.push_str(&ts_ms.to_string());
        line.push_str(",\"level\":\"");
        line.push_str(lv.name());
        line.push_str("\",\"target\":\"");
        json_escape(target, &mut line);
        line.push_str("\",\"msg\":\"");
        json_escape(msg, &mut line);
        line.push_str("\"}\n");
        if let Ok(mut f) = sink.lock() {
            // best-effort: a full disk must not take the worker down
            let _ = f.write_all(line.as_bytes());
        }
    }
}

/// [`emit`] at [`EventLevel::Error`].
pub fn error(target: &str, msg: &str) {
    emit(EventLevel::Error, target, msg);
}

/// [`emit`] at [`EventLevel::Warn`].
pub fn warn(target: &str, msg: &str) {
    emit(EventLevel::Warn, target, msg);
}

/// [`emit`] at [`EventLevel::Info`].
pub fn info(target: &str, msg: &str) {
    emit(EventLevel::Info, target, msg);
}

/// [`emit`] at [`EventLevel::Debug`].
pub fn debug(target: &str, msg: &str) {
    emit(EventLevel::Debug, target, msg);
}

/// A sub-line progress tick: `.` to stderr with no newline when info
/// events are enabled, nothing to the JSONL sink (dots are cosmetic
/// pacing, not events). Used by the `exp` table runners.
pub fn progress_tick() {
    if enabled(EventLevel::Info) {
        eprint!(".");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_most_severe_first() {
        assert!(EventLevel::Error < EventLevel::Warn);
        assert!(EventLevel::Warn < EventLevel::Info);
        assert!(EventLevel::Info < EventLevel::Debug);
    }

    #[test]
    fn json_escape_handles_specials() {
        let mut out = String::new();
        json_escape("a\"b\\c\nd\te\u{1}", &mut out);
        assert_eq!(out, "a\\\"b\\\\c\\nd\\te\\u0001");
    }
}
