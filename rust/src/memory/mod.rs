//! Memory accounting (Fig. 3, Fig. 4, Tables 12 & 22).
//!
//! The paper profiles peak GPU memory on A100s; our testbed is one CPU, so
//! we reproduce the *structure* of those exhibits two ways:
//!  1. an analytic live-bytes model per (method, model size) derived from
//!     the artifact dims — the same accounting the paper's §3.4 analysis
//!     does (weights + activations + grads + optimizer state + caches);
//!  2. measured process peak-RSS around real artifact executions
//!     (memory::peak_rss), cross-checking the model's ordering.

use crate::util::json::{obj, Json};

/// Model size ladder (matches python/compile/model.py::SIZES).
#[derive(Debug, Clone, Copy)]
pub struct SizeSpec {
    /// ladder name ("tiny" … "xl")
    pub name: &'static str,
    /// residual-stream width
    pub d_model: u64,
    /// transformer block count
    pub n_layers: u64,
    /// attention heads per block
    pub n_heads: u64,
    /// MLP hidden width
    pub d_ff: u64,
}

/// The five profiled model sizes, smallest to largest.
pub const SIZES: [SizeSpec; 5] = [
    SizeSpec { name: "tiny", d_model: 64, n_layers: 2, n_heads: 2, d_ff: 256 },
    SizeSpec { name: "small", d_model: 128, n_layers: 4, n_heads: 4, d_ff: 512 },
    SizeSpec { name: "base", d_model: 256, n_layers: 6, n_heads: 8, d_ff: 1024 },
    SizeSpec { name: "large", d_model: 512, n_layers: 8, n_heads: 8, d_ff: 2048 },
    SizeSpec { name: "xl", d_model: 1024, n_layers: 12, n_heads: 16, d_ff: 4096 },
];

/// Vocabulary size shared by every ladder entry.
pub const VOCAB: u64 = 512;
/// Maximum sequence length shared by every ladder entry.
pub const MAX_SEQ: u64 = 64;

/// Look a ladder entry up by its name.
pub fn size_by_name(name: &str) -> Option<SizeSpec> {
    SIZES.iter().copied().find(|s| s.name == name)
}

/// Parameter count (mirrors model.param_specs for tuning=full).
pub fn n_params(s: SizeSpec) -> u64 {
    let d = s.d_model;
    let per_layer = 2 * d // ln1
        + 4 * d * d + 4 * d // attn w+b
        + 2 * d // ln2
        + d * s.d_ff + s.d_ff + s.d_ff * d + d; // mlp
    VOCAB * d + MAX_SEQ * d + s.n_layers * per_layer + 2 * d
}

/// Largest single weight matrix (the token embedding here) — the extra
/// buffer MeZO needs if it perturbs whole matrices at once (§2.1).
pub fn largest_matrix(s: SizeSpec) -> u64 {
    (VOCAB * s.d_model).max(s.d_model * s.d_ff)
}

/// Tuning/evaluation methods profiled in Fig. 3 / Table 22.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// zero-shot / MeZO — the paper's headline identity
    Inference,
    /// MeZO perturbing whole matrices (one extra matrix buffer)
    MezoMatrix,
    /// in-context learning (inference with longer context)
    Icl,
    /// forward-mode JVP (Appendix D / Table 12): weights + z + activations
    Jvp,
    /// prefix/LoRA FT: weights + full activation cache, tiny grads/state
    FtPrefix,
    /// full FT with SGD: weights + grads + cache
    FtSgd,
    /// full FT with Adam: weights + grads + 2 moments + cache
    FtAdam,
    /// full FT with Adam + gradient checkpointing (sqrt cache)
    FtAdamCkpt,
}

/// Every method the Fig. 3 / Table 22 exhibits compare.
pub const PROFILED_METHODS: [Method; 8] = [
    Method::Inference, Method::MezoMatrix, Method::Icl, Method::Jvp,
    Method::FtPrefix, Method::FtSgd, Method::FtAdam, Method::FtAdamCkpt,
];

impl Method {
    /// Display name, as it appears in the paper's tables.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Inference => "zero-shot/MeZO",
            Method::MezoMatrix => "MeZO(matrix)",
            Method::Icl => "ICL",
            Method::Jvp => "JVP fwd-AD",
            Method::FtPrefix => "FT(prefix)",
            Method::FtSgd => "FT(SGD)",
            Method::FtAdam => "FT(Adam)",
            Method::FtAdamCkpt => "FT(Adam)+ckpt",
        }
    }
}

/// Per-layer live activation set during a forward pass (bytes), for batch
/// B and sequence S: q,k,v,attn-out,mlp-hidden tiles + attention scores.
fn act_layer_bytes(s: SizeSpec, b: u64, seq: u64) -> u64 {
    4 * (b * seq * (4 * s.d_model + s.d_ff) + b * s.n_heads * seq * seq)
}

/// Full backprop activation cache: every layer's intermediates are held.
fn cache_bytes(s: SizeSpec, b: u64, seq: u64) -> u64 {
    s.n_layers * act_layer_bytes(s, b, seq) + logits_bytes(b, seq)
}

fn logits_bytes(b: u64, seq: u64) -> u64 {
    4 * b * seq * VOCAB
}

/// Analytic peak live bytes for one step of `method`.
pub fn live_bytes(s: SizeSpec, method: Method, b: u64, seq: u64) -> u64 {
    let w = 4 * n_params(s);
    let act = 2 * act_layer_bytes(s, b, seq) + logits_bytes(b, seq); // double-buffered fwd
    match method {
        Method::Inference => w + act,
        Method::MezoMatrix => w + act + 4 * largest_matrix(s),
        // ICL: same memory, longer effective context (2x here)
        Method::Icl => w + 2 * act_layer_bytes(s, b, 2 * seq) + logits_bytes(b, 2 * seq),
        // JVP: weights + tangent copy of weights (z) + dual activations
        Method::Jvp => 2 * w + 2 * act,
        Method::FtPrefix => w + cache_bytes(s, b, seq) + act,
        Method::FtSgd => 2 * w + cache_bytes(s, b, seq) + act,
        Method::FtAdam => 4 * w + cache_bytes(s, b, seq) + act,
        Method::FtAdamCkpt => {
            // sqrt(L) checkpoint segments
            let segs = (s.n_layers as f64).sqrt().ceil() as u64;
            4 * w + segs * act_layer_bytes(s, b, seq) + logits_bytes(b, seq) + act
        }
    }
}

/// Fig. 4: the largest size whose `method` footprint fits `budget` bytes.
pub fn largest_fitting(method: Method, budget: u64, b: u64, seq: u64) -> Option<&'static str> {
    let mut best = None;
    for s in SIZES {
        if live_bytes(s, method, b, seq) <= budget {
            best = Some(s.name);
        }
    }
    best
}

/// Measured peak RSS (VmHWM) of this process, bytes. Linux-only.
pub fn peak_rss() -> Option<u64> {
    let txt = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in txt.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Current RSS (VmRSS), bytes.
pub fn current_rss() -> Option<u64> {
    let txt = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in txt.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Table-22-style JSON report across methods × sizes.
pub fn report(b: u64, seq: u64) -> Json {
    let rows: Vec<Json> = SIZES
        .iter()
        .map(|&s| {
            let methods: Vec<(&str, Json)> = PROFILED_METHODS
                .iter()
                .map(|&m| (m.name(), Json::from(live_bytes(s, m, b, seq) as f64)))
                .collect();
            let mut o = vec![
                ("size", Json::from(s.name)),
                ("n_params", Json::from(n_params(s) as f64)),
            ];
            o.extend(methods);
            obj(o)
        })
        .collect();
    Json::Arr(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_are_plausible() {
        let tiny = n_params(size_by_name("tiny").unwrap());
        let small = n_params(size_by_name("small").unwrap());
        let large = n_params(size_by_name("large").unwrap());
        assert!(tiny > 100_000 && tiny < 250_000, "{}", tiny);
        assert!(small > 700_000 && small < 2_000_000, "{}", small);
        assert!(large > 20_000_000 && large < 40_000_000, "{}", large);
    }

    #[test]
    fn method_ordering_matches_paper() {
        // FT(Adam) >> FT(prefix) > MeZO == inference, at every size
        for s in SIZES {
            let inf = live_bytes(s, Method::Inference, 8, 64);
            let prefix = live_bytes(s, Method::FtPrefix, 8, 64);
            let adam = live_bytes(s, Method::FtAdam, 8, 64);
            let jvp = live_bytes(s, Method::Jvp, 8, 64);
            assert!(adam > prefix, "{}", s.name);
            assert!(prefix > inf, "{}", s.name);
            assert!(jvp > inf && jvp < adam, "{}", s.name);
        }
    }

    #[test]
    fn ft_to_inference_ratio_grows_into_paper_range() {
        // the paper reports ~12x for OPT-13B; the ratio must grow with size
        let r = |s: SizeSpec| {
            live_bytes(s, Method::FtAdam, 8, 64) as f64
                / live_bytes(s, Method::Inference, 8, 64) as f64
        };
        let r_tiny = r(size_by_name("tiny").unwrap());
        let r_xl = r(size_by_name("xl").unwrap());
        assert!(r_xl > r_tiny);
        assert!(r_xl > 3.0, "ratio {}", r_xl);
    }

    #[test]
    fn fit_table_is_monotone_in_budget() {
        let b1 = largest_fitting(Method::FtAdam, 32 << 20, 8, 64);
        let b2 = largest_fitting(Method::FtAdam, 512 << 20, 8, 64);
        let i2 = largest_fitting(Method::Inference, 512 << 20, 8, 64);
        // inference fits at least as large a model as FT at equal budget
        let rank = |n: Option<&str>| SIZES.iter().position(|s| Some(s.name) == n);
        assert!(rank(b2) >= rank(b1));
        assert!(rank(i2) >= rank(b2));
    }

    #[test]
    fn rss_readers_work_on_linux() {
        assert!(peak_rss().unwrap() > 0);
        assert!(current_rss().unwrap() > 0);
        assert!(peak_rss().unwrap() >= current_rss().unwrap());
    }
}
