//! `mezo` — the CLI launcher for the MeZO reproduction.
//!
//! Subcommands:
//!   pretrain  --family ar|mlm --size tiny|small [--steps N]
//!   finetune  --task sst2 [--method mezo|ft|...] [--size S]
//!   eval      --task sst2 --size S          (zero-shot)
//!   exp <id>  [--quick] [--family ar] [--size tiny]   (table1..table23, figure4/5, all)
//!   memory                                   (analytic memory report)
//!   replay    --task sst2                    (trajectory storage demo)
//!   list                                     (experiment ids + artifacts)

use anyhow::Result;
use mezo::data::tasks::Task;
use mezo::exp::{self, tables};
use mezo::train::pretrain::{pretrained, PretrainCfg};
use mezo::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let family = args.str("family", "ar");
    let size = args.str("size", "tiny");
    match cmd {
        "pretrain" => {
            let rt = mezo::runtime::Runtime::from_env()?;
            let cfg = PretrainCfg { steps: args.usize("steps", 3000), ..Default::default() };
            let (_p, curve) = pretrained(&rt, &family, &size, &cfg)?;
            match curve.last() {
                Some(l) => println!("pretrained {}/{}: loss {:.3} -> {:.3}",
                                    family, size, curve[0].1, l.1),
                None => println!("pretrained {}/{}: cached checkpoint loaded", family, size),
            }
        }
        "exp" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            let ctx = exp::Ctx::new(args.bool("quick", false))?;
            tables::run(&ctx, id, &family, &size)?;
        }
        "eval" => {
            let ctx = exp::Ctx::new(true)?;
            let task = Task::from_name(&args.str("task", "sst2")).expect("unknown task");
            let data = ctx.task_data(task, 64, args.u64("seed", 0));
            let out = exp::run_method(&ctx, &family, &size, task, &data,
                                      &exp::Method::ZeroShot, 0)?;
            println!("zero-shot {} ({}-{}): {:.3}", task.name(), family, size, out.score);
        }
        "finetune" => {
            let ctx = exp::Ctx::new(args.bool("quick", false))?;
            let task = Task::from_name(&args.str("task", "sst2")).expect("unknown task");
            let data = ctx.task_data(task, args.usize("n-train", 256), args.u64("seed", 0));
            let method = match args.str("method", "mezo").as_str() {
                "mezo" => exp::Method::mezo("full"),
                "mezo-lora" => exp::Method::mezo("lora"),
                "mezo-prefix" => exp::Method::mezo("prefix"),
                "ft" => exp::Method::Ft { tuning: "full",
                    flavor: mezo::optim::ft::FtFlavor::Adam, lr: None },
                "lp" => exp::Method::LinearProbe,
                "icl" => exp::Method::Icl { demos: 3 },
                other => anyhow::bail!("unknown method {}", other),
            };
            let out = exp::run_method(&ctx, &family, &size, task, &data, &method, 0)?;
            println!("{} on {} ({}-{}): test {:.3} (best val {:.3}, fwd {})",
                     method.name(), task.name(), family, size,
                     out.score, out.best_val, out.forward_passes);
        }
        "memory" => {
            let ctx = exp::Ctx::new(true)?;
            tables::table22(&ctx)?;
            tables::figure4(&ctx)?;
        }
        "replay" => {
            println!("see: cargo run --release --example storage_replay");
        }
        "list" => {
            println!("experiments: {}", tables::EXPERIMENT_IDS.join(" "));
        }
        _ => {
            println!("mezo — MeZO (NeurIPS 2023) reproduction");
            println!("usage: mezo <pretrain|exp|eval|finetune|memory|replay|list> [--flags]");
            println!("       mezo exp table1 --quick --family ar --size tiny");
        }
    }
    Ok(())
}
