//! ParamStore: the rust-owned parameter buffers MeZO operates on in place.
//!
//! The store holds one contiguous f32 buffer per named tensor, in the exact
//! artifact ABI order. Each tensor also records its *global flat offset*:
//! the counter-based Gaussian stream (rng::GaussianStream) indexes z by
//! global coordinate, so perturb / restore / update passes regenerate
//! exactly the same z regardless of which tensors they touch or in what
//! order — the in-place trick at the heart of Algorithm 1.

use crate::model::meta::{ArtifactMeta, TensorDesc};
use crate::rng::Pcg;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

/// The rust-owned parameter buffers MeZO operates on in place: one
/// contiguous f32 buffer per named tensor, in artifact ABI order, each
/// with its global flat offset for counter-based z indexing (see the
/// [module docs](self)).
#[derive(Debug, Clone)]
pub struct ParamStore {
    /// tensor descriptors, in ABI order (parallel to `data`/`offsets`)
    pub specs: Vec<TensorDesc>,
    /// global flat offset of each tensor (for counter-based z indexing)
    pub offsets: Vec<u64>,
    /// the parameter values, one contiguous buffer per tensor
    pub data: Vec<Vec<f32>>,
    index: HashMap<String, usize>,
}

impl ParamStore {
    /// Store with all-zero buffers laid out per `specs` (offsets are the
    /// running scalar count, in spec order).
    ///
    /// Each buffer is first-touched through the kernel engine's chunking
    /// path right after allocation (plus a huge-page hint for multi-MiB
    /// tensors): the zkernel pool's workers are core-pinned, so under
    /// Linux's first-touch placement every page lands on the NUMA node
    /// of the worker that will keep processing it. Advisory only —
    /// values and determinism are untouched (no-op under `MEZO_PIN=0`).
    pub fn from_specs(specs: Vec<TensorDesc>) -> ParamStore {
        let mut offsets = Vec::with_capacity(specs.len());
        let mut off = 0u64;
        for s in &specs {
            offsets.push(off);
            off += s.len() as u64;
        }
        let mut data: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0f32; s.len()]).collect();
        let eng = crate::zkernel::ZEngine::default();
        for buf in &mut data {
            crate::zkernel::numa::advise_hugepages(buf);
            eng.first_touch(buf);
        }
        let index = specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.clone(), i))
            .collect();
        ParamStore { specs, offsets, data, index }
    }

    /// Store shaped after an artifact's parameter list.
    pub fn from_meta(meta: &ArtifactMeta) -> ParamStore {
        ParamStore::from_specs(meta.params.clone())
    }

    /// Total scalar count across all tensors.
    pub fn n_params(&self) -> usize {
        self.data.iter().map(|d| d.len()).sum()
    }

    /// Index of a named tensor; panics on an unknown name (the store is
    /// the ABI — a missing name is a programming error, not input).
    pub fn idx(&self, name: &str) -> usize {
        *self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("no parameter named '{}'", name))
    }

    /// Borrow a tensor's values by name.
    pub fn get(&self, name: &str) -> &[f32] {
        &self.data[self.idx(name)]
    }

    /// Mutably borrow a tensor's values by name. Returns a slice, not
    /// the `Vec` itself: tensor lengths are part of the z-indexing ABI
    /// (`offsets`/`n_params` are derived from them at construction), so
    /// callers may rewrite values but never resize a buffer.
    pub fn get_mut(&mut self, name: &str) -> &mut [f32] {
        let i = self.idx(name);
        &mut self.data[i]
    }

    /// Whether a tensor of this name exists.
    pub fn has(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    /// Standard transformer init (matches python/tests/test_model.py):
    /// LN gains = 1, all biases & LoRA `.b` = 0, everything else N(0, 0.02).
    pub fn init(&mut self, seed: u64) {
        let mut rng = Pcg::new(seed);
        for (spec, buf) in self.specs.iter().zip(self.data.iter_mut()) {
            let n = &spec.name;
            if n.ends_with(".g") {
                buf.iter_mut().for_each(|x| *x = 1.0);
            } else if is_bias(n) || (n.contains(".lora_") && n.ends_with(".b")) {
                buf.iter_mut().for_each(|x| *x = 0.0);
            } else {
                buf.iter_mut().for_each(|x| *x = rng.normal_f32(0.0, 0.02));
            }
        }
    }

    /// Indices of the tensors in `names`, in `names` order.
    pub fn indices_of(&self, names: &[String]) -> Vec<usize> {
        names.iter().map(|n| self.idx(n)).collect()
    }

    /// Total scalar count across the given tensor indices.
    pub fn len_of(&self, idxs: &[usize]) -> u64 {
        idxs.iter().map(|&i| self.data[i].len() as u64).sum()
    }

    /// L2 norm of a tensor.
    pub fn tensor_norm(&self, i: usize) -> f32 {
        self.data[i].iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Copy all buffers from another store with identical specs.
    pub fn copy_from(&mut self, other: &ParamStore) {
        assert_eq!(self.specs.len(), other.specs.len());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            a.copy_from_slice(b);
        }
    }

    /// Deterministic K-way partition of this store's global coordinate
    /// space — shorthand for [`crate::shard::ShardPlan::new`], the unit
    /// of multi-node replay (see [`crate::shard`]).
    pub fn shard_plan(&self, n_shards: usize) -> anyhow::Result<crate::shard::ShardPlan> {
        crate::shard::ShardPlan::new(self, n_shards)
    }

    // ---------------- binary checkpoints --------------------------------
    // format: magic "MZCK" u32, n_tensors u32, then per tensor:
    //   name_len u32 | name bytes | ndim u32 | dims u64... | f32 data

    /// Write a binary checkpoint (magic `"MZCK"`; see the format comment).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"MZCK")?;
        f.write_all(&(self.specs.len() as u32).to_le_bytes())?;
        for (spec, buf) in self.specs.iter().zip(&self.data) {
            let nb = spec.name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(spec.shape.len() as u32).to_le_bytes())?;
            for &d in &spec.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            // SAFETY: f32 slice reinterpreted as bytes (little-endian host)
            let bytes = unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, buf.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    /// Load a checkpoint into a store with matching tensor names/shapes.
    /// Tensors present in the file but not in `self` are ignored; tensors
    /// missing from the file keep their current values (so a `full`
    /// checkpoint can seed a `lora`/`prefix` store).
    pub fn load_into(&mut self, path: &Path) -> std::io::Result<usize> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"MZCK" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad checkpoint magic",
            ));
        }
        let mut u32b = [0u8; 4];
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u32b)?;
        let n_tensors = u32::from_le_bytes(u32b) as usize;
        let mut loaded = 0;
        for _ in 0..n_tensors {
            f.read_exact(&mut u32b)?;
            let name_len = u32::from_le_bytes(u32b) as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8_lossy(&name).to_string();
            f.read_exact(&mut u32b)?;
            let ndim = u32::from_le_bytes(u32b) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                f.read_exact(&mut u64b)?;
                shape.push(u64::from_le_bytes(u64b) as usize);
            }
            let len: usize = shape.iter().product::<usize>().max(1);
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)?;
            if let Some(&i) = self.index.get(&name) {
                if self.specs[i].shape != shape {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("shape mismatch for {}", name),
                    ));
                }
                let dst = &mut self.data[i];
                for (j, chunk) in bytes.chunks_exact(4).enumerate() {
                    dst[j] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
                }
                loaded += 1;
            }
        }
        Ok(loaded)
    }
}

impl super::Theta for ParamStore {
    fn specs(&self) -> &[TensorDesc] {
        &self.specs
    }

    fn tensor_offset(&self, ti: usize) -> u64 {
        self.offsets[ti]
    }

    fn tensor_index(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    fn read_tensor_into(&self, ti: usize, out: &mut [f32]) {
        out.copy_from_slice(&self.data[ti]);
    }

    fn n_params(&self) -> usize {
        ParamStore::n_params(self)
    }

    fn as_dense(&self) -> Option<&ParamStore> {
        Some(self)
    }

    fn as_dense_mut(&mut self) -> Option<&mut ParamStore> {
        Some(self)
    }

    fn axpy_z(
        &mut self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        stream: crate::rng::GaussianStream,
        s: f32,
    ) {
        engine.axpy_z(stream, self.offsets[ti], &mut self.data[ti], s);
    }

    fn perturb_into(
        &self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        stream: crate::rng::GaussianStream,
        s: f32,
        out: &mut [f32],
    ) {
        engine.perturb_into(stream, self.offsets[ti], &self.data[ti], s, out);
    }

    fn sgd_update(
        &mut self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        stream: crate::rng::GaussianStream,
        lr: f32,
        g: f32,
        wd: f32,
    ) {
        engine.sgd_update(stream, self.offsets[ti], &mut self.data[ti], lr, g, wd);
    }

    fn multi_sgd_update(
        &mut self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        zs: &[(crate::rng::GaussianStream, f32)],
        lr: f32,
        wd: f32,
    ) {
        engine.multi_sgd_update(zs, self.offsets[ti], &mut self.data[ti], lr, wd);
    }

    fn fzoo_update(
        &mut self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        zs: &[(crate::rng::GaussianStream, f32)],
        lr: f32,
        wd: f32,
    ) {
        engine.fzoo_update(zs, self.offsets[ti], &mut self.data[ti], lr, wd);
    }

    fn multi_axpy_z(
        &mut self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        zs: &[(crate::rng::GaussianStream, f32)],
    ) {
        engine.multi_axpy_z(zs, self.offsets[ti], &mut self.data[ti]);
    }

    fn axpy_z_masked(
        &mut self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        stream: crate::rng::GaussianStream,
        idxs: &[u32],
        s: f32,
    ) {
        engine.axpy_z_masked(stream, self.offsets[ti], idxs, &mut self.data[ti], s);
    }

    fn perturb_into_masked(
        &self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        stream: crate::rng::GaussianStream,
        idxs: &[u32],
        s: f32,
        out: &mut [f32],
    ) {
        engine.perturb_into_masked(stream, self.offsets[ti], idxs, &self.data[ti], s, out);
    }

    fn sgd_update_masked(
        &mut self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        stream: crate::rng::GaussianStream,
        idxs: &[u32],
        lr: f32,
        g: f32,
        wd: f32,
    ) {
        engine.sgd_update_masked(stream, self.offsets[ti], idxs, &mut self.data[ti], lr, g, wd);
    }

    fn multi_sgd_update_masked(
        &mut self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        zs: &[(crate::rng::GaussianStream, f32)],
        idxs: &[u32],
        lr: f32,
        wd: f32,
    ) {
        engine.multi_sgd_update_masked(zs, self.offsets[ti], idxs, &mut self.data[ti], lr, wd);
    }

    fn fzoo_update_masked(
        &mut self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        zs: &[(crate::rng::GaussianStream, f32)],
        idxs: &[u32],
        lr: f32,
        wd: f32,
    ) {
        engine.fzoo_update_masked(zs, self.offsets[ti], idxs, &mut self.data[ti], lr, wd);
    }

    fn multi_axpy_z_masked(
        &mut self,
        engine: &crate::zkernel::ZEngine,
        ti: usize,
        zs: &[(crate::rng::GaussianStream, f32)],
        idxs: &[u32],
    ) {
        engine.multi_axpy_z_masked(zs, self.offsets[ti], idxs, &mut self.data[ti]);
    }
}

fn is_bias(name: &str) -> bool {
    name.ends_with(".b")
        || name.ends_with(".bq")
        || name.ends_with(".bk")
        || name.ends_with(".bv")
        || name.ends_with(".bo")
        || name.ends_with(".b1")
        || name.ends_with(".b2")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_specs() -> Vec<TensorDesc> {
        vec![
            TensorDesc { name: "embed.tok".into(), shape: vec![16, 4], dtype: "f32".into() },
            TensorDesc { name: "layer0.ln1.g".into(), shape: vec![4], dtype: "f32".into() },
            TensorDesc { name: "layer0.attn.bq".into(), shape: vec![4], dtype: "f32".into() },
            TensorDesc { name: "layer0.attn.wq".into(), shape: vec![4, 4], dtype: "f32".into() },
        ]
    }

    #[test]
    fn init_patterns() {
        let mut p = ParamStore::from_specs(toy_specs());
        p.init(0);
        assert!(p.get("layer0.ln1.g").iter().all(|&x| x == 1.0));
        assert!(p.get("layer0.attn.bq").iter().all(|&x| x == 0.0));
        assert!(p.get("embed.tok").iter().any(|&x| x != 0.0));
        let std = {
            let d = p.get("embed.tok");
            (d.iter().map(|x| x * x).sum::<f32>() / d.len() as f32).sqrt()
        };
        assert!((std - 0.02).abs() < 0.01, "std {}", std);
    }

    #[test]
    fn get_mut_cannot_desync_n_params() {
        let mut p = ParamStore::from_specs(toy_specs());
        let n = p.n_params();
        let offs = p.offsets.clone();
        // get_mut hands out a slice: values may change, lengths cannot,
        // so offsets/n_params (the z-indexing ABI) stay pinned.
        p.get_mut("embed.tok").iter_mut().for_each(|x| *x = 1.5);
        assert_eq!(p.n_params(), n);
        assert_eq!(p.offsets, offs);
        assert!(p.get("embed.tok").iter().all(|&x| x == 1.5));
    }

    #[test]
    fn offsets_are_cumulative() {
        let p = ParamStore::from_specs(toy_specs());
        assert_eq!(p.offsets, vec![0, 64, 68, 72]);
        assert_eq!(p.n_params(), 88);
    }

    #[test]
    fn shard_plan_shorthand_matches_direct_construction() {
        let p = ParamStore::from_specs(toy_specs());
        let a = p.shard_plan(3).unwrap();
        let b = crate::shard::ShardPlan::new(&p, 3).unwrap();
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.total(), 88);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("mezo_test_ckpt");
        let path = dir.join("toy.ckpt");
        let mut p = ParamStore::from_specs(toy_specs());
        p.init(3);
        p.save(&path).unwrap();
        let mut q = ParamStore::from_specs(toy_specs());
        let n = q.load_into(&path).unwrap();
        assert_eq!(n, 4);
        for (a, b) in p.data.iter().zip(&q.data) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn partial_load_for_peft() {
        let dir = std::env::temp_dir().join("mezo_test_ckpt2");
        let path = dir.join("base.ckpt");
        let mut base = ParamStore::from_specs(toy_specs());
        base.init(5);
        base.save(&path).unwrap();
        // a store with one extra (PEFT) tensor
        let mut specs = toy_specs();
        specs.push(TensorDesc {
            name: "layer0.lora_q.a".into(),
            shape: vec![4, 2],
            dtype: "f32".into(),
        });
        let mut peft = ParamStore::from_specs(specs);
        peft.init(6);
        let lora_before = peft.get("layer0.lora_q.a").to_vec();
        let n = peft.load_into(&path).unwrap();
        assert_eq!(n, 4);
        assert_eq!(peft.get("embed.tok"), base.get("embed.tok"));
        assert_eq!(peft.get("layer0.lora_q.a"), &lora_before[..]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = std::env::temp_dir().join("mezo_test_ckpt3");
        let path = dir.join("bad.ckpt");
        let mut p = ParamStore::from_specs(toy_specs());
        p.init(0);
        p.save(&path).unwrap();
        let mut specs = toy_specs();
        specs[0].shape = vec![8, 4];
        let mut q = ParamStore::from_specs(specs);
        assert!(q.load_into(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
