//! QuantStore: the block-quantized (SensZOQ) parameter store.
//!
//! The full SensZOQ recipe (PAPERS.md, 2410.09823) on top of the sparse
//! masks the crate already has: keep θ's dense bulk in int8/int4 blocks
//! with one f32 scale per [`QBLOCK`] coordinates, and keep ONLY the
//! sparse *sensitive* coordinates (a [`SparseMask`]'s per-tensor lists)
//! in exact f32, compacted into a per-tensor **overlay**. That is a
//! 4–8× memory cut per replica versus a dense [`ParamStore`] — the
//! quantity that decides how many tenants a serving box fits and how
//! many bytes a shard scatter ships.
//!
//! [`QuantStore`] carries the same tensor specs and the same global
//! flat offsets as the dense store it was quantized from, so it speaks
//! the same z-indexing ABI: a trajectory recorded against the dense
//! store replays against the quantized one at identical z counters.
//! Both stores are served through the [`Theta`] trait; kernel passes
//! route to the `_quant` tier ([`crate::zkernel::quant`]), which keeps
//! overlay coordinates `to_bits()`-identical to the dense path and
//! everything else within the per-block dequantization bound (half a
//! scale step — see [`QBits::levels`]).

use crate::model::meta::TensorDesc;
use crate::model::params::ParamStore;
use crate::model::Theta;
use crate::rng::GaussianStream;
use crate::zkernel::{quant, QBits, QuantTensorMut, QuantTensorRef, SparseMask, ZEngine, QBLOCK};
use anyhow::Result;
use std::collections::HashMap;

/// One tensor's quantized payload (layout contract in
/// [`QuantTensorRef`]).
#[derive(Debug, Clone)]
struct QTensor {
    len: usize,
    data: Vec<u8>,
    scales: Vec<f32>,
    idxs: Vec<u32>,
    overlay: Vec<f32>,
}

/// Block-quantized parameter store: int8/int4 codes + per-block f32
/// scales + an exact-f32 overlay for the coordinates of the
/// [`SparseMask`] it was quantized under (see the [module docs](self)).
///
/// ```
/// use mezo::model::meta::TensorDesc;
/// use mezo::model::params::ParamStore;
/// use mezo::model::quant::QuantStore;
/// use mezo::model::Theta;
/// use mezo::zkernel::QBits;
///
/// let specs = vec![TensorDesc { name: "w".into(), shape: vec![300], dtype: "f32".into() }];
/// let mut p = ParamStore::from_specs(specs);
/// p.init(7);
/// let q = QuantStore::quantize(&p, QBits::Int8, None).unwrap();
/// assert_eq!(q.n_params(), p.n_params());
/// // every coordinate dequantizes within half a scale step
/// let d = q.to_dense();
/// let bound = q.dequant_error_bound();
/// for (a, b) in p.data[0].iter().zip(&d.data[0]) {
///     assert!((a - b).abs() <= bound);
/// }
/// ```
#[derive(Debug, Clone)]
pub struct QuantStore {
    /// tensor descriptors, in ABI order (parallel to `offsets`)
    pub specs: Vec<TensorDesc>,
    /// global flat offset of each tensor — identical to the dense
    /// store's, which is what keeps the z-indexing ABI shared
    pub offsets: Vec<u64>,
    bits: QBits,
    tensors: Vec<QTensor>,
    index: HashMap<String, usize>,
    mask_digest: Option<u64>,
}

impl QuantStore {
    /// Quantize a dense store: per tensor, the coordinates of `mask`
    /// (validated against `params` first) are lifted verbatim into the
    /// f32 overlay; everything else is symmetric-absmax quantized per
    /// [`QBLOCK`] (masked coordinates excluded from each block's absmax
    /// and stored as code 0). `mask: None` quantizes with an empty
    /// overlay — every coordinate lives in the codes.
    pub fn quantize(
        params: &ParamStore,
        bits: QBits,
        mask: Option<&SparseMask>,
    ) -> Result<QuantStore> {
        if let Some(m) = mask {
            m.validate(params)?;
        }
        let mut tensors = Vec::with_capacity(params.specs.len());
        for (ti, vals) in params.data.iter().enumerate() {
            let idxs: Vec<u32> =
                mask.map(|m| m.indices(ti).to_vec()).unwrap_or_default();
            let overlay: Vec<f32> = idxs.iter().map(|&i| vals[i as usize]).collect();
            let mut data = vec![0u8; bits.bytes_for(vals.len())];
            let mut scales = vec![0.0f32; vals.len().div_ceil(QBLOCK)];
            quant::quantize(bits, vals, &idxs, &mut data, &mut scales);
            tensors.push(QTensor { len: vals.len(), data, scales, idxs, overlay });
        }
        let index = params
            .specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.clone(), i))
            .collect();
        Ok(QuantStore {
            specs: params.specs.clone(),
            offsets: params.offsets.clone(),
            bits,
            tensors,
            index,
            mask_digest: mask.map(|m| m.digest()),
        })
    }

    /// Code width of this store.
    pub fn bits(&self) -> QBits {
        self.bits
    }

    /// Digest of the [`SparseMask`] the store was quantized under, if
    /// any — the same digest a masked [`crate::storage::Trajectory`]
    /// logs, so serving can guard mask/store agreement.
    pub fn mask_digest(&self) -> Option<u64> {
        self.mask_digest
    }

    /// Read-only kernel view of tensor `ti`.
    pub fn view(&self, ti: usize) -> QuantTensorRef<'_> {
        let t = &self.tensors[ti];
        QuantTensorRef {
            bits: self.bits,
            len: t.len,
            data: &t.data,
            scales: &t.scales,
            idxs: &t.idxs,
            overlay: &t.overlay,
        }
    }

    /// Mutable kernel view of tensor `ti`.
    pub fn view_mut(&mut self, ti: usize) -> QuantTensorMut<'_> {
        let bits = self.bits;
        let t = &mut self.tensors[ti];
        QuantTensorMut {
            bits,
            len: t.len,
            data: &mut t.data,
            scales: &mut t.scales,
            idxs: &t.idxs,
            overlay: &mut t.overlay,
        }
    }

    /// Dequantize every tensor into a dense store with identical specs
    /// (codes·scale everywhere, overlay values exact).
    pub fn dequantize_into(&self, out: &mut ParamStore) {
        assert_eq!(
            self.specs.len(),
            out.specs.len(),
            "QuantStore: dequantize target has different tensor count"
        );
        for (ti, buf) in out.data.iter_mut().enumerate() {
            quant::dequantize(self.view(ti), buf);
        }
    }

    /// A fresh dense store holding this store's dequantized values.
    pub fn to_dense(&self) -> ParamStore {
        let mut p = ParamStore::from_specs(self.specs.clone());
        self.dequantize_into(&mut p);
        p
    }

    /// Payload bytes of the quantized representation (codes + scales +
    /// overlay indices + overlay values) — the memory-per-replica
    /// number the `quant_kernels` bench group reports against
    /// `4 * n_params` for the dense store.
    pub fn bytes(&self) -> usize {
        self.tensors
            .iter()
            .map(|t| t.data.len() + 4 * t.scales.len() + 4 * t.idxs.len() + 4 * t.overlay.len())
            .sum()
    }

    /// The pinned dequantization error bound: every unmasked coordinate
    /// is within `max(scale) / 2` of its f32 value (round-to-nearest on
    /// a symmetric absmax grid; masked coordinates are exact).
    pub fn dequant_error_bound(&self) -> f32 {
        let mut worst = 0.0f32;
        for t in &self.tensors {
            for &s in &t.scales {
                worst = worst.max(s);
            }
        }
        worst * 0.5
    }
}

impl Theta for QuantStore {
    fn specs(&self) -> &[TensorDesc] {
        &self.specs
    }

    fn tensor_offset(&self, ti: usize) -> u64 {
        self.offsets[ti]
    }

    fn tensor_index(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    fn read_tensor_into(&self, ti: usize, out: &mut [f32]) {
        quant::dequantize(self.view(ti), out);
    }

    fn axpy_z(&mut self, engine: &ZEngine, ti: usize, stream: GaussianStream, s: f32) {
        let off = self.offsets[ti];
        engine.axpy_z_quant(stream, off, self.view_mut(ti), s);
    }

    fn perturb_into(
        &self,
        engine: &ZEngine,
        ti: usize,
        stream: GaussianStream,
        s: f32,
        out: &mut [f32],
    ) {
        engine.perturb_into_quant(stream, self.offsets[ti], self.view(ti), s, out);
    }

    fn sgd_update(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        stream: GaussianStream,
        lr: f32,
        g: f32,
        wd: f32,
    ) {
        let off = self.offsets[ti];
        engine.sgd_update_quant(stream, off, self.view_mut(ti), lr, g, wd);
    }

    fn multi_sgd_update(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        zs: &[(GaussianStream, f32)],
        lr: f32,
        wd: f32,
    ) {
        let off = self.offsets[ti];
        engine.multi_sgd_update_quant(zs, off, self.view_mut(ti), lr, wd);
    }

    fn fzoo_update(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        zs: &[(GaussianStream, f32)],
        lr: f32,
        wd: f32,
    ) {
        let off = self.offsets[ti];
        engine.fzoo_update_quant(zs, off, self.view_mut(ti), lr, wd);
    }

    fn multi_axpy_z(&mut self, engine: &ZEngine, ti: usize, zs: &[(GaussianStream, f32)]) {
        let off = self.offsets[ti];
        engine.multi_axpy_z_quant(zs, off, self.view_mut(ti));
    }

    fn axpy_z_masked(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        stream: GaussianStream,
        idxs: &[u32],
        s: f32,
    ) {
        let off = self.offsets[ti];
        engine.axpy_z_quant_masked(stream, off, idxs, self.view_mut(ti), s);
    }

    fn perturb_into_masked(
        &self,
        engine: &ZEngine,
        ti: usize,
        stream: GaussianStream,
        idxs: &[u32],
        s: f32,
        out: &mut [f32],
    ) {
        engine.perturb_into_quant_masked(stream, self.offsets[ti], idxs, self.view(ti), s, out);
    }

    fn sgd_update_masked(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        stream: GaussianStream,
        idxs: &[u32],
        lr: f32,
        g: f32,
        wd: f32,
    ) {
        let off = self.offsets[ti];
        engine.sgd_update_quant_masked(stream, off, idxs, self.view_mut(ti), lr, g, wd);
    }

    fn multi_sgd_update_masked(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        zs: &[(GaussianStream, f32)],
        idxs: &[u32],
        lr: f32,
        wd: f32,
    ) {
        let off = self.offsets[ti];
        engine.multi_sgd_update_quant_masked(zs, off, idxs, self.view_mut(ti), lr, wd);
    }

    fn fzoo_update_masked(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        zs: &[(GaussianStream, f32)],
        idxs: &[u32],
        lr: f32,
        wd: f32,
    ) {
        let off = self.offsets[ti];
        engine.fzoo_update_quant_masked(zs, off, idxs, self.view_mut(ti), lr, wd);
    }

    fn multi_axpy_z_masked(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        zs: &[(GaussianStream, f32)],
        idxs: &[u32],
    ) {
        let off = self.offsets[ti];
        engine.multi_axpy_z_quant_masked(zs, off, idxs, self.view_mut(ti));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_store(seed: u64, lens: &[usize]) -> ParamStore {
        let specs = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| TensorDesc {
                name: format!("t{}", i),
                shape: vec![n],
                dtype: "f32".into(),
            })
            .collect();
        let mut p = ParamStore::from_specs(specs);
        p.init(seed);
        p
    }

    #[test]
    fn roundtrip_within_bound_int8_and_int4() {
        // unaligned lengths on purpose: 300 is not a QBLOCK multiple,
        // 257 is not a BLOCK multiple
        let p = toy_store(3, &[300, 257]);
        for bits in [QBits::Int8, QBits::Int4] {
            let q = QuantStore::quantize(&p, bits, None).unwrap();
            let d = q.to_dense();
            let bound = q.dequant_error_bound();
            for (a, b) in p.data.iter().flatten().zip(d.data.iter().flatten()) {
                assert!((a - b).abs() <= bound, "{} vs {} (bound {})", a, b, bound);
            }
        }
    }

    #[test]
    fn masked_coordinates_are_exact() {
        let p = toy_store(5, &[200, 90]);
        let mask = SparseMask::top_k(&p, &[0, 1], 40, crate::zkernel::Sensitivity::Magnitude);
        let q = QuantStore::quantize(&p, QBits::Int4, Some(&mask)).unwrap();
        assert_eq!(q.mask_digest(), Some(mask.digest()));
        let d = q.to_dense();
        for ti in 0..2 {
            for &idx in mask.indices(ti) {
                assert_eq!(
                    p.data[ti][idx as usize].to_bits(),
                    d.data[ti][idx as usize].to_bits(),
                    "masked coordinate must dequantize bit-exactly"
                );
            }
        }
    }

    #[test]
    fn quant_store_shares_the_z_abi() {
        let p = toy_store(9, &[64, 100]);
        let q = QuantStore::quantize(&p, QBits::Int8, None).unwrap();
        assert_eq!(q.offsets, p.offsets);
        assert_eq!(q.n_params(), p.n_params());
        assert_eq!(q.tensor_index("t1"), Some(1));
        assert_eq!(Theta::tensor_offset(&q, 1), 64);
    }

    #[test]
    fn quantized_bytes_beat_dense() {
        let p = toy_store(11, &[4096]);
        let q8 = QuantStore::quantize(&p, QBits::Int8, None).unwrap();
        let q4 = QuantStore::quantize(&p, QBits::Int4, None).unwrap();
        let dense = 4 * p.n_params();
        assert!(q8.bytes() * 3 < dense, "int8 {} vs dense {}", q8.bytes(), dense);
        assert!(q4.bytes() * 6 < dense, "int4 {} vs dense {}", q4.bytes(), dense);
    }
}
