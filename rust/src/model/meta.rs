//! Artifact metadata: the `.meta.json` sidecar emitted by `python/compile/aot.py`.
//!
//! This is the ABI contract between the build-time python layer and the
//! runtime rust layer: parameter order/shapes, batch tensor layout, output
//! layout, model dims and cost estimates.

use crate::util::json::Json;
use std::path::Path;

/// One named tensor of the artifact ABI: name, shape and dtype string
/// (`"f32"`/`"float32"`, `"i32"`, …) exactly as the sidecar declares them.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorDesc {
    /// dotted tensor name (e.g. `layer0.attn.wq`) — also the
    /// `ParamStore` lookup key
    pub name: String,
    /// dimension sizes, row-major; empty = scalar
    pub shape: Vec<usize>,
    /// element type string as emitted by the compiler sidecar
    pub dtype: String,
}

impl TensorDesc {
    /// Scalar count (product of dims; 1 for a scalar).
    pub fn len(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Transformer dimensions of the compiled model.
#[derive(Debug, Clone)]
pub struct Dims {
    /// residual-stream width
    pub d_model: usize,
    /// transformer block count
    pub n_layers: usize,
    /// attention heads per block
    pub n_heads: usize,
    /// feed-forward hidden width
    pub d_ff: usize,
    /// per-head key/query width
    pub head_dim: usize,
}

/// The parsed `.meta.json` sidecar of one compiled loss/logits artifact —
/// everything the runtime needs to feed and read it without recompiling.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// artifact identifier (also its file stem under `artifacts/`)
    pub name: String,
    /// model family tag (`"ar"`, `"mlm"`, …)
    pub family: String,
    /// model size tag (`"tiny"`, `"small"`, …)
    pub size: String,
    /// tuning mode: `"full"`, `"lora"` or `"prefix"`
    pub tuning: String,
    /// artifact output mode (`"loss"` or `"logits"`)
    pub mode: String,
    /// compiled batch size (the ABI is shape-static)
    pub batch: usize,
    /// compiled sequence length
    pub seq: usize,
    /// vocabulary size
    pub vocab: usize,
    /// maximum sequence length the position table supports
    pub max_seq: usize,
    /// transformer dimensions
    pub dims: Dims,
    /// LoRA rank (when `tuning == "lora"`)
    pub lora_r: usize,
    /// LoRA scale α
    pub lora_alpha: f64,
    /// prefix length (when `tuning == "prefix"`)
    pub prefix_len: usize,
    /// every parameter tensor, in the exact upload (ABI) order
    pub params: Vec<TensorDesc>,
    /// names of the tensors fine-tuning may update
    pub trainable: Vec<String>,
    /// non-parameter inputs (token ids, masks, targets), in ABI order
    pub batch_inputs: Vec<TensorDesc>,
    /// artifact outputs, in ABI order
    pub outputs: Vec<TensorDesc>,
    /// estimated FLOPs of one forward pass (cost model for tables)
    pub flops_forward: f64,
    /// total parameter count as computed at compile time
    pub n_params: usize,
}

fn tensor_list(j: &Json, default_dtype: &str) -> Result<Vec<TensorDesc>, String> {
    let arr = j.as_arr().ok_or("expected array of tensors")?;
    arr.iter()
        .map(|t| {
            Ok(TensorDesc {
                name: t.get("name").as_str().ok_or("tensor missing name")?.to_string(),
                shape: t
                    .get("shape")
                    .as_arr()
                    .ok_or("tensor missing shape")?
                    .iter()
                    .map(|d| d.as_usize().ok_or("bad dim".to_string()))
                    .collect::<Result<Vec<_>, _>>()?,
                dtype: t
                    .get("dtype")
                    .as_str()
                    .unwrap_or(default_dtype)
                    .to_string(),
            })
        })
        .collect()
}

impl ArtifactMeta {
    /// Parse a `.meta.json` sidecar body; errors name the missing field.
    pub fn parse(text: &str) -> Result<ArtifactMeta, String> {
        let j = Json::parse(text)?;
        let d = j.get("dims");
        Ok(ArtifactMeta {
            name: j.get("name").as_str().ok_or("missing name")?.to_string(),
            family: j.get("family").as_str().unwrap_or("").to_string(),
            size: j.get("size").as_str().unwrap_or("").to_string(),
            tuning: j.get("tuning").as_str().unwrap_or("full").to_string(),
            mode: j.get("mode").as_str().unwrap_or("").to_string(),
            batch: j.get("batch").as_usize().ok_or("missing batch")?,
            seq: j.get("seq").as_usize().ok_or("missing seq")?,
            vocab: j.get("vocab").as_usize().unwrap_or(512),
            max_seq: j.get("max_seq").as_usize().unwrap_or(64),
            dims: Dims {
                d_model: d.get("d_model").as_usize().ok_or("missing d_model")?,
                n_layers: d.get("n_layers").as_usize().ok_or("missing n_layers")?,
                n_heads: d.get("n_heads").as_usize().unwrap_or(1),
                d_ff: d.get("d_ff").as_usize().unwrap_or(0),
                head_dim: d.get("head_dim").as_usize().unwrap_or(0),
            },
            lora_r: j.get("lora_r").as_usize().unwrap_or(8),
            lora_alpha: j.get("lora_alpha").as_f64().unwrap_or(16.0),
            prefix_len: j.get("prefix_len").as_usize().unwrap_or(8),
            params: tensor_list(j.get("params"), "float32")?,
            trainable: j
                .get("trainable")
                .as_arr()
                .ok_or("missing trainable")?
                .iter()
                .map(|t| t.as_str().unwrap_or("").to_string())
                .collect(),
            batch_inputs: tensor_list(j.get("batch_inputs"), "f32")?,
            outputs: tensor_list(j.get("outputs"), "float32")?,
            flops_forward: j.get("flops_forward").as_f64().unwrap_or(0.0),
            n_params: j.get("n_params").as_usize().unwrap_or(0),
        })
    }

    /// Read and parse a sidecar file.
    pub fn load(path: &Path) -> Result<ArtifactMeta, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {}", path.display(), e))?;
        ArtifactMeta::parse(&text)
    }

    /// Position of a named output in the artifact's output list.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|o| o.name == name)
    }

    /// Check a batch's (b, s) against the artifact ABI. Shared by
    /// `Artifact::run` and `Artifact::run_perturbed` so the fast path
    /// cannot silently accept a mis-shaped batch.
    pub fn validate_batch(&self, b: usize, s: usize) -> Result<(), String> {
        if b != self.batch || s != self.seq {
            return Err(format!(
                "batch shape ({},{}) != artifact ({},{})",
                b, s, self.batch, self.seq
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "ar_tiny_full_loss_b8_s64", "family": "ar", "size": "tiny",
      "tuning": "full", "mode": "loss", "batch": 8, "seq": 64,
      "vocab": 512, "max_seq": 64,
      "dims": {"d_model": 64, "n_layers": 2, "n_heads": 2, "d_ff": 256, "head_dim": 32},
      "lora_r": 8, "lora_alpha": 16, "prefix_len": 8,
      "params": [{"name": "embed.tok", "shape": [512, 64]}],
      "trainable": ["embed.tok"],
      "batch_inputs": [{"name": "input_ids", "shape": [8, 64], "dtype": "i32"}],
      "outputs": [{"name": "mean_loss", "shape": [], "dtype": "float32"},
                  {"name": "per_example_loss", "shape": [8], "dtype": "float32"}],
      "flops_forward": 1.0, "n_params": 32768
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "ar_tiny_full_loss_b8_s64");
        assert_eq!(m.dims.d_model, 64);
        assert_eq!(m.params[0].len(), 512 * 64);
        assert_eq!(m.batch_inputs[0].dtype, "i32");
        assert_eq!(m.output_index("per_example_loss"), Some(1));
        assert_eq!(m.output_index("nope"), None);
        // scalar output has len 1
        assert_eq!(m.outputs[0].len(), 1);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(ArtifactMeta::parse("{}").is_err());
    }

    #[test]
    fn validate_batch_accepts_abi_shape_and_rejects_others() {
        let m = ArtifactMeta::parse(SAMPLE).unwrap();
        assert!(m.validate_batch(8, 64).is_ok());
        for (b, s) in [(4, 64), (8, 32), (16, 128), (0, 0)] {
            let err = m.validate_batch(b, s).unwrap_err();
            assert!(err.contains("batch shape"), "{}", err);
            assert!(err.contains(&format!("({},{})", b, s)), "{}", err);
        }
    }
}
