//! Model-side state owned by the rust coordinator: artifact ABI metadata
//! and the in-place parameter store MeZO operates on.
pub mod meta;
pub mod params;
