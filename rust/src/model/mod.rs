//! Model-side state owned by the rust coordinator: artifact ABI metadata
//! and the parameter stores MeZO operates on — dense f32
//! ([`params::ParamStore`]) and block-quantized SensZOQ
//! ([`quant::QuantStore`]) — unified behind the [`Theta`] trait.
pub mod meta;
pub mod params;
pub mod quant;

use crate::rng::GaussianStream;
use crate::zkernel::ZEngine;
use meta::TensorDesc;
use params::ParamStore;

/// The unified parameter-store API: everything the optimizers
/// ([`crate::optim::mezo::MezoSgd`], [`crate::optim::fzoo::Fzoo`]),
/// trajectory replay ([`crate::storage::Trajectory`]) and the serving
/// layer ([`crate::serve::ServeStore`]) need from θ, abstracted over the
/// representation. Two implementations exist: the dense f32
/// [`ParamStore`] and the block-quantized [`quant::QuantStore`]
/// (int8/int4 codes + per-block scales + an f32 overlay for the sparse
/// masked coordinates — the SensZOQ recipe).
///
/// The design splits into three tiers:
///
/// 1. **Shape/identity** — [`Theta::specs`], [`Theta::tensor_offset`],
///    [`Theta::tensor_index`]: the tensor list, the global flat offsets
///    that define the z-indexing ABI, and name lookup. These are the
///    *same* for a dense store and any quantized view of it, which is
///    what lets a trajectory recorded against one replay against the
///    other.
/// 2. **Reads** — [`Theta::read_tensor_into`] materializes one tensor as
///    f32 (a copy for the dense store, a dequantization pass for the
///    quantized one).
/// 3. **Engine-chunked mutation** — the per-tensor kernel entry points
///    ([`Theta::axpy_z`], [`Theta::sgd_update`], … and their `_masked`
///    forms). Each takes the [`ZEngine`] that supplies threading/SIMD
///    dispatch and a tensor index; the implementation routes to the
///    dense or quantized kernel tier. Masked forms touch only the given
///    sorted coordinate list, reading z at the same global counters as
///    the dense kernels — on a `QuantStore` they walk the f32 overlay,
///    so masked coordinates stay `to_bits()`-identical to the dense
///    path (the acceptance bar pinned by `tests/quant.rs`).
///
/// [`Theta::as_dense`] / [`Theta::as_dense_mut`] are capability probes:
/// paths that genuinely need raw f32 buffers (moment-carrying flavors,
/// shard scatter, checkpointing) ask for the dense store and fail
/// loudly — with a typed error, not a silent wrong answer — when θ is
/// quantized.
///
/// The trait is object-safe: `&mut dyn Theta` is how
/// [`crate::storage::ReplayTarget`] carries either store.
pub trait Theta {
    /// Tensor descriptors in ABI order (parallel to offsets/data).
    fn specs(&self) -> &[TensorDesc];

    /// Global flat offset of tensor `ti` — the base z counter every
    /// kernel pass over that tensor uses.
    fn tensor_offset(&self, ti: usize) -> u64;

    /// Index of a named tensor, if present.
    fn tensor_index(&self, name: &str) -> Option<usize>;

    /// Materialize tensor `ti` as f32 into `out` (length must equal the
    /// tensor's length): a copy for a dense store, a dequantization
    /// (codes·scale, overlay spliced exactly) for a quantized one.
    fn read_tensor_into(&self, ti: usize, out: &mut [f32]);

    /// Number of tensors.
    fn n_tensors(&self) -> usize {
        self.specs().len()
    }

    /// Scalar length of tensor `ti`.
    fn tensor_len(&self, ti: usize) -> usize {
        self.specs()[ti].len()
    }

    /// Total scalar count across all tensors.
    fn n_params(&self) -> usize {
        self.specs().iter().map(|s| s.len()).sum()
    }

    /// Index of a named tensor; panics on an unknown name (the store is
    /// the ABI — a missing name is a programming error, not input).
    fn tensor_idx(&self, name: &str) -> usize {
        self.tensor_index(name)
            .unwrap_or_else(|| panic!("no parameter named '{}'", name))
    }

    /// Indices of the tensors in `names`, in `names` order.
    fn indices_of(&self, names: &[String]) -> Vec<usize> {
        names.iter().map(|n| self.tensor_idx(n)).collect()
    }

    /// Total scalar count across the given tensor indices.
    fn len_of(&self, idxs: &[usize]) -> u64 {
        idxs.iter().map(|&i| self.tensor_len(i) as u64).sum()
    }

    /// The dense store behind this θ, if it is one (capability probe —
    /// see the trait docs). Default: not dense.
    fn as_dense(&self) -> Option<&ParamStore> {
        None
    }

    /// Mutable form of [`Theta::as_dense`].
    fn as_dense_mut(&mut self) -> Option<&mut ParamStore> {
        None
    }

    // ---- engine-chunked per-tensor kernels (dense tier or quant tier) ----

    /// θ[j] += s · z(offset + j) over tensor `ti` — perturb / restore /
    /// replay ([`ZEngine::axpy_z`] resp. [`ZEngine::axpy_z_quant`]).
    fn axpy_z(&mut self, engine: &ZEngine, ti: usize, stream: GaussianStream, s: f32);

    /// out[j] = θ[j] + s · z(offset + j) for tensor `ti`; θ untouched
    /// (`out` length = tensor length).
    fn perturb_into(
        &self,
        engine: &ZEngine,
        ti: usize,
        stream: GaussianStream,
        s: f32,
        out: &mut [f32],
    );

    /// The MeZO-SGD update θ −= lr·(g·z + wd·θ) over tensor `ti`.
    fn sgd_update(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        stream: GaussianStream,
        lr: f32,
        g: f32,
        wd: f32,
    );

    /// n-SPSA: every `(stream, g)` update applied in slice order, one
    /// pass over tensor `ti`.
    fn multi_sgd_update(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        zs: &[(GaussianStream, f32)],
        lr: f32,
        wd: f32,
    );

    /// FZOO batched one-sided mean update over tensor `ti`.
    fn fzoo_update(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        zs: &[(GaussianStream, f32)],
        lr: f32,
        wd: f32,
    );

    /// Batched multi-seed axpy θ += Σᵢ sᵢ·zᵢ over tensor `ti` — the
    /// seed-batched replay primitive.
    fn multi_axpy_z(&mut self, engine: &ZEngine, ti: usize, zs: &[(GaussianStream, f32)]);

    // ---- masked (SensZOQ) forms: sorted coordinate lists, same global
    // ---- z counters as the dense kernels --------------------------------

    /// Masked [`Theta::axpy_z`]: only the coordinates in `idxs`.
    fn axpy_z_masked(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        stream: GaussianStream,
        idxs: &[u32],
        s: f32,
    );

    /// Masked [`Theta::perturb_into`]: only the coordinates in `idxs`
    /// are written to `out` (callers keep the rest mirroring θ).
    #[allow(clippy::too_many_arguments)]
    fn perturb_into_masked(
        &self,
        engine: &ZEngine,
        ti: usize,
        stream: GaussianStream,
        idxs: &[u32],
        s: f32,
        out: &mut [f32],
    );

    /// Masked [`Theta::sgd_update`].
    #[allow(clippy::too_many_arguments)]
    fn sgd_update_masked(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        stream: GaussianStream,
        idxs: &[u32],
        lr: f32,
        g: f32,
        wd: f32,
    );

    /// Masked [`Theta::multi_sgd_update`].
    #[allow(clippy::too_many_arguments)]
    fn multi_sgd_update_masked(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        zs: &[(GaussianStream, f32)],
        idxs: &[u32],
        lr: f32,
        wd: f32,
    );

    /// Masked [`Theta::fzoo_update`].
    #[allow(clippy::too_many_arguments)]
    fn fzoo_update_masked(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        zs: &[(GaussianStream, f32)],
        idxs: &[u32],
        lr: f32,
        wd: f32,
    );

    /// Masked [`Theta::multi_axpy_z`] — the sparse seed-batched replay
    /// primitive.
    fn multi_axpy_z_masked(
        &mut self,
        engine: &ZEngine,
        ti: usize,
        zs: &[(GaussianStream, f32)],
        idxs: &[u32],
    );
}
