//! Trajectory storage & replay (§2.1 "Storage Efficiency of MeZO").
//!
//! A full MeZO fine-tuning run is reconstructible from the initial
//! checkpoint plus one `(seed, projected_grad)` pair per step — ~12 bytes a
//! step (the paper quantizes grads to 2 bytes; we store f32 and report both
//! sizes). `replay` re-applies every update with the counter-based z
//! stream and *no forward passes and no data access*.

use crate::model::params::ParamStore;
use crate::optim::mezo::StepRecord;
use crate::rng::GaussianStream;
use crate::zkernel::ZEngine;
use anyhow::{bail, Result};
use std::io::{Read, Write};
use std::path::Path;

/// A full training run as a replayable (seed, projected-grad, lr) log.
#[derive(Debug, Clone, PartialEq)]
pub struct Trajectory {
    /// names of the tensors the run trained (replay must match)
    pub trainable: Vec<String>,
    /// one record per applied seed, in application order
    pub records: Vec<StepRecord>,
}

impl Trajectory {
    /// Empty trajectory over the given trainable tensor names.
    pub fn new(trainable: Vec<String>) -> Trajectory {
        Trajectory { trainable, records: Vec::new() }
    }

    /// Trajectory from an optimizer's history (e.g. `MezoSgd::history`,
    /// `Fzoo::history`).
    pub fn from_run(trainable: Vec<String>, records: &[StepRecord]) -> Trajectory {
        Trajectory { trainable, records: records.to_vec() }
    }

    /// bytes needed at f32 grad precision
    pub fn bytes_f32(&self) -> usize {
        self.records.len() * (8 + 4 + 4)
    }

    /// bytes at the paper's 2-byte grad quantization (+ one master seed)
    pub fn bytes_quantized(&self) -> usize {
        8 + self.records.len() * 2
    }

    /// Re-apply every recorded update in order: θ ← θ − lr·g·z(seed).
    /// No forward passes, no data — just the log. Records stay sequential
    /// (each z regenerates from its own seed); within a record every
    /// tensor runs as one blocked/threaded axpy with coefficient −lr·g.
    pub fn replay(&self, params: &mut ParamStore) {
        self.replay_with(&ZEngine::default(), params)
    }

    /// As [`Trajectory::replay`], on an explicit kernel engine.
    pub fn replay_with(&self, engine: &ZEngine, params: &mut ParamStore) {
        let idxs = params.indices_of(&self.trainable);
        for r in &self.records {
            let stream = GaussianStream::new(r.seed);
            for &ti in &idxs {
                engine.axpy_z(
                    stream,
                    params.offsets[ti],
                    &mut params.data[ti],
                    -(r.lr * r.pgrad),
                );
            }
        }
    }

    /// Re-apply a seed-batched (FZOO-style) trajectory: records group into
    /// consecutive batches of `seeds_per_step` (one optimizer step each),
    /// and every batch applies as ONE fused pass over each tensor
    /// ([`ZEngine::multi_axpy_z`] with per-seed coefficient −lr·pgrad)
    /// instead of `seeds_per_step` sequential passes.
    ///
    /// Per coordinate the batch applies in record order, so the result is
    /// bit-identical to [`Trajectory::replay`] for ANY batch size —
    /// batching changes how many passes are made over θ (one per batch
    /// instead of one per record), never the arithmetic. The divisibility
    /// check is an integrity guard, not a numerical requirement: a record
    /// count that does not split into whole seed-batches means a
    /// truncated/corrupt log or a wrong belief about the run's batch
    /// size, and erroring beats quietly replaying such a log.
    pub fn replay_batched(&self, params: &mut ParamStore, seeds_per_step: usize) -> Result<()> {
        self.replay_batched_with(&ZEngine::default(), params, seeds_per_step)
    }

    /// As [`Trajectory::replay_batched`], on an explicit kernel engine.
    pub fn replay_batched_with(
        &self,
        engine: &ZEngine,
        params: &mut ParamStore,
        seeds_per_step: usize,
    ) -> Result<()> {
        if seeds_per_step == 0 {
            bail!("replay_batched: seeds_per_step must be > 0");
        }
        if self.records.len() % seeds_per_step != 0 {
            bail!(
                "replay_batched: {} records do not divide into seed-batches of {}",
                self.records.len(),
                seeds_per_step
            );
        }
        let idxs = params.indices_of(&self.trainable);
        for batch in self.records.chunks(seeds_per_step) {
            let zs: Vec<(GaussianStream, f32)> = batch
                .iter()
                .map(|r| (GaussianStream::new(r.seed), -(r.lr * r.pgrad)))
                .collect();
            for &ti in &idxs {
                engine.multi_axpy_z(&zs, params.offsets[ti], &mut params.data[ti]);
            }
        }
        Ok(())
    }

    /// Write the log to disk. Binary format:
    /// `"MZTJ" | n_names u32 | (len u32, bytes)* | n_records u64 |
    /// (seed u64, pgrad f32, lr f32)*`, all little-endian.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"MZTJ")?;
        f.write_all(&(self.trainable.len() as u32).to_le_bytes())?;
        for n in &self.trainable {
            f.write_all(&(n.len() as u32).to_le_bytes())?;
            f.write_all(n.as_bytes())?;
        }
        f.write_all(&(self.records.len() as u64).to_le_bytes())?;
        for r in &self.records {
            f.write_all(&r.seed.to_le_bytes())?;
            f.write_all(&r.pgrad.to_le_bytes())?;
            f.write_all(&r.lr.to_le_bytes())?;
        }
        Ok(())
    }

    /// Read a trajectory written by [`Trajectory::save`].
    pub fn load(path: &Path) -> std::io::Result<Trajectory> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"MZTJ" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad trajectory magic",
            ));
        }
        let mut u32b = [0u8; 4];
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u32b)?;
        let n_names = u32::from_le_bytes(u32b) as usize;
        let mut trainable = Vec::with_capacity(n_names);
        for _ in 0..n_names {
            f.read_exact(&mut u32b)?;
            let len = u32::from_le_bytes(u32b) as usize;
            let mut b = vec![0u8; len];
            f.read_exact(&mut b)?;
            trainable.push(String::from_utf8_lossy(&b).to_string());
        }
        f.read_exact(&mut u64b)?;
        let n = u64::from_le_bytes(u64b) as usize;
        let mut records = Vec::with_capacity(n);
        for _ in 0..n {
            f.read_exact(&mut u64b)?;
            let seed = u64::from_le_bytes(u64b);
            f.read_exact(&mut u32b)?;
            let pgrad = f32::from_le_bytes(u32b);
            f.read_exact(&mut u32b)?;
            let lr = f32::from_le_bytes(u32b);
            records.push(StepRecord { seed, pgrad, lr });
        }
        Ok(Trajectory { trainable, records })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;
    use crate::optim::mezo::{MezoConfig, MezoSgd};

    fn toy() -> ParamStore {
        let mut p = ParamStore::from_specs(vec![
            TensorDesc { name: "w1".into(), shape: vec![10], dtype: "f32".into() },
            TensorDesc { name: "w2".into(), shape: vec![5], dtype: "f32".into() },
        ]);
        p.init(0);
        p
    }

    #[test]
    fn replay_reconstructs_training_trajectory() {
        let mut trained = toy();
        let cfg = MezoConfig { lr: 1e-2, eps: 1e-3, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 9);
        for _ in 0..50 {
            opt.step(&mut trained, |p| {
                Ok(p.data.iter().flatten().map(|&x| (x - 0.5) * (x - 0.5)).sum())
            })
            .unwrap();
        }
        let traj = Trajectory::from_run(
            vec!["w1".into(), "w2".into()],
            &opt.history,
        );
        let mut replayed = toy();
        traj.replay(&mut replayed);
        for (a, b) in trained.data.iter().flatten().zip(replayed.data.iter().flatten()) {
            // equal up to the ±ε perturb/restore rounding of Algorithm 1
            assert!((a - b).abs() < 1e-4, "{} vs {}", a, b);
        }
    }

    #[test]
    fn replay_batched_with_unit_batches_is_bitwise_replay() {
        // seeds_per_step = 1 must be the sequential replay, bit for bit
        let mut traj = Trajectory::new(vec!["w1".into(), "w2".into()]);
        for i in 0..7u64 {
            traj.records.push(StepRecord {
                seed: 100 + i,
                pgrad: 0.1 * i as f32 - 0.3,
                lr: 1e-3,
            });
        }
        let mut a = toy();
        let mut b = toy();
        traj.replay(&mut a);
        traj.replay_batched(&mut b, 1).unwrap();
        for (x, y) in a.data.iter().flatten().zip(b.data.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{} vs {}", x, y);
        }
    }

    #[test]
    fn replay_batched_rejects_mismatched_seed_batch_sizes() {
        // 7 records cannot be a run of 4-seed steps; the guard flags a
        // truncated or mislabeled log instead of quietly accepting it
        let mut traj = Trajectory::new(vec!["w1".into()]);
        for i in 0..7u64 {
            traj.records.push(StepRecord { seed: i, pgrad: 0.1, lr: 1e-3 });
        }
        let mut p = toy();
        let err = traj.replay_batched(&mut p, 4).unwrap_err();
        let msg = format!("{}", err);
        assert!(msg.contains("seed-batches"), "unexpected error: {}", msg);
        // zero-size batches are rejected too
        assert!(traj.replay_batched(&mut p, 0).is_err());
        // and a dividing batch size is accepted
        assert!(traj.replay_batched(&mut p, 7).is_ok());
    }

    #[test]
    fn save_load_roundtrip() {
        let path = std::env::temp_dir().join("mezo_traj_test.bin");
        let mut traj = Trajectory::new(vec!["w1".into()]);
        traj.records.push(StepRecord { seed: 7, pgrad: 0.25, lr: 1e-3 });
        traj.records.push(StepRecord { seed: 8, pgrad: -0.5, lr: 1e-3 });
        traj.save(&path).unwrap();
        let back = Trajectory::load(&path).unwrap();
        assert_eq!(back, traj);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn storage_is_tiny_versus_checkpoint() {
        // 20k steps (the paper's OPT runs) => ~40KB quantized, < 0.1MB
        let traj = Trajectory {
            trainable: vec!["w".into()],
            records: vec![StepRecord { seed: 0, pgrad: 0.0, lr: 0.0 }; 20_000],
        };
        assert!(traj.bytes_quantized() < 100_000);
        assert!(traj.bytes_f32() < 400_000);
    }
}
