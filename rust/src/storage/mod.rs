//! Trajectory storage & replay (§2.1 "Storage Efficiency of MeZO").
//!
//! A full MeZO fine-tuning run is reconstructible from the initial
//! checkpoint plus one `(seed, projected_grad)` pair per step — ~12 bytes a
//! step (the paper quantizes grads to 2 bytes; we store f32 and report both
//! sizes). `replay` re-applies every update with the counter-based z
//! stream and *no forward passes and no data access*.
//!
//! Replay is ONE dispatcher, [`Trajectory::replay_as`], parameterized by
//! [`ReplayTarget`] (any [`Theta`] store — dense or quantized — or a
//! sharded copy) × [`ReplayMode`] (sequential / seed-batched / masked /
//! both); the named `replay_*` methods are thin forwarding wrappers kept
//! for call-site clarity.

use crate::model::Theta;
use crate::optim::mezo::StepRecord;
use crate::rng::GaussianStream;
use crate::shard::{trainable_flags, ShardManifest, ShardedStore};
use crate::zkernel::{SparseMask, ZEngine, QBLOCK};
use anyhow::{bail, Result};
use std::io::{Read, Write};
use std::path::Path;

/// A full training run as a replayable (seed, projected-grad, lr) log.
#[derive(Debug, Clone, PartialEq)]
pub struct Trajectory {
    /// names of the tensors the run trained (replay must match)
    pub trainable: Vec<String>,
    /// one record per applied seed, in application order
    pub records: Vec<StepRecord>,
    /// [`SparseMask::digest`] of the mask the run stepped under, `None`
    /// for a dense run. A sparse log is only meaningful together with its
    /// mask — the masked replay paths verify the digest and fail loudly
    /// on mismatch, and the dense paths refuse digest-carrying logs.
    pub mask_digest: Option<u64>,
}

/// HOW a log applies in [`Trajectory::replay_as`]: the four replay
/// disciplines the named `replay_*` entry points collapse to.
/// Sequential-vs-batched only changes how many passes are made over θ
/// (per coordinate the records apply in log order either way, so the
/// results are bit-identical); dense-vs-masked must match how the log
/// was recorded — the digest guards fail loudly on a mismatch.
#[derive(Debug, Clone, Copy)]
pub enum ReplayMode<'m> {
    /// One pass over each trainable tensor per record, in log order —
    /// the discipline of [`Trajectory::replay`]. Dense logs only.
    Sequential,
    /// Consecutive groups of `seeds_per_step` records fuse into ONE
    /// pass per tensor ([`ZEngine::multi_axpy_z`]) — the discipline of
    /// [`Trajectory::replay_batched`]. Dense logs only.
    Batched {
        /// records per optimizer step (FZOO's `n`); must divide the
        /// record count — a remainder means a truncated/mislabeled log
        seeds_per_step: usize,
    },
    /// Walk only the mask's coordinates, exactly as the recorded run
    /// did — the discipline of [`Trajectory::replay_masked`]. Sparse
    /// logs only; `mask` must digest-match the logged one.
    Masked {
        /// the sensitive-coordinate mask the run trained under
        mask: &'m SparseMask,
    },
    /// Masked and seed-batched at once — the discipline of
    /// [`Trajectory::replay_batched_masked`].
    MaskedBatched {
        /// the sensitive-coordinate mask the run trained under
        mask: &'m SparseMask,
        /// records per optimizer step; must divide the record count
        seeds_per_step: usize,
    },
}

/// WHERE a log lands in [`Trajectory::replay_as`].
pub enum ReplayTarget<'a> {
    /// Any [`Theta`] store — the dense
    /// [`ParamStore`](crate::model::params::ParamStore) or the
    /// block-quantized [`QuantStore`](crate::model::quant::QuantStore)
    /// (whose f32 overlay keeps masked replay bit-identical to dense).
    Store(&'a mut dyn Theta),
    /// A sharded copy of the parameters plus the MZT3 manifest of the
    /// plan it was scattered under (digest-checked before any write).
    /// Dense modes only: sharding partitions the DENSE parameter pass,
    /// so the masked modes are rejected on this target.
    Sharded(&'a mut ShardedStore, &'a ShardManifest),
}

impl Trajectory {
    /// Empty trajectory over the given trainable tensor names.
    pub fn new(trainable: Vec<String>) -> Trajectory {
        Trajectory { trainable, records: Vec::new(), mask_digest: None }
    }

    /// Trajectory from an optimizer's history (e.g. `MezoSgd::history`,
    /// `Fzoo::history`). For a masked run, chain
    /// [`Trajectory::with_mask_digest`].
    pub fn from_run(trainable: Vec<String>, records: &[StepRecord]) -> Trajectory {
        Trajectory { trainable, records: records.to_vec(), mask_digest: None }
    }

    /// Tag the log with the digest of the sparse mask the run stepped
    /// under (`optimizer.mask.digest()`), making it a sparse log: only
    /// [`Trajectory::replay_masked`]/[`Trajectory::replay_batched_masked`]
    /// — handed a mask with the same digest — will replay it.
    pub fn with_mask_digest(mut self, digest: u64) -> Trajectory {
        self.mask_digest = Some(digest);
        self
    }

    /// bytes needed at f32 grad precision
    pub fn bytes_f32(&self) -> usize {
        self.records.len() * (8 + 4 + 4)
    }

    /// Bytes at the paper's 2-byte grad quantization, accounted in the
    /// same block format [`QuantStore`](crate::model::quant::QuantStore)
    /// uses for θ: one 8-byte master seed, 2 bytes of codes per record,
    /// plus one 4-byte f32 scale per [`QBLOCK`]-record block (symmetric
    /// absmax codes are meaningless without their per-block scale, so
    /// honest accounting includes it).
    pub fn bytes_quantized(&self) -> usize {
        8 + self.records.len() * 2 + self.records.len().div_ceil(QBLOCK) * 4
    }

    /// Re-apply every recorded update in order: θ ← θ − lr·g·z(seed).
    /// No forward passes, no data — just the log. Records stay sequential
    /// (each z regenerates from its own seed); within a record every
    /// tensor runs as one blocked/threaded axpy with coefficient −lr·g.
    ///
    /// Dense logs only — panics on a sparse (digest-carrying) log, whose
    /// updates only ever touched its mask's coordinates: use
    /// [`Trajectory::replay_masked`] with the run's mask instead.
    ///
    /// Thin wrapper over the [`Trajectory::replay_as`] dispatcher with
    /// [`ReplayMode::Sequential`] — as are all the named `replay_*`
    /// entry points.
    pub fn replay<T: Theta + ?Sized>(&self, params: &mut T) {
        self.replay_with(&ZEngine::default(), params)
    }

    /// As [`Trajectory::replay`], on an explicit kernel engine.
    pub fn replay_with<T: Theta + ?Sized>(&self, engine: &ZEngine, params: &mut T) {
        if let Err(e) = self.replay_store_as(engine, params, ReplayMode::Sequential) {
            panic!("{}", e);
        }
    }

    /// Re-apply a sparse (SensZOQ) run: every recorded update walks only
    /// `mask`'s coordinates, exactly as the run did. The mask's digest
    /// must equal the logged one — a reconstruction under a different
    /// sensitive-weight set would silently train different coordinates,
    /// so mismatch is an error, as is handing a mask to a dense log.
    pub fn replay_masked<T: Theta + ?Sized>(
        &self,
        params: &mut T,
        mask: &SparseMask,
    ) -> Result<()> {
        self.replay_masked_with(&ZEngine::default(), params, mask)
    }

    /// As [`Trajectory::replay_masked`], on an explicit kernel engine.
    pub fn replay_masked_with<T: Theta + ?Sized>(
        &self,
        engine: &ZEngine,
        params: &mut T,
        mask: &SparseMask,
    ) -> Result<()> {
        self.replay_store_as(engine, params, ReplayMode::Masked { mask })
    }

    /// Shared guard of the masked replay paths: the log must carry a
    /// digest and the handed mask must hash to it (and fit the store).
    fn check_mask<T: Theta + ?Sized>(&self, params: &T, mask: &SparseMask) -> Result<()> {
        let logged = match self.mask_digest {
            Some(d) => d,
            None => bail!(
                "replay_masked: this log was recorded dense (no mask digest); \
                 use replay/replay_batched"
            ),
        };
        let got = mask.digest();
        if got != logged {
            bail!(
                "replay_masked: mask digest {:#x} does not match the logged {:#x} — \
                 this is not the mask the run trained under",
                got,
                logged
            );
        }
        mask.validate(params)
    }

    /// Re-apply a seed-batched (FZOO-style) trajectory: records group into
    /// consecutive batches of `seeds_per_step` (one optimizer step each),
    /// and every batch applies as ONE fused pass over each tensor
    /// ([`ZEngine::multi_axpy_z`] with per-seed coefficient −lr·pgrad)
    /// instead of `seeds_per_step` sequential passes.
    ///
    /// Per coordinate the batch applies in record order, so the result is
    /// bit-identical to [`Trajectory::replay`] for ANY batch size —
    /// batching changes how many passes are made over θ (one per batch
    /// instead of one per record), never the arithmetic. The divisibility
    /// check is an integrity guard, not a numerical requirement: a record
    /// count that does not split into whole seed-batches means a
    /// truncated/corrupt log or a wrong belief about the run's batch
    /// size, and erroring beats quietly replaying such a log.
    pub fn replay_batched<T: Theta + ?Sized>(
        &self,
        params: &mut T,
        seeds_per_step: usize,
    ) -> Result<()> {
        self.replay_batched_with(&ZEngine::default(), params, seeds_per_step)
    }

    /// As [`Trajectory::replay_batched`], on an explicit kernel engine.
    pub fn replay_batched_with<T: Theta + ?Sized>(
        &self,
        engine: &ZEngine,
        params: &mut T,
        seeds_per_step: usize,
    ) -> Result<()> {
        self.replay_store_as(engine, params, ReplayMode::Batched { seeds_per_step })
    }

    /// Sparse counterpart of [`Trajectory::replay_batched`]: consecutive
    /// batches of `seeds_per_step` records apply as ONE fused masked pass
    /// per tensor. Digest and divisibility guards as in the sequential
    /// and dense variants.
    pub fn replay_batched_masked<T: Theta + ?Sized>(
        &self,
        params: &mut T,
        mask: &SparseMask,
        seeds_per_step: usize,
    ) -> Result<()> {
        self.replay_batched_masked_with(&ZEngine::default(), params, mask, seeds_per_step)
    }

    /// As [`Trajectory::replay_batched_masked`], on an explicit engine.
    pub fn replay_batched_masked_with<T: Theta + ?Sized>(
        &self,
        engine: &ZEngine,
        params: &mut T,
        mask: &SparseMask,
        seeds_per_step: usize,
    ) -> Result<()> {
        self.replay_store_as(engine, params, ReplayMode::MaskedBatched { mask, seeds_per_step })
    }

    /// The unified replay dispatcher: every named `replay_*` entry point
    /// is a thin wrapper that forwards here. Pick WHERE the log lands
    /// with [`ReplayTarget`] and HOW it applies with [`ReplayMode`]; the
    /// guards (dense-vs-sparse log kind, mask digest, manifest digest,
    /// seed-batch divisibility) run per combination exactly as the named
    /// wrappers always enforced them, before any coordinate is written.
    /// The masked modes do not compose with the sharded target.
    ///
    /// Two per-worker primitives stay OUTSIDE this collapse on purpose:
    /// [`Trajectory::replay_shard_with`] and
    /// [`Trajectory::replay_shard_batched_with`] replay one named shard
    /// `k` for a distributed worker — an operand no [`ReplayMode`]
    /// carries, because it selects a slice of the work rather than a
    /// replay discipline.
    pub fn replay_as(
        &self,
        engine: &ZEngine,
        target: ReplayTarget<'_>,
        mode: ReplayMode<'_>,
    ) -> Result<()> {
        match target {
            ReplayTarget::Store(params) => self.replay_store_as(engine, params, mode),
            ReplayTarget::Sharded(store, manifest) => {
                self.replay_sharded_as(engine, store, manifest, mode)
            }
        }
    }

    /// Store-target body behind [`Trajectory::replay_as`] and the named
    /// wrappers. Generic so monomorphized callers skip the vtable the
    /// `dyn Theta` of [`ReplayTarget::Store`] pays.
    fn replay_store_as<T: Theta + ?Sized>(
        &self,
        engine: &ZEngine,
        params: &mut T,
        mode: ReplayMode<'_>,
    ) -> Result<()> {
        match mode {
            ReplayMode::Sequential => {
                if let Some(d) = self.mask_digest {
                    bail!(
                        "replay: this log was recorded under a sparse mask (digest {:#x}); \
                         dense replay would update coordinates the run never touched — \
                         use replay_masked with the run's mask",
                        d
                    );
                }
                let idxs = params.indices_of(&self.trainable);
                for r in &self.records {
                    let stream = GaussianStream::new(r.seed);
                    for &ti in &idxs {
                        params.axpy_z(engine, ti, stream, -(r.lr * r.pgrad));
                    }
                }
            }
            ReplayMode::Batched { seeds_per_step } => {
                if let Some(d) = self.mask_digest {
                    bail!(
                        "replay_batched: this log was recorded under a sparse mask \
                         (digest {:#x}); use replay_batched_masked with the run's mask",
                        d
                    );
                }
                self.check_batches(seeds_per_step)?;
                let idxs = params.indices_of(&self.trainable);
                for zs in self.batched_coeffs(seeds_per_step) {
                    for &ti in &idxs {
                        params.multi_axpy_z(engine, ti, &zs);
                    }
                }
            }
            ReplayMode::Masked { mask } => {
                self.check_mask(params, mask)?;
                let idxs = params.indices_of(&self.trainable);
                for r in &self.records {
                    let stream = GaussianStream::new(r.seed);
                    for &ti in &idxs {
                        params.axpy_z_masked(
                            engine,
                            ti,
                            stream,
                            mask.indices(ti),
                            -(r.lr * r.pgrad),
                        );
                    }
                }
            }
            ReplayMode::MaskedBatched { mask, seeds_per_step } => {
                self.check_mask(params, mask)?;
                self.check_batches(seeds_per_step)?;
                let idxs = params.indices_of(&self.trainable);
                for zs in self.batched_coeffs(seeds_per_step) {
                    for &ti in &idxs {
                        params.multi_axpy_z_masked(engine, ti, &zs, mask.indices(ti));
                    }
                }
            }
        }
        Ok(())
    }

    /// Sharded-target body behind [`Trajectory::replay_as`] and the
    /// `replay_sharded*` wrappers.
    fn replay_sharded_as(
        &self,
        engine: &ZEngine,
        store: &mut ShardedStore,
        manifest: &ShardManifest,
        mode: ReplayMode<'_>,
    ) -> Result<()> {
        match mode {
            ReplayMode::Sequential => {
                let trainable = self.check_sharded(store, manifest)?;
                for k in 0..store.plan().n_shards() {
                    self.replay_shard_unchecked(engine, store, &trainable, k);
                }
                Ok(())
            }
            ReplayMode::Batched { seeds_per_step } => {
                let trainable = self.check_sharded(store, manifest)?;
                self.check_batches(seeds_per_step)?;
                let batches = self.batched_coeffs(seeds_per_step);
                for k in 0..store.plan().n_shards() {
                    replay_shard_batched_unchecked(engine, store, &trainable, k, &batches);
                }
                Ok(())
            }
            ReplayMode::Masked { .. } | ReplayMode::MaskedBatched { .. } => bail!(
                "replay_as: masked replay does not compose with a sharded target — \
                 sharding partitions the DENSE parameter pass; replay a sparse log \
                 against a dense or quantized store with ReplayMode::Masked"
            ),
        }
    }

    /// Re-apply the whole log onto a sharded copy of the parameters: for
    /// every shard, every record's update runs over just that shard's
    /// segments, reading z at the tensors' global counters — so each
    /// shard's buffers end up bitwise the slice of what dense
    /// [`Trajectory::replay`] produces, and a
    /// [`ShardedStore::gather_into`] afterwards is `to_bits()`-identical
    /// to the dense replay (pinned in `tests/properties.rs`). The MZT3
    /// `manifest` must match the store's plan — replaying under a
    /// different partition would scatter updates onto the wrong
    /// coordinates, so mismatch fails loudly, as does a sparse
    /// (mask-digest-carrying) log.
    pub fn replay_sharded(
        &self,
        store: &mut ShardedStore,
        manifest: &ShardManifest,
    ) -> Result<()> {
        self.replay_sharded_with(&ZEngine::default(), store, manifest)
    }

    /// As [`Trajectory::replay_sharded`], on an explicit kernel engine.
    /// Validation (manifest digest, trainable names) runs once, not once
    /// per shard.
    pub fn replay_sharded_with(
        &self,
        engine: &ZEngine,
        store: &mut ShardedStore,
        manifest: &ShardManifest,
    ) -> Result<()> {
        self.replay_sharded_as(engine, store, manifest, ReplayMode::Sequential)
    }

    /// One worker's share of [`Trajectory::replay_sharded`]: replay the
    /// log over shard `k`'s segments only. Safe to run per shard on
    /// separate machines — shards are disjoint and each reads z from the
    /// log's seeds alone.
    ///
    /// Deliberately NOT part of the [`Trajectory::replay_as`] collapse:
    /// the shard index `k` names one worker's slice of the work, which
    /// is not a replay discipline a [`ReplayMode`] could carry.
    pub fn replay_shard_with(
        &self,
        engine: &ZEngine,
        store: &mut ShardedStore,
        manifest: &ShardManifest,
        k: usize,
    ) -> Result<()> {
        let trainable = self.check_sharded(store, manifest)?;
        self.replay_shard_unchecked(engine, store, &trainable, k);
        Ok(())
    }

    /// Guard-free body of the per-shard sequential replay: callers have
    /// already validated the manifest and resolved the trainable flags.
    fn replay_shard_unchecked(
        &self,
        engine: &ZEngine,
        store: &mut ShardedStore,
        trainable: &[bool],
        k: usize,
    ) {
        let offsets: Vec<u64> = store.plan().offsets().to_vec();
        for r in &self.records {
            let stream = GaussianStream::new(r.seed);
            for (seg, buf) in store.segments_mut(k) {
                if !trainable[seg.tensor] {
                    continue;
                }
                // buf IS the [lo, hi) slice, so the counter base advances
                // by lo — the same alignment the in-place shard kernels use
                engine.axpy_z(
                    stream,
                    offsets[seg.tensor] + seg.lo as u64,
                    buf,
                    -(r.lr * r.pgrad),
                );
            }
        }
    }

    /// Seed-batched flavor of [`Trajectory::replay_sharded`]: consecutive
    /// batches of `seeds_per_step` records apply as ONE fused pass per
    /// shard segment ([`ZEngine::multi_axpy_z`]). Bitwise equal to the
    /// sequential sharded replay for any batch size, with the same
    /// integrity guards as [`Trajectory::replay_batched`].
    pub fn replay_sharded_batched(
        &self,
        store: &mut ShardedStore,
        manifest: &ShardManifest,
        seeds_per_step: usize,
    ) -> Result<()> {
        self.replay_sharded_batched_with(&ZEngine::default(), store, manifest, seeds_per_step)
    }

    /// As [`Trajectory::replay_sharded_batched`], on an explicit engine.
    /// Validation (manifest digest, trainable names, batch divisibility)
    /// and the per-batch coefficient vectors are computed once, not once
    /// per shard.
    pub fn replay_sharded_batched_with(
        &self,
        engine: &ZEngine,
        store: &mut ShardedStore,
        manifest: &ShardManifest,
        seeds_per_step: usize,
    ) -> Result<()> {
        self.replay_sharded_as(engine, store, manifest, ReplayMode::Batched { seeds_per_step })
    }

    /// One worker's share of [`Trajectory::replay_sharded_batched`].
    /// Like [`Trajectory::replay_shard_with`], deliberately outside the
    /// [`Trajectory::replay_as`] collapse — it names one shard's slice
    /// of the work.
    pub fn replay_shard_batched_with(
        &self,
        engine: &ZEngine,
        store: &mut ShardedStore,
        manifest: &ShardManifest,
        k: usize,
        seeds_per_step: usize,
    ) -> Result<()> {
        let trainable = self.check_sharded(store, manifest)?;
        self.check_batches(seeds_per_step)?;
        let batches = self.batched_coeffs(seeds_per_step);
        replay_shard_batched_unchecked(engine, store, &trainable, k, &batches);
        Ok(())
    }

    /// Per-seed-batch `(stream, −lr·pgrad)` coefficient vectors — shared
    /// by every shard of a batched sharded replay, so they are built once
    /// per replay, not once per shard.
    fn batched_coeffs(&self, seeds_per_step: usize) -> Vec<Vec<(GaussianStream, f32)>> {
        self.records
            .chunks(seeds_per_step)
            .map(|batch| {
                batch
                    .iter()
                    .map(|r| (GaussianStream::new(r.seed), -(r.lr * r.pgrad)))
                    .collect()
            })
            .collect()
    }

    /// Shared guard of the sharded replay paths: dense logs only, the
    /// manifest must hash-match the store's plan, and every trainable
    /// name must resolve in the plan. Returns the per-tensor trainable
    /// flags the segment walks filter by.
    fn check_sharded(&self, store: &ShardedStore, manifest: &ShardManifest) -> Result<Vec<bool>> {
        if let Some(d) = self.mask_digest {
            bail!(
                "replay_sharded: this log was recorded under a sparse mask (digest {:#x}); \
                 sharded replay covers dense logs — use replay_masked on a dense store",
                d
            );
        }
        manifest.check(store.plan())?;
        let idxs = store.plan().indices_of(&self.trainable)?;
        Ok(trainable_flags(store.plan().n_tensors(), &idxs))
    }

    /// The seed-batch integrity guard shared by the batched replays.
    fn check_batches(&self, seeds_per_step: usize) -> Result<()> {
        if seeds_per_step == 0 {
            bail!("replay_batched: seeds_per_step must be > 0");
        }
        if self.records.len() % seeds_per_step != 0 {
            bail!(
                "replay_batched: {} records do not divide into seed-batches of {}",
                self.records.len(),
                seeds_per_step
            );
        }
        Ok(())
    }

    /// Write the log to disk. Binary format:
    /// `"MZTJ" | n_names u32 | (len u32, bytes)* | n_records u64 |
    /// (seed u64, pgrad f32, lr f32)*`, all little-endian. A sparse log
    /// (carrying a mask digest) writes magic `"MZT2"` instead, with
    /// `digest u64` inserted right after the magic — dense logs keep the
    /// legacy layout so older readers are unaffected.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        match self.mask_digest {
            None => f.write_all(b"MZTJ")?,
            Some(d) => {
                f.write_all(b"MZT2")?;
                f.write_all(&d.to_le_bytes())?;
            }
        }
        f.write_all(&(self.trainable.len() as u32).to_le_bytes())?;
        for n in &self.trainable {
            f.write_all(&(n.len() as u32).to_le_bytes())?;
            f.write_all(n.as_bytes())?;
        }
        f.write_all(&(self.records.len() as u64).to_le_bytes())?;
        for r in &self.records {
            f.write_all(&r.seed.to_le_bytes())?;
            f.write_all(&r.pgrad.to_le_bytes())?;
            f.write_all(&r.lr.to_le_bytes())?;
        }
        Ok(())
    }

    /// Read a trajectory written by [`Trajectory::save`] (either magic).
    pub fn load(path: &Path) -> std::io::Result<Trajectory> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        let mut u32b = [0u8; 4];
        let mut u64b = [0u8; 8];
        let mask_digest = match &magic {
            b"MZTJ" => None,
            b"MZT2" => {
                f.read_exact(&mut u64b)?;
                Some(u64::from_le_bytes(u64b))
            }
            _ => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "bad trajectory magic",
                ))
            }
        };
        f.read_exact(&mut u32b)?;
        let n_names = u32::from_le_bytes(u32b) as usize;
        let mut trainable = Vec::with_capacity(n_names);
        for _ in 0..n_names {
            f.read_exact(&mut u32b)?;
            let len = u32::from_le_bytes(u32b) as usize;
            let mut b = vec![0u8; len];
            f.read_exact(&mut b)?;
            trainable.push(String::from_utf8_lossy(&b).to_string());
        }
        f.read_exact(&mut u64b)?;
        let n = u64::from_le_bytes(u64b) as usize;
        let mut records = Vec::with_capacity(n);
        for _ in 0..n {
            f.read_exact(&mut u64b)?;
            let seed = u64::from_le_bytes(u64b);
            f.read_exact(&mut u32b)?;
            let pgrad = f32::from_le_bytes(u32b);
            f.read_exact(&mut u32b)?;
            let lr = f32::from_le_bytes(u32b);
            records.push(StepRecord { seed, pgrad, lr });
        }
        Ok(Trajectory { trainable, records, mask_digest })
    }
}

/// Guard-free body of the per-shard seed-batched replay: one fused
/// [`ZEngine::multi_axpy_z`] pass per batch per trainable segment of
/// shard `k`. Callers have validated the manifest, resolved the
/// trainable flags, and built the per-batch coefficients.
fn replay_shard_batched_unchecked(
    engine: &ZEngine,
    store: &mut ShardedStore,
    trainable: &[bool],
    k: usize,
    batches: &[Vec<(GaussianStream, f32)>],
) {
    let offsets: Vec<u64> = store.plan().offsets().to_vec();
    for zs in batches {
        for (seg, buf) in store.segments_mut(k) {
            if !trainable[seg.tensor] {
                continue;
            }
            engine.multi_axpy_z(zs, offsets[seg.tensor] + seg.lo as u64, buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;
    use crate::model::params::ParamStore;
    use crate::optim::mezo::{MezoConfig, MezoSgd};

    fn toy() -> ParamStore {
        let mut p = ParamStore::from_specs(vec![
            TensorDesc { name: "w1".into(), shape: vec![10], dtype: "f32".into() },
            TensorDesc { name: "w2".into(), shape: vec![5], dtype: "f32".into() },
        ]);
        p.init(0);
        p
    }

    #[test]
    fn replay_reconstructs_training_trajectory() {
        let mut trained = toy();
        let cfg = MezoConfig { lr: 1e-2, eps: 1e-3, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 9);
        for _ in 0..50 {
            opt.step(&mut trained, |p| {
                Ok(p.data.iter().flatten().map(|&x| (x - 0.5) * (x - 0.5)).sum())
            })
            .unwrap();
        }
        let traj = Trajectory::from_run(
            vec!["w1".into(), "w2".into()],
            &opt.history,
        );
        let mut replayed = toy();
        traj.replay(&mut replayed);
        for (a, b) in trained.data.iter().flatten().zip(replayed.data.iter().flatten()) {
            // equal up to the ±ε perturb/restore rounding of Algorithm 1
            assert!((a - b).abs() < 1e-4, "{} vs {}", a, b);
        }
    }

    #[test]
    fn replay_batched_with_unit_batches_is_bitwise_replay() {
        // seeds_per_step = 1 must be the sequential replay, bit for bit
        let mut traj = Trajectory::new(vec!["w1".into(), "w2".into()]);
        for i in 0..7u64 {
            traj.records.push(StepRecord {
                seed: 100 + i,
                pgrad: 0.1 * i as f32 - 0.3,
                lr: 1e-3,
            });
        }
        let mut a = toy();
        let mut b = toy();
        traj.replay(&mut a);
        traj.replay_batched(&mut b, 1).unwrap();
        for (x, y) in a.data.iter().flatten().zip(b.data.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{} vs {}", x, y);
        }
    }

    #[test]
    fn replay_is_bit_identical_on_pool_and_scope_dispatch() {
        use crate::model::meta::TensorDesc;
        // a tensor large enough that both dispatchers actually fan out
        let mut p = ParamStore::from_specs(vec![TensorDesc {
            name: "w".into(),
            shape: vec![70_000],
            dtype: "f32".into(),
        }]);
        p.init(11);
        let mut traj = Trajectory::new(vec!["w".into()]);
        for i in 0..12u64 {
            traj.records.push(StepRecord {
                seed: 40 + i,
                pgrad: 0.07 * i as f32 - 0.3,
                lr: 1e-3,
            });
        }
        let mut pool = p.clone();
        traj.replay_with(&ZEngine::with_threads(8), &mut pool);
        let mut scope = p.clone();
        traj.replay_with(&ZEngine::with_threads_scoped(8), &mut scope);
        for (x, y) in pool.data[0].iter().zip(&scope.data[0]) {
            assert_eq!(x.to_bits(), y.to_bits(), "{} vs {}", x, y);
        }
        // the seed-batched flavor too
        let mut pool_b = p.clone();
        traj.replay_batched_with(&ZEngine::with_threads(8), &mut pool_b, 3).unwrap();
        let mut scope_b = p.clone();
        traj.replay_batched_with(&ZEngine::with_threads_scoped(8), &mut scope_b, 3).unwrap();
        for (x, y) in pool_b.data[0].iter().zip(&scope_b.data[0]) {
            assert_eq!(x.to_bits(), y.to_bits(), "batched: {} vs {}", x, y);
        }
    }

    #[test]
    fn replay_batched_rejects_mismatched_seed_batch_sizes() {
        // 7 records cannot be a run of 4-seed steps; the guard flags a
        // truncated or mislabeled log instead of quietly accepting it
        let mut traj = Trajectory::new(vec!["w1".into()]);
        for i in 0..7u64 {
            traj.records.push(StepRecord { seed: i, pgrad: 0.1, lr: 1e-3 });
        }
        let mut p = toy();
        let err = traj.replay_batched(&mut p, 4).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("seed-batches"), "unexpected error: {}", msg);
        // zero-size batches are rejected too
        assert!(traj.replay_batched(&mut p, 0).is_err());
        // and a dividing batch size is accepted
        assert!(traj.replay_batched(&mut p, 7).is_ok());
    }

    #[test]
    fn save_load_roundtrip() {
        let path = std::env::temp_dir().join("mezo_traj_test.bin");
        let mut traj = Trajectory::new(vec!["w1".into()]);
        traj.records.push(StepRecord { seed: 7, pgrad: 0.25, lr: 1e-3 });
        traj.records.push(StepRecord { seed: 8, pgrad: -0.5, lr: 1e-3 });
        traj.save(&path).unwrap();
        let back = Trajectory::load(&path).unwrap();
        assert_eq!(back, traj);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn masked_replay_reconstructs_sparse_run_and_guards_digest() {
        use crate::optim::fzoo::{Fzoo, FzooConfig};
        use crate::zkernel::{Sensitivity, SparseMask};
        let mut trained = toy();
        let mask = SparseMask::top_k(&trained, &[0, 1], 9, Sensitivity::Magnitude).unwrap();
        let n = 3usize;
        let cfg = FzooConfig { lr: 1e-2, eps: 1e-3, n, ..Default::default() };
        let mut opt = Fzoo::new(cfg, vec![0, 1], 13);
        opt.mask = Some(mask.clone());
        for _ in 0..20 {
            opt.step(&mut trained, |p| {
                Ok(p.data.iter().flatten().map(|&x| (x - 0.5) * (x - 0.5)).sum())
            })
            .unwrap();
        }
        let traj = Trajectory::from_run(vec!["w1".into(), "w2".into()], &opt.history)
            .with_mask_digest(mask.digest());

        // sequential and batched masked replay land on the trained params
        // (wd = 0: the log is the whole update)
        for batched in [false, true] {
            let mut replayed = toy();
            if batched {
                traj.replay_batched_masked(&mut replayed, &mask, n).unwrap();
            } else {
                traj.replay_masked(&mut replayed, &mask).unwrap();
            }
            for (a, b) in trained.data.iter().flatten().zip(replayed.data.iter().flatten()) {
                assert!((a - b).abs() < 1e-5, "batched={}: {} vs {}", batched, a, b);
            }
        }

        // a different mask fails loudly
        let other = SparseMask::top_k(&trained, &[0, 1], 5, Sensitivity::Magnitude).unwrap();
        let err = traj.replay_masked(&mut toy(), &other).unwrap_err();
        assert!(err.to_string().contains("digest"), "{}", err);
        let err = traj.replay_batched_masked(&mut toy(), &other, n).unwrap_err();
        assert!(err.to_string().contains("digest"), "{}", err);
        // the dense batched path refuses a sparse log
        let err = traj.replay_batched(&mut toy(), n).unwrap_err();
        assert!(err.to_string().contains("sparse mask"), "{}", err);
        // and masked replay refuses a dense log
        let dense = Trajectory::from_run(vec!["w1".into(), "w2".into()], &opt.history);
        let err = dense.replay_masked(&mut toy(), &mask).unwrap_err();
        assert!(err.to_string().contains("dense"), "{}", err);
    }

    #[test]
    fn sharded_replay_gathers_to_the_dense_replay_bitwise() {
        use crate::shard::{ShardPlan, ShardedStore};
        // a tensor big enough that the engine actually fans out, plus a
        // small one so a shard cut can land mid-tensor
        let mk = || {
            let mut p = ParamStore::from_specs(vec![
                TensorDesc { name: "w1".into(), shape: vec![70_000], dtype: "f32".into() },
                TensorDesc { name: "w2".into(), shape: vec![123], dtype: "f32".into() },
            ]);
            p.init(9);
            p
        };
        let mut traj = Trajectory::new(vec!["w1".into(), "w2".into()]);
        for i in 0..9u64 {
            traj.records.push(StepRecord {
                seed: 70 + i,
                pgrad: 0.05 * i as f32 - 0.2,
                lr: 1e-3,
            });
        }
        let init = mk();
        let mut dense = mk();
        traj.replay_with(&ZEngine::with_threads(2), &mut dense);
        for k in [1usize, 2, 4] {
            let plan = ShardPlan::new(&init, k).unwrap();
            let manifest = plan.manifest();
            for batched in [false, true] {
                let mut sharded = ShardedStore::scatter(&plan, &init).unwrap();
                if batched {
                    traj.replay_sharded_batched_with(
                        &ZEngine::with_threads(2),
                        &mut sharded,
                        &manifest,
                        3,
                    )
                    .unwrap();
                } else {
                    traj.replay_sharded_with(&ZEngine::with_threads(2), &mut sharded, &manifest)
                        .unwrap();
                }
                let mut gathered = mk();
                sharded.gather_into(&mut gathered).unwrap();
                for (a, b) in dense.data.iter().flatten().zip(gathered.data.iter().flatten()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "k={} batched={}", k, batched);
                }
            }
        }
    }

    #[test]
    fn sharded_replay_guards_manifest_log_kind_and_names() {
        use crate::shard::{ShardPlan, ShardedStore};
        let p = toy();
        let plan = ShardPlan::new(&p, 2).unwrap();
        let mut traj = Trajectory::new(vec!["w1".into()]);
        traj.records.push(StepRecord { seed: 3, pgrad: 0.2, lr: 1e-3 });
        // a manifest from a DIFFERENT plan fails loudly
        let wrong = ShardPlan::new(&p, 3).unwrap().manifest();
        let mut sharded = ShardedStore::scatter(&plan, &p).unwrap();
        let err = traj.replay_sharded(&mut sharded, &wrong).unwrap_err();
        assert!(err.to_string().contains("plan digest"), "{}", err);
        let err = traj.replay_sharded_batched(&mut sharded, &wrong, 1).unwrap_err();
        assert!(err.to_string().contains("plan digest"), "{}", err);
        // a sparse log is refused
        let sparse = Trajectory::from_run(vec!["w1".into()], &traj.records)
            .with_mask_digest(0xBEEF);
        let err = sparse.replay_sharded(&mut sharded, &plan.manifest()).unwrap_err();
        assert!(err.to_string().contains("sparse mask"), "{}", err);
        // an unknown trainable name is refused
        let alien = Trajectory::from_run(vec!["nope".into()], &traj.records);
        let err = alien.replay_sharded(&mut sharded, &plan.manifest()).unwrap_err();
        assert!(err.to_string().contains("no tensor named"), "{}", err);
        // the matching manifest replays fine, and only w1 moves
        let before = sharded.clone();
        traj.replay_sharded(&mut sharded, &plan.manifest()).unwrap();
        let mut moved = false;
        for k in 0..plan.n_shards() {
            for (si, seg) in plan.shard(k).segments.iter().enumerate() {
                let (a, b) = (before.segment(k, si), sharded.segment(k, si));
                if seg.tensor == 0 {
                    moved |= a.iter().zip(b).any(|(x, y)| x.to_bits() != y.to_bits());
                } else {
                    assert!(
                        a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                        "non-trainable tensor moved"
                    );
                }
            }
        }
        assert!(moved, "trainable tensor never moved");
    }

    #[test]
    #[should_panic(expected = "sparse mask")]
    fn dense_replay_panics_on_sparse_log() {
        let traj = Trajectory::new(vec!["w1".into()]).with_mask_digest(0xDEAD);
        traj.replay(&mut toy());
    }

    #[test]
    fn save_load_roundtrips_sparse_logs_and_stays_legacy_for_dense() {
        let dir = std::env::temp_dir();
        // sparse: digest survives the roundtrip under the MZT2 magic
        let path = dir.join("mezo_traj_sparse_test.bin");
        let mut traj = Trajectory::new(vec!["w1".into()]).with_mask_digest(0xC0FFEE);
        traj.records.push(StepRecord { seed: 7, pgrad: 0.25, lr: 1e-3 });
        traj.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..4], b"MZT2");
        let back = Trajectory::load(&path).unwrap();
        assert_eq!(back, traj);
        assert_eq!(back.mask_digest, Some(0xC0FFEE));
        std::fs::remove_file(&path).ok();
        // dense: byte-identical legacy header
        let path = dir.join("mezo_traj_dense_test.bin");
        let dense = Trajectory::from_run(vec!["w1".into()], &traj.records);
        dense.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..4], b"MZTJ");
        assert_eq!(Trajectory::load(&path).unwrap().mask_digest, None);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replay_as_matches_the_named_wrappers_bitwise() {
        use crate::zkernel::{Sensitivity, SparseMask};
        let mut traj = Trajectory::new(vec!["w1".into(), "w2".into()]);
        for i in 0..6u64 {
            traj.records.push(StepRecord {
                seed: 300 + i,
                pgrad: 0.08 * i as f32 - 0.2,
                lr: 1e-3,
            });
        }
        let engine = ZEngine::default();
        let same_bits = |x: &ParamStore, y: &ParamStore| {
            x.data
                .iter()
                .flatten()
                .zip(y.data.iter().flatten())
                .all(|(a, b)| a.to_bits() == b.to_bits())
        };
        // dense: Sequential and Batched through replay_as == the wrappers
        let mut a = toy();
        let mut b = toy();
        traj.replay_with(&engine, &mut a);
        traj.replay_as(&engine, ReplayTarget::Store(&mut b), ReplayMode::Sequential).unwrap();
        assert!(same_bits(&a, &b));
        let mut c = toy();
        traj.replay_as(
            &engine,
            ReplayTarget::Store(&mut c),
            ReplayMode::Batched { seeds_per_step: 3 },
        )
        .unwrap();
        assert!(same_bits(&a, &c));
        // sparse: Masked and MaskedBatched through replay_as == wrappers
        let mask = SparseMask::top_k(&toy(), &[0, 1], 7, Sensitivity::Magnitude).unwrap();
        let sparse = Trajectory::from_run(vec!["w1".into(), "w2".into()], &traj.records)
            .with_mask_digest(mask.digest());
        let mut ma = toy();
        let mut mb = toy();
        sparse.replay_masked_with(&engine, &mut ma, &mask).unwrap();
        sparse
            .replay_as(&engine, ReplayTarget::Store(&mut mb), ReplayMode::Masked { mask: &mask })
            .unwrap();
        assert!(same_bits(&ma, &mb));
        let mut mc = toy();
        sparse
            .replay_as(
                &engine,
                ReplayTarget::Store(&mut mc),
                ReplayMode::MaskedBatched { mask: &mask, seeds_per_step: 2 },
            )
            .unwrap();
        assert!(same_bits(&ma, &mc));
        // the guards fire through the dispatcher too
        let err = sparse
            .replay_as(&engine, ReplayTarget::Store(&mut toy()), ReplayMode::Sequential)
            .unwrap_err();
        assert!(err.to_string().contains("sparse mask"), "{}", err);
    }

    #[test]
    fn replay_as_rejects_masked_modes_on_sharded_targets() {
        use crate::shard::{ShardPlan, ShardedStore};
        use crate::zkernel::{Sensitivity, SparseMask};
        let p = toy();
        let mask = SparseMask::top_k(&p, &[0, 1], 5, Sensitivity::Magnitude).unwrap();
        let mut traj = Trajectory::new(vec!["w1".into()]).with_mask_digest(mask.digest());
        traj.records.push(StepRecord { seed: 5, pgrad: 0.1, lr: 1e-3 });
        let plan = ShardPlan::new(&p, 2).unwrap();
        let manifest = plan.manifest();
        let mut sharded = ShardedStore::scatter(&plan, &p).unwrap();
        let engine = ZEngine::default();
        for mode in [
            ReplayMode::Masked { mask: &mask },
            ReplayMode::MaskedBatched { mask: &mask, seeds_per_step: 1 },
        ] {
            let err = traj
                .replay_as(&engine, ReplayTarget::Sharded(&mut sharded, &manifest), mode)
                .unwrap_err();
            assert!(err.to_string().contains("sharded target"), "{}", err);
        }
        // and the dense sharded modes still dispatch (dense log)
        let dense = Trajectory::from_run(vec!["w1".into()], &traj.records);
        dense
            .replay_as(
                &engine,
                ReplayTarget::Sharded(&mut sharded, &manifest),
                ReplayMode::Sequential,
            )
            .unwrap();
        dense
            .replay_as(
                &engine,
                ReplayTarget::Sharded(&mut sharded, &manifest),
                ReplayMode::Batched { seeds_per_step: 1 },
            )
            .unwrap();
    }

    #[test]
    fn masked_replay_on_a_quant_store_is_bitwise_the_dense_masked_replay() {
        use crate::model::quant::QuantStore;
        use crate::zkernel::{QBits, Sensitivity, SparseMask};
        let base = toy();
        let mask = SparseMask::top_k(&base, &[0, 1], 6, Sensitivity::Magnitude).unwrap();
        let mut traj = Trajectory::new(vec!["w1".into(), "w2".into()])
            .with_mask_digest(mask.digest());
        for i in 0..8u64 {
            traj.records.push(StepRecord {
                seed: 900 + i,
                pgrad: 0.09 * i as f32 - 0.31,
                lr: 2e-3,
            });
        }
        let mut dense = base.clone();
        traj.replay_masked(&mut dense, &mask).unwrap();
        for bits in [QBits::Int8, QBits::Int4] {
            let mut q = QuantStore::quantize(&base, bits, Some(&mask)).unwrap();
            traj.replay_masked(&mut q, &mask).unwrap();
            // every masked coordinate lives in the f32 overlay, so the
            // quantized replay is bit-identical there to the dense one
            let out = q.to_dense();
            for ti in 0..base.specs.len() {
                for &i in mask.indices(ti) {
                    assert_eq!(
                        dense.data[ti][i as usize].to_bits(),
                        out.data[ti][i as usize].to_bits(),
                        "bits={:?} ti={} i={}",
                        bits,
                        ti,
                        i
                    );
                }
            }
        }
    }

    #[test]
    fn storage_is_tiny_versus_checkpoint() {
        // 20k steps (the paper's OPT runs) => ~40KB quantized, < 0.1MB
        let traj = Trajectory {
            trainable: vec!["w".into()],
            records: vec![StepRecord { seed: 0, pgrad: 0.0, lr: 0.0 }; 20_000],
            mask_digest: None,
        };
        assert!(traj.bytes_quantized() < 100_000);
        assert!(traj.bytes_f32() < 400_000);
    }
}
