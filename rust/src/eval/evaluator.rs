//! The artifact-backed evaluator (candidate scoring, greedy decoding,
//! feature extraction) — pjrt builds only; split out of `eval` so the
//! runtime-free `metrics` stay available to the default build.

use super::metrics;
use crate::data::batch::Batch;
use crate::data::tasks::{Example, Task, TaskType};
use crate::model::params::ParamStore;
use crate::runtime::{vec_f32, Artifact};
use crate::tokenizer::EOS;
use anyhow::Result;
use std::rc::Rc;

/// Artifact-backed task evaluator: scores candidates by mean per-token
/// NLL (Appendix E.4), greedy-decodes generation tasks, and extracts
/// pooled features for linear probing.
pub struct Evaluator {
    /// loss-mode artifact (candidate scoring + train loss)
    pub loss_art: Rc<Artifact>,
    /// logits-mode artifact (generation + features); optional
    pub logits_art: Option<Rc<Artifact>>,
    /// masked-LM input convention (RoBERTa-style) instead of
    /// autoregressive
    pub mlm: bool,
}

/// Aggregate scores of one evaluation pass over a task split.
#[derive(Debug, Clone, Default)]
pub struct EvalResult {
    /// accuracy for cls/mch; token-F1 for generation
    pub score: f64,
    /// exact-match rate (generation tasks; equals `score` otherwise)
    pub em: f64,
    /// examples evaluated
    pub n: usize,
}

impl Evaluator {
    /// Evaluator over a loss artifact, an optional logits artifact (for
    /// generation/features) and the input convention flag.
    pub fn new(loss_art: Rc<Artifact>, logits_art: Option<Rc<Artifact>>, mlm: bool) -> Evaluator {
        Evaluator { loss_art, logits_art, mlm }
    }

    fn b(&self) -> usize {
        self.loss_art.meta.batch
    }
    fn s(&self) -> usize {
        self.loss_art.meta.seq
    }

    /// Mean NLL of each (example, candidate) pair, batched through the loss
    /// artifact.
    pub fn candidate_nlls(
        &self,
        params: &ParamStore,
        examples: &[&Example],
    ) -> Result<Vec<Vec<f32>>> {
        let (b, s) = (self.b(), self.s());
        // flatten all (example, candidate) rows
        let mut rows: Vec<(usize, usize)> = Vec::new();
        for (ei, ex) in examples.iter().enumerate() {
            for ci in 0..ex.candidates.len() {
                rows.push((ei, ci));
            }
        }
        let mut out: Vec<Vec<f32>> =
            examples.iter().map(|e| vec![0.0; e.candidates.len()]).collect();
        let mut i = 0;
        while i < rows.len() {
            let mut batch = Batch::zeros(b, s);
            let chunk = &rows[i..(i + b).min(rows.len())];
            for (row, &(ei, ci)) in chunk.iter().enumerate() {
                let (seq, range) = examples[ei].with_candidate(ci);
                batch.set_row(row, &seq, range, self.mlm);
            }
            // duplicate the last row into any padding rows so shapes hold
            for row in chunk.len()..b {
                let &(ei, ci) = &chunk[chunk.len() - 1];
                let (seq, range) = examples[ei].with_candidate(ci);
                batch.set_row(row, &seq, range, self.mlm);
            }
            let res = self.loss_art.run(params, Some(&batch), &[])?;
            let per_ex = vec_f32(&res[1])?;
            for (row, &(ei, ci)) in chunk.iter().enumerate() {
                out[ei][ci] = per_ex[row];
            }
            i += b;
        }
        Ok(out)
    }

    /// Predicted candidate index per example (min mean NLL).
    pub fn predict(&self, params: &ParamStore, examples: &[&Example]) -> Result<Vec<usize>> {
        let nlls = self.candidate_nlls(params, examples)?;
        Ok(nlls
            .iter()
            .map(|ns| {
                ns.iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect())
    }

    /// Greedy decoding: generate up to `max_new` tokens after each context.
    pub fn generate(
        &self,
        params: &ParamStore,
        examples: &[&Example],
        max_new: usize,
    ) -> Result<Vec<Vec<u32>>> {
        let art = self
            .logits_art
            .as_ref()
            .expect("generation requires a logits artifact");
        let (b, s) = (art.meta.batch, art.meta.seq);
        let vocab = art.meta.vocab;
        let stop = EOS;
        let mut outputs: Vec<Vec<u32>> = vec![Vec::new(); examples.len()];
        let mut i = 0;
        while i < examples.len() {
            let chunk = &examples[i..(i + b).min(examples.len())];
            let mut seqs: Vec<Vec<u32>> = chunk.iter().map(|e| e.context.clone()).collect();
            let mut done = vec![false; chunk.len()];
            for _ in 0..max_new {
                let mut batch = Batch::zeros(b, s);
                for (row, seq) in seqs.iter().enumerate() {
                    for (t, &tok) in seq.iter().enumerate().take(s) {
                        batch.input_ids[row * s + t] = tok as i32;
                        batch.attn_mask[row * s + t] = 1.0;
                    }
                }
                let res = art.run(params, Some(&batch), &[])?;
                let logits = vec_f32(&res[0])?; // (B, S, V)
                for (row, seq) in seqs.iter_mut().enumerate() {
                    if done[row] || seq.len() >= s {
                        continue;
                    }
                    let pos = seq.len() - 1;
                    let base = row * s * vocab + pos * vocab;
                    let slice = &logits[base..base + vocab];
                    let mut best = 0usize;
                    let mut bv = f32::NEG_INFINITY;
                    for (t, &v) in slice.iter().enumerate() {
                        if v > bv {
                            bv = v;
                            best = t;
                        }
                    }
                    let tok = best as u32;
                    if tok == stop {
                        done[row] = true;
                    } else {
                        seq.push(tok);
                    }
                }
                if done.iter().all(|&d| d) {
                    break;
                }
            }
            for (row, ex) in chunk.iter().enumerate() {
                outputs[i + row] = seqs[row][ex.context.len()..].to_vec();
            }
            i += b;
        }
        Ok(outputs)
    }

    /// Evaluate a task split end to end.
    pub fn evaluate(&self, params: &ParamStore, task: Task, examples: &[Example]) -> Result<EvalResult> {
        let refs: Vec<&Example> = examples.iter().collect();
        match task.task_type() {
            TaskType::Classification | TaskType::MultipleChoice => {
                let preds = self.predict(params, &refs)?;
                let golds: Vec<usize> = examples.iter().map(|e| e.label).collect();
                Ok(EvalResult {
                    score: metrics::accuracy(&preds, &golds),
                    em: 0.0,
                    n: examples.len(),
                })
            }
            TaskType::Generation => {
                let max_new = examples.iter().map(|e| e.answer.len()).max().unwrap_or(2) + 1;
                let gens = self.generate(params, &refs, max_new)?;
                let mut f1 = 0.0;
                let mut em = 0.0;
                for (g, ex) in gens.iter().zip(examples) {
                    // score against the answer without the trailing period
                    let gold: Vec<u32> = ex.answer.clone();
                    let pred = g.get(..gold.len().min(g.len())).unwrap_or(&[]).to_vec();
                    f1 += metrics::token_f1(&pred, &gold);
                    em += metrics::exact_match(&pred, &gold);
                }
                let n = examples.len().max(1);
                Ok(EvalResult { score: f1 / n as f64, em: em / n as f64, n: examples.len() })
            }
        }
    }

    /// Pooled features for linear probing: the final hidden state at the
    /// last context token (AR) / the mask position (MLM).
    pub fn features(&self, params: &ParamStore, examples: &[&Example]) -> Result<Vec<Vec<f32>>> {
        let art = self
            .logits_art
            .as_ref()
            .expect("features require a logits artifact");
        let (b, s) = (art.meta.batch, art.meta.seq);
        let d = art.meta.dims.d_model;
        let mut out: Vec<Vec<f32>> = Vec::with_capacity(examples.len());
        let mut i = 0;
        while i < examples.len() {
            let chunk = &examples[i..(i + b).min(examples.len())];
            let mut batch = Batch::zeros(b, s);
            let mut pos = vec![0usize; chunk.len()];
            for (row, ex) in chunk.iter().enumerate() {
                if self.mlm {
                    // context + [MASK] + suffix; feature at the mask slot
                    let mut seq = ex.context.clone();
                    let hole = seq.len();
                    seq.push(crate::tokenizer::MASK);
                    seq.extend_from_slice(&ex.suffix);
                    batch.set_row(row, &seq, hole..hole + 1, true);
                    pos[row] = hole;
                } else {
                    let seq = ex.context.clone();
                    batch.set_row(row, &seq, 1..seq.len(), false);
                    pos[row] = seq.len() - 1;
                }
            }
            let res = art.run(params, Some(&batch), &[])?;
            let hidden = vec_f32(&res[1])?; // (B, S, D)
            for (row, _) in chunk.iter().enumerate() {
                let base = row * s * d + pos[row] * d;
                out.push(hidden[base..base + d].to_vec());
            }
            i += b;
        }
        Ok(out)
    }
}
