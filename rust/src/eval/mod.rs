//! Evaluation: candidate scoring, greedy decoding, feature extraction.
//!
//! Classification & multiple choice follow the paper (Appendix E.4): each
//! candidate is filled into the prompt and scored by its average per-token
//! log-likelihood (the `per_example_loss` output of the loss artifact);
//! the lowest-NLL candidate wins. Generation uses teacher forcing for
//! training and greedy decoding for inference.

pub mod metrics;

#[cfg(feature = "pjrt")]
mod evaluator;
#[cfg(feature = "pjrt")]
pub use evaluator::{EvalResult, Evaluator};
