//! Task metrics: accuracy, macro-F1, token-level F1 and exact match
//! (the paper reports accuracy for classification/multiple-choice and
//! F1 for SQuAD/DROP-style generation).

/// Plain accuracy over (pred, gold) pairs.
pub fn accuracy(preds: &[usize], golds: &[usize]) -> f64 {
    assert_eq!(preds.len(), golds.len());
    if preds.is_empty() {
        return 0.0;
    }
    let hits = preds.iter().zip(golds).filter(|(p, g)| p == g).count();
    hits as f64 / preds.len() as f64
}

/// Macro-averaged F1 over classes 0..n_classes.
pub fn macro_f1(preds: &[usize], golds: &[usize], n_classes: usize) -> f64 {
    assert_eq!(preds.len(), golds.len());
    let mut f1_sum = 0.0;
    for c in 0..n_classes {
        let tp = preds.iter().zip(golds).filter(|(p, g)| **p == c && **g == c).count() as f64;
        let fp = preds.iter().zip(golds).filter(|(p, g)| **p == c && **g != c).count() as f64;
        let f_n = preds.iter().zip(golds).filter(|(p, g)| **p != c && **g == c).count() as f64;
        let prec = if tp + fp > 0.0 { tp / (tp + fp) } else { 0.0 };
        let rec = if tp + f_n > 0.0 { tp / (tp + f_n) } else { 0.0 };
        f1_sum += if prec + rec > 0.0 { 2.0 * prec * rec / (prec + rec) } else { 0.0 };
    }
    f1_sum / n_classes as f64
}

/// Token-overlap F1 (SQuAD-style, bag-of-tokens with multiplicity).
pub fn token_f1(pred: &[u32], gold: &[u32]) -> f64 {
    if pred.is_empty() && gold.is_empty() {
        return 1.0;
    }
    if pred.is_empty() || gold.is_empty() {
        return 0.0;
    }
    let mut gold_counts = std::collections::HashMap::new();
    for &t in gold {
        *gold_counts.entry(t).or_insert(0usize) += 1;
    }
    let mut overlap = 0usize;
    for &t in pred {
        if let Some(c) = gold_counts.get_mut(&t) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let prec = overlap as f64 / pred.len() as f64;
    let rec = overlap as f64 / gold.len() as f64;
    2.0 * prec * rec / (prec + rec)
}

/// Exact match.
pub fn exact_match(pred: &[u32], gold: &[u32]) -> f64 {
    if pred == gold {
        1.0
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 1], &[0, 1, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn macro_f1_perfect_and_degenerate() {
        assert!((macro_f1(&[0, 1, 0, 1], &[0, 1, 0, 1], 2) - 1.0).abs() < 1e-12);
        // all-one-class predictions get 0 F1 on the other class
        let f = macro_f1(&[0, 0, 0, 0], &[0, 0, 1, 1], 2);
        assert!(f < 0.5);
    }

    #[test]
    fn token_f1_overlap() {
        assert_eq!(token_f1(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(token_f1(&[1, 3], &[1, 2]), 0.5);
        assert_eq!(token_f1(&[], &[1]), 0.0);
        assert_eq!(token_f1(&[], &[]), 1.0);
        // multiplicity counts
        assert!((token_f1(&[5, 5], &[5]) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn em_is_strict() {
        assert_eq!(exact_match(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(exact_match(&[1, 2, 3], &[1, 2]), 0.0);
    }
}
