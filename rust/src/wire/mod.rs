//! The MZW1 shard wire protocol: frames, transports, workers, fleet.
//!
//! PR 5's sharded store proved a MeZO fine-tune decomposes into
//! `(ShardPlan, shard slices, seed/pgrad log)` with bitwise identity to
//! the dense run; this module ships those pieces over a wire and puts a
//! process (or thread) on each end:
//!
//! * [`frame`] — the versioned binary frame codec ("MZW1":
//!   length-prefixed, digest-authenticated, loud typed failure on any
//!   mismatch) and the [`Msg`] protocol vocabulary.
//! * [`transport`] — the [`Transport`] trait with in-process channel
//!   and TCP carriers (no new dependencies).
//! * [`worker`] — [`ShardWorker`], which holds one shard's detached
//!   buffers and serves perturb/update/replay/fetch commands; the
//!   `mezo-worker` binary is a TCP wrapper around it.
//! * [`fleet`] — [`Fleet`], the coordinator: scatter, drive, verify
//!   digests, gather bitwise-identical to dense, and survive worker
//!   churn via checkpoint + command-log replay.
//!
//! The adversarial test surface lives in `tests/properties.rs` (frame
//! fuzzing: arbitrary bytes, truncations, bit flips — typed errors,
//! never panics) and `tests/churn.rs` (kill/restart workers mid-step
//! and mid-replay; the gathered store stays `to_bits()`-identical).

pub mod fleet;
pub mod frame;
pub mod transport;
pub mod worker;

pub use fleet::{channel_spawner, Fleet, FleetConfig, SpawnFn};
pub use frame::{
    frame_digest, Msg, WireError, HEADER_LEN, MAGIC, MAX_PAYLOAD, TRAILER_LEN, VERSION,
};
pub use transport::{channel_pair, ChannelTransport, TcpTransport, Transport};
pub use worker::ShardWorker;
