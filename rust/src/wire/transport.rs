//! Frame transports: how MZW1 frames move between a coordinator and its
//! workers. Two built-in carriers, zero new dependencies:
//!
//! * [`ChannelTransport`] — in-process `std::sync::mpsc` byte-vector
//!   channels, one encoded frame per message. The default for tests and
//!   single-machine fleets; [`channel_pair`] wires a coordinator end to
//!   a worker end.
//! * [`TcpTransport`] — one frame stream over a `TcpStream` (local
//!   sockets; the `mezo-worker` binary's carrier). A read deadline maps
//!   to [`WireError::Timeout`] so a coordinator can treat a stuck
//!   worker exactly like a dead one.
//!
//! Both ends speak the same [`Transport`] trait, so the fleet, the
//! churn harness's chaos wrappers (`tests/churn.rs`) and any future
//! carrier are interchangeable.

use super::frame::{Msg, WireError};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::Duration;

/// One bidirectional frame pipe: send a [`Msg`], receive a [`Msg`].
/// Implementations must preserve frame boundaries and order; integrity
/// comes from the MZW1 digest, which every `recv` verifies.
pub trait Transport: Send {
    /// Send one message. [`WireError::Disconnected`] when the peer is
    /// gone.
    fn send(&mut self, msg: &Msg) -> Result<(), WireError>;
    /// Receive the next message, verifying its frame digest.
    /// [`WireError::Timeout`] when a configured deadline expires first.
    fn recv(&mut self) -> Result<Msg, WireError>;
}

/// In-process transport: encoded frames over a pair of mpsc channels.
pub struct ChannelTransport {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    timeout: Option<Duration>,
}

/// A connected pair of in-process transports — give one end to the
/// coordinator and move the other into the worker's thread. `timeout`
/// bounds every `recv` on both ends (None blocks forever).
pub fn channel_pair(timeout: Option<Duration>) -> (ChannelTransport, ChannelTransport) {
    let (a_tx, b_rx) = mpsc::channel();
    let (b_tx, a_rx) = mpsc::channel();
    (
        ChannelTransport { tx: a_tx, rx: a_rx, timeout },
        ChannelTransport { tx: b_tx, rx: b_rx, timeout },
    )
}

impl Transport for ChannelTransport {
    fn send(&mut self, msg: &Msg) -> Result<(), WireError> {
        self.tx.send(msg.encode()).map_err(|_| WireError::Disconnected)
    }

    fn recv(&mut self) -> Result<Msg, WireError> {
        let bytes = match self.timeout {
            Some(d) => self.rx.recv_timeout(d).map_err(|e| match e {
                mpsc::RecvTimeoutError::Timeout => WireError::Timeout,
                mpsc::RecvTimeoutError::Disconnected => WireError::Disconnected,
            })?,
            None => self.rx.recv().map_err(|_| WireError::Disconnected)?,
        };
        let (msg, used) = Msg::decode(&bytes)?;
        if used != bytes.len() {
            return Err(WireError::BadPayload(format!(
                "channel message carries {} bytes past the frame",
                bytes.len() - used
            )));
        }
        Ok(msg)
    }
}

/// Socket transport: the MZW1 stream framing over TCP.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Wrap a connected stream. `read_timeout` bounds every `recv`
    /// (None blocks forever); Nagle is disabled — frames are
    /// request/response sized, latency beats batching here.
    pub fn new(stream: TcpStream, read_timeout: Option<Duration>) -> std::io::Result<TcpTransport> {
        stream.set_read_timeout(read_timeout)?;
        stream.set_nodelay(true)?;
        Ok(TcpTransport { stream })
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> Result<(), WireError> {
        msg.write_to(&mut self.stream)
    }

    fn recv(&mut self) -> Result<Msg, WireError> {
        Msg::read_from(&mut self.stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn channel_pair_roundtrips_and_times_out() {
        let (mut a, mut b) = channel_pair(Some(Duration::from_millis(50)));
        a.send(&Msg::Hello { node: 7 }).unwrap();
        assert_eq!(b.recv().unwrap(), Msg::Hello { node: 7 });
        b.send(&Msg::Ack).unwrap();
        assert_eq!(a.recv().unwrap(), Msg::Ack);
        // nothing pending: the deadline fires as a typed Timeout
        assert_eq!(a.recv().unwrap_err().kind_name(), "timeout");
        // dropping one end disconnects the other
        drop(b);
        assert_eq!(a.recv().unwrap_err().kind_name(), "disconnected");
        assert_eq!(a.send(&Msg::Ack).unwrap_err().kind_name(), "disconnected");
    }

    #[test]
    fn tcp_transport_roundtrips_and_times_out() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut t = TcpTransport::new(
                TcpStream::connect(addr).unwrap(),
                Some(Duration::from_secs(5)),
            )
            .unwrap();
            t.send(&Msg::Hello { node: 1 }).unwrap();
            assert_eq!(t.recv().unwrap(), Msg::Shutdown);
        });
        let (stream, _) = listener.accept().unwrap();
        let mut server = TcpTransport::new(stream, Some(Duration::from_millis(50))).unwrap();
        assert_eq!(server.recv().unwrap(), Msg::Hello { node: 1 });
        // empty socket: deadline -> typed Timeout
        assert_eq!(server.recv().unwrap_err().kind_name(), "timeout");
        server.send(&Msg::Shutdown).unwrap();
        client.join().unwrap();
        // client hung up after the shutdown: EOF -> Disconnected
        assert_eq!(server.recv().unwrap_err().kind_name(), "disconnected");
    }
}
