//! The worker half of the shard protocol: holds ONE shard's detached
//! segment buffers and serves perturb / update / replay / fetch
//! commands over a [`Transport`].
//!
//! Bit-exactness is inherited, not re-proven: every buffer IS the
//! `[lo, hi)` slice of its tensor, so running the dense kernels with
//! the z counter based at `offset + lo` performs exactly the arithmetic
//! the `_shard` kernels (and therefore the dense step) perform on that
//! slice — the same alignment `storage::Trajectory::replay_shard_with`
//! uses. A fleet of these workers therefore reproduces the dense run
//! bit for bit (`tests/churn.rs` pins this, including under churn).
//!
//! Failure discipline: a command carrying a stale plan digest, an
//! unknown tensor name, a sparse log, or malformed geometry is refused
//! with [`Msg::Nack`] — the worker stays up and keeps its state, the
//! *coordinator* decides what to do. Only transport-level failures
//! (peer gone) end the serve loop.

use super::frame::{Msg, WireError};
use super::transport::Transport;
use crate::obs::metrics;
use crate::rng::GaussianStream;
use crate::shard::ShardPlan;
use crate::storage::Trajectory;
use crate::zkernel::ZEngine;
use anyhow::{bail, Result};

/// A worker's installed shard: the plan it serves under, which shard it
/// owns, the per-tensor trainable flags, and one detached buffer per
/// segment.
struct Loaded {
    plan: ShardPlan,
    shard: usize,
    trainable: Vec<bool>,
    segments: Vec<Vec<f32>>,
}

/// One shard-serving worker. Drive it with [`ShardWorker::serve`] over
/// any transport (the `mezo-worker` binary serves TCP; tests serve
/// in-process channels), or feed it messages directly with
/// [`ShardWorker::handle`].
pub struct ShardWorker {
    engine: ZEngine,
    state: Option<Loaded>,
}

impl Default for ShardWorker {
    fn default() -> ShardWorker {
        ShardWorker::new()
    }
}

impl ShardWorker {
    /// A worker with no shard installed yet, on the process-default
    /// engine (`MEZO_THREADS` / `MEZO_SIMD` apply as everywhere else).
    pub fn new() -> ShardWorker {
        ShardWorker { engine: ZEngine::default(), state: None }
    }

    /// A worker on an explicit kernel engine.
    pub fn with_engine(engine: ZEngine) -> ShardWorker {
        ShardWorker { engine, state: None }
    }

    /// Serve requests until the peer disconnects or sends
    /// [`Msg::Shutdown`] (both return `Ok`). Malformed frames and
    /// refused commands are answered with [`Msg::Nack`] and the loop
    /// continues; only an unusable transport is an error.
    pub fn serve<T: Transport + ?Sized>(&mut self, transport: &mut T) -> Result<(), WireError> {
        loop {
            let msg = match transport.recv() {
                Ok(m) => m,
                Err(WireError::Disconnected) => return Ok(()),
                Err(e) if e.is_transport() => return Err(e),
                // decode-level failure: the frame was delivered but is
                // corrupt or skewed — tell the peer loudly, keep serving
                Err(e) => {
                    if e.kind_name() == "bad_digest" {
                        metrics::WORKER_DIGEST_FAILURES.inc();
                    }
                    metrics::WORKER_NACKS.inc();
                    transport.send(&Msg::Nack { message: e.to_string() })?;
                    continue;
                }
            };
            metrics::WORKER_FRAMES[metrics::msg_kind_index(msg.kind_name())].inc();
            let shutdown = matches!(msg, Msg::Shutdown);
            let reply = match self.handle(msg) {
                Ok(r) => r,
                Err(e) => {
                    metrics::WORKER_NACKS.inc();
                    Msg::Nack { message: e.to_string() }
                }
            };
            transport.send(&reply)?;
            if shutdown {
                return Ok(());
            }
        }
    }

    /// Handle one request, returning the reply frame. Exposed so tests
    /// (and in-process fleets) can drive a worker without a transport.
    pub fn handle(&mut self, msg: Msg) -> Result<Msg> {
        match msg {
            Msg::Hello { .. } | Msg::Shutdown => Ok(Msg::Ack),
            Msg::LoadShard { plan, shard, trainable, segments } => {
                self.load(*plan, shard as usize, &trainable, segments)?;
                Ok(Msg::Ack)
            }
            Msg::Perturb { plan_digest, seed, scale } => {
                let engine = self.engine;
                let st = self.loaded(plan_digest)?;
                let stream = GaussianStream::new(seed);
                // each buffer IS its segment's [lo, hi) slice: counter
                // base offset + lo, the exact alignment of the in-place
                // shard kernels
                for (base, buf) in st.trainable_segments() {
                    engine.axpy_z(stream, base, buf, scale);
                }
                Ok(Msg::Ack)
            }
            Msg::Update { plan_digest, zs, lr, wd } => {
                let engine = self.engine;
                let st = self.loaded(plan_digest)?;
                let streams: Vec<(GaussianStream, f32)> =
                    zs.iter().map(|&(seed, c)| (GaussianStream::new(seed), c)).collect();
                for (base, buf) in st.trainable_segments() {
                    engine.multi_sgd_update(&streams, base, buf, lr, wd);
                }
                Ok(Msg::Ack)
            }
            Msg::Replay { plan_digest, log, seeds_per_step } => {
                self.replay(plan_digest, &log, seeds_per_step as usize)?;
                Ok(Msg::Ack)
            }
            Msg::FetchShard { plan_digest } => {
                let st = self.loaded(plan_digest)?;
                Ok(Msg::ShardSlice {
                    plan_digest: st.plan.digest(),
                    shard: st.shard as u32,
                    shard_digest: st.plan.shard_digest(st.shard),
                    segments: st.segments.clone(),
                })
            }
            other => bail!("worker: unexpected {} frame", other.kind_name()),
        }
    }

    /// Which shard the worker currently holds, if any.
    pub fn shard(&self) -> Option<usize> {
        self.state.as_ref().map(|s| s.shard)
    }

    fn load(
        &mut self,
        plan: ShardPlan,
        shard: usize,
        trainable: &[String],
        segments: Vec<Vec<f32>>,
    ) -> Result<()> {
        if shard >= plan.n_shards() {
            bail!(
                "worker: shard index {} out of range for a {}-shard plan",
                shard,
                plan.n_shards()
            );
        }
        let idxs = plan.indices_of(trainable)?;
        let trainable = crate::shard::trainable_flags(plan.n_tensors(), &idxs);
        let segs = &plan.shard(shard).segments;
        if segments.len() != segs.len() {
            bail!(
                "worker: shard {} has {} segments in the plan but {} buffers were shipped",
                shard,
                segs.len(),
                segments.len()
            );
        }
        for (si, (seg, buf)) in segs.iter().zip(&segments).enumerate() {
            if buf.len() != seg.len() {
                bail!(
                    "worker: segment {} of shard {} spans {} coordinates but the buffer \
                     holds {}",
                    si,
                    shard,
                    seg.len(),
                    buf.len()
                );
            }
        }
        self.state = Some(Loaded { plan, shard, trainable, segments });
        Ok(())
    }

    /// The digest-guarded state access every mutating command goes
    /// through: no shard installed, or a command minted against a
    /// different plan, is refused before any coordinate is touched.
    fn loaded(&mut self, plan_digest: u64) -> Result<&mut Loaded> {
        let st = match self.state.as_mut() {
            Some(s) => s,
            None => bail!("worker: no shard loaded"),
        };
        if st.plan.digest() != plan_digest {
            bail!(
                "worker: stale plan digest {:#018x} (worker serves plan {:#018x}) — \
                 re-scatter before commanding this worker",
                plan_digest,
                st.plan.digest()
            );
        }
        Ok(st)
    }

    fn replay(&mut self, plan_digest: u64, log: &Trajectory, seeds_per_step: usize) -> Result<()> {
        let engine = self.engine;
        let st = self.loaded(plan_digest)?;
        if let Some(d) = log.mask_digest {
            bail!(
                "worker: log was recorded under a sparse mask (digest {:#x}); \
                 shard replay covers dense logs",
                d
            );
        }
        let idxs = st.plan.indices_of(&log.trainable)?;
        let trainable = crate::shard::trainable_flags(st.plan.n_tensors(), &idxs);
        let offsets: Vec<u64> = st.plan.offsets().to_vec();
        let segs = st.plan.shard(st.shard).segments.clone();
        let walk = |bufs: &mut [Vec<f32>], f: &mut dyn FnMut(u64, &mut [f32])| {
            for (seg, buf) in segs.iter().zip(bufs.iter_mut()) {
                if trainable[seg.tensor] {
                    f(offsets[seg.tensor] + seg.lo as u64, buf);
                }
            }
        };
        if seeds_per_step == 0 {
            // sequential replay: record order per coordinate, exactly
            // Trajectory::replay_shard_with
            for r in &log.records {
                let stream = GaussianStream::new(r.seed);
                walk(&mut st.segments, &mut |base, buf| {
                    engine.axpy_z(stream, base, buf, -(r.lr * r.pgrad));
                });
            }
        } else {
            if log.records.len() % seeds_per_step != 0 {
                bail!(
                    "worker: {} records do not divide into seed-batches of {}",
                    log.records.len(),
                    seeds_per_step
                );
            }
            for batch in log.records.chunks(seeds_per_step) {
                let zs: Vec<(GaussianStream, f32)> = batch
                    .iter()
                    .map(|r| (GaussianStream::new(r.seed), -(r.lr * r.pgrad)))
                    .collect();
                walk(&mut st.segments, &mut |base, buf| {
                    engine.multi_axpy_z(&zs, base, buf);
                });
            }
        }
        Ok(())
    }
}

impl Loaded {
    /// `(global counter base, buffer)` pairs of the trainable segments —
    /// the walk every mutating command does. The base is the segment
    /// tensor's global offset plus `lo`, so dense kernels over these
    /// detached buffers generate exactly the dense run's z values.
    fn trainable_segments(&mut self) -> impl Iterator<Item = (u64, &mut Vec<f32>)> {
        let Loaded { plan, shard, trainable, segments } = self;
        let offsets = plan.offsets();
        plan.shard(*shard)
            .segments
            .iter()
            .zip(segments.iter_mut())
            .filter(move |(seg, _)| trainable[seg.tensor])
            .map(move |(seg, buf)| (offsets[seg.tensor] + seg.lo as u64, buf))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;
    use crate::model::params::ParamStore;
    use crate::optim::mezo::StepRecord;
    use crate::shard::ShardedStore;

    fn store(lens: &[usize]) -> ParamStore {
        let specs = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| TensorDesc {
                name: format!("t{}", i),
                shape: vec![n],
                dtype: "f32".into(),
            })
            .collect();
        let mut p = ParamStore::from_specs(specs);
        p.init(11);
        p
    }

    fn load_msg(plan: &ShardPlan, p: &ParamStore, k: usize, trainable: Vec<String>) -> Msg {
        let segments = plan
            .shard(k)
            .segments
            .iter()
            .map(|seg| p.data[seg.tensor][seg.lo..seg.hi].to_vec())
            .collect();
        Msg::LoadShard { plan: Box::new(plan.clone()), shard: k as u32, trainable, segments }
    }

    #[test]
    fn worker_replay_matches_the_shard_replay_path() {
        let p = store(&[300, 7, 129]);
        let plan = ShardPlan::new(&p, 3).unwrap();
        let mut log = Trajectory::new(vec!["t0".into(), "t2".into()]);
        log.records = (0..6)
            .map(|i| StepRecord { seed: 100 + i, pgrad: 0.1 * i as f32 - 0.2, lr: 1e-3 })
            .collect();
        // reference: the in-process sharded replay
        let mut reference = ShardedStore::scatter(&plan, &p).unwrap();
        log.replay_sharded(&mut reference, &plan.manifest()).unwrap();
        for k in 0..plan.n_shards() {
            let mut w = ShardWorker::new();
            let tr = vec!["t0".to_string(), "t2".to_string()];
            assert_eq!(w.handle(load_msg(&plan, &p, k, tr)).unwrap(), Msg::Ack);
            let replay = Msg::Replay {
                plan_digest: plan.digest(),
                log: Box::new(log.clone()),
                seeds_per_step: 0,
            };
            assert_eq!(w.handle(replay).unwrap(), Msg::Ack);
            match w.handle(Msg::FetchShard { plan_digest: plan.digest() }).unwrap() {
                Msg::ShardSlice { shard, shard_digest, segments, .. } => {
                    assert_eq!(shard as usize, k);
                    assert_eq!(shard_digest, plan.shard_digest(k));
                    for (si, buf) in segments.iter().enumerate() {
                        let want = reference.segment(k, si);
                        assert_eq!(buf.len(), want.len());
                        for (a, b) in buf.iter().zip(want) {
                            assert_eq!(a.to_bits(), b.to_bits(), "shard {} seg {}", k, si);
                        }
                    }
                }
                other => panic!("expected a shard slice, got {}", other.kind_name()),
            }
        }
    }

    #[test]
    fn worker_refuses_stale_plans_sparse_logs_and_bad_geometry() {
        let p = store(&[100, 100]);
        let plan = ShardPlan::new(&p, 2).unwrap();
        let other = ShardPlan::new(&p, 4).unwrap();
        let mut w = ShardWorker::new();
        // nothing loaded yet
        let err = w
            .handle(Msg::Perturb { plan_digest: plan.digest(), seed: 1, scale: 0.1 })
            .unwrap_err();
        assert!(err.to_string().contains("no shard loaded"), "{}", err);
        assert_eq!(w.shard(), None);
        w.handle(load_msg(&plan, &p, 0, vec!["t0".into()])).unwrap();
        assert_eq!(w.shard(), Some(0));
        // stale digest: a command minted against a different plan
        let err = w
            .handle(Msg::Perturb { plan_digest: other.digest(), seed: 1, scale: 0.1 })
            .unwrap_err();
        assert!(err.to_string().contains("stale plan digest"), "{}", err);
        // sparse log refused
        let sparse = Trajectory::new(vec!["t0".into()]).with_mask_digest(0xBEEF);
        let err = w
            .handle(Msg::Replay {
                plan_digest: plan.digest(),
                log: Box::new(sparse),
                seeds_per_step: 0,
            })
            .unwrap_err();
        assert!(err.to_string().contains("sparse mask"), "{}", err);
        // unknown trainable name refused
        let err = w.handle(load_msg(&plan, &p, 0, vec!["nope".into()])).unwrap_err();
        assert!(err.to_string().contains("no tensor named"), "{}", err);
        // wrong buffer geometry refused
        let bad = Msg::LoadShard {
            plan: Box::new(plan.clone()),
            shard: 0,
            trainable: vec!["t0".into()],
            segments: vec![vec![0.0; 3]],
        };
        assert!(w.handle(bad).is_err());
        // shard index out of range refused
        let mut oob = load_msg(&plan, &p, 0, vec!["t0".into()]);
        if let Msg::LoadShard { shard, .. } = &mut oob {
            *shard = 9;
        }
        assert!(w.handle(oob).is_err());
        // an unexpected frame kind is refused, not crashed on
        assert!(w.handle(Msg::Ack).is_err());
    }
}
