//! The MZW1 frame codec: every message crossing a transport is one
//! length-prefixed, digest-authenticated binary frame.
//!
//! Layout, all integers little-endian:
//!
//! ```text
//! "MZW1" | version u8 | kind u8 | payload_len u32 | payload | digest u64
//! ```
//!
//! The trailing digest is a chained-splitmix64 walk (the same
//! construction as [`ShardPlan::digest`](crate::shard::ShardPlan)) over
//! `version`, `kind`, `payload_len` and the payload bytes, with the
//! length folded in first so zero-padding a short payload cannot
//! collide. It is an integrity check against truncation, bit rot and
//! protocol skew — not a cryptographic MAC.
//!
//! Decoding is total: [`Msg::decode`] and [`Msg::read_from`] return a
//! typed [`WireError`] for every malformed input — wrong magic, unknown
//! version or kind, truncated frame, oversized length, digest mismatch,
//! malformed payload — and never panic on arbitrary bytes. Allocation
//! is bounded by [`MAX_PAYLOAD`] and by cross-checking every embedded
//! count against the bytes actually present before reserving, so a
//! fuzzed length field fails loudly instead of attempting a huge
//! allocation (`tests/properties.rs` drives all of this).

use crate::rng::splitmix64;
use crate::shard::{ShardManifest, ShardPlan};
use crate::storage::Trajectory;
use std::fmt;
use std::io::{Read, Write};

/// Frame magic: the first four bytes of every MZW1 frame.
pub const MAGIC: [u8; 4] = *b"MZW1";

/// Protocol version this build speaks. A frame with any other version
/// byte is rejected with [`WireError::BadVersion`] — skewed peers must
/// fail loudly, not misparse.
pub const VERSION: u8 = 1;

/// Hard cap on a frame's payload length (256 MiB). A length field above
/// this is rejected before any allocation ([`WireError::Oversize`]).
pub const MAX_PAYLOAD: usize = 256 << 20;

/// Fixed bytes before the payload: magic, version, kind, payload_len.
pub const HEADER_LEN: usize = 4 + 1 + 1 + 4;

/// Fixed bytes after the payload: the u64 digest.
pub const TRAILER_LEN: usize = 8;

const N_KINDS: u8 = 13;

/// Every way a frame or transport operation can fail. Typed so tests
/// and the coordinator's churn logic can tell *protocol* failures
/// (corrupt frames, skewed peers — fatal) from *transport* failures
/// (timeout, disconnect — retriable via worker respawn).
#[derive(Debug)]
pub enum WireError {
    /// First four bytes were not `"MZW1"`.
    BadMagic([u8; 4]),
    /// Version byte differs from [`VERSION`].
    BadVersion(u8),
    /// Kind byte names no known frame kind.
    UnknownKind(u8),
    /// Fewer bytes than the frame's own header promises.
    Truncated {
        /// bytes the frame needs in total
        needed: usize,
        /// bytes actually available
        have: usize,
    },
    /// Payload length field exceeds [`MAX_PAYLOAD`].
    Oversize {
        /// the claimed payload length
        len: usize,
        /// the cap it exceeded
        max: usize,
    },
    /// Recomputed digest disagrees with the frame's trailer.
    BadDigest {
        /// digest recomputed from the received bytes
        want: u64,
        /// digest the frame carried
        got: u64,
    },
    /// Digest-valid frame whose payload bytes do not parse as the kind
    /// claims (includes an embedded-digest mismatch on a decoded plan).
    BadPayload(String),
    /// Transport read deadline expired with no frame.
    Timeout,
    /// Peer hung up (channel dropped / clean EOF).
    Disconnected,
    /// Underlying socket error other than timeout/EOF.
    Io(std::io::Error),
}

impl WireError {
    /// Stable short name of the variant — what the fuzz properties
    /// assert on without matching display strings.
    pub fn kind_name(&self) -> &'static str {
        match self {
            WireError::BadMagic(_) => "bad_magic",
            WireError::BadVersion(_) => "bad_version",
            WireError::UnknownKind(_) => "unknown_kind",
            WireError::Truncated { .. } => "truncated",
            WireError::Oversize { .. } => "oversize",
            WireError::BadDigest { .. } => "bad_digest",
            WireError::BadPayload(_) => "bad_payload",
            WireError::Timeout => "timeout",
            WireError::Disconnected => "disconnected",
            WireError::Io(_) => "io",
        }
    }

    /// Whether this failure is a transport fault a coordinator may heal
    /// by respawning the worker (vs. a protocol fault that must abort).
    pub fn is_transport(&self) -> bool {
        matches!(
            self,
            WireError::Timeout | WireError::Disconnected | WireError::Io(_)
        )
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::BadMagic(m) => {
                write!(f, "wire: bad frame magic {:02x?} (expected \"MZW1\")", m)
            }
            WireError::BadVersion(v) => {
                write!(f, "wire: protocol version {} (this build speaks {})", v, VERSION)
            }
            WireError::UnknownKind(k) => write!(f, "wire: unknown frame kind {}", k),
            WireError::Truncated { needed, have } => {
                write!(f, "wire: truncated frame ({} bytes present, {} needed)", have, needed)
            }
            WireError::Oversize { len, max } => {
                write!(f, "wire: payload length {} exceeds the {} byte cap", len, max)
            }
            WireError::BadDigest { want, got } => write!(
                f,
                "wire: frame digest mismatch (computed {:#018x}, frame carries {:#018x})",
                want, got
            ),
            WireError::BadPayload(m) => write!(f, "wire: bad payload: {}", m),
            WireError::Timeout => write!(f, "wire: read timed out"),
            WireError::Disconnected => write!(f, "wire: peer disconnected"),
            WireError::Io(e) => write!(f, "wire: io error: {}", e),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        match e.kind() {
            std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock => WireError::Timeout,
            std::io::ErrorKind::UnexpectedEof
            | std::io::ErrorKind::BrokenPipe
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::ConnectionAborted => WireError::Disconnected,
            _ => WireError::Io(e),
        }
    }
}

/// The chained-splitmix64 frame digest over `(version, kind,
/// payload_len, payload)`. The length is folded in before the bytes so
/// payloads that differ only by trailing zero bytes digest differently.
pub fn frame_digest(version: u8, kind: u8, payload: &[u8]) -> u64 {
    let mut h = splitmix64(0x0007_77AE ^ ((version as u64) << 8) ^ kind as u64);
    h = splitmix64(h ^ payload.len() as u64);
    let mut chunks = payload.chunks_exact(8);
    for c in &mut chunks {
        h = splitmix64(h ^ u64::from_le_bytes(c.try_into().expect("chunks_exact(8)")));
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut tail = [0u8; 8];
        tail[..rem.len()].copy_from_slice(rem);
        h = splitmix64(h ^ u64::from_le_bytes(tail));
    }
    h
}

/// Every message the shard protocol ships, one frame kind per variant.
/// Encode with [`Msg::encode`] / [`Msg::write_to`]; decode with
/// [`Msg::decode`] / [`Msg::read_from`]. The roundtrip is byte-exact:
/// re-encoding a decoded frame reproduces the original bytes
/// (`tests/properties.rs` pins this for every kind).
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Handshake / liveness probe; a worker answers [`Msg::Ack`].
    Hello {
        /// sender's node id (coordinator uses the shard index)
        node: u32,
    },
    /// Positive acknowledgement of the previous request.
    Ack,
    /// The peer refused the previous request (stale digest, unknown
    /// tensor, malformed command...). A protocol-level refusal, distinct
    /// from a transport failure: the connection stays usable.
    Nack {
        /// human-readable reason, for the coordinator's error
        message: String,
    },
    /// A full [`ShardPlan`], structurally encoded (the receiver rebuilds
    /// segments and digests and cross-checks the sender's digest).
    Plan(Box<ShardPlan>),
    /// An MZT3 [`ShardManifest`].
    Manifest(ShardManifest),
    /// A full `(seed, pgrad, lr)` [`Trajectory`] log.
    Log(Box<Trajectory>),
    /// Install shard `shard` of `plan` on the receiving worker, with the
    /// trainable tensor names and one detached buffer per plan segment.
    LoadShard {
        /// the partition the worker will serve under
        plan: Box<ShardPlan>,
        /// which shard of the plan this worker owns
        shard: u32,
        /// trainable tensor names (resolved against the plan's ABI)
        trainable: Vec<String>,
        /// `segments[si]` = the values of `plan.shard(shard).segments[si]`
        segments: Vec<Vec<f32>>,
    },
    /// In-place `θ += scale · z(seed)` over the worker's trainable
    /// segments, z indexed at the segments' *global* counters.
    Perturb {
        /// [`ShardPlan::digest`] the command was issued under — a worker
        /// holding a different plan refuses with [`Msg::Nack`]
        plan_digest: u64,
        /// Gaussian stream seed
        seed: u64,
        /// perturbation scale (±ε, −2ε...)
        scale: f32,
    },
    /// Fused multi-seed SGD update over the worker's trainable segments:
    /// one [`ZEngine::multi_sgd_update`](crate::zkernel::ZEngine) pass
    /// with `(seed, coeff)` pairs (coeff = pgrad/n on the MeZO path).
    Update {
        /// plan digest guard, as in [`Msg::Perturb`]
        plan_digest: u64,
        /// per-seed `(stream seed, update coefficient)` pairs
        zs: Vec<(u64, f32)>,
        /// learning rate
        lr: f32,
        /// weight decay
        wd: f32,
    },
    /// Replay a whole trajectory over the worker's shard (sequential
    /// when `seeds_per_step == 0`, fused seed batches otherwise).
    Replay {
        /// plan digest guard, as in [`Msg::Perturb`]
        plan_digest: u64,
        /// the `(seed, pgrad, lr)` log to re-apply
        log: Box<Trajectory>,
        /// fused batch size; 0 = sequential record-by-record replay
        seeds_per_step: u32,
    },
    /// Ask the worker for its current shard values.
    FetchShard {
        /// plan digest guard, as in [`Msg::Perturb`]
        plan_digest: u64,
    },
    /// A worker's shard values, digest-stamped so the coordinator can
    /// verify provenance before gathering.
    ShardSlice {
        /// digest of the plan the worker serves under
        plan_digest: u64,
        /// which shard the values belong to
        shard: u32,
        /// [`ShardPlan::shard_digest`] of that shard
        shard_digest: u64,
        /// one buffer per plan segment, in segment order
        segments: Vec<Vec<f32>>,
    },
    /// Orderly worker shutdown (worker acks, then exits its serve loop).
    Shutdown,
}

impl Msg {
    /// The frame kind byte this message encodes as.
    pub fn kind(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 0,
            Msg::Ack => 1,
            Msg::Nack { .. } => 2,
            Msg::Plan(_) => 3,
            Msg::Manifest(_) => 4,
            Msg::Log(_) => 5,
            Msg::LoadShard { .. } => 6,
            Msg::Perturb { .. } => 7,
            Msg::Update { .. } => 8,
            Msg::Replay { .. } => 9,
            Msg::FetchShard { .. } => 10,
            Msg::ShardSlice { .. } => 11,
            Msg::Shutdown => 12,
        }
    }

    /// Stable human-readable name of the frame kind, for error messages.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::Ack => "ack",
            Msg::Nack { .. } => "nack",
            Msg::Plan(_) => "plan",
            Msg::Manifest(_) => "manifest",
            Msg::Log(_) => "log",
            Msg::LoadShard { .. } => "load_shard",
            Msg::Perturb { .. } => "perturb",
            Msg::Update { .. } => "update",
            Msg::Replay { .. } => "replay",
            Msg::FetchShard { .. } => "fetch_shard",
            Msg::ShardSlice { .. } => "shard_slice",
            Msg::Shutdown => "shutdown",
        }
    }

    /// Encode the message as one complete MZW1 frame.
    pub fn encode(&self) -> Vec<u8> {
        let payload = self.encode_payload();
        debug_assert!(payload.len() <= MAX_PAYLOAD, "payload exceeds MAX_PAYLOAD");
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.push(self.kind());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out.extend_from_slice(&frame_digest(VERSION, self.kind(), &payload).to_le_bytes());
        out
    }

    /// Decode one frame from the front of `bytes`; on success returns
    /// the message and the number of bytes consumed (trailing bytes are
    /// left for the caller — streams carry back-to-back frames). Total:
    /// every malformed input yields a typed [`WireError`], never a
    /// panic, and allocation is bounded by the bytes actually present.
    pub fn decode(bytes: &[u8]) -> Result<(Msg, usize), WireError> {
        if bytes.len() < HEADER_LEN {
            return Err(WireError::Truncated { needed: HEADER_LEN, have: bytes.len() });
        }
        let mut magic = [0u8; 4];
        magic.copy_from_slice(&bytes[..4]);
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        let version = bytes[4];
        if version != VERSION {
            return Err(WireError::BadVersion(version));
        }
        let kind = bytes[5];
        if kind >= N_KINDS {
            return Err(WireError::UnknownKind(kind));
        }
        let len =
            u32::from_le_bytes(bytes[6..10].try_into().expect("4 header bytes")) as usize;
        if len > MAX_PAYLOAD {
            return Err(WireError::Oversize { len, max: MAX_PAYLOAD });
        }
        let total = HEADER_LEN + len + TRAILER_LEN;
        if bytes.len() < total {
            return Err(WireError::Truncated { needed: total, have: bytes.len() });
        }
        let payload = &bytes[HEADER_LEN..HEADER_LEN + len];
        let got = u64::from_le_bytes(
            bytes[HEADER_LEN + len..total].try_into().expect("8 trailer bytes"),
        );
        let want = frame_digest(version, kind, payload);
        if want != got {
            return Err(WireError::BadDigest { want, got });
        }
        let msg = Msg::decode_payload(kind, payload)?;
        Ok((msg, total))
    }

    /// Write the message as one frame to a stream.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<(), WireError> {
        w.write_all(&self.encode())?;
        w.flush()?;
        Ok(())
    }

    /// Read exactly one frame from a stream. EOF at a frame boundary is
    /// [`WireError::Disconnected`]; a read deadline on the underlying
    /// stream surfaces as [`WireError::Timeout`]. Header fields are
    /// validated before the payload is allocated or read.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Msg, WireError> {
        let mut head = [0u8; HEADER_LEN];
        r.read_exact(&mut head)?;
        let mut magic = [0u8; 4];
        magic.copy_from_slice(&head[..4]);
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        if head[4] != VERSION {
            return Err(WireError::BadVersion(head[4]));
        }
        let kind = head[5];
        if kind >= N_KINDS {
            return Err(WireError::UnknownKind(kind));
        }
        let len = u32::from_le_bytes(head[6..10].try_into().expect("4 header bytes")) as usize;
        if len > MAX_PAYLOAD {
            return Err(WireError::Oversize { len, max: MAX_PAYLOAD });
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload)?;
        let mut trailer = [0u8; TRAILER_LEN];
        r.read_exact(&mut trailer)?;
        let got = u64::from_le_bytes(trailer);
        let want = frame_digest(VERSION, kind, &payload);
        if want != got {
            return Err(WireError::BadDigest { want, got });
        }
        Msg::decode_payload(kind, &payload)
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut e = Enc::default();
        match self {
            Msg::Hello { node } => e.u32(*node),
            Msg::Ack | Msg::Shutdown => {}
            Msg::Nack { message } => e.str(message),
            Msg::Plan(plan) => e.plan(plan),
            Msg::Manifest(m) => {
                e.u64(m.plan_digest);
                e.u32(m.shard_digests.len() as u32);
                for &d in &m.shard_digests {
                    e.u64(d);
                }
            }
            Msg::Log(log) => e.trajectory(log),
            Msg::LoadShard { plan, shard, trainable, segments } => {
                e.plan(plan);
                e.u32(*shard);
                e.strs(trainable);
                e.seg_bufs(segments);
            }
            Msg::Perturb { plan_digest, seed, scale } => {
                e.u64(*plan_digest);
                e.u64(*seed);
                e.f32(*scale);
            }
            Msg::Update { plan_digest, zs, lr, wd } => {
                e.u64(*plan_digest);
                e.u32(zs.len() as u32);
                for &(seed, coeff) in zs {
                    e.u64(seed);
                    e.f32(coeff);
                }
                e.f32(*lr);
                e.f32(*wd);
            }
            Msg::Replay { plan_digest, log, seeds_per_step } => {
                e.u64(*plan_digest);
                e.trajectory(log);
                e.u32(*seeds_per_step);
            }
            Msg::FetchShard { plan_digest } => e.u64(*plan_digest),
            Msg::ShardSlice { plan_digest, shard, shard_digest, segments } => {
                e.u64(*plan_digest);
                e.u32(*shard);
                e.u64(*shard_digest);
                e.seg_bufs(segments);
            }
        }
        e.buf
    }

    fn decode_payload(kind: u8, payload: &[u8]) -> Result<Msg, WireError> {
        let mut d = Dec::new(payload);
        let msg = match kind {
            0 => Msg::Hello { node: d.u32()? },
            1 => Msg::Ack,
            2 => Msg::Nack { message: d.str()? },
            3 => Msg::Plan(Box::new(d.plan()?)),
            4 => {
                let plan_digest = d.u64()?;
                let n = d.u32()? as usize;
                d.fits(n.checked_mul(8))?;
                let mut shard_digests = Vec::with_capacity(n);
                for _ in 0..n {
                    shard_digests.push(d.u64()?);
                }
                Msg::Manifest(ShardManifest { plan_digest, shard_digests })
            }
            5 => Msg::Log(Box::new(d.trajectory()?)),
            6 => {
                let plan = Box::new(d.plan()?);
                let shard = d.u32()?;
                let trainable = d.strs()?;
                let segments = d.seg_bufs()?;
                Msg::LoadShard { plan, shard, trainable, segments }
            }
            7 => Msg::Perturb { plan_digest: d.u64()?, seed: d.u64()?, scale: d.f32()? },
            8 => {
                let plan_digest = d.u64()?;
                let n = d.u32()? as usize;
                d.fits(n.checked_mul(12))?;
                let mut zs = Vec::with_capacity(n);
                for _ in 0..n {
                    let seed = d.u64()?;
                    let coeff = d.f32()?;
                    zs.push((seed, coeff));
                }
                Msg::Update { plan_digest, zs, lr: d.f32()?, wd: d.f32()? }
            }
            9 => {
                let plan_digest = d.u64()?;
                let log = Box::new(d.trajectory()?);
                let seeds_per_step = d.u32()?;
                Msg::Replay { plan_digest, log, seeds_per_step }
            }
            10 => Msg::FetchShard { plan_digest: d.u64()? },
            11 => {
                let plan_digest = d.u64()?;
                let shard = d.u32()?;
                let shard_digest = d.u64()?;
                let segments = d.seg_bufs()?;
                Msg::ShardSlice { plan_digest, shard, shard_digest, segments }
            }
            12 => Msg::Shutdown,
            _ => return Err(WireError::UnknownKind(kind)),
        };
        d.finish()?;
        Ok(msg)
    }
}

/// Payload writer: primitive little-endian emitters plus the composite
/// layouts shared by several frame kinds.
#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// `len u32 | utf8 bytes`
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    /// `count u32 | str*`
    fn strs(&mut self, ss: &[String]) {
        self.u32(ss.len() as u32);
        for s in ss {
            self.str(s);
        }
    }
    /// `count u64 | f32 LE*`
    fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.f32(x);
        }
    }
    /// `count u32 | f32s*` — the segment-buffer list of a shard.
    fn seg_bufs(&mut self, bufs: &[Vec<f32>]) {
        self.u32(bufs.len() as u32);
        for b in bufs {
            self.f32s(b);
        }
    }
    /// Structural plan layout:
    /// `n_tensors u32 | (name str, len u64)* | n_shards u32 |
    ///  (start u64, end u64)* | digest u64`.
    /// The receiver rebuilds segments/offsets/digests from the structure
    /// and cross-checks the trailing digest — a plan whose derivation
    /// rules disagree between peers fails loudly instead of silently
    /// mis-addressing z counters.
    fn plan(&mut self, p: &ShardPlan) {
        self.u32(p.n_tensors() as u32);
        for (name, &len) in p.names().iter().zip(p.lens()) {
            self.str(name);
            self.u64(len as u64);
        }
        self.u32(p.n_shards() as u32);
        for s in p.shards() {
            self.u64(s.start);
            self.u64(s.end);
        }
        self.u64(p.digest());
    }
    /// Trajectory layout:
    /// `mask_flag u8 | [mask_digest u64] | trainable strs |
    ///  n_records u64 | (seed u64, pgrad f32, lr f32)*`.
    fn trajectory(&mut self, t: &Trajectory) {
        match t.mask_digest {
            Some(d) => {
                self.u8(1);
                self.u64(d);
            }
            None => self.u8(0),
        }
        self.strs(&t.trainable);
        self.u64(t.records.len() as u64);
        for r in &t.records {
            self.u64(r.seed);
            self.f32(r.pgrad);
            self.f32(r.lr);
        }
    }
}

/// Payload reader over a digest-verified byte slice. Every read is
/// bounds-checked (a forged frame with a colliding digest still cannot
/// panic or over-allocate) and [`Dec::finish`] rejects trailing bytes,
/// so a payload parses for exactly one message.
struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    /// Check an up-front size claim (typically `count * elem_size`,
    /// passed as a `checked_mul` result) against the bytes left, BEFORE
    /// any `Vec::with_capacity` — corrupt counts fail loudly, they do
    /// not allocate.
    fn fits(&self, need: Option<usize>) -> Result<(), WireError> {
        match need {
            Some(n) if n <= self.remaining() => Ok(()),
            Some(n) => Err(WireError::BadPayload(format!(
                "embedded count needs {} bytes, {} remain",
                n,
                self.remaining()
            ))),
            None => Err(WireError::BadPayload("embedded count overflows usize".into())),
        }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::BadPayload(format!(
                "payload needs {} more bytes, {} remain",
                n,
                self.remaining()
            )));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::BadPayload("string is not valid utf-8".into()))
    }

    fn strs(&mut self) -> Result<Vec<String>, WireError> {
        let n = self.u32()? as usize;
        // each string costs at least its 4-byte length prefix
        self.fits(n.checked_mul(4))?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.str()?);
        }
        Ok(out)
    }

    fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n64 = self.u64()?;
        let n = usize::try_from(n64)
            .map_err(|_| WireError::BadPayload("f32 count overflows usize".into()))?;
        self.fits(n.checked_mul(4))?;
        let bytes = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().expect("4 bytes")));
        }
        Ok(out)
    }

    fn seg_bufs(&mut self) -> Result<Vec<Vec<f32>>, WireError> {
        let n = self.u32()? as usize;
        // each buffer costs at least its 8-byte count prefix
        self.fits(n.checked_mul(8))?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32s()?);
        }
        Ok(out)
    }

    fn plan(&mut self) -> Result<ShardPlan, WireError> {
        let nt = self.u32()? as usize;
        self.fits(nt.checked_mul(12))?;
        let mut names = Vec::with_capacity(nt);
        let mut lens = Vec::with_capacity(nt);
        for _ in 0..nt {
            names.push(self.str()?);
            let len64 = self.u64()?;
            lens.push(usize::try_from(len64).map_err(|_| {
                WireError::BadPayload("tensor length overflows usize".into())
            })?);
        }
        let ns = self.u32()? as usize;
        self.fits(ns.checked_mul(16))?;
        let mut ranges = Vec::with_capacity(ns);
        for _ in 0..ns {
            let start = self.u64()?;
            let end = self.u64()?;
            ranges.push((start, end));
        }
        let claimed = self.u64()?;
        let plan = ShardPlan::from_parts(names, lens, &ranges)
            .map_err(|e| WireError::BadPayload(format!("plan structure invalid: {}", e)))?;
        if plan.digest() != claimed {
            return Err(WireError::BadPayload(format!(
                "plan digest mismatch: rebuilt {:#018x}, frame claims {:#018x} — \
                 peers disagree on the plan derivation",
                plan.digest(),
                claimed
            )));
        }
        Ok(plan)
    }

    fn trajectory(&mut self) -> Result<Trajectory, WireError> {
        let mask_digest = match self.u8()? {
            0 => None,
            1 => Some(self.u64()?),
            f => {
                return Err(WireError::BadPayload(format!(
                    "trajectory mask flag must be 0 or 1, got {}",
                    f
                )))
            }
        };
        let trainable = self.strs()?;
        let n64 = self.u64()?;
        let n = usize::try_from(n64)
            .map_err(|_| WireError::BadPayload("record count overflows usize".into()))?;
        self.fits(n.checked_mul(16))?;
        let mut records = Vec::with_capacity(n);
        for _ in 0..n {
            let seed = self.u64()?;
            let pgrad = self.f32()?;
            let lr = self.f32()?;
            records.push(crate::optim::mezo::StepRecord { seed, pgrad, lr });
        }
        let mut t = Trajectory::new(trainable);
        t.records = records;
        if let Some(d) = mask_digest {
            t = t.with_mask_digest(d);
        }
        Ok(t)
    }

    /// Reject unconsumed trailing bytes — one payload, one message.
    fn finish(&self) -> Result<(), WireError> {
        if self.pos != self.b.len() {
            return Err(WireError::BadPayload(format!(
                "{} trailing bytes after a complete message",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;
    use crate::model::params::ParamStore;
    use crate::optim::mezo::StepRecord;

    fn plan(lens: &[usize], k: usize) -> ShardPlan {
        let specs = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| TensorDesc {
                name: format!("t{}", i),
                shape: vec![n],
                dtype: "f32".into(),
            })
            .collect();
        ShardPlan::new(&ParamStore::from_specs(specs), k).unwrap()
    }

    #[test]
    fn frame_digest_is_length_and_content_sensitive() {
        let a = frame_digest(VERSION, 1, b"abcdefgh");
        assert_ne!(a, frame_digest(VERSION, 1, b"abcdefgi"), "content");
        assert_ne!(a, frame_digest(VERSION, 2, b"abcdefgh"), "kind");
        assert_ne!(a, frame_digest(VERSION + 1, 1, b"abcdefgh"), "version");
        // zero-padding must not collide with the shorter payload
        assert_ne!(frame_digest(VERSION, 1, b"ab"), frame_digest(VERSION, 1, b"ab\0\0"));
        // deterministic across calls (the wire contract)
        assert_eq!(a, frame_digest(VERSION, 1, b"abcdefgh"));
    }

    #[test]
    fn layout_matches_the_spec_constants() {
        let bytes = Msg::Ack.encode();
        assert_eq!(bytes.len(), HEADER_LEN + TRAILER_LEN);
        assert_eq!(&bytes[..4], b"MZW1");
        assert_eq!(bytes[4], VERSION);
        assert_eq!(bytes[5], Msg::Ack.kind());
        assert_eq!(u32::from_le_bytes(bytes[6..10].try_into().unwrap()), 0);
    }

    #[test]
    fn every_kind_roundtrips_through_decode() {
        let p = plan(&[300, 7, 129], 3);
        let mut log = Trajectory::new(vec!["t0".into(), "t2".into()]);
        log.records = vec![
            StepRecord { seed: 7, pgrad: 0.25, lr: 1e-3 },
            StepRecord { seed: 9, pgrad: -1.5, lr: 2e-3 },
        ];
        let msgs = vec![
            Msg::Hello { node: 3 },
            Msg::Ack,
            Msg::Nack { message: "stale plan".into() },
            Msg::Plan(Box::new(p.clone())),
            Msg::Manifest(p.manifest()),
            Msg::Log(Box::new(log.clone())),
            Msg::LoadShard {
                plan: Box::new(p.clone()),
                shard: 1,
                trainable: vec!["t0".into()],
                segments: vec![vec![1.0, -2.5], vec![]],
            },
            Msg::Perturb { plan_digest: p.digest(), seed: 42, scale: 1e-3 },
            Msg::Update {
                plan_digest: p.digest(),
                zs: vec![(1, 0.5), (2, -0.25)],
                lr: 1e-3,
                wd: 0.1,
            },
            Msg::Replay {
                plan_digest: p.digest(),
                log: Box::new(log.with_mask_digest(0xDEAD)),
                seeds_per_step: 2,
            },
            Msg::FetchShard { plan_digest: p.digest() },
            Msg::ShardSlice {
                plan_digest: p.digest(),
                shard: 2,
                shard_digest: p.shard_digest(2),
                segments: vec![vec![0.0, f32::MIN, f32::MAX]],
            },
            Msg::Shutdown,
        ];
        for m in msgs {
            let bytes = m.encode();
            let (back, used) = Msg::decode(&bytes).unwrap();
            assert_eq!(used, bytes.len(), "{}: whole frame consumed", m.kind_name());
            assert_eq!(back, m, "{}: value roundtrip", m.kind_name());
            assert_eq!(back.encode(), bytes, "{}: byte roundtrip", m.kind_name());
            // a stream suffix is left untouched
            let mut two = bytes.clone();
            two.extend_from_slice(&Msg::Ack.encode());
            let (first, used2) = Msg::decode(&two).unwrap();
            assert_eq!((first, used2), (m, bytes.len()));
        }
    }

    #[test]
    fn header_corruptions_hit_their_typed_arms() {
        let good = Msg::Hello { node: 1 }.encode();
        let mut b = good.clone();
        b[0] = b'X';
        assert_eq!(Msg::decode(&b).unwrap_err().kind_name(), "bad_magic");
        let mut b = good.clone();
        b[4] = 9;
        assert_eq!(Msg::decode(&b).unwrap_err().kind_name(), "bad_version");
        let mut b = good.clone();
        b[5] = 200;
        assert_eq!(Msg::decode(&b).unwrap_err().kind_name(), "unknown_kind");
        let mut b = good.clone();
        b[6..10].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        assert_eq!(Msg::decode(&b).unwrap_err().kind_name(), "oversize");
        let mut b = good.clone();
        *b.last_mut().unwrap() ^= 1;
        assert_eq!(Msg::decode(&b).unwrap_err().kind_name(), "bad_digest");
        for cut in 0..good.len() {
            assert!(Msg::decode(&good[..cut]).is_err(), "prefix of {} bytes", cut);
        }
    }

    #[test]
    fn io_errors_map_to_timeout_and_disconnect() {
        let timed: WireError =
            std::io::Error::new(std::io::ErrorKind::WouldBlock, "deadline").into();
        assert_eq!(timed.kind_name(), "timeout");
        let eof: WireError =
            std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof").into();
        assert_eq!(eof.kind_name(), "disconnected");
        assert!(timed.is_transport() && eof.is_transport());
        assert!(!WireError::BadVersion(3).is_transport());
    }
}
