//! The coordinator half of the shard protocol: scatters a dense store
//! across K workers, drives MeZO stepping and trajectory replay over
//! MZW1 frames, and gathers a result pinned bitwise-identical to the
//! dense path.
//!
//! ## Bit-exactness
//!
//! [`Fleet::step`] issues exactly the dense [`MezoSgd`] (Sgd flavor)
//! kernel sequence — per seed `+ε`, `−2ε`, `+ε` perturbs, then ONE
//! fused multi-seed update with coefficients `pgrad/n` — and each
//! worker executes its segment slices at the segments' global z
//! counters, so the gathered store is `to_bits()`-identical to
//! `MezoSgd::step` on a dense store with the same master seed
//! (`tests/churn.rs` pins this for shards 1/2/4, with and without
//! churn). Losses are evaluated on a dense *mirror* refreshed from the
//! workers before each forward, so the loss closure sees exactly the
//! perturbed parameters a dense run would.
//!
//! ## Churn
//!
//! Worker failure is expected, not exceptional. The fleet keeps, per
//! shard: the slice values at the last checkpoint, plus the log of
//! every mutating command issued since. When a worker times out or
//! disconnects, the fleet spawns a replacement (the [`SpawnFn`]),
//! re-installs the checkpoint slice, re-drives the command log in
//! order, and retries the in-flight command. Every kernel is
//! deterministic, so the rebuilt worker's buffers are bit-identical to
//! the lost worker's — recovery is invisible in the gathered result.
//! A command is appended to the log only *after* every worker has
//! acked it, so a mid-broadcast respawn applies it exactly once.
//! Protocol refusals ([`Msg::Nack`] — stale digests, sparse logs) are
//! NOT churn: they mean the fleet itself is wrong, and abort loudly.

use super::frame::{Msg, WireError};
use super::transport::Transport;
use crate::model::params::ParamStore;
use crate::obs::{self, metrics};
use crate::optim::mezo::{StepInfo, StepRecord};
use crate::rng::Pcg;
use crate::shard::ShardPlan;
use crate::storage::Trajectory;
use anyhow::{bail, Result};

/// Spawns (or re-spawns) the transport to worker `k`. Called once per
/// shard at fleet construction and again on every churn recovery; the
/// factory owns whatever lives behind the transport (a thread, a child
/// process, a socket).
pub type SpawnFn = Box<dyn FnMut(usize) -> Result<Box<dyn Transport>> + Send>;

/// Fleet stepping hyperparameters — the subset of
/// [`MezoConfig`](crate::optim::mezo::MezoConfig) the wire protocol
/// carries (Sgd flavor; moments are dense-only, see ROADMAP).
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// learning rate
    pub lr: f32,
    /// perturbation scale ε
    pub eps: f32,
    /// weight decay
    pub weight_decay: f32,
    /// SPSA samples per step (n-SPSA averaging)
    pub n: usize,
    /// transport failures tolerated per command before giving up
    pub max_retries: usize,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig { lr: 1e-3, eps: 1e-3, weight_decay: 0.0, n: 1, max_retries: 3 }
    }
}

/// How one worker call failed — the split the churn logic turns on.
enum CallErr {
    /// Transport fault (timeout / disconnect / io): respawn and retry.
    Churn(WireError),
    /// Protocol fault (refusal, wrong reply kind): abort the fleet op.
    Fatal(anyhow::Error),
}

/// A coordinator plus K shard workers. Build with [`Fleet::new`], drive
/// with [`Fleet::step`] / [`Fleet::replay`], read back with
/// [`Fleet::gather_into`].
pub struct Fleet {
    plan: ShardPlan,
    trainable: Vec<String>,
    cfg: FleetConfig,
    workers: Vec<Box<dyn Transport>>,
    /// workers that died and were respawned but not yet re-driven
    needs_reload: Vec<bool>,
    spawn: SpawnFn,
    /// per-shard segment values at the last checkpoint
    checkpoint: Vec<Vec<Vec<f32>>>,
    /// mutating commands issued since the checkpoint, in order
    cmd_log: Vec<Msg>,
    /// dense mirror the loss closure evaluates against
    mirror: ParamStore,
    seed_rng: Pcg,
    /// the full `(seed, pgrad, lr)` log, exactly as a dense `MezoSgd`
    /// would have recorded it — replayable anywhere
    pub history: Vec<StepRecord>,
    /// steps taken
    pub step: u64,
    /// workers respawned over the fleet's lifetime (observability; the
    /// churn tests assert recovery actually happened)
    pub respawns: usize,
}

impl Fleet {
    /// Scatter `params` into `n_shards` shards and install one on each
    /// freshly spawned worker. `trainable` names the tensors stepping
    /// and replay may touch; `master_seed` drives the per-step seed
    /// stream exactly like [`MezoSgd::new`], so a fleet and a dense
    /// optimizer given the same seed walk the same seeds.
    ///
    /// [`MezoSgd`]: crate::optim::mezo::MezoSgd
    /// [`MezoSgd::new`]: crate::optim::mezo::MezoSgd::new
    pub fn new(
        params: &ParamStore,
        n_shards: usize,
        trainable: Vec<String>,
        master_seed: u64,
        cfg: FleetConfig,
        spawn: SpawnFn,
    ) -> Result<Fleet> {
        let plan = ShardPlan::new(params, n_shards)?;
        plan.indices_of(&trainable)
            .map_err(|e| e.context("Fleet: trainable names must resolve in the plan"))?;
        let checkpoint: Vec<Vec<Vec<f32>>> = plan
            .shards()
            .iter()
            .map(|s| {
                s.segments
                    .iter()
                    .map(|seg| params.data[seg.tensor][seg.lo..seg.hi].to_vec())
                    .collect()
            })
            .collect();
        let mut mirror = ParamStore::from_specs(params.specs.clone());
        mirror.copy_from(params);
        let mut fleet = Fleet {
            plan,
            trainable,
            cfg,
            workers: Vec::new(),
            needs_reload: vec![false; n_shards],
            spawn,
            checkpoint,
            cmd_log: Vec::new(),
            mirror,
            seed_rng: Pcg::new(master_seed),
            history: Vec::new(),
            step: 0,
            respawns: 0,
        };
        for k in 0..n_shards {
            let t = (fleet.spawn)(k)
                .map_err(|e| e.context(format!("Fleet: spawning worker {}", k)))?;
            fleet.workers.push(t);
            fleet.reload(k).map_err(|e| match e {
                CallErr::Churn(w) => anyhow::Error::new(w)
                    .context(format!("Fleet: initial scatter to worker {}", k)),
                CallErr::Fatal(e) => e,
            })?;
        }
        Ok(fleet)
    }

    /// The partition the fleet serves under.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// One MeZO step, distributed: the dense Algorithm-1 sequence with
    /// every parameter write broadcast to the shard workers and every
    /// forward evaluated on the refreshed dense mirror. Returns the same
    /// [`StepInfo`] a dense step would.
    pub fn step<F>(&mut self, mut loss: F) -> Result<StepInfo>
    where
        F: FnMut(&ParamStore) -> Result<f32>,
    {
        let n = self.cfg.n.max(1);
        let (eps, lr) = (self.cfg.eps, self.cfg.lr);
        let pd = self.plan.digest();
        let mut records: Vec<StepRecord> = Vec::with_capacity(n);
        let mut mean_loss = 0.0f32;
        let mut fwd = 0usize;
        for _ in 0..n {
            let seed = self.seed_rng.next_u64();
            self.broadcast(Msg::Perturb { plan_digest: pd, seed, scale: eps })?;
            self.refresh_mirror()?;
            let lp = loss(&self.mirror)?;
            self.broadcast(Msg::Perturb { plan_digest: pd, seed, scale: -2.0 * eps })?;
            self.refresh_mirror()?;
            let lm = loss(&self.mirror)?;
            self.broadcast(Msg::Perturb { plan_digest: pd, seed, scale: eps })?;
            fwd += 2;
            mean_loss += 0.5 * (lp + lm);
            records.push(StepRecord { seed, pgrad: (lp - lm) / (2.0 * eps), lr });
        }
        mean_loss /= n as f32;
        let zs: Vec<(u64, f32)> =
            records.iter().map(|r| (r.seed, r.pgrad / n as f32)).collect();
        self.broadcast(Msg::Update {
            plan_digest: pd,
            zs,
            lr,
            wd: self.cfg.weight_decay,
        })?;
        self.checkpoint_now()?;
        self.history.extend(records.iter().copied());
        self.step += 1;
        let last = records.last().expect("n >= 1");
        metrics::OPT_STEPS.inc();
        metrics::OPT_FORWARD_PASSES.add(fwd as u64);
        metrics::OPT_LOSS.set(mean_loss as f64);
        Ok(StepInfo { loss: mean_loss, pgrad: last.pgrad, seed: last.seed, forward_passes: fwd })
    }

    /// Replay a `(seed, pgrad, lr)` log across the fleet — every worker
    /// re-applies the whole log over its own shard (`seeds_per_step = 0`
    /// replays record-by-record; otherwise records apply as fused seed
    /// batches, bitwise equal for any batch size). The coordinator-side
    /// guards mirror [`Trajectory::replay_sharded`]'s: sparse logs and
    /// unresolvable trainable names are refused before any frame ships.
    pub fn replay(&mut self, log: &Trajectory, seeds_per_step: usize) -> Result<()> {
        if log.mask_digest.is_some() {
            bail!("Fleet: sparse (masked) logs cannot replay over a shard fleet");
        }
        self.plan.indices_of(&log.trainable)?;
        if seeds_per_step > 0 && log.records.len() % seeds_per_step != 0 {
            bail!(
                "Fleet: {} records do not divide into seed-batches of {}",
                log.records.len(),
                seeds_per_step
            );
        }
        self.broadcast(Msg::Replay {
            plan_digest: self.plan.digest(),
            log: Box::new(log.clone()),
            seeds_per_step: seeds_per_step as u32,
        })?;
        self.checkpoint_now()
    }

    /// Fetch every shard, verify digest provenance, and write the
    /// values into `out` (validated against the plan first). Bitwise:
    /// the gathered store equals the dense run's.
    pub fn gather_into(&mut self, out: &mut ParamStore) -> Result<()> {
        self.plan.validate(out)?;
        self.refresh_mirror()?;
        out.copy_from(&self.mirror);
        Ok(())
    }

    /// Orderly shutdown: best-effort [`Msg::Shutdown`] to every worker
    /// (a dead worker is already shut down — errors are ignored).
    pub fn shutdown(mut self) {
        for t in self.workers.iter_mut() {
            let _ = t.send(&Msg::Shutdown);
            let _ = t.recv();
        }
    }

    /// Broadcast one mutating command to every worker, then append it
    /// to the since-checkpoint log. Appending AFTER the acks is what
    /// makes churn recovery exactly-once: a worker respawned mid-
    /// broadcast reloads the log *without* this command, then the retry
    /// delivers it.
    fn broadcast(&mut self, cmd: Msg) -> Result<()> {
        for k in 0..self.workers.len() {
            match self.rpc(k, &cmd)? {
                Msg::Ack => {}
                other => bail!(
                    "Fleet: worker {} answered {} to a {} broadcast",
                    k,
                    other.kind_name(),
                    cmd.kind_name()
                ),
            }
        }
        self.cmd_log.push(cmd);
        Ok(())
    }

    /// One request/response against worker `k`, with churn recovery:
    /// transport failures respawn the worker (checkpoint + command-log
    /// re-drive) and retry, up to `cfg.max_retries` times; protocol
    /// refusals abort immediately.
    fn rpc(&mut self, k: usize, msg: &Msg) -> Result<Msg> {
        let _rtt =
            obs::Span::start(&metrics::FLEET_RPC_NS[metrics::msg_kind_index(msg.kind_name())]);
        let mut attempts = 0usize;
        loop {
            let err = match self.attempt(k, msg) {
                Ok(reply) => return Ok(reply),
                Err(CallErr::Fatal(e)) => return Err(e),
                Err(CallErr::Churn(e)) => e,
            };
            attempts += 1;
            metrics::FLEET_RETRIES.inc();
            if attempts > self.cfg.max_retries {
                return Err(anyhow::Error::new(err).context(format!(
                    "Fleet: worker {} still failing after {} respawn attempts",
                    k, attempts
                )));
            }
            self.respawn(k, &err)?;
        }
    }

    /// One send/recv against worker `k`, re-driving its state first if
    /// it was respawned since the last successful call.
    fn attempt(&mut self, k: usize, msg: &Msg) -> Result<Msg, CallErr> {
        if self.needs_reload[k] {
            self.reload(k)?;
        }
        let t = &mut self.workers[k];
        t.send(msg).map_err(CallErr::Churn)?;
        match t.recv().map_err(CallErr::Churn)? {
            Msg::Nack { message } => {
                metrics::FLEET_NACKS.inc();
                Err(CallErr::Fatal(anyhow::anyhow!(
                    "Fleet: worker {} refused {}: {}",
                    k,
                    msg.kind_name(),
                    message
                )))
            }
            reply => Ok(reply),
        }
    }

    /// Replace worker `k`'s transport after a churn failure; the state
    /// re-drive happens lazily on the next [`Fleet::attempt`].
    fn respawn(&mut self, k: usize, cause: &WireError) -> Result<()> {
        self.respawns += 1;
        metrics::FLEET_RESPAWNS.inc();
        obs::event::debug(
            "fleet",
            &format!("Fleet: respawning worker {} after {}", k, cause.kind_name()),
        );
        self.workers[k] = (self.spawn)(k).map_err(|e| {
            e.context(format!(
                "Fleet: respawning worker {} after transport failure ({})",
                k, cause
            ))
        })?;
        self.needs_reload[k] = true;
        Ok(())
    }

    /// Re-install worker `k`'s checkpoint slice and re-drive every
    /// command issued since. Deterministic kernels + identical command
    /// order = the rebuilt buffers are bit-identical to the lost ones.
    fn reload(&mut self, k: usize) -> Result<(), CallErr> {
        let load = Msg::LoadShard {
            plan: Box::new(self.plan.clone()),
            shard: k as u32,
            trainable: self.trainable.clone(),
            segments: self.checkpoint[k].clone(),
        };
        let replays: Vec<Msg> = self.cmd_log.clone();
        let t = &mut self.workers[k];
        for cmd in std::iter::once(&load).chain(replays.iter()) {
            t.send(cmd).map_err(CallErr::Churn)?;
            match t.recv().map_err(CallErr::Churn)? {
                Msg::Ack => {}
                Msg::Nack { message } => {
                    return Err(CallErr::Fatal(anyhow::anyhow!(
                        "Fleet: worker {} refused {} during state re-drive: {}",
                        k,
                        cmd.kind_name(),
                        message
                    )))
                }
                other => {
                    return Err(CallErr::Fatal(anyhow::anyhow!(
                        "Fleet: worker {} answered {} to a {} re-drive",
                        k,
                        other.kind_name(),
                        cmd.kind_name()
                    )))
                }
            }
        }
        self.needs_reload[k] = false;
        Ok(())
    }

    /// Fetch every worker's current slice (digest-verified) into the
    /// dense mirror.
    fn refresh_mirror(&mut self) -> Result<()> {
        let pd = self.plan.digest();
        for k in 0..self.workers.len() {
            let reply = self.rpc(k, &Msg::FetchShard { plan_digest: pd })?;
            let (plan_digest, shard, shard_digest, segments) = match reply {
                Msg::ShardSlice { plan_digest, shard, shard_digest, segments } => {
                    (plan_digest, shard, shard_digest, segments)
                }
                other => bail!("Fleet: worker {} answered {} to a fetch", k, other.kind_name()),
            };
            if plan_digest != pd || shard as usize != k || shard_digest != self.plan.shard_digest(k)
            {
                bail!(
                    "Fleet: worker {} returned a slice for plan {:#018x} shard {} \
                     (digest {:#018x}); expected plan {:#018x} shard {} (digest {:#018x})",
                    k,
                    plan_digest,
                    shard,
                    shard_digest,
                    pd,
                    k,
                    self.plan.shard_digest(k)
                );
            }
            let segs = &self.plan.shard(k).segments;
            if segments.len() != segs.len() {
                bail!(
                    "Fleet: worker {} returned {} segment buffers, plan has {}",
                    k,
                    segments.len(),
                    segs.len()
                );
            }
            for (seg, buf) in segs.iter().zip(&segments) {
                if buf.len() != seg.len() {
                    bail!(
                        "Fleet: worker {} segment buffer holds {} values, segment spans {}",
                        k,
                        buf.len(),
                        seg.len()
                    );
                }
                self.mirror.data[seg.tensor][seg.lo..seg.hi].copy_from_slice(buf);
            }
        }
        Ok(())
    }

    /// Promote the workers' current state to the new checkpoint and
    /// clear the command log — the recovery baseline rolls forward at
    /// every step/replay boundary, so re-drives stay short.
    fn checkpoint_now(&mut self) -> Result<()> {
        self.refresh_mirror()?;
        for (k, shard) in self.plan.shards().iter().enumerate() {
            for (si, seg) in shard.segments.iter().enumerate() {
                self.checkpoint[k][si]
                    .copy_from_slice(&self.mirror.data[seg.tensor][seg.lo..seg.hi]);
            }
        }
        self.cmd_log.clear();
        Ok(())
    }
}

/// Spawn one in-process channel worker per shard: each call starts a
/// thread running [`ShardWorker::serve`](super::ShardWorker::serve)
/// over the worker end of a [`channel_pair`](super::channel_pair) and
/// returns the coordinator end. The default [`SpawnFn`] for
/// single-process fleets, and the churn tests' respawn path (an
/// orphaned worker thread exits when its channel disconnects).
pub fn channel_spawner(timeout: Option<std::time::Duration>) -> SpawnFn {
    Box::new(move |_k| {
        let (coord, mut worker) = super::transport::channel_pair(timeout);
        std::thread::spawn(move || {
            let mut w = super::worker::ShardWorker::new();
            // Disconnect-driven lifetime: serve() returns Ok when the
            // coordinator drops this end (normal or churn teardown).
            let _ = w.serve(&mut worker);
        });
        Ok(Box::new(coord) as Box<dyn Transport>)
    })
}
