//! Minimal JSON parser/writer (substrate: no serde in the offline crate set).
//!
//! Supports the full JSON grammar needed by artifact `.meta.json` sidecars,
//! experiment configs and metrics logs: objects, arrays, strings (with
//! escapes), numbers, bools, null. Numbers parse as f64; helpers expose
//! integer views.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (always held as f64; see the integer accessors).
    Num(f64),
    /// A string (escapes already resolved).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; keys sorted (BTreeMap) so serialization is stable.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// The object's map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// The array's elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// Integer view of a number (truncating cast).
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    /// `usize` view of a number (truncating cast).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// `obj["k"]` access; returns Null for missing keys (chainable).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
    /// `arr[i]` access; returns Null out of bounds (chainable).
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (no whitespace); `Json::parse` round-trips it.
/// `.to_string()` comes with this impl via the blanket `ToString`.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(|x| x.into()).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{}': {}", txt, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").idx(1).as_f64(), Some(2.5));
        assert_eq!(v.get("a").idx(2).as_f64(), Some(-300.0));
        assert_eq!(v.get("b").as_str(), Some("x\ny"));
        assert_eq!(v.get("c").as_bool(), Some(true));
        assert_eq!(v.get("d"), &Json::Null);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn nested_and_unicode() {
        let src = r#"{"x": {"y": [{"z": "A"}]}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("x").get("y").idx(0).get("z").as_str(), Some("A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope").get("deeper"), &Json::Null);
    }
}
