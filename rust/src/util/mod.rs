//! Shared substrates built in-repo (the offline crate set has no serde /
//! clap / criterion): JSON, CLI args, stats/benchmarking.
pub mod args;
pub mod json;
pub mod prop;
pub mod stats;
