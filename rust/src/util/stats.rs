//! Timing + summary statistics helpers (substrate: no criterion offline).

use std::time::Instant;

/// Simple wall-clock timer: one `Instant` with unit-converting readers.
/// The single timing primitive for benches, examples and the obs layer —
/// hand-rolled `Instant::now()` deltas belong here instead.
pub struct Timer(Instant);

impl Timer {
    /// Start timing now.
    pub fn start() -> Timer {
        Timer(Instant::now())
    }
    /// Seconds elapsed since [`Timer::start`].
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    /// Milliseconds elapsed since [`Timer::start`].
    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
    /// Whole nanoseconds elapsed since [`Timer::start`], saturating at
    /// `u64::MAX` (~585 years) — the unit the obs-layer latency
    /// histograms ([`crate::obs::Histo::record`]) take.
    pub fn ns(&self) -> u64 {
        u64::try_from(self.0.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }
}

/// Summary statistics over a sample. NaN observations are counted in
/// [`Summary::nan`] and excluded from every other statistic — a single
/// NaN loss must not take down a bench run or the serving harness.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    /// sample size (NaN observations included)
    pub n: usize,
    /// NaN observations — excluded from mean/std/min/max/percentiles
    pub nan: usize,
    /// arithmetic mean
    pub mean: f64,
    /// population standard deviation
    pub std: f64,
    /// smallest observation
    pub min: f64,
    /// largest observation
    pub max: f64,
    /// median (nearest-rank)
    pub p50: f64,
    /// 90th percentile (nearest-rank)
    pub p90: f64,
    /// 99th percentile (nearest-rank) — the serving-latency tail
    pub p99: f64,
}

/// Summary statistics of a sample (all-zero [`Summary`] when empty, or
/// when every observation is NaN — `n`/`nan` still report the counts).
/// Sorting uses [`f64::total_cmp`], so NaNs sort last instead of
/// panicking the comparator; they are then dropped from the statistics
/// and surfaced in [`Summary::nan`].
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mut sorted = xs.to_vec();
    // total order: -NaN < -inf < ... < +inf < NaN; our NaNs (no sign bit
    // games in timing/loss data) land at the tail
    sorted.sort_by(|a, b| a.total_cmp(b));
    let nan = sorted.iter().filter(|x| x.is_nan()).count();
    sorted.retain(|x| !x.is_nan());
    if sorted.is_empty() {
        return Summary { n, nan, ..Summary::default() };
    }
    let m = sorted.len();
    let mean = sorted.iter().sum::<f64>() / m as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / m as f64;
    let pct = |p: f64| sorted[(((m - 1) as f64) * p).round() as usize];
    Summary {
        n,
        nan,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[m - 1],
        p50: pct(0.5),
        p90: pct(0.9),
        p99: pct(0.99),
    }
}

/// Measure a closure `iters` times after `warmup` runs; returns per-iter
/// seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        times.push(t.secs());
    }
    summarize(&times)
}

/// mean ± std formatted like the paper's tables: "90.5 (1.2)".
pub fn fmt_mean_std(vals: &[f64]) -> String {
    let s = summarize(vals);
    format!("{:.1} ({:.1})", s.mean, s.std)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn empty_is_safe() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn fmt_matches_paper_style() {
        assert_eq!(fmt_mean_std(&[90.0, 91.0, 92.0]), "91.0 (0.8)");
    }

    #[test]
    fn nan_samples_do_not_panic_and_are_excluded() {
        // regression: partial_cmp().unwrap() used to panic on any NaN
        let s = summarize(&[3.0, f64::NAN, 1.0, 2.0, f64::NAN]);
        assert_eq!(s.n, 5);
        assert_eq!(s.nan, 2);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
        assert!(s.p99.is_finite());
    }

    #[test]
    fn all_nan_sample_is_safe() {
        let s = summarize(&[f64::NAN, -f64::NAN]);
        assert_eq!((s.n, s.nan), (2, 2));
        assert_eq!(s.mean, 0.0); // the empty-statistics default, not NaN
    }

    #[test]
    fn p99_is_the_tail_observation() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert_eq!(s.p99, 99.0); // nearest-rank on 0..=99: round(99*.99)=98
        assert_eq!(s.p90, 90.0);
    }
}
