//! Timing + summary statistics helpers (substrate: no criterion offline).

use std::time::Instant;

/// Simple wall-clock timer.
pub struct Timer(Instant);

impl Timer {
    /// Start timing now.
    pub fn start() -> Timer {
        Timer(Instant::now())
    }
    /// Seconds elapsed since [`Timer::start`].
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    /// Milliseconds elapsed since [`Timer::start`].
    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Summary statistics over a sample.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    /// sample size
    pub n: usize,
    /// arithmetic mean
    pub mean: f64,
    /// population standard deviation
    pub std: f64,
    /// smallest observation
    pub min: f64,
    /// largest observation
    pub max: f64,
    /// median (nearest-rank)
    pub p50: f64,
    /// 90th percentile (nearest-rank)
    pub p90: f64,
}

/// Summary statistics of a sample (all-zero [`Summary`] when empty).
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| sorted[(((n - 1) as f64) * p).round() as usize];
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: pct(0.5),
        p90: pct(0.9),
    }
}

/// Measure a closure `iters` times after `warmup` runs; returns per-iter
/// seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        times.push(t.secs());
    }
    summarize(&times)
}

/// mean ± std formatted like the paper's tables: "90.5 (1.2)".
pub fn fmt_mean_std(vals: &[f64]) -> String {
    let s = summarize(vals);
    format!("{:.1} ({:.1})", s.mean, s.std)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn empty_is_safe() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn fmt_matches_paper_style() {
        assert_eq!(fmt_mean_std(&[90.0, 91.0, 92.0]), "91.0 (0.8)");
    }
}
