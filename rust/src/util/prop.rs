//! Miniature property-testing harness (substrate: no proptest offline).
//!
//! `forall(n, seed, gen, prop)` draws `n` random cases from `gen` and
//! asserts `prop` on each; on failure it re-reports the failing case's
//! seed so the case can be reproduced deterministically.

use crate::rng::Pcg;

/// Run `prop` on `n` generated cases. Panics with the failing case seed.
pub fn forall<T, G, P>(n: usize, seed: u64, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Pcg) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for i in 0..n {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(i as u64);
        let mut rng = Pcg::new(case_seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed on case #{} (seed {}): {}\ncase: {:?}",
                i, case_seed, msg, case
            );
        }
    }
}

/// Assert helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(50, 1, |r| r.below(100), |&x| ensure(x < 100, "range"));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(50, 2, |r| r.below(100), |&x| ensure(x < 50, "half"));
    }
}
