//! Tiny CLI argument parser (substrate: no clap in the offline crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and defaults.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order plus `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Non-flag arguments, in the order they appeared.
    pub positional: Vec<String>,
    /// Flag values keyed by name (bare `--flag` stores `"true"`).
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse an argv slice (without the program name). `--key value`,
    /// `--key=value` and bare boolean `--flag` forms are accepted;
    /// anything else is positional.
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let t = &argv[i];
            if let Some(rest) = t.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.flags.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                a.positional.push(t.clone());
            }
            i += 1;
        }
        a
    }

    /// Parse the process's own arguments (skipping the program name).
    pub fn from_env() -> Args {
        Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())
    }

    /// String flag with a default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Flag value if present, `None` otherwise.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// `usize` flag with a default (also on parse failure).
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// `u64` flag with a default (also on parse failure).
    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// `f32` flag with a default (also on parse failure).
    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Boolean flag: `true`/`1`/`yes` and `false`/`0`/`no` are
    /// recognized; anything else (or absence) yields the default.
    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(&sv(&[
            "exp", "table1", "--steps", "500", "--lr=1e-3", "--quick",
        ]));
        assert_eq!(a.positional, sv(&["exp", "table1"]));
        assert_eq!(a.usize("steps", 0), 500);
        assert!((a.f32("lr", 0.0) - 1e-3).abs() < 1e-9);
        assert!(a.bool("quick", false));
        assert!(!a.bool("missing", false));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]));
        assert_eq!(a.str("task", "sst2"), "sst2");
        assert_eq!(a.usize("k", 16), 16);
    }
}
