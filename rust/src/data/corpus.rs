//! Synthetic pre-training corpus generator.
//!
//! Substitutes the paper's web-scale pre-training data (DESIGN.md §2.1):
//! sentences are drawn from a structured grammar whose surface patterns are
//! *exactly* the patterns the downstream prompts reuse — polarity words
//! co-occur with the sentiment label words ("... it was great"), topic nouns
//! co-occur with topic labels ("about sports"), yes/no agreement patterns
//! appear for NLI, and fact/retrieval patterns for QA. Pre-training on this
//! corpus is what gives MeZO the benign, low-effective-rank fine-tuning
//! landscape the theory (§4) requires.

use crate::rng::Pcg;
use crate::tokenizer::{Vocab, EOS, NOUNS_PER_TOPIC, N_DIGIT, N_NEG_ADJ, N_PERSON,
                        N_PLACE, N_POS_ADJ, N_VERB, TOPICS};

/// One corpus sentence (token ids, no padding).
pub fn sample_sentence(rng: &mut Pcg, v: &Vocab) -> Vec<u32> {
    match rng.below(10) {
        0 | 1 => sentiment_sentence(rng, v),
        2 => sentiment_review(rng, v),
        3 | 4 => topic_sentence(rng, v),
        5 => agreement_pair(rng, v),
        6 => fact_sentence(rng, v),
        7 => qa_pattern(rng, v),
        8 => score_pattern(rng, v),
        _ => filler_sentence(rng, v),
    }
}

/// "the <noun> was <adj> and <adj-same-polarity>"
fn sentiment_sentence(rng: &mut Pcg, v: &Vocab) -> Vec<u32> {
    let topic = rng.below(TOPICS.len());
    let noun = v.noun(topic, rng.below(NOUNS_PER_TOPIC));
    let pos = rng.below(2) == 0;
    let adj = |rng: &mut Pcg| {
        if pos {
            v.pos_adj(rng.below(N_POS_ADJ))
        } else {
            v.neg_adj(rng.below(N_NEG_ADJ))
        }
    };
    let mut s = vec![v.id("the"), noun, v.id("was"), adj(rng)];
    if rng.below(2) == 0 {
        s.push(v.id("and"));
        s.push(adj(rng));
    }
    s.push(v.id("."));
    s
}

/// "review : the <noun> was <adj...> . it was <label> ." — the bridge
/// between content polarity and the sentiment label words.
///
/// The label word is *sampled from a polarity-conditional distribution*
/// (not a deterministic function of surface form): positive contexts emit
/// great/good, negative ones terrible/bad, neutral ones okay, with strength
/// (1 vs 2 adjectives) shifting the mix. This forces the model to learn
/// p(label-word | polarity) — the transferable signal the downstream
/// sentiment prompts reuse — rather than an adjective-counting shortcut.
fn sentiment_review(rng: &mut Pcg, v: &Vocab) -> Vec<u32> {
    let topic = rng.below(TOPICS.len());
    let noun = v.noun(topic, rng.below(NOUNS_PER_TOPIC));
    let polarity = rng.below(5); // 0,1 neg; 2 neutral; 3,4 pos
    let two = rng.below(2) == 0;
    let adj = |rng: &mut Pcg| match polarity {
        0 | 1 => v.neg_adj(rng.below(N_NEG_ADJ)),
        2 => v.neu_adj(rng.below(crate::tokenizer::N_NEU_ADJ)),
        _ => v.pos_adj(rng.below(N_POS_ADJ)),
    };
    let label = match polarity {
        0 | 1 => {
            // stronger (two-adjective) reviews skew to the extreme word
            let p_extreme = if two { 0.7 } else { 0.3 };
            if rng.next_f64() < p_extreme { "terrible" } else { "bad" }
        }
        2 => "okay",
        _ => {
            let p_extreme = if two { 0.7 } else { 0.3 };
            if rng.next_f64() < p_extreme { "great" } else { "good" }
        }
    };
    let mut s = vec![v.id("review"), v.id(":"), v.id("the"), noun, v.id("was"), adj(rng)];
    if two {
        s.push(v.id("and"));
        s.push(adj(rng));
    }
    s.extend([v.id("."), v.id("it"), v.id("was"), v.id(label), v.id(".")]);
    s
}

/// "the <noun> and the <noun2> . about <topic> ."
fn topic_sentence(rng: &mut Pcg, v: &Vocab) -> Vec<u32> {
    let topic = rng.below(TOPICS.len());
    let n1 = v.noun(topic, rng.below(NOUNS_PER_TOPIC));
    let n2 = v.noun(topic, rng.below(NOUNS_PER_TOPIC));
    let verb = v.verb(rng.below(N_VERB));
    vec![
        v.id("the"), n1, verb, v.id("the"), n2, v.id("."),
        v.id("about"), v.topic_label(topic), v.id("."),
    ]
}

/// "the <noun> was <adjA> . the <noun2> was <adjB> ? <Yes|No|Maybe> ." —
/// premise, hypothesis, then the agreement label at the END (AR models must
/// be able to condition the label on both sentences; the paper's OPT
/// prompts likewise put the label word last).
fn agreement_pair(rng: &mut Pcg, v: &Vocab) -> Vec<u32> {
    let topic = rng.below(TOPICS.len());
    let noun = v.noun(topic, rng.below(NOUNS_PER_TOPIC));
    let pos = rng.below(2) == 0;
    let adj = if pos { v.pos_adj(rng.below(N_POS_ADJ)) } else { v.neg_adj(rng.below(N_NEG_ADJ)) };
    let kind = rng.below(3);
    let (label, noun2, adj2) = match kind {
        0 => ("Yes", noun, adj),
        1 => {
            // contradiction: same noun, opposite polarity
            let a2 = if pos { v.neg_adj(rng.below(N_NEG_ADJ)) } else { v.pos_adj(rng.below(N_POS_ADJ)) };
            ("No", noun, a2)
        }
        _ => {
            // neutral: different noun
            let t2 = rng.below(TOPICS.len());
            ("Maybe", v.noun(t2, rng.below(NOUNS_PER_TOPIC)), adj)
        }
    };
    vec![
        v.id("the"), noun, v.id("was"), adj, v.id("."),
        v.id("the"), noun2, v.id("was"), adj2, v.id("?"),
        v.id(label), v.id("."),
    ]
}

/// "<person> went to <place> ."
fn fact_sentence(rng: &mut Pcg, v: &Vocab) -> Vec<u32> {
    vec![
        v.person(rng.below(N_PERSON)), v.id("went"), v.id("to"),
        v.place(rng.below(N_PLACE)), v.id("."),
    ]
}

/// "passage : <person> went to <place> . question : <person> ? answer : <place> ."
fn qa_pattern(rng: &mut Pcg, v: &Vocab) -> Vec<u32> {
    let p = v.person(rng.below(N_PERSON));
    let pl = v.place(rng.below(N_PLACE));
    vec![
        v.id("passage"), v.id(":"), p, v.id("went"), v.id("to"), pl, v.id("."),
        v.id("question"), v.id(":"), p, v.id("?"),
        v.id("answer"), v.id(":"), pl, v.id("."),
    ]
}

/// "<person> scored <num> . question : <person> ? answer : <num> ."
fn score_pattern(rng: &mut Pcg, v: &Vocab) -> Vec<u32> {
    let p = v.person(rng.below(N_PERSON));
    let d = v.digit(rng.below(N_DIGIT));
    vec![
        p, v.id("scored"), d, v.id("."),
        v.id("question"), v.id(":"), p, v.id("?"),
        v.id("answer"), v.id(":"), d, v.id("."),
    ]
}

/// unconditional filler to keep the distribution from being fully templated
fn filler_sentence(rng: &mut Pcg, v: &Vocab) -> Vec<u32> {
    let topic = rng.below(TOPICS.len());
    let mut s = vec![v.id("a")];
    for _ in 0..rng.range(2, 5) {
        s.push(v.noun(topic, rng.below(NOUNS_PER_TOPIC)));
    }
    s.push(v.id("."));
    s
}

/// Pack sentences into fixed-length sequences of `seq_len` tokens
/// (documents separated by EOS), yielding `n_seqs` rows.
pub fn pack_sequences(rng: &mut Pcg, v: &Vocab, n_seqs: usize, seq_len: usize) -> Vec<Vec<u32>> {
    let mut out = Vec::with_capacity(n_seqs);
    let mut buf: Vec<u32> = Vec::new();
    while out.len() < n_seqs {
        while buf.len() < seq_len {
            buf.extend(sample_sentence(rng, v));
            buf.push(EOS);
        }
        out.push(buf.drain(..seq_len).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::PAD;

    #[test]
    fn sentences_are_valid_token_ids() {
        let v = Vocab::standard();
        let mut rng = Pcg::new(0);
        for _ in 0..500 {
            let s = sample_sentence(&mut rng, &v);
            assert!(!s.is_empty());
            for &t in &s {
                assert!(t < v.used, "token {} out of lexicon", t);
                assert_ne!(t, PAD);
            }
        }
    }

    #[test]
    fn packing_yields_exact_lengths() {
        let v = Vocab::standard();
        let mut rng = Pcg::new(1);
        let seqs = pack_sequences(&mut rng, &v, 10, 64);
        assert_eq!(seqs.len(), 10);
        assert!(seqs.iter().all(|s| s.len() == 64));
    }

    #[test]
    fn review_pattern_links_polarity_to_label() {
        let v = Vocab::standard();
        let mut rng = Pcg::new(2);
        let mut seen_great = false;
        let mut seen_terrible = false;
        for _ in 0..200 {
            let s = sentiment_review(&mut rng, &v);
            let text = v.decode(&s);
            if text.contains("it was great") {
                assert!(text.contains("pos_a"), "{}", text);
                seen_great = true;
            }
            if text.contains("it was terrible") {
                assert!(text.contains("neg_a"), "{}", text);
                seen_terrible = true;
            }
        }
        assert!(seen_great && seen_terrible);
    }

    #[test]
    fn corpus_is_deterministic_per_seed() {
        let v = Vocab::standard();
        let a = pack_sequences(&mut Pcg::new(7), &v, 5, 32);
        let b = pack_sequences(&mut Pcg::new(7), &v, 5, 32);
        assert_eq!(a, b);
    }
}
