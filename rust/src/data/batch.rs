//! Batch assembly: fixed-shape (B, S) tensors matching the artifact ABI.
//!
//! The artifacts are compiled for static shapes, so every sequence is padded
//! to S with [PAD], `attn_mask` zeroed on padding, and `loss_mask` selecting
//! exactly the positions the objective covers:
//!   * AR:  position t predicts token t+1 (targets are the input shifted
//!          left); a candidate spanning tokens [a, b) is scored by masking
//!          predictor positions [a-1, b-1).
//!   * MLM: the candidate's single token is replaced by [MASK] in the input
//!          and supervised in place.

use crate::data::tasks::Example;
use crate::rng::Pcg;
use crate::tokenizer::{MASK, PAD, SEP};

/// One fixed-shape (B, S) batch in the artifact ABI: four row-major
/// `B × S` buffers, padded with [PAD] / zeros past each sequence's end.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Batch size B (rows).
    pub b: usize,
    /// Sequence length S (columns); every row is padded to exactly S.
    pub s: usize,
    /// Input token ids, `[PAD]` past the sequence end.
    pub input_ids: Vec<i32>,
    /// Per-position target token ids (AR: input shifted left; MLM: the
    /// original token at masked positions). Only read where `loss_mask`
    /// is set.
    pub targets: Vec<i32>,
    /// 1.0 exactly on the positions the objective supervises.
    pub loss_mask: Vec<f32>,
    /// 1.0 on real tokens, 0.0 on padding.
    pub attn_mask: Vec<f32>,
}

impl Batch {
    /// An all-padding batch: `[PAD]` inputs/targets, zeroed masks.
    pub fn zeros(b: usize, s: usize) -> Batch {
        Batch {
            b,
            s,
            input_ids: vec![PAD as i32; b * s],
            targets: vec![PAD as i32; b * s],
            loss_mask: vec![0.0; b * s],
            attn_mask: vec![0.0; b * s],
        }
    }

    fn set_row_ar(&mut self, row: usize, seq: &[u32], score: std::ops::Range<usize>) {
        let s = self.s;
        assert!(seq.len() <= s, "sequence {} exceeds S={}", seq.len(), s);
        assert!(score.start >= 1, "AR cannot score position 0 (no left context)");
        for (t, &tok) in seq.iter().enumerate() {
            self.input_ids[row * s + t] = tok as i32;
            self.attn_mask[row * s + t] = 1.0;
            if t + 1 < seq.len() {
                self.targets[row * s + t] = seq[t + 1] as i32;
            }
        }
        for t in score.start.saturating_sub(1)..score.end - 1 {
            self.loss_mask[row * s + t] = 1.0;
        }
    }

    fn set_row_mlm(&mut self, row: usize, seq: &[u32], score: std::ops::Range<usize>) {
        let s = self.s;
        assert!(seq.len() <= s, "sequence {} exceeds S={}", seq.len(), s);
        for (t, &tok) in seq.iter().enumerate() {
            self.input_ids[row * s + t] = tok as i32;
            self.attn_mask[row * s + t] = 1.0;
        }
        for t in score.clone() {
            self.input_ids[row * s + t] = MASK as i32;
            self.targets[row * s + t] = seq[t] as i32;
            self.loss_mask[row * s + t] = 1.0;
        }
    }

    /// Write one sequence into `row`, supervising the `score` token range
    /// under the AR objective (`mlm = false`: predictor positions
    /// `[score.start−1, score.end−1)` are masked) or the MLM objective
    /// (`mlm = true`: the range is replaced by [MASK] and supervised in
    /// place).
    pub fn set_row(&mut self, row: usize, seq: &[u32], score: std::ops::Range<usize>, mlm: bool) {
        if mlm {
            self.set_row_mlm(row, seq, score)
        } else {
            self.set_row_ar(row, seq, score)
        }
    }
}

/// Training batches from examples (gold candidate filled).
/// Pads the final batch by repeating examples; `weights` gives the number of
/// *distinct* examples in each batch row (1.0 for real rows, 0-loss rows are
/// avoided by repetition which leaves the mean unbiased enough for training).
pub fn example_batches(examples: &[Example], b: usize, s: usize, mlm: bool) -> Vec<Batch> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < examples.len() {
        let mut batch = Batch::zeros(b, s);
        for row in 0..b {
            let ex = &examples[(i + row) % examples.len()];
            let (seq, range) = ex.filled();
            batch.set_row(row, &seq, range, mlm);
        }
        out.push(batch);
        i += b;
    }
    out
}

/// One training batch from a sampled subset of examples.
pub fn sample_batch(examples: &[Example], rng: &mut Pcg, b: usize, s: usize, mlm: bool) -> Batch {
    let mut batch = Batch::zeros(b, s);
    for row in 0..b {
        let ex = rng.choice(examples);
        let (seq, range) = ex.filled();
        batch.set_row(row, &seq, range, mlm);
    }
    batch
}

/// LM pre-training batch from packed corpus sequences.
pub fn lm_batch(seqs: &[Vec<u32>], rng: &mut Pcg, b: usize, s: usize, mlm: bool) -> Batch {
    let mut batch = Batch::zeros(b, s);
    for row in 0..b {
        let seq = rng.choice(seqs);
        assert_eq!(seq.len(), s);
        if mlm {
            // BERT-style: mask 15% of positions
            for (t, &tok) in seq.iter().enumerate() {
                batch.input_ids[row * s + t] = tok as i32;
                batch.attn_mask[row * s + t] = 1.0;
            }
            for t in 0..s {
                if rng.next_f32() < 0.15 {
                    batch.input_ids[row * s + t] = MASK as i32;
                    batch.targets[row * s + t] = seq[t] as i32;
                    batch.loss_mask[row * s + t] = 1.0;
                }
            }
        } else {
            batch.set_row_ar(row, seq, 1..seq.len());
        }
    }
    batch
}

/// In-context learning: prepend as many demonstrations (gold-filled,
/// [SEP]-separated) as fit the S-token budget before the test context
/// (paper Appendix E.4 uses 32; our budget fits ~3).
pub fn icl_example(demos: &[Example], test: &Example, max_demos: usize, s: usize) -> Example {
    let mut ctx: Vec<u32> = Vec::new();
    let test_len = test.context.len()
        + test.suffix.len()
        + test
            .candidates
            .iter()
            .map(|c| c.len())
            .max()
            .unwrap_or(test.answer.len())
        + 1;
    for demo in demos.iter().take(max_demos) {
        let (seq, _) = demo.filled();
        if ctx.len() + seq.len() + 1 + test_len > s {
            break;
        }
        ctx.extend_from_slice(&seq);
        ctx.push(SEP);
    }
    ctx.extend_from_slice(&test.context);
    Example {
        context: ctx,
        suffix: test.suffix.clone(),
        candidates: test.candidates.clone(),
        label: test.label,
        answer: test.answer.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{generate, GenOpts, Task};
    use crate::tokenizer::Vocab;

    #[test]
    fn ar_row_shifts_targets() {
        let mut b = Batch::zeros(1, 8);
        let seq = [10u32, 11, 12, 13];
        b.set_row(0, &seq, 3..4, false);
        assert_eq!(&b.input_ids[..4], &[10, 11, 12, 13]);
        assert_eq!(b.targets[2], 13); // position 2 predicts token 3
        assert_eq!(b.loss_mask[2], 1.0);
        assert_eq!(b.loss_mask.iter().sum::<f32>(), 1.0);
        assert_eq!(b.attn_mask[3], 1.0);
        assert_eq!(b.attn_mask[4], 0.0);
        assert_eq!(b.input_ids[7], PAD as i32);
    }

    #[test]
    fn mlm_row_masks_in_place() {
        let mut b = Batch::zeros(1, 8);
        let seq = [10u32, 11, 12, 13];
        b.set_row(0, &seq, 2..3, true);
        assert_eq!(b.input_ids[2], MASK as i32);
        assert_eq!(b.targets[2], 12);
        assert_eq!(b.loss_mask[2], 1.0);
        assert_eq!(b.loss_mask.iter().sum::<f32>(), 1.0);
    }

    #[test]
    fn example_batches_cover_all() {
        let v = Vocab::standard();
        let data = generate(Task::Sst2, &v, GenOpts { n_train: 10, ..Default::default() });
        let batches = example_batches(&data.train, 4, 64, false);
        assert_eq!(batches.len(), 3); // ceil(10/4)
        for b in &batches {
            assert!(b.loss_mask.iter().sum::<f32>() > 0.0);
        }
    }

    #[test]
    fn lm_batch_ar_and_mlm() {
        let v = Vocab::standard();
        let mut rng = Pcg::new(0);
        let seqs = crate::data::corpus::pack_sequences(&mut rng, &v, 4, 32);
        let ar = lm_batch(&seqs, &mut Pcg::new(1), 2, 32, false);
        assert!(ar.loss_mask.iter().sum::<f32>() >= 31.0);
        let mlm = lm_batch(&seqs, &mut Pcg::new(2), 2, 32, true);
        let n_masked = mlm.loss_mask.iter().sum::<f32>();
        assert!(n_masked > 0.0 && n_masked < 32.0);
        // masked positions read [MASK]
        for t in 0..32 {
            if mlm.loss_mask[t] == 1.0 {
                assert_eq!(mlm.input_ids[t], MASK as i32);
            }
        }
    }

    #[test]
    fn icl_fits_budget_and_keeps_label() {
        let v = Vocab::standard();
        let data = generate(Task::Sst2, &v, GenOpts { n_train: 8, ..Default::default() });
        let ex = icl_example(&data.train, &data.test[0], 8, 64);
        assert_eq!(ex.label, data.test[0].label);
        let (seq, _) = ex.filled();
        assert!(seq.len() <= 64);
        assert!(ex.context.len() > data.test[0].context.len());
    }
}
