//! Synthetic workload substrate: pre-training corpus, downstream task
//! suite, prompt formats, and fixed-shape batch assembly.
pub mod batch;
pub mod corpus;
pub mod tasks;
