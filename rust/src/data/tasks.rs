//! The 11-task synthetic downstream suite (DESIGN.md §2.3).
//!
//! Each task mirrors one of the paper's evaluation datasets in *type* and
//! *prompt format* (Appendix E.2): classification via single-token label
//! words, multiple choice via candidate log-likelihood, and generation via
//! teacher forcing + greedy decoding. Labels derive from the same latent
//! attributes the pre-training corpus encodes, so prompt-based transfer is
//! real, not memorised.

use crate::rng::Pcg;
use crate::tokenizer::{Vocab, NOUNS_PER_TOPIC, N_DIGIT, N_NEG_ADJ, N_NEU_ADJ,
                        N_PERSON, N_PLACE, N_POS_ADJ, N_VERB, TOPICS};

/// Paper-task analogs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// SST-2: 2-way sentiment
    Sst2,
    /// SST-5: 5-way sentiment strength
    Sst5,
    /// SNLI: 3-way NLI
    Snli,
    /// MNLI: 3-way NLI (shifted topic distribution)
    Mnli,
    /// RTE: 2-way NLI
    Rte,
    /// CB: 3-way NLI, small data regime
    Cb,
    /// TREC: 6-way topic
    Trec,
    /// BoolQ: passage yes/no
    BoolQ,
    /// WSC analog: membership yes/no
    Wsc,
    /// WiC analog: same-sense yes/no
    Wic,
    /// MultiRC: answer-correctness yes/no over a passage
    MultiRc,
    /// COPA: 2-choice plausible continuation
    Copa,
    /// ReCoRD: entity cloze multiple choice
    Record,
    /// SQuAD: extractive QA, generation
    Squad,
    /// DROP: numeric QA, generation
    Drop,
}

/// How a task is scored (paper Appendix E.2 prompt families).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskType {
    /// Single-token label words, scored by label-word log-likelihood.
    Classification,
    /// Multi-token candidates, scored by candidate log-likelihood.
    MultipleChoice,
    /// Free-form answers: teacher forcing to train, greedy decode to eval.
    Generation,
}

impl Task {
    /// Stable lowercase identifier (CLI names, result-table keys).
    pub fn name(&self) -> &'static str {
        match self {
            Task::Sst2 => "sst2",
            Task::Sst5 => "sst5",
            Task::Snli => "snli",
            Task::Mnli => "mnli",
            Task::Rte => "rte",
            Task::Cb => "cb",
            Task::Trec => "trec",
            Task::BoolQ => "boolq",
            Task::Wsc => "wsc",
            Task::Wic => "wic",
            Task::MultiRc => "multirc",
            Task::Copa => "copa",
            Task::Record => "record",
            Task::Squad => "squad",
            Task::Drop => "drop",
        }
    }

    /// Inverse of [`Task::name`]; `None` for an unknown identifier.
    pub fn from_name(s: &str) -> Option<Task> {
        ALL_TASKS.iter().copied().find(|t| t.name() == s)
    }

    /// The scoring family this task belongs to.
    pub fn task_type(&self) -> TaskType {
        match self {
            Task::Copa | Task::Record => TaskType::MultipleChoice,
            Task::Squad | Task::Drop => TaskType::Generation,
            _ => TaskType::Classification,
        }
    }

    /// Label/candidate count (0 for the generation tasks, which have no
    /// fixed candidate set).
    pub fn n_classes(&self) -> usize {
        match self {
            Task::Sst2 | Task::Rte | Task::BoolQ | Task::Wsc | Task::Wic
            | Task::MultiRc | Task::Copa => 2,
            Task::Snli | Task::Mnli | Task::Cb => 3,
            Task::Sst5 => 5,
            Task::Trec => 6,
            Task::Record => 3, // candidates per example
            Task::Squad | Task::Drop => 0,
        }
    }
}

/// Every task in the suite, in declaration order.
pub const ALL_TASKS: [Task; 15] = [
    Task::Sst2, Task::Sst5, Task::Snli, Task::Mnli, Task::Rte, Task::Cb,
    Task::Trec, Task::BoolQ, Task::Wsc, Task::Wic, Task::MultiRc, Task::Copa,
    Task::Record, Task::Squad, Task::Drop,
];

/// The OPT-family eleven (Table 1).
pub const OPT_TASKS: [Task; 11] = [
    Task::Sst2, Task::Rte, Task::Cb, Task::BoolQ, Task::Wsc, Task::Wic,
    Task::MultiRc, Task::Copa, Task::Record, Task::Squad, Task::Drop,
];
/// The RoBERTa-family six (Table 18 / Fig. 2).
pub const ROBERTA_TASKS: [Task; 6] =
    [Task::Sst2, Task::Sst5, Task::Snli, Task::Mnli, Task::Rte, Task::Trec];

/// One task example. `context` holds the full prompt with a single hole:
/// for classification/multiple-choice the hole is where a candidate goes
/// (position `hole` in the assembled sequence); for generation the answer
/// is generated after the context.
#[derive(Debug, Clone)]
pub struct Example {
    /// tokens before the hole
    pub context: Vec<u32>,
    /// tokens after the hole (empty for generation / end-positioned holes)
    pub suffix: Vec<u32>,
    /// candidate completions (cls: single-token label words)
    pub candidates: Vec<Vec<u32>>,
    /// index of the correct candidate (cls / mch)
    pub label: usize,
    /// gold answer tokens (generation; == candidates[label] otherwise)
    pub answer: Vec<u32>,
}

impl Example {
    /// Assemble the full training sequence with the gold candidate filled in.
    pub fn filled(&self) -> (Vec<u32>, std::ops::Range<usize>) {
        let cand = if self.candidates.is_empty() {
            &self.answer
        } else {
            &self.candidates[self.label]
        };
        let mut seq = self.context.clone();
        let start = seq.len();
        seq.extend_from_slice(cand);
        let end = seq.len();
        seq.extend_from_slice(&self.suffix);
        (seq, start..end)
    }

    /// Assemble with candidate `i` filled in (for log-likelihood scoring).
    pub fn with_candidate(&self, i: usize) -> (Vec<u32>, std::ops::Range<usize>) {
        let mut seq = self.context.clone();
        let start = seq.len();
        seq.extend_from_slice(&self.candidates[i]);
        let end = seq.len();
        seq.extend_from_slice(&self.suffix);
        (seq, start..end)
    }
}

/// A generated dataset split.
#[derive(Debug, Clone)]
pub struct TaskData {
    /// Which task the splits were generated for.
    pub task: Task,
    /// Training examples (balanced labels for classification tasks).
    pub train: Vec<Example>,
    /// Validation examples.
    pub val: Vec<Example>,
    /// Held-out test examples.
    pub test: Vec<Example>,
}

/// Generation options. `prompt=false` reproduces the Table 5 ablation:
/// the raw input is presented without the template words that tie the task
/// to pre-training patterns.
#[derive(Debug, Clone, Copy)]
pub struct GenOpts {
    /// Master seed; generation is deterministic per (task, seed).
    pub seed: u64,
    /// Training examples to generate.
    pub n_train: usize,
    /// Validation examples to generate.
    pub n_val: usize,
    /// Test examples to generate.
    pub n_test: usize,
    /// Include the prompt-template words (false = Table 5 ablation).
    pub prompt: bool,
}

impl Default for GenOpts {
    fn default() -> Self {
        GenOpts { seed: 0, n_train: 256, n_val: 128, n_test: 256, prompt: true }
    }
}

/// k-shot per class (paper §3.1: k = 16 / 512).
pub fn kshot(task: Task, v: &Vocab, k: usize, opts: GenOpts) -> TaskData {
    let per_class = k.max(1);
    let n = per_class * task.n_classes().max(1);
    generate(task, v, GenOpts { n_train: n, n_val: n, ..opts })
}

/// Generate train/val/test splits for `task`, deterministically from
/// `opts.seed` (labels balanced round-robin for classification tasks).
pub fn generate(task: Task, v: &Vocab, opts: GenOpts) -> TaskData {
    let mut rng = Pcg::new(opts.seed ^ (task as u64).wrapping_mul(0x9E37));
    let gen_split = |rng: &mut Pcg, n: usize| -> Vec<Example> {
        let mut out = Vec::with_capacity(n);
        let classes = task.n_classes().max(1);
        for i in 0..n {
            // balanced labels for classification tasks
            let want = i % classes;
            out.push(gen_example(task, v, rng, want, opts.prompt));
        }
        out
    };
    let train = gen_split(&mut rng, opts.n_train);
    let val = gen_split(&mut rng, opts.n_val);
    let test = gen_split(&mut rng, opts.n_test);
    TaskData { task, train, val, test }
}

// ---------------------------------------------------------------------
// per-task generators
// ---------------------------------------------------------------------

fn sample_adj(v: &Vocab, rng: &mut Pcg, positive: bool) -> u32 {
    if positive {
        v.pos_adj(rng.below(N_POS_ADJ))
    } else {
        v.neg_adj(rng.below(N_NEG_ADJ))
    }
}

fn sentiment_words(v: &Vocab, rng: &mut Pcg, strength: usize) -> Vec<u32> {
    // strength: 0 terrible .. 4 great
    match strength {
        0 => vec![v.neg_adj(rng.below(N_NEG_ADJ)), v.id("and"), v.neg_adj(rng.below(N_NEG_ADJ))],
        1 => vec![v.neg_adj(rng.below(N_NEG_ADJ))],
        2 => vec![v.neu_adj(rng.below(N_NEU_ADJ))],
        3 => vec![v.pos_adj(rng.below(N_POS_ADJ))],
        _ => vec![v.pos_adj(rng.below(N_POS_ADJ)), v.id("and"), v.pos_adj(rng.below(N_POS_ADJ))],
    }
}

fn label_words(v: &Vocab, words: &[&str]) -> Vec<Vec<u32>> {
    words.iter().map(|w| vec![v.id(w)]).collect()
}

fn gen_example(task: Task, v: &Vocab, rng: &mut Pcg, want: usize, prompt: bool) -> Example {
    match task {
        Task::Sst2 => {
            // want: 0 = terrible, 1 = great. Three adjectives with a 2:1
            // polarity majority — the corpus never mixes polarities within
            // a review, so zero-shot is imperfect and the majority rule has
            // to be *learned* (headroom for MeZO/FT, as in the paper).
            let topic = rng.below(TOPICS.len());
            let noun = v.noun(topic, rng.below(NOUNS_PER_TOPIC));
            let maj = want == 1;
            let mut adjs = vec![
                sample_adj(v, rng, maj),
                sample_adj(v, rng, maj),
                sample_adj(v, rng, !maj),
            ];
            rng.shuffle(&mut adjs);
            let mut ctx = if prompt { vec![v.id("review"), v.id(":")] } else { vec![] };
            ctx.extend([v.id("the"), noun, v.id("was")]);
            for (i, a) in adjs.iter().enumerate() {
                if i > 0 {
                    ctx.push(v.id("and"));
                }
                ctx.push(*a);
            }
            ctx.push(v.id("."));
            if prompt {
                ctx.extend([v.id("it"), v.id("was")]);
            }
            Example {
                context: ctx,
                suffix: vec![],
                candidates: label_words(v, &["terrible", "great"]),
                label: want,
                answer: vec![],
            }
        }
        Task::Sst5 => {
            // two adjective slots; label = summed polarity + 2
            // (−2 → terrible … +2 → great). Mixed pairs (label 1..3) never
            // co-occur with label words in the corpus.
            let topic = rng.below(TOPICS.len());
            let noun = v.noun(topic, rng.below(NOUNS_PER_TOPIC));
            let (p1, p2): (i32, i32) = match want {
                0 => (-1, -1),
                1 => (-1, 0),
                2 => (0, 0),
                3 => (1, 0),
                _ => (1, 1),
            };
            let adj = |rng: &mut Pcg, p: i32| match p {
                -1 => v.neg_adj(rng.below(N_NEG_ADJ)),
                0 => v.neu_adj(rng.below(N_NEU_ADJ)),
                _ => v.pos_adj(rng.below(N_POS_ADJ)),
            };
            let mut pair = vec![adj(rng, p1), adj(rng, p2)];
            rng.shuffle(&mut pair);
            let mut ctx = if prompt { vec![v.id("review"), v.id(":")] } else { vec![] };
            ctx.extend([v.id("the"), noun, v.id("was"), pair[0], v.id("and"), pair[1], v.id(".")]);
            if prompt {
                ctx.extend([v.id("it"), v.id("was")]);
            }
            Example {
                context: ctx,
                suffix: vec![],
                candidates: label_words(v, &["terrible", "bad", "okay", "good", "great"]),
                label: want,
                answer: vec![],
            }
        }
        Task::Snli | Task::Mnli | Task::Cb | Task::Rte => {
            // premise . hypothesis ? <label> — label at the END so the AR
            // family can condition on both sentences (OPT prompt style).
            // 0=entail(Yes), 1=neutral(Maybe), 2=contradict(No); RTE is
            // 2-way (Yes/No).
            let topics: &[usize] = match task {
                Task::Mnli => &[3, 4, 5],
                _ => &[0, 1, 2],
            };
            let topic = *rng.choice(topics);
            let noun = v.noun(topic, rng.below(NOUNS_PER_TOPIC));
            let pos = rng.below(2) == 0;
            let adj = if pos { v.pos_adj(rng.below(N_POS_ADJ)) } else { v.neg_adj(rng.below(N_NEG_ADJ)) };
            let two_way = task == Task::Rte;
            let label = want;
            let (noun2, adj2) = match (two_way, label) {
                (_, 0) => {
                    // entailment: same noun, same-polarity adjective
                    (noun, sample_adj(v, rng, pos))
                }
                (false, 1) => {
                    // neutral: unrelated noun
                    let t2 = *rng.choice(topics);
                    (v.noun(t2, rng.below(NOUNS_PER_TOPIC)), adj)
                }
                _ => {
                    // contradiction: same noun, flipped polarity
                    (noun, sample_adj(v, rng, !pos))
                }
            };
            let mut ctx = vec![v.id("the"), noun, v.id("was"), adj, v.id(".")];
            ctx.extend([v.id("the"), noun2, v.id("was"), adj2]);
            ctx.push(if prompt { v.id("?") } else { v.id(".") });
            let candidates = if two_way {
                label_words(v, &["Yes", "No"])
            } else {
                label_words(v, &["Yes", "Maybe", "No"])
            };
            Example { context: ctx, suffix: vec![], candidates, label, answer: vec![] }
        }
        Task::Trec => {
            // three nouns, 2:1 topic majority — corpus topic sentences are
            // pure, so the majority rule must be learned.
            let topic = want;
            let mut other = rng.below(TOPICS.len());
            while other == topic {
                other = rng.below(TOPICS.len());
            }
            let mut nouns = vec![
                v.noun(topic, rng.below(NOUNS_PER_TOPIC)),
                v.noun(topic, rng.below(NOUNS_PER_TOPIC)),
                v.noun(other, rng.below(NOUNS_PER_TOPIC)),
            ];
            rng.shuffle(&mut nouns);
            let verb = v.verb(rng.below(N_VERB));
            let mut ctx = vec![v.id("the"), nouns[0], verb, v.id("the"), nouns[1],
                               v.id("and"), v.id("the"), nouns[2], v.id(".")];
            if prompt {
                ctx.push(v.id("about"));
            }
            let candidates = (0..TOPICS.len()).map(|t| vec![v.topic_label(t)]).collect();
            Example { context: ctx, suffix: vec![], candidates, label: want, answer: vec![] }
        }
        Task::BoolQ => {
            // passage: two facts; question about one fact (Yes) or a
            // corrupted fact (No)
            let p1 = rng.below(N_PERSON);
            let mut p2 = rng.below(N_PERSON);
            while p2 == p1 { p2 = rng.below(N_PERSON); }
            let pl1 = rng.below(N_PLACE);
            let mut pl2 = rng.below(N_PLACE);
            while pl2 == pl1 { pl2 = rng.below(N_PLACE); }
            let mut ctx = vec![];
            if prompt {
                ctx.extend([v.id("passage"), v.id(":")]);
            }
            ctx.extend([v.person(p1), v.id("went"), v.id("to"), v.place(pl1), v.id(".")]);
            ctx.extend([v.person(p2), v.id("went"), v.id("to"), v.place(pl2), v.id(".")]);
            // question: did p1 go to X?
            let asked_place = if want == 0 {
                pl1 // true fact -> Yes
            } else {
                // wrong place -> No
                let mut w = rng.below(N_PLACE);
                while w == pl1 { w = rng.below(N_PLACE); }
                w
            };
            if prompt {
                ctx.extend([v.id("question"), v.id(":")]);
            }
            ctx.extend([v.id("did"), v.person(p1), v.id("went"), v.id("to"),
                        v.place(asked_place), v.id("?")]);
            Example {
                context: ctx,
                suffix: vec![],
                candidates: label_words(v, &["Yes", "No"]),
                label: want,
                answer: vec![],
            }
        }
        Task::Wsc => {
            // membership: "the <noun> is in <topic> ? Yes/No"
            let topic = rng.below(TOPICS.len());
            let noun = v.noun(topic, rng.below(NOUNS_PER_TOPIC));
            let asked = if want == 0 {
                topic
            } else {
                let mut t = rng.below(TOPICS.len());
                while t == topic { t = rng.below(TOPICS.len()); }
                t
            };
            let mut ctx = vec![v.id("the"), noun, v.id("is"), v.id("in"), v.topic_label(asked)];
            ctx.push(if prompt { v.id("?") } else { v.id(".") });
            Example {
                context: ctx,
                suffix: vec![],
                candidates: label_words(v, &["Yes", "No"]),
                label: want,
                answer: vec![],
            }
        }
        Task::Wic => {
            // same-category: "<w1> and <w2> same ? Yes/No"
            let t1 = rng.below(TOPICS.len());
            let w1 = v.noun(t1, rng.below(NOUNS_PER_TOPIC));
            let w2 = if want == 0 {
                v.noun(t1, rng.below(NOUNS_PER_TOPIC))
            } else {
                let mut t2 = rng.below(TOPICS.len());
                while t2 == t1 { t2 = rng.below(TOPICS.len()); }
                v.noun(t2, rng.below(NOUNS_PER_TOPIC))
            };
            let mut ctx = vec![w1, v.id("and"), w2, v.id("same")];
            ctx.push(if prompt { v.id("?") } else { v.id(".") });
            Example {
                context: ctx,
                suffix: vec![],
                candidates: label_words(v, &["Yes", "No"]),
                label: want,
                answer: vec![],
            }
        }
        Task::MultiRc => {
            // passage + question + proposed answer; is it correct?
            let p1 = rng.below(N_PERSON);
            let pl1 = rng.below(N_PLACE);
            let mut ctx = vec![];
            if prompt {
                ctx.extend([v.id("passage"), v.id(":")]);
            }
            ctx.extend([v.person(p1), v.id("went"), v.id("to"), v.place(pl1), v.id(".")]);
            if prompt {
                ctx.extend([v.id("question"), v.id(":")]);
            }
            ctx.extend([v.person(p1), v.id("?")]);
            let proposed = if want == 0 {
                pl1
            } else {
                let mut w = rng.below(N_PLACE);
                while w == pl1 { w = rng.below(N_PLACE); }
                w
            };
            if prompt {
                ctx.extend([v.id("answer"), v.id(":")]);
            }
            ctx.extend([v.place(proposed), v.id("."), v.id("correct"), v.id("?")]);
            Example {
                context: ctx,
                suffix: vec![],
                candidates: label_words(v, &["Yes", "No"]),
                label: want,
                answer: vec![],
            }
        }
        Task::Copa => {
            // premise with polarity; choose the plausible effect clause
            let topic = rng.below(TOPICS.len());
            let noun = v.noun(topic, rng.below(NOUNS_PER_TOPIC));
            let pos = want == 0; // candidate 0 = "it was great"
            let adj = if pos { v.pos_adj(rng.below(N_POS_ADJ)) } else { v.neg_adj(rng.below(N_NEG_ADJ)) };
            let mut ctx = vec![v.id("the"), noun, v.id("was"), adj];
            if prompt {
                ctx.push(v.id("so"));
            } else {
                ctx.push(v.id("."));
            }
            let candidates = vec![
                vec![v.id("it"), v.id("was"), v.id("great"), v.id(".")],
                vec![v.id("it"), v.id("was"), v.id("terrible"), v.id(".")],
            ];
            Example {
                context: ctx,
                suffix: vec![],
                candidates,
                label: if pos { 0 } else { 1 },
                answer: vec![],
            }
        }
        Task::Record => {
            // passage with 3 facts; cloze query about one person;
            // candidates = the three mentioned places.
            let mut persons = vec![];
            let mut places = vec![];
            while persons.len() < 3 {
                let p = rng.below(N_PERSON);
                if !persons.contains(&p) { persons.push(p); }
            }
            while places.len() < 3 {
                let p = rng.below(N_PLACE);
                if !places.contains(&p) { places.push(p); }
            }
            let mut ctx = vec![];
            if prompt {
                ctx.extend([v.id("passage"), v.id(":")]);
            }
            for i in 0..3 {
                ctx.extend([v.person(persons[i]), v.id("went"), v.id("to"),
                            v.place(places[i]), v.id(".")]);
            }
            let q = want % 3;
            ctx.extend([v.person(persons[q]), v.id("went"), v.id("to")]);
            let candidates: Vec<Vec<u32>> =
                places.iter().map(|&p| vec![v.place(p)]).collect();
            Example { context: ctx, suffix: vec![], candidates, label: q, answer: vec![] }
        }
        Task::Squad => {
            let p1 = rng.below(N_PERSON);
            let mut p2 = rng.below(N_PERSON);
            while p2 == p1 { p2 = rng.below(N_PERSON); }
            let pl1 = rng.below(N_PLACE);
            let pl2 = rng.below(N_PLACE);
            let mut ctx = vec![];
            if prompt {
                ctx.extend([v.id("passage"), v.id(":")]);
            }
            ctx.extend([v.person(p1), v.id("went"), v.id("to"), v.place(pl1), v.id(".")]);
            ctx.extend([v.person(p2), v.id("went"), v.id("to"), v.place(pl2), v.id(".")]);
            let ask_first = rng.below(2) == 0;
            let (qp, gold) = if ask_first { (p1, pl1) } else { (p2, pl2) };
            if prompt {
                ctx.extend([v.id("question"), v.id(":")]);
            }
            ctx.extend([v.person(qp), v.id("?")]);
            if prompt {
                ctx.extend([v.id("answer"), v.id(":")]);
            }
            Example {
                context: ctx,
                suffix: vec![],
                candidates: vec![],
                label: 0,
                answer: vec![v.place(gold), v.id(".")],
            }
        }
        Task::Drop => {
            let p1 = rng.below(N_PERSON);
            let mut p2 = rng.below(N_PERSON);
            while p2 == p1 { p2 = rng.below(N_PERSON); }
            let d1 = rng.below(N_DIGIT);
            let d2 = rng.below(N_DIGIT);
            let mut ctx = vec![];
            if prompt {
                ctx.extend([v.id("passage"), v.id(":")]);
            }
            ctx.extend([v.person(p1), v.id("scored"), v.digit(d1), v.id(".")]);
            ctx.extend([v.person(p2), v.id("scored"), v.digit(d2), v.id(".")]);
            let ask_first = rng.below(2) == 0;
            let (qp, gold) = if ask_first { (p1, d1) } else { (p2, d2) };
            if prompt {
                ctx.extend([v.id("question"), v.id(":")]);
            }
            ctx.extend([v.person(qp), v.id("?")]);
            if prompt {
                ctx.extend([v.id("answer"), v.id(":")]);
            }
            Example {
                context: ctx,
                suffix: vec![],
                candidates: vec![],
                label: 0,
                answer: vec![v.digit(gold), v.id(".")],
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_and_fit_sequence_budget() {
        let v = Vocab::standard();
        for &task in ALL_TASKS.iter() {
            let data = generate(task, &v, GenOpts { n_train: 24, n_val: 12, n_test: 24, ..Default::default() });
            assert_eq!(data.train.len(), 24);
            for ex in data.train.iter().chain(&data.test) {
                let (seq, range) = ex.filled();
                assert!(seq.len() + 2 <= 64, "{} seq too long: {}", task.name(), seq.len());
                assert!(range.end <= seq.len() && range.start < range.end);
                for &t in &seq {
                    assert!(t < v.used);
                }
            }
        }
    }

    #[test]
    fn labels_are_balanced() {
        let v = Vocab::standard();
        for &task in &[Task::Sst2, Task::Snli, Task::Trec] {
            let data = generate(task, &v, GenOpts { n_train: 60, ..Default::default() });
            let classes = task.n_classes();
            let mut counts = vec![0usize; classes];
            for ex in &data.train {
                counts[ex.label] += 1;
            }
            for &c in &counts {
                assert_eq!(c, 60 / classes);
            }
        }
    }

    #[test]
    fn candidate_fill_matches_label() {
        let v = Vocab::standard();
        let data = generate(Task::Sst2, &v, GenOpts { n_train: 8, ..Default::default() });
        for ex in &data.train {
            let (gold, r) = ex.filled();
            let (with, r2) = ex.with_candidate(ex.label);
            assert_eq!(gold, with);
            assert_eq!(r, r2);
        }
    }

    #[test]
    fn sst2_labels_track_polarity() {
        let v = Vocab::standard();
        let data = generate(Task::Sst2, &v, GenOpts { n_train: 40, ..Default::default() });
        for ex in &data.train {
            let text = v.decode(&ex.context);
            if ex.label == 1 {
                assert!(text.contains("pos_a"), "{}", text);
            } else {
                assert!(text.contains("neg_a"), "{}", text);
            }
        }
    }

    #[test]
    fn prompt_false_strips_template() {
        let v = Vocab::standard();
        let with = generate(Task::Sst2, &v, GenOpts { seed: 5, n_train: 4, ..Default::default() });
        let without = generate(Task::Sst2, &v,
            GenOpts { seed: 5, n_train: 4, prompt: false, ..Default::default() });
        let t_with = v.decode(&with.train[0].context);
        let t_without = v.decode(&without.train[0].context);
        assert!(t_with.ends_with("it was"));
        assert!(!t_without.ends_with("it was"));
    }

    #[test]
    fn generation_tasks_have_answers() {
        let v = Vocab::standard();
        for &task in &[Task::Squad, Task::Drop] {
            let data = generate(task, &v, GenOpts { n_train: 10, ..Default::default() });
            for ex in &data.train {
                assert!(ex.candidates.is_empty());
                assert!(!ex.answer.is_empty());
            }
        }
    }

    #[test]
    fn squad_answer_is_in_passage() {
        let v = Vocab::standard();
        let data = generate(Task::Squad, &v, GenOpts { n_train: 20, ..Default::default() });
        for ex in &data.train {
            assert!(ex.context.contains(&ex.answer[0]));
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let v = Vocab::standard();
        let a = generate(Task::Rte, &v, GenOpts { seed: 9, ..Default::default() });
        let b = generate(Task::Rte, &v, GenOpts { seed: 9, ..Default::default() });
        assert_eq!(a.train[0].context, b.train[0].context);
        let c = generate(Task::Rte, &v, GenOpts { seed: 10, ..Default::default() });
        assert_ne!(
            (0..16).map(|i| a.train[i].context.clone()).collect::<Vec<_>>(),
            (0..16).map(|i| c.train[i].context.clone()).collect::<Vec<_>>()
        );
    }
}
