//! Sharded parameter store: the unit of multi-node MeZO replay.
//!
//! A MeZO fine-tune is reconstructible anywhere the `(seed, pgrad, lr)`
//! log is (§2.1, `storage::Trajectory`) — which makes serving many
//! fine-tunes cheap *if* the parameter vector itself can be partitioned
//! across workers. This module is that partition:
//!
//! * [`ShardPlan`] deterministically splits a [`ParamStore`]'s global
//!   coordinate space `[0, n_params)` into K contiguous shards —
//!   tensor-aligned where a tensor boundary lies close to the ideal cut,
//!   coordinate-split where a tensor genuinely straddles it — and stamps
//!   the whole structure with a chained-splitmix64 digest (the same
//!   construction as [`SparseMask::digest`](crate::zkernel::SparseMask)).
//! * [`ShardedStore`] holds one detached buffer per shard segment,
//!   scattered from / gathered back to a dense store bitwise.
//! * [`ShardManifest`] is the "MZT3" digest record shipped next to a
//!   trajectory so a worker can refuse a mismatched plan loudly before
//!   touching a single coordinate.
//!
//! The bit-exactness story is the [`crate::zkernel`] determinism contract
//! promoted to an API: every kernel is pure per coordinate in its own
//! *global* z index, so running a kernel over the `[lo, hi)` slice of a
//! tensor with the counter offset advanced by `lo` produces exactly the
//! `[lo, hi)` slice of the dense result — the same argument that makes
//! thread-chunking invariant. A shard worker therefore replays or steps
//! its slice independently (`ZEngine::*_shard`,
//! `storage::Trajectory::replay_sharded`,
//! `optim::mezo::MezoSgd::shard` / `optim::fzoo::Fzoo::shard`) and a
//! gather after K-way sharded replay is `to_bits()`-identical to the
//! dense run (`tests/properties.rs`).
//!
//! ```
//! use mezo::model::meta::TensorDesc;
//! use mezo::model::params::ParamStore;
//! use mezo::shard::{ShardPlan, ShardedStore};
//! let mut p = ParamStore::from_specs(vec![
//!     TensorDesc { name: "w1".into(), shape: vec![300], dtype: "f32".into() },
//!     TensorDesc { name: "w2".into(), shape: vec![200], dtype: "f32".into() },
//! ]);
//! p.init(7);
//! let plan = ShardPlan::new(&p, 4).unwrap();
//! assert_eq!(plan.n_shards(), 4);
//! // scatter -> gather is a bitwise round trip
//! let sharded = ShardedStore::scatter(&plan, &p).unwrap();
//! let mut q = ParamStore::from_specs(p.specs.clone());
//! sharded.gather_into(&mut q).unwrap();
//! assert_eq!(p.data, q.data);
//! ```

use crate::model::params::ParamStore;
use crate::rng::splitmix64;
use anyhow::{bail, Result};
use std::io::{Read, Write};
use std::path::Path;

/// One contiguous sub-range of a single tensor — the intersection of a
/// shard's global range with that tensor's coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    /// index of the tensor in the store's spec order
    pub tensor: usize,
    /// first tensor-local coordinate (inclusive)
    pub lo: usize,
    /// one past the last tensor-local coordinate
    pub hi: usize,
}

impl Segment {
    /// Coordinates in the segment.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    /// Whether the segment covers no coordinates.
    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }
}

/// One shard: a contiguous slice `[start, end)` of the global coordinate
/// space, decomposed into per-tensor [`Segment`]s.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    /// first global coordinate (inclusive)
    pub start: u64,
    /// one past the last global coordinate
    pub end: u64,
    /// the tensor sub-ranges `[start, end)` decomposes into, in tensor
    /// order (empty for an empty shard)
    pub segments: Vec<Segment>,
}

impl Shard {
    /// Global coordinates the shard owns.
    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    /// Whether the shard owns no coordinates (only possible in degenerate
    /// plans, e.g. more shards than parameters).
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// A deterministic K-way partition of a [`ParamStore`]'s global coordinate
/// space, with structural digests for the whole plan and for every shard.
/// See the [module docs](self) for the cut rule and the bit-exactness
/// contract.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPlan {
    /// tensor names, in the store's spec order (part of the digest: a
    /// plan is bound to one parameter ABI)
    names: Vec<String>,
    /// tensor lengths, parallel to `names`
    lens: Vec<usize>,
    /// global flat offset of each tensor (the z-counter base)
    offsets: Vec<u64>,
    /// the K shards, contiguous and covering `[0, total)`
    shards: Vec<Shard>,
    /// chained-splitmix64 digest of the whole structure
    digest: u64,
    /// per-shard structural digests, parallel to `shards`
    shard_digests: Vec<u64>,
}

impl ShardPlan {
    /// Partition `params` into `n_shards` contiguous shards.
    ///
    /// Cut rule, deterministic in `(store geometry, n_shards)`: the k-th
    /// cut starts at the ideal point `total·k/K`; if an *interior* tensor
    /// boundary lies within a quarter of the ideal shard width of it, the
    /// cut snaps there (tensor-aligned shards ship whole tensors), else
    /// the straddled tensor is coordinate-split at the ideal point. Cuts
    /// are clamped monotone, so degenerate inputs (more shards than
    /// parameters) yield empty trailing shards rather than an error.
    pub fn new(params: &ParamStore, n_shards: usize) -> Result<ShardPlan> {
        if n_shards == 0 {
            bail!("ShardPlan: shard count must be > 0");
        }
        let names: Vec<String> = params.specs.iter().map(|s| s.name.clone()).collect();
        let lens: Vec<usize> = params.data.iter().map(|d| d.len()).collect();
        let offsets = params.offsets.clone();
        let total: u64 = lens.iter().map(|&l| l as u64).sum();

        let mut cuts: Vec<u64> = Vec::with_capacity(n_shards + 1);
        cuts.push(0);
        let tol = total / n_shards as u64 / 4;
        for k in 1..n_shards {
            let prev = *cuts.last().unwrap();
            let ideal = (total as u128 * k as u128 / n_shards as u128) as u64;
            let snapped = nearest_interior_boundary(&offsets, ideal)
                .filter(|&b| b > prev && b < total && b.abs_diff(ideal) <= tol);
            cuts.push(snapped.unwrap_or(ideal).clamp(prev, total));
        }
        cuts.push(total);

        let shards: Vec<Shard> =
            cuts.windows(2).map(|w| build_shard(w[0], w[1], &offsets, &lens)).collect();

        let (digest, shard_digests) = compute_digests(&names, &lens, &shards);
        Ok(ShardPlan { names, lens, offsets, shards, digest, shard_digests })
    }

    /// Rebuild a plan from its structural parts — tensor ABI plus the
    /// shard ranges — re-deriving offsets, segments and digests exactly
    /// as [`ShardPlan::new`] does. This is how a plan crosses the wire
    /// (`wire::frame`): the sender ships only `(names, lens, ranges,
    /// digest)` and the receiver reconstructs, so a peer whose
    /// derivation disagrees produces a different digest and fails the
    /// frame's embedded-digest check loudly.
    ///
    /// Errors on structurally invalid ranges: no shards at all, a range
    /// with `start > end`, a first shard not starting at 0, a gap or
    /// overlap between consecutive shards, or a last shard not ending at
    /// the tensor total.
    pub fn from_parts(
        names: Vec<String>,
        lens: Vec<usize>,
        ranges: &[(u64, u64)],
    ) -> Result<ShardPlan> {
        if names.len() != lens.len() {
            bail!("ShardPlan: {} names but {} lengths", names.len(), lens.len());
        }
        if ranges.is_empty() {
            bail!("ShardPlan: shard count must be > 0");
        }
        let mut offsets = Vec::with_capacity(lens.len());
        let mut total = 0u64;
        for &len in &lens {
            offsets.push(total);
            total = total
                .checked_add(len as u64)
                .ok_or_else(|| anyhow::anyhow!("ShardPlan: tensor lengths overflow u64"))?;
        }
        if ranges[0].0 != 0 {
            bail!("ShardPlan: first shard starts at {}, not 0", ranges[0].0);
        }
        if ranges[ranges.len() - 1].1 != total {
            bail!(
                "ShardPlan: last shard ends at {}, but the tensors total {}",
                ranges[ranges.len() - 1].1,
                total
            );
        }
        for (k, &(start, end)) in ranges.iter().enumerate() {
            if start > end {
                bail!("ShardPlan: shard {} range [{}, {}) is inverted", k, start, end);
            }
            if k > 0 && ranges[k - 1].1 != start {
                bail!(
                    "ShardPlan: shard {} starts at {} but shard {} ends at {} — \
                     shards must tile [0, total) contiguously",
                    k,
                    start,
                    k - 1,
                    ranges[k - 1].1
                );
            }
        }
        let shards: Vec<Shard> =
            ranges.iter().map(|&(s, e)| build_shard(s, e, &offsets, &lens)).collect();
        let (digest, shard_digests) = compute_digests(&names, &lens, &shards);
        Ok(ShardPlan { names, lens, offsets, shards, digest, shard_digests })
    }

    /// Number of shards in the plan.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Number of tensors the plan is defined over (== the store's).
    pub fn n_tensors(&self) -> usize {
        self.names.len()
    }

    /// Total coordinates across the whole plan.
    pub fn total(&self) -> u64 {
        self.shards.last().map(|s| s.end).unwrap_or(0)
    }

    /// All shards, in global-coordinate order.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// One shard by index.
    pub fn shard(&self, k: usize) -> &Shard {
        &self.shards[k]
    }

    /// Global flat offsets of the tensors (the z-counter bases the shard
    /// kernels index from).
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Tensor names, in the store's spec order (the plan's ABI half;
    /// what [`ShardPlan::from_parts`] reconstructs a peer's plan from).
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Tensor lengths, parallel to [`ShardPlan::names`].
    pub fn lens(&self) -> &[usize] {
        &self.lens
    }

    /// Order- and structure-sensitive digest of the whole plan: tensor
    /// names and lengths, shard count, every shard's range and segments.
    /// Any change — a renamed tensor, a moved cut, a different K —
    /// changes the digest.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    /// Structural digest of one shard (range + segments).
    pub fn shard_digest(&self, k: usize) -> u64 {
        self.shard_digests[k]
    }

    /// The MZT3 manifest for this plan: the record shipped next to a
    /// trajectory so replaying workers can verify plan identity.
    pub fn manifest(&self) -> ShardManifest {
        ShardManifest { plan_digest: self.digest, shard_digests: self.shard_digests.clone() }
    }

    /// Check the plan is applicable to a store: same tensor names and
    /// lengths, in the same order. A plan built against a different ABI
    /// would mis-address z counters, so mismatch is an error.
    ///
    /// Generic over [`Theta`](crate::model::Theta): only the tensor ABI
    /// (names + lengths) is consulted, so a plan validates against dense
    /// and quantized stores alike.
    pub fn validate<T: crate::model::Theta + ?Sized>(&self, params: &T) -> Result<()> {
        let specs = params.specs();
        if self.names.len() != specs.len() {
            bail!(
                "ShardPlan: plan covers {} tensors, store has {}",
                self.names.len(),
                specs.len()
            );
        }
        for (ti, (name, &len)) in self.names.iter().zip(&self.lens).enumerate() {
            if specs[ti].name != *name {
                bail!(
                    "ShardPlan: tensor {} is '{}' in the plan but '{}' in the store",
                    ti,
                    name,
                    specs[ti].name
                );
            }
            if specs[ti].len() != len {
                bail!(
                    "ShardPlan: tensor '{}' has {} coordinates in the plan but {} in the store",
                    name,
                    len,
                    specs[ti].len()
                );
            }
        }
        Ok(())
    }

    /// Indices of the named tensors, in `names` order; errors on a name
    /// the plan does not know (replay resolves a trajectory's trainable
    /// list through this without needing a dense store).
    pub fn indices_of(&self, names: &[String]) -> Result<Vec<usize>> {
        let mut out = Vec::with_capacity(names.len());
        for n in names {
            match self.names.iter().position(|p| p == n) {
                Some(i) => out.push(i),
                None => bail!("ShardPlan: no tensor named '{}'", n),
            }
        }
        Ok(out)
    }

    /// Every segment whose tensor is flagged in `keep`, in shard-major
    /// order — the walk every shard-scoped parameter pass does (build
    /// `keep` with [`trainable_flags`]).
    pub fn segments_where<'a>(
        &'a self,
        keep: &'a [bool],
    ) -> impl Iterator<Item = &'a Segment> + 'a {
        self.shards.iter().flat_map(|s| &s.segments).filter(move |seg| keep[seg.tensor])
    }
}

/// Per-tensor membership flags of a tensor-index set — what
/// [`ShardPlan::segments_where`] filters by (the shard-scoped optimizer
/// and replay paths build this from their trainable lists).
pub fn trainable_flags(n_tensors: usize, trainable: &[usize]) -> Vec<bool> {
    let mut f = vec![false; n_tensors];
    for &ti in trainable {
        f[ti] = true;
    }
    f
}

/// Decompose the global range `[start, end)` into per-tensor segments —
/// the one derivation shared by [`ShardPlan::new`] and
/// [`ShardPlan::from_parts`], so a plan rebuilt from its wire parts is
/// structurally (and therefore digest-) identical to the original.
fn build_shard(start: u64, end: u64, offsets: &[u64], lens: &[usize]) -> Shard {
    let mut segments = Vec::new();
    for (ti, (&off, &len)) in offsets.iter().zip(lens).enumerate() {
        let t_end = off + len as u64;
        let lo = start.max(off);
        let hi = end.min(t_end);
        if lo < hi {
            segments.push(Segment { tensor: ti, lo: (lo - off) as usize, hi: (hi - off) as usize });
        }
    }
    Shard { start, end, segments }
}

/// The interior tensor boundary (a tensor's global start offset, excluding
/// 0) nearest to `ideal`; ties break toward the lower boundary. `None`
/// when there is no interior boundary (zero or one tensor).
fn nearest_interior_boundary(offsets: &[u64], ideal: u64) -> Option<u64> {
    let interior = match offsets.split_first() {
        Some((_, rest)) if !rest.is_empty() => rest,
        _ => return None,
    };
    let i = interior.partition_point(|&b| b < ideal);
    let lo = i.checked_sub(1).map(|j| interior[j]);
    let hi = interior.get(i).copied();
    match (lo, hi) {
        (Some(a), Some(b)) => Some(if ideal - a <= b - ideal { a } else { b }),
        (Some(a), None) => Some(a),
        (None, b) => b,
    }
}

/// The chained splitmix64 walk behind [`ShardPlan::digest`] /
/// [`ShardPlan::shard_digest`] — same construction as the sparse-mask
/// digest, extended with the tensor ABI (names + lengths).
fn compute_digests(names: &[String], lens: &[usize], shards: &[Shard]) -> (u64, Vec<u64>) {
    const GOLD: u64 = 0x9E3779B97F4A7C15;
    let shard_digests: Vec<u64> = shards
        .iter()
        .enumerate()
        .map(|(k, s)| {
            let mut h = splitmix64(0x0005_44A2_u64 ^ (k as u64).wrapping_mul(GOLD));
            h = splitmix64(h ^ s.start);
            h = splitmix64(h ^ s.end.wrapping_mul(GOLD));
            for seg in &s.segments {
                h = splitmix64(h ^ (seg.tensor as u64).wrapping_mul(GOLD));
                h = splitmix64(h ^ seg.lo as u64);
                h = splitmix64(h ^ (seg.hi as u64).wrapping_mul(GOLD));
            }
            h
        })
        .collect();
    let mut h = splitmix64(0x0005_44A9_u64 ^ shards.len() as u64);
    h = splitmix64(h ^ names.len() as u64);
    for (name, &len) in names.iter().zip(lens) {
        h = splitmix64(h ^ name.len() as u64);
        for &b in name.as_bytes() {
            h = splitmix64(h ^ b as u64);
        }
        h = splitmix64(h ^ (len as u64).wrapping_mul(GOLD));
    }
    for &sd in &shard_digests {
        h = splitmix64(h ^ sd);
    }
    (h, shard_digests)
}

/// The per-shard parameter slices of one [`ShardPlan`] over one store:
/// what a K-worker deployment would spread across K machines, held
/// in-process here. Detached buffers — mutating a dense store after
/// scattering does not move the shards, and vice versa, until an explicit
/// [`ShardedStore::gather_into`].
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedStore {
    plan: ShardPlan,
    /// `data[k][si]` = the buffer for `plan.shard(k).segments[si]`
    data: Vec<Vec<Vec<f32>>>,
}

impl ShardedStore {
    /// Copy every shard segment's slice out of a dense store (validated
    /// against the plan first).
    pub fn scatter(plan: &ShardPlan, params: &ParamStore) -> Result<ShardedStore> {
        plan.validate(params)?;
        let data = plan
            .shards
            .iter()
            .map(|s| {
                s.segments
                    .iter()
                    .map(|seg| params.data[seg.tensor][seg.lo..seg.hi].to_vec())
                    .collect()
            })
            .collect();
        Ok(ShardedStore { plan: plan.clone(), data })
    }

    /// The plan the store was scattered under.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Copy every shard segment back into a dense store (validated
    /// against the plan first). Shards partition the coordinate space, so
    /// this rewrites every coordinate of every tensor.
    pub fn gather_into(&self, params: &mut ParamStore) -> Result<()> {
        self.plan.validate(params)?;
        for (shard, bufs) in self.plan.shards.iter().zip(&self.data) {
            for (seg, buf) in shard.segments.iter().zip(bufs) {
                params.data[seg.tensor][seg.lo..seg.hi].copy_from_slice(buf);
            }
        }
        Ok(())
    }

    /// Borrow one segment's buffer.
    pub fn segment(&self, shard: usize, si: usize) -> &[f32] {
        &self.data[shard][si]
    }

    /// Visit every `(segment, buffer)` pair of one shard mutably — the
    /// shape a shard-local replay pass walks.
    pub fn segments_mut(
        &mut self,
        shard: usize,
    ) -> impl Iterator<Item = (&Segment, &mut Vec<f32>)> {
        self.plan.shards[shard].segments.iter().zip(self.data[shard].iter_mut())
    }

    /// Total coordinates held across all shards (== the store's
    /// `n_params` the plan was built against).
    pub fn n_values(&self) -> usize {
        self.data.iter().flatten().map(|b| b.len()).sum()
    }
}

/// The MZT3 manifest: the shard-plan digest plus every per-shard digest,
/// shipped next to a trajectory so a replaying worker can verify — before
/// touching a single coordinate — that its local [`ShardPlan`] is the one
/// the log's publisher partitioned under. Binary format:
/// `"MZT3" | plan_digest u64 | n_shards u32 | (shard_digest u64)*`,
/// little-endian.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardManifest {
    /// [`ShardPlan::digest`] of the publishing plan
    pub plan_digest: u64,
    /// [`ShardPlan::shard_digest`] of every shard, in shard order
    pub shard_digests: Vec<u64>,
}

impl ShardManifest {
    /// Number of shards the manifest describes.
    pub fn n_shards(&self) -> usize {
        self.shard_digests.len()
    }

    /// Verify a local plan against the manifest; any mismatch — a
    /// different K, different cuts, a different tensor ABI — fails
    /// loudly, because replaying under the wrong plan would scatter
    /// updates onto the wrong coordinates.
    pub fn check(&self, plan: &ShardPlan) -> Result<()> {
        if self.plan_digest != plan.digest() {
            bail!(
                "ShardManifest: plan digest {:#018x} does not match the manifest's {:#018x} — \
                 this is not the shard plan the trajectory was published under",
                plan.digest(),
                self.plan_digest
            );
        }
        if self.shard_digests != plan.shard_digests {
            bail!(
                "ShardManifest: per-shard digests disagree with the plan despite a matching \
                 plan digest — corrupt manifest"
            );
        }
        Ok(())
    }

    /// Write the manifest to disk (magic `"MZT3"`; see the type docs for
    /// the layout).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"MZT3")?;
        f.write_all(&self.plan_digest.to_le_bytes())?;
        f.write_all(&(self.shard_digests.len() as u32).to_le_bytes())?;
        for &d in &self.shard_digests {
            f.write_all(&d.to_le_bytes())?;
        }
        Ok(())
    }

    /// Read a manifest written by [`ShardManifest::save`].
    pub fn load(path: &Path) -> std::io::Result<ShardManifest> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"MZT3" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad shard manifest magic",
            ));
        }
        let mut u64b = [0u8; 8];
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u64b)?;
        let plan_digest = u64::from_le_bytes(u64b);
        f.read_exact(&mut u32b)?;
        let n = u32::from_le_bytes(u32b) as usize;
        // the count is untrusted input: cap the pre-allocation so a
        // corrupt header fails on the short read below, not on a huge
        // up-front allocation
        let mut shard_digests = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            f.read_exact(&mut u64b)?;
            shard_digests.push(u64::from_le_bytes(u64b));
        }
        Ok(ShardManifest { plan_digest, shard_digests })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;

    fn store(lens: &[usize]) -> ParamStore {
        let specs = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| TensorDesc {
                name: format!("t{}", i),
                shape: vec![n],
                dtype: "f32".into(),
            })
            .collect();
        let mut p = ParamStore::from_specs(specs);
        p.init(5);
        p
    }

    /// every plan must cover [0, total) contiguously, shard segments must
    /// reconstruct the shard's range exactly, and segments must respect
    /// tensor bounds
    fn assert_plan_covers(plan: &ShardPlan, lens: &[usize]) {
        let total: u64 = lens.iter().map(|&l| l as u64).sum();
        assert_eq!(plan.total(), total);
        assert_eq!(plan.shards().first().map(|s| s.start), Some(0));
        assert_eq!(plan.shards().last().map(|s| s.end), Some(total));
        for w in plan.shards().windows(2) {
            assert_eq!(w[0].end, w[1].start, "shards contiguous");
        }
        for s in plan.shards() {
            let seg_total: u64 = s.segments.iter().map(|g| g.len() as u64).sum();
            assert_eq!(seg_total, s.len(), "segments reconstruct the shard range");
            for g in &s.segments {
                assert!(g.lo < g.hi && g.hi <= lens[g.tensor]);
            }
        }
    }

    #[test]
    fn plans_cover_the_space_for_many_shapes_and_counts() {
        for lens in [vec![10], vec![64, 68, 72, 100], vec![3, 3, 3], vec![1000, 7, 2000]] {
            let p = store(&lens);
            for k in [1usize, 2, 3, 4, 8] {
                let plan = ShardPlan::new(&p, k).unwrap();
                assert_eq!(plan.n_shards(), k);
                assert_plan_covers(&plan, &lens);
            }
        }
    }

    #[test]
    fn cuts_snap_to_nearby_tensor_boundaries() {
        // total 200, K=2: ideal cut 100, tensor boundary at 90 is within
        // the quarter-width tolerance (25) -> shard 0 is exactly tensor 0
        let p = store(&[90, 110]);
        let plan = ShardPlan::new(&p, 2).unwrap();
        assert_eq!(plan.shard(0).end, 90);
        assert_eq!(plan.shard(0).segments, vec![Segment { tensor: 0, lo: 0, hi: 90 }]);
        assert_eq!(plan.shard(1).segments, vec![Segment { tensor: 1, lo: 0, hi: 110 }]);
    }

    #[test]
    fn straddling_tensors_are_coordinate_split_at_the_ideal_cut() {
        // one tensor, no interior boundary to snap to: the tensor splits
        let p = store(&[200]);
        let plan = ShardPlan::new(&p, 2).unwrap();
        assert_eq!(plan.shard(0).segments, vec![Segment { tensor: 0, lo: 0, hi: 100 }]);
        assert_eq!(plan.shard(1).segments, vec![Segment { tensor: 0, lo: 100, hi: 200 }]);
        // a far-away boundary does NOT snap: total 1000, ideal 500,
        // boundary at 100 is outside tol 125 -> coordinate split at 500
        let p = store(&[100, 900]);
        let plan = ShardPlan::new(&p, 2).unwrap();
        assert_eq!(plan.shard(0).end, 500);
        assert_eq!(
            plan.shard(0).segments,
            vec![Segment { tensor: 0, lo: 0, hi: 100 }, Segment { tensor: 1, lo: 0, hi: 400 }]
        );
    }

    #[test]
    fn zero_tensor_store_plans_to_empty_shards() {
        let p = ParamStore::from_specs(Vec::new());
        let plan = ShardPlan::new(&p, 3).unwrap();
        assert_eq!(plan.n_shards(), 3);
        assert_eq!(plan.total(), 0);
        assert!(plan.shards().iter().all(|s| s.is_empty() && s.segments.is_empty()));
        let sharded = ShardedStore::scatter(&plan, &p).unwrap();
        assert_eq!(sharded.n_values(), 0);
    }

    #[test]
    fn degenerate_plans_get_empty_trailing_shards_and_zero_shards_error() {
        let p = store(&[3]);
        let plan = ShardPlan::new(&p, 8).unwrap();
        assert_eq!(plan.n_shards(), 8);
        let held: u64 = plan.shards().iter().map(|s| s.len()).sum();
        assert_eq!(held, 3);
        assert!(plan.shards().iter().any(|s| s.is_empty()));
        // empty shards still scatter/gather
        let sharded = ShardedStore::scatter(&plan, &p).unwrap();
        let mut q = store(&[3]);
        q.data[0].iter_mut().for_each(|x| *x = f32::NAN);
        sharded.gather_into(&mut q).unwrap();
        assert_eq!(p.data, q.data);
        assert!(ShardPlan::new(&p, 0).is_err());
    }

    #[test]
    fn from_parts_rebuilds_plans_digest_identically() {
        for lens in [vec![10], vec![64, 68, 72, 100], vec![3], vec![1000, 7, 2000]] {
            let p = store(&lens);
            for k in [1usize, 2, 4, 8] {
                let plan = ShardPlan::new(&p, k).unwrap();
                let ranges: Vec<(u64, u64)> =
                    plan.shards().iter().map(|s| (s.start, s.end)).collect();
                let back = ShardPlan::from_parts(
                    plan.names().to_vec(),
                    plan.lens().to_vec(),
                    &ranges,
                )
                .unwrap();
                assert_eq!(back, plan, "structural identity, lens {:?} k {}", lens, k);
                assert_eq!(back.digest(), plan.digest());
                assert_eq!(back.offsets(), plan.offsets());
            }
        }
    }

    #[test]
    fn from_parts_rejects_non_tiling_ranges() {
        let names = vec!["a".into(), "b".into()];
        let lens = vec![100usize, 100];
        let bad: &[(&str, Vec<(u64, u64)>)] = &[
            ("no shards", vec![]),
            ("first not at 0", vec![(5, 200)]),
            ("last short of total", vec![(0, 150)]),
            ("gap", vec![(0, 80), (90, 200)]),
            ("overlap", vec![(0, 120), (110, 200)]),
            ("inverted", vec![(0, 200), (200, 150)]),
        ];
        for (what, ranges) in bad {
            assert!(
                ShardPlan::from_parts(names.clone(), lens.clone(), ranges).is_err(),
                "{} must be rejected",
                what
            );
        }
        // empty trailing shards ARE valid structure (degenerate plans)
        let ok = ShardPlan::from_parts(vec!["a".into()], vec![3], &[(0, 2), (2, 3), (3, 3)]);
        assert!(ok.unwrap().shard(2).is_empty());
    }

    #[test]
    fn digest_is_structure_and_abi_sensitive() {
        let p = store(&[100, 100]);
        let a = ShardPlan::new(&p, 2).unwrap();
        let b = ShardPlan::new(&p, 4).unwrap();
        assert_ne!(a.digest(), b.digest(), "different K");
        assert_eq!(a.digest(), ShardPlan::new(&p, 2).unwrap().digest(), "deterministic");
        let q = store(&[100, 101]);
        assert_ne!(a.digest(), ShardPlan::new(&q, 2).unwrap().digest(), "different lengths");
        // same shapes, different names -> different ABI -> different digest
        let mut specs = p.specs.clone();
        specs[1].name = "renamed".into();
        let r = ParamStore::from_specs(specs);
        assert_ne!(a.digest(), ShardPlan::new(&r, 2).unwrap().digest(), "different names");
        // per-shard digests are pairwise distinct for non-degenerate plans
        assert_ne!(a.shard_digest(0), a.shard_digest(1));
    }

    #[test]
    fn validate_rejects_mismatched_stores_and_indices_resolve_names() {
        let p = store(&[50, 60]);
        let plan = ShardPlan::new(&p, 2).unwrap();
        assert!(plan.validate(&p).is_ok());
        let err = plan.validate(&store(&[50])).unwrap_err();
        assert!(err.to_string().contains("tensors"), "{}", err);
        let err = plan.validate(&store(&[50, 61])).unwrap_err();
        assert!(err.to_string().contains("coordinates"), "{}", err);
        assert_eq!(plan.indices_of(&["t1".into(), "t0".into()]).unwrap(), vec![1, 0]);
        assert!(plan.indices_of(&["nope".into()]).is_err());
    }

    #[test]
    fn scatter_gather_roundtrip_is_bitwise() {
        let p = store(&[300, 7, 129]);
        for k in [1usize, 2, 4] {
            let plan = ShardPlan::new(&p, k).unwrap();
            let sharded = ShardedStore::scatter(&plan, &p).unwrap();
            assert_eq!(sharded.n_values(), p.n_params());
            let mut q = store(&[300, 7, 129]);
            q.data.iter_mut().flatten().for_each(|x| *x = -9.0);
            sharded.gather_into(&mut q).unwrap();
            for (a, b) in p.data.iter().flatten().zip(q.data.iter().flatten()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            // scatter refuses a mismatched store
            assert!(ShardedStore::scatter(&plan, &store(&[300, 7])).is_err());
        }
    }

    #[test]
    fn manifest_roundtrips_and_guards_plan_identity() {
        let p = store(&[128, 64]);
        let plan = ShardPlan::new(&p, 3).unwrap();
        let manifest = plan.manifest();
        assert_eq!(manifest.n_shards(), 3);
        assert!(manifest.check(&plan).is_ok());
        let err = manifest.check(&ShardPlan::new(&p, 2).unwrap()).unwrap_err();
        assert!(err.to_string().contains("plan digest"), "{}", err);

        let path = std::env::temp_dir().join("mezo_shard_manifest_test.mzt3");
        manifest.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..4], b"MZT3");
        let back = ShardManifest::load(&path).unwrap();
        assert_eq!(back, manifest);
        std::fs::remove_file(&path).ok();
        // a corrupt magic is rejected
        let bad = std::env::temp_dir().join("mezo_shard_manifest_bad.mzt3");
        std::fs::write(&bad, b"MZTXxxxxxxxx").unwrap();
        assert!(ShardManifest::load(&bad).is_err());
        std::fs::remove_file(&bad).ok();
    }
}
