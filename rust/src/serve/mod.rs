//! Multi-tenant trajectory serving: N fine-tunes as logs over ONE base θ.
//!
//! The deepest systems consequence of MeZO's seed-replay determinism
//! (§2.1 "Storage Efficiency"): a per-user fine-tune is not a parameter
//! copy, it is a few KB of `(seed, pgrad, lr)` records. A serving tier
//! therefore needs to hold exactly one base θ ([`ServeBase`]: a dense
//! [`ParamStore`], or a block-quantized [`QuantStore`] 4–8× smaller) plus
//! one [`Trajectory`] log per user, and *materialize* a user's parameters on
//! demand by replaying the log over a copy of the base — dense
//! ([`Trajectory::replay_batched`]), sparse SensZOQ
//! ([`Trajectory::replay_masked`]), or K-way sharded
//! ([`Trajectory::replay_sharded`]) — all of which are pinned
//! `to_bits()`-identical to the training run at any thread count and SIMD
//! tier by the zkernel determinism contract.
//!
//! [`ServeStore`] is that tier:
//!
//! * **One refcounted base.** The base store lives behind an [`Arc`];
//!   users whose log is still empty are served a dense base itself — zero
//!   copies, pure refcount traffic. (A quantized base cannot be handed
//!   out raw, so its empty-log requests materialize a dequantized copy
//!   through the cache like any other request.)
//! * **Clone-on-materialize with buffer recycling.** A user with records
//!   gets a private copy of the base (the "copy" of copy-on-write), but
//!   the copy's allocations are recycled: evicted materializations whose
//!   `Arc` refcount has dropped to one return their buffers to a free
//!   pool, and the next materialization reuses them via
//!   [`ParamStore::copy_from`] instead of allocating multi-MB tensors.
//! * **A bounded LRU cache.** Materialized stores are cached up to
//!   `cache_capacity` entries; a cache hit is a refcount bump. Entries
//!   remember the log length they were materialized at, so appending
//!   records to a user's log ([`ServeStore::append_steps`]) makes the
//!   cached entry stale and the next request re-materializes. Capacity 0
//!   disables caching entirely (every request replays) without changing
//!   any result bits.
//! * **Digest guards survive the cache.** A sparse log (one tagged with a
//!   mask digest) refuses dense materialization, and a mask with the
//!   wrong digest is rejected by [`Trajectory::replay_masked`]'s own
//!   check — errors are never cached, so the guard fires on every
//!   request, hit path or miss path.
//!
//! The synthetic Zipf load harness lives in `examples/serve_scale.rs`
//! (materializations/sec, cache hit rate, p50/p99 latency into
//! `BENCH_serving.json`); the bitwise properties — cached == fresh dense
//! replay under arbitrary eviction orders, capacities 0/1/N, concurrent
//! same-user requests — are pinned in `tests/serving.rs` and re-run under
//! the `MEZO_THREADS` matrix by `scripts/verify.sh`.

use crate::model::params::ParamStore;
use crate::model::quant::QuantStore;
use crate::model::Theta;
use crate::obs::{self, metrics};
use crate::shard::{ShardPlan, ShardedStore};
use crate::storage::Trajectory;
use crate::zkernel::{SparseMask, ZEngine};
use anyhow::{bail, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// One tenant: a trajectory log plus how to replay it.
///
/// The mask / shard attachments mirror the optimizer scoping modes: a
/// masked run must ship the mask whose digest its log carries, a sharded
/// run may attach the plan its workers used (materialization then runs
/// shard-by-shard, bitwise the dense result). `seeds_per_step > 0`
/// selects the fused seed-batched replay (one pass over θ per step of
/// `seeds_per_step` records — e.g. an FZOO run's n); 0 replays
/// sequentially. Both are bit-identical by the kernel contract.
#[derive(Debug, Clone)]
pub struct UserLog {
    /// the user's `(seed, pgrad, lr)` fine-tune log
    pub log: Trajectory,
    /// the SensZOQ mask a sparse log was recorded under
    pub mask: Option<Arc<SparseMask>>,
    /// the shard plan to decompose replay over (dense result, K dispatches)
    pub shard: Option<Arc<ShardPlan>>,
    /// records per fused replay batch; 0 = sequential replay
    pub seeds_per_step: usize,
}

impl UserLog {
    /// A dense log, replayed sequentially.
    pub fn dense(log: Trajectory) -> UserLog {
        UserLog { log, mask: None, shard: None, seeds_per_step: 0 }
    }

    /// A dense log replayed in fused batches of `seeds_per_step` records
    /// (must divide the log length at materialization time).
    pub fn dense_batched(log: Trajectory, seeds_per_step: usize) -> UserLog {
        UserLog { log, mask: None, shard: None, seeds_per_step }
    }

    /// A sparse log with its mask. The digest is checked at replay, not
    /// here, so a mismatched mask fails loudly on every request.
    pub fn masked(log: Trajectory, mask: Arc<SparseMask>) -> UserLog {
        UserLog { log, mask: Some(mask), shard: None, seeds_per_step: 0 }
    }

    /// A dense log materialized through a K-way shard plan (per-segment
    /// dispatches — what a worker fleet would run — gathered back dense).
    pub fn sharded(log: Trajectory, plan: Arc<ShardPlan>) -> UserLog {
        UserLog { log, mask: None, shard: Some(plan), seeds_per_step: 0 }
    }
}

/// Serving counters, reset with [`ServeStore::reset_stats`].
///
/// Per-store and exact (plain fields, not gated) — tests pin precise
/// tuples against them. Each increment is mirrored into the process-wide
/// [`crate::obs`] registry (`mezo_serve_*`), which additionally times the
/// hit and materialize paths at span level.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// total [`ServeStore::get`] calls
    pub requests: usize,
    /// requests answered from the cache (refcount bump only)
    pub hits: usize,
    /// requests that had to materialize (includes stale refreshes)
    pub misses: usize,
    /// cache entries discarded because the user's log grew underneath them
    pub stale: usize,
    /// entries discarded to respect the capacity bound
    pub evictions: usize,
    /// full log replays performed
    pub materializations: usize,
    /// empty-log requests served as the refcounted base itself (no copy)
    pub base_served: usize,
}

impl ServeStats {
    /// Cache hit rate over the cacheable traffic (hits + misses);
    /// base-served requests never touch the cache and are excluded.
    pub fn hit_rate(&self) -> f64 {
        let denom = self.hits + self.misses;
        if denom == 0 {
            0.0
        } else {
            self.hits as f64 / denom as f64
        }
    }
}

/// The shared θ every materialization starts from: a dense f32 store, or
/// a block-quantized SensZOQ [`QuantStore`] (int8/int4 codes + per-block
/// scales + the f32 overlay of its masked coordinates). Served tenants
/// always receive DENSE parameters — a quantized base is dequantized
/// into the materialization buffer before the log replays — and because
/// the overlay splices masked coordinates back exactly, a masked log
/// served from a quantized base stays `to_bits()`-identical to the
/// training run on every masked coordinate (pinned in `tests/quant.rs`).
#[derive(Debug, Clone)]
pub enum ServeBase {
    /// a dense f32 base store
    Dense(Arc<ParamStore>),
    /// a block-quantized base store (4–8× smaller per replica)
    Quant(Arc<QuantStore>),
}

impl ServeBase {
    /// The base as a [`Theta`] — shapes, names and offsets for the
    /// admission-time geometry guards.
    fn theta(&self) -> &dyn Theta {
        match self {
            ServeBase::Dense(p) => p.as_ref(),
            ServeBase::Quant(q) => q.as_ref(),
        }
    }

    /// A fresh dense buffer holding the base's values (a clone for a
    /// dense base, a full dequantization for a quantized one).
    fn to_param_store(&self) -> ParamStore {
        match self {
            ServeBase::Dense(p) => p.as_ref().clone(),
            ServeBase::Quant(q) => q.to_dense(),
        }
    }
}

struct CacheEntry {
    store: Arc<ParamStore>,
    /// log length at materialization; a longer log means stale
    version: usize,
    /// recency stamp (key into the LRU order map)
    tick: u64,
}

/// The multi-tenant serving store: one refcounted dense base, N per-user
/// logs, an LRU cache of materialized stores with recycled buffers.
///
/// ```
/// use mezo::model::meta::TensorDesc;
/// use mezo::model::params::ParamStore;
/// use mezo::optim::mezo::StepRecord;
/// use mezo::serve::{ServeConfig, ServeStore, UserLog};
/// use mezo::storage::Trajectory;
/// let mut base = ParamStore::from_specs(vec![
///     TensorDesc { name: "w".into(), shape: vec![64], dtype: "f32".into() },
/// ]);
/// base.init(7);
/// let mut serve = ServeStore::new(base, ServeConfig { cache_capacity: 8 });
/// let recs = [StepRecord { seed: 1, pgrad: 0.5, lr: 1e-2 }];
/// serve.admit(42, UserLog::dense(Trajectory::from_run(vec!["w".into()], &recs))).unwrap();
/// let served = serve.get(42).unwrap();          // miss: replays the log
/// let again = serve.get(42).unwrap();           // hit: same Arc
/// assert!(std::sync::Arc::ptr_eq(&served, &again));
/// let fresh = serve.materialize_fresh(42).unwrap();
/// assert_eq!(served.data, fresh.data);          // bitwise the fresh replay
/// ```
pub struct ServeStore {
    base: ServeBase,
    engine: ZEngine,
    users: HashMap<u64, UserLog>,
    capacity: usize,
    cache: HashMap<u64, CacheEntry>,
    /// LRU order: tick -> user; first entry is the eviction victim
    recency: BTreeMap<u64, u64>,
    tick: u64,
    /// recycled materialization buffers (clone-on-materialize reuse)
    free: Vec<ParamStore>,
    stats: ServeStats,
}

/// Construction knobs for [`ServeStore`].
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// LRU bound on cached materialized stores; 0 disables caching
    pub cache_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { cache_capacity: 64 }
    }
}

impl ServeStore {
    /// Take ownership of the dense base and serve on the process-default
    /// engine (`MEZO_THREADS` / `MEZO_SIMD` aware).
    pub fn new(base: ParamStore, cfg: ServeConfig) -> ServeStore {
        ServeStore::with_engine(base, cfg, ZEngine::default())
    }

    /// Serve from a block-quantized base ([`ServeBase::Quant`]): one
    /// [`QuantStore`] replica (4–8× smaller than dense f32) backs every
    /// tenant; materializations dequantize it into recycled dense
    /// buffers before replaying. Empty logs cannot be answered with a
    /// refcount bump here (the base is not a dense store), so they go
    /// through the cache/materialize path like any other request.
    pub fn new_quant(base: QuantStore, cfg: ServeConfig) -> ServeStore {
        ServeStore::with_base(ServeBase::Quant(Arc::new(base)), cfg, ZEngine::default())
    }

    /// As [`ServeStore::new`] on an explicit engine (thread/tier control).
    pub fn with_engine(base: ParamStore, cfg: ServeConfig, engine: ZEngine) -> ServeStore {
        ServeStore::with_base(ServeBase::Dense(Arc::new(base)), cfg, engine)
    }

    /// The fully general constructor: any [`ServeBase`], any engine.
    pub fn with_base(base: ServeBase, cfg: ServeConfig, engine: ZEngine) -> ServeStore {
        ServeStore {
            base,
            engine,
            users: HashMap::new(),
            capacity: cfg.cache_capacity,
            cache: HashMap::new(),
            recency: BTreeMap::new(),
            tick: 0,
            free: Vec::new(),
            stats: ServeStats::default(),
        }
    }

    /// The shared dense base store every materialization starts from.
    /// Panics if this store serves a quantized base — match on
    /// [`ServeStore::serve_base`] instead when the representation is not
    /// known statically.
    pub fn base(&self) -> &Arc<ParamStore> {
        match &self.base {
            ServeBase::Dense(p) => p,
            ServeBase::Quant(_) => panic!(
                "ServeStore::base: this store serves a quantized base — use serve_base()"
            ),
        }
    }

    /// The shared base — dense or quantized — every materialization
    /// starts from.
    pub fn serve_base(&self) -> &ServeBase {
        &self.base
    }

    /// Registered tenants.
    pub fn n_users(&self) -> usize {
        self.users.len()
    }

    /// Currently cached materializations.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// The LRU capacity this store was built with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Counters so far.
    pub fn stats(&self) -> ServeStats {
        self.stats
    }

    /// Zero the counters (cache content is untouched).
    pub fn reset_stats(&mut self) {
        self.stats = ServeStats::default();
    }

    /// Register (or replace) a tenant. Geometry is validated against the
    /// base up front — tensor names must exist, masks and plans must fit
    /// the base ABI — so a request can only fail on *log*-level guards
    /// (digest mismatch, batch divisibility), which are deliberately left
    /// to the replay layer. Replacing a user invalidates any cached entry.
    pub fn admit(&mut self, user: u64, ulog: UserLog) -> Result<()> {
        for name in &ulog.log.trainable {
            if self.base.theta().tensor_index(name).is_none() {
                bail!("serve: user {}: log names unknown tensor {:?}", user, name);
            }
        }
        if let Some(m) = &ulog.mask {
            m.validate(self.base.theta())?;
        }
        if let Some(plan) = &ulog.shard {
            if ulog.mask.is_some() {
                bail!(
                    "serve: user {}: a sparse mask and a shard plan cannot combine \
                     (same rule as stepping — sharding decomposes the DENSE pass)",
                    user
                );
            }
            plan.validate(self.base.theta())?;
        }
        self.users.insert(user, ulog);
        self.drop_cached(user);
        Ok(())
    }

    /// Extend a user's log — the serving-side view of more fine-tuning
    /// steps landing. The cached materialization (if any) becomes stale
    /// and is refreshed on the next request.
    pub fn append_steps(
        &mut self,
        user: u64,
        records: &[crate::optim::mezo::StepRecord],
    ) -> Result<()> {
        match self.users.get_mut(&user) {
            Some(u) => {
                u.log.records.extend_from_slice(records);
                Ok(())
            }
            None => bail!("serve: unknown user {}", user),
        }
    }

    /// Forget a tenant (and any cached materialization).
    pub fn remove_user(&mut self, user: u64) {
        self.users.remove(&user);
        self.drop_cached(user);
    }

    /// Drop a user's cached entry, recycling its buffers if unshared.
    pub fn invalidate(&mut self, user: u64) {
        self.drop_cached(user);
    }

    /// Serve a user's parameters: refcounted base for empty logs, cache
    /// hit when the materialization is current, otherwise a replay over a
    /// (recycled) copy of the base. The returned store is shared — every
    /// concurrent holder of the same materialization sees the same bits.
    pub fn get(&mut self, user: u64) -> Result<Arc<ParamStore>> {
        self.stats.requests += 1;
        metrics::SERVE_REQUESTS.inc();
        let t0 = obs::clock();
        let ulog = match self.users.get(&user) {
            Some(u) => u,
            None => bail!("serve: unknown user {}", user),
        };
        let version = ulog.log.records.len();
        if version == 0 {
            if let ServeBase::Dense(base) = &self.base {
                // an empty log IS the base — copy-on-write's "no write" arm
                self.stats.base_served += 1;
                metrics::SERVE_BASE_SERVED.inc();
                return Ok(Arc::clone(base));
            }
            // a quantized base cannot be handed out as dense parameters;
            // fall through so the dequantized copy is cached like any
            // other materialization
        }
        // cache probe (field-precise borrows: users stays borrowed)
        let mut stale = false;
        if self.capacity > 0 {
            if let Some(entry) = self.cache.get_mut(&user) {
                if entry.version == version {
                    self.recency.remove(&entry.tick);
                    self.tick += 1;
                    entry.tick = self.tick;
                    self.recency.insert(self.tick, user);
                    self.stats.hits += 1;
                    metrics::SERVE_HITS.inc();
                    obs::record_since(t0, &metrics::SERVE_HIT_NS);
                    return Ok(Arc::clone(&entry.store));
                }
                stale = true;
            }
        }
        // miss (or stale refresh): materialize into a recycled buffer
        self.stats.misses += 1;
        metrics::SERVE_MISSES.inc();
        let mut store = match self.free.pop() {
            Some(s) => s,
            None => self.base.to_param_store(),
        };
        if let Err(e) = replay_user(&self.engine, &self.base, user, ulog, &mut store) {
            // errors are never cached: the digest guard must fire again on
            // the next request; the buffers go back to the pool
            self.recycle(store);
            return Err(e);
        }
        self.stats.materializations += 1;
        metrics::SERVE_MATERIALIZATIONS.inc();
        obs::record_since(t0, &metrics::SERVE_MATERIALIZE_NS);
        if stale {
            self.stats.stale += 1;
            metrics::SERVE_STALE.inc();
            self.drop_cached(user);
        }
        let arc = Arc::new(store);
        if self.capacity > 0 {
            self.tick += 1;
            let tick = self.tick;
            self.cache
                .insert(user, CacheEntry { store: Arc::clone(&arc), version, tick });
            self.recency.insert(tick, user);
            self.evict_to_capacity();
        }
        Ok(arc)
    }

    /// The uncached reference path: a fresh clone of the base plus a
    /// sequential dense (or masked) replay — no cache, no pool, no seed
    /// batching, no shard decomposition. Every [`ServeStore::get`] result
    /// is pinned `to_bits()`-identical to this.
    pub fn materialize_fresh(&self, user: u64) -> Result<ParamStore> {
        let ulog = match self.users.get(&user) {
            Some(u) => u,
            None => bail!("serve: unknown user {}", user),
        };
        let mut store = self.base.to_param_store();
        if ulog.log.records.is_empty() {
            return Ok(store);
        }
        match &ulog.mask {
            Some(m) => ulog.log.replay_masked_with(&self.engine, &mut store, m)?,
            None => {
                check_dense(user, &ulog.log)?;
                ulog.log.replay_with(&self.engine, &mut store);
            }
        }
        Ok(store)
    }

    /// Drop `user`'s cache entry (if any), recycling unshared buffers.
    fn drop_cached(&mut self, user: u64) {
        if let Some(entry) = self.cache.remove(&user) {
            self.recency.remove(&entry.tick);
            if let Ok(store) = Arc::try_unwrap(entry.store) {
                self.recycle(store);
            }
        }
    }

    /// Evict least-recently-used entries down to the capacity bound.
    fn evict_to_capacity(&mut self) {
        while self.cache.len() > self.capacity {
            let victim = match self.recency.iter().next() {
                Some((&tick, &user)) => (tick, user),
                None => break,
            };
            self.recency.remove(&victim.0);
            if let Some(entry) = self.cache.remove(&victim.1) {
                self.stats.evictions += 1;
                metrics::SERVE_EVICTIONS.inc();
                // a still-borrowed materialization keeps living with its
                // holders; only sole-owned buffers return to the pool
                if let Ok(store) = Arc::try_unwrap(entry.store) {
                    self.recycle(store);
                }
            }
        }
    }

    /// Keep at most capacity + 2 spare buffers (bounded memory).
    fn recycle(&mut self, store: ParamStore) {
        if self.free.len() <= self.capacity + 1 {
            self.free.push(store);
        }
    }
}

/// Guard shared by the dense replay paths: a digest-carrying (sparse) log
/// must never be replayed densely — the run never touched the unmasked
/// coordinates. The [`Trajectory`] layer enforces the same rule; this
/// serve-level check turns its dense-path assertion into a typed error
/// that fires on every request (errors are never cached).
fn check_dense(user: u64, log: &Trajectory) -> Result<()> {
    if let Some(d) = log.mask_digest {
        bail!(
            "serve: user {} holds a sparse log (mask digest {:#018x}) with no mask \
             attached — dense materialization refused; admit with UserLog::masked \
             and the run's mask",
            user,
            d
        );
    }
    Ok(())
}

/// Replay `ulog` over `into` (already a copy of `base` or a recycled
/// buffer): seed it with the base — a bitwise copy of a dense base, a
/// dequantization pass over a quantized one — then run the
/// attachment-appropriate replay.
fn replay_user(
    engine: &ZEngine,
    base: &ServeBase,
    user: u64,
    ulog: &UserLog,
    into: &mut ParamStore,
) -> Result<()> {
    match base {
        ServeBase::Dense(b) => into.copy_from(b),
        ServeBase::Quant(q) => q.dequantize_into(into),
    }
    let log = &ulog.log;
    match (&ulog.mask, &ulog.shard) {
        (Some(mask), _) => {
            // digest + geometry guards live in the replay layer
            if ulog.seeds_per_step > 0 {
                log.replay_batched_masked_with(engine, into, mask, ulog.seeds_per_step)
            } else {
                log.replay_masked_with(engine, into, mask)
            }
        }
        (None, Some(plan)) => {
            // shard-decomposed materialization: per-segment dispatches at
            // unchanged global z counters, gathered back — bitwise dense
            check_dense(user, log)?;
            let manifest = plan.manifest();
            let mut sharded = ShardedStore::scatter(plan, into)?;
            if ulog.seeds_per_step > 0 {
                log.replay_sharded_batched_with(
                    engine,
                    &mut sharded,
                    &manifest,
                    ulog.seeds_per_step,
                )?;
            } else {
                log.replay_sharded_with(engine, &mut sharded, &manifest)?;
            }
            sharded.gather_into(into)
        }
        (None, None) => {
            check_dense(user, log)?;
            if ulog.seeds_per_step > 0 {
                log.replay_batched_with(engine, into, ulog.seeds_per_step)
            } else {
                log.replay_with(engine, into);
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;
    use crate::optim::mezo::StepRecord;
    use crate::rng::Pcg;

    fn base_store(seed: u64) -> ParamStore {
        let specs = vec![
            TensorDesc { name: "emb".into(), shape: vec![300], dtype: "f32".into() },
            TensorDesc { name: "w".into(), shape: vec![517], dtype: "f32".into() },
        ];
        let mut p = ParamStore::from_specs(specs);
        p.init(seed);
        p
    }

    fn random_log(rng: &mut Pcg, n: usize) -> Trajectory {
        let recs: Vec<StepRecord> = (0..n)
            .map(|_| StepRecord {
                seed: rng.next_u64(),
                pgrad: rng.next_f32() - 0.5,
                lr: 1e-3,
            })
            .collect();
        Trajectory::from_run(vec!["emb".into(), "w".into()], &recs)
    }

    fn bits(p: &ParamStore) -> Vec<u32> {
        p.data.iter().flatten().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn empty_log_serves_the_refcounted_base_itself() {
        let mut s = ServeStore::new(base_store(1), ServeConfig::default());
        s.admit(9, UserLog::dense(Trajectory::new(vec!["w".into()]))).unwrap();
        let got = s.get(9).unwrap();
        assert!(Arc::ptr_eq(&got, s.base()));
        assert_eq!(s.stats().base_served, 1);
        assert_eq!(s.stats().materializations, 0);
    }

    #[test]
    fn hit_miss_evict_counters_and_bits() {
        let mut rng = Pcg::new(11);
        let mut s = ServeStore::new(base_store(2), ServeConfig { cache_capacity: 1 });
        s.admit(1, UserLog::dense(random_log(&mut rng, 3))).unwrap();
        s.admit(2, UserLog::dense(random_log(&mut rng, 5))).unwrap();
        let a1 = s.get(1).unwrap(); // miss
        let a2 = s.get(1).unwrap(); // hit
        assert!(Arc::ptr_eq(&a1, &a2));
        let b = s.get(2).unwrap(); // miss, evicts user 1
        let a3 = s.get(1).unwrap(); // miss again
        let st = s.stats();
        assert_eq!((st.hits, st.misses, st.evictions), (1, 3, 2));
        assert_eq!(bits(&a1), bits(&a3)); // eviction cannot move bits
        assert_eq!(bits(&a3), bits(&s.materialize_fresh(1).unwrap()));
        assert_eq!(bits(&b), bits(&s.materialize_fresh(2).unwrap()));
    }

    #[test]
    fn append_steps_makes_the_cache_entry_stale() {
        let mut rng = Pcg::new(12);
        let mut s = ServeStore::new(base_store(3), ServeConfig { cache_capacity: 4 });
        s.admit(7, UserLog::dense(random_log(&mut rng, 2))).unwrap();
        let before = s.get(7).unwrap();
        let extra = [StepRecord { seed: 99, pgrad: 0.25, lr: 1e-3 }];
        s.append_steps(7, &extra).unwrap();
        let after = s.get(7).unwrap();
        assert_ne!(bits(&before), bits(&after));
        assert_eq!(bits(&after), bits(&s.materialize_fresh(7).unwrap()));
        assert_eq!(s.stats().stale, 1);
    }

    #[test]
    fn batched_and_sequential_replay_serve_identical_bits() {
        let mut rng = Pcg::new(13);
        let log = random_log(&mut rng, 6);
        let mut s = ServeStore::new(base_store(4), ServeConfig { cache_capacity: 4 });
        s.admit(1, UserLog::dense(log.clone())).unwrap();
        s.admit(2, UserLog::dense_batched(log, 3)).unwrap();
        assert_eq!(bits(&s.get(1).unwrap()), bits(&s.get(2).unwrap()));
    }

    #[test]
    fn sharded_materialization_is_bitwise_dense() {
        let mut rng = Pcg::new(14);
        let base = base_store(5);
        let plan = Arc::new(ShardPlan::new(&base, 3).unwrap());
        let log = random_log(&mut rng, 4);
        let mut s = ServeStore::new(base, ServeConfig { cache_capacity: 4 });
        s.admit(1, UserLog::sharded(log.clone(), plan)).unwrap();
        s.admit(2, UserLog::dense(log)).unwrap();
        assert_eq!(bits(&s.get(1).unwrap()), bits(&s.get(2).unwrap()));
        assert_eq!(bits(&s.get(1).unwrap()), bits(&s.materialize_fresh(1).unwrap()));
    }

    #[test]
    fn sparse_log_without_mask_refuses_dense_materialization_every_time() {
        let mut rng = Pcg::new(15);
        let base = base_store(6);
        let mask = SparseMask::full(&base, &[0, 1]);
        let log = random_log(&mut rng, 3).with_mask_digest(mask.digest());
        let mut s = ServeStore::new(base, ServeConfig { cache_capacity: 4 });
        s.admit(1, UserLog::dense(log)).unwrap();
        for _ in 0..3 {
            let err = s.get(1).unwrap_err();
            assert!(err.to_string().contains("sparse log"), "{}", err);
        }
        assert_eq!(s.stats().materializations, 0);
    }

    #[test]
    fn wrong_mask_digest_is_rejected_through_the_cache() {
        let mut rng = Pcg::new(16);
        let base = base_store(7);
        let right = Arc::new(SparseMask::full(&base, &[0, 1]));
        let wrong = Arc::new(SparseMask::full(&base, &[0]));
        let log = random_log(&mut rng, 3).with_mask_digest(right.digest());
        let mut s = ServeStore::new(base, ServeConfig { cache_capacity: 4 });
        s.admit(1, UserLog::masked(log.clone(), wrong)).unwrap();
        for _ in 0..2 {
            let err = s.get(1).unwrap_err();
            assert!(err.to_string().contains("digest"), "{}", err);
        }
        // re-admitting with the recorded mask recovers, and a full-mask
        // replay is bitwise the dense replay of the same records
        s.admit(1, UserLog::masked(log.clone(), right)).unwrap();
        let got = s.get(1).unwrap();
        let mut dense = s.base().as_ref().clone();
        Trajectory::from_run(log.trainable.clone(), &log.records).replay(&mut dense);
        assert_eq!(bits(&got), bits(&dense));
    }

    #[test]
    fn eviction_recycles_buffers_into_the_pool() {
        let mut rng = Pcg::new(17);
        let mut s = ServeStore::new(base_store(8), ServeConfig { cache_capacity: 1 });
        for u in 0..4u64 {
            s.admit(u, UserLog::dense(random_log(&mut rng, 2))).unwrap();
        }
        for u in 0..4u64 {
            let got = s.get(u).unwrap();
            drop(got); // release the caller's refcount so eviction recycles
        }
        assert!(!s.free.is_empty(), "evictions should feed the buffer pool");
        // pooled buffers must not leak stale bits into later requests
        for u in 0..4u64 {
            assert_eq!(bits(&s.get(u).unwrap()), bits(&s.materialize_fresh(u).unwrap()));
        }
    }

    #[test]
    fn capacity_zero_disables_caching_without_changing_bits() {
        let mut rng = Pcg::new(18);
        let mut s = ServeStore::new(base_store(9), ServeConfig { cache_capacity: 0 });
        s.admit(1, UserLog::dense(random_log(&mut rng, 3))).unwrap();
        let a = s.get(1).unwrap();
        let b = s.get(1).unwrap();
        assert_eq!(s.cache_len(), 0);
        assert_eq!(s.stats().hits, 0);
        assert_eq!(s.stats().misses, 2);
        assert_eq!(bits(&a), bits(&b));
    }

    #[test]
    fn quant_base_serves_masked_logs_bitwise_on_masked_coordinates() {
        use crate::zkernel::QBits;
        let mut rng = Pcg::new(19);
        let dense_base = base_store(20);
        let mask = Arc::new(SparseMask::full(&dense_base, &[0, 1]));
        let log = random_log(&mut rng, 4).with_mask_digest(mask.digest());
        // dense reference: the same masked log served from the dense base
        let mut dense_srv = ServeStore::new(dense_base.clone(), ServeConfig::default());
        dense_srv.admit(1, UserLog::masked(log.clone(), Arc::clone(&mask))).unwrap();
        let want = dense_srv.get(1).unwrap();
        for bits_w in [QBits::Int8, QBits::Int4] {
            let q = QuantStore::quantize(&dense_base, bits_w, Some(&mask)).unwrap();
            let mut s = ServeStore::new_quant(q, ServeConfig::default());
            s.admit(1, UserLog::masked(log.clone(), Arc::clone(&mask))).unwrap();
            let got = s.get(1).unwrap();
            // cache hit path returns the same materialization
            assert!(Arc::ptr_eq(&got, &s.get(1).unwrap()));
            // and it is bitwise the fresh replay
            assert_eq!(bits(&got), bits(&s.materialize_fresh(1).unwrap()));
            // masked coordinates are bitwise the dense-base serving result
            // (the full mask makes that every coordinate here)
            assert_eq!(bits(&got), bits(&want), "bits={:?}", bits_w);
        }
    }

    #[test]
    fn quant_base_materializes_empty_logs_through_the_cache() {
        use crate::zkernel::QBits;
        let dense_base = base_store(21);
        let q = QuantStore::quantize(&dense_base, QBits::Int8, None).unwrap();
        let reference = q.to_dense();
        let mut s = ServeStore::new_quant(q, ServeConfig::default());
        s.admit(5, UserLog::dense(Trajectory::new(vec!["w".into()]))).unwrap();
        let got = s.get(5).unwrap();
        // not a refcount on the base (there is no dense base): a cached
        // dequantized materialization, within the pinned dequant bound
        assert_eq!(s.stats().base_served, 0);
        assert_eq!(s.stats().materializations, 1);
        assert_eq!(bits(&got), bits(&reference));
        assert!(Arc::ptr_eq(&got, &s.get(5).unwrap()));
    }

    #[test]
    #[should_panic(expected = "quantized base")]
    fn base_accessor_panics_on_a_quant_base() {
        use crate::zkernel::QBits;
        let q = QuantStore::quantize(&base_store(22), QBits::Int8, None).unwrap();
        let s = ServeStore::new_quant(q, ServeConfig::default());
        let _ = s.base();
    }

    #[test]
    fn admit_rejects_unknown_tensors_and_mismatched_geometry() {
        let mut s = ServeStore::new(base_store(10), ServeConfig::default());
        let log = Trajectory::new(vec!["nope".into()]);
        assert!(s.admit(1, UserLog::dense(log)).is_err());
        let other = base_store(10);
        let mask = Arc::new(SparseMask::full(&other, &[0]));
        let mut bad = UserLog::masked(Trajectory::new(vec!["w".into()]), mask);
        bad.shard = Some(Arc::new(ShardPlan::new(&other, 2).unwrap()));
        let err = s.admit(1, bad).unwrap_err();
        assert!(err.to_string().contains("cannot combine"), "{}", err);
    }
}
