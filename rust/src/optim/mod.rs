//! Optimizers: the MeZO family (zeroth-order, in-place), the FZOO
//! batched-seed variant, and the backpropagation baselines.
pub mod ft;
pub mod fzoo;
pub mod mezo;
pub mod variance;

use crate::model::params::ParamStore;
use anyhow::Result;

/// Object-safe facade over the ZO optimizers so trainers and experiment
/// drivers can swap estimator variants (Tables 6, 8-11).
pub trait ZoStepper {
    /// One optimization step; returns the (mean) loss observed.
    fn zo_step(
        &mut self,
        params: &mut ParamStore,
        loss: &mut dyn FnMut(&ParamStore) -> Result<f32>,
    ) -> Result<f32>;
    /// Forward passes consumed so far.
    fn forward_passes(&self) -> usize;
    /// The full (seed, projected-grad, lr) trajectory so far.
    fn records(&self) -> &[mezo::StepRecord];
    /// Digest of the sparse SensZOQ mask the optimizer is stepping under,
    /// if any — persist it next to [`ZoStepper::records`] (see
    /// `storage::Trajectory::with_mask_digest`) so replay can verify it
    /// reconstructs under the same mask. `None` = dense stepping.
    fn mask_digest(&self) -> Option<u64> {
        None
    }
    /// Optional fast path: a whole step against a loss artifact with the
    /// perturbation fused into the upload (see MezoSgd::step_artifact).
    /// Returns None when the variant has no fast path. pjrt builds only.
    #[cfg(feature = "pjrt")]
    fn zo_step_artifact(
        &mut self,
        _params: &mut ParamStore,
        _art: &crate::runtime::Artifact,
        _batch: &crate::data::batch::Batch,
    ) -> Option<Result<f32>> {
        None
    }
}

/// [`ZoStepper`] adapter over [`mezo::MezoSgd`] (all MeZO flavors).
pub struct MezoStepper {
    /// the wrapped optimizer
    pub inner: mezo::MezoSgd,
    fwd: usize,
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    scratch: Vec<f32>,
    /// set false to force the reference in-place path (used by benches)
    pub use_fast_path: bool,
}

impl MezoStepper {
    /// Wrap a [`mezo::MezoSgd`] for trainers/experiment drivers.
    pub fn new(inner: mezo::MezoSgd) -> MezoStepper {
        MezoStepper { inner, fwd: 0, scratch: Vec::new(), use_fast_path: true }
    }
}

/// [`ZoStepper`] adapter over [`fzoo::Fzoo`], so trainers and experiment
/// drivers can swap FZOO in wherever a MeZO variant runs.
pub struct FzooStepper {
    /// the wrapped optimizer
    pub inner: fzoo::Fzoo,
    fwd: usize,
}

impl FzooStepper {
    /// Wrap an [`fzoo::Fzoo`] for trainers/experiment drivers.
    pub fn new(inner: fzoo::Fzoo) -> FzooStepper {
        FzooStepper { inner, fwd: 0 }
    }
}

impl ZoStepper for FzooStepper {
    fn zo_step(
        &mut self,
        params: &mut ParamStore,
        loss: &mut dyn FnMut(&ParamStore) -> Result<f32>,
    ) -> Result<f32> {
        let info = self.inner.step(params, |p| loss(p))?;
        self.fwd += info.forward_passes;
        Ok(info.loss)
    }
    fn forward_passes(&self) -> usize {
        self.fwd
    }
    fn records(&self) -> &[mezo::StepRecord] {
        &self.inner.history
    }
    fn mask_digest(&self) -> Option<u64> {
        self.inner.mask.as_ref().map(|m| m.digest())
    }
}

impl ZoStepper for MezoStepper {
    fn zo_step(
        &mut self,
        params: &mut ParamStore,
        loss: &mut dyn FnMut(&ParamStore) -> Result<f32>,
    ) -> Result<f32> {
        let info = self.inner.step(params, |p| loss(p))?;
        self.fwd += info.forward_passes;
        Ok(info.loss)
    }
    fn forward_passes(&self) -> usize {
        self.fwd
    }
    fn records(&self) -> &[mezo::StepRecord] {
        &self.inner.history
    }
    fn mask_digest(&self) -> Option<u64> {
        self.inner.mask.as_ref().map(|m| m.digest())
    }
    #[cfg(feature = "pjrt")]
    fn zo_step_artifact(
        &mut self,
        params: &mut ParamStore,
        art: &crate::runtime::Artifact,
        batch: &crate::data::batch::Batch,
    ) -> Option<Result<f32>> {
        use mezo::Flavor;
        let plain = self.use_fast_path
            && self.inner.cfg.flavor == Flavor::Sgd
            && !self.inner.cfg.one_point
            && self.inner.cfg.n <= 1;
        if !plain {
            return None;
        }
        let r = self
            .inner
            .step_artifact(params, art, batch, &mut self.scratch)
            .map(|info| {
                self.fwd += info.forward_passes;
                info.loss
            });
        Some(r)
    }
}

impl ZoStepper for variance::ModifiedSpsa {
    fn zo_step(
        &mut self,
        params: &mut ParamStore,
        loss: &mut dyn FnMut(&ParamStore) -> Result<f32>,
    ) -> Result<f32> {
        self.step(params, |p| loss(p))
    }
    fn forward_passes(&self) -> usize {
        2 * self.step as usize
    }
    fn records(&self) -> &[mezo::StepRecord] {
        &self.history
    }
}
