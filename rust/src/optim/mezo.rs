//! MeZO — Algorithm 1, in place, in rust (the paper's core contribution).
//!
//! The perturbation z ~ N(0, I_d) is never materialised: each of its uses
//! (perturb +ε, perturb −2ε, restore +ε, update) regenerates the same
//! coordinates from the step's seed via the counter-based
//! [`GaussianStream`]. Memory overhead over inference is O(1): a seed and
//! two scalars per step — which is also exactly what gets *persisted* for
//! checkpoint reconstruction (§2.1 "Storage Efficiency", storage::trajectory).
//!
//! Implemented variants (Appendix A/B):
//!  * n-SPSA averaging (Algorithm 2) with constant or linear schedules,
//!  * the one-point estimator (Definition 8, Zhang et al. 2022),
//!  * MeZO-momentum and MeZO-Adam (B.2) — moment state is *recomputable*
//!    from the (seed, projected_grad) history; we keep dense moments for
//!    speed and verify the recomputation equivalence in tests.
//!
//! §Perf L4 — all parameter passes run on the blocked, multi-threaded
//! [`crate::zkernel`] engine. Two consequences worth calling out:
//!
//! * every pass (perturb / restore / update / staging) generates z in
//!   256-coordinate blocks and is chunked across threads by global offset,
//!   which the counter-based stream makes bit-identical for any thread
//!   count — the trajectory tests below pin this down;
//! * the n-SPSA update is a **single pass** over θ: instead of applying n
//!   per-seed updates back to back (n reads + n writes of every
//!   coordinate), [`crate::zkernel::ZEngine::multi_sgd_update`] walks θ
//!   once, applying all n `(seed, pgrad)` updates per coordinate in record
//!   order — the same floating-point sequence, n× less parameter traffic.

use crate::model::params::ParamStore;
use crate::model::Theta;
use crate::rng::{GaussianStream, Pcg};
use crate::shard::{trainable_flags, ShardPlan};
use crate::zkernel::{AdamParams, SparseMask, ZEngine};
use anyhow::{bail, Result};

/// Which update rule consumes the SPSA gradient estimate (Appendix B.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    /// plain ZO-SGD (Definition 2)
    Sgd,
    /// SGD + momentum on the SPSA estimate
    Momentum,
    /// Adam on the SPSA estimate
    Adam,
}

/// Configuration of the [`MezoSgd`] optimizer family.
#[derive(Debug, Clone)]
pub struct MezoConfig {
    /// learning rate η
    pub lr: f32,
    /// perturbation scale ε
    pub eps: f32,
    /// decoupled weight decay
    pub weight_decay: f32,
    /// number of z samples per step (n-SPSA); 1 is the paper default
    pub n: usize,
    /// if true, n grows linearly from 1 to `n` over the run (Table 6)
    pub linear_n_schedule: bool,
    /// update rule on the SPSA estimate
    pub flavor: Flavor,
    /// momentum coefficient (Momentum flavor)
    pub momentum: f32,
    /// first-moment EMA coefficient (Adam flavor)
    pub beta1: f32,
    /// second-moment EMA coefficient (Adam flavor)
    pub beta2: f32,
    /// Adam denominator stabilizer
    pub adam_eps: f32,
    /// one-point estimator (Definition 8) instead of two-point SPSA
    pub one_point: bool,
    /// total planned steps (for schedules)
    pub total_steps: usize,
}

impl Default for MezoConfig {
    fn default() -> Self {
        MezoConfig {
            lr: 1e-3,
            eps: 1e-3,
            weight_decay: 0.0,
            n: 1,
            linear_n_schedule: false,
            flavor: Flavor::Sgd,
            momentum: 0.9,
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
            one_point: false,
            total_steps: 1000,
        }
    }
}

/// One history record — all that is needed to replay the trajectory.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepRecord {
    /// the z seed this update regenerated from
    pub seed: u64,
    /// projected gradient applied with this seed (mean-normalized when the
    /// step batched several seeds)
    pub pgrad: f32,
    /// learning rate the update used (FZOO stores its variance-adapted lr)
    pub lr: f32,
}

/// What one optimization step observed and consumed.
#[derive(Debug, Clone, Copy)]
pub struct StepInfo {
    /// loss observed this step (mean of the perturbed losses for MeZO,
    /// the unperturbed anchor for FZOO)
    pub loss: f32,
    /// last seed's recorded projected gradient — exactly as it entered the
    /// history, so mean-normalized (gₙ/n) for FZOO's batched steps
    pub pgrad: f32,
    /// last seed drawn
    pub seed: u64,
    /// forward passes this step consumed
    pub forward_passes: usize,
}

/// The MeZO optimizer (Algorithm 1) and its n-SPSA / one-point / momentum /
/// Adam variants, all parameter passes on the [`ZEngine`].
pub struct MezoSgd {
    /// configuration (mutable between steps)
    pub cfg: MezoConfig,
    /// indices (into ParamStore) of the trainable tensors
    pub trainable: Vec<usize>,
    /// steps taken so far
    pub step: u64,
    /// the blocked/threaded kernel engine every parameter pass runs on;
    /// bit-identical for any `engine.threads` (see zkernel::tests)
    pub engine: ZEngine,
    /// optional sparse SensZOQ mask: when set, perturb and update walk
    /// ONLY the masked coordinates (same global z counters as dense, so a
    /// full mask reproduces dense stepping bit for bit). Sgd flavor only —
    /// `step` errors under Momentum/Adam, whose moment buffers are dense.
    /// Log [`SparseMask::digest`] next to `history` so replay can verify
    /// mask identity (`storage::Trajectory::with_mask_digest`).
    pub mask: Option<SparseMask>,
    /// optional shard plan: when set, every parameter write (perturb /
    /// restore / update) walks the plan's shard segments through the
    /// shard-scoped kernels instead of whole tensors — the same
    /// coordinates at the same global z counters, so a sharded step is
    /// bit-identical to the dense step while each shard's passes are
    /// independent dispatches a worker could own (see [`crate::shard`]).
    /// Sgd flavor only, and exclusive with `mask`; `step` errors
    /// otherwise.
    pub shard: Option<ShardPlan>,
    seed_rng: Pcg,
    /// (seed, projected_grad, lr) per applied z — the full trajectory
    pub history: Vec<StepRecord>,
    /// dense first/second moments (momentum / adam flavors only)
    m: Option<Vec<Vec<f32>>>,
    v: Option<Vec<Vec<f32>>>,
    /// one-point state: previous perturbed loss
    prev_loss: Option<f32>,
}

impl MezoSgd {
    /// New optimizer; `master_seed` drives the per-step seed stream.
    pub fn new(cfg: MezoConfig, trainable: Vec<usize>, master_seed: u64) -> MezoSgd {
        MezoSgd {
            cfg,
            trainable,
            step: 0,
            engine: ZEngine::default(),
            mask: None,
            shard: None,
            seed_rng: Pcg::new(master_seed),
            history: Vec::new(),
            m: None,
            v: None,
            prev_loss: None,
        }
    }

    /// In-place perturbation: θ += scale · z(seed), walking only trainable
    /// tensors but indexing z by each tensor's *global* offset so every
    /// pass regenerates identical coordinates. Under a sparse mask, only
    /// the masked coordinates are touched (same z per coordinate).
    ///
    /// Generic over [`Theta`]: a dense [`ParamStore`] routes to the dense
    /// kernel tier, a [`QuantStore`](crate::model::quant::QuantStore) to
    /// the quantized one. Shard-scoped perturbation stays dense-only (the
    /// shard kernels walk raw f32 buffers) and panics on a non-dense
    /// store; [`MezoSgd::step`] rejects that combination up front with a
    /// typed [`ScopeError`] instead.
    pub fn perturb<T: Theta + ?Sized>(&self, params: &mut T, seed: u64, scale: f32) {
        let tr = self
            .shard
            .as_ref()
            .map(|_| trainable_flags(params.specs().len(), &self.trainable));
        self.perturb_scoped(params, seed, scale, tr.as_deref());
    }

    /// Body of [`MezoSgd::perturb`] with the shard-walk flags already
    /// built — `step` hoists them once per step instead of once per pass
    /// (a step runs 3n+ perturb passes).
    fn perturb_scoped<T: Theta + ?Sized>(
        &self,
        params: &mut T,
        seed: u64,
        scale: f32,
        tr: Option<&[bool]>,
    ) {
        match (&self.mask, &self.shard) {
            (Some(m), _) => {
                let stream = GaussianStream::new(seed);
                for &ti in &self.trainable {
                    params.axpy_z_masked(&self.engine, ti, stream, m.indices(ti), scale);
                }
            }
            (None, Some(plan)) => {
                // shard-major walk over the trainable segments: the same
                // coordinates at the same global z counters as the dense
                // arm, each segment an independent shard-local dispatch
                let dp = params
                    .as_dense_mut()
                    .expect("shard-scoped perturbation requires a dense store (step validates)");
                let stream = GaussianStream::new(seed);
                for seg in plan.segments_where(tr.expect("shard flags built with the plan")) {
                    self.engine.axpy_z_shard(
                        stream,
                        dp.offsets[seg.tensor],
                        seg.lo,
                        seg.hi,
                        &mut dp.data[seg.tensor],
                        scale,
                    );
                }
            }
            (None, None) => {
                perturb_tensors_with(&self.engine, params, &self.trainable, seed, scale)
            }
        }
    }

    /// current n per the sample schedule
    fn n_now(&self) -> usize {
        if !self.cfg.linear_n_schedule || self.cfg.n <= 1 {
            return self.cfg.n.max(1);
        }
        let frac = (self.step as f64 / self.cfg.total_steps.max(1) as f64).min(1.0);
        (1.0 + frac * (self.cfg.n as f64 - 1.0)).round() as usize
    }

    /// One optimization step. `loss` evaluates L(θ; B) for the *current*
    /// in-place parameters (two calls per z for SPSA, one for one-point).
    ///
    /// Generic over [`Theta`]: stepping a dense [`ParamStore`] is the
    /// paper's Algorithm 1 verbatim; stepping a
    /// [`QuantStore`](crate::model::quant::QuantStore) is the SensZOQ
    /// recipe — pair it with a sparse mask so the walk stays on the exact
    /// f32 overlay (masked stepping on a quantized store is
    /// `to_bits()`-identical to the dense masked step; see
    /// `tests/quant.rs`). Moment flavors and shard plans need raw dense
    /// buffers and are rejected with a typed [`ScopeError`] on any other
    /// store.
    ///
    /// ```
    /// use mezo::model::meta::TensorDesc;
    /// use mezo::model::params::ParamStore;
    /// use mezo::optim::mezo::{MezoConfig, MezoSgd};
    /// let mut p = ParamStore::from_specs(vec![
    ///     TensorDesc { name: "w".into(), shape: vec![8], dtype: "f32".into() },
    /// ]);
    /// p.init(0);
    /// let mut opt = MezoSgd::new(MezoConfig::default(), vec![0], 42);
    /// let info = opt
    ///     .step(&mut p, |p| Ok(p.data[0].iter().map(|&x| x * x).sum()))
    ///     .unwrap();
    /// assert_eq!(info.forward_passes, 2); // Algorithm 1: +ε and −ε
    /// assert_eq!(opt.history.len(), 1);   // replayable (seed, g, lr) log
    /// ```
    pub fn step<T, F>(&mut self, params: &mut T, mut loss: F) -> Result<StepInfo>
    where
        T: Theta + ?Sized,
        F: FnMut(&T) -> Result<f32>,
    {
        validate_scoping(self.mask.as_ref(), self.shard.as_ref(), self.cfg.flavor, params)?;
        let n = self.n_now();
        let eps = self.cfg.eps;
        let lr = self.cfg.lr;
        let mut records: Vec<StepRecord> = Vec::with_capacity(n);
        let mut mean_loss = 0.0f32;
        let mut fwd = 0usize;
        // shard-walk flags, hoisted once per step (a step runs 3n+
        // perturb passes plus the update)
        let shard_tr = self
            .shard
            .as_ref()
            .map(|_| trainable_flags(params.specs().len(), &self.trainable));

        for _ in 0..n {
            let seed = self.seed_rng.next_u64();
            let pgrad = if self.cfg.one_point {
                // Definition 8: g = (L(θ_t + εz_t) − L(θ_{t−1} + εz_{t−1}))/ε
                self.perturb_scoped(params, seed, eps, shard_tr.as_deref());
                let lp = loss(params)?;
                fwd += 1;
                self.perturb_scoped(params, seed, -eps, shard_tr.as_deref()); // restore
                let g = match self.prev_loss {
                    Some(prev) => (lp - prev) / eps,
                    None => 0.0,
                };
                self.prev_loss = Some(lp);
                mean_loss += lp;
                g
            } else {
                // Algorithm 1: θ+εz, θ−εz, restore
                self.perturb_scoped(params, seed, eps, shard_tr.as_deref());
                let lp = loss(params)?;
                self.perturb_scoped(params, seed, -2.0 * eps, shard_tr.as_deref());
                let lm = loss(params)?;
                self.perturb_scoped(params, seed, eps, shard_tr.as_deref());
                fwd += 2;
                mean_loss += 0.5 * (lp + lm);
                (lp - lm) / (2.0 * eps)
            };
            records.push(StepRecord { seed, pgrad, lr });
        }
        mean_loss /= n as f32;

        // apply the update(s)
        match self.cfg.flavor {
            Flavor::Sgd => {
                // §Perf L4: all n seeds applied in ONE pass over θ —
                // per-coordinate update order is still record order, so the
                // result is bit-identical to n sequential apply_sgd passes.
                let zs: Vec<(GaussianStream, f32)> = records
                    .iter()
                    .map(|r| (GaussianStream::new(r.seed), r.pgrad / n as f32))
                    .collect();
                if let Some(plan) = &self.shard {
                    // shard-major: each segment's fused update is its own
                    // dispatch at the segment's global counters — bitwise
                    // the slice of the dense update below
                    let dp = params
                        .as_dense_mut()
                        .expect("validated at step entry: shard stepping requires a dense store");
                    let tr = shard_tr.as_deref().expect("shard flags built with the plan");
                    for seg in plan.segments_where(tr) {
                        self.engine.multi_sgd_update_shard(
                            &zs,
                            dp.offsets[seg.tensor],
                            seg.lo,
                            seg.hi,
                            &mut dp.data[seg.tensor],
                            lr,
                            self.cfg.weight_decay,
                        );
                    }
                } else {
                    for &ti in &self.trainable {
                        match &self.mask {
                            None => params.multi_sgd_update(
                                &self.engine,
                                ti,
                                &zs,
                                lr,
                                self.cfg.weight_decay,
                            ),
                            Some(m) => params.multi_sgd_update_masked(
                                &self.engine,
                                ti,
                                &zs,
                                m.indices(ti),
                                lr,
                                self.cfg.weight_decay,
                            ),
                        }
                    }
                }
            }
            Flavor::Momentum | Flavor::Adam => {
                let dp = params
                    .as_dense_mut()
                    .expect("validated at step entry: moment flavors require a dense store");
                self.apply_with_moments(dp, &records);
            }
        }
        // n_now() >= 1 makes `records` non-empty; keep the invariant as a
        // typed error rather than an unwrap panic if it ever breaks
        let last = match records.last() {
            Some(r) => *r,
            None => bail!("MeZO step produced no records (n_now() must be >= 1)"),
        };
        self.history.extend(records.iter().copied());
        self.step += 1;
        crate::obs::metrics::OPT_STEPS.inc();
        crate::obs::metrics::OPT_FORWARD_PASSES.add(fwd as u64);
        crate::obs::metrics::OPT_LOSS.set(mean_loss as f64);
        Ok(StepInfo { loss: mean_loss, pgrad: last.pgrad, seed: last.seed, forward_passes: fwd })
    }

    /// §Perf L3 fast path: one MeZO step against a loss artifact with the
    /// perturbation fused into the literal upload (runtime::run_perturbed).
    /// Semantically identical to `step` for the SGD flavor with n = 1 —
    /// same seed stream, same z, same update — but 3 z-passes instead of 4
    /// and no in-place perturb/restore writes (no float drift either).
    /// pjrt builds only: needs the compiled artifact runtime.
    #[cfg(feature = "pjrt")]
    pub fn step_artifact(
        &mut self,
        params: &mut ParamStore,
        art: &crate::runtime::Artifact,
        batch: &crate::data::batch::Batch,
        scratch: &mut Vec<f32>,
    ) -> Result<StepInfo> {
        assert!(self.cfg.flavor == Flavor::Sgd && !self.cfg.one_point && self.n_now() == 1
                    && self.mask.is_none() && self.shard.is_none(),
                "fast path covers plain dense 2-point MeZO-SGD; use step() for variants");
        let eps = self.cfg.eps;
        let lr = self.cfg.lr;
        let seed = self.seed_rng.next_u64();
        let mut mask = vec![false; params.specs.len()];
        for &ti in &self.trainable {
            mask[ti] = true;
        }
        let lp = crate::runtime::scalar_f32(
            &art.run_perturbed(params, &mask, seed, eps, Some(batch), scratch)?[0])?;
        let lm = crate::runtime::scalar_f32(
            &art.run_perturbed(params, &mask, seed, -eps, Some(batch), scratch)?[0])?;
        let pgrad = (lp - lm) / (2.0 * eps);
        self.apply_sgd(params, seed, pgrad);
        self.history.push(StepRecord { seed, pgrad, lr });
        self.step += 1;
        Ok(StepInfo { loss: 0.5 * (lp + lm), pgrad, seed, forward_passes: 2 })
    }

    /// θ ← θ − lr·(g·z + wd·θ), regenerating z from the seed.
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    fn apply_sgd(&self, params: &mut ParamStore, seed: u64, g: f32) {
        let stream = GaussianStream::new(seed);
        for &ti in &self.trainable {
            self.engine.sgd_update(
                stream,
                params.offsets[ti],
                &mut params.data[ti],
                self.cfg.lr,
                g,
                self.cfg.weight_decay,
            );
        }
    }

    fn apply_with_moments(&mut self, params: &mut ParamStore, records: &[StepRecord]) {
        let zs: Vec<(GaussianStream, f32)> =
            records.iter().map(|r| (GaussianStream::new(r.seed), r.pgrad)).collect();
        let cfg = MomentCfg {
            flavor: self.cfg.flavor,
            lr: self.cfg.lr,
            wd: self.cfg.weight_decay,
            momentum: self.cfg.momentum,
            beta1: self.cfg.beta1,
            beta2: self.cfg.beta2,
            adam_eps: self.cfg.adam_eps,
            t: (self.step + 1) as f32,
        };
        apply_moment_update(
            self.engine,
            &self.trainable,
            params,
            &zs,
            cfg,
            &mut self.m,
            &mut self.v,
        );
    }
}

/// Scalar knobs of one fused moment update (shared by [`MezoSgd`] and
/// `Fzoo`): which rule, and every coefficient it consumes.
#[derive(Debug, Clone, Copy)]
pub(crate) struct MomentCfg {
    pub flavor: Flavor,
    pub lr: f32,
    pub wd: f32,
    pub momentum: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub adam_eps: f32,
    /// 1-based step count for Adam bias correction
    pub t: f32,
}

/// Shared wiring of the fused moment kernels: lazily size the m (and,
/// for Adam, v) buffers, then feed the record batch through
/// [`ZEngine::momentum_update`] / [`ZEngine::adam_update`] per trainable
/// tensor. Both optimizers route their Momentum/Adam flavors through
/// this one function — MezoSgd at `cfg.lr`, Fzoo at its
/// variance-adapted `lr_eff` — so the moment-update plumbing cannot
/// drift between them.
pub(crate) fn apply_moment_update(
    engine: ZEngine,
    trainable: &[usize],
    params: &mut ParamStore,
    zs: &[(GaussianStream, f32)],
    cfg: MomentCfg,
    m_slot: &mut Option<Vec<Vec<f32>>>,
    v_slot: &mut Option<Vec<Vec<f32>>>,
) {
    if m_slot.is_none() {
        *m_slot = Some(trainable.iter().map(|&ti| vec![0.0; params.data[ti].len()]).collect());
    }
    if cfg.flavor == Flavor::Adam && v_slot.is_none() {
        *v_slot = Some(trainable.iter().map(|&ti| vec![0.0; params.data[ti].len()]).collect());
    }
    let n = zs.len() as f32;
    let m = m_slot.as_mut().unwrap();
    for (k, &ti) in trainable.iter().enumerate() {
        let off = params.offsets[ti];
        let buf = &mut params.data[ti];
        let mk = &mut m[k];
        match cfg.flavor {
            Flavor::Momentum => {
                engine.momentum_update(zs, off, buf, mk, cfg.lr, cfg.wd, cfg.momentum, n);
            }
            Flavor::Adam => {
                let vk = &mut v_slot.as_mut().unwrap()[k];
                engine.adam_update(
                    zs,
                    off,
                    buf,
                    mk,
                    vk,
                    AdamParams {
                        lr: cfg.lr,
                        wd: cfg.wd,
                        beta1: cfg.beta1,
                        beta2: cfg.beta2,
                        eps: cfg.adam_eps,
                        t: cfg.t,
                        n,
                    },
                );
            }
            Flavor::Sgd => unreachable!(),
        }
    }
}

/// Typed rejection of an unsupported scoping × flavor combination.
///
/// Masked and shard-scoped stepping support the Sgd flavor only — the
/// Momentum/Adam moment buffers are dense, neither masked nor
/// shard-partitioned (ROADMAP carries "unify moment-state scoping" as the
/// open item that would lift this) — and a mask cannot combine with a
/// shard plan, because sharding decomposes the DENSE parameter pass.
/// Every such combination is rejected up front by [`MezoSgd::step`] /
/// `Fzoo::step` *before* any parameter is touched: never a silent no-op,
/// never a panic. Returned inside [`anyhow::Error`]; recover the variant
/// with `err.downcast_ref::<ScopeError>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScopeError {
    /// a sparse mask was attached with a Momentum/Adam flavor
    MaskRequiresSgd(Flavor),
    /// a shard plan was attached with a Momentum/Adam flavor
    ShardRequiresSgd(Flavor),
    /// a sparse mask and a shard plan were attached together
    MaskShardExclusive,
    /// a shard plan was attached but the store is not dense (the shard
    /// kernels walk raw f32 buffers — a quantized θ cannot be sharded)
    ShardRequiresDense,
    /// a Momentum/Adam flavor was requested on a non-dense store (the
    /// moment buffers mirror raw f32 tensors)
    MomentRequiresDense(Flavor),
}

impl std::fmt::Display for ScopeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScopeError::MaskRequiresSgd(flavor) => write!(
                f,
                "sparse masks support the Sgd flavor only (a static coordinate set is \
                 perturbed/updated; the Momentum/Adam moment buffers are dense) — \
                 got {:?}",
                flavor
            ),
            ScopeError::ShardRequiresSgd(flavor) => write!(
                f,
                "shard-scoped stepping supports the Sgd flavor only (the Momentum/Adam \
                 moment buffers are dense, not shard-partitioned) — got {:?}",
                flavor
            ),
            ScopeError::MaskShardExclusive => write!(
                f,
                "a sparse mask and a shard plan cannot combine: sharding decomposes the \
                 DENSE parameter pass — clear one of the two"
            ),
            ScopeError::ShardRequiresDense => write!(
                f,
                "shard-scoped stepping requires a dense ParamStore: the shard kernels walk \
                 raw f32 buffers, which a quantized store does not expose"
            ),
            ScopeError::MomentRequiresDense(flavor) => write!(
                f,
                "the {:?} flavor requires a dense ParamStore (its moment buffers mirror \
                 raw f32 tensors) — step a quantized store with the Sgd flavor",
                flavor
            ),
        }
    }
}

impl std::error::Error for ScopeError {}

/// Shared step-entry guard of the scoping modes: a mask must fit the
/// store and a shard plan must match it (geometry errors from their own
/// `validate`), and every unsupported scoping × flavor combination maps
/// to a typed [`ScopeError`]. Runs before any parameter write.
pub(crate) fn validate_scoping<T: Theta + ?Sized>(
    mask: Option<&SparseMask>,
    shard: Option<&ShardPlan>,
    flavor: Flavor,
    params: &T,
) -> Result<()> {
    if let Some(m) = mask {
        m.validate(params)?;
        if flavor != Flavor::Sgd {
            return Err(ScopeError::MaskRequiresSgd(flavor).into());
        }
    }
    if let Some(plan) = shard {
        if mask.is_some() {
            return Err(ScopeError::MaskShardExclusive.into());
        }
        plan.validate(params)?;
        if flavor != Flavor::Sgd {
            return Err(ScopeError::ShardRequiresSgd(flavor).into());
        }
        if params.as_dense().is_none() {
            return Err(ScopeError::ShardRequiresDense.into());
        }
    }
    if flavor != Flavor::Sgd && params.as_dense().is_none() {
        return Err(ScopeError::MomentRequiresDense(flavor).into());
    }
    Ok(())
}

/// θ += scale · z(seed) over the given tensors (shared with variance
/// variants and trajectory replay), on the default kernel engine. Generic
/// over [`Theta`]: dense stores take the dense kernel tier, quantized
/// stores the block-dequantizing one.
pub fn perturb_tensors<T: Theta + ?Sized>(
    params: &mut T,
    tensors: &[usize],
    seed: u64,
    scale: f32,
) {
    perturb_tensors_with(&ZEngine::default(), params, tensors, seed, scale);
}

/// As [`perturb_tensors`], on an explicit engine (thread-count control).
pub fn perturb_tensors_with<T: Theta + ?Sized>(
    engine: &ZEngine,
    params: &mut T,
    tensors: &[usize],
    seed: u64,
    scale: f32,
) {
    let stream = GaussianStream::new(seed);
    for &ti in tensors {
        params.axpy_z(engine, ti, stream, scale);
    }
}

/// Recompute the Adam/momentum first moment at step T directly from the
/// (seed, pgrad) history — the paper's B.2 memory-saving argument. Used in
/// tests to prove the dense state equals the recomputed one.
pub fn recompute_first_moment(
    params: &ParamStore,
    trainable: &[usize],
    history: &[StepRecord],
    beta_or_momentum: f32,
    adam_style: bool,
) -> Vec<Vec<f32>> {
    let engine = ZEngine::default();
    let mut m: Vec<Vec<f32>> =
        trainable.iter().map(|&ti| vec![0.0; params.data[ti].len()]).collect();
    // records stay sequential (the EMA across steps doesn't commute);
    // within a record each tensor runs on the blocked/threaded kernel
    for r in history {
        let stream = GaussianStream::new(r.seed);
        for (k, &ti) in trainable.iter().enumerate() {
            engine.ema_z(
                stream,
                params.offsets[ti],
                &mut m[k],
                r.pgrad,
                beta_or_momentum,
                adam_style,
            );
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;

    fn toy_params() -> ParamStore {
        let specs = vec![
            TensorDesc { name: "w1".into(), shape: vec![4, 4], dtype: "f32".into() },
            TensorDesc { name: "w2".into(), shape: vec![8], dtype: "f32".into() },
        ];
        let mut p = ParamStore::from_specs(specs);
        p.init(0);
        p
    }

    /// quadratic loss L(θ) = Σ (θ_i − 1)², evaluated on the store
    fn quad_loss(p: &ParamStore) -> Result<f32> {
        Ok(p.data.iter().flatten().map(|&x| (x - 1.0) * (x - 1.0)).sum())
    }

    #[test]
    fn perturb_restore_is_exact_roundtrip() {
        let mut p = toy_params();
        let before = p.data.clone();
        let opt = MezoSgd::new(MezoConfig::default(), vec![0, 1], 7);
        opt.perturb(&mut p, 123, 1e-3);
        assert_ne!(p.data, before);
        opt.perturb(&mut p, 123, -2e-3);
        opt.perturb(&mut p, 123, 1e-3);
        // float error only
        for (a, b) in p.data.iter().flatten().zip(before.iter().flatten()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn mezo_optimizes_quadratic() {
        let mut p = toy_params();
        let cfg = MezoConfig { lr: 2e-2, eps: 1e-3, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 1);
        let l0 = quad_loss(&p).unwrap();
        for _ in 0..300 {
            opt.step(&mut p, |p| quad_loss(p)).unwrap();
        }
        let l1 = quad_loss(&p).unwrap();
        assert!(l1 < l0 * 0.2, "l0={} l1={}", l0, l1);
        assert_eq!(opt.history.len(), 300);
    }

    #[test]
    fn n_spsa_reduces_variance() {
        // with n=8 the per-step pgrad*z update should track the true
        // gradient direction better; test that optimization still works and
        // uses 2n forward passes
        let mut p = toy_params();
        let cfg = MezoConfig { lr: 2e-2, eps: 1e-3, n: 4, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 2);
        let info = opt.step(&mut p, |p| quad_loss(p)).unwrap();
        assert_eq!(info.forward_passes, 8);
        assert_eq!(opt.history.len(), 4);
    }

    #[test]
    fn linear_n_schedule_grows() {
        let cfg = MezoConfig {
            n: 9,
            linear_n_schedule: true,
            total_steps: 100,
            ..Default::default()
        };
        let mut opt = MezoSgd::new(cfg, vec![], 3);
        assert_eq!(opt.n_now(), 1);
        opt.step = 50;
        assert_eq!(opt.n_now(), 5);
        opt.step = 100;
        assert_eq!(opt.n_now(), 9);
    }

    #[test]
    fn one_point_estimator_runs_single_forward() {
        let mut p = toy_params();
        let cfg = MezoConfig { one_point: true, lr: 1e-4, eps: 1e-2, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 4);
        let i1 = opt.step(&mut p, |p| quad_loss(p)).unwrap();
        assert_eq!(i1.forward_passes, 1);
        assert_eq!(i1.pgrad, 0.0); // no previous loss yet
        let i2 = opt.step(&mut p, |p| quad_loss(p)).unwrap();
        assert_eq!(i2.forward_passes, 1);
        // optimizes, eventually
        let l_before = quad_loss(&p).unwrap();
        for _ in 0..3000 {
            opt.step(&mut p, |p| quad_loss(p)).unwrap();
        }
        // far noisier than SPSA (that's Table 11's point) but it improves
        let l_after = quad_loss(&p).unwrap();
        assert!(l_after < l_before, "one-point did not improve: {} -> {}", l_before, l_after);
    }

    #[test]
    fn adam_and_momentum_flavors_optimize() {
        for flavor in [Flavor::Momentum, Flavor::Adam] {
            let mut p = toy_params();
            let lr = if flavor == Flavor::Adam { 2e-2 } else { 1e-3 };
            let cfg = MezoConfig { lr, eps: 1e-3, flavor, ..Default::default() };
            let mut opt = MezoSgd::new(cfg, vec![0, 1], 6);
            let l0 = quad_loss(&p).unwrap();
            for _ in 0..300 {
                opt.step(&mut p, |p| quad_loss(p)).unwrap();
            }
            let l1 = quad_loss(&p).unwrap();
            assert!(l1 < l0 * 0.6, "{:?}: l0={} l1={}", flavor, l0, l1);
        }
    }

    #[test]
    fn moment_state_is_recomputable_from_history() {
        // B.2: the dense momentum buffer equals the recomputation from the
        // (seed, pgrad) log — the memory-efficient MeZO-momentum claim.
        let mut p = toy_params();
        let cfg = MezoConfig {
            lr: 1e-3,
            eps: 1e-3,
            flavor: Flavor::Momentum,
            momentum: 0.9,
            weight_decay: 0.0,
            ..Default::default()
        };
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 8);
        for _ in 0..20 {
            opt.step(&mut p, |p| quad_loss(p)).unwrap();
        }
        let recomputed = recompute_first_moment(&p, &[0, 1], &opt.history, 0.9, false);
        let dense = opt.m.as_ref().unwrap();
        for (a, b) in dense.iter().flatten().zip(recomputed.iter().flatten()) {
            assert!((a - b).abs() < 1e-5, "{} vs {}", a, b);
        }
    }

    /// The seed implementation's step(), kept verbatim as a scalar
    /// reference: per-element z() loops, n sequential SGD applications,
    /// scalar moment updates. The kernel-based optimizer must reproduce
    /// its trajectory bit for bit.
    struct ScalarRef {
        cfg: MezoConfig,
        trainable: Vec<usize>,
        step: u64,
        seed_rng: Pcg,
        history: Vec<StepRecord>,
        m: Option<Vec<Vec<f32>>>,
        v: Option<Vec<Vec<f32>>>,
    }

    impl ScalarRef {
        fn new(cfg: MezoConfig, trainable: Vec<usize>, master_seed: u64) -> ScalarRef {
            ScalarRef {
                cfg,
                trainable,
                step: 0,
                seed_rng: Pcg::new(master_seed),
                history: Vec::new(),
                m: None,
                v: None,
            }
        }

        fn perturb(&self, params: &mut ParamStore, seed: u64, scale: f32) {
            let stream = GaussianStream::new(seed);
            for &ti in &self.trainable {
                let off = params.offsets[ti];
                for (j, th) in params.data[ti].iter_mut().enumerate() {
                    *th += scale * stream.z(off + j as u64);
                }
            }
        }

        fn apply_sgd(&self, params: &mut ParamStore, seed: u64, g: f32) {
            let stream = GaussianStream::new(seed);
            let (lr, wd) = (self.cfg.lr, self.cfg.weight_decay);
            for &ti in &self.trainable {
                let off = params.offsets[ti];
                for (j, th) in params.data[ti].iter_mut().enumerate() {
                    let z = stream.z(off + j as u64);
                    *th -= lr * (g * z + wd * *th);
                }
            }
        }

        fn step<F>(&mut self, params: &mut ParamStore, mut loss: F) -> Result<()>
        where
            F: FnMut(&ParamStore) -> Result<f32>,
        {
            let n = self.cfg.n.max(1);
            let eps = self.cfg.eps;
            let lr = self.cfg.lr;
            let mut records = Vec::with_capacity(n);
            for _ in 0..n {
                let seed = self.seed_rng.next_u64();
                self.perturb(params, seed, eps);
                let lp = loss(params)?;
                self.perturb(params, seed, -2.0 * eps);
                let lm = loss(params)?;
                self.perturb(params, seed, eps);
                let pgrad = (lp - lm) / (2.0 * eps);
                records.push(StepRecord { seed, pgrad, lr });
            }
            match self.cfg.flavor {
                Flavor::Sgd => {
                    for r in &records {
                        self.apply_sgd(params, r.seed, r.pgrad / n as f32);
                    }
                }
                Flavor::Momentum | Flavor::Adam => self.apply_moments(params, &records),
            }
            self.history.extend(records);
            self.step += 1;
            Ok(())
        }

        fn apply_moments(&mut self, params: &mut ParamStore, records: &[StepRecord]) {
            let cfg = self.cfg.clone();
            if self.m.is_none() {
                self.m = Some(
                    self.trainable.iter().map(|&ti| vec![0.0; params.data[ti].len()]).collect(),
                );
            }
            if cfg.flavor == Flavor::Adam && self.v.is_none() {
                self.v = Some(
                    self.trainable.iter().map(|&ti| vec![0.0; params.data[ti].len()]).collect(),
                );
            }
            let n = records.len() as f32;
            let t = (self.step + 1) as f32;
            let streams: Vec<GaussianStream> =
                records.iter().map(|r| GaussianStream::new(r.seed)).collect();
            let mut m = self.m.take().unwrap();
            let mut v = self.v.take();
            for (k, &ti) in self.trainable.iter().enumerate() {
                let off = params.offsets[ti];
                let buf = &mut params.data[ti];
                let mk = &mut m[k];
                match cfg.flavor {
                    Flavor::Momentum => {
                        for j in 0..buf.len() {
                            let mut g = 0.0f32;
                            for (s, r) in streams.iter().zip(records) {
                                g += r.pgrad * s.z(off + j as u64);
                            }
                            g = g / n + cfg.weight_decay * buf[j];
                            mk[j] = cfg.momentum * mk[j] + g;
                            buf[j] -= cfg.lr * mk[j];
                        }
                    }
                    Flavor::Adam => {
                        let vk = &mut v.as_mut().unwrap()[k];
                        for j in 0..buf.len() {
                            let mut g = 0.0f32;
                            for (s, r) in streams.iter().zip(records) {
                                g += r.pgrad * s.z(off + j as u64);
                            }
                            g = g / n + cfg.weight_decay * buf[j];
                            mk[j] = cfg.beta1 * mk[j] + (1.0 - cfg.beta1) * g;
                            vk[j] = cfg.beta2 * vk[j] + (1.0 - cfg.beta2) * g * g;
                            let mhat = mk[j] / (1.0 - cfg.beta1.powf(t));
                            let vhat = vk[j] / (1.0 - cfg.beta2.powf(t));
                            buf[j] -= cfg.lr * mhat / (vhat.sqrt() + cfg.adam_eps);
                        }
                    }
                    Flavor::Sgd => unreachable!(),
                }
            }
            self.m = Some(m);
            self.v = v;
        }
    }

    /// larger-than-one-block tensors so the blocked path really blocks
    fn big_params() -> ParamStore {
        let specs = vec![
            TensorDesc { name: "w1".into(), shape: vec![40, 20], dtype: "f32".into() },
            TensorDesc { name: "w2".into(), shape: vec![300], dtype: "f32".into() },
        ];
        let mut p = ParamStore::from_specs(specs);
        p.init(0);
        p
    }

    #[test]
    fn kernel_trajectory_is_bit_identical_to_scalar_reference() {
        // the tentpole acceptance: same master seed => same StepRecord
        // history (bitwise) and same final parameters (bitwise), for every
        // flavor, n > 1, weight decay on, and across thread counts
        for flavor in [Flavor::Sgd, Flavor::Momentum, Flavor::Adam] {
            for threads in [1usize, 2, 8] {
                let cfg = MezoConfig {
                    lr: 1e-2,
                    eps: 1e-3,
                    weight_decay: 1e-4,
                    n: 3,
                    flavor,
                    ..Default::default()
                };
                let master = 0xC0FFEE;
                let mut p_ref = big_params();
                let mut sref = ScalarRef::new(cfg.clone(), vec![0, 1], master);
                let mut p_ker = big_params();
                let mut opt = MezoSgd::new(cfg, vec![0, 1], master);
                opt.engine = ZEngine::with_threads(threads);
                for _ in 0..5 {
                    sref.step(&mut p_ref, |p| quad_loss(p)).unwrap();
                    opt.step(&mut p_ker, |p| quad_loss(p)).unwrap();
                }
                assert_eq!(sref.history.len(), opt.history.len());
                for (a, b) in sref.history.iter().zip(&opt.history) {
                    assert_eq!(a.seed, b.seed, "{:?} t={}", flavor, threads);
                    assert_eq!(
                        a.pgrad.to_bits(),
                        b.pgrad.to_bits(),
                        "{:?} t={}: pgrad {} vs {}",
                        flavor, threads, a.pgrad, b.pgrad
                    );
                    assert_eq!(a.lr.to_bits(), b.lr.to_bits());
                }
                for (x, y) in p_ref.data.iter().flatten().zip(p_ker.data.iter().flatten()) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{:?} t={}: param {} vs {}",
                        flavor, threads, x, y
                    );
                }
            }
        }
    }

    #[test]
    fn pool_and_scope_dispatch_produce_identical_mezo_runs() {
        // the persistent-pool dispatcher is a pure scheduling change:
        // every flavor's full optimizer loop lands on identical bits vs
        // the retained per-call thread::scope path
        for flavor in [Flavor::Sgd, Flavor::Momentum, Flavor::Adam] {
            for threads in [2usize, 8] {
                let mut runs: Vec<(Vec<StepRecord>, Vec<Vec<f32>>)> = Vec::new();
                for scoped in [false, true] {
                    let cfg = MezoConfig {
                        lr: 1e-2,
                        eps: 1e-3,
                        weight_decay: 1e-4,
                        n: 3,
                        flavor,
                        ..Default::default()
                    };
                    let mut p = big_params();
                    let mut opt = MezoSgd::new(cfg, vec![0, 1], 0xD00D);
                    opt.engine = if scoped {
                        ZEngine::with_threads_scoped(threads)
                    } else {
                        ZEngine::with_threads(threads)
                    };
                    for _ in 0..4 {
                        opt.step(&mut p, |p| quad_loss(p)).unwrap();
                    }
                    runs.push((opt.history.clone(), p.data.clone()));
                }
                let (pool_hist, pool_data) = &runs[0];
                let (scope_hist, scope_data) = &runs[1];
                assert_eq!(pool_hist.len(), scope_hist.len());
                for (a, b) in pool_hist.iter().zip(scope_hist) {
                    assert_eq!(a.seed, b.seed, "{:?} t={}", flavor, threads);
                    assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits(), "{:?} t={}", flavor, threads);
                    assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "{:?} t={}", flavor, threads);
                }
                for (x, y) in pool_data.iter().flatten().zip(scope_data.iter().flatten()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{:?} t={}: {} vs {}", flavor, threads, x, y);
                }
            }
        }
    }

    #[test]
    fn full_mask_step_is_bitwise_identical_to_dense_step() {
        // the dense-oracle property at the optimizer level: a full mask
        // changes nothing, bit for bit, for any thread count
        for threads in [1usize, 2, 8] {
            let cfg = MezoConfig {
                lr: 1e-2,
                eps: 1e-3,
                weight_decay: 1e-4,
                n: 2,
                ..Default::default()
            };
            let mut p_dense = big_params();
            let mut dense = MezoSgd::new(cfg.clone(), vec![0, 1], 0xABCD);
            dense.engine = ZEngine::with_threads(threads);
            let mut p_masked = big_params();
            let mut masked = MezoSgd::new(cfg, vec![0, 1], 0xABCD);
            masked.engine = ZEngine::with_threads(threads);
            masked.mask = Some(SparseMask::full(&p_masked, &[0, 1]));
            for _ in 0..4 {
                dense.step(&mut p_dense, |p| quad_loss(p)).unwrap();
                masked.step(&mut p_masked, |p| quad_loss(p)).unwrap();
            }
            for (a, b) in dense.history.iter().zip(&masked.history) {
                assert_eq!(a.seed, b.seed, "t={}", threads);
                assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits(), "t={}", threads);
            }
            for (x, y) in p_dense.data.iter().flatten().zip(p_masked.data.iter().flatten()) {
                assert_eq!(x.to_bits(), y.to_bits(), "t={}: {} vs {}", threads, x, y);
            }
        }
    }

    #[test]
    fn sparse_mask_freezes_unmasked_coordinates() {
        let mut p = big_params();
        let mask = crate::zkernel::SparseMask::top_k(
            &p,
            &[0, 1],
            97,
            crate::zkernel::Sensitivity::Magnitude,
        )
        .unwrap();
        let before = p.data.clone();
        let cfg = MezoConfig { lr: 1e-2, eps: 1e-3, n: 2, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 0xFEED);
        opt.mask = Some(mask.clone());
        for _ in 0..5 {
            opt.step(&mut p, |p| quad_loss(p)).unwrap();
        }
        let mut changed = 0usize;
        for (ti, (now, then)) in p.data.iter().zip(&before).enumerate() {
            let mut hit = vec![false; now.len()];
            for &i in mask.indices(ti) {
                hit[i as usize] = true;
            }
            for (j, (a, b)) in now.iter().zip(then).enumerate() {
                if hit[j] {
                    changed += (a.to_bits() != b.to_bits()) as usize;
                } else {
                    assert_eq!(a.to_bits(), b.to_bits(), "unmasked coord {}:{} moved", ti, j);
                }
            }
        }
        assert!(changed > 0, "masked coordinates never moved");
    }

    #[test]
    fn sparse_masked_trajectory_is_bit_identical_across_threads() {
        let mut reference: Option<(Vec<StepRecord>, Vec<Vec<f32>>)> = None;
        for threads in [1usize, 2, 8] {
            let mut p = big_params();
            let mask = crate::zkernel::SparseMask::top_k(
                &p,
                &[0, 1],
                200,
                crate::zkernel::Sensitivity::Magnitude,
            )
            .unwrap();
            let cfg = MezoConfig {
                lr: 1e-2,
                eps: 1e-3,
                weight_decay: 1e-4,
                n: 3,
                ..Default::default()
            };
            let mut opt = MezoSgd::new(cfg, vec![0, 1], 0xB00);
            opt.engine = ZEngine::with_threads(threads);
            opt.mask = Some(mask);
            for _ in 0..4 {
                opt.step(&mut p, |p| quad_loss(p)).unwrap();
            }
            if let Some((hist, data)) = &reference {
                for (a, b) in hist.iter().zip(&opt.history) {
                    assert_eq!(a.seed, b.seed, "t={}", threads);
                    assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits(), "t={}", threads);
                }
                for (x, y) in data.iter().flatten().zip(p.data.iter().flatten()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "t={}", threads);
                }
            } else {
                reference = Some((opt.history.clone(), p.data.clone()));
            }
        }
    }

    #[test]
    fn sharded_step_is_bitwise_identical_to_dense_step() {
        // the sharding acceptance at the optimizer level: a shard plan
        // changes which dispatches write θ, never a single bit — for any
        // shard count, any thread count, n > 1, weight decay on
        use crate::shard::ShardPlan;
        for k in [1usize, 2, 4] {
            for threads in [1usize, 2, 8] {
                let cfg = MezoConfig {
                    lr: 1e-2,
                    eps: 1e-3,
                    weight_decay: 1e-4,
                    n: 3,
                    ..Default::default()
                };
                let mut p_dense = big_params();
                let mut dense = MezoSgd::new(cfg.clone(), vec![0, 1], 0x51AB);
                dense.engine = ZEngine::with_threads(threads);
                let mut p_shard = big_params();
                let mut sharded = MezoSgd::new(cfg, vec![0, 1], 0x51AB);
                sharded.engine = ZEngine::with_threads(threads);
                sharded.shard = Some(ShardPlan::new(&p_shard, k).unwrap());
                for _ in 0..4 {
                    dense.step(&mut p_dense, |p| quad_loss(p)).unwrap();
                    sharded.step(&mut p_shard, |p| quad_loss(p)).unwrap();
                }
                for (a, b) in dense.history.iter().zip(&sharded.history) {
                    assert_eq!(a.seed, b.seed, "k={} t={}", k, threads);
                    assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits(), "k={} t={}", k, threads);
                }
                for (x, y) in p_dense.data.iter().flatten().zip(p_shard.data.iter().flatten()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "k={} t={}: {} vs {}", k, threads, x, y);
                }
            }
        }
    }

    #[test]
    fn sharded_step_skips_non_trainable_tensors() {
        use crate::shard::ShardPlan;
        let mut p = big_params();
        let before = p.data.clone();
        let cfg = MezoConfig { lr: 1e-2, eps: 1e-3, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, vec![1], 0xF00D); // w2 only
        opt.shard = Some(ShardPlan::new(&p, 3).unwrap());
        for _ in 0..3 {
            opt.step(&mut p, |p| quad_loss(p)).unwrap();
        }
        for (a, b) in p.data[0].iter().zip(&before[0]) {
            assert_eq!(a.to_bits(), b.to_bits(), "frozen tensor moved under sharding");
        }
        assert!(p.data[1].iter().zip(&before[1]).any(|(a, b)| a.to_bits() != b.to_bits()));
    }

    #[test]
    fn shard_plan_rejects_moment_flavors_masks_and_wrong_stores() {
        use crate::shard::ShardPlan;
        let mut p = toy_params();
        let plan = ShardPlan::new(&p, 2).unwrap();
        // moment flavors bail
        let cfg = MezoConfig { flavor: Flavor::Adam, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 1);
        opt.shard = Some(plan.clone());
        let err = opt.step(&mut p, |p| quad_loss(p)).unwrap_err();
        assert!(err.to_string().contains("Sgd flavor"), "{}", err);
        // mask + shard bails
        let mut opt = MezoSgd::new(MezoConfig::default(), vec![0, 1], 1);
        opt.mask = Some(SparseMask::full(&p, &[0, 1]));
        opt.shard = Some(plan.clone());
        let err = opt.step(&mut p, |p| quad_loss(p)).unwrap_err();
        assert!(err.to_string().contains("cannot combine"), "{}", err);
        // a plan built for another store bails
        let mut opt = MezoSgd::new(MezoConfig::default(), vec![0, 1], 1);
        opt.shard = Some(ShardPlan::new(&big_params(), 2).unwrap());
        assert!(opt.step(&mut p, |p| quad_loss(p)).is_err());
    }

    #[test]
    fn mask_with_moment_flavor_errors() {
        let mut p = toy_params();
        let cfg = MezoConfig { flavor: Flavor::Adam, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 1);
        opt.mask = Some(SparseMask::full(&p, &[0, 1]));
        let err = opt.step(&mut p, |p| quad_loss(p)).unwrap_err();
        assert!(err.to_string().contains("Sgd flavor"), "{}", err);
    }

    #[test]
    fn every_scoping_x_moment_flavor_combination_is_typed_and_touches_nothing() {
        use crate::shard::ShardPlan;
        let mut p = toy_params();
        let before = p.data.clone();
        for flavor in [Flavor::Momentum, Flavor::Adam] {
            for shard in [false, true] {
                let cfg = MezoConfig { flavor, ..Default::default() };
                let mut opt = MezoSgd::new(cfg, vec![0, 1], 1);
                if shard {
                    opt.shard = Some(ShardPlan::new(&p, 2).unwrap());
                } else {
                    opt.mask = Some(SparseMask::full(&p, &[0, 1]));
                }
                let err = opt.step(&mut p, |p| quad_loss(p)).unwrap_err();
                let typed = err.downcast_ref::<ScopeError>().expect("typed ScopeError");
                let want = if shard {
                    ScopeError::ShardRequiresSgd(flavor)
                } else {
                    ScopeError::MaskRequiresSgd(flavor)
                };
                assert_eq!(*typed, want, "{}", err);
                assert!(opt.history.is_empty(), "no silent partial step");
                assert_eq!(p.data, before, "θ untouched on the error path");
            }
        }
        // mask + shard together: the mask-flavor guard has precedence for
        // moment flavors; Sgd reaches the exclusivity arm
        for flavor in [Flavor::Sgd, Flavor::Momentum, Flavor::Adam] {
            let cfg = MezoConfig { flavor, ..Default::default() };
            let mut opt = MezoSgd::new(cfg, vec![0, 1], 1);
            opt.mask = Some(SparseMask::full(&p, &[0, 1]));
            opt.shard = Some(ShardPlan::new(&p, 2).unwrap());
            let err = opt.step(&mut p, |p| quad_loss(p)).unwrap_err();
            let want = match flavor {
                Flavor::Sgd => ScopeError::MaskShardExclusive,
                other => ScopeError::MaskRequiresSgd(other),
            };
            assert_eq!(*err.downcast_ref::<ScopeError>().unwrap(), want, "{}", err);
            assert_eq!(p.data, before, "θ untouched on the error path");
        }
    }

    #[test]
    fn mask_built_for_another_store_errors() {
        let mut p = toy_params();
        let big = big_params();
        let cfg = MezoConfig::default();
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 1);
        opt.mask = Some(SparseMask::full(&big, &[0, 1])); // indices exceed toy tensors
        assert!(opt.step(&mut p, |p| quad_loss(p)).is_err());
    }

    #[test]
    fn update_uses_same_z_as_perturbation() {
        // after one step with pgrad g, θ' − θ == −lr·g·z(seed) exactly
        let mut p = toy_params();
        let before = p.data.clone();
        let cfg = MezoConfig { lr: 1e-2, eps: 1e-3, weight_decay: 0.0, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, vec![0, 1], 5);
        let info = opt.step(&mut p, |p| quad_loss(p)).unwrap();
        let stream = GaussianStream::new(info.seed);
        for (k, &ti) in [0usize, 1].iter().enumerate() {
            let off = p.offsets[ti];
            for j in 0..p.data[ti].len() {
                let want = before[k][j] - 1e-2 * info.pgrad * stream.z(off + j as u64);
                assert!((p.data[ti][j] - want).abs() < 1e-6);
            }
        }
    }
}
