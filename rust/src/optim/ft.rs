//! FT baselines: fine-tuning with backpropagation (SGD / Adam), consuming
//! the gradients computed by the AOT `grad` artifact.
//!
//! This is the paper's "FT" comparator (12× memory in their profile): the
//! backward pass runs inside XLA; rust applies the optimizer update to the
//! same ParamStore MeZO uses, so both paths share evaluation and
//! checkpointing.

use crate::model::params::ParamStore;
use anyhow::Result;

/// First-order update rule for the backprop baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FtFlavor {
    /// plain SGD
    Sgd,
    /// Adam (the paper's FT default)
    Adam,
}

/// Configuration of the [`FtOptimizer`] backprop baseline.
#[derive(Debug, Clone)]
pub struct FtConfig {
    /// learning rate
    pub lr: f32,
    /// decoupled weight decay
    pub weight_decay: f32,
    /// update rule
    pub flavor: FtFlavor,
    /// first-moment EMA coefficient (Adam)
    pub beta1: f32,
    /// second-moment EMA coefficient (Adam)
    pub beta2: f32,
    /// Adam denominator stabilizer
    pub adam_eps: f32,
    /// linear decay to zero over total_steps (paper's FT schedule)
    pub linear_decay: bool,
    /// total planned steps (for the decay schedule)
    pub total_steps: usize,
}

impl Default for FtConfig {
    fn default() -> Self {
        FtConfig {
            lr: 1e-3,
            weight_decay: 0.0,
            flavor: FtFlavor::Adam,
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
            linear_decay: true,
            total_steps: 1000,
        }
    }
}

/// The backprop fine-tuning baseline: consumes externally computed
/// gradients (the AOT `grad` artifact) and applies SGD/Adam updates.
pub struct FtOptimizer {
    /// configuration (mutable between steps)
    pub cfg: FtConfig,
    /// indices (into ParamStore) of the trainable tensors
    pub trainable: Vec<usize>,
    /// steps taken so far
    pub step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl FtOptimizer {
    /// New optimizer with zeroed moment buffers sized to the trainables.
    pub fn new(cfg: FtConfig, trainable: Vec<usize>, params: &ParamStore) -> FtOptimizer {
        let m = trainable.iter().map(|&ti| vec![0.0; params.data[ti].len()]).collect();
        let v = trainable.iter().map(|&ti| vec![0.0; params.data[ti].len()]).collect();
        FtOptimizer { cfg, trainable, step: 0, m, v }
    }

    /// Learning rate at the current step (after any linear decay).
    pub fn lr_now(&self) -> f32 {
        if self.cfg.linear_decay {
            let frac = 1.0 - self.step as f32 / self.cfg.total_steps.max(1) as f32;
            self.cfg.lr * frac.max(0.0)
        } else {
            self.cfg.lr
        }
    }

    /// Apply one update. `grads[k]` is the gradient of trainable tensor k
    /// (same order as `self.trainable`), as returned by the grad artifact.
    pub fn apply(&mut self, params: &mut ParamStore, grads: &[Vec<f32>]) -> Result<()> {
        assert_eq!(grads.len(), self.trainable.len());
        let lr = self.lr_now();
        let t = (self.step + 1) as f32;
        let cfg = &self.cfg;
        for (k, &ti) in self.trainable.iter().enumerate() {
            let buf = &mut params.data[ti];
            let g_in = &grads[k];
            assert_eq!(g_in.len(), buf.len(), "grad shape mismatch");
            match cfg.flavor {
                FtFlavor::Sgd => {
                    for j in 0..buf.len() {
                        let g = g_in[j] + cfg.weight_decay * buf[j];
                        buf[j] -= lr * g;
                    }
                }
                FtFlavor::Adam => {
                    let mk = &mut self.m[k];
                    let vk = &mut self.v[k];
                    for j in 0..buf.len() {
                        let g = g_in[j] + cfg.weight_decay * buf[j];
                        mk[j] = cfg.beta1 * mk[j] + (1.0 - cfg.beta1) * g;
                        vk[j] = cfg.beta2 * vk[j] + (1.0 - cfg.beta2) * g * g;
                        let mhat = mk[j] / (1.0 - cfg.beta1.powf(t));
                        let vhat = vk[j] / (1.0 - cfg.beta2.powf(t));
                        buf[j] -= lr * mhat / (vhat.sqrt() + cfg.adam_eps);
                    }
                }
            }
        }
        self.step += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;

    fn toy() -> ParamStore {
        let mut p = ParamStore::from_specs(vec![TensorDesc {
            name: "w".into(),
            shape: vec![8],
            dtype: "f32".into(),
        }]);
        p.init(0);
        p
    }

    fn quad_grad(p: &ParamStore) -> Vec<Vec<f32>> {
        vec![p.data[0].iter().map(|&x| 2.0 * (x - 1.0)).collect()]
    }

    fn quad_loss(p: &ParamStore) -> f32 {
        p.data[0].iter().map(|&x| (x - 1.0) * (x - 1.0)).sum()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut p = toy();
        let cfg = FtConfig { lr: 0.1, flavor: FtFlavor::Sgd, linear_decay: false, ..Default::default() };
        let mut opt = FtOptimizer::new(cfg, vec![0], &p);
        for _ in 0..100 {
            let g = quad_grad(&p);
            opt.apply(&mut p, &g).unwrap();
        }
        assert!(quad_loss(&p) < 1e-6);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut p = toy();
        let cfg = FtConfig { lr: 0.05, flavor: FtFlavor::Adam, linear_decay: false, ..Default::default() };
        let mut opt = FtOptimizer::new(cfg, vec![0], &p);
        for _ in 0..400 {
            let g = quad_grad(&p);
            opt.apply(&mut p, &g).unwrap();
        }
        assert!(quad_loss(&p) < 1e-3, "{}", quad_loss(&p));
    }

    #[test]
    fn linear_decay_reaches_zero() {
        let p = toy();
        let cfg = FtConfig { lr: 1.0, linear_decay: true, total_steps: 10, ..Default::default() };
        let mut opt = FtOptimizer::new(cfg, vec![0], &p);
        assert!((opt.lr_now() - 1.0).abs() < 1e-6);
        opt.step = 5;
        assert!((opt.lr_now() - 0.5).abs() < 1e-6);
        opt.step = 10;
        assert_eq!(opt.lr_now(), 0.0);
    }

    #[test]
    #[should_panic]
    fn grad_shape_mismatch_panics() {
        let mut p = toy();
        let cfg = FtConfig::default();
        let mut opt = FtOptimizer::new(cfg, vec![0], &p);
        opt.apply(&mut p, &[vec![0.0; 3]]).unwrap();
    }
}
