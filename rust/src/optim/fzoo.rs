//! FZOO — batched-seed one-sided zeroth-order steps with a
//! variance-adaptive step size (Dang et al., 2025, arXiv:2506.09034), the
//! first post-MeZO workload on the [`crate::zkernel`] engine.
//!
//! MeZO (Algorithm 1) spends two forward passes per seed and walks θ four
//! times per seed (perturb +ε, perturb −2ε, restore, update). FZOO
//! restructures the step around a *batch* of one-sided perturbations:
//!
//!  1. one forward at the unperturbed θ gives the anchor loss L₀;
//!  2. each of n seeds costs ONE forward at θ + ε·zᵢ — staged through
//!     [`crate::zkernel::ZEngine::perturb_into`] into a scratch store, so θ
//!     is never touched and never accumulates perturb/restore rounding;
//!  3. the per-seed projected gradients gᵢ = (Lᵢ − L₀)/ε are averaged and
//!     applied in ONE fused pass over θ
//!     ([`crate::zkernel::ZEngine::fzoo_update`]);
//!  4. the step size is normalized by the empirical standard deviation of
//!     the loss differences Δᵢ = Lᵢ − L₀ (FZOO's variance-adaptive rule):
//!     a sharp, consistent loss landscape yields small σ and a confident
//!     large step, a noisy batch yields a cautious one. We express the rule
//!     on the gradient scale, σ_g = σ_Δ/ε, so `lr_eff = lr / σ_g`.
//!
//! Per forward pass, parameter traffic drops from MeZO's 2 z-passes
//! (amortized) to ~1, and the n-seed update costs one pass over θ instead
//! of n. At a matched forward-pass budget B, FZOO takes one step with
//! n = B − 1 seeds where MeZO n-SPSA takes one step with B/2 seeds — the
//! `benches/step_time.rs` group `fzoo_vs_mezo` tracks exactly this.
//!
//! The trajectory contract: every step appends n [`StepRecord`]s, one per
//! seed, carrying the *mean-normalized* projected gradient gᵢ/n and the
//! step's effective learning rate. `Trajectory::replay` (sequential) and
//! `Trajectory::replay_batched` (fused, one pass per step) therefore
//! reconstruct the run from the log alone, and
//! [`crate::optim::mezo::recompute_first_moment`] sees each seed's true
//! contribution to the step.
//!
//! [`FzooConfig::flavor`] selects what consumes the batched estimate:
//! `Sgd` (the plain FZOO mean update), or `Momentum`/`Adam` — the
//! FZOO-Adam variant — which feed the SAME per-coordinate mean
//! g = (Σᵢ gᵢ·zᵢ)/n through the fused moment kernels
//! ([`ZEngine::momentum_update`] / [`ZEngine::adam_update`]) at the
//! variance-adapted step size, one pass over θ + moments per step.

use crate::model::params::ParamStore;
use crate::model::Theta;
use crate::optim::mezo::{Flavor, StepInfo, StepRecord};
use crate::rng::{GaussianStream, Pcg};
use crate::shard::{trainable_flags, ShardPlan};
use crate::zkernel::{SparseMask, ZEngine};
use anyhow::Result;

/// Configuration of the [`Fzoo`] optimizer.
#[derive(Debug, Clone)]
pub struct FzooConfig {
    /// base learning rate η
    pub lr: f32,
    /// one-sided perturbation scale ε
    pub eps: f32,
    /// decoupled weight decay (one term per step, not per seed)
    pub weight_decay: f32,
    /// seeds per step — the batch of one-sided perturbations (n + 1
    /// forward passes per step)
    pub n: usize,
    /// variance-adaptive step size: divide lr by the empirical std of the
    /// per-seed projected gradients (σ_Δ/ε). Off, or with n == 1 (no
    /// variance to estimate), the raw lr applies and the step reduces to
    /// the one-sided MeZO/SPSA update — see tests/properties.rs.
    pub variance_norm: bool,
    /// below this σ_g the normalization is skipped (degenerate batches
    /// where every seed saw the same loss must not explode the step)
    pub sigma_floor: f32,
    /// update rule consuming the batched one-sided estimate: `Sgd` is the
    /// plain FZOO mean update; `Momentum`/`Adam` feed the SAME estimate
    /// (mean of the per-seed gᵢ·zᵢ, one wd term, lr already
    /// variance-normalized) through the fused moment kernels
    /// ([`ZEngine::momentum_update`] / [`ZEngine::adam_update`]) — the
    /// FZOO-Adam variant. Note the replay caveat: like MeZO's own moment
    /// flavors, a Momentum/Adam run's `history` records the raw
    /// estimates (from which the moments are *recomputable*,
    /// [`crate::optim::mezo::recompute_first_moment`]), so plain
    /// `Trajectory::replay` reconstructs Sgd-flavor runs only
    pub flavor: Flavor,
    /// momentum coefficient (Momentum flavor)
    pub momentum: f32,
    /// first-moment EMA coefficient (Adam flavor)
    pub beta1: f32,
    /// second-moment EMA coefficient (Adam flavor)
    pub beta2: f32,
    /// Adam denominator stabilizer
    pub adam_eps: f32,
}

impl Default for FzooConfig {
    fn default() -> Self {
        FzooConfig {
            lr: 1e-3,
            eps: 1e-3,
            weight_decay: 0.0,
            n: 8,
            variance_norm: true,
            sigma_floor: 1e-6,
            flavor: Flavor::Sgd,
            momentum: 0.9,
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
        }
    }
}

/// The FZOO optimizer: batched one-sided seed perturbations, staged
/// evaluation (θ untouched between updates), variance-adaptive step size,
/// single-pass n-seed updates on the [`ZEngine`].
pub struct Fzoo {
    /// configuration (mutable between steps; `n` may be rescheduled)
    pub cfg: FzooConfig,
    /// indices (into ParamStore) of the trainable tensors
    pub trainable: Vec<usize>,
    /// steps taken so far
    pub step: u64,
    /// the blocked/threaded kernel engine every parameter pass runs on;
    /// bit-identical for any `engine.threads` (see zkernel::tests)
    pub engine: ZEngine,
    /// optional sparse SensZOQ mask: when set, staging and the fused
    /// update walk ONLY the masked coordinates (same global z counters as
    /// dense, so a full mask reproduces dense stepping bit for bit). Log
    /// [`SparseMask::digest`] next to `history` so replay can verify mask
    /// identity (`storage::Trajectory::with_mask_digest`).
    pub mask: Option<SparseMask>,
    /// optional shard plan: when set, staging and the fused update walk
    /// the plan's shard segments through the shard-scoped kernels instead
    /// of whole tensors — the same coordinates at the same global z
    /// counters, so a sharded step is bit-identical to the dense step
    /// while each shard's passes are independent dispatches a worker
    /// could own (see [`crate::shard`]). Sgd flavor only, and exclusive
    /// with `mask`; `step` errors otherwise.
    pub shard: Option<ShardPlan>,
    /// (seed, gᵢ/n, lr_eff) per applied seed — the full trajectory, in the
    /// shape `Trajectory::replay`/`replay_batched` reconstruct from
    pub history: Vec<StepRecord>,
    seed_rng: Pcg,
    /// dense first/second moments (Momentum / Adam flavors only)
    m: Option<Vec<Vec<f32>>>,
    v: Option<Vec<Vec<f32>>>,
    /// staging store, allocated once and reused every step — no per-step
    /// clone or reallocation (pointer/capacity identity pinned in the
    /// `scratch_store_is_reused_without_reallocation` test). Dense steps
    /// rewrite the trainable tensors per seed
    /// via `perturb_into`; masked steps rewrite only masked coordinates,
    /// relying on the unmasked ones still mirroring θ (sparse updates
    /// never move them). Content refreshes happen in place: trainable
    /// tensors are re-copied when the active mask digest changes, and the
    /// whole store is re-copied after [`Fzoo::invalidate_scratch`];
    /// non-trainable tensors are otherwise NOT re-mirrored per step — the
    /// optimizer is bound to one store whose frozen tensors stay fixed
    /// between steps. Reallocated only on shape mismatch.
    scratch: Option<ParamStore>,
    /// digest of the mask the scratch content was staged under (None =
    /// dense); a change triggers the in-place trainable-tensor refresh
    scratch_digest: Option<u64>,
    /// set by [`Fzoo::invalidate_scratch`]: full in-place re-copy next step
    scratch_stale: bool,
}

impl Fzoo {
    /// New optimizer; `master_seed` drives the per-step seed stream.
    pub fn new(cfg: FzooConfig, trainable: Vec<usize>, master_seed: u64) -> Fzoo {
        Fzoo {
            cfg,
            trainable,
            step: 0,
            engine: ZEngine::default(),
            mask: None,
            shard: None,
            history: Vec::new(),
            seed_rng: Pcg::new(master_seed),
            m: None,
            v: None,
            scratch: None,
            scratch_digest: None,
            scratch_stale: false,
        }
    }

    /// Hand out the staging store, refreshing its content *in place* when
    /// needed (never reallocating unless the tensor shapes changed):
    ///
    /// * stale ([`Fzoo::invalidate_scratch`]) → copy every tensor from
    ///   `params`;
    /// * active mask digest differs from the one the scratch was staged
    ///   under (dense→masked, masked→dense, or a different mask) → copy
    ///   only the trainable tensors: frozen tensors were copied at build
    ///   and are never written by staging, so they are still exact, while
    ///   trainable tensors may hold a previous mask's ±εz residue on
    ///   coordinates the new mask no longer rewrites;
    /// * otherwise → reuse as-is (dense staging rewrites trainable
    ///   tensors per seed; masked staging rewrites the masked coordinates
    ///   and the unmasked ones still mirror θ, which sparse updates never
    ///   move).
    ///
    /// The reuse check is shape-only: a *different* store with identical
    /// tensor shapes would be accepted with the previous store's frozen
    /// tensors still in the staging copy. The optimizer is therefore
    /// bound to one logical store per run — call
    /// [`Fzoo::invalidate_scratch`] when that assumption breaks.
    fn take_scratch<T: Theta + ?Sized>(&mut self, params: &T) -> ParamStore {
        let digest = self.mask.as_ref().map(|m| m.digest());
        let specs = params.specs();
        let s = match self.scratch.take() {
            Some(mut s)
                if s.data.len() == specs.len()
                    && s.data.iter().zip(specs).all(|(a, b)| a.len() == b.len()) =>
            {
                if self.scratch_stale {
                    for (ti, buf) in s.data.iter_mut().enumerate() {
                        params.read_tensor_into(ti, buf);
                    }
                } else if self.scratch_digest != digest {
                    for &ti in &self.trainable {
                        params.read_tensor_into(ti, &mut s.data[ti]);
                    }
                }
                s
            }
            _ => {
                // fresh allocation: materialize every tensor as f32 (a
                // copy for a dense store, a dequantization for a
                // quantized one)
                let mut s = ParamStore::from_specs(specs.to_vec());
                for (ti, buf) in s.data.iter_mut().enumerate() {
                    params.read_tensor_into(ti, buf);
                }
                s
            }
        };
        self.scratch_stale = false;
        self.scratch_digest = digest;
        s
    }

    /// Mark the staging store stale so the next [`Fzoo::step`] re-copies
    /// every tensor from the parameters it is given (in place — the
    /// allocation is kept). Required after swapping to a different
    /// (same-shaped) `ParamStore` or mutating tensors outside the
    /// optimizer — staging only rewrites what it stages (trainable
    /// tensors; under a mask, only masked coordinates), so external edits
    /// would otherwise silently skew every per-seed loss. Mask changes do
    /// NOT need this: the digest check in `take_scratch` refreshes the
    /// trainable tensors automatically.
    pub fn invalidate_scratch(&mut self) {
        self.scratch_stale = true;
    }

    /// FZOO's variance-adaptive rule: lr / max over the floor of the
    /// sample std of the per-seed projected gradients (σ_Δ/ε). Identity
    /// when `variance_norm` is off, fewer than two seeds, or σ_g at or
    /// below `sigma_floor`.
    fn effective_lr(&self, diffs: &[f32]) -> f32 {
        if !self.cfg.variance_norm || diffs.len() < 2 {
            return self.cfg.lr;
        }
        let n = diffs.len() as f32;
        let mean = diffs.iter().sum::<f32>() / n;
        let var = diffs.iter().map(|&d| (d - mean) * (d - mean)).sum::<f32>() / (n - 1.0);
        let sigma_g = var.sqrt() / self.cfg.eps;
        if sigma_g <= self.cfg.sigma_floor {
            self.cfg.lr
        } else {
            self.cfg.lr / sigma_g
        }
    }

    /// FZOO-momentum / FZOO-Adam: feed the batched one-sided estimate
    /// through the fused moment kernels (the wiring shared with
    /// `MezoSgd`, `optim::mezo::apply_moment_update`). `zs` carries the
    /// *raw* per-seed projected gradients; the kernels take the mean over
    /// `zs.len()` per coordinate (exactly the estimate the Sgd flavor
    /// applies) before the EMA and parameter updates, with the step's
    /// variance-adapted `lr_eff`.
    fn apply_with_moments(
        &mut self,
        params: &mut ParamStore,
        zs: &[(GaussianStream, f32)],
        lr_eff: f32,
    ) {
        let cfg = crate::optim::mezo::MomentCfg {
            flavor: self.cfg.flavor,
            lr: lr_eff,
            wd: self.cfg.weight_decay,
            momentum: self.cfg.momentum,
            beta1: self.cfg.beta1,
            beta2: self.cfg.beta2,
            adam_eps: self.cfg.adam_eps,
            t: (self.step + 1) as f32,
        };
        crate::optim::mezo::apply_moment_update(
            self.engine,
            &self.trainable,
            params,
            zs,
            cfg,
            &mut self.m,
            &mut self.v,
        );
    }

    /// One FZOO step: n + 1 forward passes (`loss` is called once on the
    /// unperturbed θ and once per staged θ + ε·zᵢ), then the whole
    /// n-seed update in a single fused pass over every trainable tensor.
    ///
    /// Generic over [`Theta`]; `loss` always receives a dense
    /// [`ParamStore`] because staging is dense by construction. For a
    /// dense store the anchor pass evaluates `params` itself; for a
    /// quantized store ([`QuantStore`](crate::model::quant::QuantStore))
    /// the anchor is evaluated through the staging store after its
    /// trainable tensors are refreshed from θ — pair quantized stepping
    /// with a sparse mask so every walk stays on the exact f32 overlay.
    /// Moment flavors and shard plans require raw dense buffers and are
    /// rejected with a typed
    /// [`ScopeError`](crate::optim::mezo::ScopeError) on any other store.
    ///
    /// ```
    /// use mezo::model::meta::TensorDesc;
    /// use mezo::model::params::ParamStore;
    /// use mezo::optim::fzoo::{Fzoo, FzooConfig};
    /// let mut p = ParamStore::from_specs(vec![
    ///     TensorDesc { name: "w".into(), shape: vec![16], dtype: "f32".into() },
    /// ]);
    /// p.init(0);
    /// let cfg = FzooConfig { n: 4, ..Default::default() };
    /// let mut opt = Fzoo::new(cfg, vec![0], 42);
    /// let info = opt
    ///     .step(&mut p, |p| Ok(p.data[0].iter().map(|&x| (x - 1.0) * (x - 1.0)).sum()))
    ///     .unwrap();
    /// assert_eq!(info.forward_passes, 5); // anchor + one per seed
    /// assert_eq!(opt.history.len(), 4);   // one record per seed
    /// ```
    pub fn step<T, F>(&mut self, params: &mut T, mut loss: F) -> Result<StepInfo>
    where
        T: Theta + ?Sized,
        F: FnMut(&ParamStore) -> Result<f32>,
    {
        crate::optim::mezo::validate_scoping(
            self.mask.as_ref(),
            self.shard.as_ref(),
            self.cfg.flavor,
            params,
        )?;
        let n = self.cfg.n.max(1);
        let eps = self.cfg.eps;
        let mut scratch = self.take_scratch(params);
        // anchor: one forward at the unperturbed θ. A dense store is
        // evaluated directly; any other store is evaluated through the
        // staging copy, whose trainable tensors are refreshed first (the
        // masked coordinates may still hold the previous step's staged
        // ±εz values).
        let l0 = match params.as_dense() {
            Some(dense) => loss(dense)?,
            None => {
                for &ti in &self.trainable {
                    params.read_tensor_into(ti, &mut scratch.data[ti]);
                }
                loss(&scratch)?
            }
        };
        let mut zs: Vec<(GaussianStream, f32)> = Vec::with_capacity(n);
        let mut seeds: Vec<u64> = Vec::with_capacity(n);
        let mut diffs: Vec<f32> = Vec::with_capacity(n);
        let tr = self
            .shard
            .as_ref()
            .map(|_| trainable_flags(params.specs().len(), &self.trainable));
        for _ in 0..n {
            let seed = self.seed_rng.next_u64();
            let stream = GaussianStream::new(seed);
            // stage θ + ε·z without touching θ (no restore pass, no
            // drift); under a mask only the masked coordinates are
            // rewritten — the rest of scratch already mirrors θ; under a
            // shard plan the segments jointly rewrite every trainable
            // coordinate, one shard-local dispatch per segment
            match (&self.mask, &self.shard) {
                (Some(m), _) => {
                    for &ti in &self.trainable {
                        params.perturb_into_masked(
                            &self.engine,
                            ti,
                            stream,
                            m.indices(ti),
                            eps,
                            &mut scratch.data[ti],
                        );
                    }
                }
                (None, Some(plan)) => {
                    let dp = params
                        .as_dense()
                        .expect("validated at step entry: shard staging requires a dense store");
                    for seg in plan.segments_where(tr.as_ref().unwrap()) {
                        self.engine.perturb_into_shard(
                            stream,
                            dp.offsets[seg.tensor],
                            seg.lo,
                            seg.hi,
                            &dp.data[seg.tensor],
                            eps,
                            &mut scratch.data[seg.tensor],
                        );
                    }
                }
                (None, None) => {
                    for &ti in &self.trainable {
                        params.perturb_into(
                            &self.engine,
                            ti,
                            stream,
                            eps,
                            &mut scratch.data[ti],
                        );
                    }
                }
            }
            let li = loss(&scratch)?;
            diffs.push(li - l0);
            seeds.push(seed);
            zs.push((stream, (li - l0) / eps));
        }
        self.scratch = Some(scratch);

        let lr_eff = self.effective_lr(&diffs);
        match self.cfg.flavor {
            Flavor::Sgd => {
                // the whole n-seed batch in one fused pass per tensor (or
                // per shard segment)
                if let Some(plan) = &self.shard {
                    let dp = params
                        .as_dense_mut()
                        .expect("validated at step entry: shard stepping requires a dense store");
                    for seg in plan.segments_where(tr.as_ref().unwrap()) {
                        self.engine.fzoo_update_shard(
                            &zs,
                            dp.offsets[seg.tensor],
                            seg.lo,
                            seg.hi,
                            &mut dp.data[seg.tensor],
                            lr_eff,
                            self.cfg.weight_decay,
                        );
                    }
                } else {
                    for &ti in &self.trainable {
                        match &self.mask {
                            None => params.fzoo_update(
                                &self.engine,
                                ti,
                                &zs,
                                lr_eff,
                                self.cfg.weight_decay,
                            ),
                            Some(m) => params.fzoo_update_masked(
                                &self.engine,
                                ti,
                                &zs,
                                m.indices(ti),
                                lr_eff,
                                self.cfg.weight_decay,
                            ),
                        }
                    }
                }
            }
            // FZOO-Adam / FZOO-momentum: the same batched one-sided
            // estimate — g = (Σᵢ gᵢ·zᵢ)/n + wd·θ per coordinate — through
            // the fused moment kernels, at the variance-adapted lr
            Flavor::Momentum | Flavor::Adam => {
                let dp = params
                    .as_dense_mut()
                    .expect("validated at step entry: moment flavors require a dense store");
                self.apply_with_moments(dp, &zs, lr_eff)
            }
        }
        // one record per seed, gradient mean-normalized so that replay's
        // θ −= lr·pgrad·z reconstructs this step's update for the Sgd
        // flavor (wd aside). Moment flavors log the SAME estimate — the
        // moments are recomputable from it (B.2,
        // optim::mezo::recompute_first_moment) — but a plain
        // Trajectory::replay of such a log applies the un-EMA'd updates
        // and does NOT land on the trained θ, exactly as for MeZO's own
        // Momentum/Adam flavors.
        let n_f = n as f32;
        let recs: Vec<StepRecord> = seeds
            .iter()
            .zip(&zs)
            .map(|(&seed, &(_, g))| StepRecord { seed, pgrad: g / n_f, lr: lr_eff })
            .collect();
        // n >= 1 makes `recs` non-empty; keep the invariant as a typed
        // error rather than an unwrap panic if it ever breaks (the old
        // `history.last().unwrap()` also read a *prior* step's record if
        // this step somehow logged nothing)
        let last = match recs.last() {
            Some(r) => *r,
            None => anyhow::bail!("FZOO step produced no seed records (n must be >= 1)"),
        };
        self.history.extend(recs);
        self.step += 1;
        crate::obs::metrics::OPT_STEPS.inc();
        crate::obs::metrics::OPT_FORWARD_PASSES.add((n + 1) as u64);
        crate::obs::metrics::OPT_LOSS.set(l0 as f64);
        Ok(StepInfo {
            loss: l0,
            pgrad: last.pgrad,
            seed: last.seed,
            forward_passes: n + 1,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;
    use crate::storage::Trajectory;

    fn toy_params() -> ParamStore {
        let specs = vec![
            TensorDesc { name: "w1".into(), shape: vec![4, 4], dtype: "f32".into() },
            TensorDesc { name: "w2".into(), shape: vec![8], dtype: "f32".into() },
        ];
        let mut p = ParamStore::from_specs(specs);
        p.init(0);
        p
    }

    /// larger-than-one-block tensors so the blocked path really blocks
    fn big_params() -> ParamStore {
        let specs = vec![
            TensorDesc { name: "w1".into(), shape: vec![40, 20], dtype: "f32".into() },
            TensorDesc { name: "w2".into(), shape: vec![300], dtype: "f32".into() },
        ];
        let mut p = ParamStore::from_specs(specs);
        p.init(0);
        p
    }

    fn quad_loss(p: &ParamStore) -> Result<f32> {
        Ok(p.data.iter().flatten().map(|&x| (x - 1.0) * (x - 1.0)).sum())
    }

    #[test]
    fn fzoo_optimizes_quadratic() {
        let mut p = toy_params();
        let cfg = FzooConfig { lr: 2e-2, eps: 1e-3, n: 8, ..Default::default() };
        let mut opt = Fzoo::new(cfg, vec![0, 1], 1);
        let l0 = quad_loss(&p).unwrap();
        for _ in 0..200 {
            opt.step(&mut p, |p| quad_loss(p)).unwrap();
        }
        let l1 = quad_loss(&p).unwrap();
        assert!(l1 < l0 * 0.2, "l0={} l1={}", l0, l1);
        assert_eq!(opt.history.len(), 200 * 8);
        assert_eq!(opt.step, 200);
    }

    #[test]
    fn step_counts_forward_passes_and_anchor_loss() {
        let mut p = toy_params();
        let cfg = FzooConfig { n: 4, ..Default::default() };
        let mut opt = Fzoo::new(cfg, vec![0, 1], 2);
        let l_before = quad_loss(&p).unwrap();
        let info = opt.step(&mut p, |p| quad_loss(p)).unwrap();
        assert_eq!(info.forward_passes, 5);
        // the reported loss is the anchor L(θ) before the update
        assert_eq!(info.loss.to_bits(), l_before.to_bits());
    }

    #[test]
    fn theta_is_untouched_between_updates() {
        // staging through perturb_into means the only write to θ is the
        // final fused update: a loss that records the params it sees must
        // observe the SAME unperturbed θ at the anchor as before the step
        let mut p = toy_params();
        let before = p.data.clone();
        let cfg = FzooConfig { lr: 0.0, n: 3, ..Default::default() };
        let mut opt = Fzoo::new(cfg, vec![0, 1], 3);
        opt.step(&mut p, |p| quad_loss(p)).unwrap();
        // lr = 0: the update is θ −= 0·(…) which can only flip -0.0 signs;
        // numeric equality is exact
        for (a, b) in p.data.iter().flatten().zip(before.iter().flatten()) {
            assert_eq!(*a, *b, "{} vs {}", a, b);
        }
    }

    #[test]
    fn variance_norm_shrinks_steps_on_noisy_batches() {
        // same trajectory of seeds; the normalized run must use a smaller
        // effective lr than the raw one when σ_g > 1
        let mut p1 = big_params();
        let mut p2 = big_params();
        let cfg_raw = FzooConfig { lr: 1e-2, n: 6, variance_norm: false, ..Default::default() };
        let cfg_norm = FzooConfig { lr: 1e-2, n: 6, variance_norm: true, ..Default::default() };
        let mut raw = Fzoo::new(cfg_raw, vec![0, 1], 7);
        let mut norm = Fzoo::new(cfg_norm, vec![0, 1], 7);
        raw.step(&mut p1, |p| quad_loss(p)).unwrap();
        norm.step(&mut p2, |p| quad_loss(p)).unwrap();
        // identical seeds and anchor => identical pgrad records up to the
        // lr column
        for (a, b) in raw.history.iter().zip(&norm.history) {
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits());
        }
        let lr_raw = raw.history[0].lr;
        let lr_norm = norm.history[0].lr;
        assert_eq!(lr_raw, 1e-2);
        assert_ne!(lr_norm.to_bits(), lr_raw.to_bits());
        // the quadratic's gradient norm is ~10 here, so σ_g >> 1 and the
        // adaptive lr must be smaller
        assert!(lr_norm < lr_raw, "lr_norm={} lr_raw={}", lr_norm, lr_raw);
    }

    #[test]
    fn trajectory_is_bit_identical_across_thread_counts() {
        // the determinism contract extended to FZOO: same master seed =>
        // same history (bitwise) and same final θ (bitwise) at 1/2/8
        // threads, variance normalization and weight decay on
        let mut reference: Option<(Vec<StepRecord>, Vec<Vec<f32>>)> = None;
        for threads in [1usize, 2, 8] {
            let mut p = big_params();
            let cfg = FzooConfig {
                lr: 5e-3,
                eps: 1e-3,
                weight_decay: 1e-4,
                n: 5,
                variance_norm: true,
                ..Default::default()
            };
            let mut opt = Fzoo::new(cfg, vec![0, 1], 0xF00);
            opt.engine = ZEngine::with_threads(threads);
            for _ in 0..5 {
                opt.step(&mut p, |p| quad_loss(p)).unwrap();
            }
            if reference.is_none() {
                reference = Some((opt.history.clone(), p.data.clone()));
            } else {
                let (hist, data) = reference.as_ref().unwrap();
                assert_eq!(hist.len(), opt.history.len());
                for (a, b) in hist.iter().zip(&opt.history) {
                    assert_eq!(a.seed, b.seed, "t={}", threads);
                    assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits(), "t={}", threads);
                    assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "t={}", threads);
                }
                for (x, y) in data.iter().flatten().zip(p.data.iter().flatten()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "t={}: {} vs {}", threads, x, y);
                }
            }
        }
    }

    #[test]
    fn pool_and_scope_dispatch_produce_identical_fzoo_runs() {
        // the persistent-pool dispatcher is a pure scheduling change: the
        // whole optimizer loop (staging, anchor, fused update, variance
        // norm) lands on identical bits vs the retained scope path
        for threads in [2usize, 8] {
            let mut runs: Vec<(Vec<StepRecord>, Vec<Vec<f32>>)> = Vec::new();
            for scoped in [false, true] {
                let mut p = big_params();
                let cfg = FzooConfig {
                    lr: 5e-3,
                    eps: 1e-3,
                    weight_decay: 1e-4,
                    n: 4,
                    variance_norm: true,
                    ..Default::default()
                };
                let mut opt = Fzoo::new(cfg, vec![0, 1], 0xD00D);
                opt.engine = if scoped {
                    ZEngine::with_threads_scoped(threads)
                } else {
                    ZEngine::with_threads(threads)
                };
                for _ in 0..4 {
                    opt.step(&mut p, |p| quad_loss(p)).unwrap();
                }
                runs.push((opt.history.clone(), p.data.clone()));
            }
            let (pool_hist, pool_data) = &runs[0];
            let (scope_hist, scope_data) = &runs[1];
            assert_eq!(pool_hist.len(), scope_hist.len());
            for (a, b) in pool_hist.iter().zip(scope_hist) {
                assert_eq!(a.seed, b.seed, "t={}", threads);
                assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits(), "t={}", threads);
                assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "t={}", threads);
            }
            for (x, y) in pool_data.iter().flatten().zip(scope_data.iter().flatten()) {
                assert_eq!(x.to_bits(), y.to_bits(), "t={}: {} vs {}", threads, x, y);
            }
        }
    }

    #[test]
    fn scratch_store_is_reused_without_reallocation() {
        // the staging store is allocated once; steps, mask swaps and
        // invalidation all refresh it in place (pointer/capacity identity)
        let mut p = big_params();
        let cfg = FzooConfig { lr: 1e-3, n: 3, ..Default::default() };
        let mut opt = Fzoo::new(cfg, vec![0, 1], 5);
        opt.step(&mut p, |p| quad_loss(p)).unwrap();
        let ids: Vec<(*const f32, usize)> = opt
            .scratch
            .as_ref()
            .unwrap()
            .data
            .iter()
            .map(|v| (v.as_ptr(), v.capacity()))
            .collect();
        for _ in 0..10 {
            opt.step(&mut p, |p| quad_loss(p)).unwrap();
        }
        // switching to a sparse mask refreshes content, not allocation
        opt.mask = Some(
            crate::zkernel::SparseMask::top_k(
                &p,
                &[0, 1],
                64,
                crate::zkernel::Sensitivity::Magnitude,
            )
            .unwrap(),
        );
        for _ in 0..5 {
            opt.step(&mut p, |p| quad_loss(p)).unwrap();
        }
        // explicit invalidation re-copies in place too
        opt.invalidate_scratch();
        opt.step(&mut p, |p| quad_loss(p)).unwrap();
        let after: Vec<(*const f32, usize)> = opt
            .scratch
            .as_ref()
            .unwrap()
            .data
            .iter()
            .map(|v| (v.as_ptr(), v.capacity()))
            .collect();
        assert_eq!(ids, after, "staging store was reallocated");
    }

    #[test]
    fn full_mask_fzoo_is_bitwise_identical_to_dense() {
        for threads in [1usize, 2, 8] {
            let cfg = FzooConfig {
                lr: 5e-3,
                eps: 1e-3,
                weight_decay: 1e-4,
                n: 4,
                variance_norm: true,
                ..Default::default()
            };
            let mut p_dense = big_params();
            let mut dense = Fzoo::new(cfg.clone(), vec![0, 1], 0xFACE);
            dense.engine = ZEngine::with_threads(threads);
            let mut p_masked = big_params();
            let mut masked = Fzoo::new(cfg, vec![0, 1], 0xFACE);
            masked.engine = ZEngine::with_threads(threads);
            masked.mask = Some(crate::zkernel::SparseMask::full(&p_masked, &[0, 1]));
            for _ in 0..4 {
                dense.step(&mut p_dense, |p| quad_loss(p)).unwrap();
                masked.step(&mut p_masked, |p| quad_loss(p)).unwrap();
            }
            for (a, b) in dense.history.iter().zip(&masked.history) {
                assert_eq!(a.seed, b.seed, "t={}", threads);
                assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits(), "t={}", threads);
                assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "t={}", threads);
            }
            for (x, y) in p_dense.data.iter().flatten().zip(p_masked.data.iter().flatten()) {
                assert_eq!(x.to_bits(), y.to_bits(), "t={}: {} vs {}", threads, x, y);
            }
        }
    }

    #[test]
    fn sparse_masked_fzoo_is_bit_identical_across_threads_and_freezes_rest() {
        let mut reference: Option<(Vec<StepRecord>, Vec<Vec<f32>>)> = None;
        for threads in [1usize, 2, 8] {
            let mut p = big_params();
            let p0 = p.clone();
            let mask = crate::zkernel::SparseMask::top_k(
                &p,
                &[0, 1],
                150,
                crate::zkernel::Sensitivity::Magnitude,
            )
            .unwrap();
            let cfg = FzooConfig {
                lr: 5e-3,
                eps: 1e-3,
                weight_decay: 1e-4,
                n: 5,
                variance_norm: true,
                ..Default::default()
            };
            let mut opt = Fzoo::new(cfg, vec![0, 1], 0xD00D);
            opt.engine = ZEngine::with_threads(threads);
            opt.mask = Some(mask.clone());
            for _ in 0..4 {
                opt.step(&mut p, |p| quad_loss(p)).unwrap();
            }
            // unmasked coordinates are exactly frozen
            for (ti, (now, then)) in p.data.iter().zip(&p0.data).enumerate() {
                let mut hit = vec![false; now.len()];
                for &i in mask.indices(ti) {
                    hit[i as usize] = true;
                }
                for (j, (a, b)) in now.iter().zip(then).enumerate() {
                    if !hit[j] {
                        assert_eq!(a.to_bits(), b.to_bits(), "t={} coord {}:{}", threads, ti, j);
                    }
                }
            }
            if let Some((hist, data)) = &reference {
                assert_eq!(hist.len(), opt.history.len());
                for (a, b) in hist.iter().zip(&opt.history) {
                    assert_eq!(a.seed, b.seed, "t={}", threads);
                    assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits(), "t={}", threads);
                    assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "t={}", threads);
                }
                for (x, y) in data.iter().flatten().zip(p.data.iter().flatten()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "t={}", threads);
                }
            } else {
                reference = Some((opt.history.clone(), p.data.clone()));
            }
        }
    }

    #[test]
    fn fzoo_adam_and_momentum_flavors_optimize() {
        for flavor in [Flavor::Momentum, Flavor::Adam] {
            let mut p = toy_params();
            let lr = if flavor == Flavor::Adam { 2e-2 } else { 5e-3 };
            let cfg = FzooConfig { lr, eps: 1e-3, n: 6, flavor, ..Default::default() };
            let mut opt = Fzoo::new(cfg, vec![0, 1], 6);
            let l0 = quad_loss(&p).unwrap();
            for _ in 0..150 {
                opt.step(&mut p, |p| quad_loss(p)).unwrap();
            }
            let l1 = quad_loss(&p).unwrap();
            assert!(l1 < l0 * 0.6, "{:?}: l0={} l1={}", flavor, l0, l1);
        }
    }

    #[test]
    fn fzoo_adam_trajectory_is_bit_identical_across_threads() {
        // the FZOO-Adam satellite pin: same master seed => same history
        // (bitwise) and same final θ (bitwise) at threads 1/2/8, variance
        // normalization and weight decay on
        for flavor in [Flavor::Momentum, Flavor::Adam] {
            let mut reference: Option<(Vec<StepRecord>, Vec<Vec<f32>>)> = None;
            for threads in [1usize, 2, 8] {
                let mut p = big_params();
                let cfg = FzooConfig {
                    lr: 5e-3,
                    eps: 1e-3,
                    weight_decay: 1e-4,
                    n: 5,
                    variance_norm: true,
                    flavor,
                    ..Default::default()
                };
                let mut opt = Fzoo::new(cfg, vec![0, 1], 0xADA);
                opt.engine = ZEngine::with_threads(threads);
                for _ in 0..5 {
                    opt.step(&mut p, |p| quad_loss(p)).unwrap();
                }
                if let Some((hist, data)) = &reference {
                    assert_eq!(hist.len(), opt.history.len());
                    for (a, b) in hist.iter().zip(&opt.history) {
                        assert_eq!(a.seed, b.seed, "{:?} t={}", flavor, threads);
                        assert_eq!(
                            a.pgrad.to_bits(),
                            b.pgrad.to_bits(),
                            "{:?} t={}",
                            flavor,
                            threads
                        );
                        assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "{:?} t={}", flavor, threads);
                    }
                    for (x, y) in data.iter().flatten().zip(p.data.iter().flatten()) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "{:?} t={}: {} vs {}",
                            flavor,
                            threads,
                            x,
                            y
                        );
                    }
                } else {
                    reference = Some((opt.history.clone(), p.data.clone()));
                }
            }
        }
    }

    #[test]
    fn fzoo_adam_single_step_is_the_fused_adam_update_of_the_batched_estimate() {
        // wiring pin: one FZOO-Adam step == adam_update applied to the
        // step's raw per-seed gradients (history pgrads are gᵢ/n) at the
        // recorded lr, from zero moments, bit for bit
        use crate::zkernel::AdamParams;
        let mut p = toy_params();
        let p0 = p.clone();
        let (wd, n) = (1e-4f32, 4usize);
        let cfg = FzooConfig {
            lr: 1e-2,
            eps: 1e-3,
            weight_decay: wd,
            n,
            flavor: Flavor::Adam,
            ..Default::default()
        };
        let mut opt = Fzoo::new(cfg.clone(), vec![0, 1], 0xBADA);
        opt.step(&mut p, |p| quad_loss(p)).unwrap();
        assert_eq!(opt.history.len(), n);
        let zs: Vec<(GaussianStream, f32)> = opt
            .history
            .iter()
            .map(|r| (GaussianStream::new(r.seed), r.pgrad * n as f32))
            .collect();
        let engine = ZEngine::default();
        let mut want = p0.clone();
        let mut m: Vec<Vec<f32>> = vec![vec![0.0; 16], vec![0.0; 8]];
        let mut v: Vec<Vec<f32>> = vec![vec![0.0; 16], vec![0.0; 8]];
        for (k, &ti) in [0usize, 1].iter().enumerate() {
            engine.adam_update(
                &zs,
                want.offsets[ti],
                &mut want.data[ti],
                &mut m[k],
                &mut v[k],
                AdamParams {
                    lr: opt.history[0].lr,
                    wd,
                    beta1: cfg.beta1,
                    beta2: cfg.beta2,
                    eps: cfg.adam_eps,
                    t: 1.0,
                    n: n as f32,
                },
            );
        }
        for (x, y) in p.data.iter().flatten().zip(want.data.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{} vs {}", x, y);
        }
    }

    #[test]
    fn sharded_fzoo_step_is_bitwise_identical_to_dense() {
        use crate::shard::ShardPlan;
        for k in [1usize, 2, 4] {
            for threads in [1usize, 2, 8] {
                let cfg = FzooConfig {
                    lr: 5e-3,
                    eps: 1e-3,
                    weight_decay: 1e-4,
                    n: 4,
                    variance_norm: true,
                    ..Default::default()
                };
                let mut p_dense = big_params();
                let mut dense = Fzoo::new(cfg.clone(), vec![0, 1], 0x5AFE);
                dense.engine = ZEngine::with_threads(threads);
                let mut p_shard = big_params();
                let mut sharded = Fzoo::new(cfg, vec![0, 1], 0x5AFE);
                sharded.engine = ZEngine::with_threads(threads);
                sharded.shard = Some(ShardPlan::new(&p_shard, k).unwrap());
                for _ in 0..4 {
                    dense.step(&mut p_dense, |p| quad_loss(p)).unwrap();
                    sharded.step(&mut p_shard, |p| quad_loss(p)).unwrap();
                }
                for (a, b) in dense.history.iter().zip(&sharded.history) {
                    assert_eq!(a.seed, b.seed, "k={} t={}", k, threads);
                    assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits(), "k={} t={}", k, threads);
                    assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "k={} t={}", k, threads);
                }
                for (x, y) in p_dense.data.iter().flatten().zip(p_shard.data.iter().flatten()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "k={} t={}: {} vs {}", k, threads, x, y);
                }
            }
        }
    }

    #[test]
    fn fzoo_flavor_and_shard_guards_error_loudly() {
        use crate::shard::ShardPlan;
        let mut p = toy_params();
        // mask + moment flavor bails
        let cfg = FzooConfig { flavor: Flavor::Adam, ..Default::default() };
        let mut opt = Fzoo::new(cfg, vec![0, 1], 1);
        opt.mask = Some(crate::zkernel::SparseMask::full(&p, &[0, 1]));
        let err = opt.step(&mut p, |p| quad_loss(p)).unwrap_err();
        assert!(err.to_string().contains("Sgd flavor"), "{}", err);
        // shard + moment flavor bails
        let cfg = FzooConfig { flavor: Flavor::Momentum, ..Default::default() };
        let mut opt = Fzoo::new(cfg, vec![0, 1], 1);
        opt.shard = Some(ShardPlan::new(&p, 2).unwrap());
        let err = opt.step(&mut p, |p| quad_loss(p)).unwrap_err();
        assert!(err.to_string().contains("Sgd flavor"), "{}", err);
        // mask + shard bails
        let mut opt = Fzoo::new(FzooConfig::default(), vec![0, 1], 1);
        opt.mask = Some(crate::zkernel::SparseMask::full(&p, &[0, 1]));
        opt.shard = Some(ShardPlan::new(&p, 2).unwrap());
        let err = opt.step(&mut p, |p| quad_loss(p)).unwrap_err();
        assert!(err.to_string().contains("cannot combine"), "{}", err);
        // a plan built for another store bails
        let mut opt = Fzoo::new(FzooConfig::default(), vec![0, 1], 1);
        opt.shard = Some(ShardPlan::new(&big_params(), 2).unwrap());
        assert!(opt.step(&mut p, |p| quad_loss(p)).is_err());
    }

    #[test]
    fn every_scoping_x_moment_flavor_combination_is_typed_and_touches_nothing() {
        use crate::optim::mezo::ScopeError;
        use crate::shard::ShardPlan;
        let mut p = toy_params();
        let before = p.data.clone();
        for flavor in [Flavor::Momentum, Flavor::Adam] {
            for shard in [false, true] {
                let cfg = FzooConfig { flavor, ..Default::default() };
                let mut opt = Fzoo::new(cfg, vec![0, 1], 1);
                if shard {
                    opt.shard = Some(ShardPlan::new(&p, 2).unwrap());
                } else {
                    opt.mask = Some(crate::zkernel::SparseMask::full(&p, &[0, 1]));
                }
                let err = opt.step(&mut p, |p| quad_loss(p)).unwrap_err();
                let typed = err.downcast_ref::<ScopeError>().expect("typed ScopeError");
                let want = if shard {
                    ScopeError::ShardRequiresSgd(flavor)
                } else {
                    ScopeError::MaskRequiresSgd(flavor)
                };
                assert_eq!(*typed, want, "{}", err);
                assert!(opt.history.is_empty(), "no silent partial step");
                assert_eq!(p.data, before, "θ untouched on the error path");
            }
        }
        // mask + shard together, every flavor: the mask-flavor guard has
        // precedence for moment flavors, Sgd reaches the exclusivity arm
        for flavor in [Flavor::Sgd, Flavor::Momentum, Flavor::Adam] {
            let cfg = FzooConfig { flavor, ..Default::default() };
            let mut opt = Fzoo::new(cfg, vec![0, 1], 1);
            opt.mask = Some(crate::zkernel::SparseMask::full(&p, &[0, 1]));
            opt.shard = Some(ShardPlan::new(&p, 2).unwrap());
            let err = opt.step(&mut p, |p| quad_loss(p)).unwrap_err();
            let want = match flavor {
                Flavor::Sgd => ScopeError::MaskShardExclusive,
                other => ScopeError::MaskRequiresSgd(other),
            };
            assert_eq!(*err.downcast_ref::<ScopeError>().unwrap(), want, "{}", err);
            assert_eq!(p.data, before, "θ untouched on the error path");
        }
    }

    #[test]
    fn mask_swap_refreshes_scratch_so_losses_stay_honest() {
        // run masked with mask A (leaves +εz residue on A's coordinates in
        // scratch), swap to a disjoint mask B, and verify the next step's
        // staged losses see θ — not A's residue — on every un-B coordinate.
        // A run with B from scratch must produce the identical trajectory.
        let build = |warm_mask: Option<&[u32]>| -> (Vec<StepRecord>, Vec<Vec<f32>>) {
            let mut p = big_params();
            let cfg =
                FzooConfig { lr: 1e-3, eps: 1e-3, n: 3, variance_norm: false, ..Default::default() };
            let mut opt = Fzoo::new(cfg, vec![0, 1], 0xAB);
            if let Some(idxs) = warm_mask {
                // warm-up step under mask A — its only lasting effect on
                // the optimizer should be the scratch store's content
                let mask_a = crate::zkernel::SparseMask::from_indices(vec![
                    idxs.to_vec(),
                    Vec::new(),
                ])
                .unwrap();
                opt.mask = Some(mask_a);
                opt.step(&mut p, |p| quad_loss(p)).unwrap();
                // reset θ, history and the seed stream so both runs
                // compare the B phase only
                p = big_params();
                opt.history.clear();
            }
            opt.seed_rng = Pcg::new(0xCD);
            let mask_b = crate::zkernel::SparseMask::from_indices(vec![
                vec![500, 501, 502, 600],
                vec![7, 9],
            ])
            .unwrap();
            opt.mask = Some(mask_b);
            for _ in 0..3 {
                opt.step(&mut p, |p| quad_loss(p)).unwrap();
            }
            (opt.history.clone(), p.data.clone())
        };
        let (h_fresh, p_fresh) = build(None);
        let (h_warm, p_warm) = build(Some(&[0, 1, 2, 3, 90]));
        assert_eq!(h_fresh.len(), h_warm.len());
        for (a, b) in h_fresh.iter().zip(&h_warm) {
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.pgrad.to_bits(), b.pgrad.to_bits(), "stale scratch skewed a loss");
        }
        for (x, y) in p_fresh.iter().flatten().zip(p_warm.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn recompute_first_moment_understands_fzoo_history() {
        // with β = 1 the momentum-style recomputation is Σᵢ pgradᵢ·zᵢ over
        // the whole log; for a constant-lr FZOO run (variance_norm off,
        // wd = 0) the net parameter change is exactly −lr · that sum —
        // i.e. the B.2 moment-from-log machinery reads FZOO records as-is
        let mut p = toy_params();
        let p0 = p.clone();
        let lr = 5e-3f32;
        let cfg =
            FzooConfig { lr, eps: 1e-3, n: 3, variance_norm: false, ..Default::default() };
        let mut opt = Fzoo::new(cfg, vec![0, 1], 11);
        for _ in 0..10 {
            opt.step(&mut p, |p| quad_loss(p)).unwrap();
        }
        let m = crate::optim::mezo::recompute_first_moment(&p, &[0, 1], &opt.history, 1.0, false);
        for (k, &ti) in [0usize, 1].iter().enumerate() {
            for j in 0..p.data[ti].len() {
                let want = p0.data[ti][j] - lr * m[k][j];
                assert!(
                    (p.data[ti][j] - want).abs() < 1e-5,
                    "{} vs {}",
                    p.data[ti][j],
                    want
                );
            }
        }
    }

    #[test]
    fn replay_batched_reconstructs_fzoo_run() {
        // wd = 0: the log is the whole update, so batched replay lands on
        // the trained parameters (up to f32 re-association, no perturb
        // rounding at all — θ was never perturbed in place)
        let mut trained = toy_params();
        let n = 4usize;
        let cfg = FzooConfig { lr: 1e-2, eps: 1e-3, n, ..Default::default() };
        let mut opt = Fzoo::new(cfg, vec![0, 1], 9);
        for _ in 0..30 {
            opt.step(&mut trained, |p| quad_loss(p)).unwrap();
        }
        let traj = Trajectory::from_run(vec!["w1".into(), "w2".into()], &opt.history);
        let mut replayed = toy_params();
        traj.replay_batched(&mut replayed, n).unwrap();
        for (a, b) in trained.data.iter().flatten().zip(replayed.data.iter().flatten()) {
            assert!((a - b).abs() < 1e-5, "{} vs {}", a, b);
        }
    }
}
