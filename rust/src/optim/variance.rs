//! Variance- and expectation-modified SPSA (Appendix B.3/B.4).
//!
//! Definition 6: perturb with d⁻¹⊙z, update with d⊙z — unbiased, rescaled
//! variance. Definition 7: perturb with d⁻¹⊙z, update with z — a biased
//! estimator of the *normalized* gradient. `d` is a per-parameter-group
//! scale (one group per tensor here; the paper groups per layer), set to
//! either the group's parameter norm or a ZO estimate of its gradient norm
//! (Proposition 1: perturb only group ℓ and read |ℓ₊−ℓ₋|/2ε).

use crate::model::params::ParamStore;
use crate::optim::mezo::{perturb_tensors_with, StepRecord};
use crate::rng::{GaussianStream, Pcg};
use crate::zkernel::ZEngine;
use anyhow::Result;

/// Where the per-parameter-group scale d comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DSource {
    /// d_g = ||θ_g|| (parameter norm, Table 9)
    ParamNorm,
    /// d_g = ZO estimate of ||∇_g L|| (Prop. 1, Tables 8/10)
    GradNormZo,
}

/// Which modified estimator the update uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Definition 6 (unbiased, modified variance)
    Variance,
    /// Definition 7 (normalized-gradient expectation)
    Expectation,
}

/// Configuration of the [`ModifiedSpsa`] estimator variants.
#[derive(Debug, Clone)]
pub struct ModifiedSpsaConfig {
    /// learning rate
    pub lr: f32,
    /// perturbation scale ε
    pub eps: f32,
    /// which modified estimator (Definition 6 or 7)
    pub mode: Mode,
    /// where the per-group scale d comes from
    pub d_source: DSource,
    /// re-estimate d every `refresh_every` steps (0 = only once)
    pub refresh_every: usize,
}

/// Variance/expectation-modified SPSA optimizer (Appendix B.3/B.4).
pub struct ModifiedSpsa {
    /// configuration (mutable between steps)
    pub cfg: ModifiedSpsaConfig,
    /// indices (into ParamStore) of the trainable tensors
    pub trainable: Vec<usize>,
    /// per-trainable-tensor scale d_g (clamped away from zero)
    pub d: Vec<f32>,
    /// blocked/threaded kernel engine for all z passes
    pub engine: ZEngine,
    seed_rng: Pcg,
    /// steps taken so far
    pub step: u64,
    /// (seed, projected-grad, lr) per step — the replayable trajectory
    pub history: Vec<StepRecord>,
}

impl ModifiedSpsa {
    /// New optimizer; `seed` drives the per-step seed stream.
    pub fn new(cfg: ModifiedSpsaConfig, trainable: Vec<usize>, seed: u64) -> ModifiedSpsa {
        let d = vec![1.0; trainable.len()];
        ModifiedSpsa {
            cfg,
            trainable,
            d,
            engine: ZEngine::default(),
            seed_rng: Pcg::new(seed),
            step: 0,
            history: Vec::new(),
        }
    }

    /// Proposition 1: ZO estimate of the gradient norm of group g —
    /// perturb only that tensor and read |ℓ₊ − ℓ₋| / 2ε. 2·G forward passes.
    pub fn estimate_grad_norms<F>(
        &mut self,
        params: &mut ParamStore,
        mut loss: F,
    ) -> Result<Vec<f32>>
    where
        F: FnMut(&ParamStore) -> Result<f32>,
    {
        let eps = self.cfg.eps;
        let mut norms = Vec::with_capacity(self.trainable.len());
        for &ti in &self.trainable.clone() {
            let seed = self.seed_rng.next_u64();
            perturb_tensors_with(&self.engine, params, &[ti], seed, eps);
            let lp = loss(params)?;
            perturb_tensors_with(&self.engine, params, &[ti], seed, -2.0 * eps);
            let lm = loss(params)?;
            perturb_tensors_with(&self.engine, params, &[ti], seed, eps);
            norms.push(((lp - lm) / (2.0 * eps)).abs());
        }
        Ok(norms)
    }

    /// Recompute the per-group scales d_g from the configured source and
    /// normalize them to mean 1 (so the lr keeps its meaning).
    pub fn refresh_d<F>(&mut self, params: &mut ParamStore, loss: F) -> Result<()>
    where
        F: FnMut(&ParamStore) -> Result<f32>,
    {
        let d: Vec<f32> = match self.cfg.d_source {
            DSource::ParamNorm => self
                .trainable
                .iter()
                .map(|&ti| params.tensor_norm(ti))
                .collect(),
            DSource::GradNormZo => self.estimate_grad_norms(params, loss)?,
        };
        // normalize scales to mean 1 so the lr keeps its meaning, and clamp
        let mean = d.iter().sum::<f32>() / d.len().max(1) as f32;
        let mean = if mean > 1e-12 { mean } else { 1.0 };
        self.d = d.iter().map(|&x| (x / mean).max(1e-3)).collect();
        Ok(())
    }

    /// perturb θ_g += scale · d_mult_g · z — a per-tensor axpy on the
    /// kernel engine, with the group scale folded into the coefficient
    /// (same multiplication order as the scalar loop it replaced).
    fn perturb_scaled(&self, params: &mut ParamStore, seed: u64, scale: f32, inverse: bool) {
        let stream = GaussianStream::new(seed);
        for (k, &ti) in self.trainable.iter().enumerate() {
            let dg = if inverse { 1.0 / self.d[k] } else { self.d[k] };
            self.engine.axpy_z(stream, params.offsets[ti], &mut params.data[ti], scale * dg);
        }
    }

    /// One modified-SPSA step (two forward passes + any d refresh);
    /// returns the mean of the two perturbed losses.
    pub fn step<F>(&mut self, params: &mut ParamStore, mut loss: F) -> Result<f32>
    where
        F: FnMut(&ParamStore) -> Result<f32>,
    {
        if self.step == 0
            || (self.cfg.refresh_every > 0 && self.step % self.cfg.refresh_every as u64 == 0)
        {
            if self.step == 0 || self.cfg.refresh_every > 0 {
                self.refresh_d(params, &mut loss)?;
            }
        }
        let eps = self.cfg.eps;
        let seed = self.seed_rng.next_u64();
        // perturb with d^{-1} ⊙ z
        self.perturb_scaled(params, seed, eps, true);
        let lp = loss(params)?;
        self.perturb_scaled(params, seed, -2.0 * eps, true);
        let lm = loss(params)?;
        self.perturb_scaled(params, seed, eps, true);
        let g = (lp - lm) / (2.0 * eps);
        // update with d ⊙ z (Def. 6) or plain z (Def. 7): θ −= (lr·g·dg)·z
        // is an axpy with a negated coefficient (IEEE negation is exact)
        let stream = GaussianStream::new(seed);
        for (k, &ti) in self.trainable.iter().enumerate() {
            let dg = match self.cfg.mode {
                Mode::Variance => self.d[k],
                Mode::Expectation => 1.0,
            };
            self.engine.axpy_z(
                stream,
                params.offsets[ti],
                &mut params.data[ti],
                -(self.cfg.lr * g * dg),
            );
        }
        self.history.push(StepRecord { seed, pgrad: g, lr: self.cfg.lr });
        self.step += 1;
        Ok(0.5 * (lp + lm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;

    fn toy() -> ParamStore {
        let mut p = ParamStore::from_specs(vec![
            TensorDesc { name: "a".into(), shape: vec![12], dtype: "f32".into() },
            TensorDesc { name: "b".into(), shape: vec![6], dtype: "f32".into() },
        ]);
        p.init(1);
        p
    }

    // loss with very different per-group curvature
    fn loss(p: &ParamStore) -> Result<f32> {
        let a: f32 = p.data[0].iter().map(|&x| 10.0 * (x - 1.0) * (x - 1.0)).sum();
        let b: f32 = p.data[1].iter().map(|&x| 0.1 * (x + 1.0) * (x + 1.0)).sum();
        Ok(a + b)
    }

    #[test]
    fn grad_norm_estimate_orders_groups() {
        let mut p = toy();
        let cfg = ModifiedSpsaConfig {
            lr: 1e-3,
            eps: 1e-3,
            mode: Mode::Variance,
            d_source: DSource::GradNormZo,
            refresh_every: 0,
        };
        let mut opt = ModifiedSpsa::new(cfg, vec![0, 1], 2);
        // average a few estimates: group 0 has ~100x the gradient scale
        let mut n0 = 0.0;
        let mut n1 = 0.0;
        for _ in 0..20 {
            let est = opt.estimate_grad_norms(&mut p, loss).unwrap();
            n0 += est[0];
            n1 += est[1];
        }
        assert!(n0 > n1 * 3.0, "n0={} n1={}", n0, n1);
    }

    #[test]
    fn variance_mode_still_optimizes() {
        let mut p = toy();
        let l0 = loss(&p).unwrap();
        let cfg = ModifiedSpsaConfig {
            lr: 2e-3,
            eps: 1e-3,
            mode: Mode::Variance,
            d_source: DSource::ParamNorm,
            refresh_every: 50,
        };
        let mut opt = ModifiedSpsa::new(cfg, vec![0, 1], 3);
        for _ in 0..400 {
            opt.step(&mut p, loss).unwrap();
        }
        assert!(loss(&p).unwrap() < l0 * 0.5);
    }

    #[test]
    fn expectation_mode_runs() {
        let mut p = toy();
        let cfg = ModifiedSpsaConfig {
            lr: 1e-3,
            eps: 1e-3,
            mode: Mode::Expectation,
            d_source: DSource::GradNormZo,
            refresh_every: 0,
        };
        let mut opt = ModifiedSpsa::new(cfg, vec![0, 1], 4);
        for _ in 0..50 {
            opt.step(&mut p, loss).unwrap();
        }
        assert_eq!(opt.history.len(), 50);
    }
}
