//! Closed-vocabulary word-level tokenizer over the synthetic world.
//!
//! The vocabulary is *structured*: every content word carries latent
//! attributes (topic, polarity, entity type) that both the pre-training
//! corpus generator and the downstream task generators draw from — this is
//! what makes prompt-based fine-tuning work here for the same reason it
//! works in the paper (the prompt maps the task into patterns the model has
//! already seen in pre-training; DESIGN.md §2.1).
//!
//! Vocab layout (512 ids, matching the AOT artifacts' vocab dim):
//!   specials, function/template words, label words, topic nouns (6×30),
//!   polarity adjectives (40+40+20), persons (30), places (30), verbs (20),
//!   digit words (10), then reserved/unused padding ids.

/// Total vocabulary size — fixed at 512 ids to match the vocab dimension
/// the AOT model artifacts were compiled against.
pub const VOCAB_SIZE: usize = 512;

/// Padding token id.
pub const PAD: u32 = 0;
/// Mask token id (the MLM pre-training target slot).
pub const MASK: u32 = 1;
/// Beginning-of-sequence token id.
pub const BOS: u32 = 2;
/// End-of-sequence token id.
pub const EOS: u32 = 3;
/// Separator token id (between prompt segments).
pub const SEP: u32 = 4;

/// The six latent topics every content noun is drawn from.
pub const TOPICS: [&str; 6] = ["sports", "science", "politics", "music", "food", "travel"];
/// Content nouns per topic (`sports_n0` … `sports_n29`, …).
pub const NOUNS_PER_TOPIC: usize = 30;
/// Positive-polarity adjectives (`pos_a0` …).
pub const N_POS_ADJ: usize = 40;
/// Negative-polarity adjectives (`neg_a0` …).
pub const N_NEG_ADJ: usize = 40;
/// Neutral-polarity adjectives (`neu_a0` …).
pub const N_NEU_ADJ: usize = 20;
/// Person entities (`person0` … — coref / QA subjects).
pub const N_PERSON: usize = 30;
/// Place entities (`place0` …).
pub const N_PLACE: usize = 30;
/// Content verbs (`verb0` …).
pub const N_VERB: usize = 20;
/// Digit words (`num0` … `num9` — the arithmetic task's operands).
pub const N_DIGIT: usize = 10;

/// Function / template words every prompt is built from.
pub const FUNCTION_WORDS: [&str; 28] = [
    "the", "a", "it", "was", "is", "and", "or", "not", ".", ",", "?", ":",
    "about", "so", "because", "question", "answer", "passage", "review",
    "went", "to", "scored", "same", "correct", "does", "did", "refer", "in",
];

/// Label words (verbalizers) — single tokens, as the paper's prompts require.
pub const LABEL_WORDS: [&str; 11] = [
    "great", "good", "okay", "bad", "terrible", // sentiment scale
    "Yes", "No", "Maybe",                        // NLI / boolean
    "he", "she", "they",                         // coref fillers
];

/// The closed word-level vocabulary: id ↔ word tables plus the category
/// range markers the attribute accessors ([`Vocab::polarity`],
/// [`Vocab::topic_of_noun`], …) decode ids against. Categories occupy
/// contiguous id ranges `[start, next_start)` in the layout order the
/// module doc lists.
#[derive(Debug, Clone)]
pub struct Vocab {
    words: Vec<String>,
    index: std::collections::HashMap<String, u32>,
    /// First function/template word id (specials end here).
    pub fn_start: u32,
    /// First label-word (verbalizer) id.
    pub label_start: u32,
    /// First topic-noun id (topic labels sit between labels and nouns).
    pub noun_start: u32,
    /// First positive-adjective id.
    pub pos_adj_start: u32,
    /// First negative-adjective id.
    pub neg_adj_start: u32,
    /// First neutral-adjective id.
    pub neu_adj_start: u32,
    /// First person-entity id.
    pub person_start: u32,
    /// First place-entity id.
    pub place_start: u32,
    /// First content-verb id.
    pub verb_start: u32,
    /// First digit-word id.
    pub digit_start: u32,
    /// One past the last assigned id; ids in `used..VOCAB_SIZE` are
    /// reserved `[UNUSEDi]` padding.
    pub used: u32,
}

impl Vocab {
    /// The one standard vocabulary every model artifact was compiled against.
    pub fn standard() -> Vocab {
        let mut words: Vec<String> =
            ["[PAD]", "[MASK]", "[BOS]", "[EOS]", "[SEP]"].iter().map(|s| s.to_string()).collect();
        let fn_start = words.len() as u32;
        words.extend(FUNCTION_WORDS.iter().map(|s| s.to_string()));
        let label_start = words.len() as u32;
        words.extend(LABEL_WORDS.iter().map(|s| s.to_string()));
        words.extend(TOPICS.iter().map(|s| s.to_string())); // topic labels
        let noun_start = words.len() as u32;
        for t in TOPICS.iter() {
            for i in 0..NOUNS_PER_TOPIC {
                words.push(format!("{}_n{}", t, i));
            }
        }
        let pos_adj_start = words.len() as u32;
        for i in 0..N_POS_ADJ {
            words.push(format!("pos_a{}", i));
        }
        let neg_adj_start = words.len() as u32;
        for i in 0..N_NEG_ADJ {
            words.push(format!("neg_a{}", i));
        }
        let neu_adj_start = words.len() as u32;
        for i in 0..N_NEU_ADJ {
            words.push(format!("neu_a{}", i));
        }
        let person_start = words.len() as u32;
        for i in 0..N_PERSON {
            words.push(format!("person{}", i));
        }
        let place_start = words.len() as u32;
        for i in 0..N_PLACE {
            words.push(format!("place{}", i));
        }
        let verb_start = words.len() as u32;
        for i in 0..N_VERB {
            words.push(format!("verb{}", i));
        }
        let digit_start = words.len() as u32;
        for i in 0..N_DIGIT {
            words.push(format!("num{}", i));
        }
        let used = words.len() as u32;
        assert!(
            (used as usize) <= VOCAB_SIZE,
            "lexicon {} exceeds vocab {}",
            used,
            VOCAB_SIZE
        );
        while words.len() < VOCAB_SIZE {
            words.push(format!("[UNUSED{}]", words.len()));
        }
        let index = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        Vocab {
            words,
            index,
            fn_start,
            label_start,
            noun_start,
            pos_adj_start,
            neg_adj_start,
            neu_adj_start,
            person_start,
            place_start,
            verb_start,
            digit_start,
            used,
        }
    }

    /// Id of `word`; panics on a word outside the closed vocabulary.
    pub fn id(&self, word: &str) -> u32 {
        *self
            .index
            .get(word)
            .unwrap_or_else(|| panic!("unknown word '{}'", word))
    }

    /// Surface form of `id`.
    pub fn word(&self, id: u32) -> &str {
        &self.words[id as usize]
    }

    /// Whitespace-split `text` into ids (every word must be in-vocab).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    /// Space-join `ids` back into their surface forms.
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.word(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    // ----- category accessors ------------------------------------------
    /// Id of the label word naming `topic` (the topic-classification
    /// verbalizer).
    pub fn topic_label(&self, topic: usize) -> u32 {
        // topic labels sit right after LABEL_WORDS
        self.label_start + LABEL_WORDS.len() as u32 + topic as u32
    }
    /// Id of noun `i` of `topic`.
    pub fn noun(&self, topic: usize, i: usize) -> u32 {
        self.noun_start + (topic * NOUNS_PER_TOPIC + i) as u32
    }
    /// Topic index of a noun id; `None` if `id` is not a topic noun.
    pub fn topic_of_noun(&self, id: u32) -> Option<usize> {
        if id >= self.noun_start && id < self.pos_adj_start {
            Some(((id - self.noun_start) as usize) / NOUNS_PER_TOPIC)
        } else {
            None
        }
    }
    /// Id of positive adjective `i`.
    pub fn pos_adj(&self, i: usize) -> u32 {
        self.pos_adj_start + i as u32
    }
    /// Id of negative adjective `i`.
    pub fn neg_adj(&self, i: usize) -> u32 {
        self.neg_adj_start + i as u32
    }
    /// Id of neutral adjective `i`.
    pub fn neu_adj(&self, i: usize) -> u32 {
        self.neu_adj_start + i as u32
    }
    /// polarity of an adjective id: +1 / -1 / 0; None if not an adjective.
    pub fn polarity(&self, id: u32) -> Option<i32> {
        if id >= self.pos_adj_start && id < self.neg_adj_start {
            Some(1)
        } else if id >= self.neg_adj_start && id < self.neu_adj_start {
            Some(-1)
        } else if id >= self.neu_adj_start && id < self.person_start {
            Some(0)
        } else {
            None
        }
    }
    /// Id of person entity `i`.
    pub fn person(&self, i: usize) -> u32 {
        self.person_start + i as u32
    }
    /// Id of place entity `i`.
    pub fn place(&self, i: usize) -> u32 {
        self.place_start + i as u32
    }
    /// Id of content verb `i`.
    pub fn verb(&self, i: usize) -> u32 {
        self.verb_start + i as u32
    }
    /// Id of digit word `i` (`num{i}`).
    pub fn digit(&self, i: usize) -> u32 {
        self.digit_start + i as u32
    }
    /// Numeric value of a digit-word id; `None` if not a digit word.
    pub fn digit_value(&self, id: u32) -> Option<usize> {
        if id >= self.digit_start && id < self.digit_start + N_DIGIT as u32 {
            Some((id - self.digit_start) as usize)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_vocab_fits_and_roundtrips() {
        let v = Vocab::standard();
        assert_eq!(v.words.len(), VOCAB_SIZE);
        assert!(v.used <= VOCAB_SIZE as u32);
        assert_eq!(v.id("[PAD]"), PAD);
        assert_eq!(v.id("[MASK]"), MASK);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let v = Vocab::standard();
        let text = "the sports_n3 was pos_a7 . it was great";
        let ids = v.encode(text);
        assert_eq!(v.decode(&ids), text);
    }

    #[test]
    fn category_attributes() {
        let v = Vocab::standard();
        assert_eq!(v.polarity(v.pos_adj(0)), Some(1));
        assert_eq!(v.polarity(v.neg_adj(39)), Some(-1));
        assert_eq!(v.polarity(v.neu_adj(5)), Some(0));
        assert_eq!(v.polarity(v.person(0)), None);
        assert_eq!(v.topic_of_noun(v.noun(2, 29)), Some(2));
        assert_eq!(v.topic_of_noun(v.pos_adj(0)), None);
        assert_eq!(v.digit_value(v.digit(7)), Some(7));
        for (t, name) in TOPICS.iter().enumerate() {
            assert_eq!(v.word(v.topic_label(t)), *name);
        }
    }

    #[test]
    #[should_panic]
    fn unknown_word_panics() {
        Vocab::standard().id("definitely_not_a_word");
    }
}
