//! Experiment registry: one entry per paper table/figure (DESIGN.md §5).
//!
//! `Ctx` owns the runtime + cached pre-trained checkpoints; `run_method`
//! executes one (task × method) cell the way the paper's protocol does
//! (prompted data, constant-LR MeZO with best-val checkpointing, linear
//! -decay FT, candidate scoring / greedy decode). Each `table*` function in
//! [`tables`] prints the paper-shaped rows and writes a JSON record under
//! `runs/results/` for EXPERIMENTS.md.

pub mod tables;

use crate::baselines::{self, linear_probe::{LogReg, LogRegCfg}};
use crate::data::tasks::{generate, GenOpts, Task, TaskData, TaskType};
use crate::eval::Evaluator;
use crate::model::params::ParamStore;
use crate::optim::ft::{FtConfig, FtFlavor, FtOptimizer};
use crate::optim::mezo::{Flavor, MezoConfig, MezoSgd};
use crate::optim::MezoStepper;
use crate::runtime::{vec_f32, Runtime};
use crate::tokenizer::Vocab;
use crate::train::pretrain::{self, PretrainCfg};
use crate::train::{train_ft, train_zo, TrainCfg};
use crate::util::json::{obj, Json};
use anyhow::{anyhow, Result};
use std::path::PathBuf;

/// Shared experiment context: the PJRT runtime, the vocabulary, the
/// quick/full scale switch, and where result JSON lands.
pub struct Ctx {
    /// The artifact runtime every cell executes against.
    pub rt: Runtime,
    /// The synthetic-task vocabulary (fixed across all experiments).
    pub vocab: Vocab,
    /// Quick mode: shrunk step counts / test sets for CI smoke runs.
    pub quick: bool,
    /// Directory receiving one `<name>.json` record per table.
    pub out_dir: PathBuf,
    /// Pre-training steps for checkpoints built on demand.
    pub pretrain_steps: usize,
}

impl Ctx {
    /// Build a context from the environment: `Runtime::from_env()` plus
    /// a `runs/results` output directory (override the root with
    /// `MEZO_RUNS`).
    pub fn new(quick: bool) -> Result<Ctx> {
        let rt = Runtime::from_env()?;
        let out_dir = PathBuf::from(
            std::env::var("MEZO_RUNS").unwrap_or_else(|_| "runs".to_string()),
        )
        .join("results");
        std::fs::create_dir_all(&out_dir)?;
        Ok(Ctx { rt, vocab: Vocab::standard(), quick, out_dir, pretrain_steps: 3000 })
    }

    /// Pick the full-run or quick-mode value of a size knob.
    pub fn scale(&self, full: usize, quick: usize) -> usize {
        if self.quick {
            quick
        } else {
            full
        }
    }

    /// The artifact name for a (family, size, mode, tuning) cell.
    pub fn art(&self, family: &str, size: &str, mode: &str, tuning: &str) -> String {
        pretrain::artifact_name(family, size, mode, tuning)
    }

    /// Ensure the pre-trained checkpoint for (family, size) exists.
    pub fn ensure_pretrained(&self, family: &str, size: &str) -> Result<()> {
        pretrain::pretrained(
            &self.rt,
            family,
            size,
            &PretrainCfg { steps: self.pretrain_steps, ..Default::default() },
        )?;
        Ok(())
    }

    /// An [`Evaluator`] over the cell's loss artifact (plus the logits
    /// artifact when one exists for greedy decoding).
    pub fn evaluator(&self, family: &str, size: &str, tuning: &str) -> Result<Evaluator> {
        let loss = self.rt.load(&self.art(family, size, "loss", tuning))?;
        let logits_name = self.art(family, size, "logits", tuning);
        let logits = if self.rt.artifact_exists(&logits_name) {
            Some(self.rt.load(&logits_name)?)
        } else {
            None
        };
        Ok(Evaluator::new(loss, logits, family == "mlm"))
    }

    /// Pre-trained params shaped for `tuning`'s artifact ABI. Prefix params
    /// are initialised from real activations (paper Appendix E.5) unless
    /// `random_prefix`.
    pub fn params(&self, family: &str, size: &str, tuning: &str, seed: u64,
                  random_prefix: bool) -> Result<ParamStore> {
        self.ensure_pretrained(family, size)?;
        let name = self.art(family, size, "loss", tuning);
        let mut params = pretrain::params_for(&self.rt, &name, family, size, seed)?;
        if tuning == "prefix" && !random_prefix {
            self.init_prefix_from_activations(family, size, &mut params, seed)?;
        }
        Ok(params)
    }

    /// Paper's prefix init: pass random real tokens through the model and
    /// copy their per-layer key/value activations into the prefix tensors.
    pub fn init_prefix_from_activations(
        &self,
        family: &str,
        size: &str,
        params: &mut ParamStore,
        seed: u64,
    ) -> Result<()> {
        let kv_name = format!("{}_{}_prefix_kv_b1_s8", family, size);
        if !self.rt.artifact_exists(&kv_name) {
            return Ok(()); // fall back to random init
        }
        let art = self.rt.load(&kv_name)?;
        let m = art.meta.prefix_len;
        // random non-special tokens
        let mut rng = crate::rng::Pcg::new(seed ^ 0x9A7);
        let mut batch = crate::data::batch::Batch::zeros(1, m);
        for t in 0..m {
            batch.input_ids[t] = rng.range(5, self.vocab.used as usize) as i32;
            batch.attn_mask[t] = 1.0;
        }
        // base params only (kv artifact is tuning=prefix, same ABI as params)
        let out = art.run(params, Some(&batch), &[])?;
        let n_layers = art.meta.dims.n_layers;
        for i in 0..n_layers {
            let k = vec_f32(&out[2 * i])?;
            let v = vec_f32(&out[2 * i + 1])?;
            params.get_mut(&format!("layer{}.prefix.k", i)).copy_from_slice(&k);
            params.get_mut(&format!("layer{}.prefix.v", i)).copy_from_slice(&v);
        }
        Ok(())
    }

    /// Generate a task's prompted train/val/test splits at this
    /// context's scale.
    pub fn task_data(&self, task: Task, n_train: usize, seed: u64) -> TaskData {
        let n_test = self.scale(192, 96);
        generate(
            task,
            &self.vocab,
            GenOpts { seed, n_train, n_val: 64, n_test, prompt: true },
        )
    }

    /// Write one result record to `<out_dir>/<name>.json`.
    pub fn write_json(&self, name: &str, value: &Json) -> Result<()> {
        let path = self.out_dir.join(format!("{}.json", name));
        std::fs::write(&path, value.to_string())?;
        Ok(())
    }
}

/// One method cell in a results table.
#[derive(Debug, Clone)]
pub enum Method {
    /// No adaptation: evaluate the pre-trained model as-is.
    ZeroShot,
    /// In-context learning with `demos` demonstrations in the prompt.
    Icl {
        /// demonstrations prepended per test example
        demos: usize,
    },
    /// Logistic-regression linear probe over frozen features.
    LinearProbe,
    /// MeZO fine-tuning under a tuning mode (full / prefix / lora).
    Mezo {
        /// parameter-efficiency mode: "full", "prefix" or "lora"
        tuning: &'static str,
        /// update rule (SGD / momentum / Adam)
        flavor: Flavor,
        /// explicit hyperparameters; `None` = the per-tuning defaults
        cfg: Option<MezoConfig>,
    },
    /// Backprop fine-tuning under a tuning mode.
    Ft {
        /// parameter-efficiency mode: "full", "prefix" or "lora"
        tuning: &'static str,
        /// optimizer (SGD / Adam)
        flavor: FtFlavor,
        /// explicit learning rate; `None` = [`default_ft_lr`]
        lr: Option<f32>,
    },
    /// Table 19's linear-probe-then-MeZO warm start.
    LpMezo,
}

impl Method {
    /// MeZO-SGD under `tuning` with default hyperparameters.
    pub fn mezo(tuning: &'static str) -> Method {
        Method::Mezo { tuning, flavor: Flavor::Sgd, cfg: None }
    }
    /// The method's row label, matching the paper's tables.
    pub fn name(&self) -> String {
        match self {
            Method::ZeroShot => "Zero-shot".into(),
            Method::Icl { .. } => "ICL".into(),
            Method::LinearProbe => "LP".into(),
            Method::LpMezo => "LP-MeZO".into(),
            Method::Mezo { tuning, flavor, .. } => match (flavor, *tuning) {
                (Flavor::Adam, _) => "MeZO-Adam".into(),
                (_, "full") => "MeZO".into(),
                (_, t) => format!("MeZO ({})", t),
            },
            Method::Ft { tuning, flavor, .. } => match (flavor, *tuning) {
                (FtFlavor::Sgd, "full") => "FT (SGD)".into(),
                (_, "full") => "FT".into(),
                (_, t) => format!("FT ({})", t),
            },
        }
    }
}

/// Default MeZO hyperparameters per tuning mode (Appendix E.3 grids,
/// re-centred for this model scale by the sweep recorded in EXPERIMENTS.md).
pub fn default_mezo_cfg(tuning: &str, steps: usize) -> MezoConfig {
    let (lr, eps) = match tuning {
        "prefix" => (1e-2, 1e-1),
        "lora" => (3e-3, 1e-2),
        _ => (1e-4, 1e-3),
    };
    MezoConfig { lr, eps, total_steps: steps, ..Default::default() }
}

/// Default backprop-FT learning rate per tuning mode.
pub fn default_ft_lr(tuning: &str) -> f32 {
    match tuning {
        "prefix" | "lora" => 1e-3,
        _ => 1e-4,
    }
}

/// What one executed cell reports back to its table.
#[derive(Debug, Clone, Default)]
pub struct RunOut {
    /// test metric (accuracy or F1, task-dependent)
    pub score: f64,
    /// exact-match rate for generation tasks (0 elsewhere)
    pub em: f64,
    /// best validation metric seen during training
    pub best_val: f64,
    /// total forward passes consumed (the ZO budget axis)
    pub forward_passes: usize,
    /// (step, val metric) checkpoints
    pub val_curve: Vec<(usize, f64)>,
    /// (step, train loss) samples
    pub train_curve: Vec<(usize, f32)>,
}

/// Execute one (family, size, task, method) cell.
pub fn run_method(
    ctx: &Ctx,
    family: &str,
    size: &str,
    task: Task,
    data: &TaskData,
    method: &Method,
    seed: u64,
) -> Result<RunOut> {
    let mezo_steps = ctx.scale(3000, 600);
    let ft_steps = ctx.scale(300, 80);
    match method {
        Method::ZeroShot => {
            let ev = ctx.evaluator(family, size, "full")?;
            let params = ctx.params(family, size, "full", seed, true)?;
            let r = ev.evaluate(&params, task, &data.test)?;
            Ok(RunOut { score: r.score, em: r.em, ..Default::default() })
        }
        Method::Icl { demos } => {
            let ev = ctx.evaluator(family, size, "full")?;
            let params = ctx.params(family, size, "full", seed, true)?;
            let score = baselines::icl(&ev, &params, task, &data.train, &data.test, *demos)?;
            Ok(RunOut { score, ..Default::default() })
        }
        Method::LinearProbe => {
            if task.task_type() != TaskType::Classification {
                return Err(anyhow!("LP supports classification tasks only"));
            }
            let ev = ctx.evaluator(family, size, "full")?;
            let params = ctx.params(family, size, "full", seed, true)?;
            let (lp, _) = fit_linear_probe(&ev, &params, data)?;
            let test_refs: Vec<&_> = data.test.iter().collect();
            let feats = ev.features(&params, &test_refs)?;
            let golds: Vec<usize> = data.test.iter().map(|e| e.label).collect();
            Ok(RunOut { score: lp.accuracy(&feats, &golds), ..Default::default() })
        }
        Method::Mezo { tuning, flavor, cfg } => {
            let ev = ctx.evaluator(family, size, tuning)?;
            let mut params = ctx.params(family, size, tuning, seed, false)?;
            let loss_art = ev.loss_art.clone();
            let trainable = params.indices_of(&loss_art.meta.trainable);
            let mut mcfg = cfg.clone().unwrap_or_else(|| default_mezo_cfg(tuning, mezo_steps));
            mcfg.flavor = *flavor;
            if *flavor == Flavor::Adam && cfg.is_none() {
                mcfg.lr = 1e-4;
            }
            let steps = mcfg.total_steps;
            let mut opt = MezoStepper::new(MezoSgd::new(mcfg, trainable, seed ^ 0x2E20));
            let tcfg = TrainCfg { steps, eval_every: (steps / 5).max(1), seed, ..Default::default() };
            let tr = train_zo(&mut opt, &mut params, &loss_art, &ev, task,
                              &data.train, &data.val, &tcfg)?;
            let r = ev.evaluate(&params, task, &data.test)?;
            Ok(RunOut {
                score: r.score,
                em: r.em,
                best_val: tr.best_val,
                forward_passes: tr.forward_passes,
                val_curve: tr.val_curve,
                train_curve: tr.curve,
            })
        }
        Method::Ft { tuning, flavor, lr } => {
            let ev = ctx.evaluator(family, size, tuning)?;
            let mut params = ctx.params(family, size, tuning, seed, false)?;
            let grad_art = ctx.rt.load(&ctx.art(family, size, "grad", tuning))?;
            let trainable = params.indices_of(&grad_art.meta.trainable);
            let fcfg = FtConfig {
                lr: lr.unwrap_or_else(|| default_ft_lr(tuning)),
                flavor: *flavor,
                total_steps: ft_steps,
                ..Default::default()
            };
            let mut opt = FtOptimizer::new(fcfg, trainable, &params);
            let tcfg = TrainCfg { steps: ft_steps, eval_every: (ft_steps / 4).max(1), seed,
                                  ..Default::default() };
            let tr = train_ft(&mut opt, &mut params, &grad_art, &ev, task,
                              &data.train, &data.val, &tcfg)?;
            let r = ev.evaluate(&params, task, &data.test)?;
            Ok(RunOut {
                score: r.score,
                em: r.em,
                best_val: tr.best_val,
                forward_passes: tr.forward_passes,
                val_curve: tr.val_curve,
                train_curve: tr.curve,
            })
        }
        Method::LpMezo => {
            // Table 19: linear-probe-then-MeZO. The tied LM head makes the
            // label-word embedding rows an exact linear head over features,
            // so we write the fitted LP weights into those rows, then MeZO.
            let ev = ctx.evaluator(family, size, "full")?;
            let mut params = ctx.params(family, size, "full", seed, true)?;
            let (lp, label_tokens) = fit_linear_probe(&ev, &params, data)?;
            inject_lp_head(&mut params, &lp, &label_tokens);
            let loss_art = ev.loss_art.clone();
            let trainable = params.indices_of(&loss_art.meta.trainable);
            let mcfg = default_mezo_cfg("full", mezo_steps);
            let steps = mcfg.total_steps;
            let mut opt = MezoStepper::new(MezoSgd::new(mcfg, trainable, seed ^ 0x17));
            let tcfg = TrainCfg { steps, eval_every: (steps / 5).max(1), seed, ..Default::default() };
            let tr = train_zo(&mut opt, &mut params, &loss_art, &ev, task,
                              &data.train, &data.val, &tcfg)?;
            let r = ev.evaluate(&params, task, &data.test)?;
            Ok(RunOut { score: r.score, best_val: tr.best_val,
                        forward_passes: tr.forward_passes, ..Default::default() })
        }
    }
}

/// Fit the LP classifier on train features; returns it plus the label-word
/// token ids (single-token candidates assumed for classification tasks).
fn fit_linear_probe(
    ev: &Evaluator,
    params: &ParamStore,
    data: &TaskData,
) -> Result<(LogReg, Vec<u32>)> {
    let train_refs: Vec<&_> = data.train.iter().collect();
    let feats = ev.features(params, &train_refs)?;
    let labels: Vec<usize> = data.train.iter().map(|e| e.label).collect();
    let n_classes = data.task.n_classes();
    let lp = LogReg::fit(&feats, &labels, n_classes, &LogRegCfg::default())?;
    let label_tokens: Vec<u32> = data.train[0]
        .candidates
        .iter()
        .map(|c| c[0])
        .collect();
    Ok((lp, label_tokens))
}

/// Write LP class weights into the label-word embedding rows (tied head).
fn inject_lp_head(params: &mut ParamStore, lp: &LogReg, label_tokens: &[u32]) {
    let d = lp.d;
    let emb = params.get_mut("embed.tok");
    for (c, &tok) in label_tokens.iter().enumerate() {
        let row = tok as usize * d;
        // blend: keep the pre-trained direction, add the LP direction
        for j in 0..d {
            emb[row + j] = 0.5 * emb[row + j] + 0.5 * lp.w[c][j] as f32;
        }
    }
}

/// Format a fraction as the paper's "90.5"-style percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

/// Fixed-width table printer.
pub fn print_table(title: &str, header: &[String], rows: &[Vec<String>]) {
    println!("\n=== {} ===", title);
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(header));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// JSON record for a table: {title, header, rows}.
pub fn table_json(title: &str, header: &[String], rows: &[Vec<String>]) -> Json {
    obj(vec![
        ("title", Json::from(title)),
        ("header", Json::Arr(header.iter().map(|h| Json::from(h.as_str())).collect())),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::from(c.as_str())).collect()))
                    .collect(),
            ),
        ),
    ])
}
