//! One driver per paper exhibit. Each prints the paper-shaped rows and
//! persists a JSON record under runs/results/ (consumed by EXPERIMENTS.md).
//!
//! Progress goes through the structured event log ([`crate::obs::event`]):
//! per-cell ticks as sub-line dots, per-row completions as info events
//! (same stderr text as the `eprintln!` lines they replaced, so CI greps
//! keep working; `MEZO_LOG=warn` silences them, `MEZO_OBS_JSONL` records
//! them). Table output itself is program output and stays on stdout.

use super::{default_mezo_cfg, pct, print_table, run_method, table_json, Ctx, Method};
use crate::obs::event;
use crate::data::tasks::{generate, GenOpts, Task, TaskType, OPT_TASKS, ROBERTA_TASKS};
use crate::memory::{self, Method as MemMethod, PROFILED_METHODS, SIZES};
use crate::optim::ft::FtFlavor;
use crate::optim::mezo::{Flavor, MezoConfig, MezoSgd};
use crate::optim::variance::{DSource, Mode, ModifiedSpsa, ModifiedSpsaConfig};
use crate::optim::MezoStepper;
use crate::train::{train_zo, Objective, TrainCfg};
use crate::util::json::{obj, Json};
use crate::util::stats::Timer;
use anyhow::Result;

fn na() -> String {
    "-".into()
}

fn cell(r: Result<super::RunOut>) -> String {
    match r {
        Ok(o) => pct(o.score),
        Err(_) => na(),
    }
}

/// Table 1 / Figure 1: the 11-task suite on the AR family.
pub fn table1(ctx: &Ctx, family: &str, size: &str) -> Result<()> {
    let methods = vec![
        Method::ZeroShot,
        Method::Icl { demos: 3 },
        Method::LinearProbe,
        Method::mezo("full"),
        Method::mezo("lora"),
        Method::mezo("prefix"),
        Method::Ft { tuning: "full", flavor: FtFlavor::Adam, lr: None },
    ];
    let n_train = ctx.scale(256, 128);
    let mut header = vec!["Method".to_string()];
    header.extend(OPT_TASKS.iter().map(|t| t.name().to_string()));
    let mut rows = Vec::new();
    for m in &methods {
        let mut row = vec![m.name()];
        for &task in OPT_TASKS.iter() {
            let data = ctx.task_data(task, n_train, 0);
            row.push(cell(run_method(ctx, family, size, task, &data, m, 0)));
            event::progress_tick();
        }
        event::info("exp", &format!(" {}", m.name()));
        rows.push(row);
    }
    let title = format!("Table 1 / Figure 1 — {}-{} on the 11-task suite", family, size);
    print_table(&title, &header, &rows);
    ctx.write_json("table1", &table_json(&title, &header, &rows))?;
    Ok(())
}

/// Table 18 / Figure 2: masked-LM family, k-shot (16 / 512).
pub fn table18(ctx: &Ctx, size: &str) -> Result<()> {
    let family = "mlm";
    let ks = [16usize, 512];
    let methods: Vec<Method> = if ctx.quick {
        vec![
            Method::ZeroShot,
            Method::LinearProbe,
            Method::mezo("full"),
            Method::Mezo { tuning: "full", flavor: Flavor::Adam, cfg: None },
            Method::Ft { tuning: "full", flavor: FtFlavor::Adam, lr: None },
        ]
    } else {
        vec![
            Method::ZeroShot,
            Method::LinearProbe,
            Method::mezo("full"),
            Method::mezo("lora"),
            Method::mezo("prefix"),
            Method::Mezo { tuning: "full", flavor: Flavor::Adam, cfg: None },
            Method::Ft { tuning: "full", flavor: FtFlavor::Adam, lr: None },
            Method::Ft { tuning: "lora", flavor: FtFlavor::Adam, lr: None },
            Method::Ft { tuning: "prefix", flavor: FtFlavor::Adam, lr: None },
        ]
    };
    let mut header = vec!["k".to_string(), "Method".to_string()];
    header.extend(ROBERTA_TASKS.iter().map(|t| t.name().to_string()));
    let mut rows = Vec::new();
    for &k in &ks {
        for m in &methods {
            let mut row = vec![format!("{}", k), m.name()];
            for &task in ROBERTA_TASKS.iter() {
                let n = k * task.n_classes();
                let data = ctx.task_data(task, n, 0);
                row.push(cell(run_method(ctx, family, size, task, &data, m, 0)));
                event::progress_tick();
            }
            event::info("exp", &format!(" k={} {}", k, m.name()));
            rows.push(row);
        }
    }
    let title = format!("Table 18 / Figure 2 — {}-{} k-shot suite", family, size);
    print_table(&title, &header, &rows);
    ctx.write_json("table18", &table_json(&title, &header, &rows))?;
    Ok(())
}

/// Table 2 / Table 20: scaling the AR family up the size ladder.
pub fn table2(ctx: &Ctx) -> Result<()> {
    let sizes: Vec<&str> = if ctx.quick { vec!["small"] } else { vec!["small", "base"] };
    let tasks: Vec<Task> = if ctx.quick {
        vec![Task::Sst2, Task::BoolQ]
    } else {
        vec![Task::Sst2, Task::Rte, Task::BoolQ, Task::Wsc, Task::Wic, Task::Squad]
    };
    let methods = vec![Method::ZeroShot, Method::Icl { demos: 3 }, Method::mezo("full")];
    let mut header = vec!["Size".to_string(), "Method".to_string()];
    header.extend(tasks.iter().map(|t| t.name().to_string()));
    let mut rows = Vec::new();
    for size in &sizes {
        for m in &methods {
            let mut row = vec![size.to_string(), m.name()];
            for &task in &tasks {
                let data = ctx.task_data(task, ctx.scale(256, 128), 0);
                row.push(cell(run_method(ctx, "ar", size, task, &data, m, 0)));
                event::progress_tick();
            }
            event::info("exp", &format!(" {} {}", size, m.name()));
            rows.push(row);
        }
    }
    let title = "Table 2 / 20 — scaling MeZO up the size ladder (ar family)";
    print_table(title, &header, &rows);
    ctx.write_json("table2", &table_json(title, &header, &rows))?;
    Ok(())
}

/// Table 3: non-differentiable objectives (accuracy / F1).
pub fn table3(ctx: &Ctx, family: &str, size: &str) -> Result<()> {
    let cls_tasks = [Task::Sst2, Task::Sst5, Task::Snli, Task::Trec];
    let mut header = vec!["Objective".to_string()];
    header.extend(cls_tasks.iter().map(|t| t.name().to_string()));
    header.push("squad".into());

    let steps = ctx.scale(1500, 400);
    let mut rows = Vec::new();
    // zero-shot row
    {
        let mut row = vec!["Zero-shot".to_string()];
        for &task in cls_tasks.iter() {
            let data = ctx.task_data(task, 64, 0);
            row.push(cell(run_method(ctx, family, size, task, &data, &Method::ZeroShot, 0)));
        }
        let data = ctx.task_data(Task::Squad, 64, 0);
        row.push(cell(run_method(ctx, family, size, Task::Squad, &data, &Method::ZeroShot, 0)));
        rows.push(row);
    }
    // cross-entropy rows (FT + MeZO)
    for m in [
        Method::Ft { tuning: "full", flavor: FtFlavor::Adam, lr: None },
        Method::mezo("full"),
    ] {
        let mut row = vec![format!("Cross entropy ({})", m.name())];
        for &task in cls_tasks.iter() {
            let data = ctx.task_data(task, ctx.scale(256, 128), 0);
            row.push(cell(run_method(ctx, family, size, task, &data, &m, 0)));
            event::progress_tick();
        }
        let data = ctx.task_data(Task::Squad, ctx.scale(256, 128), 0);
        row.push(cell(run_method(ctx, family, size, Task::Squad, &data, &m, 0)));
        event::info("exp", &format!(" {}", m.name()));
        rows.push(row);
    }
    // non-differentiable objective row: accuracy for cls, F1 for squad
    {
        let mut row = vec!["Accuracy/F1 (MeZO)".to_string()];
        for &task in cls_tasks.iter().chain([Task::Squad].iter()) {
            let data = ctx.task_data(task, ctx.scale(256, 128), 0);
            let ev = ctx.evaluator(family, size, "full")?;
            let mut params = ctx.params(family, size, "full", 0, true)?;
            let loss_art = ev.loss_art.clone();
            let trainable = params.indices_of(&loss_art.meta.trainable);
            let mut cfg = default_mezo_cfg("full", steps);
            cfg.eps = 1e-2; // accuracy steps are flat at tiny eps
            let mut opt = MezoStepper::new(MezoSgd::new(cfg, trainable, 5));
            let objective = if task.task_type() == TaskType::Generation {
                Objective::NegF1
            } else {
                Objective::NegAccuracy
            };
            let tcfg = TrainCfg {
                steps,
                eval_every: (steps / 4).max(1),
                seed: 0,
                objective,
                nondiff_batch: 16,
            };
            let r = train_zo(&mut opt, &mut params, &loss_art, &ev, task,
                             &data.train, &data.val, &tcfg);
            match r {
                Ok(_) => {
                    let s = ev.evaluate(&params, task, &data.test)?.score;
                    row.push(pct(s));
                }
                Err(_) => row.push(na()),
            }
            event::progress_tick();
        }
        event::info("exp", " nondiff");
        rows.push(row);
    }
    let title = format!("Table 3 — non-differentiable objectives ({}-{})", family, size);
    print_table(&title, &header, &rows);
    ctx.write_json("table3", &table_json(&title, &header, &rows))?;
    Ok(())
}

/// Table 5: MeZO with vs without the prompt template.
pub fn table5(ctx: &Ctx, family: &str, size: &str) -> Result<()> {
    let tasks = [Task::Sst2, Task::Snli, Task::Trec];
    let mut header = vec!["Setting".to_string()];
    header.extend(tasks.iter().map(|t| t.name().to_string()));
    let mut rows = Vec::new();
    for (label, prompt) in [("Prompt", true), ("No Prompt", false)] {
        let mut row = vec![label.to_string()];
        for &task in &tasks {
            let data = generate(task, &ctx.vocab, GenOpts {
                seed: 0,
                n_train: 16 * task.n_classes(),
                n_val: 64,
                n_test: ctx.scale(192, 96),
                prompt,
            });
            row.push(cell(run_method(ctx, family, size, task, &data,
                                     &Method::mezo("full"), 0)));
            event::progress_tick();
        }
        event::info("exp", &format!(" {}", label));
        rows.push(row);
    }
    let title = "Table 5 — prompt vs no-prompt (MeZO, k=16)";
    print_table(title, &header, &rows);
    ctx.write_json("table5", &table_json(title, &header, &rows))?;
    Ok(())
}

/// Table 6: n-SPSA sample schedules at a fixed forward-pass budget.
pub fn table6(ctx: &Ctx, family: &str, size: &str) -> Result<()> {
    let tasks = [Task::Sst2, Task::Snli, Task::Trec];
    let budget = ctx.scale(6000, 1600); // total forward passes
    let settings: Vec<(String, usize, bool)> = vec![
        ("n=1 const".into(), 1, false),
        ("n=4 const".into(), 4, false),
        ("n=4 linear".into(), 4, true),
        ("n=16 const".into(), 16, false),
        ("n=16 linear".into(), 16, true),
    ];
    let mut header = vec!["Schedule".to_string()];
    header.extend(tasks.iter().map(|t| t.name().to_string()));
    let mut rows = Vec::new();
    for (label, n, linear) in &settings {
        let mut row = vec![label.clone()];
        for &task in &tasks {
            // steps so that total ≈ budget forward passes (avg n for linear)
            let avg_n = if *linear { (1 + n) / 2 } else { *n };
            let steps = (budget / (2 * avg_n.max(1))).max(1);
            let mut cfg = default_mezo_cfg("full", steps);
            cfg.n = *n;
            cfg.linear_n_schedule = *linear;
            // linear-scaling rule: lr grows with n (Appendix A.2)
            cfg.lr *= *n as f32;
            let data = ctx.task_data(task, 16 * task.n_classes(), 0);
            let m = Method::Mezo { tuning: "full", flavor: Flavor::Sgd, cfg: Some(cfg) };
            row.push(cell(run_method(ctx, family, size, task, &data, &m, 0)));
            event::progress_tick();
        }
        event::info("exp", &format!(" {}", label));
        rows.push(row);
    }
    let title = format!("Table 6 — n-SPSA schedules at {} forward passes", budget);
    print_table(&title, &header, &rows);
    ctx.write_json("table6", &table_json(&title, &header, &rows))?;
    Ok(())
}

/// Tables 8/9/10: variance- and expectation-modified SPSA.
pub fn table8910(ctx: &Ctx, family: &str, size: &str) -> Result<()> {
    let tasks = [Task::Sst2, Task::Snli, Task::Trec];
    let steps = ctx.scale(2000, 500);
    let settings: Vec<(String, Option<(Mode, DSource, usize)>)> = vec![
        ("Baseline MeZO".into(), None),
        ("Var: param norm (T9)".into(), Some((Mode::Variance, DSource::ParamNorm, 0))),
        ("Var: param norm, refresh (T9)".into(), Some((Mode::Variance, DSource::ParamNorm, 200))),
        ("Var: ZO grad norm (T8)".into(), Some((Mode::Variance, DSource::GradNormZo, 0))),
        ("Var: ZO grad norm, refresh (T8)".into(), Some((Mode::Variance, DSource::GradNormZo, 200))),
        ("Expect: normalized grad (T10)".into(), Some((Mode::Expectation, DSource::GradNormZo, 0))),
    ];
    let mut header = vec!["Variant".to_string()];
    header.extend(tasks.iter().map(|t| t.name().to_string()));
    let mut rows = Vec::new();
    for (label, setting) in &settings {
        let mut row = vec![label.clone()];
        for &task in &tasks {
            let data = ctx.task_data(task, 16 * task.n_classes(), 0);
            let score: Result<f64> = (|| {
                let ev = ctx.evaluator(family, size, "full")?;
                let mut params = ctx.params(family, size, "full", 0, true)?;
                let loss_art = ev.loss_art.clone();
                let trainable = params.indices_of(&loss_art.meta.trainable);
                let tcfg = TrainCfg { steps, eval_every: (steps / 4).max(1),
                                      ..Default::default() };
                match setting {
                    None => {
                        let cfg = default_mezo_cfg("full", steps);
                        let mut opt = MezoStepper::new(MezoSgd::new(cfg, trainable, 3));
                        train_zo(&mut opt, &mut params, &loss_art, &ev, task,
                                 &data.train, &data.val, &tcfg)?;
                    }
                    Some((mode, src, refresh)) => {
                        let cfg = ModifiedSpsaConfig {
                            lr: 1e-4,
                            eps: 1e-3,
                            mode: *mode,
                            d_source: *src,
                            refresh_every: *refresh,
                        };
                        let mut opt = ModifiedSpsa::new(cfg, trainable, 3);
                        train_zo(&mut opt, &mut params, &loss_art, &ev, task,
                                 &data.train, &data.val, &tcfg)?;
                    }
                }
                Ok(ev.evaluate(&params, task, &data.test)?.score)
            })();
            row.push(score.map(pct).unwrap_or_else(|_| na()));
            event::progress_tick();
        }
        event::info("exp", &format!(" {}", label));
        rows.push(row);
    }
    let title = "Tables 8/9/10 — variance/expectation-modified SPSA (k=16)";
    print_table(title, &header, &rows);
    ctx.write_json("table8910", &table_json(title, &header, &rows))?;
    Ok(())
}

/// Table 11: two-point SPSA vs the one-point estimator at equal forwards.
pub fn table11(ctx: &Ctx, family: &str, size: &str) -> Result<()> {
    let tasks = [Task::Sst2, Task::Snli, Task::Trec];
    let base_steps = ctx.scale(2000, 500);
    let settings = vec![
        ("SPSA (2-point)".to_string(), false, base_steps),
        ("One-point, same steps".to_string(), true, base_steps),
        ("One-point, 2x steps (equal fwd)".to_string(), true, 2 * base_steps),
    ];
    let mut header = vec!["Estimator".to_string()];
    header.extend(tasks.iter().map(|t| t.name().to_string()));
    let mut rows = Vec::new();
    for (label, one_point, steps) in &settings {
        let mut row = vec![label.clone()];
        for &task in &tasks {
            let mut cfg = default_mezo_cfg("full", *steps);
            cfg.one_point = *one_point;
            if *one_point {
                cfg.lr *= 0.3; // one-point is noisier; see Appendix B.5
            }
            let data = ctx.task_data(task, 16 * task.n_classes(), 0);
            let m = Method::Mezo { tuning: "full", flavor: Flavor::Sgd, cfg: Some(cfg) };
            row.push(cell(run_method(ctx, family, size, task, &data, &m, 0)));
            event::progress_tick();
        }
        event::info("exp", &format!(" {}", label));
        rows.push(row);
    }
    let title = "Table 11 — SPSA vs one-point estimator";
    print_table(title, &header, &rows);
    ctx.write_json("table11", &table_json(title, &header, &rows))?;
    Ok(())
}

/// Table 12 + Fig. 3 + Table 22: analytic memory accounting per method.
pub fn table22(ctx: &Ctx) -> Result<()> {
    let (b, s) = (8u64, 64u64);
    let mut header = vec!["Size".to_string(), "params".to_string()];
    header.extend(PROFILED_METHODS.iter().map(|m| m.name().to_string()));
    let mut rows = Vec::new();
    for spec in SIZES {
        let mut row = vec![spec.name.to_string(),
                           format!("{:.2}M", memory::n_params(spec) as f64 / 1e6)];
        for m in PROFILED_METHODS {
            row.push(format!("{:.1}MB", memory::live_bytes(spec, m, b, s) as f64 / 1e6));
        }
        rows.push(row);
    }
    // ratio row (the paper's 12x headline)
    let mut ratio_row = vec!["FT/inference ratio @xl".to_string(), "".to_string()];
    let xl = SIZES[4];
    let inf = memory::live_bytes(xl, MemMethod::Inference, b, s) as f64;
    for m in PROFILED_METHODS {
        ratio_row.push(format!("{:.1}x", memory::live_bytes(xl, m, b, s) as f64 / inf));
    }
    rows.push(ratio_row);
    let title = "Table 22 / Fig. 3 / Table 12 — analytic memory by method x size (B=8, S=64)";
    print_table(title, &header, &rows);
    ctx.write_json("table22", &table_json(title, &header, &rows))?;

    // measured cross-check: peak RSS growth when loading+running artifacts
    let mut mrows = Vec::new();
    for size in ["tiny", "small", "base", "large"] {
        let before = memory::current_rss().unwrap_or(0);
        let art = ctx.rt.load(&ctx.art("ar", size, "loss", "full"))?;
        let mut params = crate::model::params::ParamStore::from_meta(&art.meta);
        params.init(0);
        let batch = crate::data::batch::Batch::zeros(8, 64);
        let _ = art.run(&params, Some(&batch), &[])?;
        let after = memory::current_rss().unwrap_or(0);
        mrows.push(vec![
            size.to_string(),
            format!("{:.1}MB", (after.saturating_sub(before)) as f64 / 1e6),
        ]);
    }
    print_table(
        "Fig. 3 (measured) — process RSS growth per loaded+run loss artifact",
        &["Size".to_string(), "RSS delta".to_string()],
        &mrows,
    );
    ctx.write_json("figure3_measured",
                   &table_json("measured RSS", &["Size".into(), "RSS delta".into()], &mrows))?;
    Ok(())
}

/// Figure 4: largest model that fits a memory budget, per method.
pub fn figure4(ctx: &Ctx) -> Result<()> {
    let budgets_mb: [u64; 4] = [24, 64, 192, 512];
    let methods = [MemMethod::FtAdam, MemMethod::FtPrefix, MemMethod::Inference];
    let mut header = vec!["Budget".to_string()];
    header.extend(methods.iter().map(|m| m.name().to_string()));
    let mut rows = Vec::new();
    for mb in budgets_mb {
        let mut row = vec![format!("{}MB", mb)];
        for m in methods {
            row.push(
                memory::largest_fitting(m, mb << 20, 8, 64)
                    .unwrap_or("-")
                    .to_string(),
            );
        }
        rows.push(row);
    }
    let title = "Figure 4 — largest model per memory budget (analytic)";
    print_table(title, &header, &rows);
    ctx.write_json("figure4", &table_json(title, &header, &rows))?;
    Ok(())
}

/// Table 17: prefix init — random vs real activations (FT-prefix).
pub fn table17(ctx: &Ctx) -> Result<()> {
    let (family, size) = ("mlm", "small");
    let tasks = [Task::Sst2, Task::Snli];
    let mut header = vec!["Init".to_string()];
    header.extend(tasks.iter().map(|t| t.name().to_string()));
    let mut rows = Vec::new();
    for (label, random) in [("random init", true), ("real act init", false)] {
        let mut row = vec![label.to_string()];
        for &task in &tasks {
            let data = ctx.task_data(task, 16 * task.n_classes(), 0);
            let score: Result<f64> = (|| {
                let ev = ctx.evaluator(family, size, "prefix")?;
                let mut params = ctx.params(family, size, "prefix", 0, random)?;
                let grad_art = ctx.rt.load(&ctx.art(family, size, "grad", "prefix"))?;
                let trainable = params.indices_of(&grad_art.meta.trainable);
                let steps = ctx.scale(150, 60);
                let fcfg = crate::optim::ft::FtConfig {
                    lr: 1e-3,
                    total_steps: steps,
                    ..Default::default()
                };
                let mut opt = crate::optim::ft::FtOptimizer::new(fcfg, trainable, &params);
                let tcfg = TrainCfg { steps, eval_every: (steps / 3).max(1), ..Default::default() };
                crate::train::train_ft(&mut opt, &mut params, &grad_art, &ev, task,
                                       &data.train, &data.val, &tcfg)?;
                Ok(ev.evaluate(&params, task, &data.test)?.score)
            })();
            row.push(score.map(pct).unwrap_or_else(|_| na()));
            event::progress_tick();
        }
        event::info("exp", &format!(" {}", label));
        rows.push(row);
    }
    let title = "Table 17 — prefix-tuning init ablation (FT-prefix, mlm-small)";
    print_table(title, &header, &rows);
    ctx.write_json("table17", &table_json(title, &header, &rows))?;
    Ok(())
}

/// Table 19: LP, MeZO, LP-then-MeZO.
pub fn table19(ctx: &Ctx, family: &str, size: &str) -> Result<()> {
    let tasks = [Task::Sst2, Task::Snli, Task::Trec];
    let methods = vec![Method::LinearProbe, Method::mezo("full"), Method::LpMezo];
    let mut header = vec!["Method".to_string()];
    header.extend(tasks.iter().map(|t| t.name().to_string()));
    let mut rows = Vec::new();
    for m in &methods {
        let mut row = vec![m.name()];
        for &task in &tasks {
            let data = ctx.task_data(task, 16 * task.n_classes(), 0);
            row.push(cell(run_method(ctx, family, size, task, &data, m, 0)));
            event::progress_tick();
        }
        event::info("exp", &format!(" {}", m.name()));
        rows.push(row);
    }
    let title = "Table 19 — LP, MeZO, LP-then-MeZO (k=16)";
    print_table(title, &header, &rows);
    ctx.write_json("table19", &table_json(title, &header, &rows))?;
    Ok(())
}

/// Table 21: MeZO family vs the BBTv2-style ES baseline.
pub fn table21(ctx: &Ctx, family: &str, size: &str) -> Result<()> {
    let tasks = [Task::Sst2, Task::Snli, Task::Rte];
    let mut header = vec!["Method".to_string()];
    header.extend(tasks.iter().map(|t| t.name().to_string()));
    let mut rows = Vec::new();

    // BBTv2-like: ES over a low-dim projection of the prefix tensors
    {
        let mut row = vec!["BBTv2-like (ES prefix)".to_string()];
        for &task in &tasks {
            let data = ctx.task_data(task, 16 * task.n_classes(), 0);
            let score: Result<f64> = (|| {
                let ev = ctx.evaluator(family, size, "prefix")?;
                let mut params = ctx.params(family, size, "prefix", 0, false)?;
                let loss_art = ev.loss_art.clone();
                let prefix_tensors: Vec<usize> =
                    params.indices_of(&loss_art.meta.trainable);
                let gens = ctx.scale(120, 40);
                let cfg = crate::baselines::bbt::BbtCfg {
                    d_low: 32,
                    lambda: 10,
                    mu: 3,
                    sigma: 0.3,
                    iters: gens,
                    seed: 0,
                };
                let mut bbt = crate::baselines::bbt::Bbt::new(cfg, prefix_tensors, &params);
                let mut rng = crate::rng::Pcg::new(0x88);
                let (b, s) = (loss_art.meta.batch, loss_art.meta.seq);
                for _ in 0..gens {
                    let batch = crate::data::batch::sample_batch(
                        &data.train, &mut rng, b, s, family == "mlm");
                    bbt.step(&mut params, |p| {
                        crate::train::batch_loss(&loss_art, p, &batch)
                    })?;
                }
                Ok(ev.evaluate(&params, task, &data.test)?.score)
            })();
            row.push(score.map(pct).unwrap_or_else(|_| na()));
            event::progress_tick();
        }
        event::info("exp", " BBT");
        rows.push(row);
    }
    for m in [Method::mezo("full"), Method::mezo("lora"), Method::mezo("prefix")] {
        let mut row = vec![m.name()];
        for &task in &tasks {
            let data = ctx.task_data(task, 16 * task.n_classes(), 0);
            row.push(cell(run_method(ctx, family, size, task, &data, &m, 0)));
            event::progress_tick();
        }
        event::info("exp", &format!(" {}", m.name()));
        rows.push(row);
    }
    let title = "Table 21 — MeZO vs BBTv2-style baseline (k=16)";
    print_table(title, &header, &rows);
    ctx.write_json("table21", &table_json(title, &header, &rows))?;
    Ok(())
}

/// Table 23: wall-clock per optimization step, MeZO vs FT, per size.
pub fn table23(ctx: &Ctx) -> Result<()> {
    let sizes = ["tiny", "small", "base", "large"];
    let mut header: Vec<String> =
        vec!["Method".into()];
    header.extend(sizes.iter().map(|s| s.to_string()));
    let reps = ctx.scale(10, 4);
    let mut mezo_row = vec!["MeZO step (2 fwd + in-place)".to_string()];
    let mut fast_row = vec!["MeZO fast step (fused upload)".to_string()];
    let mut fused_row = vec!["MeZO fused-step artifact".to_string()];
    let mut ft_row = vec!["FT step (fwd+bwd+Adam)".to_string()];
    let mut ratio_row = vec!["FT/MeZO(fast) per-step ratio".to_string()];
    for size in sizes {
        let loss_art = ctx.rt.load(&ctx.art("ar", size, "loss", "full"))?;
        let grad_art = ctx.rt.load(&ctx.art("ar", size, "grad", "full"))?;
        let mut params = crate::model::params::ParamStore::from_meta(&loss_art.meta);
        params.init(0);
        let trainable: Vec<usize> = (0..params.specs.len()).collect();
        let mut batch = crate::data::batch::Batch::zeros(8, 64);
        for row in 0..8 {
            let seq: Vec<u32> = (0..60).map(|t| ((t * 13 + row * 7) % 500 + 5) as u32).collect();
            batch.set_row(row, &seq, 1..seq.len(), false);
        }
        // MeZO step timing
        let cfg = MezoConfig { lr: 1e-4, eps: 1e-3, ..Default::default() };
        let mut opt = MezoSgd::new(cfg, trainable.clone(), 1);
        opt.step(&mut params, |p| crate::train::batch_loss(&loss_art, p, &batch))?; // warmup
        let t = Timer::start();
        for _ in 0..reps {
            opt.step(&mut params, |p| crate::train::batch_loss(&loss_art, p, &batch))?;
        }
        let mezo_ms = t.ms() / reps as f64;
        // fast path: perturbation fused into the literal upload
        let mut scratch = Vec::new();
        opt.step_artifact(&mut params, &loss_art, &batch, &mut scratch)?; // warmup
        let t = Timer::start();
        for _ in 0..reps {
            opt.step_artifact(&mut params, &loss_art, &batch, &mut scratch)?;
        }
        let fast_ms = t.ms() / reps as f64;
        // fused-step artifact (where lowered)
        let fused_name = ctx.art("ar", size, "fused", "full");
        let fused_ms = if ctx.rt.artifact_exists(&fused_name) {
            let fused = ctx.rt.load(&fused_name)?;
            let extras = [
                crate::runtime::i32_literal(&[1], &[7])?,
                crate::runtime::f32_literal(&[1], &[1e-3])?,
                crate::runtime::f32_literal(&[1], &[1e-4])?,
            ];
            let _ = fused.run(&params, Some(&batch), &extras)?; // warmup
            let t = Timer::start();
            for _ in 0..reps {
                let _ = fused.run(&params, Some(&batch), &extras)?;
            }
            Some(t.ms() / reps as f64)
        } else {
            None
        };
        // FT step timing
        let fcfg = crate::optim::ft::FtConfig { lr: 1e-4, ..Default::default() };
        let mut ft = crate::optim::ft::FtOptimizer::new(fcfg, trainable, &params);
        let step_ft = |ft: &mut crate::optim::ft::FtOptimizer,
                       params: &mut crate::model::params::ParamStore|
         -> Result<()> {
            let out = grad_art.run(params, Some(&batch), &[])?;
            let grads: Vec<Vec<f32>> =
                out[1..].iter().map(crate::runtime::vec_f32).collect::<Result<Vec<_>>>()?;
            ft.apply(params, &grads)?;
            Ok(())
        };
        step_ft(&mut ft, &mut params)?; // warmup
        let t = Timer::start();
        for _ in 0..reps {
            step_ft(&mut ft, &mut params)?;
        }
        let ft_ms = t.ms() / reps as f64;
        mezo_row.push(format!("{:.1}ms", mezo_ms));
        fast_row.push(format!("{:.1}ms", fast_ms));
        fused_row.push(fused_ms.map(|x| format!("{:.1}ms", x)).unwrap_or_else(na));
        ft_row.push(format!("{:.1}ms", ft_ms));
        ratio_row.push(format!("{:.2}x", ft_ms / fast_ms));
        event::progress_tick();
    }
    event::info("exp", " table23");
    let rows = vec![mezo_row, fast_row, fused_row, ft_row, ratio_row];
    let title = "Table 23 — wall-clock per step (B=8, S=64, 1 CPU core)";
    print_table(title, &header, &rows);
    ctx.write_json("table23", &table_json(title, &header, &rows))?;
    Ok(())
}

/// Figure 5: convergence of MeZO full vs LoRA vs prefix (val curves).
pub fn figure5(ctx: &Ctx, family: &str, size: &str) -> Result<()> {
    let task = Task::Sst2;
    let data = ctx.task_data(task, 256, 0);
    let steps = ctx.scale(2000, 600);
    let mut series: Vec<(String, Vec<(usize, f64)>)> = Vec::new();
    for tuning in ["full", "lora", "prefix"] {
        let mut cfg = default_mezo_cfg(tuning, steps);
        cfg.total_steps = steps;
        let m = Method::Mezo { tuning: match tuning {
            "full" => "full", "lora" => "lora", _ => "prefix" },
            flavor: Flavor::Sgd, cfg: Some(cfg) };
        let out = run_method(ctx, family, size, task, &data, &m, 0)?;
        event::info("exp", &format!("figure5: {} final {:.3}", tuning, out.score));
        series.push((tuning.to_string(), out.val_curve));
    }
    println!("\n=== Figure 5 — MeZO convergence, full vs LoRA vs prefix ({}) ===", task.name());
    for (name, curve) in &series {
        let pts: Vec<String> =
            curve.iter().map(|(s, v)| format!("({}, {:.3})", s, v)).collect();
        println!("{:>7}: {}", name, pts.join(" "));
    }
    let j = Json::Arr(
        series
            .iter()
            .map(|(n, c)| {
                obj(vec![
                    ("tuning", Json::from(n.as_str())),
                    (
                        "curve",
                        Json::Arr(
                            c.iter()
                                .map(|(s, v)| {
                                    Json::Arr(vec![Json::from(*s), Json::from(*v)])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    ctx.write_json("figure5", &j)?;
    Ok(())
}

/// Dispatch by experiment id.
pub fn run(ctx: &Ctx, id: &str, family: &str, size: &str) -> Result<()> {
    match id {
        "table1" | "figure1" => table1(ctx, family, size),
        "table18" | "figure2" => table18(ctx, size),
        "table2" | "table20" => table2(ctx),
        "table3" => table3(ctx, family, size),
        "table5" => table5(ctx, family, size),
        "table6" => table6(ctx, family, size),
        "table8" | "table9" | "table10" | "table8910" => table8910(ctx, family, size),
        "table11" => table11(ctx, family, size),
        "table12" | "table22" | "figure3" => table22(ctx),
        "figure4" => figure4(ctx),
        "table17" => table17(ctx),
        "table19" => table19(ctx, family, size),
        "table21" => table21(ctx, family, size),
        "table23" => table23(ctx),
        "figure5" => figure5(ctx, family, size),
        "all" => {
            for id in ["table22", "figure4", "table23", "table5", "table19",
                       "table21", "table6", "table8910", "table11", "table3",
                       "figure5", "table1", "table18", "table2", "table17"] {
                println!("\n########## {} ##########", id);
                if let Err(e) = run(ctx, id, family, size) {
                    event::error("exp", &format!("[exp {}] failed: {:#}", id, e));
                }
            }
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown experiment id '{}'", other)),
    }
}

/// Every id [`run`] accepts, for the CLI's usage text.
pub const EXPERIMENT_IDS: [&str; 16] = [
    "table1", "table18", "table2", "table3", "table5", "table6", "table8910",
    "table11", "table17", "table19", "table21", "table22", "table23",
    "figure4", "figure5", "all",
];
