//! MeZO: full-system reproduction of "Fine-Tuning Language Models with Just
//! Forward Passes" (Malladi et al., NeurIPS 2023).
//!
//! Three-layer architecture (see DESIGN.md):
//!  - L1/L2 (build-time python): Pallas kernels + JAX transformer, AOT-lowered
//!    to HLO text artifacts under `artifacts/`.
//!  - L3 (this crate): the MeZO optimizer family operating **in place** on
//!    rust-owned parameter buffers via a counter-based Gaussian stream, plus
//!    the training / evaluation / baseline / experiment system. Python never
//!    runs at runtime.
pub mod baselines;
pub mod data;
pub mod eval;
pub mod exp;
pub mod memory;
pub mod model;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod storage;
pub mod tokenizer;
pub mod train;
pub mod util;
