//! MeZO: full-system reproduction of "Fine-Tuning Language Models with Just
//! Forward Passes" (Malladi et al., NeurIPS 2023).
//!
//! Three-layer architecture (see DESIGN.md):
//!  - L1/L2 (build-time python): Pallas kernels + JAX transformer, AOT-lowered
//!    to HLO text artifacts under `artifacts/`.
//!  - L3 (this crate): the MeZO optimizer family operating **in place** on
//!    rust-owned parameter buffers via a counter-based Gaussian stream and
//!    the blocked, multi-threaded [`zkernel`] engine, plus the training /
//!    evaluation / baseline / experiment system. Python never runs at
//!    runtime.
//!
//! Feature `pjrt` gates everything that needs the XLA/PJRT runtime
//! (artifact execution: [`runtime`], [`train`], [`exp`], the evaluator and
//! the CLI). The default build is the pure-rust optimizer/kernel substrate
//! and is what tier-1 `cargo build --release && cargo test -q` verifies
//! offline.
pub mod baselines;
pub mod data;
pub mod eval;
#[cfg(feature = "pjrt")]
pub mod exp;
pub mod memory;
pub mod model;
pub mod optim;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod storage;
pub mod tokenizer;
#[cfg(feature = "pjrt")]
pub mod train;
pub mod util;
pub mod zkernel;
