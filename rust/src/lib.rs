//! MeZO: full-system reproduction of "Fine-Tuning Language Models with Just
//! Forward Passes" (Malladi et al., NeurIPS 2023).
//!
//! Three-layer architecture (see DESIGN.md):
//!  - L1/L2 (build-time python): Pallas kernels + JAX transformer, AOT-lowered
//!    to HLO text artifacts under `artifacts/`.
//!  - L3 (this crate): the MeZO optimizer family (and the FZOO batched-seed
//!    variant, [`optim::fzoo`]) operating **in place** on rust-owned
//!    parameter buffers via a counter-based Gaussian stream and the
//!    blocked, multi-threaded [`zkernel`] engine — optionally restricted
//!    to a static sparse sensitive-weight set ([`zkernel::mask`], the
//!    SensZOQ workload) or decomposed across a K-way shard partition
//!    ([`shard`], the multi-node replay unit) — plus the training /
//!    evaluation / baseline / experiment system. Python never runs at
//!    runtime.
//!
//! Feature `pjrt` gates everything that needs the XLA/PJRT runtime
//! (artifact execution: `runtime`, `train`, `exp`, the evaluator and
//! the CLI). The default build is the pure-rust optimizer/kernel substrate
//! and is what tier-1 `cargo build --release && cargo test -q` verifies
//! offline. Docs are part of the verify path too:
//! `RUSTDOCFLAGS="-D warnings" cargo doc --no-deps` must pass.
//!
//! See `README.md` for a quickstart and module map, and
//! `docs/ARCHITECTURE.md` for the paper-section → module mapping.
#![warn(missing_docs)]

// The core subsystems — rng, zkernel (incl. the sparse mask tier, the
// SIMD dispatch tiers, the quant tier, and the worker pool), optim,
// storage, shard, serve, wire, model (incl. the quantized store), util,
// baselines, memory, data, eval, tokenizer, train, exp, obs — are fully
// documented and hold the missing_docs line. The remaining modules are
// grandfathered with module-level allows until their own doc pass;
// shrinking this list is cheap follow-up work (document-then-remove a
// marker, never add one).
pub mod baselines;
pub mod data;
pub mod eval;
#[cfg(feature = "pjrt")]
pub mod exp;
pub mod memory;
pub mod model;
pub mod obs;
pub mod optim;
pub mod rng;
#[cfg(feature = "pjrt")]
#[allow(missing_docs)]
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod storage;
pub mod tokenizer;
#[cfg(feature = "pjrt")]
pub mod train;
pub mod util;
pub mod wire;
pub mod zkernel;
