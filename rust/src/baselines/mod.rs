//! Memory-equivalent baselines the paper compares MeZO against:
//! zero-shot, in-context learning, linear probing, and a BBTv2-style
//! gradient-free prefix optimizer.

pub mod bbt;
pub mod linear_probe;

#[cfg(feature = "pjrt")]
use crate::data::batch::icl_example;
#[cfg(feature = "pjrt")]
use crate::data::tasks::{Example, Task};
#[cfg(feature = "pjrt")]
use crate::eval::Evaluator;
#[cfg(feature = "pjrt")]
use crate::model::params::ParamStore;
#[cfg(feature = "pjrt")]
use anyhow::Result;

/// Zero-shot: evaluate the pre-trained model with the prompt, no tuning.
#[cfg(feature = "pjrt")]
pub fn zero_shot(
    evaluator: &Evaluator,
    params: &ParamStore,
    task: Task,
    test: &[Example],
) -> Result<f64> {
    Ok(evaluator.evaluate(params, task, test)?.score)
}

/// In-context learning: prepend up to `max_demos` gold demonstrations from
/// the train split to every test prompt (paper Appendix E.4).
#[cfg(feature = "pjrt")]
pub fn icl(
    evaluator: &Evaluator,
    params: &ParamStore,
    task: Task,
    train: &[Example],
    test: &[Example],
    max_demos: usize,
) -> Result<f64> {
    let s = evaluator.loss_art.meta.seq;
    let wrapped: Vec<Example> = test
        .iter()
        .map(|ex| icl_example(train, ex, max_demos, s))
        .collect();
    Ok(evaluator.evaluate(params, task, &wrapped)?.score)
}

#[cfg(test)]
mod tests {
    // zero_shot / icl are exercised end-to-end in tests/pipeline.rs where a
    // compiled artifact is available.
}
