//! Linear probing: multinomial logistic regression on frozen features
//! (the paper's "LP" baseline — memory cost ≈ inference, like MeZO).
//!
//! Features are the final hidden state at the prediction position
//! (Evaluator::features). Trained full-batch with gradient descent +
//! early stopping on training loss plateau; no external solver (the paper
//! used scipy — substrate rule: build it).

use crate::rng::Pcg;
use anyhow::Result;

/// Configuration of the [`LogReg`] full-batch gradient-descent fit.
#[derive(Debug, Clone)]
pub struct LogRegCfg {
    /// gradient-descent learning rate
    pub lr: f64,
    /// L2 regularization strength
    pub l2: f64,
    /// iteration cap
    pub max_iters: usize,
    /// early-stopping tolerance on the training-loss plateau
    pub tol: f64,
}

impl Default for LogRegCfg {
    fn default() -> Self {
        LogRegCfg { lr: 0.5, l2: 1e-4, max_iters: 500, tol: 1e-6 }
    }
}

/// W: (n_classes, d+1) with bias folded in as the last column.
#[derive(Debug, Clone)]
pub struct LogReg {
    /// per-class weight rows, each `d + 1` long (bias last)
    pub w: Vec<Vec<f64>>,
    /// number of classes the probe separates
    pub n_classes: usize,
    /// feature dimensionality (without the bias column)
    pub d: usize,
}

impl LogReg {
    /// Fit a multinomial logistic regression on frozen features with
    /// full-batch gradient descent (features are standardized internally
    /// and the standardization is folded back into the weights, so
    /// [`LogReg::predict`] takes raw features).
    pub fn fit(
        feats: &[Vec<f32>],
        labels: &[usize],
        n_classes: usize,
        cfg: &LogRegCfg,
    ) -> Result<LogReg> {
        assert_eq!(feats.len(), labels.len());
        let n = feats.len();
        let d = feats[0].len();
        // standardize features for stable GD
        let mut mean = vec![0.0f64; d];
        let mut std = vec![0.0f64; d];
        for f in feats {
            for (j, &x) in f.iter().enumerate() {
                mean[j] += x as f64;
            }
        }
        mean.iter_mut().for_each(|m| *m /= n as f64);
        for f in feats {
            for (j, &x) in f.iter().enumerate() {
                std[j] += (x as f64 - mean[j]).powi(2);
            }
        }
        std.iter_mut().for_each(|s| *s = (*s / n as f64).sqrt().max(1e-6));
        let xs: Vec<Vec<f64>> = feats
            .iter()
            .map(|f| {
                let mut v: Vec<f64> = f
                    .iter()
                    .enumerate()
                    .map(|(j, &x)| (x as f64 - mean[j]) / std[j])
                    .collect();
                v.push(1.0); // bias
                v
            })
            .collect();

        let dim = d + 1;
        let mut rng = Pcg::new(13);
        let mut w: Vec<Vec<f64>> = (0..n_classes)
            .map(|_| (0..dim).map(|_| rng.normal() * 0.01).collect())
            .collect();
        let mut prev_loss = f64::INFINITY;
        for _ in 0..cfg.max_iters {
            // forward: probs (n, C), grad accumulation
            let mut grad = vec![vec![0.0f64; dim]; n_classes];
            let mut loss = 0.0f64;
            for (x, &y) in xs.iter().zip(labels) {
                let logits: Vec<f64> = w
                    .iter()
                    .map(|wc| wc.iter().zip(x).map(|(a, b)| a * b).sum())
                    .collect();
                let mx = logits.iter().cloned().fold(f64::MIN, f64::max);
                let exps: Vec<f64> = logits.iter().map(|l| (l - mx).exp()).collect();
                let z: f64 = exps.iter().sum();
                loss -= ((exps[y] / z) + 1e-12).ln();
                for c in 0..n_classes {
                    let p = exps[c] / z;
                    let err = p - if c == y { 1.0 } else { 0.0 };
                    for j in 0..dim {
                        grad[c][j] += err * x[j];
                    }
                }
            }
            loss /= n as f64;
            for c in 0..n_classes {
                for j in 0..dim {
                    let g = grad[c][j] / n as f64 + cfg.l2 * w[c][j];
                    w[c][j] -= cfg.lr * g;
                }
            }
            if (prev_loss - loss).abs() < cfg.tol {
                break;
            }
            prev_loss = loss;
        }
        // fold standardization back into the weights so predict() takes raw
        // features: w·((x−mean)/std) + b = (w/std)·x + (b − w·mean/std)
        for wc in w.iter_mut() {
            let mut bias_adj = 0.0;
            for j in 0..d {
                wc[j] /= std[j];
                bias_adj += wc[j] * mean[j];
            }
            wc[d] -= bias_adj;
        }
        Ok(LogReg { w, n_classes, d })
    }

    /// Arg-max class for one raw (unstandardized) feature vector.
    pub fn predict(&self, feat: &[f32]) -> usize {
        let mut best = 0;
        let mut bv = f64::MIN;
        for (c, wc) in self.w.iter().enumerate() {
            let mut s = wc[self.d];
            for (j, &x) in feat.iter().enumerate() {
                s += wc[j] * x as f64;
            }
            if s > bv {
                bv = s;
                best = c;
            }
        }
        best
    }

    /// Classification accuracy of [`LogReg::predict`] over a labeled set.
    pub fn accuracy(&self, feats: &[Vec<f32>], labels: &[usize]) -> f64 {
        let preds: Vec<usize> = feats.iter().map(|f| self.predict(f)).collect();
        crate::eval::metrics::accuracy(&preds, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(n: usize, d: usize, classes: usize, sep: f32, seed: u64) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut rng = Pcg::new(seed);
        let mut feats = Vec::new();
        let mut labels = Vec::new();
        for i in 0..n {
            let c = i % classes;
            let mut f = vec![0.0f32; d];
            for (j, fj) in f.iter_mut().enumerate() {
                let center = if j % classes == c { sep } else { 0.0 };
                *fj = center + rng.normal() as f32;
            }
            feats.push(f);
            labels.push(c);
        }
        (feats, labels)
    }

    #[test]
    fn separable_blobs_reach_high_accuracy() {
        let (feats, labels) = blobs(120, 8, 3, 4.0, 0);
        let lr = LogReg::fit(&feats, &labels, 3, &LogRegCfg::default()).unwrap();
        assert!(lr.accuracy(&feats, &labels) > 0.95);
    }

    #[test]
    fn random_labels_stay_near_chance_on_heldout() {
        let (feats, _) = blobs(200, 8, 2, 0.0, 1);
        let mut rng = Pcg::new(2);
        let labels: Vec<usize> = (0..200).map(|_| rng.below(2)).collect();
        let lr = LogReg::fit(&feats[..100].to_vec(), &labels[..100].to_vec(), 2,
                             &LogRegCfg::default()).unwrap();
        let acc = lr.accuracy(&feats[100..].to_vec(), &labels[100..].to_vec());
        assert!(acc > 0.25 && acc < 0.75, "acc {}", acc);
    }

    #[test]
    fn standardization_fold_is_transparent() {
        // shifting/scaling features must not change predictions after fit
        let (mut feats, labels) = blobs(60, 4, 2, 3.0, 3);
        for f in feats.iter_mut() {
            f[0] = f[0] * 100.0 + 500.0;
        }
        let lr = LogReg::fit(&feats, &labels, 2, &LogRegCfg::default()).unwrap();
        assert!(lr.accuracy(&feats, &labels) > 0.9);
    }
}
