//! BBTv2-style baseline (Sun et al. 2022; paper Appendix F.4 / Table 21).
//!
//! BBTv2 tunes a *low-dimensional projection* of per-layer prefixes with an
//! evolutionary strategy (CMA-ES) — gradient-free like MeZO, but limited to
//! the projected prefix subspace. We implement a (μ/μ, λ) ES with diagonal
//! covariance adaptation over z ∈ R^dlow, mapped to the prefix tensors by a
//! fixed random Gaussian projection A (one per tensor), prefix = A·z.

use crate::model::params::ParamStore;
use crate::rng::{GaussianStream, Pcg};
use crate::zkernel::ZEngine;
use anyhow::Result;

/// Configuration of the [`Bbt`] evolutionary prefix optimizer.
#[derive(Debug, Clone)]
pub struct BbtCfg {
    /// intrinsic dimension of the search space (BBTv2 uses 500)
    pub d_low: usize,
    /// population size λ
    pub lambda: usize,
    /// parents μ
    pub mu: usize,
    /// initial step size
    pub sigma: f32,
    /// planned ES generations (drivers budget forward passes with this)
    pub iters: usize,
    /// master seed for the population sampler and the projection
    pub seed: u64,
}

impl Default for BbtCfg {
    fn default() -> Self {
        BbtCfg { d_low: 64, lambda: 12, mu: 4, sigma: 0.3, iters: 50, seed: 0 }
    }
}

/// The BBTv2-style (μ/μ, λ) evolutionary strategy over a fixed random
/// projection of the prefix tensors — gradient-free like MeZO, but
/// searching a `d_low`-dimensional subspace instead of the full θ.
pub struct Bbt {
    /// configuration (mutable between generations)
    pub cfg: BbtCfg,
    /// indices of the prefix tensors this optimizer controls
    pub tensors: Vec<usize>,
    /// projection seed (A is regenerated, never stored — same trick as MeZO)
    proj_seed: u64,
    /// current search mean in the projected space, length `d_low`
    pub mean: Vec<f32>,
    /// per-coordinate step sizes (diagonal covariance), length `d_low`
    pub sigma: Vec<f32>,
    /// blocked/threaded kernel engine for the projection rows
    pub engine: ZEngine,
    rng: Pcg,
    /// saved originals of the controlled tensors
    base: Vec<Vec<f32>>,
}

impl Bbt {
    /// New optimizer over the given prefix tensors; the tensors' current
    /// values become the projection's base point.
    pub fn new(cfg: BbtCfg, tensors: Vec<usize>, params: &ParamStore) -> Bbt {
        let base = tensors.iter().map(|&ti| params.data[ti].clone()).collect();
        Bbt {
            mean: vec![0.0; cfg.d_low],
            sigma: vec![cfg.sigma; cfg.d_low],
            engine: ZEngine::default(),
            rng: Pcg::new(cfg.seed ^ 0xBB7),
            proj_seed: cfg.seed ^ 0x9E37_79B9,
            cfg,
            tensors,
            base,
        }
    }

    /// prefix_t = base_t + A_t · z, with A_t entries N(0, 1/sqrt(d_low))
    /// regenerated from (proj_seed, tensor, coordinate) counters. Each
    /// output coordinate is an independent projection row, so the matvec
    /// parallelizes over rows on the kernel engine.
    pub fn apply(&self, params: &mut ParamStore, z: &[f32]) {
        let scale = 1.0 / (self.cfg.d_low as f32).sqrt();
        for (k, &ti) in self.tensors.iter().enumerate() {
            let stream = GaussianStream::new(self.proj_seed ^ (k as u64) << 32);
            self.engine.project_rows(
                stream,
                self.cfg.d_low,
                z,
                &self.base[k],
                scale,
                &mut params.data[ti],
            );
        }
    }

    /// One ES generation. `loss` evaluates the current params.
    pub fn step<F>(&mut self, params: &mut ParamStore, mut loss: F) -> Result<f32>
    where
        F: FnMut(&ParamStore) -> Result<f32>,
    {
        let d = self.cfg.d_low;
        let lambda = self.cfg.lambda;
        let mu = self.cfg.mu.min(lambda);
        let mut pop: Vec<(f32, Vec<f32>)> = Vec::with_capacity(lambda);
        for _ in 0..lambda {
            let z: Vec<f32> = (0..d)
                .map(|i| self.mean[i] + self.sigma[i] * self.rng.normal() as f32)
                .collect();
            self.apply(params, &z);
            let l = loss(params)?;
            pop.push((l, z));
        }
        pop.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // recombine the μ best
        let mut new_mean = vec![0.0f32; d];
        for (_, z) in pop.iter().take(mu) {
            for (m, &zi) in new_mean.iter_mut().zip(z) {
                *m += zi / mu as f32;
            }
        }
        // diagonal covariance adaptation toward the elite spread
        for i in 0..d {
            let var: f32 = pop
                .iter()
                .take(mu)
                .map(|(_, z)| (z[i] - new_mean[i]).powi(2))
                .sum::<f32>()
                / mu as f32;
            self.sigma[i] = (0.8 * self.sigma[i] + 0.2 * var.sqrt()).max(1e-3);
        }
        self.mean = new_mean;
        // leave params at the current best mean
        let mean = self.mean.clone();
        self.apply(params, &mean);
        Ok(pop[0].0)
    }

    /// Forward passes a run of `iters_done` generations consumed (λ
    /// population evaluations plus the post-recombination mean, each).
    pub fn forward_passes(&self, iters_done: usize) -> usize {
        iters_done * (self.cfg.lambda + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;

    fn toy() -> ParamStore {
        let mut p = ParamStore::from_specs(vec![TensorDesc {
            name: "prefix".into(),
            shape: vec![16],
            dtype: "f32".into(),
        }]);
        p.init(0);
        p
    }

    #[test]
    fn es_minimizes_quadratic_in_projected_space() {
        let mut p = toy();
        let target: Vec<f32> = (0..16).map(|i| (i as f32) * 0.05).collect();
        let tgt = target.clone();
        let loss = move |p: &ParamStore| -> Result<f32> {
            Ok(p.data[0].iter().zip(&tgt).map(|(a, b)| (a - b) * (a - b)).sum())
        };
        let cfg = BbtCfg { d_low: 8, lambda: 16, mu: 4, sigma: 0.5, iters: 0, seed: 1 };
        let mut bbt = Bbt::new(cfg, vec![0], &p);
        let l0 = loss(&p).unwrap();
        let mut last = l0;
        for _ in 0..40 {
            last = bbt.step(&mut p, &loss).unwrap();
        }
        assert!(last < l0 * 0.7, "l0={} last={}", l0, last);
    }

    #[test]
    fn projection_is_bit_identical_on_pool_and_scope_dispatch() {
        // project_rows is the BBT hot path; the pool dispatcher must be a
        // pure scheduling change here too (rows >= 8 chunks at t=8)
        let mut p = ParamStore::from_specs(vec![TensorDesc {
            name: "prefix".into(),
            shape: vec![70_000],
            dtype: "f32".into(),
        }]);
        p.init(3);
        let cfg = BbtCfg { d_low: 32, ..Default::default() };
        let mut bbt = Bbt::new(cfg, vec![0], &p);
        let z: Vec<f32> = (0..32).map(|i| 0.1 * (i as f32) - 1.5).collect();
        let mut pool = p.clone();
        bbt.engine = ZEngine::with_threads(8);
        bbt.apply(&mut pool, &z);
        let mut scope = p.clone();
        bbt.engine = ZEngine::with_threads_scoped(8);
        bbt.apply(&mut scope, &z);
        for (a, b) in pool.data[0].iter().zip(&scope.data[0]) {
            assert_eq!(a.to_bits(), b.to_bits(), "{} vs {}", a, b);
        }
    }

    #[test]
    fn apply_is_deterministic_given_z() {
        let mut p = toy();
        let cfg = BbtCfg { d_low: 4, ..Default::default() };
        let bbt = Bbt::new(cfg, vec![0], &p);
        let z = vec![0.3, -0.2, 0.1, 0.9];
        bbt.apply(&mut p, &z);
        let a = p.data[0].clone();
        bbt.apply(&mut p, &z);
        assert_eq!(a, p.data[0]);
        // z = 0 restores the base exactly
        bbt.apply(&mut p, &[0.0; 4]);
        let base = toy();
        assert_eq!(p.data[0], base.data[0]);
    }
}
