//! Bit-exactness of the blocked/threaded kernels against the scalar
//! per-coordinate reference (the seed implementation's loops), across
//! thread counts 1/2/8, block-unaligned lengths and nonzero offsets.

use super::*;
use crate::rng::{GaussianStream, Pcg};

/// lengths that straddle block and threading boundaries
const LENS: [usize; 7] = [1, 5, BLOCK - 1, BLOCK, BLOCK + 3, 1000, 70_003];
const OFFSETS: [u64; 3] = [0, 7, 123_456];
const THREADS: [usize; 3] = [1, 2, 8];

fn randomized(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{}: length", what);
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{}: coord {} ({} vs {})", what, i, x, y);
    }
}

#[test]
fn fill_matches_scalar_reference_across_threads() {
    let stream = GaussianStream::new(42);
    for &len in &LENS {
        for &off in &OFFSETS {
            let reference: Vec<f32> = (0..len).map(|j| stream.z(off + j as u64)).collect();
            for &t in &THREADS {
                let eng = ZEngine::with_threads(t);
                let mut out = vec![0.0f32; len];
                eng.fill_z(stream, off, &mut out);
                assert_bits_eq(&out, &reference, &format!("fill len={} off={} t={}", len, off, t));
            }
        }
    }
}

#[test]
fn axpy_matches_scalar_reference_across_threads() {
    let stream = GaussianStream::new(7);
    let s = 1e-3f32;
    for &len in &LENS {
        for &off in &OFFSETS {
            let init = randomized(len, 1);
            let mut reference = init.clone();
            for (j, th) in reference.iter_mut().enumerate() {
                *th += s * stream.z(off + j as u64);
            }
            for &t in &THREADS {
                let eng = ZEngine::with_threads(t);
                let mut theta = init.clone();
                eng.axpy_z(stream, off, &mut theta, s);
                assert_bits_eq(&theta, &reference, &format!("axpy len={} off={} t={}", len, off, t));
            }
        }
    }
}

#[test]
fn perturb_into_matches_scalar_reference() {
    let stream = GaussianStream::new(8);
    let s = -2e-3f32;
    for &len in &[BLOCK + 3, 70_003] {
        let theta = randomized(len, 2);
        let off = 11u64;
        let reference: Vec<f32> = theta
            .iter()
            .enumerate()
            .map(|(j, &th)| th + s * stream.z(off + j as u64))
            .collect();
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            let mut out = vec![0.0f32; len];
            eng.perturb_into(stream, off, &theta, s, &mut out);
            assert_bits_eq(&out, &reference, &format!("perturb_into len={} t={}", len, t));
        }
    }
}

#[test]
fn sgd_update_matches_scalar_reference_across_threads() {
    let stream = GaussianStream::new(9);
    let (lr, g, wd) = (1e-2f32, 0.37f32, 1e-4f32);
    for &len in &LENS {
        let init = randomized(len, 3);
        let off = 64u64;
        let mut reference = init.clone();
        for (j, th) in reference.iter_mut().enumerate() {
            let z = stream.z(off + j as u64);
            *th -= lr * (g * z + wd * *th);
        }
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            let mut theta = init.clone();
            eng.sgd_update(stream, off, &mut theta, lr, g, wd);
            assert_bits_eq(&theta, &reference, &format!("sgd len={} t={}", len, t));
        }
    }
}

#[test]
fn multi_sgd_equals_sequential_single_seed_updates() {
    // the one-pass n-SPSA kernel must reproduce n sequential SGD passes bit
    // for bit (per coordinate the update order is the record order)
    let zs: Vec<(GaussianStream, f32)> = (0..5)
        .map(|k| (GaussianStream::new(100 + k), 0.1 * (k as f32 + 1.0) - 0.25))
        .collect();
    let (lr, wd) = (3e-3f32, 1e-4f32);
    for &len in &[1usize, BLOCK + 3, 70_003] {
        let init = randomized(len, 4);
        let off = 17u64;
        let mut reference = init.clone();
        for &(stream, g) in &zs {
            for (j, th) in reference.iter_mut().enumerate() {
                let z = stream.z(off + j as u64);
                *th -= lr * (g * z + wd * *th);
            }
        }
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            let mut theta = init.clone();
            eng.multi_sgd_update(&zs, off, &mut theta, lr, wd);
            assert_bits_eq(&theta, &reference, &format!("multi len={} t={}", len, t));
        }
    }
}

#[test]
fn fzoo_kernel_matches_scalar_reference_across_threads() {
    // the batched one-sided update: per coordinate, mean the per-seed
    // gradients first, then one fused subtraction with one wd term
    let zs: Vec<(GaussianStream, f32)> = (0..4)
        .map(|k| (GaussianStream::new(400 + k), 0.2 * (k as f32 + 1.0) - 0.5))
        .collect();
    let (lr, wd) = (2e-3f32, 1e-4f32);
    let n_f = zs.len() as f32;
    for &len in &[1usize, BLOCK + 3, 70_003] {
        let init = randomized(len, 13);
        let off = 21u64;
        let mut reference = init.clone();
        for (j, th) in reference.iter_mut().enumerate() {
            let mut g = 0.0f32;
            for &(stream, pg) in &zs {
                g += pg * stream.z(off + j as u64);
            }
            *th -= lr * (g / n_f + wd * *th);
        }
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            let mut theta = init.clone();
            eng.fzoo_update(&zs, off, &mut theta, lr, wd);
            assert_bits_eq(&theta, &reference, &format!("fzoo len={} t={}", len, t));
        }
    }
}

#[test]
fn fzoo_kernel_with_one_seed_equals_sgd_update() {
    // the n = 1 degenerate case IS the one-sided SPSA update
    let stream = GaussianStream::new(500);
    let (g, lr, wd) = (0.31f32, 1e-2f32, 1e-4f32);
    for &len in &[BLOCK + 3, 70_003] {
        let init = randomized(len, 14);
        let mut want = init.clone();
        let eng = ZEngine::with_threads(2);
        eng.sgd_update(stream, 5, &mut want, lr, g, wd);
        let mut got = init.clone();
        eng.fzoo_update(&[(stream, g)], 5, &mut got, lr, wd);
        assert_bits_eq(&got, &want, &format!("fzoo-n1 len={}", len));
    }
}

#[test]
fn multi_axpy_equals_sequential_axpy_across_threads() {
    // the batched replay kernel must reproduce k sequential axpy passes
    // bit for bit (per coordinate the seeds apply in slice order)
    let zs: Vec<(GaussianStream, f32)> = (0..5)
        .map(|k| (GaussianStream::new(600 + k), 1e-3 * (k as f32 + 1.0) - 2.5e-3))
        .collect();
    for &len in &[1usize, BLOCK + 3, 70_003] {
        let init = randomized(len, 15);
        let off = 13u64;
        let mut reference = init.clone();
        for &(stream, s) in &zs {
            for (j, th) in reference.iter_mut().enumerate() {
                *th += s * stream.z(off + j as u64);
            }
        }
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            let mut theta = init.clone();
            eng.multi_axpy_z(&zs, off, &mut theta);
            assert_bits_eq(&theta, &reference, &format!("multi_axpy len={} t={}", len, t));
        }
    }
}

#[test]
fn momentum_kernel_matches_scalar_reference() {
    let zs: Vec<(GaussianStream, f32)> =
        (0..3).map(|k| (GaussianStream::new(200 + k), 0.3 - 0.2 * k as f32)).collect();
    let (lr, wd, mu, n) = (1e-3f32, 1e-4f32, 0.9f32, 3.0f32);
    for &len in &[BLOCK + 3, 70_003] {
        let init_th = randomized(len, 5);
        let init_m = randomized(len, 6);
        let off = 9u64;
        let mut ref_th = init_th.clone();
        let mut ref_m = init_m.clone();
        for j in 0..len {
            let mut g = 0.0f32;
            for &(stream, pg) in &zs {
                g += pg * stream.z(off + j as u64);
            }
            g = g / n + wd * ref_th[j];
            ref_m[j] = mu * ref_m[j] + g;
            ref_th[j] -= lr * ref_m[j];
        }
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            let mut th = init_th.clone();
            let mut m = init_m.clone();
            eng.momentum_update(&zs, off, &mut th, &mut m, lr, wd, mu, n);
            assert_bits_eq(&th, &ref_th, &format!("momentum th len={} t={}", len, t));
            assert_bits_eq(&m, &ref_m, &format!("momentum m len={} t={}", len, t));
        }
    }
}

#[test]
fn adam_kernel_matches_scalar_reference() {
    let zs: Vec<(GaussianStream, f32)> =
        (0..2).map(|k| (GaussianStream::new(300 + k), 0.5 - 0.7 * k as f32)).collect();
    let p = AdamParams {
        lr: 1e-3,
        wd: 1e-4,
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-8,
        t: 4.0,
        n: 2.0,
    };
    for &len in &[BLOCK + 3, 70_003] {
        let init_th = randomized(len, 7);
        let init_m = randomized(len, 8);
        let init_v: Vec<f32> = randomized(len, 9).iter().map(|x| x * x).collect();
        let off = 33u64;
        let mut ref_th = init_th.clone();
        let mut ref_m = init_m.clone();
        let mut ref_v = init_v.clone();
        for j in 0..len {
            let mut g = 0.0f32;
            for &(stream, pg) in &zs {
                g += pg * stream.z(off + j as u64);
            }
            g = g / p.n + p.wd * ref_th[j];
            ref_m[j] = p.beta1 * ref_m[j] + (1.0 - p.beta1) * g;
            ref_v[j] = p.beta2 * ref_v[j] + (1.0 - p.beta2) * g * g;
            let mhat = ref_m[j] / (1.0 - p.beta1.powf(p.t));
            let vhat = ref_v[j] / (1.0 - p.beta2.powf(p.t));
            ref_th[j] -= p.lr * mhat / (vhat.sqrt() + p.eps);
        }
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            let mut th = init_th.clone();
            let mut m = init_m.clone();
            let mut v = init_v.clone();
            eng.adam_update(&zs, off, &mut th, &mut m, &mut v, p);
            assert_bits_eq(&th, &ref_th, &format!("adam th len={} t={}", len, t));
            assert_bits_eq(&m, &ref_m, &format!("adam m len={} t={}", len, t));
            assert_bits_eq(&v, &ref_v, &format!("adam v len={} t={}", len, t));
        }
    }
}

#[test]
fn ema_kernel_matches_scalar_reference() {
    let stream = GaussianStream::new(77);
    let (pgrad, beta) = (0.42f32, 0.9f32);
    for adam_style in [false, true] {
        for &len in &[BLOCK + 3, 70_003] {
            let init = randomized(len, 10);
            let off = 3u64;
            let mut reference = init.clone();
            for (j, mk) in reference.iter_mut().enumerate() {
                let g = pgrad * stream.z(off + j as u64);
                *mk = if adam_style { beta * *mk + (1.0 - beta) * g } else { beta * *mk + g };
            }
            for &t in &THREADS {
                let eng = ZEngine::with_threads(t);
                let mut m = init.clone();
                eng.ema_z(stream, off, &mut m, pgrad, beta, adam_style);
                assert_bits_eq(&m, &reference, &format!("ema len={} t={} adam={}", len, t, adam_style));
            }
        }
    }
}

#[test]
fn project_rows_matches_scalar_reference() {
    let stream = GaussianStream::new(55);
    let d_low = 48usize;
    let v = randomized(d_low, 11);
    let scale = 1.0 / (d_low as f32).sqrt();
    for &rows in &[3usize, 700] {
        let base = randomized(rows, 12);
        let reference: Vec<f32> = (0..rows)
            .map(|j| {
                let row = j as u64 * d_low as u64;
                let mut acc = 0.0f32;
                for (i, &vi) in v.iter().enumerate() {
                    acc += stream.z(row + i as u64) * vi;
                }
                base[j] + scale * acc
            })
            .collect();
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            let mut out = vec![0.0f32; rows];
            eng.project_rows(stream, d_low, &v, &base, scale, &mut out);
            assert_bits_eq(&out, &reference, &format!("project rows={} t={}", rows, t));
        }
    }
}

/// Random sorted mask over [0, len) with roughly `density` selection,
/// deterministic in `seed`.
fn random_mask(len: usize, density: f64, seed: u64) -> Vec<u32> {
    let mut rng = Pcg::new(seed);
    (0..len as u32).filter(|_| rng.next_f64() < density).collect()
}

#[test]
fn masked_axpy_full_mask_is_dense_and_sparse_touches_only_mask() {
    let stream = GaussianStream::new(91);
    let s = 2e-3f32;
    // 70_003 at 8 threads exercises the index-list carving (> PAR_MIN)
    for &len in &[BLOCK + 3, 70_003] {
        let init = randomized(len, 21);
        let off = 19u64;
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            // full mask == dense kernel, bitwise
            let full: Vec<u32> = (0..len as u32).collect();
            let mut dense = init.clone();
            eng.axpy_z(stream, off, &mut dense, s);
            let mut masked = init.clone();
            eng.axpy_z_masked(stream, off, &full, &mut masked, s);
            assert_bits_eq(&masked, &dense, &format!("masked axpy full len={} t={}", len, t));
            // sparse mask: masked coords get the dense kernel's value for
            // that coordinate; everything else is untouched
            let idxs = random_mask(len, 0.13, 77);
            let mut sparse = init.clone();
            eng.axpy_z_masked(stream, off, &idxs, &mut sparse, s);
            let mut hit = vec![false; len];
            for &i in &idxs {
                hit[i as usize] = true;
            }
            for j in 0..len {
                let want = if hit[j] { dense[j] } else { init[j] };
                assert_eq!(
                    sparse[j].to_bits(),
                    want.to_bits(),
                    "masked axpy sparse len={} t={} coord {}",
                    len, t, j
                );
            }
            // empty mask is a no-op
            let mut noop = init.clone();
            eng.axpy_z_masked(stream, off, &[], &mut noop, s);
            assert_bits_eq(&noop, &init, &format!("masked axpy empty len={} t={}", len, t));
        }
    }
}

#[test]
fn masked_kernels_cross_the_fill_crossover_consistently() {
    // a mask with one fully-dense block (>= MASK_FILL_MIN hits -> fill
    // path) and scattered singles (scalar z() path) must agree with the
    // scalar reference on every coordinate — the hybrid is a perf knob,
    // never a values knob
    let stream = GaussianStream::new(92);
    let len = 4 * BLOCK + 7;
    let mut idxs: Vec<u32> = (BLOCK as u32..2 * BLOCK as u32).collect(); // dense block
    idxs.extend([3u32, 700, 901, len as u32 - 1]); // sparse strays
    idxs.sort_unstable();
    assert!(idxs.len() >= super::kernels::MASK_FILL_MIN);
    let init = randomized(len, 22);
    let (lr, g, wd, off) = (1e-2f32, 0.4f32, 1e-4f32, 5u64);
    let mut reference = init.clone();
    for &i in &idxs {
        let z = stream.z(off + i as u64);
        let th = &mut reference[i as usize];
        *th -= lr * (g * z + wd * *th);
    }
    for &t in &THREADS {
        let eng = ZEngine::with_threads(t);
        let mut theta = init.clone();
        eng.sgd_update_masked(stream, off, &idxs, &mut theta, lr, g, wd);
        assert_bits_eq(&theta, &reference, &format!("masked sgd hybrid t={}", t));
    }
}

#[test]
fn masked_multi_seed_kernels_match_scalar_reference() {
    let zs: Vec<(GaussianStream, f32)> = (0..3)
        .map(|k| (GaussianStream::new(700 + k), 0.25 - 0.2 * k as f32))
        .collect();
    let (lr, wd, off) = (2e-3f32, 1e-4f32, 31u64);
    let n_f = zs.len() as f32;
    for &len in &[BLOCK + 3, 70_003] {
        let idxs = random_mask(len, 0.2, 55);
        let init = randomized(len, 23);
        // multi_sgd: per coord, seeds in slice order
        let mut ref_msgd = init.clone();
        for &i in &idxs {
            let th = &mut ref_msgd[i as usize];
            for &(stream, g) in &zs {
                let z = stream.z(off + i as u64);
                *th -= lr * (g * z + wd * *th);
            }
        }
        // fzoo: per coord, mean first then one fused subtraction
        let mut ref_fzoo = init.clone();
        for &i in &idxs {
            let th = &mut ref_fzoo[i as usize];
            let mut g = 0.0f32;
            for &(stream, pg) in &zs {
                g += pg * stream.z(off + i as u64);
            }
            *th -= lr * (g / n_f + wd * *th);
        }
        // multi_axpy: per coord, seeds in slice order
        let mut ref_maxpy = init.clone();
        for &i in &idxs {
            let th = &mut ref_maxpy[i as usize];
            for &(stream, s) in &zs {
                *th += s * stream.z(off + i as u64);
            }
        }
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            let mut a = init.clone();
            eng.multi_sgd_update_masked(&zs, off, &idxs, &mut a, lr, wd);
            assert_bits_eq(&a, &ref_msgd, &format!("masked multi_sgd len={} t={}", len, t));
            let mut b = init.clone();
            eng.fzoo_update_masked(&zs, off, &idxs, &mut b, lr, wd);
            assert_bits_eq(&b, &ref_fzoo, &format!("masked fzoo len={} t={}", len, t));
            let mut c = init.clone();
            eng.multi_axpy_z_masked(&zs, off, &idxs, &mut c);
            assert_bits_eq(&c, &ref_maxpy, &format!("masked multi_axpy len={} t={}", len, t));
        }
    }
}

#[test]
fn masked_perturb_into_writes_only_masked_coords() {
    let stream = GaussianStream::new(93);
    let s = 1e-3f32;
    for &len in &[BLOCK + 3, 70_003] {
        let theta = randomized(len, 24);
        let idxs = random_mask(len, 0.1, 66);
        let off = 47u64;
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            // out primed with a sentinel: unmasked coords must keep it
            let mut out = vec![f32::NEG_INFINITY; len];
            eng.perturb_into_masked(stream, off, &idxs, &theta, s, &mut out);
            let mut hit = vec![false; len];
            for &i in &idxs {
                hit[i as usize] = true;
            }
            for j in 0..len {
                if hit[j] {
                    let want = theta[j] + s * stream.z(off + j as u64);
                    assert_eq!(out[j].to_bits(), want.to_bits(), "len={} t={} coord {}", len, t, j);
                } else {
                    assert_eq!(out[j], f32::NEG_INFINITY, "len={} t={} coord {} written", len, t, j);
                }
            }
        }
    }
}

#[test]
#[should_panic(expected = "out of range")]
fn masked_kernel_rejects_out_of_range_index() {
    let mut theta = vec![0.0f32; 8];
    ZEngine::with_threads(1).axpy_z_masked(GaussianStream::new(1), 0, &[3, 8], &mut theta, 1.0);
}

#[test]
fn mask_bounds_cover_and_respect_caps() {
    for &n in &[1usize, 5, 1000, 70_003] {
        for &t in &[1usize, 2, 3, 8] {
            let bounds = mask_bounds(n, t, 1);
            assert_eq!(bounds.first().map(|r| r.0), Some(0));
            assert_eq!(bounds.last().map(|r| r.1), Some(n));
            for w in bounds.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            assert!(bounds.len() <= t);
        }
    }
}

#[test]
fn ranges_are_block_aligned_and_cover() {
    for &len in &[0usize, 1, BLOCK, 10 * BLOCK + 5, 70_003] {
        for &t in &[1usize, 2, 3, 8, 64] {
            let eng = ZEngine::with_threads(t);
            let ranges = eng.ranges(len, 1);
            assert_eq!(ranges.first().map(|r| r.0), Some(0));
            assert_eq!(ranges.last().map(|r| r.1), Some(len));
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
                assert_eq!(w[0].0 % BLOCK, 0, "block-aligned start");
            }
            assert!(ranges.len() <= t.max(1));
        }
    }
}

// ---------------- shard (range-scoped) kernels --------------------------

#[test]
fn shard_kernels_produce_bitwise_slices_of_the_dense_kernels() {
    // the sharding contract at unit level: running a kernel over [lo, hi)
    // with the counter advanced by lo equals the dense kernel's [lo, hi)
    // slice, for every kernel, at block-misaligned cuts, at threads 1/2/8
    let zs: Vec<(GaussianStream, f32)> = (0..3)
        .map(|k| (GaussianStream::new(800 + k), 0.3 - 0.25 * k as f32))
        .collect();
    let (stream, g) = zs[0];
    let (lr, wd, s, off) = (1e-2f32, 1e-4f32, 2e-3f32, 29u64);
    for &len in &[BLOCK + 3, 70_003] {
        let init = randomized(len, 41);
        // cuts misaligned with BLOCK and with thread chunking
        let mut cuts = vec![0usize, 7, BLOCK - 1, len / 2 + 3, len];
        cuts.sort_unstable();
        for &t in &THREADS {
            let eng = ZEngine::with_threads(t);
            // dense references
            let mut d_axpy = init.clone();
            eng.axpy_z(stream, off, &mut d_axpy, s);
            let mut d_pert = vec![0.0f32; len];
            eng.perturb_into(stream, off, &init, s, &mut d_pert);
            let mut d_sgd = init.clone();
            eng.sgd_update(stream, off, &mut d_sgd, lr, g, wd);
            let mut d_msgd = init.clone();
            eng.multi_sgd_update(&zs, off, &mut d_msgd, lr, wd);
            let mut d_fzoo = init.clone();
            eng.fzoo_update(&zs, off, &mut d_fzoo, lr, wd);
            let mut d_maxpy = init.clone();
            eng.multi_axpy_z(&zs, off, &mut d_maxpy);
            // shard-by-shard runs over the SAME full buffers
            let mut s_axpy = init.clone();
            let mut s_pert = vec![0.0f32; len];
            let mut s_sgd = init.clone();
            let mut s_msgd = init.clone();
            let mut s_fzoo = init.clone();
            let mut s_maxpy = init.clone();
            for w in cuts.windows(2) {
                let (lo, hi) = (w[0], w[1]);
                eng.axpy_z_shard(stream, off, lo, hi, &mut s_axpy, s);
                eng.perturb_into_shard(stream, off, lo, hi, &init, s, &mut s_pert);
                eng.sgd_update_shard(stream, off, lo, hi, &mut s_sgd, lr, g, wd);
                eng.multi_sgd_update_shard(&zs, off, lo, hi, &mut s_msgd, lr, wd);
                eng.fzoo_update_shard(&zs, off, lo, hi, &mut s_fzoo, lr, wd);
                eng.multi_axpy_z_shard(&zs, off, lo, hi, &mut s_maxpy);
            }
            assert_bits_eq(&s_axpy, &d_axpy, &format!("shard axpy len={} t={}", len, t));
            assert_bits_eq(&s_pert, &d_pert, &format!("shard perturb len={} t={}", len, t));
            assert_bits_eq(&s_sgd, &d_sgd, &format!("shard sgd len={} t={}", len, t));
            assert_bits_eq(&s_msgd, &d_msgd, &format!("shard multi_sgd len={} t={}", len, t));
            assert_bits_eq(&s_fzoo, &d_fzoo, &format!("shard fzoo len={} t={}", len, t));
            assert_bits_eq(&s_maxpy, &d_maxpy, &format!("shard multi_axpy len={} t={}", len, t));
        }
    }
}

#[test]
fn shard_kernels_touch_only_their_range() {
    let stream = GaussianStream::new(94);
    let len = 2 * BLOCK + 11;
    let init = randomized(len, 42);
    let (lo, hi) = (37usize, BLOCK + 5);
    let mut theta = init.clone();
    ZEngine::with_threads(4).axpy_z_shard(stream, 3, lo, hi, &mut theta, 1e-3);
    let mut moved = 0usize;
    for j in 0..len {
        if j < lo || j >= hi {
            assert_eq!(theta[j].to_bits(), init[j].to_bits(), "coord {} outside range moved", j);
        } else {
            moved += (theta[j].to_bits() != init[j].to_bits()) as usize;
        }
    }
    // (a tiny z can leave an individual coordinate bit-identical; the
    // range as a whole must move)
    assert!(moved > (hi - lo) / 2, "only {} of {} in-range coords moved", moved, hi - lo);
    // an empty range is a no-op
    let mut noop = init.clone();
    ZEngine::with_threads(4).axpy_z_shard(stream, 3, 5, 5, &mut noop, 1e-3);
    assert_bits_eq(&noop, &init, "empty shard range");
}

#[test]
#[should_panic(expected = "shard range")]
fn shard_kernel_rejects_out_of_range() {
    let mut theta = vec![0.0f32; 8];
    ZEngine::with_threads(1).axpy_z_shard(GaussianStream::new(1), 0, 4, 9, &mut theta, 1.0);
}

// ---------------- persistent worker pool lifecycle ----------------------

#[test]
fn pool_and_scope_dispatch_are_bit_identical() {
    // the tentpole pin at unit level (the full kernel matrix lives in
    // tests/properties.rs): pool dispatch vs the retained scope path
    let stream = GaussianStream::new(99);
    let (lr, g, wd, s) = (1e-2f32, 0.37f32, 1e-4f32, 1e-3f32);
    for &len in &[BLOCK + 3, 70_003, 200_000] {
        let init = randomized(len, 33);
        let idxs = random_mask(len, 0.2, 34);
        for &t in &THREADS {
            let pool_eng = ZEngine::with_threads(t);
            let scope_eng = ZEngine::with_threads_scoped(t);
            let mut a = init.clone();
            pool_eng.sgd_update(stream, 7, &mut a, lr, g, wd);
            let mut b = init.clone();
            scope_eng.sgd_update(stream, 7, &mut b, lr, g, wd);
            assert_bits_eq(&a, &b, &format!("sgd pool vs scope len={} t={}", len, t));
            let mut a = init.clone();
            pool_eng.axpy_z_masked(stream, 7, &idxs, &mut a, s);
            let mut b = init.clone();
            scope_eng.axpy_z_masked(stream, 7, &idxs, &mut b, s);
            assert_bits_eq(&a, &b, &format!("masked axpy pool vs scope len={} t={}", len, t));
        }
    }
}

#[test]
fn engine_is_deterministic_when_used_from_concurrent_os_threads() {
    // several OS threads dispatching on the shared pool at once: no
    // deadlock, and every thread gets the single-thread bits
    let stream = GaussianStream::new(777);
    let len = 150_000;
    let init = randomized(len, 31);
    let (lr, g, wd) = (1e-3f32, 0.21f32, 1e-5f32);
    let mut want = init.clone();
    ZEngine::with_threads(1).sgd_update(stream, 3, &mut want, lr, g, wd);
    std::thread::scope(|sc| {
        for _ in 0..4 {
            sc.spawn(|| {
                for &t in &[2usize, 4, 8] {
                    let mut theta = init.clone();
                    ZEngine::with_threads(t).sgd_update(stream, 3, &mut theta, lr, g, wd);
                    assert_bits_eq(&theta, &want, &format!("concurrent t={}", t));
                }
            });
        }
    });
}

#[test]
fn pool_grows_with_demand_and_still_serves_smaller_budgets() {
    let stream = GaussianStream::new(555);
    let len = 200_000; // >= 8 * PAR_MIN coordinates -> 8 chunks at t=8
    let init = randomized(len, 35);
    let mut want = init.clone();
    ZEngine::with_threads(1).axpy_z(stream, 0, &mut want, 1e-3);
    let mut big = init.clone();
    ZEngine::with_threads(8).axpy_z(stream, 0, &mut big, 1e-3);
    assert_bits_eq(&big, &want, "t=8");
    // 8 chunks -> 7 helper jobs (the 8th chunk ran on this thread); the
    // pool never shrinks, so this holds regardless of test ordering
    assert!(
        pool::spawned_workers() >= 7,
        "pool should have grown to >= 7 workers, have {}",
        pool::spawned_workers()
    );
    // a smaller budget after growth still chunks by ITS budget and
    // produces the same bits
    let mut small = init.clone();
    ZEngine::with_threads(2).axpy_z(stream, 0, &mut small, 1e-3);
    assert_bits_eq(&small, &want, "t=2 after growth");
}

#[test]
fn mezo_threads_is_respected_after_pool_init() {
    // grow the pool well past the default budget first
    let mut buf = vec![0.0f32; 200_000];
    ZEngine::with_threads(8).fill_z(GaussianStream::new(3), 0, &mut buf);
    // the env knob still decides ZEngine::default() — pool growth must
    // never leak into the thread budget (verify.sh runs this whole suite
    // under MEZO_THREADS=1/2/8, which is when the assertion bites)
    if let Some(n) =
        std::env::var("MEZO_THREADS").ok().and_then(|s| s.parse::<usize>().ok()).filter(|&n| n > 0)
    {
        assert_eq!(default_threads(), n);
        assert_eq!(ZEngine::default().threads, n);
    }
    // and the default engine's bits match the explicit single-thread bits
    let stream = GaussianStream::new(888);
    let init = randomized(150_000, 32);
    let mut want = init.clone();
    ZEngine::with_threads(1).axpy_z(stream, 5, &mut want, 2e-3);
    let mut got = init.clone();
    ZEngine::default().axpy_z(stream, 5, &mut got, 2e-3);
    assert_bits_eq(&got, &want, "default engine after pool growth");
}

#[test]
fn pool_propagates_worker_panics_and_stays_usable() {
    let jobs: Vec<pool::Job<'static>> = vec![
        Box::new(|| panic!("boom-worker")),
        Box::new(|| {}), // final job runs on the calling thread
    ];
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool::run_jobs(jobs)));
    assert!(caught.is_err(), "worker panic must resurface on the caller");
    // the worker caught the panic pool-side and parked again; the pool
    // keeps serving dispatches with correct bits
    let stream = GaussianStream::new(4242);
    let init = randomized(200_000, 30);
    let mut want = init.clone();
    ZEngine::with_threads(1).axpy_z(stream, 0, &mut want, 1e-3);
    let mut got = init.clone();
    ZEngine::with_threads(8).axpy_z(stream, 0, &mut got, 1e-3);
    assert_bits_eq(&got, &want, "pool dispatch after a worker panic");
}

#[test]
fn default_engine_is_sane() {
    let eng = ZEngine::default();
    assert!(eng.threads >= 1);
    assert!(eng.simd().supported());
    // a tiny buffer must not spawn: exercised implicitly (no panic, right
    // result) — the real assertion is bit-equality above
    let mut out = vec![0.0f32; 4];
    eng.fill_z(GaussianStream::new(1), 0, &mut out);
    assert!(out.iter().all(|x| x.is_finite()));
}

// ---------------- explicit SIMD tiers ------------------------------------

#[test]
fn every_simd_tier_matches_scalar_bits_across_threads() {
    // The tentpole pin at unit level (the full 17-kernel matrix including
    // masked/_shard entry points lives in tests/properties.rs): every
    // runnable SIMD tier == the scalar tier, to the bit, for the dense
    // kernels, across threads 1/2/8 and lengths that are NOT multiples of
    // any lane width (1, 5, BLOCK-1, BLOCK+3, 70_003 exercise both the
    // vector loop and every remainder size).
    let stream = GaussianStream::new(321);
    let zs: Vec<(GaussianStream, f32)> =
        (0..3).map(|k| (GaussianStream::new(900 + k), 0.4 - 0.3 * k as f32)).collect();
    let (lr, g, wd, s) = (1e-2f32, 0.37f32, 1e-4f32, 1e-3f32);
    let p = AdamParams { lr, wd, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 3.0, n: 3.0 };
    for tier in Tier::available() {
        if tier == Tier::Scalar {
            continue;
        }
        for &len in &LENS {
            let init = randomized(len, 51);
            let off = 13u64;
            for &t in &THREADS {
                let simd_eng = ZEngine::with_threads_simd(t, tier);
                let ref_eng = ZEngine::with_threads_simd(t, Tier::Scalar);
                assert_eq!(simd_eng.simd(), tier);
                let label = |k: &str| format!("{} tier={} len={} t={}", k, tier, len, t);

                let mut a = vec![0.0f32; len];
                let mut b = vec![0.0f32; len];
                simd_eng.fill_z(stream, off, &mut a);
                ref_eng.fill_z(stream, off, &mut b);
                assert_bits_eq(&a, &b, &label("fill_z"));

                let mut a = init.clone();
                let mut b = init.clone();
                simd_eng.axpy_z(stream, off, &mut a, s);
                ref_eng.axpy_z(stream, off, &mut b, s);
                assert_bits_eq(&a, &b, &label("axpy_z"));

                let mut a = vec![0.0f32; len];
                let mut b = vec![0.0f32; len];
                simd_eng.perturb_into(stream, off, &init, s, &mut a);
                ref_eng.perturb_into(stream, off, &init, s, &mut b);
                assert_bits_eq(&a, &b, &label("perturb_into"));

                let mut a = init.clone();
                let mut b = init.clone();
                simd_eng.sgd_update(stream, off, &mut a, lr, g, wd);
                ref_eng.sgd_update(stream, off, &mut b, lr, g, wd);
                assert_bits_eq(&a, &b, &label("sgd_update"));

                let mut a = init.clone();
                let mut b = init.clone();
                simd_eng.multi_sgd_update(&zs, off, &mut a, lr, wd);
                ref_eng.multi_sgd_update(&zs, off, &mut b, lr, wd);
                assert_bits_eq(&a, &b, &label("multi_sgd_update"));

                let mut a = init.clone();
                let mut b = init.clone();
                simd_eng.fzoo_update(&zs, off, &mut a, lr, wd);
                ref_eng.fzoo_update(&zs, off, &mut b, lr, wd);
                assert_bits_eq(&a, &b, &label("fzoo_update"));

                let mut a = init.clone();
                let mut b = init.clone();
                simd_eng.multi_axpy_z(&zs, off, &mut a);
                ref_eng.multi_axpy_z(&zs, off, &mut b);
                assert_bits_eq(&a, &b, &label("multi_axpy_z"));

                let m0 = randomized(len, 52);
                let mut a = init.clone();
                let mut am = m0.clone();
                let mut b = init.clone();
                let mut bm = m0.clone();
                simd_eng.momentum_update(&zs, off, &mut a, &mut am, lr, wd, 0.9, 3.0);
                ref_eng.momentum_update(&zs, off, &mut b, &mut bm, lr, wd, 0.9, 3.0);
                assert_bits_eq(&a, &b, &label("momentum th"));
                assert_bits_eq(&am, &bm, &label("momentum m"));

                let v0: Vec<f32> = randomized(len, 53).iter().map(|x| x * x).collect();
                let mut a = init.clone();
                let mut am = m0.clone();
                let mut av = v0.clone();
                let mut b = init.clone();
                let mut bm = m0.clone();
                let mut bv = v0.clone();
                simd_eng.adam_update(&zs, off, &mut a, &mut am, &mut av, p);
                ref_eng.adam_update(&zs, off, &mut b, &mut bm, &mut bv, p);
                assert_bits_eq(&a, &b, &label("adam th"));
                assert_bits_eq(&am, &bm, &label("adam m"));
                assert_bits_eq(&av, &bv, &label("adam v"));

                for adam_style in [false, true] {
                    let mut a = m0.clone();
                    let mut b = m0.clone();
                    simd_eng.ema_z(stream, off, &mut a, 0.42, 0.9, adam_style);
                    ref_eng.ema_z(stream, off, &mut b, 0.42, 0.9, adam_style);
                    assert_bits_eq(&a, &b, &label(&format!("ema_z adam={}", adam_style)));
                }
            }
        }
    }
}

#[test]
fn project_rows_is_tier_invariant() {
    // project_rows keeps its sequential dot in every tier (only the row
    // fill dispatches), so its bits must be tier-independent too
    let stream = GaussianStream::new(322);
    let d_low = 48usize;
    let v = randomized(d_low, 54);
    let base = randomized(700, 55);
    let scale = 1.0 / (d_low as f32).sqrt();
    let mut want = vec![0.0f32; 700];
    ZEngine::with_threads_simd(1, Tier::Scalar).project_rows(stream, d_low, &v, &base, scale, &mut want);
    for tier in Tier::available() {
        for &t in &THREADS {
            let mut got = vec![0.0f32; 700];
            ZEngine::with_threads_simd(t, tier).project_rows(stream, d_low, &v, &base, scale, &mut got);
            assert_bits_eq(&got, &want, &format!("project_rows tier={} t={}", tier, t));
        }
    }
}

#[test]
fn first_touch_preserves_bits() {
    for &len in &[5usize, 70_003, 200_000] {
        let init = randomized(len, 56);
        let mut buf = init.clone();
        for &t in &THREADS {
            ZEngine::with_threads(t).first_touch(&mut buf);
            assert_bits_eq(&buf, &init, &format!("first_touch len={} t={}", len, t));
        }
    }
}

#[test]
#[should_panic(expected = "not runnable")]
fn forcing_an_unsupported_tier_on_the_engine_fails_loudly() {
    // On every platform at least one hardware tier is foreign (NEON on
    // x86_64, the AVX tiers on aarch64), so this panics everywhere.
    let foreign = [Tier::Neon, Tier::Avx2, Tier::Avx512]
        .into_iter()
        .find(|t| !t.supported())
        .expect("some tier must be unsupported on any given platform");
    let _ = ZEngine::with_threads_simd(1, foreign);
}
