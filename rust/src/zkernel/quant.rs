//! Quantized (SensZOQ) kernel tier: block-quantized θ under the dense
//! kernel arithmetic.
//!
//! The SensZOQ recipe (PAPERS.md, 2410.09823) keeps the *dense* weights
//! in fixed-width integer blocks — [`QBLOCK`] coordinates per block, one
//! f32 scale each, int8 or int4 codes ([`QBits`]) — and only the sparse
//! *sensitive* coordinates (a [`super::SparseMask`]'s lists) in full f32,
//! stored compacted in an **overlay** (`idxs[k] ↦ overlay[k]`). This
//! module supplies the kernel entry points for that layout:
//!
//! * **Dense quant kernels** ([`ZEngine::axpy_z_quant`],
//!   [`ZEngine::sgd_update_quant`], [`ZEngine::multi_sgd_update_quant`],
//!   [`ZEngine::fzoo_update_quant`], [`ZEngine::multi_axpy_z_quant`],
//!   [`ZEngine::perturb_into_quant`]) dequantize one [`BLOCK`] at a time
//!   into a stack buffer, splice the overlay's exact f32 values over the
//!   masked slots, run the *existing* dense serial kernel body (the same
//!   `block_apply8!`/SIMD dispatch, at the same global z counters) over
//!   the block, write masked results back to the overlay, and requantize
//!   each [`QBLOCK`] sub-block. Masked (overlay) coordinates therefore
//!   see bit-for-bit the dense kernel's arithmetic; unmasked coordinates
//!   land within the per-block dequantization bound (half a scale step —
//!   see [`QBits`]) of where the dense kernel would put them.
//! * **Masked quant kernels** ([`ZEngine::axpy_z_quant_masked`] and
//!   friends) walk the overlay directly — pure f32, per-coordinate
//!   `z(offset + idx)` through the same shared `*1` op bodies as the
//!   dense kernels ([`GaussianStream::fill`] is elementwise `z()`, so
//!   blocked and per-coordinate generation agree bitwise) — which is
//!   what makes masked quantized stepping `to_bits()`-identical to the
//!   dense masked path at any thread count and SIMD tier (pinned in
//!   `tests/quant.rs` under the verify matrix).
//!
//! Threading reuses the engine's block-aligned range carving: chunk
//! boundaries are [`BLOCK`]-aligned, [`QBLOCK`] divides [`BLOCK`], and
//! int4 codes pack two per byte, so every chunk owns disjoint code
//! bytes, scale slots and overlay runs — the same determinism argument
//! as the dense kernels, extended to the quantized buffers.

use super::{kernels, pool, Tier, ZEngine, BLOCK, PAR_MIN};
use crate::rng::GaussianStream;

/// Coordinates per quantization block (one f32 scale each). Divides
/// [`BLOCK`], so engine chunk boundaries never split a scale block.
pub const QBLOCK: usize = 64;

/// Code width of a quantized tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QBits {
    /// One signed byte per coordinate; codes in [−127, 127].
    Int8,
    /// One nibble per coordinate (two per byte, even index in the low
    /// nibble, stored biased by +8); codes in [−7, 7].
    Int4,
}

impl QBits {
    /// Largest code magnitude: 127 (int8) or 7 (int4). A block's scale
    /// is `absmax / levels`, so every unmasked coordinate dequantizes
    /// within `scale / 2` of its f32 value — the pinned per-block
    /// dequantization error bound.
    pub fn levels(self) -> f32 {
        match self {
            QBits::Int8 => 127.0,
            QBits::Int4 => 7.0,
        }
    }

    /// [`QBits::levels`] as the integer clamp limit.
    pub fn q_max(self) -> i32 {
        match self {
            QBits::Int8 => 127,
            QBits::Int4 => 7,
        }
    }

    /// Code bytes needed for the first `len` coordinates of a tensor.
    pub fn bytes_for(self, len: usize) -> usize {
        match self {
            QBits::Int8 => len,
            QBits::Int4 => len.div_ceil(2),
        }
    }
}

/// Read-only view of one quantized tensor (codes + scales + overlay).
/// The overlay is compacted: `idxs[k]` (tensor-absolute, strictly
/// increasing) holds its exact f32 value in `overlay[k]`, and the code
/// under a masked coordinate is 0 — reads go through the overlay.
#[derive(Debug, Clone, Copy)]
pub struct QuantTensorRef<'a> {
    /// Code width.
    pub bits: QBits,
    /// Tensor length in coordinates.
    pub len: usize,
    /// Packed codes (`bits.bytes_for(len)` bytes).
    pub data: &'a [u8],
    /// Per-[`QBLOCK`] scales (`len.div_ceil(QBLOCK)` of them).
    pub scales: &'a [f32],
    /// Sorted masked coordinates (tensor-absolute).
    pub idxs: &'a [u32],
    /// Exact f32 values of the masked coordinates, parallel to `idxs`.
    pub overlay: &'a [f32],
}

/// Mutable view of one quantized tensor — what the quant kernels write
/// through. Same layout contract as [`QuantTensorRef`].
#[derive(Debug)]
pub struct QuantTensorMut<'a> {
    /// Code width.
    pub bits: QBits,
    /// Tensor length in coordinates.
    pub len: usize,
    /// Packed codes (`bits.bytes_for(len)` bytes).
    pub data: &'a mut [u8],
    /// Per-[`QBLOCK`] scales (`len.div_ceil(QBLOCK)` of them).
    pub scales: &'a mut [f32],
    /// Sorted masked coordinates (tensor-absolute).
    pub idxs: &'a [u32],
    /// Exact f32 values of the masked coordinates, parallel to `idxs`.
    pub overlay: &'a mut [f32],
}

impl QuantTensorMut<'_> {
    /// Reborrow as a read-only view.
    pub fn as_ref(&self) -> QuantTensorRef<'_> {
        QuantTensorRef {
            bits: self.bits,
            len: self.len,
            data: self.data,
            scales: self.scales,
            idxs: self.idxs,
            overlay: self.overlay,
        }
    }
}

/// A malformed quant view would silently read codes or scales at the
/// wrong slots, so fail fast with named errors (mirrors `check_mask`).
fn check_quant(bits: QBits, len: usize, data: &[u8], scales: &[f32], idxs: &[u32], overlay: &[f32]) {
    assert_eq!(data.len(), bits.bytes_for(len), "zkernel: quant code buffer length mismatch");
    assert_eq!(scales.len(), len.div_ceil(QBLOCK), "zkernel: quant scale buffer length mismatch");
    assert_eq!(overlay.len(), idxs.len(), "zkernel: quant overlay/index length mismatch");
    debug_assert!(
        idxs.windows(2).all(|w| w[0] < w[1]),
        "zkernel: quant overlay indices not sorted/unique"
    );
    if let Some(&last) = idxs.last() {
        assert!(
            (last as usize) < len,
            "zkernel: quant overlay index {} out of range for tensor of length {}",
            last,
            len
        );
    }
}

/// Code of coordinate `i` (buffer-local), sign-extended.
#[inline(always)]
fn q_get(bits: QBits, data: &[u8], i: usize) -> i32 {
    match bits {
        QBits::Int8 => data[i] as i8 as i32,
        QBits::Int4 => {
            let b = data[i / 2];
            let nib = if i % 2 == 0 { b & 0x0f } else { b >> 4 };
            nib as i32 - 8
        }
    }
}

/// Store code `q` at coordinate `i` (buffer-local).
#[inline(always)]
fn q_set(bits: QBits, data: &mut [u8], i: usize, q: i32) {
    match bits {
        QBits::Int8 => data[i] = q as i8 as u8,
        QBits::Int4 => {
            let nib = (q + 8) as u8;
            let b = &mut data[i / 2];
            if i % 2 == 0 {
                *b = (*b & 0xf0) | nib;
            } else {
                *b = (*b & 0x0f) | (nib << 4);
            }
        }
    }
}

/// Quantize one whole tensor: symmetric absmax per [`QBLOCK`] over the
/// UNMASKED coordinates (`idxs` sorted, tensor-absolute), codes
/// round-to-nearest clamped to ±[`QBits::q_max`]; masked coordinates
/// store code 0 (their value lives in the overlay). An all-zero (or
/// fully masked) block stores scale 0 with all-zero codes.
pub fn quantize(bits: QBits, vals: &[f32], idxs: &[u32], data: &mut [u8], scales: &mut [f32]) {
    assert_eq!(data.len(), bits.bytes_for(vals.len()), "zkernel: quant code buffer length mismatch");
    assert_eq!(
        scales.len(),
        vals.len().div_ceil(QBLOCK),
        "zkernel: quant scale buffer length mismatch"
    );
    let levels = bits.levels();
    let lim = bits.q_max();
    let mut mi = 0usize;
    let mut b = 0usize;
    while b < vals.len() {
        let n = QBLOCK.min(vals.len() - b);
        let m0 = mi;
        while mi < idxs.len() && (idxs[mi] as usize) < b + n {
            mi += 1;
        }
        let masked = &idxs[m0..mi];
        let mut amax = 0.0f32;
        let mut mk = 0usize;
        for j in 0..n {
            if mk < masked.len() && masked[mk] as usize == b + j {
                mk += 1;
                continue;
            }
            amax = amax.max(vals[b + j].abs());
        }
        let scale = if amax > 0.0 { amax / levels } else { 0.0 };
        scales[b / QBLOCK] = scale;
        mk = 0;
        for j in 0..n {
            let q = if (mk < masked.len() && masked[mk] as usize == b + j) || scale == 0.0 {
                if mk < masked.len() && masked[mk] as usize == b + j {
                    mk += 1;
                }
                0
            } else {
                ((vals[b + j] / scale).round() as i32).clamp(-lim, lim)
            };
            q_set(bits, data, b + j, q);
        }
        b += n;
    }
}

/// Dequantize one whole tensor into `out`: codes·scale everywhere, then
/// the overlay's exact f32 values spliced over the masked coordinates.
pub fn dequantize(t: QuantTensorRef<'_>, out: &mut [f32]) {
    assert_eq!(out.len(), t.len, "zkernel: quant dequantize length mismatch");
    check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
    for (c, o) in out.iter_mut().enumerate() {
        *o = q_get(t.bits, t.data, c) as f32 * t.scales[c / QBLOCK];
    }
    for (k, &idx) in t.idxs.iter().enumerate() {
        out[idx as usize] = t.overlay[k];
    }
}

/// One fused dense-kernel op, carried into the per-chunk quant driver.
enum QuantOp<'a> {
    /// θ += s·z
    Axpy { stream: GaussianStream, s: f32 },
    /// θ −= lr·(g·z + wd·θ)
    Sgd { stream: GaussianStream, lr: f32, g: f32, wd: f32 },
    /// n-SPSA: every (stream, g) update in slice order
    MultiSgd { zs: &'a [(GaussianStream, f32)], lr: f32, wd: f32 },
    /// FZOO batched one-sided mean update
    Fzoo { zs: &'a [(GaussianStream, f32)], lr: f32, wd: f32 },
    /// θ += Σᵢ sᵢ·zᵢ
    MultiAxpy { zs: &'a [(GaussianStream, f32)] },
}

impl QuantOp<'_> {
    /// Run the op's dense serial body over one dequantized block whose
    /// first coordinate has global z counter `zoff` — exactly the
    /// arithmetic (and z) the dense kernel applies to that block.
    fn apply(&self, tier: Tier, zoff: u64, buf: &mut [f32]) {
        match *self {
            QuantOp::Axpy { stream, s } => kernels::axpy_serial(tier, stream, zoff, buf, s),
            QuantOp::Sgd { stream, lr, g, wd } => {
                kernels::sgd_serial(tier, stream, zoff, buf, lr, g, wd)
            }
            QuantOp::MultiSgd { zs, lr, wd } => {
                kernels::multi_sgd_serial(tier, zs, zoff, buf, lr, wd)
            }
            QuantOp::Fzoo { zs, lr, wd } => kernels::fzoo_serial(tier, zs, zoff, buf, lr, wd),
            QuantOp::MultiAxpy { zs } => kernels::multi_axpy_serial(tier, zs, zoff, buf),
        }
    }
}

/// Serial quant-op driver over one chunk: per [`BLOCK`], dequantize into
/// a stack buffer, splice the overlay, run the dense serial body at the
/// block's global z counters, copy masked results back to the overlay,
/// and requantize each [`QBLOCK`] sub-block (masked coordinates excluded
/// from the absmax, stored as code 0).
#[allow(clippy::too_many_arguments)]
fn quant_chunk(
    tier: Tier,
    op: &QuantOp<'_>,
    zoff: u64,
    start: usize,
    len: usize,
    bits: QBits,
    data: &mut [u8],
    scales: &mut [f32],
    idxs: &[u32],
    overlay: &mut [f32],
) {
    let levels = bits.levels();
    let lim = bits.q_max();
    let mut buf = [0.0f32; BLOCK];
    let mut mi = 0usize;
    let mut i = 0usize;
    while i < len {
        let n = BLOCK.min(len - i);
        let mut masked = [false; BLOCK];
        for (j, b) in buf[..n].iter_mut().enumerate() {
            let c = i + j;
            *b = q_get(bits, data, c) as f32 * scales[c / QBLOCK];
        }
        let m0 = mi;
        while mi < idxs.len() && (idxs[mi] as usize) < start + i + n {
            let j = idxs[mi] as usize - start - i;
            buf[j] = overlay[mi];
            masked[j] = true;
            mi += 1;
        }
        op.apply(tier, zoff + i as u64, &mut buf[..n]);
        for k in m0..mi {
            overlay[k] = buf[idxs[k] as usize - start - i];
        }
        let mut qb = 0usize;
        while qb < n {
            let qn = QBLOCK.min(n - qb);
            let mut amax = 0.0f32;
            for j in qb..qb + qn {
                if !masked[j] {
                    amax = amax.max(buf[j].abs());
                }
            }
            let scale = if amax > 0.0 { amax / levels } else { 0.0 };
            scales[(i + qb) / QBLOCK] = scale;
            for j in qb..qb + qn {
                let q = if masked[j] || scale == 0.0 {
                    0
                } else {
                    ((buf[j] / scale).round() as i32).clamp(-lim, lim)
                };
                q_set(bits, data, i + j, q);
            }
            qb += qn;
        }
        i += n;
    }
}

impl ZEngine {
    /// Run `f(start, len, codes, scales, idxs, overlay)` over disjoint
    /// chunks of a quantized tensor, carved on the engine's block-aligned
    /// ranges. [`QBLOCK`] divides [`BLOCK`] and int4 packs two codes per
    /// byte, so every boundary lands between scale blocks and between
    /// code bytes; the overlay is carved by `partition_point` on the
    /// chunk's coordinate range.
    fn run_quant<F>(&self, t: QuantTensorMut<'_>, min_per_thread: usize, f: F)
    where
        F: Fn(usize, usize, &mut [u8], &mut [f32], &[u32], &mut [f32]) + Sync,
    {
        let QuantTensorMut { bits, len, data, scales, idxs, overlay } = t;
        let ranges = self.ranges(len, min_per_thread);
        if ranges.len() <= 1 {
            f(0, len, data, scales, idxs, overlay);
            return;
        }
        let fr = &f;
        let mut rest_d = data;
        let mut rest_s = scales;
        let mut rest_o = overlay;
        let mut rest_i = idxs;
        let mut done_b = 0usize;
        let mut done_s = 0usize;
        let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(ranges.len());
        for &(start, end) in &ranges {
            let nb = bits.bytes_for(end) - done_b;
            let (cd, td) = std::mem::take(&mut rest_d).split_at_mut(nb);
            let ns = end.div_ceil(QBLOCK) - done_s;
            let (cs, ts) = std::mem::take(&mut rest_s).split_at_mut(ns);
            let cut = rest_i.partition_point(|&ix| (ix as usize) < end);
            let (ci, tri) = rest_i.split_at(cut);
            let (co, to) = std::mem::take(&mut rest_o).split_at_mut(cut);
            rest_d = td;
            rest_s = ts;
            rest_i = tri;
            rest_o = to;
            done_b += nb;
            done_s += ns;
            jobs.push(Box::new(move || fr(start, end - start, cd, cs, ci, co)));
        }
        self.execute(jobs);
    }

    /// As [`ZEngine::run_quant`], for the staging shape: the quantized
    /// tensor is read-only and a full-length f32 `out` is carved mutably
    /// in lockstep.
    fn run_quant_src<F>(&self, t: QuantTensorRef<'_>, out: &mut [f32], min_per_thread: usize, f: F)
    where
        F: Fn(usize, &[u8], &[f32], &[u32], &[f32], &mut [f32]) + Sync,
    {
        assert_eq!(t.len, out.len(), "zkernel: quant src/dst length mismatch");
        let ranges = self.ranges(t.len, min_per_thread);
        if ranges.len() <= 1 {
            f(0, t.data, t.scales, t.idxs, t.overlay, out);
            return;
        }
        let fr = &f;
        let mut rest = out;
        let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(ranges.len());
        for &(start, end) in &ranges {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
            rest = tail;
            let cd = &t.data[t.bits.bytes_for(start)..t.bits.bytes_for(end)];
            let cs = &t.scales[start / QBLOCK..end.div_ceil(QBLOCK)];
            let a = t.idxs.partition_point(|&ix| (ix as usize) < start);
            let b = t.idxs.partition_point(|&ix| (ix as usize) < end);
            let ci = &t.idxs[a..b];
            let co = &t.overlay[a..b];
            jobs.push(Box::new(move || fr(start, cd, cs, ci, co, chunk)));
        }
        self.execute(jobs);
    }

    // ---------------- dense quant kernels --------------------------------
    //
    // Each is the quantized counterpart of the like-named dense kernel:
    // same per-coordinate arithmetic, same global z counters, applied to
    // the dequantized block and requantized after. Overlay (masked)
    // coordinates pass through in exact f32 — bitwise the dense kernel's
    // result; unmasked coordinates are within half a scale step.

    /// Quantized [`ZEngine::axpy_z`]: θ[j] += s · z(offset + j) over a
    /// quantized tensor.
    pub fn axpy_z_quant(&self, stream: GaussianStream, offset: u64, t: QuantTensorMut<'_>, s: f32) {
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let tier = self.simd;
        let bits = t.bits;
        let op = QuantOp::Axpy { stream, s };
        self.run_quant(t, PAR_MIN, |start, len, d, sc, ix, ov| {
            quant_chunk(tier, &op, offset + start as u64, start, len, bits, d, sc, ix, ov);
        });
    }

    /// Quantized [`ZEngine::sgd_update`]: the MeZO-SGD update over a
    /// quantized tensor.
    pub fn sgd_update_quant(
        &self,
        stream: GaussianStream,
        offset: u64,
        t: QuantTensorMut<'_>,
        lr: f32,
        g: f32,
        wd: f32,
    ) {
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let tier = self.simd;
        let bits = t.bits;
        let op = QuantOp::Sgd { stream, lr, g, wd };
        self.run_quant(t, PAR_MIN, |start, len, d, sc, ix, ov| {
            quant_chunk(tier, &op, offset + start as u64, start, len, bits, d, sc, ix, ov);
        });
    }

    /// Quantized [`ZEngine::multi_sgd_update`]: all n-SPSA updates in one
    /// pass over a quantized tensor.
    pub fn multi_sgd_update_quant(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        t: QuantTensorMut<'_>,
        lr: f32,
        wd: f32,
    ) {
        if zs.is_empty() {
            return;
        }
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let tier = self.simd;
        let bits = t.bits;
        let op = QuantOp::MultiSgd { zs, lr, wd };
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run_quant(t, min, |start, len, d, sc, ix, ov| {
            quant_chunk(tier, &op, offset + start as u64, start, len, bits, d, sc, ix, ov);
        });
    }

    /// Quantized [`ZEngine::fzoo_update`]: the FZOO batched one-sided
    /// mean update over a quantized tensor.
    pub fn fzoo_update_quant(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        t: QuantTensorMut<'_>,
        lr: f32,
        wd: f32,
    ) {
        if zs.is_empty() {
            return;
        }
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let tier = self.simd;
        let bits = t.bits;
        let op = QuantOp::Fzoo { zs, lr, wd };
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run_quant(t, min, |start, len, d, sc, ix, ov| {
            quant_chunk(tier, &op, offset + start as u64, start, len, bits, d, sc, ix, ov);
        });
    }

    /// Quantized [`ZEngine::multi_axpy_z`]: θ[j] += Σᵢ sᵢ·zᵢ(offset + j)
    /// over a quantized tensor — the seed-batched replay primitive.
    pub fn multi_axpy_z_quant(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        t: QuantTensorMut<'_>,
    ) {
        if zs.is_empty() {
            return;
        }
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let tier = self.simd;
        let bits = t.bits;
        let op = QuantOp::MultiAxpy { zs };
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run_quant(t, min, |start, len, d, sc, ix, ov| {
            quant_chunk(tier, &op, offset + start as u64, start, len, bits, d, sc, ix, ov);
        });
    }

    /// Quantized [`ZEngine::perturb_into`]: out[j] = θ[j] + s · z(offset
    /// + j) with θ dequantized on the fly (overlay exact, codes·scale
    /// elsewhere); the quantized tensor is untouched. The `θ + s·z` is
    /// applied by the dense axpy body over the dequantized chunk — the
    /// identical per-coordinate arithmetic and z as
    /// [`ZEngine::perturb_into`] on a dense θ.
    pub fn perturb_into_quant(
        &self,
        stream: GaussianStream,
        offset: u64,
        t: QuantTensorRef<'_>,
        s: f32,
        out: &mut [f32],
    ) {
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let tier = self.simd;
        let bits = t.bits;
        self.run_quant_src(t, out, PAR_MIN, |start, d, sc, ix, ov, chunk| {
            for (c, o) in chunk.iter_mut().enumerate() {
                *o = q_get(bits, d, c) as f32 * sc[c / QBLOCK];
            }
            for (k, &idx) in ix.iter().enumerate() {
                chunk[idx as usize - start] = ov[k];
            }
            kernels::axpy_serial(tier, stream, offset + start as u64, chunk, s);
        });
    }

    // ---------------- masked quant kernels -------------------------------
    //
    // Sparse SensZOQ stepping on a quantized store touches ONLY overlay
    // coordinates — exact f32, per-coordinate z at the dense counters,
    // through the same `*1` op bodies as every other kernel tier — so
    // each is `to_bits()`-identical to its dense `_masked` counterpart.
    // The walk is serial (overlay lists are small by construction);
    // every op index must have an overlay slot, else the store was
    // quantized under a different mask — fail fast.

    /// Masked quantized axpy: overlay[idx] += s · z(offset + idx) for
    /// each `idx` in `idxs` (every idx must be an overlay coordinate).
    pub fn axpy_z_quant_masked(
        &self,
        stream: GaussianStream,
        offset: u64,
        idxs: &[u32],
        t: QuantTensorMut<'_>,
        s: f32,
    ) {
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let mut slot = 0usize;
        for &idx in idxs {
            slot = overlay_slot(t.idxs, slot, idx);
            kernels::axpy1(&mut t.overlay[slot], stream.z(offset + idx as u64), s);
        }
    }

    /// Masked quantized perturb-into: out[idx] = overlay[idx] + s ·
    /// z(offset + idx); other coordinates of `out` are NOT written.
    pub fn perturb_into_quant_masked(
        &self,
        stream: GaussianStream,
        offset: u64,
        idxs: &[u32],
        t: QuantTensorRef<'_>,
        s: f32,
        out: &mut [f32],
    ) {
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        assert_eq!(t.len, out.len(), "zkernel: quant src/dst length mismatch");
        let mut slot = 0usize;
        for &idx in idxs {
            slot = overlay_slot(t.idxs, slot, idx);
            let z = stream.z(offset + idx as u64);
            kernels::perturb1(&mut out[idx as usize], t.overlay[slot], z, s);
        }
    }

    /// Masked quantized MeZO-SGD update over the overlay coordinates.
    #[allow(clippy::too_many_arguments)]
    pub fn sgd_update_quant_masked(
        &self,
        stream: GaussianStream,
        offset: u64,
        idxs: &[u32],
        t: QuantTensorMut<'_>,
        lr: f32,
        g: f32,
        wd: f32,
    ) {
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let mut slot = 0usize;
        for &idx in idxs {
            slot = overlay_slot(t.idxs, slot, idx);
            kernels::sgd1(&mut t.overlay[slot], stream.z(offset + idx as u64), lr, g, wd);
        }
    }

    /// Masked quantized n-SPSA: every `(stream, g)` update applied in
    /// slice order per overlay coordinate.
    pub fn multi_sgd_update_quant_masked(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        idxs: &[u32],
        t: QuantTensorMut<'_>,
        lr: f32,
        wd: f32,
    ) {
        if zs.is_empty() {
            return;
        }
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let mut slot = 0usize;
        for &idx in idxs {
            slot = overlay_slot(t.idxs, slot, idx);
            let z = |kk: usize| zs[kk].0.z(offset + idx as u64);
            kernels::multi_sgd1(&mut t.overlay[slot], zs, z, lr, wd);
        }
    }

    /// Masked quantized FZOO batched one-sided mean update over the
    /// overlay coordinates.
    pub fn fzoo_update_quant_masked(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        idxs: &[u32],
        t: QuantTensorMut<'_>,
        lr: f32,
        wd: f32,
    ) {
        if zs.is_empty() {
            return;
        }
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let n_f = zs.len() as f32;
        let mut slot = 0usize;
        for &idx in idxs {
            slot = overlay_slot(t.idxs, slot, idx);
            let z = |kk: usize| zs[kk].0.z(offset + idx as u64);
            kernels::fzoo1(&mut t.overlay[slot], zs, z, n_f, lr, wd);
        }
    }

    /// Masked quantized multi-seed axpy — the sparse seed-batched replay
    /// primitive over the overlay coordinates.
    pub fn multi_axpy_z_quant_masked(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        idxs: &[u32],
        t: QuantTensorMut<'_>,
    ) {
        if zs.is_empty() {
            return;
        }
        check_quant(t.bits, t.len, t.data, t.scales, t.idxs, t.overlay);
        let mut slot = 0usize;
        for &idx in idxs {
            slot = overlay_slot(t.idxs, slot, idx);
            let z = |kk: usize| zs[kk].0.z(offset + idx as u64);
            kernels::multi_axpy1(&mut t.overlay[slot], zs, z);
        }
    }
}

/// Advance the two-pointer overlay walk to `idx`'s slot; panics when the
/// store's overlay has no such coordinate (the op's mask is not the mask
/// the store was quantized under).
#[inline]
fn overlay_slot(overlay_idxs: &[u32], from: usize, idx: u32) -> usize {
    let mut slot = from;
    while slot < overlay_idxs.len() && overlay_idxs[slot] < idx {
        slot += 1;
    }
    assert!(
        slot < overlay_idxs.len() && overlay_idxs[slot] == idx,
        "zkernel: quant masked index {} has no overlay coordinate (mask/store mismatch)",
        idx
    );
    slot
}
