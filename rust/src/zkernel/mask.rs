//! Sparse SensZOQ-style masks: static sets of "sensitive" coordinates the
//! zeroth-order step perturbs and updates, everything else frozen.
//!
//! SensZOQ (Wang et al., 2024, arXiv:2410.09823) observes that a small,
//! *static* subset of weights — selected once by a sensitivity score such
//! as weight magnitude or the empirical-Fisher diagonal — captures almost
//! all of the fine-tuning signal, so the ZO perturb/update passes only
//! need to walk that subset. A [`SparseMask`] is the crate's
//! representation of such a subset: one sorted, duplicate-free list of
//! coordinate indices per tensor of a
//! [`ParamStore`](crate::model::params::ParamStore), aligned with the
//! store's tensor order.
//!
//! Two invariants make masks compose with the [`ZEngine`](super::ZEngine)
//! determinism contract:
//!
//! 1. **Global z-indexing is preserved.** A masked kernel reads coordinate
//!    `i` of a tensor with `z(tensor_offset + i)` — exactly the index the
//!    dense kernel uses — so a full mask reproduces the dense kernel
//!    bit for bit, and sparse results are independent of what the mask
//!    *excludes* (see `tests/properties.rs`).
//! 2. **Sorted, unique indices.** The engine chunks the index list across
//!    threads and carves the parameter buffer at chunk-boundary
//!    coordinates; sortedness is what makes those carve points disjoint.
//!    [`SparseMask::from_indices`] rejects unsorted or duplicated input,
//!    so every mask reaching a kernel satisfies the invariant.
//!
//! ```
//! use mezo::model::meta::TensorDesc;
//! use mezo::model::params::ParamStore;
//! use mezo::rng::GaussianStream;
//! use mezo::zkernel::{Sensitivity, SparseMask, ZEngine};
//! let mut p = ParamStore::from_specs(vec![
//!     TensorDesc { name: "w".into(), shape: vec![512], dtype: "f32".into() },
//! ]);
//! p.init(1);
//! // keep the 64 largest-magnitude weights (SensZOQ's simplest score)
//! let mask = SparseMask::top_k(&p, &[0], 64, Sensitivity::Magnitude).unwrap();
//! assert_eq!(mask.n_selected(), 64);
//! // a masked perturbation touches ONLY the selected coordinates, and
//! // gives each one the same z the dense kernel would
//! let before = p.data[0].clone();
//! let stream = GaussianStream::new(7);
//! ZEngine::with_threads(2).axpy_z_masked(stream, 0, mask.indices(0), &mut p.data[0], 1e-2);
//! for (j, (a, b)) in p.data[0].iter().zip(&before).enumerate() {
//!     if mask.indices(0).contains(&(j as u32)) {
//!         assert_eq!(*a, b + 1e-2 * stream.z(j as u64));
//!     } else {
//!         assert_eq!(a, b);
//!     }
//! }
//! ```

use crate::model::params::ParamStore;
use crate::rng::splitmix64;
use anyhow::{bail, Result};

/// How [`SparseMask::top_k`] scores a coordinate's sensitivity.
#[derive(Debug, Clone, Copy)]
pub enum Sensitivity<'a> {
    /// `|θ_i|` — weight-magnitude selection, computable from the store
    /// alone.
    Magnitude,
    /// External per-coordinate scores, one slice per *selected tensor* in
    /// the `tensors` argument's order (e.g. accumulated squared projected
    /// gradients `Σ (g·z_i)²`, the ZO estimate of the empirical-Fisher
    /// diagonal SensZOQ selects with). Slice lengths must match the
    /// tensors they score.
    Scores(&'a [Vec<f32>]),
}

/// A static sparse coordinate set over a [`ParamStore`]: per tensor, a
/// sorted duplicate-free list of the coordinates the masked kernels may
/// touch. See the [module docs](self) for the invariants.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SparseMask {
    /// `tensors[ti]` = sorted unique coordinate indices of store tensor
    /// `ti`; empty for tensors the mask excludes entirely.
    tensors: Vec<Vec<u32>>,
    /// digest of `tensors`, computed once at construction (masks are
    /// immutable) so per-step digest reads are O(1)
    digest: u64,
}

impl SparseMask {
    /// Internal constructor: callers guarantee the sorted-unique
    /// invariant; the digest is computed here, once.
    fn from_validated(tensors: Vec<Vec<u32>>) -> SparseMask {
        let digest = compute_digest(&tensors);
        SparseMask { tensors, digest }
    }

    /// Mask from explicit per-tensor index lists (aligned with the store's
    /// tensor order; one entry per tensor, empty = tensor fully frozen).
    /// Errors on unsorted or duplicated indices — the engine's carving
    /// depends on the invariant.
    pub fn from_indices(tensors: Vec<Vec<u32>>) -> Result<SparseMask> {
        for (ti, idxs) in tensors.iter().enumerate() {
            for w in idxs.windows(2) {
                if w[0] >= w[1] {
                    bail!(
                        "SparseMask: tensor {} indices not strictly increasing ({} then {})",
                        ti,
                        w[0],
                        w[1]
                    );
                }
            }
        }
        Ok(SparseMask::from_validated(tensors))
    }

    /// The empty mask over a store: every kernel is a no-op under it.
    pub fn empty(params: &ParamStore) -> SparseMask {
        SparseMask::from_validated(vec![Vec::new(); params.specs.len()])
    }

    /// The full mask over the given tensors: every coordinate selected.
    /// Masked kernels under a full mask are bit-identical to their dense
    /// counterparts — the oracle the property suite pins. (The index list
    /// materializes 4 bytes per coordinate; full masks are for testing and
    /// density sweeps, not production sparsity.)
    pub fn full(params: &ParamStore, tensors: &[usize]) -> SparseMask {
        let mut out = vec![Vec::new(); params.specs.len()];
        for &ti in tensors {
            out[ti] = (0..params.data[ti].len() as u32).collect();
        }
        SparseMask::from_validated(out)
    }

    /// Select the `k` most sensitive coordinates across the given tensors
    /// (SensZOQ's static sensitive-weight set). Ordering is a total order
    /// — score descending, then (tensor, index) ascending — so selection
    /// is deterministic even under score ties. `k` of zero gives the empty
    /// mask; `k` at or above the tensors' total size gives the full mask.
    pub fn top_k(
        params: &ParamStore,
        tensors: &[usize],
        k: usize,
        how: Sensitivity<'_>,
    ) -> Result<SparseMask> {
        let mut seen = vec![false; params.specs.len()];
        for &ti in tensors {
            if ti >= params.specs.len() {
                bail!(
                    "SparseMask::top_k: tensor index {} out of range (store has {})",
                    ti,
                    params.specs.len()
                );
            }
            if seen[ti] {
                // a duplicated tensor would duplicate its candidates and
                // could select the same coordinate twice, silently breaking
                // the sorted-unique invariant the kernels carve by
                bail!("SparseMask::top_k: tensor {} listed more than once", ti);
            }
            seen[ti] = true;
        }
        if let Sensitivity::Scores(scores) = how {
            if scores.len() != tensors.len() {
                bail!(
                    "SparseMask::top_k: {} score slices for {} tensors",
                    scores.len(),
                    tensors.len()
                );
            }
            for (s, &ti) in scores.iter().zip(tensors) {
                if s.len() != params.data[ti].len() {
                    bail!(
                        "SparseMask::top_k: score slice length {} != tensor {} length {}",
                        s.len(),
                        ti,
                        params.data[ti].len()
                    );
                }
            }
        }
        let total: usize = tensors.iter().map(|&ti| params.data[ti].len()).sum();
        if k >= total {
            return Ok(SparseMask::full(params, tensors));
        }
        let mut out = vec![Vec::new(); params.specs.len()];
        if k == 0 {
            return Ok(SparseMask::from_validated(out));
        }
        // (score, tensor, index) for every candidate coordinate; a partial
        // select puts the k best first, then each tensor's survivors sort.
        let mut all: Vec<(f32, u32, u32)> = Vec::with_capacity(total);
        for (slot, &ti) in tensors.iter().enumerate() {
            for (j, &v) in params.data[ti].iter().enumerate() {
                let score = match how {
                    Sensitivity::Magnitude => v.abs(),
                    Sensitivity::Scores(scores) => scores[slot][j],
                };
                all.push((score, ti as u32, j as u32));
            }
        }
        let cmp = |a: &(f32, u32, u32), b: &(f32, u32, u32)| {
            b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
        };
        all.select_nth_unstable_by(k - 1, cmp);
        all.truncate(k);
        for &(_, ti, j) in &all {
            out[ti as usize].push(j);
        }
        for idxs in &mut out {
            idxs.sort_unstable();
        }
        Ok(SparseMask::from_validated(out))
    }

    /// The sorted coordinate list for store tensor `ti` (empty slice when
    /// the tensor is fully frozen) — what the masked kernels walk.
    pub fn indices(&self, ti: usize) -> &[u32] {
        &self.tensors[ti]
    }

    /// Number of tensors the mask is defined over (== the store's).
    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    /// Total selected coordinates across all tensors.
    pub fn n_selected(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Selected fraction of the whole store's parameters, in [0, 1].
    pub fn density(&self, params: &ParamStore) -> f64 {
        let n = params.n_params();
        if n == 0 {
            0.0
        } else {
            self.n_selected() as f64 / n as f64
        }
    }

    /// Check the mask is applicable to a store: one index list per store
    /// tensor, every index in range. (Sortedness/uniqueness hold by
    /// construction.) Optimizers call this before stepping so a mask built
    /// against the wrong store fails loudly instead of mis-addressing z.
    ///
    /// Generic over [`Theta`](crate::model::Theta): a mask validates
    /// against any store sharing the tensor ABI — dense or quantized —
    /// because only shapes are consulted, never values.
    pub fn validate<T: crate::model::Theta + ?Sized>(&self, params: &T) -> Result<()> {
        if self.tensors.len() != params.specs().len() {
            bail!(
                "SparseMask: mask covers {} tensors, store has {}",
                self.tensors.len(),
                params.specs().len()
            );
        }
        for (ti, idxs) in self.tensors.iter().enumerate() {
            if let Some(&last) = idxs.last() {
                if last as usize >= params.tensor_len(ti) {
                    bail!(
                        "SparseMask: tensor {} index {} out of range (len {})",
                        ti,
                        last,
                        params.tensor_len(ti)
                    );
                }
            }
        }
        Ok(())
    }

    /// Order-sensitive 64-bit digest of the mask's full structure
    /// (tensor count, per-tensor counts, every index), via a chained
    /// splitmix64 walk computed once at construction — masks are
    /// immutable, so this is an O(1) read. Logged next to a sparse run's
    /// trajectory so replay can verify it is reconstructing under the
    /// *same* mask — any added/removed/moved index changes the digest
    /// (`storage::Trajectory::replay_masked` checks it and fails loudly
    /// on mismatch).
    pub fn digest(&self) -> u64 {
        self.digest
    }
}

/// The chained splitmix64 walk behind [`SparseMask::digest`].
fn compute_digest(tensors: &[Vec<u32>]) -> u64 {
    let mut h = splitmix64(0x0005_EA5E_u64 ^ tensors.len() as u64);
    for idxs in tensors {
        h = splitmix64(h ^ idxs.len() as u64);
        for &i in idxs {
            h = splitmix64(h ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::TensorDesc;

    fn store(lens: &[usize]) -> ParamStore {
        let specs = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| TensorDesc {
                name: format!("t{}", i),
                shape: vec![n],
                dtype: "f32".into(),
            })
            .collect();
        let mut p = ParamStore::from_specs(specs);
        p.init(3);
        p
    }

    #[test]
    fn from_indices_rejects_unsorted_and_duplicates() {
        assert!(SparseMask::from_indices(vec![vec![0, 2, 1]]).is_err());
        assert!(SparseMask::from_indices(vec![vec![0, 1, 1]]).is_err());
        assert!(SparseMask::from_indices(vec![vec![0, 1, 5], vec![]]).is_ok());
    }

    #[test]
    fn full_and_empty_shapes() {
        let p = store(&[10, 7]);
        let full = SparseMask::full(&p, &[0, 1]);
        assert_eq!(full.n_selected(), 17);
        assert_eq!(full.indices(1), &[0, 1, 2, 3, 4, 5, 6]);
        assert!((full.density(&p) - 1.0).abs() < 1e-12);
        let empty = SparseMask::empty(&p);
        assert_eq!(empty.n_selected(), 0);
        assert_eq!(empty.n_tensors(), 2);
    }

    #[test]
    fn top_k_magnitude_picks_largest_weights() {
        let mut p = store(&[6]);
        p.data[0] = vec![0.1, -5.0, 0.2, 3.0, -0.05, 4.0];
        let m = SparseMask::top_k(&p, &[0], 3, Sensitivity::Magnitude).unwrap();
        assert_eq!(m.indices(0), &[1, 3, 5]);
        // k >= total selects everything; k == 0 selects nothing
        let all = SparseMask::top_k(&p, &[0], 99, Sensitivity::Magnitude).unwrap();
        assert_eq!(all.n_selected(), 6);
        let none = SparseMask::top_k(&p, &[0], 0, Sensitivity::Magnitude).unwrap();
        assert_eq!(none.n_selected(), 0);
    }

    #[test]
    fn top_k_is_deterministic_under_ties() {
        let mut p = store(&[8]);
        p.data[0] = vec![1.0; 8]; // all tied: (tensor, index) order breaks ties
        let m = SparseMask::top_k(&p, &[0], 3, Sensitivity::Magnitude).unwrap();
        assert_eq!(m.indices(0), &[0, 1, 2]);
    }

    #[test]
    fn top_k_scores_selects_by_external_sensitivity() {
        let p = store(&[4, 4]);
        let scores = vec![vec![0.0, 9.0, 0.0, 1.0], vec![5.0, 0.0, 7.0, 0.0]];
        let m = SparseMask::top_k(&p, &[0, 1], 3, Sensitivity::Scores(&scores)).unwrap();
        assert_eq!(m.indices(0), &[1]);
        assert_eq!(m.indices(1), &[0, 2]);
        // malformed score shapes are rejected
        assert!(SparseMask::top_k(&p, &[0, 1], 3, Sensitivity::Scores(&scores[..1])).is_err());
        let bad = vec![vec![0.0; 3], vec![0.0; 4]];
        assert!(SparseMask::top_k(&p, &[0, 1], 3, Sensitivity::Scores(&bad)).is_err());
    }

    #[test]
    fn top_k_rejects_duplicate_and_out_of_range_tensors() {
        let p = store(&[8, 8]);
        // a duplicated tensor id could select the same coordinate twice
        let err = SparseMask::top_k(&p, &[0, 0], 4, Sensitivity::Magnitude).unwrap_err();
        assert!(err.to_string().contains("more than once"), "{}", err);
        let err = SparseMask::top_k(&p, &[2], 4, Sensitivity::Magnitude).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{}", err);
    }

    #[test]
    fn validate_checks_tensor_count_and_range() {
        let p = store(&[10, 7]);
        assert!(SparseMask::full(&p, &[0, 1]).validate(&p).is_ok());
        let wrong_count = SparseMask::from_indices(vec![vec![0]]).unwrap();
        assert!(wrong_count.validate(&p).is_err());
        let out_of_range = SparseMask::from_indices(vec![vec![0], vec![7]]).unwrap();
        assert!(out_of_range.validate(&p).is_err());
    }

    #[test]
    fn digest_is_structure_sensitive() {
        let p = store(&[64, 64]);
        let a = SparseMask::from_indices(vec![vec![1, 5, 9], vec![2]]).unwrap();
        let b = SparseMask::from_indices(vec![vec![1, 5, 10], vec![2]]).unwrap();
        let c = SparseMask::from_indices(vec![vec![1, 5], vec![2, 9]]).unwrap();
        assert_ne!(a.digest(), b.digest());
        assert_ne!(a.digest(), c.digest());
        assert_ne!(b.digest(), c.digest());
        assert_eq!(a.digest(), a.clone().digest());
        assert_ne!(SparseMask::empty(&p).digest(), SparseMask::full(&p, &[0]).digest());
    }
}
