//! Explicit, runtime-dispatched SIMD tiers for the per-block kernel bodies.
//!
//! PR 4's `block_apply8!` unroll is autovectorizer *bait*: whether the
//! compiler actually emits vector code for a given body depends on
//! optimization mood. This module makes the vector shape explicit. Each
//! hot per-block body (the arithmetic between one `GaussianStream` fill
//! and the next) exists in up to four **tiers**:
//!
//! * [`Tier::Avx512`] — 16 × f32 lanes via `_mm512_*` (`std::arch`);
//!   needs `avx512f`+`avx512dq` at runtime AND a rustc ≥ 1.89 build (the
//!   intrinsics' stabilization release — see `build.rs`, cfg
//!   `mezo_avx512`). This tier also carries the only SIMD z-*generation*
//!   body (`GaussianStream::fill_dispatch`): splitmix64 mixing needs
//!   64-bit lane multiplies, which first appear in AVX-512DQ.
//! * [`Tier::Avx2`] — 8 × f32 lanes via `_mm256_*`.
//! * [`Tier::Neon`] — 4 × f32 lanes via `v*q_f32` (aarch64).
//! * [`Tier::Scalar`] — the PR 4 unrolled path in `kernels.rs`, always
//!   available, and the **reference bits** every other tier is pinned to.
//!
//! BIT-EXACTNESS ACROSS TIERS: lanes are whole, independent coordinates,
//! and every vector op used here (`add/sub/mul/div/sqrt`, f32) is a
//! single correctly-rounded IEEE-754 operation — identical to its scalar
//! counterpart. The generated bodies perform, per coordinate, exactly the
//! operation sequence of the scalar `*1` helpers in `kernels.rs`:
//! multi-seed accumulation stays *within* a lane in slice order, no
//! horizontal reductions, and **no FMA** (contraction would change
//! rounding; none of the fused-multiply intrinsics appear here, and Rust
//! never contracts `a * b + c` on its own). Remainder coordinates
//! (`n % LANES`) run through the scalar helpers themselves. Hence every
//! tier is `to_bits()`-identical to [`Tier::Scalar`] by construction —
//! and by test: `zkernel/tests.rs` and the `tests/properties.rs` SIMD
//! group pin all available tiers against scalar across thread counts,
//! unaligned lengths, masked and `_shard` entry points.
//!
//! `project_rows` deliberately has NO SIMD tier: its inner loop is a
//! sequential reduction and lane-splitting it would reorder the
//! summation (see `kernels::project_rows_serial`).
//!
//! Tier selection: [`Tier::active`] reads `MEZO_SIMD` once per process
//! (same discipline as `MEZO_THREADS`; precedence rules live in the
//! `zkernel` module docs) and falls back to the best tier the CPU
//! supports. A bogus or unsupported value panics loudly — silently
//! falling back would un-test the tier CI asked for.

use std::sync::OnceLock;

/// SIMD instruction tier for the per-block kernel bodies. Selection never
/// changes results — every tier is pinned `to_bits()`-identical to
/// [`Tier::Scalar`] — only wall-clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// 16-lane `_mm512_*` bodies plus the SIMD z-fill. Requires runtime
    /// `avx512f`+`avx512dq` and a rustc ≥ 1.89 build (`mezo_avx512`).
    Avx512,
    /// 8-lane `_mm256_*` bodies (x86_64 with runtime `avx2`).
    Avx2,
    /// 4-lane NEON bodies (aarch64; `neon` is baseline there).
    Neon,
    /// The unrolled scalar path (`block_apply8!`) — always available; the
    /// reference bits for every other tier.
    Scalar,
}

#[cfg(all(target_arch = "x86_64", mezo_avx512))]
fn have_avx512() -> bool {
    is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512dq")
}
#[cfg(not(all(target_arch = "x86_64", mezo_avx512)))]
fn have_avx512() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn have_avx2() -> bool {
    is_x86_feature_detected!("avx2")
}
#[cfg(not(target_arch = "x86_64"))]
fn have_avx2() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn have_neon() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}
#[cfg(not(target_arch = "aarch64"))]
fn have_neon() -> bool {
    false
}

impl Tier {
    /// The tier's `MEZO_SIMD` name.
    pub fn name(self) -> &'static str {
        match self {
            Tier::Avx512 => "avx512",
            Tier::Avx2 => "avx2",
            Tier::Neon => "neon",
            Tier::Scalar => "scalar",
        }
    }

    /// Whether this CPU *and* this build can actually run the tier.
    /// [`Tier::Scalar`] is always supported; [`Tier::Avx512`] additionally
    /// requires the crate to have been built by rustc ≥ 1.89 (`build.rs`).
    pub fn supported(self) -> bool {
        match self {
            Tier::Avx512 => have_avx512(),
            Tier::Avx2 => have_avx2(),
            Tier::Neon => have_neon(),
            Tier::Scalar => true,
        }
    }

    /// Every tier runnable here, best first (always ends with `Scalar`).
    /// The cross-tier bit-identity tests and the `simd_dispatch` bench
    /// group iterate this.
    pub fn available() -> Vec<Tier> {
        [Tier::Avx512, Tier::Avx2, Tier::Neon, Tier::Scalar]
            .into_iter()
            .filter(|t| t.supported())
            .collect()
    }

    /// Best tier the CPU supports (what `MEZO_SIMD=auto` resolves to).
    pub fn detect() -> Tier {
        if have_avx512() {
            Tier::Avx512
        } else if have_avx2() {
            Tier::Avx2
        } else if have_neon() {
            Tier::Neon
        } else {
            Tier::Scalar
        }
    }

    /// Process-default tier: `MEZO_SIMD` (read ONCE, like `MEZO_THREADS` —
    /// precedence rules in the `zkernel` module docs) or [`Tier::detect`].
    /// Panics on a bogus or unsupported `MEZO_SIMD` value.
    pub fn active() -> Tier {
        static T: OnceLock<Tier> = OnceLock::new();
        *T.get_or_init(|| match std::env::var("MEZO_SIMD") {
            Ok(v) => parse_mezo_simd(&v),
            Err(_) => Tier::detect(),
        })
    }

    /// Whether this tier has a SIMD z-generation body (AVX-512 only; see
    /// `GaussianStream::fill_dispatch`).
    pub(crate) fn simd_fill(self) -> bool {
        self == Tier::Avx512
    }
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Resolve a `MEZO_SIMD` value to a tier. Unknown names and tiers this
/// CPU/build cannot run both panic — loudly, by design: a CI leg that
/// asks for `avx512` must test avx512 or fail, never silently fall back
/// to scalar and go green.
pub(crate) fn parse_mezo_simd(value: &str) -> Tier {
    let tier = match value.trim().to_ascii_lowercase().as_str() {
        "auto" => return Tier::detect(),
        "avx512" => Tier::Avx512,
        "avx2" => Tier::Avx2,
        "neon" => Tier::Neon,
        "scalar" => Tier::Scalar,
        other => panic!(
            "MEZO_SIMD={:?}: unknown SIMD tier (expected auto|avx512|avx2|neon|scalar)",
            other
        ),
    };
    assert!(
        tier.supported(),
        "MEZO_SIMD={}: tier not runnable on this CPU/toolchain (available: {})",
        value,
        Tier::available().iter().map(|t| t.name()).collect::<Vec<_>>().join("|"),
    );
    tier
}

// ---------------- per-kernel tier dispatch ------------------------------
//
// One dispatcher per block body. The scalar arm calls the `block_apply8!`
// body in `kernels.rs`; the SIMD arms call the per-ISA `unsafe fn`s
// below. SAFETY invariant for every `unsafe` arm: a `Tier` value only
// reaches a dispatcher through `ZEngine`, whose constructors validate
// `Tier::supported()` (runtime CPU feature detection) — the `#[target_
// feature]` bodies are never entered on a CPU lacking the feature.

macro_rules! dispatcher {
    ($(#[$doc:meta])* $name:ident ($($arg:ident : $ty:ty),* $(,)?)) => {
        $(#[$doc])*
        #[allow(clippy::too_many_arguments)]
        pub(super) fn $name(tier: Tier, $($arg: $ty),*) {
            #[cfg(all(target_arch = "x86_64", mezo_avx512))]
            {
                if tier == Tier::Avx512 {
                    // SAFETY: avx512f+avx512dq verified at tier construction.
                    unsafe { avx512::$name($($arg),*) };
                    return;
                }
            }
            #[cfg(target_arch = "x86_64")]
            {
                if tier == Tier::Avx2 {
                    // SAFETY: avx2 verified at tier construction.
                    unsafe { avx2::$name($($arg),*) };
                    return;
                }
            }
            #[cfg(target_arch = "aarch64")]
            {
                if tier == Tier::Neon {
                    // SAFETY: neon verified at tier construction.
                    unsafe { neon::$name($($arg),*) };
                    return;
                }
            }
            let _ = tier;
            super::kernels::$name($($arg),*);
        }
    };
}

use crate::rng::GaussianStream;
use crate::zkernel::AdamParams;

dispatcher!(
    /// θ[j] += s·zb[j] (per-coordinate body: `kernels::axpy1`).
    axpy_block(th: &mut [f32], zb: &[f32], s: f32)
);
dispatcher!(
    /// out[j] = θ[j] + s·zb[j] (`kernels::perturb1`).
    perturb_block(out: &mut [f32], th: &[f32], zb: &[f32], s: f32)
);
dispatcher!(
    /// θ[j] −= lr·(g·zb[j] + wd·θ[j]) (`kernels::sgd1`).
    sgd_block(th: &mut [f32], zb: &[f32], lr: f32, g: f32, wd: f32)
);
dispatcher!(
    /// n-SPSA updates in seed order per coordinate (`kernels::multi_sgd1`);
    /// `zb` holds seed k's block at `zb[k*BLOCK..]`.
    multi_sgd_block(
        th: &mut [f32],
        zb: &[f32],
        zs: &[(GaussianStream, f32)],
        lr: f32,
        wd: f32,
    )
);
dispatcher!(
    /// FZOO batched mean update (`kernels::fzoo1`); `zb` strided by BLOCK.
    fzoo_block(
        th: &mut [f32],
        zb: &[f32],
        zs: &[(GaussianStream, f32)],
        n_f: f32,
        lr: f32,
        wd: f32,
    )
);
dispatcher!(
    /// θ[j] += Σᵢ sᵢ·zᵢ[j] in seed order (`kernels::multi_axpy1`).
    multi_axpy_block(th: &mut [f32], zb: &[f32], zs: &[(GaussianStream, f32)])
);
dispatcher!(
    /// Fused momentum block (`kernels::momentum1`).
    momentum_block(
        th: &mut [f32],
        m: &mut [f32],
        zb: &[f32],
        zs: &[(GaussianStream, f32)],
        lr: f32,
        wd: f32,
        momentum: f32,
        n_records: f32,
    )
);
dispatcher!(
    /// Fused bias-corrected Adam block (`kernels::adam1`).
    adam_block(
        th: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        zb: &[f32],
        zs: &[(GaussianStream, f32)],
        p: AdamParams,
        bc1: f32,
        bc2: f32,
    )
);
dispatcher!(
    /// Moment EMA block (`kernels::ema1`).
    ema_block(m: &mut [f32], zb: &[f32], pgrad: f32, beta: f32, adam_style: bool)
);

// ---------------- shared ISA kernel bodies ------------------------------
//
// One macro generates the nine block bodies for every ISA module. The
// invoking module supplies a lane count `LANES` and eight `#[target_
// feature]` wrapper fns (`ld/st/splat/vadd/vsub/vmul/vdiv/vsqrt`) over
// its vector type; the bodies are otherwise IDENTICAL across ISAs, which
// is what makes the bit-exactness argument reviewable in one place:
// per coordinate, each body performs exactly the operation sequence of
// the scalar `*1` helper it names — same order, same associativity, one
// IEEE op per intrinsic, no FMA — and the scalar remainder loop calls
// the `*1` helper itself.

macro_rules! simd_block_kernels {
    ($feat:literal) => {
        use crate::rng::GaussianStream;
        use crate::zkernel::{kernels as sk, AdamParams, BLOCK};

        /// θ[j] += s·zb[j] — lane body of `sk::axpy1`.
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn axpy_block(th: &mut [f32], zb: &[f32], s: f32) {
            debug_assert_eq!(th.len(), zb.len());
            let n = th.len();
            let sv = splat(s);
            let mut j = 0;
            while j + LANES <= n {
                let t = ld(th.as_ptr().add(j));
                let z = ld(zb.as_ptr().add(j));
                st(th.as_mut_ptr().add(j), vadd(t, vmul(sv, z)));
                j += LANES;
            }
            while j < n {
                sk::axpy1(&mut th[j], zb[j], s);
                j += 1;
            }
        }

        /// out[j] = θ[j] + s·zb[j] — lane body of `sk::perturb1`.
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn perturb_block(out: &mut [f32], th: &[f32], zb: &[f32], s: f32) {
            debug_assert_eq!(out.len(), th.len());
            debug_assert_eq!(out.len(), zb.len());
            let n = out.len();
            let sv = splat(s);
            let mut j = 0;
            while j + LANES <= n {
                let t = ld(th.as_ptr().add(j));
                let z = ld(zb.as_ptr().add(j));
                st(out.as_mut_ptr().add(j), vadd(t, vmul(sv, z)));
                j += LANES;
            }
            while j < n {
                sk::perturb1(&mut out[j], th[j], zb[j], s);
                j += 1;
            }
        }

        /// θ[j] −= lr·(g·zb[j] + wd·θ[j]) — lane body of `sk::sgd1`.
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn sgd_block(th: &mut [f32], zb: &[f32], lr: f32, g: f32, wd: f32) {
            debug_assert_eq!(th.len(), zb.len());
            let n = th.len();
            let (lrv, gv, wdv) = (splat(lr), splat(g), splat(wd));
            let mut j = 0;
            while j + LANES <= n {
                let t = ld(th.as_ptr().add(j));
                let z = ld(zb.as_ptr().add(j));
                let upd = vmul(lrv, vadd(vmul(gv, z), vmul(wdv, t)));
                st(th.as_mut_ptr().add(j), vsub(t, upd));
                j += LANES;
            }
            while j < n {
                sk::sgd1(&mut th[j], zb[j], lr, g, wd);
                j += 1;
            }
        }

        /// n-SPSA in seed order per coordinate — lane body of
        /// `sk::multi_sgd1`; θ stays in-register across the seed loop.
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn multi_sgd_block(
            th: &mut [f32],
            zb: &[f32],
            zs: &[(GaussianStream, f32)],
            lr: f32,
            wd: f32,
        ) {
            let n = th.len();
            let (lrv, wdv) = (splat(lr), splat(wd));
            let mut j = 0;
            while j + LANES <= n {
                let mut t = ld(th.as_ptr().add(j));
                for (k, &(_, g)) in zs.iter().enumerate() {
                    let z = ld(zb.as_ptr().add(k * BLOCK + j));
                    t = vsub(t, vmul(lrv, vadd(vmul(splat(g), z), vmul(wdv, t))));
                }
                st(th.as_mut_ptr().add(j), t);
                j += LANES;
            }
            while j < n {
                sk::multi_sgd1(&mut th[j], zs, |kk| zb[kk * BLOCK + j], lr, wd);
                j += 1;
            }
        }

        /// FZOO batched mean update — lane body of `sk::fzoo1`.
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn fzoo_block(
            th: &mut [f32],
            zb: &[f32],
            zs: &[(GaussianStream, f32)],
            n_f: f32,
            lr: f32,
            wd: f32,
        ) {
            let n = th.len();
            let (nv, lrv, wdv) = (splat(n_f), splat(lr), splat(wd));
            let mut j = 0;
            while j + LANES <= n {
                let mut gacc = splat(0.0);
                for (k, &(_, pg)) in zs.iter().enumerate() {
                    let z = ld(zb.as_ptr().add(k * BLOCK + j));
                    gacc = vadd(gacc, vmul(splat(pg), z));
                }
                let t = ld(th.as_ptr().add(j));
                let upd = vmul(lrv, vadd(vdiv(gacc, nv), vmul(wdv, t)));
                st(th.as_mut_ptr().add(j), vsub(t, upd));
                j += LANES;
            }
            while j < n {
                sk::fzoo1(&mut th[j], zs, |kk| zb[kk * BLOCK + j], n_f, lr, wd);
                j += 1;
            }
        }

        /// θ[j] += Σᵢ sᵢ·zᵢ[j] in seed order — lane body of
        /// `sk::multi_axpy1`.
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn multi_axpy_block(
            th: &mut [f32],
            zb: &[f32],
            zs: &[(GaussianStream, f32)],
        ) {
            let n = th.len();
            let mut j = 0;
            while j + LANES <= n {
                let mut t = ld(th.as_ptr().add(j));
                for (k, &(_, s)) in zs.iter().enumerate() {
                    let z = ld(zb.as_ptr().add(k * BLOCK + j));
                    t = vadd(t, vmul(splat(s), z));
                }
                st(th.as_mut_ptr().add(j), t);
                j += LANES;
            }
            while j < n {
                sk::multi_axpy1(&mut th[j], zs, |kk| zb[kk * BLOCK + j]);
                j += 1;
            }
        }

        /// Fused momentum update — lane body of `sk::momentum1`.
        #[allow(clippy::too_many_arguments)]
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn momentum_block(
            th: &mut [f32],
            m: &mut [f32],
            zb: &[f32],
            zs: &[(GaussianStream, f32)],
            lr: f32,
            wd: f32,
            momentum: f32,
            n_records: f32,
        ) {
            let n = th.len();
            let (lrv, wdv, muv, nv) = (splat(lr), splat(wd), splat(momentum), splat(n_records));
            let mut j = 0;
            while j + LANES <= n {
                let mut gacc = splat(0.0);
                for (k, &(_, pg)) in zs.iter().enumerate() {
                    let z = ld(zb.as_ptr().add(k * BLOCK + j));
                    gacc = vadd(gacc, vmul(splat(pg), z));
                }
                let t = ld(th.as_ptr().add(j));
                let mk = ld(m.as_ptr().add(j));
                let g2 = vadd(vdiv(gacc, nv), vmul(wdv, t));
                let mnew = vadd(vmul(muv, mk), g2);
                st(m.as_mut_ptr().add(j), mnew);
                st(th.as_mut_ptr().add(j), vsub(t, vmul(lrv, mnew)));
                j += LANES;
            }
            while j < n {
                let z = |kk: usize| zb[kk * BLOCK + j];
                sk::momentum1(&mut th[j], &mut m[j], zs, z, lr, wd, momentum, n_records);
                j += 1;
            }
        }

        /// Fused bias-corrected Adam update — lane body of `sk::adam1`.
        /// `1 − β` is splat from the identical scalar computation, and
        /// `(1−β₂)·g·g` keeps the scalar's left association.
        #[allow(clippy::too_many_arguments)]
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn adam_block(
            th: &mut [f32],
            m: &mut [f32],
            v: &mut [f32],
            zb: &[f32],
            zs: &[(GaussianStream, f32)],
            p: AdamParams,
            bc1: f32,
            bc2: f32,
        ) {
            let n = th.len();
            let (nv, wdv, lrv, epsv) = (splat(p.n), splat(p.wd), splat(p.lr), splat(p.eps));
            let (b1v, b2v) = (splat(p.beta1), splat(p.beta2));
            let (c1v, c2v) = (splat(1.0 - p.beta1), splat(1.0 - p.beta2));
            let (bc1v, bc2v) = (splat(bc1), splat(bc2));
            let mut j = 0;
            while j + LANES <= n {
                let mut gacc = splat(0.0);
                for (k, &(_, pg)) in zs.iter().enumerate() {
                    let z = ld(zb.as_ptr().add(k * BLOCK + j));
                    gacc = vadd(gacc, vmul(splat(pg), z));
                }
                let t = ld(th.as_ptr().add(j));
                let mk = ld(m.as_ptr().add(j));
                let vk = ld(v.as_ptr().add(j));
                let g2 = vadd(vdiv(gacc, nv), vmul(wdv, t));
                let mnew = vadd(vmul(b1v, mk), vmul(c1v, g2));
                let vnew = vadd(vmul(b2v, vk), vmul(vmul(c2v, g2), g2));
                st(m.as_mut_ptr().add(j), mnew);
                st(v.as_mut_ptr().add(j), vnew);
                let mhat = vdiv(mnew, bc1v);
                let vhat = vdiv(vnew, bc2v);
                let upd = vdiv(vmul(lrv, mhat), vadd(vsqrt(vhat), epsv));
                st(th.as_mut_ptr().add(j), vsub(t, upd));
                j += LANES;
            }
            while j < n {
                let z = |kk: usize| zb[kk * BLOCK + j];
                sk::adam1(&mut th[j], &mut m[j], &mut v[j], zs, z, p, bc1, bc2);
                j += 1;
            }
        }

        /// Moment EMA — lane body of `sk::ema1`. `c·g` with `c = 1−β` is
        /// splat from the same scalar subtraction; the non-Adam branch
        /// adds `g` directly (no multiply), matching the scalar exactly.
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn ema_block(
            m: &mut [f32],
            zb: &[f32],
            pgrad: f32,
            beta: f32,
            adam_style: bool,
        ) {
            debug_assert_eq!(m.len(), zb.len());
            let n = m.len();
            let (pgv, bv) = (splat(pgrad), splat(beta));
            let cv = splat(1.0 - beta);
            let mut j = 0;
            while j + LANES <= n {
                let z = ld(zb.as_ptr().add(j));
                let mk = ld(m.as_ptr().add(j));
                let g = vmul(pgv, z);
                let mnew = if adam_style {
                    vadd(vmul(bv, mk), vmul(cv, g))
                } else {
                    vadd(vmul(bv, mk), g)
                };
                st(m.as_mut_ptr().add(j), mnew);
                j += LANES;
            }
            while j < n {
                sk::ema1(&mut m[j], zb[j], pgrad, beta, adam_style);
                j += 1;
            }
        }
    };
}

/// 8-lane AVX2 tier (`__m256`). The wrapper fns are safe to *call* from
/// same-featured fns (target_feature 1.1); their bodies perform the raw
/// loads/stores, which stay `unsafe` for the pointer arithmetic.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    const LANES: usize = 8;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn ld(p: *const f32) -> __m256 {
        _mm256_loadu_ps(p)
    }
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn st(p: *mut f32, v: __m256) {
        _mm256_storeu_ps(p, v)
    }
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn splat(x: f32) -> __m256 {
        _mm256_set1_ps(x)
    }
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn vadd(a: __m256, b: __m256) -> __m256 {
        _mm256_add_ps(a, b)
    }
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn vsub(a: __m256, b: __m256) -> __m256 {
        _mm256_sub_ps(a, b)
    }
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn vmul(a: __m256, b: __m256) -> __m256 {
        _mm256_mul_ps(a, b)
    }
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn vdiv(a: __m256, b: __m256) -> __m256 {
        _mm256_div_ps(a, b)
    }
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn vsqrt(a: __m256) -> __m256 {
        _mm256_sqrt_ps(a)
    }

    simd_block_kernels!("avx2");
}

/// 16-lane AVX-512 tier (`__m512`); compiled only under rustc ≥ 1.89
/// (`mezo_avx512`, see `build.rs`).
#[cfg(all(target_arch = "x86_64", mezo_avx512))]
mod avx512 {
    use core::arch::x86_64::*;

    const LANES: usize = 16;

    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn ld(p: *const f32) -> __m512 {
        _mm512_loadu_ps(p)
    }
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn st(p: *mut f32, v: __m512) {
        _mm512_storeu_ps(p, v)
    }
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn splat(x: f32) -> __m512 {
        _mm512_set1_ps(x)
    }
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn vadd(a: __m512, b: __m512) -> __m512 {
        _mm512_add_ps(a, b)
    }
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn vsub(a: __m512, b: __m512) -> __m512 {
        _mm512_sub_ps(a, b)
    }
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn vmul(a: __m512, b: __m512) -> __m512 {
        _mm512_mul_ps(a, b)
    }
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn vdiv(a: __m512, b: __m512) -> __m512 {
        _mm512_div_ps(a, b)
    }
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn vsqrt(a: __m512) -> __m512 {
        _mm512_sqrt_ps(a)
    }

    simd_block_kernels!("avx512f");
}

/// 4-lane NEON tier (`float32x4_t`). `vfmaq`/`vmlaq` (fused) are
/// deliberately absent — only the exact one-op intrinsics appear.
#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    const LANES: usize = 4;

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn ld(p: *const f32) -> float32x4_t {
        vld1q_f32(p)
    }
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn st(p: *mut f32, v: float32x4_t) {
        vst1q_f32(p, v)
    }
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn splat(x: f32) -> float32x4_t {
        vdupq_n_f32(x)
    }
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn vadd(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vaddq_f32(a, b)
    }
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn vsub(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vsubq_f32(a, b)
    }
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn vmul(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vmulq_f32(a, b)
    }
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn vdiv(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vdivq_f32(a, b)
    }
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn vsqrt(a: float32x4_t) -> float32x4_t {
        vsqrtq_f32(a)
    }

    simd_block_kernels!("neon");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_available_and_last() {
        let avail = Tier::available();
        assert_eq!(avail.last(), Some(&Tier::Scalar));
        assert!(Tier::Scalar.supported());
        assert!(Tier::detect().supported());
        assert!(Tier::active().supported());
    }

    #[test]
    fn parse_accepts_every_supported_name_and_auto() {
        assert_eq!(parse_mezo_simd("scalar"), Tier::Scalar);
        assert_eq!(parse_mezo_simd("SCALAR"), Tier::Scalar); // case-folded
        assert_eq!(parse_mezo_simd(" auto "), Tier::detect());
        for tier in Tier::available() {
            assert_eq!(parse_mezo_simd(tier.name()), tier);
        }
    }

    #[test]
    #[should_panic(expected = "unknown SIMD tier")]
    fn bogus_mezo_simd_fails_loudly() {
        parse_mezo_simd("avx1024");
    }

    #[test]
    fn known_but_unsupported_tier_fails_loudly() {
        // On every platform at least one hardware tier is foreign (NEON
        // on x86_64, AVX on aarch64) — forcing it must panic, not fall
        // back to scalar.
        let Some(t) =
            [Tier::Avx512, Tier::Avx2, Tier::Neon].into_iter().find(|t| !t.supported())
        else {
            return;
        };
        let err = std::panic::catch_unwind(|| parse_mezo_simd(t.name()));
        assert!(err.is_err(), "forcing unsupported {} should panic", t.name());
    }

    #[test]
    fn names_round_trip() {
        for tier in [Tier::Avx512, Tier::Avx2, Tier::Neon, Tier::Scalar] {
            if tier.supported() {
                assert_eq!(parse_mezo_simd(tier.name()), tier);
            }
            assert_eq!(format!("{}", tier), tier.name());
        }
    }
}
