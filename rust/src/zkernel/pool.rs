//! Persistent worker pool behind every parallel kernel dispatch.
//!
//! The scope-based dispatcher this replaces paid one `std::thread::spawn`
//! plus one join per chunk on EVERY kernel call — four-plus dispatches per
//! MeZO step, tens of microseconds of pure overhead each at small-to-mid
//! tensor sizes. This module keeps a process-wide set of parked workers
//! that every [`ZEngine`](super::ZEngine) dispatch reuses:
//!
//! * **Lazy & growable.** No threads exist until a dispatch actually fans
//!   out (single-chunk dispatches run inline and never touch the pool).
//!   The pool grows to the peak *aggregate* in-flight helper-job count —
//!   summed across concurrent dispatches, so simultaneous engine users
//!   stay as parallel as the per-call spawn path they replaced — and
//!   never shrinks; workers park on a condvar while idle.
//! * **Final chunk on the caller.** A dispatch with `k` chunks enqueues
//!   `k − 1` jobs and runs the last chunk on the calling thread — one
//!   chunk of every dispatch is always handoff-free, and a pool of `N`
//!   workers serves engines with budgets up to `N + 1` threads.
//! * **Scoped borrows without scoped threads.** Jobs borrow the caller's
//!   stack frame (chunk slices, the kernel closure). [`run_jobs`] erases
//!   that lifetime to enqueue and re-establishes it with a completion
//!   latch: it never returns — not even on panic — before every job it
//!   enqueued has finished running.
//! * **Panic-transparent.** A panicking job is caught on the worker (which
//!   keeps the worker alive), recorded in the latch, and re-raised on the
//!   calling thread after all jobs complete — the same observable behavior
//!   as a panicking `std::thread::scope` spawn.
//!
//! Determinism is untouched by construction: the pool only schedules the
//! jobs the engine carved; chunk boundaries and z-counter math are decided
//! before anything is enqueued, and every coordinate's arithmetic depends
//! only on its own global index. The scope path is retained as
//! [`ZEngine::with_threads_scoped`](super::ZEngine::with_threads_scoped)
//! and pinned bit-identical to the pool path in `tests/properties.rs`.
//!
//! **Core pinning.** Each worker pins itself to one core at spawn
//! (worker *i* → core *i+1*, leaving core 0 to the calling thread; see
//! `super::numa`). Workers are persistent and jobs are carved in a fixed
//! order, so worker *i* tends to see the same θ stripes step after step —
//! with first-touch page placement that keeps each stripe's pages on the
//! node of the worker processing them. Best-effort and advisory only
//! (disabled by `MEZO_PIN=0`, a no-op off-Linux); never part of the
//! determinism argument.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// One chunk's worth of kernel work, borrowing the dispatch's stack frame.
pub(super) type Job<'a> = Box<dyn FnOnce() + Send + 'a>;

/// A job as stored in the process-wide queue. The borrow lifetime is
/// erased on submission and re-guaranteed by the completion latch (see
/// the SAFETY comment in [`run_jobs`]).
type QueuedJob = Box<dyn FnOnce() + Send + 'static>;

/// A panic payload carried from a worker back to the dispatching thread.
type PanicPayload = Box<dyn std::any::Any + Send>;

struct Pool {
    queue: Mutex<VecDeque<QueuedJob>>,
    /// Signaled when jobs are enqueued; idle workers park here.
    available: Condvar,
    /// Workers spawned so far (monotonic; tracks peak in-flight demand).
    workers: AtomicUsize,
    /// Helper jobs currently enqueued or running, across ALL concurrent
    /// dispatches. Sizing the pool to this aggregate — not to one
    /// dispatch's chunk count — keeps concurrent engine users as
    /// parallel as the per-call spawn path they replaced.
    inflight: AtomicUsize,
    /// Serializes growth so concurrent dispatches don't over-spawn.
    grow: Mutex<()>,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        queue: Mutex::new(VecDeque::new()),
        available: Condvar::new(),
        workers: AtomicUsize::new(0),
        inflight: AtomicUsize::new(0),
        grow: Mutex::new(()),
    })
}

/// Number of pool workers spawned so far (test hook). Zero until the
/// first multi-chunk dispatch — the pool is lazy.
#[cfg(test)]
pub(super) fn spawned_workers() -> usize {
    POOL.get().map_or(0, |p| p.workers.load(Ordering::Relaxed))
}

impl Pool {
    /// Grow toward `want` parked workers; returns the live worker count,
    /// which may be less than `want` if the OS refuses new threads (a
    /// transient ulimit/cgroup cap). Never panics: a spawn failure must
    /// not poison `grow` and take every future dispatch down with it —
    /// the pool serves with what it has and retries growth next time.
    fn ensure_workers(&'static self, want: usize) -> usize {
        let have = self.workers.load(Ordering::Relaxed);
        if have >= want {
            return have;
        }
        let _g = match self.grow.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let before = self.workers.load(Ordering::Relaxed);
        let mut have = before;
        while have < want {
            let idx = have;
            let spawned = std::thread::Builder::new()
                .name(format!("mezo-zkernel-{}", have))
                .spawn(move || {
                    // caller keeps core 0; workers take 1, 2, … (mod ncpu)
                    super::numa::pin_current_thread(idx + 1);
                    self.worker_loop()
                });
            match spawned {
                Ok(_) => have += 1,
                Err(_) => break, // thread cap hit: serve with what we have
            }
        }
        self.workers.store(have, Ordering::Relaxed);
        if have > before {
            crate::obs::metrics::POOL_GROW_EVENTS.inc();
            crate::obs::metrics::POOL_WORKERS.set(have as f64);
            crate::obs::event::debug(
                "zkernel",
                &format!("zkernel: pool grew {} -> {} workers", before, have),
            );
        }
        have
    }

    /// Park on the condvar until a job arrives; run it; repeat forever.
    /// Jobs arrive pre-wrapped in `catch_unwind`, so a kernel panic can
    /// never kill a worker.
    fn worker_loop(&'static self) {
        loop {
            let job = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if let Some(job) = q.pop_front() {
                        break job;
                    }
                    q = self.available.wait(q).unwrap();
                }
            };
            job();
        }
    }
}

/// Completion latch for one dispatch: counts outstanding jobs down and
/// carries the first worker panic back to the caller.
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<PanicPayload>,
}

impl Latch {
    fn new(jobs: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining: jobs, panic: None }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panic: Option<PanicPayload>) {
        let mut s = self.state.lock().unwrap();
        s.remaining -= 1;
        if s.panic.is_none() {
            s.panic = panic;
        }
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every job has completed; returns the first panic.
    fn wait(&self) -> Option<PanicPayload> {
        let mut s = self.state.lock().unwrap();
        while s.remaining > 0 {
            s = self.done.wait(s).unwrap();
        }
        s.panic.take()
    }
}

/// Run every job to completion: the FINAL job on the calling thread, the
/// rest on pool workers. Blocks until all jobs (including queued ones)
/// have finished; a panic in any job — worker or caller — is re-raised
/// here after completion, exactly like a panicking scoped spawn.
///
/// Zero jobs is a no-op; one job runs inline without touching the pool.
pub(super) fn run_jobs(mut jobs: Vec<Job<'_>>) {
    let Some(last) = jobs.pop() else { return };
    if jobs.is_empty() {
        last();
        return;
    }
    let p = pool();
    crate::obs::metrics::POOL_JOBS_ENQUEUED.add(jobs.len() as u64);
    // Size to the aggregate in-flight helper demand, not just this
    // dispatch's chunk count: with two callers each fanning out 7 helper
    // jobs concurrently, the pool grows to 14 workers, matching the
    // parallelism the per-call spawn path used to provide.
    let want = p.inflight.fetch_add(jobs.len(), Ordering::Relaxed) + jobs.len();
    if p.ensure_workers(want) == 0 {
        // The OS refused even one worker: run every chunk inline. Only
        // scheduling changes — chunk boundaries and z-counters were fixed
        // before dispatch, so the bits are identical.
        p.inflight.fetch_sub(jobs.len(), Ordering::Relaxed);
        for job in jobs {
            job();
        }
        last();
        return;
    }
    let latch = Arc::new(Latch::new(jobs.len()));
    {
        let mut q = p.queue.lock().unwrap();
        for job in jobs {
            // SAFETY: the latch guarantees `run_jobs` does not return —
            // on any path, including panics — until this job has finished
            // executing, so every borrow inside the job (chunk slices of
            // the caller's buffers, the kernel closure) strictly outlives
            // its use. The transmute erases only the lifetime parameter;
            // the trait-object layout is identical.
            let job: QueuedJob = unsafe { std::mem::transmute::<Job<'_>, QueuedJob>(job) };
            let latch = Arc::clone(&latch);
            q.push_back(Box::new(move || {
                let outcome = catch_unwind(AssertUnwindSafe(job));
                p.inflight.fetch_sub(1, Ordering::Relaxed);
                latch.complete(outcome.err());
            }));
        }
        p.available.notify_all();
    }
    // The final chunk always runs here — no handoff for it. Even if it
    // panics, the workers must be waited out first: they may still hold
    // borrows into the caller's frame.
    let mine = catch_unwind(AssertUnwindSafe(last));
    let worker_panic = latch.wait();
    if let Err(payload) = mine {
        resume_unwind(payload);
    }
    if let Some(payload) = worker_panic {
        resume_unwind(payload);
    }
}
