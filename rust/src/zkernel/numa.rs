//! Best-effort NUMA/locality plumbing for the kernel engine.
//!
//! Three levers, all advisory — results NEVER depend on any of them
//! (determinism comes from the counter-based z-stream and fixed block
//! geometry, not from where a thread or page happens to live):
//!
//! 1. **Worker pinning** — every pool worker pins itself to one core
//!    (`sched_setaffinity`, worker *i* → core *i+1*, caller keeps core
//!    0). Stable worker↔core mapping means a worker re-touches the same
//!    θ stripes across steps, keeping its chunks in the same L2/LLC
//!    slice and — with first-touch below — on the same NUMA node.
//! 2. **First-touch striping** — [`super::ZEngine::first_touch`] walks a
//!    fresh θ buffer through the normal chunking path, so under the
//!    first-touch page placement policy each page lands on the node of
//!    the worker that will keep processing it.
//! 3. **Huge pages** — [`advise_hugepages`] hints `MADV_HUGEPAGE` for
//!    multi-MiB θ buffers, cutting dTLB pressure on the d ≥ 1e6 sweeps.
//!
//! Everything here degrades to a no-op: off-Linux, on failed syscalls,
//! or when the user sets `MEZO_PIN=0` (read once, like `MEZO_THREADS` —
//! precedence rules in the `zkernel` module docs). Syscalls are issued
//! raw via inline asm so the crate stays free of a libc dependency.

use std::sync::OnceLock;

/// Bytes per page assumed for first-touch striping and huge-page
/// alignment. 4 KiB is universal on the targets we run on; if the real
/// page size is larger the walk is merely redundant, never wrong.
pub(crate) const PAGE_BYTES: usize = 4096;

/// Whether pinning/paging hints are enabled (`MEZO_PIN` != "0"; read
/// once per process).
pub(crate) fn pinning_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("MEZO_PIN").map_or(true, |v| v.trim() != "0"))
}

/// Pin the calling thread to `cpu` (mod the core count). Best-effort:
/// returns whether the affinity call succeeded; callers must not depend
/// on the answer for correctness.
pub(crate) fn pin_current_thread(cpu: usize) -> bool {
    if !pinning_enabled() {
        return false;
    }
    let ncpu = std::thread::available_parallelism().map_or(1, |n| n.get());
    let cpu = cpu % ncpu;
    // cpu_set_t is 1024 bits on Linux.
    let mut mask = [0u64; 16];
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    sys::set_affinity(&mask)
}

/// Hint the kernel to back `buf` with transparent huge pages. Rounds
/// inward to page boundaries and skips buffers below 2 MiB (one x86
/// huge page) where the hint cannot help.
pub(crate) fn advise_hugepages(buf: &[f32]) {
    if !pinning_enabled() || buf.is_empty() {
        return;
    }
    let start = buf.as_ptr() as usize;
    let end = start + std::mem::size_of_val(buf);
    let lo = start.next_multiple_of(PAGE_BYTES);
    let hi = end - end % PAGE_BYTES;
    if hi <= lo || hi - lo < 2 * 1024 * 1024 {
        return;
    }
    sys::madvise_hugepage(lo, hi - lo);
}

/// Raw syscall shims. `pid`/`addr` arguments follow the kernel ABI:
/// `sched_setaffinity(0, …)` targets the calling thread.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    const MADV_HUGEPAGE: usize = 14;

    #[cfg(target_arch = "x86_64")]
    const NR_SCHED_SETAFFINITY: usize = 203;
    #[cfg(target_arch = "x86_64")]
    const NR_MADVISE: usize = 28;

    #[cfg(target_arch = "aarch64")]
    const NR_SCHED_SETAFFINITY: usize = 122;
    #[cfg(target_arch = "aarch64")]
    const NR_MADVISE: usize = 233;

    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall3(nr: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") nr as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall3(nr: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        core::arch::asm!(
            "svc 0",
            in("x8") nr,
            inlateout("x0") a1 as isize => ret,
            in("x1") a2,
            in("x2") a3,
            options(nostack),
        );
        ret
    }

    pub(super) fn set_affinity(mask: &[u64; 16]) -> bool {
        // SAFETY: pid 0 = calling thread; the mask pointer/length pair
        // describes a live 128-byte buffer for the duration of the call.
        let ret = unsafe {
            syscall3(
                NR_SCHED_SETAFFINITY,
                0,
                std::mem::size_of_val(mask),
                mask.as_ptr() as usize,
            )
        };
        ret == 0
    }

    pub(super) fn madvise_hugepage(addr: usize, len: usize) -> bool {
        // SAFETY: [addr, addr+len) lies page-rounded-inward within a live
        // allocation (checked by the caller); MADV_HUGEPAGE is advisory
        // and never invalidates the mapping.
        let ret = unsafe { syscall3(NR_MADVISE, addr, len, MADV_HUGEPAGE) };
        ret == 0
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod sys {
    pub(super) fn set_affinity(_mask: &[u64; 16]) -> bool {
        false
    }

    pub(super) fn madvise_hugepage(_addr: usize, _len: usize) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_is_best_effort_and_never_panics() {
        // Whatever the platform answers, the call must return cleanly —
        // including for out-of-range indices (wrapped mod core count).
        let _ = pin_current_thread(0);
        let _ = pin_current_thread(usize::MAX);
    }

    #[test]
    fn advise_hugepages_handles_all_sizes() {
        advise_hugepages(&[]);
        advise_hugepages(&[1.0f32; 16]); // below a page: rounds to nothing
        let big = vec![0.0f32; 1 << 20]; // 4 MiB: real madvise span
        advise_hugepages(&big);
        assert_eq!(big.len(), 1 << 20);
    }
}
