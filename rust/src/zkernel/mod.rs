//! zkernel — blocked, multi-threaded kernels for every MeZO parameter pass.
//!
//! Each MeZO step walks the full parameter vector several times (perturb
//! +ε, perturb −2ε, restore, update), and every coordinate needs the same
//! `z(i)` regenerated from the counter-based [`GaussianStream`]. The seed
//! implementation paid a per-element `z()` call inside single-threaded
//! loops copy-pasted across the optimizers, the runtime staging path, the
//! baselines and trajectory replay. This module is the single home for
//! those passes, organised around two ideas:
//!
//! 1. **Blocked generation** — z is produced [`BLOCK`] coordinates at a
//!    time into a stack buffer ([`GaussianStream::fill`] hoists the
//!    ziggurat table lookup out of the per-coordinate path and keeps the
//!    rejection slow path out of line), and the consuming arithmetic runs
//!    over the block as a tight, vectorizable loop.
//! 2. **Deterministic parallelism** — the stream is counter-based (pure in
//!    `(seed, index)`), so a tensor can be chunked by *global offset* and
//!    the chunks processed by any number of threads with bit-identical
//!    results: every coordinate's value and update arithmetic depend only
//!    on its own index. [`ZEngine`] carves buffers into block-aligned
//!    ranges and fans them out over a lazily-initialized, process-wide
//!    **persistent worker pool** (`pool.rs`, internal): parked
//!    workers are reused across dispatches instead of spawning threads
//!    per kernel call, and the final chunk always runs on the calling
//!    thread. Chunk boundaries and z-counter math do not depend on the
//!    dispatcher, so thread count 1 and thread count N — and the pool
//!    path versus the retained per-call `std::thread::scope` path
//!    ([`ZEngine::with_threads_scoped`]) — produce the same bits
//!    (covered by tests here and in `tests/properties.rs`).
//!
//! Within each chunk, the per-block inner loops route through the
//! explicit SIMD dispatch layer (`simd.rs`): each block body runs as a
//! runtime-selected AVX-512 / AVX2 / NEON kernel, falling back to the
//! 8-wide manually unrolled scalar path (`block_apply8!` in
//! `kernels.rs`). In every tier, lanes are independent coordinates and
//! each vector instruction is one correctly-rounded IEEE op, so SIMD
//! never reorders any coordinate's own arithmetic and every tier is
//! pinned `to_bits()`-identical to scalar (see [`Tier`]). On AVX-512
//! machines the z *generation* itself is also vectorized
//! (`GaussianStream::fill_dispatch`).
//!
//! # Environment knobs (all read ONCE per process, at first use)
//!
//! This is the canonical list — each knob is latched in a `OnceLock` on
//! first read, so later `std::env::set_var` calls have no effect:
//!
//! * `MEZO_THREADS` — worker-thread budget for [`ZEngine::default`]
//!   (and the pool size ceiling). Unset/invalid → hardware parallelism.
//!   Read by [`default_threads`].
//! * `MEZO_SIMD` — `auto|avx512|avx2|neon|scalar`; the SIMD tier for
//!   engines built by [`ZEngine::with_threads`] and friends. Unset →
//!   `auto` (best supported tier). A bogus or unsupported value PANICS
//!   rather than silently falling back — a CI leg that asks for a tier
//!   must run that tier. Read by [`Tier::active`]; per-engine override
//!   via [`ZEngine::with_threads_simd`].
//! * `MEZO_PIN` — set to `0` to disable best-effort worker→core pinning
//!   and huge-page/first-touch hints (`numa.rs`). Any other value (or
//!   unset) leaves them on. Never affects results, only locality.
//! * `MEZO_OBS` — observability level for [`crate::obs`]: `0` off, `1`
//!   counters (default), `2` counters + span timing. The one deliberate
//!   exception to the latch rule: [`crate::obs::set_level`] can override
//!   it in-process so the neutrality tests and the `obs_overhead` bench
//!   can compare levels without respawning. Bogus values PANIC. Never
//!   affects results — obs only reads clocks and bumps atomics.
//! * `MEZO_LOG` — stderr threshold for the structured event log
//!   (`error|warn|info|debug`, default `info`). See [`crate::obs::event`].
//! * `MEZO_OBS_JSONL` — append-only JSONL file receiving every
//!   structured event. Unset → no machine-readable sink.
//!
//! Precedence: an explicit constructor argument (`with_threads(n)`,
//! `with_threads_simd(n, tier)`) always beats the environment; the
//! environment beats auto-detection.
//!
//! The fused kernels (see [`ZEngine`]'s methods, bodies in `kernels.rs`):
//!
//! * [`ZEngine::fill_z`] — z into a buffer (bench/reference primitive)
//! * [`ZEngine::axpy_z`] — θ += s·z (perturb / restore, variance-scaled
//!   perturbations, trajectory replay with s = −lr·g)
//! * [`ZEngine::perturb_into`] — out = θ + s·z (runtime literal staging
//!   without touching θ)
//! * [`ZEngine::sgd_update`] — θ −= lr·(g·z + wd·θ) in one pass
//! * [`ZEngine::multi_sgd_update`] — the n-SPSA update Σᵢ over seeds in
//!   ONE pass over θ instead of n (§Perf L4 in optim::mezo)
//! * [`ZEngine::fzoo_update`] — the FZOO batched one-sided update: mean of
//!   n per-seed gradients, one weight-decay term, one pass over θ
//! * [`ZEngine::multi_axpy_z`] — θ += Σᵢ sᵢ·zᵢ in one pass (seed-batched
//!   trajectory replay)
//! * [`ZEngine::momentum_update`] / [`ZEngine::adam_update`] — fused
//!   moment + parameter updates over the step's record batch
//! * [`ZEngine::ema_z`] — moment recomputation from a (seed, pgrad) log
//! * [`ZEngine::project_rows`] — out = base + scale·(Z·v) for the BBT
//!   random-projection baseline
//!
//! The sparse (SensZOQ) tier — see [`mask`] — adds `_masked` variants of
//! the hot kernels ([`ZEngine::axpy_z_masked`],
//! [`ZEngine::perturb_into_masked`], [`ZEngine::sgd_update_masked`],
//! [`ZEngine::multi_sgd_update_masked`], [`ZEngine::fzoo_update_masked`],
//! [`ZEngine::multi_axpy_z_masked`]) that walk only a [`SparseMask`]'s
//! sorted coordinate list while reading z at the SAME global counters as
//! the dense kernels, so a full mask is `to_bits()`-identical to the dense
//! kernel and sparse results never depend on the excluded coordinates.
//! Masked dispatch chunks the *index list* across threads and carves the
//! parameter buffer at chunk-boundary coordinates — deterministic at any
//! thread count for the same reason the dense kernels are.
//!
//! The sharded tier (`crate::shard`) adds `_shard` variants of the same
//! six kernels ([`ZEngine::axpy_z_shard`] and friends) that run the dense
//! kernel over a `[lo, hi)` sub-range of a tensor with the z counter
//! advanced by `lo` — each shard's output is bitwise the slice of the
//! dense kernel's, which is what lets K workers each own one shard of a
//! MeZO pass and still land on the dense bits.
//!
//! The quantized tier ([`quant`]) adds `_quant` variants of the same six
//! kernels for block-quantized θ (int8/int4 codes + per-[`QBLOCK`]
//! scales + an f32 overlay for the masked coordinates — the full
//! SensZOQ layout): blocks are dequantized on the fly, run through the
//! SAME dense serial bodies at the same z counters, and requantized, so
//! overlay coordinates stay bitwise the dense kernel's and everything
//! else lands within half a scale step.
//!
//! Every kernel is bit-for-bit equivalent to the scalar per-coordinate
//! reference (same per-coordinate operation order as the seed code); the
//! tests in this module enforce that across thread counts 1/2/8 and across
//! block-boundary lengths and offsets.

mod kernels;
pub mod mask;
pub(crate) mod numa;
mod pool;
pub mod quant;
mod simd;

pub use mask::{Sensitivity, SparseMask};
pub use quant::{QBits, QuantTensorMut, QuantTensorRef, QBLOCK};
pub use simd::Tier;

use crate::obs::{self, metrics::KernelFamily};
use crate::rng::GaussianStream;
use std::sync::OnceLock;

/// Coordinates generated per ziggurat dispatch; one 1 KiB stack buffer.
pub const BLOCK: usize = 256;

/// Below this many coordinates per thread, spawning is pure overhead.
const PAR_MIN: usize = 16 * 1024;

/// Process default thread count: `MEZO_THREADS` or the hardware's.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("MEZO_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// How a multi-chunk dispatch reaches its worker threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dispatch {
    /// Persistent process-wide worker pool; final chunk on the caller.
    Pool,
    /// Per-call `std::thread::scope` spawns — the pre-pool reference
    /// path, kept for the pool-equivalence tests.
    Scope,
}

/// The kernel engine: a thread budget plus the dispatch scaffolding. Copy,
/// cheap, stateless — optimizers embed one and tests vary `threads` to
/// prove bit-stability.
#[derive(Debug, Clone, Copy)]
pub struct ZEngine {
    /// Maximum worker threads a kernel dispatch may fan out to.
    pub threads: usize,
    /// Dispatch mechanism; never affects results, only wall-clock.
    dispatch: Dispatch,
    /// SIMD tier for the per-block bodies; never affects results, only
    /// wall-clock (every tier is pinned bit-identical to scalar).
    simd: Tier,
}

impl Default for ZEngine {
    fn default() -> ZEngine {
        ZEngine::with_threads(default_threads())
    }
}

impl ZEngine {
    /// Engine with an explicit thread budget (clamped to at least 1),
    /// dispatching over the persistent worker pool.
    ///
    /// Thread count never changes results — only wall-clock. The
    /// determinism tests run every kernel at 1/2/8 threads and assert
    /// `to_bits()` equality.
    ///
    /// ```
    /// use mezo::rng::GaussianStream;
    /// use mezo::zkernel::ZEngine;
    /// let stream = GaussianStream::new(7);
    /// let mut a = vec![0.0f32; 100_000];
    /// let mut b = vec![0.0f32; 100_000];
    /// ZEngine::with_threads(1).axpy_z(stream, 0, &mut a, 0.5);
    /// ZEngine::with_threads(8).axpy_z(stream, 0, &mut b, 0.5);
    /// assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    /// assert_eq!(a[123], 0.5 * stream.z(123));
    /// ```
    pub fn with_threads(threads: usize) -> ZEngine {
        ZEngine { threads: threads.max(1), dispatch: Dispatch::Pool, simd: Tier::active() }
    }

    /// Engine with an explicit thread budget AND an explicit SIMD tier,
    /// overriding `MEZO_SIMD`/auto-detection for this engine only — the
    /// hook the cross-tier bit-identity tests and the `simd_dispatch`
    /// bench group use to run every available tier in one process.
    ///
    /// Panics if `tier` is not runnable on this CPU/build (same loud
    /// failure as a forced `MEZO_SIMD`); [`Tier::available`] lists the
    /// runnable tiers.
    pub fn with_threads_simd(threads: usize, tier: Tier) -> ZEngine {
        assert!(
            tier.supported(),
            "ZEngine::with_threads_simd: tier {} not runnable on this CPU/toolchain \
             (available: {})",
            tier,
            Tier::available().iter().map(|t| t.name()).collect::<Vec<_>>().join("|"),
        );
        ZEngine { threads: threads.max(1), dispatch: Dispatch::Pool, simd: tier }
    }

    /// The engine's SIMD tier (selection is per-engine; the process
    /// default comes from [`Tier::active`]).
    pub fn simd(&self) -> Tier {
        self.simd
    }

    /// Engine that dispatches via per-call `std::thread::scope` spawns
    /// instead of the persistent pool — the historical dispatch path.
    ///
    /// Kept so the equivalence tests (`tests/properties.rs`, the
    /// `pool_vs_spawn` bench group) can pin the pool dispatch against the
    /// pre-pool behavior bit for bit. Kernel arithmetic, chunk carving
    /// and z-counter math are shared with the pool path, so the two
    /// engines are interchangeable everywhere; this one just pays a
    /// thread spawn + join per chunk per kernel call.
    pub fn with_threads_scoped(threads: usize) -> ZEngine {
        ZEngine { threads: threads.max(1), dispatch: Dispatch::Scope, simd: Tier::active() }
    }

    /// Fan a dispatch's chunk jobs out according to the engine's dispatch
    /// mode. Both modes run every job to completion before returning and
    /// produce identical bits — each job is pure in its own chunk; the
    /// dispatcher only decides which OS thread executes it.
    fn execute<'s>(&self, jobs: Vec<pool::Job<'s>>) {
        match self.dispatch {
            Dispatch::Pool => pool::run_jobs(jobs),
            Dispatch::Scope => {
                std::thread::scope(|sc| {
                    for job in jobs {
                        sc.spawn(job);
                    }
                });
            }
        }
    }

    /// Block-aligned contiguous ranges covering [0, len), at most
    /// `self.threads` of them and at least `min_per_thread` coordinates
    /// each (so small tensors stay single-threaded).
    fn ranges(&self, len: usize, min_per_thread: usize) -> Vec<(usize, usize)> {
        let cap = if min_per_thread == 0 {
            self.threads
        } else {
            (len / min_per_thread).max(1).min(self.threads)
        };
        if cap <= 1 || len == 0 {
            return vec![(0, len)];
        }
        let blocks = len.div_ceil(BLOCK);
        let per = blocks.div_ceil(cap) * BLOCK;
        let mut out = Vec::with_capacity(cap);
        let mut start = 0;
        while start < len {
            let end = (start + per).min(len);
            out.push((start, end));
            start = end;
        }
        out
    }

    /// Run `f(start, chunk)` over disjoint chunks of `data` in parallel.
    /// `start` is the chunk's offset within `data`, so kernels index z by
    /// `global_offset + start + j` and stay chunking-invariant.
    fn run<F>(&self, data: &mut [f32], min_per_thread: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let ranges = self.ranges(data.len(), min_per_thread);
        if ranges.len() <= 1 {
            f(0, data);
            return;
        }
        let fr = &f;
        let mut rest = data;
        let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(ranges.len());
        for &(start, end) in &ranges {
            // mem::take keeps the carved chunk at the outer lifetime
            // (a plain reborrow would not outlive the loop body)
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
            rest = tail;
            jobs.push(Box::new(move || fr(start, chunk)));
        }
        self.execute(jobs);
    }

    /// As `run`, but with a read-only source carved in lockstep
    /// (perturb-into-staging shape: src θ, dst literal buffer).
    fn run_src<F>(&self, src: &[f32], dst: &mut [f32], min_per_thread: usize, f: F)
    where
        F: Fn(usize, &[f32], &mut [f32]) + Sync,
    {
        assert_eq!(src.len(), dst.len(), "zkernel: src/dst length mismatch");
        let ranges = self.ranges(dst.len(), min_per_thread);
        if ranges.len() <= 1 {
            f(0, src, dst);
            return;
        }
        let fr = &f;
        let mut rest = dst;
        let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(ranges.len());
        for &(start, end) in &ranges {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
            rest = tail;
            let s = &src[start..end];
            jobs.push(Box::new(move || fr(start, s, chunk)));
        }
        self.execute(jobs);
    }

    /// As `run`, over two mutable buffers carved in lockstep (θ + moment).
    fn run2<F>(&self, a: &mut [f32], b: &mut [f32], min_per_thread: usize, f: F)
    where
        F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
    {
        assert_eq!(a.len(), b.len(), "zkernel: buffer length mismatch");
        let ranges = self.ranges(a.len(), min_per_thread);
        if ranges.len() <= 1 {
            f(0, a, b);
            return;
        }
        let fr = &f;
        let mut rest_a = a;
        let mut rest_b = b;
        let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(ranges.len());
        for &(start, end) in &ranges {
            let (ca, ta) = std::mem::take(&mut rest_a).split_at_mut(end - start);
            let (cb, tb) = std::mem::take(&mut rest_b).split_at_mut(end - start);
            rest_a = ta;
            rest_b = tb;
            jobs.push(Box::new(move || fr(start, ca, cb)));
        }
        self.execute(jobs);
    }

    /// As `run`, over three mutable buffers (θ + first + second moment).
    fn run3<F>(&self, a: &mut [f32], b: &mut [f32], c: &mut [f32], min_per_thread: usize, f: F)
    where
        F: Fn(usize, &mut [f32], &mut [f32], &mut [f32]) + Sync,
    {
        assert_eq!(a.len(), b.len(), "zkernel: buffer length mismatch");
        assert_eq!(a.len(), c.len(), "zkernel: buffer length mismatch");
        let ranges = self.ranges(a.len(), min_per_thread);
        if ranges.len() <= 1 {
            f(0, a, b, c);
            return;
        }
        let fr = &f;
        let mut rest_a = a;
        let mut rest_b = b;
        let mut rest_c = c;
        let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(ranges.len());
        for &(start, end) in &ranges {
            let (ca, ta) = std::mem::take(&mut rest_a).split_at_mut(end - start);
            let (cb, tb) = std::mem::take(&mut rest_b).split_at_mut(end - start);
            let (cc, tc) = std::mem::take(&mut rest_c).split_at_mut(end - start);
            rest_a = ta;
            rest_b = tb;
            rest_c = tc;
            jobs.push(Box::new(move || fr(start, ca, cb, cc)));
        }
        self.execute(jobs);
    }

    /// As `run`, but over a masked index list: the *list* is chunked (not
    /// the buffer), and `theta` is carved at each chunk's first indexed
    /// coordinate — sortedness makes the carve points disjoint.
    /// `f(idxs, base, chunk)` gets tensor-absolute indices and the chunk's
    /// base coordinate, so bodies address `chunk[idx - base]` and z by
    /// `offset + idx`, staying chunking-invariant like the dense kernels.
    fn run_masked<F>(&self, idxs: &[u32], theta: &mut [f32], min_per_thread: usize, f: F)
    where
        F: Fn(&[u32], usize, &mut [f32]) + Sync,
    {
        if idxs.is_empty() {
            return;
        }
        let bounds = mask_bounds(idxs.len(), self.threads, min_per_thread);
        if bounds.len() <= 1 {
            f(idxs, 0, theta);
            return;
        }
        let fr = &f;
        let mut rest = theta;
        let mut consumed = 0usize;
        let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(bounds.len());
        for (r, &(a, b)) in bounds.iter().enumerate() {
            let end_coord = if r + 1 == bounds.len() {
                consumed + rest.len()
            } else {
                idxs[b] as usize
            };
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end_coord - consumed);
            rest = tail;
            let ci = &idxs[a..b];
            let base = consumed;
            consumed = end_coord;
            jobs.push(Box::new(move || fr(ci, base, chunk)));
        }
        self.execute(jobs);
    }

    /// As `run_masked`, with a read-only source carved in lockstep
    /// (masked staging shape: src θ, dst literal buffer).
    fn run_src_masked<F>(
        &self,
        idxs: &[u32],
        src: &[f32],
        dst: &mut [f32],
        min_per_thread: usize,
        f: F,
    ) where
        F: Fn(&[u32], usize, &[f32], &mut [f32]) + Sync,
    {
        assert_eq!(src.len(), dst.len(), "zkernel: src/dst length mismatch");
        if idxs.is_empty() {
            return;
        }
        let bounds = mask_bounds(idxs.len(), self.threads, min_per_thread);
        if bounds.len() <= 1 {
            f(idxs, 0, src, dst);
            return;
        }
        let fr = &f;
        let mut rest = dst;
        let mut consumed = 0usize;
        let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(bounds.len());
        for (r, &(a, b)) in bounds.iter().enumerate() {
            let end_coord = if r + 1 == bounds.len() {
                consumed + rest.len()
            } else {
                idxs[b] as usize
            };
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end_coord - consumed);
            rest = tail;
            let s = &src[consumed..end_coord];
            let ci = &idxs[a..b];
            let base = consumed;
            consumed = end_coord;
            jobs.push(Box::new(move || fr(ci, base, s, chunk)));
        }
        self.execute(jobs);
    }

    // ---------------- public kernels (serial bodies in kernels.rs) -------

    /// out[j] = z(offset + j).
    pub fn fill_z(&self, stream: GaussianStream, offset: u64, out: &mut [f32]) {
        let _obs = obs::kernel_dispatch(KernelFamily::Fill);
        let sf = self.simd.simd_fill();
        self.run(out, PAR_MIN, |start, chunk| {
            stream.fill_dispatch(chunk, offset + start as u64, sf);
        });
    }

    /// Touch every page of a freshly allocated buffer through the normal
    /// chunking path, so under Linux's first-touch placement each page
    /// lands on the NUMA node of the pool worker that will keep
    /// processing that chunk (workers are core-pinned — `pool.rs`).
    /// Values are read and written back volatilely, never changed; purely
    /// a locality hint (no-op when `MEZO_PIN=0` disables pinning).
    pub fn first_touch(&self, buf: &mut [f32]) {
        if !numa::pinning_enabled() {
            return;
        }
        const PAGE_F32: usize = numa::PAGE_BYTES / std::mem::size_of::<f32>();
        self.run(buf, PAR_MIN, |_start, chunk| {
            let mut j = 0;
            while j < chunk.len() {
                let p = &mut chunk[j] as *mut f32;
                // SAFETY: p points into the live chunk; volatile keeps
                // the dead read+write from being elided.
                unsafe { std::ptr::write_volatile(p, std::ptr::read_volatile(p)) };
                j += PAGE_F32;
            }
        });
    }

    /// θ[j] += s · z(offset + j) — perturb, restore, replay.
    ///
    /// `offset` is the tensor's *global* flat offset, so every pass over a
    /// tensor regenerates identical z coordinates no matter how the work
    /// is chunked:
    ///
    /// ```
    /// use mezo::rng::GaussianStream;
    /// use mezo::zkernel::ZEngine;
    /// let eng = ZEngine::default();
    /// let stream = GaussianStream::new(42);
    /// let mut theta = vec![1.0f32; 512];
    /// eng.axpy_z(stream, 100, &mut theta, 1e-3); // perturb
    /// eng.axpy_z(stream, 100, &mut theta, -1e-3); // restore: same z
    /// assert!(theta.iter().all(|&x| (x - 1.0).abs() < 1e-6));
    /// ```
    pub fn axpy_z(&self, stream: GaussianStream, offset: u64, theta: &mut [f32], s: f32) {
        let _obs = obs::kernel_dispatch(KernelFamily::Axpy);
        let tier = self.simd;
        self.run(theta, PAR_MIN, |start, chunk| {
            kernels::axpy_serial(tier, stream, offset + start as u64, chunk, s);
        });
    }

    /// out[j] = θ[j] + s · z(offset + j) — staging write for
    /// `Artifact::run_perturbed`, θ untouched.
    pub fn perturb_into(
        &self,
        stream: GaussianStream,
        offset: u64,
        theta: &[f32],
        s: f32,
        out: &mut [f32],
    ) {
        let _obs = obs::kernel_dispatch(KernelFamily::PerturbInto);
        let tier = self.simd;
        self.run_src(theta, out, PAR_MIN, |start, src, chunk| {
            kernels::perturb_into_serial(tier, stream, offset + start as u64, src, s, chunk);
        });
    }

    /// θ[j] −= lr · (g · z(offset + j) + wd · θ[j]) — the MeZO-SGD update.
    pub fn sgd_update(
        &self,
        stream: GaussianStream,
        offset: u64,
        theta: &mut [f32],
        lr: f32,
        g: f32,
        wd: f32,
    ) {
        let _obs = obs::kernel_dispatch(KernelFamily::Sgd);
        let tier = self.simd;
        self.run(theta, PAR_MIN, |start, chunk| {
            kernels::sgd_serial(tier, stream, offset + start as u64, chunk, lr, g, wd);
        });
    }

    /// n-SPSA: apply every `(stream, g)` update in ONE pass over θ.
    /// Per coordinate the updates are applied in slice order, exactly as a
    /// sequence of `sgd_update` calls would — but θ is traversed once.
    pub fn multi_sgd_update(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        theta: &mut [f32],
        lr: f32,
        wd: f32,
    ) {
        if zs.is_empty() {
            return;
        }
        let _obs = obs::kernel_dispatch(KernelFamily::MultiSgd);
        let tier = self.simd;
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run(theta, min, |start, chunk| {
            kernels::multi_sgd_serial(tier, zs, offset + start as u64, chunk, lr, wd);
        });
    }

    /// FZOO batched one-sided update (optim::fzoo): per coordinate,
    /// g = (Σᵢ gᵢ·zᵢ)/n;  θ −= lr·(g + wd·θ) — the whole n-seed batch in
    /// ONE pass over θ with a single weight-decay term. `zs` carries the
    /// *raw* per-seed projected gradients; the mean over `zs.len()` is
    /// taken inside the kernel. With `zs.len() == 1` this computes exactly
    /// [`ZEngine::sgd_update`].
    pub fn fzoo_update(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        theta: &mut [f32],
        lr: f32,
        wd: f32,
    ) {
        if zs.is_empty() {
            return;
        }
        let _obs = obs::kernel_dispatch(KernelFamily::Fzoo);
        let tier = self.simd;
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run(theta, min, |start, chunk| {
            kernels::fzoo_serial(tier, zs, offset + start as u64, chunk, lr, wd);
        });
    }

    /// Batched multi-seed axpy: θ[j] += Σᵢ sᵢ·zᵢ(offset + j) in ONE pass
    /// over θ. Per coordinate the seeds apply in slice order, exactly as a
    /// sequence of [`ZEngine::axpy_z`] calls would — the replay primitive
    /// for seed-batched (FZOO) trajectories.
    pub fn multi_axpy_z(&self, zs: &[(GaussianStream, f32)], offset: u64, theta: &mut [f32]) {
        if zs.is_empty() {
            return;
        }
        let _obs = obs::kernel_dispatch(KernelFamily::MultiAxpy);
        let tier = self.simd;
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run(theta, min, |start, chunk| {
            kernels::multi_axpy_serial(tier, zs, offset + start as u64, chunk);
        });
    }

    /// Fused MeZO-momentum update over one step's record batch:
    /// g = (Σᵢ gᵢ·zᵢ)/n + wd·θ;  m = μ·m + g;  θ −= lr·m.
    #[allow(clippy::too_many_arguments)]
    pub fn momentum_update(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        theta: &mut [f32],
        m: &mut [f32],
        lr: f32,
        wd: f32,
        momentum: f32,
        n: f32,
    ) {
        if zs.is_empty() {
            return;
        }
        let _obs = obs::kernel_dispatch(KernelFamily::Momentum);
        let tier = self.simd;
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run2(theta, m, min, |start, th, mk| {
            kernels::momentum_serial(
                tier,
                zs,
                offset + start as u64,
                th,
                mk,
                lr,
                wd,
                momentum,
                n,
            );
        });
    }

    /// Fused MeZO-Adam update over one step's record batch.
    pub fn adam_update(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        theta: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        p: AdamParams,
    ) {
        if zs.is_empty() {
            return;
        }
        let _obs = obs::kernel_dispatch(KernelFamily::Adam);
        let tier = self.simd;
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run3(theta, m, v, min, |start, th, mk, vk| {
            kernels::adam_serial(tier, zs, offset + start as u64, th, mk, vk, p);
        });
    }

    /// One EMA step of a moment buffer from a single (seed, pgrad) record:
    /// m = β·m + (1−β)·(g·z) (Adam-style) or m = β·m + g·z (momentum).
    /// Records must still be applied in history order — the EMA across
    /// records is sequential; only the coordinate axis parallelizes.
    pub fn ema_z(
        &self,
        stream: GaussianStream,
        offset: u64,
        m: &mut [f32],
        pgrad: f32,
        beta: f32,
        adam_style: bool,
    ) {
        let _obs = obs::kernel_dispatch(KernelFamily::Ema);
        let tier = self.simd;
        self.run(m, PAR_MIN, |start, chunk| {
            kernels::ema_serial(
                tier,
                stream,
                offset + start as u64,
                chunk,
                pgrad,
                beta,
                adam_style,
            );
        });
    }

    /// Random-projection rows (BBT baseline):
    /// out[j] = base[j] + scale · Σᵢ z(j·d_low + i)·v[i].
    pub fn project_rows(
        &self,
        stream: GaussianStream,
        d_low: usize,
        v: &[f32],
        base: &[f32],
        scale: f32,
        out: &mut [f32],
    ) {
        assert_eq!(v.len(), d_low, "zkernel: projection input length != d_low");
        let _obs = obs::kernel_dispatch(KernelFamily::Project);
        let tier = self.simd;
        let min = (PAR_MIN / d_low.max(1)).max(1);
        self.run_src(base, out, min, |start, b, chunk| {
            kernels::project_rows_serial(tier, stream, d_low, v, b, scale, chunk, start);
        });
    }

    // ---------------- masked (SensZOQ) kernels ---------------------------
    //
    // Each takes the tensor's sorted coordinate list (a
    // `SparseMask::indices(ti)` slice) and touches ONLY those
    // coordinates, reading z at the same global counter the dense kernel
    // would (`offset + idx`). An empty list is a no-op; a full list is
    // `to_bits()`-identical to the dense kernel (pinned in
    // tests/properties.rs). Indices must be strictly increasing and in
    // range — [`SparseMask`] construction guarantees both.

    /// Masked [`ZEngine::axpy_z`]: θ[idx] += s · z(offset + idx) over the
    /// masked coordinates only — sparse perturb / restore / replay.
    pub fn axpy_z_masked(
        &self,
        stream: GaussianStream,
        offset: u64,
        idxs: &[u32],
        theta: &mut [f32],
        s: f32,
    ) {
        let _obs = obs::kernel_dispatch(KernelFamily::Axpy);
        check_mask(idxs, theta.len());
        self.run_masked(idxs, theta, PAR_MIN, |ci, base, chunk| {
            kernels::masked_axpy_serial(stream, offset, ci, base, chunk, s);
        });
    }

    /// Masked [`ZEngine::perturb_into`]: out[idx] = θ[idx] + s · z(offset
    /// + idx) over the masked coordinates; unmasked coordinates of `out`
    /// are NOT written (callers keep them mirroring θ, which sparse
    /// updates never change).
    pub fn perturb_into_masked(
        &self,
        stream: GaussianStream,
        offset: u64,
        idxs: &[u32],
        theta: &[f32],
        s: f32,
        out: &mut [f32],
    ) {
        let _obs = obs::kernel_dispatch(KernelFamily::PerturbInto);
        check_mask(idxs, theta.len());
        self.run_src_masked(idxs, theta, out, PAR_MIN, |ci, base, src, chunk| {
            kernels::masked_perturb_into_serial(stream, offset, ci, base, src, s, chunk);
        });
    }

    /// Masked [`ZEngine::sgd_update`]: θ[idx] −= lr · (g · z(offset + idx)
    /// + wd · θ[idx]) over the masked coordinates only.
    #[allow(clippy::too_many_arguments)]
    pub fn sgd_update_masked(
        &self,
        stream: GaussianStream,
        offset: u64,
        idxs: &[u32],
        theta: &mut [f32],
        lr: f32,
        g: f32,
        wd: f32,
    ) {
        let _obs = obs::kernel_dispatch(KernelFamily::Sgd);
        check_mask(idxs, theta.len());
        self.run_masked(idxs, theta, PAR_MIN, |ci, base, chunk| {
            kernels::masked_sgd_serial(stream, offset, ci, base, chunk, lr, g, wd);
        });
    }

    /// Masked [`ZEngine::multi_sgd_update`]: every `(stream, g)` update
    /// applied per masked coordinate in slice order, one pass.
    pub fn multi_sgd_update_masked(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        idxs: &[u32],
        theta: &mut [f32],
        lr: f32,
        wd: f32,
    ) {
        if zs.is_empty() {
            return;
        }
        let _obs = obs::kernel_dispatch(KernelFamily::MultiSgd);
        check_mask(idxs, theta.len());
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run_masked(idxs, theta, min, |ci, base, chunk| {
            kernels::masked_multi_sgd_serial(zs, offset, ci, base, chunk, lr, wd);
        });
    }

    /// Masked [`ZEngine::fzoo_update`]: the FZOO batched one-sided mean
    /// update restricted to the masked coordinates.
    pub fn fzoo_update_masked(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        idxs: &[u32],
        theta: &mut [f32],
        lr: f32,
        wd: f32,
    ) {
        if zs.is_empty() {
            return;
        }
        let _obs = obs::kernel_dispatch(KernelFamily::Fzoo);
        check_mask(idxs, theta.len());
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run_masked(idxs, theta, min, |ci, base, chunk| {
            kernels::masked_fzoo_serial(zs, offset, ci, base, chunk, lr, wd);
        });
    }

    /// Masked [`ZEngine::multi_axpy_z`]: θ[idx] += Σᵢ sᵢ·zᵢ(offset + idx)
    /// over the masked coordinates — the sparse seed-batched replay
    /// primitive.
    pub fn multi_axpy_z_masked(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        idxs: &[u32],
        theta: &mut [f32],
    ) {
        if zs.is_empty() {
            return;
        }
        let _obs = obs::kernel_dispatch(KernelFamily::MultiAxpy);
        check_mask(idxs, theta.len());
        let min = (PAR_MIN / zs.len()).max(BLOCK);
        self.run_masked(idxs, theta, min, |ci, base, chunk| {
            kernels::masked_multi_axpy_serial(zs, offset, ci, base, chunk);
        });
    }

    // ---------------- shard (range-scoped) kernels -----------------------
    //
    // Each takes a tensor-local coordinate range [lo, hi) — one shard
    // segment of the tensor (see `crate::shard::ShardPlan`) — and runs
    // the dense kernel over exactly that sub-slice while reading z at the
    // tensor's global counters (`offset + j` for tensor coordinate j).
    // Every dense kernel is pure per coordinate in its own global index,
    // so the range kernel's output is bitwise the [lo, hi) slice of the
    // dense kernel's — the same argument that makes thread-chunking
    // invariant, promoted to an API: a shard worker can run its slice of
    // a pass independently and land on exactly the dense bits (pinned in
    // zkernel/tests.rs and tests/properties.rs). `offset` is the TENSOR's
    // global flat offset, as for the dense kernels; the range advance
    // happens inside.

    /// Shard-scoped [`ZEngine::axpy_z`]: θ[j] += s · z(offset + j) for
    /// j ∈ [lo, hi) only — the shard-local perturb / restore / replay
    /// primitive.
    pub fn axpy_z_shard(
        &self,
        stream: GaussianStream,
        offset: u64,
        lo: usize,
        hi: usize,
        theta: &mut [f32],
        s: f32,
    ) {
        check_shard_range(lo, hi, theta.len());
        self.axpy_z(stream, offset + lo as u64, &mut theta[lo..hi], s);
    }

    /// Shard-scoped [`ZEngine::perturb_into`]: out[j] = θ[j] + s ·
    /// z(offset + j) for j ∈ [lo, hi); coordinates outside the range are
    /// NOT written.
    #[allow(clippy::too_many_arguments)]
    pub fn perturb_into_shard(
        &self,
        stream: GaussianStream,
        offset: u64,
        lo: usize,
        hi: usize,
        theta: &[f32],
        s: f32,
        out: &mut [f32],
    ) {
        check_shard_range(lo, hi, theta.len());
        check_shard_range(lo, hi, out.len());
        self.perturb_into(stream, offset + lo as u64, &theta[lo..hi], s, &mut out[lo..hi]);
    }

    /// Shard-scoped [`ZEngine::sgd_update`]: the MeZO-SGD update over
    /// j ∈ [lo, hi) only.
    #[allow(clippy::too_many_arguments)]
    pub fn sgd_update_shard(
        &self,
        stream: GaussianStream,
        offset: u64,
        lo: usize,
        hi: usize,
        theta: &mut [f32],
        lr: f32,
        g: f32,
        wd: f32,
    ) {
        check_shard_range(lo, hi, theta.len());
        self.sgd_update(stream, offset + lo as u64, &mut theta[lo..hi], lr, g, wd);
    }

    /// Shard-scoped [`ZEngine::multi_sgd_update`]: all n-SPSA updates in
    /// one pass over j ∈ [lo, hi) only.
    #[allow(clippy::too_many_arguments)]
    pub fn multi_sgd_update_shard(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        lo: usize,
        hi: usize,
        theta: &mut [f32],
        lr: f32,
        wd: f32,
    ) {
        check_shard_range(lo, hi, theta.len());
        self.multi_sgd_update(zs, offset + lo as u64, &mut theta[lo..hi], lr, wd);
    }

    /// Shard-scoped [`ZEngine::fzoo_update`]: the FZOO batched one-sided
    /// mean update over j ∈ [lo, hi) only.
    #[allow(clippy::too_many_arguments)]
    pub fn fzoo_update_shard(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        lo: usize,
        hi: usize,
        theta: &mut [f32],
        lr: f32,
        wd: f32,
    ) {
        check_shard_range(lo, hi, theta.len());
        self.fzoo_update(zs, offset + lo as u64, &mut theta[lo..hi], lr, wd);
    }

    /// Shard-scoped [`ZEngine::multi_axpy_z`]: θ[j] += Σᵢ sᵢ·zᵢ(offset +
    /// j) for j ∈ [lo, hi) — the shard-local seed-batched replay
    /// primitive.
    pub fn multi_axpy_z_shard(
        &self,
        zs: &[(GaussianStream, f32)],
        offset: u64,
        lo: usize,
        hi: usize,
        theta: &mut [f32],
    ) {
        check_shard_range(lo, hi, theta.len());
        self.multi_axpy_z(zs, offset + lo as u64, &mut theta[lo..hi]);
    }
}

/// Chunk a masked index list into at most `threads` contiguous ranges of
/// at least `min_per_thread` indices each. No block alignment: each
/// masked coordinate's arithmetic is pure in its own global index, and
/// the hybrid z path produces identical bits whichever side of a chunk
/// boundary a block's run lands on.
fn mask_bounds(n: usize, threads: usize, min_per_thread: usize) -> Vec<(usize, usize)> {
    let cap = if min_per_thread == 0 {
        threads
    } else {
        (n / min_per_thread).max(1).min(threads)
    };
    if cap <= 1 {
        return vec![(0, n)];
    }
    let per = n.div_ceil(cap);
    let mut out = Vec::with_capacity(cap);
    let mut a = 0;
    while a < n {
        let b = (a + per).min(n);
        out.push((a, b));
        a = b;
    }
    out
}

/// Shard kernels address a [lo, hi) sub-range of a tensor; a malformed
/// range would silently read z at the wrong counters, so fail fast.
#[inline]
fn check_shard_range(lo: usize, hi: usize, len: usize) {
    assert!(
        lo <= hi && hi <= len,
        "zkernel: shard range {}..{} invalid for tensor of length {}",
        lo,
        hi,
        len
    );
}

/// Masked kernels index θ directly; an out-of-range index would corrupt
/// the carve arithmetic, so fail fast with a named error instead.
#[inline]
fn check_mask(idxs: &[u32], len: usize) {
    debug_assert!(idxs.windows(2).all(|w| w[0] < w[1]), "zkernel: mask indices not sorted/unique");
    if let Some(&last) = idxs.last() {
        assert!(
            (last as usize) < len,
            "zkernel: mask index {} out of range for tensor of length {}",
            last,
            len
        );
    }
}

/// Scalar knobs of the fused Adam kernel (one step's worth).
#[derive(Debug, Clone, Copy)]
pub struct AdamParams {
    /// learning rate
    pub lr: f32,
    /// weight decay
    pub wd: f32,
    /// first-moment EMA coefficient
    pub beta1: f32,
    /// second-moment EMA coefficient
    pub beta2: f32,
    /// denominator stabilizer
    pub eps: f32,
    /// 1-based step count for bias correction
    pub t: f32,
    /// record-batch size (the n in g/n)
    pub n: f32,
}

#[cfg(test)]
mod tests;
