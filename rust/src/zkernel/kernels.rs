//! Serial (per-chunk) bodies of the fused z-kernels.
//!
//! Every function here processes one contiguous chunk whose first
//! coordinate has global z-index `offset`. The pattern is uniform: fill a
//! [`BLOCK`]-sized stack buffer from the counter-based stream (one
//! ziggurat-table resolve per block instead of per coordinate), then run
//! the fused arithmetic over the block through the shared unrolled lane
//! layer:
//!
//! * `block_apply8!` walks a block's coordinates 8
//!   lanes at a time with an explicit manual unroll (`f32x8`-style, no
//!   nightly features, remainder handled scalar), keeping 8 independent
//!   accumulation chains in flight for the compiler to vectorize;
//! * the `*1` op helpers (`axpy1`, `sgd1`, `fzoo1`, …) are the
//!   per-coordinate arithmetic written ONCE and shared between the dense
//!   kernels, the masked fill path, the masked per-coordinate path AND
//!   the explicit-SIMD remainder loops in `super::simd` — a lane body
//!   can never drift between variants;
//! * the `*_block` fns wrap one `block_apply8!` invocation each: they
//!   are the always-available scalar tier behind the runtime-dispatched
//!   SIMD layer (`super::simd`), which routes each dense block either to
//!   an explicit AVX2/AVX-512/NEON body or back here. The dense serial
//!   kernels below therefore take the engine's [`Tier`] and call the
//!   dispatchers; the masked kernels keep calling `block_apply8!`
//!   directly (their hot loop is index-gather-bound, not lane-bound).
//!
//! BIT-EXACTNESS CONTRACT: each kernel performs, per coordinate, exactly
//! the floating-point operations (same order, same associativity) as the
//! scalar seed loops it replaced. Lanes are whole, independent
//! coordinates — multi-seed accumulation happens *within* a lane, in
//! slice order — so the 8-wide unroll reorders nothing and blocked,
//! threaded, pooled and unrolled execution all remain interchangeable
//! with the historical code and with each other at any thread count —
//! see `zkernel::tests` and `tests/properties.rs`.

use super::{simd, AdamParams, Tier, BLOCK};
use crate::rng::GaussianStream;

/// Apply a per-coordinate lane body for `j in 0..$n`, manually unrolled 8
/// lanes at a time with a scalar remainder loop. Each lane is one whole
/// coordinate, so the unroll preserves every coordinate's operation order
/// bit for bit; it exists purely to keep 8 independent dependency chains
/// in flight (the `f32x8` shape) without nightly SIMD features.
macro_rules! block_apply8 {
    ($n:expr, |$j:ident| $body:expr) => {{
        let n__: usize = $n;
        let mut base__ = 0usize;
        while base__ + 8 <= n__ {
            {
                let $j = base__;
                $body;
            }
            {
                let $j = base__ + 1;
                $body;
            }
            {
                let $j = base__ + 2;
                $body;
            }
            {
                let $j = base__ + 3;
                $body;
            }
            {
                let $j = base__ + 4;
                $body;
            }
            {
                let $j = base__ + 5;
                $body;
            }
            {
                let $j = base__ + 6;
                $body;
            }
            {
                let $j = base__ + 7;
                $body;
            }
            base__ += 8;
        }
        while base__ < n__ {
            {
                let $j = base__;
                $body;
            }
            base__ += 1;
        }
    }};
}

// ---------------- per-coordinate op bodies (written once) ---------------
//
// Multi-seed ops read z through a `z(k)` closure so the same body serves
// the dense path (blocked buffer at `zb[k*BLOCK + j]`), the masked fill
// path (blocked buffer at the block-relative slot) and the masked
// per-coordinate path (`stream.z(offset + idx)`). Everything is
// `#[inline(always)]`: after inlining, each call site compiles to the
// exact loop body the pre-unroll kernels had.

/// θ += s·z
#[inline(always)]
pub(super) fn axpy1(th: &mut f32, z: f32, s: f32) {
    *th += s * z;
}

/// out = θ + s·z
#[inline(always)]
pub(super) fn perturb1(out: &mut f32, th: f32, z: f32, s: f32) {
    *out = th + s * z;
}

/// θ −= lr·(g·z + wd·θ)
#[inline(always)]
pub(super) fn sgd1(th: &mut f32, z: f32, lr: f32, g: f32, wd: f32) {
    *th -= lr * (g * z + wd * *th);
}

/// n-SPSA: every `(stream, g)` update applied in slice order.
#[inline(always)]
pub(super) fn multi_sgd1(
    th: &mut f32,
    zs: &[(GaussianStream, f32)],
    z: impl Fn(usize) -> f32,
    lr: f32,
    wd: f32,
) {
    for (k, &(_, g)) in zs.iter().enumerate() {
        *th -= lr * (g * z(k) + wd * *th);
    }
}

/// FZOO: g = (Σᵢ gᵢ·zᵢ)/n, then one fused subtraction with one wd term.
#[inline(always)]
pub(super) fn fzoo1(
    th: &mut f32,
    zs: &[(GaussianStream, f32)],
    z: impl Fn(usize) -> f32,
    n_f: f32,
    lr: f32,
    wd: f32,
) {
    let mut g = 0.0f32;
    for (k, &(_, pg)) in zs.iter().enumerate() {
        g += pg * z(k);
    }
    *th -= lr * (g / n_f + wd * *th);
}

/// Batched replay: θ += Σᵢ sᵢ·zᵢ, seeds in slice order.
#[inline(always)]
pub(super) fn multi_axpy1(th: &mut f32, zs: &[(GaussianStream, f32)], z: impl Fn(usize) -> f32) {
    for (k, &(_, s)) in zs.iter().enumerate() {
        *th += s * z(k);
    }
}

/// Momentum: g = (Σᵢ gᵢ·zᵢ)/n + wd·θ; m = μ·m + g; θ −= lr·m.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
pub(super) fn momentum1(
    th: &mut f32,
    mk: &mut f32,
    zs: &[(GaussianStream, f32)],
    z: impl Fn(usize) -> f32,
    lr: f32,
    wd: f32,
    momentum: f32,
    n_records: f32,
) {
    let mut g = 0.0f32;
    for (k, &(_, pg)) in zs.iter().enumerate() {
        g += pg * z(k);
    }
    g = g / n_records + wd * *th;
    *mk = momentum * *mk + g;
    *th -= lr * *mk;
}

/// Adam: bias-corrected moment EMAs + fused parameter update.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
pub(super) fn adam1(
    th: &mut f32,
    mk: &mut f32,
    vk: &mut f32,
    zs: &[(GaussianStream, f32)],
    z: impl Fn(usize) -> f32,
    p: AdamParams,
    bc1: f32,
    bc2: f32,
) {
    let mut g = 0.0f32;
    for (k, &(_, pg)) in zs.iter().enumerate() {
        g += pg * z(k);
    }
    g = g / p.n + p.wd * *th;
    *mk = p.beta1 * *mk + (1.0 - p.beta1) * g;
    *vk = p.beta2 * *vk + (1.0 - p.beta2) * g * g;
    let mhat = *mk / bc1;
    let vhat = *vk / bc2;
    *th -= p.lr * mhat / (vhat.sqrt() + p.eps);
}

/// m = β·m + (1−β)·(pgrad·z) (Adam-style) or m = β·m + pgrad·z.
#[inline(always)]
pub(super) fn ema1(mk: &mut f32, z: f32, pgrad: f32, beta: f32, adam_style: bool) {
    let g = pgrad * z;
    *mk = if adam_style { beta * *mk + (1.0 - beta) * g } else { beta * *mk + g };
}

// ---------------- scalar block bodies (the Scalar SIMD tier) ------------
//
// One `block_apply8!` invocation each, extracted from the former serial
// loop bodies so `super::simd`'s dispatchers can target them by name:
// `simd::axpy_block(tier, …)` lands here when `tier == Tier::Scalar` (or
// on any arch without the requested ISA compiled in). These are the
// reference bits every SIMD tier is pinned against. Multi-seed variants
// read seed k's z-block at `zb[k*BLOCK + j]` (stride fixed at BLOCK).

/// θ[j] += s·zb[j] for `j in 0..th.len()`.
pub(super) fn axpy_block(th: &mut [f32], zb: &[f32], s: f32) {
    block_apply8!(th.len(), |j| axpy1(&mut th[j], zb[j], s));
}

/// out[j] = θ[j] + s·zb[j].
pub(super) fn perturb_block(out: &mut [f32], th: &[f32], zb: &[f32], s: f32) {
    block_apply8!(out.len(), |j| perturb1(&mut out[j], th[j], zb[j], s));
}

/// θ[j] −= lr·(g·zb[j] + wd·θ[j]).
pub(super) fn sgd_block(th: &mut [f32], zb: &[f32], lr: f32, g: f32, wd: f32) {
    block_apply8!(th.len(), |j| sgd1(&mut th[j], zb[j], lr, g, wd));
}

/// n-SPSA block: seeds applied in slice order per coordinate.
pub(super) fn multi_sgd_block(
    th: &mut [f32],
    zb: &[f32],
    zs: &[(GaussianStream, f32)],
    lr: f32,
    wd: f32,
) {
    block_apply8!(th.len(), |j| multi_sgd1(&mut th[j], zs, |kk| zb[kk * BLOCK + j], lr, wd));
}

/// FZOO batched mean-update block.
pub(super) fn fzoo_block(
    th: &mut [f32],
    zb: &[f32],
    zs: &[(GaussianStream, f32)],
    n_f: f32,
    lr: f32,
    wd: f32,
) {
    block_apply8!(th.len(), |j| fzoo1(&mut th[j], zs, |kk| zb[kk * BLOCK + j], n_f, lr, wd));
}

/// Batched multi-seed axpy block.
pub(super) fn multi_axpy_block(th: &mut [f32], zb: &[f32], zs: &[(GaussianStream, f32)]) {
    block_apply8!(th.len(), |j| multi_axpy1(&mut th[j], zs, |kk| zb[kk * BLOCK + j]));
}

/// Fused momentum block.
#[allow(clippy::too_many_arguments)]
pub(super) fn momentum_block(
    th: &mut [f32],
    m: &mut [f32],
    zb: &[f32],
    zs: &[(GaussianStream, f32)],
    lr: f32,
    wd: f32,
    momentum: f32,
    n_records: f32,
) {
    block_apply8!(th.len(), |j| {
        let z = |kk: usize| zb[kk * BLOCK + j];
        momentum1(&mut th[j], &mut m[j], zs, z, lr, wd, momentum, n_records)
    });
}

/// Fused bias-corrected Adam block.
#[allow(clippy::too_many_arguments)]
pub(super) fn adam_block(
    th: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    zb: &[f32],
    zs: &[(GaussianStream, f32)],
    p: AdamParams,
    bc1: f32,
    bc2: f32,
) {
    block_apply8!(th.len(), |j| {
        let z = |kk: usize| zb[kk * BLOCK + j];
        adam1(&mut th[j], &mut m[j], &mut v[j], zs, z, p, bc1, bc2)
    });
}

/// Moment EMA block.
pub(super) fn ema_block(m: &mut [f32], zb: &[f32], pgrad: f32, beta: f32, adam_style: bool) {
    block_apply8!(m.len(), |j| ema1(&mut m[j], zb[j], pgrad, beta, adam_style));
}

// ---------------- dense kernel bodies -----------------------------------

/// θ[j] += s · z(offset + j)
pub(super) fn axpy_serial(
    tier: Tier,
    stream: GaussianStream,
    offset: u64,
    theta: &mut [f32],
    s: f32,
) {
    let sf = tier.simd_fill();
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        stream.fill_dispatch(&mut zb[..n], offset + i as u64, sf);
        simd::axpy_block(tier, &mut theta[i..i + n], &zb[..n], s);
        i += n;
    }
}

/// out[j] = θ[j] + s · z(offset + j)
pub(super) fn perturb_into_serial(
    tier: Tier,
    stream: GaussianStream,
    offset: u64,
    theta: &[f32],
    s: f32,
    out: &mut [f32],
) {
    let sf = tier.simd_fill();
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < out.len() {
        let n = BLOCK.min(out.len() - i);
        stream.fill_dispatch(&mut zb[..n], offset + i as u64, sf);
        simd::perturb_block(tier, &mut out[i..i + n], &theta[i..i + n], &zb[..n], s);
        i += n;
    }
}

/// θ[j] −= lr · (g · z(offset + j) + wd · θ[j])
pub(super) fn sgd_serial(
    tier: Tier,
    stream: GaussianStream,
    offset: u64,
    theta: &mut [f32],
    lr: f32,
    g: f32,
    wd: f32,
) {
    let sf = tier.simd_fill();
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        stream.fill_dispatch(&mut zb[..n], offset + i as u64, sf);
        simd::sgd_block(tier, &mut theta[i..i + n], &zb[..n], lr, g, wd);
        i += n;
    }
}

/// All n-SPSA updates in one pass: per coordinate, the (stream, g) updates
/// apply in slice order — the same operation sequence as n separate
/// `sgd_serial` passes, with θ read and written once.
pub(super) fn multi_sgd_serial(
    tier: Tier,
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    lr: f32,
    wd: f32,
) {
    let sf = tier.simd_fill();
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill_dispatch(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64, sf);
        }
        simd::multi_sgd_block(tier, &mut theta[i..i + n], &zb, zs, lr, wd);
        i += n;
    }
}

/// FZOO batched one-sided update: per coordinate the n per-seed projected
/// gradients are averaged first, then applied as one fused subtraction —
///   g = (Σᵢ gᵢ·zᵢ)/n;  θ −= lr·(g + wd·θ).
/// Unlike `multi_sgd_serial` (n sequential SGD updates per coordinate,
/// matching MeZO's record order) this is a *mean* update: one weight-decay
/// term per step, not per seed, which is what the one-sided batched
/// estimator calls for. With n = 1 the computation per coordinate is
/// `θ −= lr·(g·z + wd·θ)` — exactly `sgd_serial` (see tests/properties.rs).
pub(super) fn fzoo_serial(
    tier: Tier,
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    lr: f32,
    wd: f32,
) {
    let sf = tier.simd_fill();
    let k = zs.len();
    let n_f = k as f32;
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill_dispatch(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64, sf);
        }
        simd::fzoo_block(tier, &mut theta[i..i + n], &zb, zs, n_f, lr, wd);
        i += n;
    }
}

/// Batched multi-seed axpy: θ[j] += Σᵢ sᵢ·zᵢ(offset + j), the seeds applied
/// per coordinate in slice order — the same operation sequence as k
/// separate `axpy_serial` passes, with θ read and written once. This is the
/// replay kernel for seed-batched (FZOO) trajectories.
pub(super) fn multi_axpy_serial(
    tier: Tier,
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
) {
    let sf = tier.simd_fill();
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill_dispatch(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64, sf);
        }
        simd::multi_axpy_block(tier, &mut theta[i..i + n], &zb, zs);
        i += n;
    }
}

/// Fused momentum update over a record batch:
/// g = (Σᵢ gᵢ·zᵢ)/n + wd·θ;  m = μ·m + g;  θ −= lr·m
#[allow(clippy::too_many_arguments)]
pub(super) fn momentum_serial(
    tier: Tier,
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    m: &mut [f32],
    lr: f32,
    wd: f32,
    momentum: f32,
    n_records: f32,
) {
    let sf = tier.simd_fill();
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill_dispatch(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64, sf);
        }
        let (th, mk) = (&mut theta[i..i + n], &mut m[i..i + n]);
        simd::momentum_block(tier, th, mk, &zb, zs, lr, wd, momentum, n_records);
        i += n;
    }
}

/// Fused Adam update over a record batch (bias-corrected).
pub(super) fn adam_serial(
    tier: Tier,
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    p: AdamParams,
) {
    let sf = tier.simd_fill();
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    // same value per coordinate in the seed loop; hoisted here
    let bc1 = 1.0 - p.beta1.powf(p.t);
    let bc2 = 1.0 - p.beta2.powf(p.t);
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill_dispatch(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64, sf);
        }
        let (th, mk, vk) = (&mut theta[i..i + n], &mut m[i..i + n], &mut v[i..i + n]);
        simd::adam_block(tier, th, mk, vk, &zb, zs, p, bc1, bc2);
        i += n;
    }
}

/// m = β·m + (1−β)·(pgrad·z) (Adam-style) or m = β·m + pgrad·z.
pub(super) fn ema_serial(
    tier: Tier,
    stream: GaussianStream,
    offset: u64,
    m: &mut [f32],
    pgrad: f32,
    beta: f32,
    adam_style: bool,
) {
    let sf = tier.simd_fill();
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < m.len() {
        let n = BLOCK.min(m.len() - i);
        stream.fill_dispatch(&mut zb[..n], offset + i as u64, sf);
        simd::ema_block(tier, &mut m[i..i + n], &zb[..n], pgrad, beta, adam_style);
        i += n;
    }
}

/// out[jj] = base[jj] + scale · Σᵢ z((start+jj)·d_low + i)·v[i]
/// (`start` = chunk offset in rows; each row's z-range is contiguous, so
/// the row fills through the blocked path.)
///
/// NOT unrolled and NOT lane-split: the inner loop is a *reduction* over
/// `d_low` within one output coordinate, and splitting it into lanes (or
/// 8 accumulation chains) would change the summation order — a values
/// change, not a perf knob. The bit-exactness contract keeps this one a
/// straight sequential dot; only the row *fill* dispatches to SIMD.
#[allow(clippy::too_many_arguments)]
pub(super) fn project_rows_serial(
    tier: Tier,
    stream: GaussianStream,
    d_low: usize,
    v: &[f32],
    base: &[f32],
    scale: f32,
    out: &mut [f32],
    start: usize,
) {
    let sf = tier.simd_fill();
    let mut zrow = vec![0.0f32; d_low];
    for (jj, (o, &b)) in out.iter_mut().zip(base).enumerate() {
        let row = (start + jj) as u64 * d_low as u64;
        stream.fill_dispatch(&mut zrow, row, sf);
        let mut acc = 0.0f32;
        for (&zr, &vi) in zrow.iter().zip(v) {
            acc += zr * vi;
        }
        *o = b + scale * acc;
    }
}

// ---------------- masked (SensZOQ) kernel bodies ------------------------
//
// Each masked body walks a sorted, duplicate-free index list instead of
// the whole chunk, computing z for coordinate `idx` at the SAME global
// counter the dense kernel uses — `z(offset + idx)` — so a full mask is
// bit-identical to the dense kernel and sparse results never depend on
// what the mask excludes. `base` is the chunk's first coordinate within
// the tensor (0 when unthreaded); indices are tensor-absolute.
//
// z generation is hybrid: the sorted list is walked in runs that share one
// BLOCK-aligned z-block, and a run dense enough to amortize a block fill
// (>= MASK_FILL_MIN hits) goes through `GaussianStream::fill`; sparser
// runs pay the per-coordinate `z()` dispatch instead of generating 256
// coordinates to use a few. Both paths produce identical bits (`fill` is
// elementwise `z()` — see tests/properties.rs), so the crossover is a pure
// perf knob. Both paths run through `block_apply8!` over the run's index
// slice (lanes = masked coordinates) and reuse the same `*1` op bodies as
// the dense kernels.

/// Minimum hits in one z-block before the masked kernels fill the whole
/// block instead of calling `z()` per coordinate (~the crossover where
/// 256 blocked generations beat N dispatched ones).
pub(super) const MASK_FILL_MIN: usize = 192;

/// End of the run of `idxs[i..]` sharing `idxs[i]`'s z-block, plus that
/// block's first coordinate.
#[inline]
fn mask_run(idxs: &[u32], i: usize) -> (usize, u64) {
    let first = (idxs[i] as u64 / BLOCK as u64) * BLOCK as u64;
    let end = first + BLOCK as u64;
    let mut j = i + 1;
    while j < idxs.len() && (idxs[j] as u64) < end {
        j += 1;
    }
    (j, first)
}

/// θ[idx] += s · z(offset + idx) over the masked coordinates only.
pub(super) fn masked_axpy_serial(
    stream: GaussianStream,
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &mut [f32],
    s: f32,
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        let run = &idxs[i..j];
        if run.len() >= MASK_FILL_MIN {
            stream.fill(&mut zb, offset + first);
            block_apply8!(run.len(), |r| {
                let idx = run[r];
                let z = zb[(idx as u64 - first) as usize];
                axpy1(&mut theta[idx as usize - base], z, s)
            });
        } else {
            block_apply8!(run.len(), |r| {
                let idx = run[r];
                let z = stream.z(offset + idx as u64);
                axpy1(&mut theta[idx as usize - base], z, s)
            });
        }
        i = j;
    }
}

/// out[idx] = θ[idx] + s · z(offset + idx) over the masked coordinates;
/// unmasked coordinates of `out` are left untouched.
pub(super) fn masked_perturb_into_serial(
    stream: GaussianStream,
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &[f32],
    s: f32,
    out: &mut [f32],
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        let run = &idxs[i..j];
        if run.len() >= MASK_FILL_MIN {
            stream.fill(&mut zb, offset + first);
            block_apply8!(run.len(), |r| {
                let c = run[r] as usize - base;
                let z = zb[(run[r] as u64 - first) as usize];
                perturb1(&mut out[c], theta[c], z, s)
            });
        } else {
            block_apply8!(run.len(), |r| {
                let c = run[r] as usize - base;
                let z = stream.z(offset + run[r] as u64);
                perturb1(&mut out[c], theta[c], z, s)
            });
        }
        i = j;
    }
}

/// θ[idx] −= lr · (g · z(offset + idx) + wd · θ[idx]) over the masked
/// coordinates only.
#[allow(clippy::too_many_arguments)]
pub(super) fn masked_sgd_serial(
    stream: GaussianStream,
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &mut [f32],
    lr: f32,
    g: f32,
    wd: f32,
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        let run = &idxs[i..j];
        if run.len() >= MASK_FILL_MIN {
            stream.fill(&mut zb, offset + first);
            block_apply8!(run.len(), |r| {
                let idx = run[r];
                let z = zb[(idx as u64 - first) as usize];
                sgd1(&mut theta[idx as usize - base], z, lr, g, wd)
            });
        } else {
            block_apply8!(run.len(), |r| {
                let idx = run[r];
                let z = stream.z(offset + idx as u64);
                sgd1(&mut theta[idx as usize - base], z, lr, g, wd)
            });
        }
        i = j;
    }
}

/// Masked n-SPSA: per masked coordinate, the (stream, g) updates apply in
/// slice order — the operation sequence of `masked_sgd_serial` per seed,
/// with θ read and written once.
pub(super) fn masked_multi_sgd_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &mut [f32],
    lr: f32,
    wd: f32,
) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        let run = &idxs[i..j];
        if run.len() >= MASK_FILL_MIN {
            for (kk, &(stream, _)) in zs.iter().enumerate() {
                stream.fill(&mut zb[kk * BLOCK..(kk + 1) * BLOCK], offset + first);
            }
            block_apply8!(run.len(), |r| {
                let idx = run[r];
                let jb = (idx as u64 - first) as usize;
                let z = |kk: usize| zb[kk * BLOCK + jb];
                multi_sgd1(&mut theta[idx as usize - base], zs, z, lr, wd)
            });
        } else {
            block_apply8!(run.len(), |r| {
                let idx = run[r];
                let z = |kk: usize| zs[kk].0.z(offset + idx as u64);
                multi_sgd1(&mut theta[idx as usize - base], zs, z, lr, wd)
            });
        }
        i = j;
    }
}

/// Masked FZOO batched one-sided update: per masked coordinate,
/// g = (Σᵢ gᵢ·zᵢ)/n;  θ −= lr·(g + wd·θ) — `fzoo_serial` restricted to
/// the mask.
pub(super) fn masked_fzoo_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &mut [f32],
    lr: f32,
    wd: f32,
) {
    let k = zs.len();
    let n_f = k as f32;
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        let run = &idxs[i..j];
        if run.len() >= MASK_FILL_MIN {
            for (kk, &(stream, _)) in zs.iter().enumerate() {
                stream.fill(&mut zb[kk * BLOCK..(kk + 1) * BLOCK], offset + first);
            }
            block_apply8!(run.len(), |r| {
                let idx = run[r];
                let jb = (idx as u64 - first) as usize;
                let z = |kk: usize| zb[kk * BLOCK + jb];
                fzoo1(&mut theta[idx as usize - base], zs, z, n_f, lr, wd)
            });
        } else {
            block_apply8!(run.len(), |r| {
                let idx = run[r];
                let z = |kk: usize| zs[kk].0.z(offset + idx as u64);
                fzoo1(&mut theta[idx as usize - base], zs, z, n_f, lr, wd)
            });
        }
        i = j;
    }
}

/// Masked batched multi-seed axpy: θ[idx] += Σᵢ sᵢ·zᵢ(offset + idx), seeds
/// in slice order per coordinate — the masked replay kernel.
pub(super) fn masked_multi_axpy_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &mut [f32],
) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        let run = &idxs[i..j];
        if run.len() >= MASK_FILL_MIN {
            for (kk, &(stream, _)) in zs.iter().enumerate() {
                stream.fill(&mut zb[kk * BLOCK..(kk + 1) * BLOCK], offset + first);
            }
            block_apply8!(run.len(), |r| {
                let idx = run[r];
                let jb = (idx as u64 - first) as usize;
                let z = |kk: usize| zb[kk * BLOCK + jb];
                multi_axpy1(&mut theta[idx as usize - base], zs, z)
            });
        } else {
            block_apply8!(run.len(), |r| {
                let idx = run[r];
                let z = |kk: usize| zs[kk].0.z(offset + idx as u64);
                multi_axpy1(&mut theta[idx as usize - base], zs, z)
            });
        }
        i = j;
    }
}
