//! Serial (per-chunk) bodies of the fused z-kernels.
//!
//! Every function here processes one contiguous chunk whose first
//! coordinate has global z-index `offset`. The pattern is uniform: fill a
//! [`BLOCK`]-sized stack buffer from the counter-based stream (one
//! ziggurat-table resolve per block instead of per coordinate), then run
//! the fused arithmetic over the block in a tight loop the compiler can
//! vectorize.
//!
//! BIT-EXACTNESS CONTRACT: each kernel performs, per coordinate, exactly
//! the floating-point operations (same order, same associativity) as the
//! scalar seed loops it replaced. That is what makes blocked/threaded
//! execution interchangeable with the historical code and with itself at
//! any thread count — see `zkernel::tests`.

use super::{AdamParams, BLOCK};
use crate::rng::GaussianStream;

/// θ[j] += s · z(offset + j)
pub(super) fn axpy_serial(stream: GaussianStream, offset: u64, theta: &mut [f32], s: f32) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        stream.fill(&mut zb[..n], offset + i as u64);
        for (th, &z) in theta[i..i + n].iter_mut().zip(&zb[..n]) {
            *th += s * z;
        }
        i += n;
    }
}

/// out[j] = θ[j] + s · z(offset + j)
pub(super) fn perturb_into_serial(
    stream: GaussianStream,
    offset: u64,
    theta: &[f32],
    s: f32,
    out: &mut [f32],
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < out.len() {
        let n = BLOCK.min(out.len() - i);
        stream.fill(&mut zb[..n], offset + i as u64);
        for ((o, &th), &z) in out[i..i + n].iter_mut().zip(&theta[i..i + n]).zip(&zb[..n]) {
            *o = th + s * z;
        }
        i += n;
    }
}

/// θ[j] −= lr · (g · z(offset + j) + wd · θ[j])
pub(super) fn sgd_serial(
    stream: GaussianStream,
    offset: u64,
    theta: &mut [f32],
    lr: f32,
    g: f32,
    wd: f32,
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        stream.fill(&mut zb[..n], offset + i as u64);
        for (th, &z) in theta[i..i + n].iter_mut().zip(&zb[..n]) {
            *th -= lr * (g * z + wd * *th);
        }
        i += n;
    }
}

/// All n-SPSA updates in one pass: per coordinate, the (stream, g) updates
/// apply in slice order — the same operation sequence as n separate
/// `sgd_serial` passes, with θ read and written once.
pub(super) fn multi_sgd_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    lr: f32,
    wd: f32,
) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64);
        }
        for (j, th) in theta[i..i + n].iter_mut().enumerate() {
            for (kk, &(_, g)) in zs.iter().enumerate() {
                let z = zb[kk * BLOCK + j];
                *th -= lr * (g * z + wd * *th);
            }
        }
        i += n;
    }
}

/// FZOO batched one-sided update: per coordinate the n per-seed projected
/// gradients are averaged first, then applied as one fused subtraction —
///   g = (Σᵢ gᵢ·zᵢ)/n;  θ −= lr·(g + wd·θ).
/// Unlike `multi_sgd_serial` (n sequential SGD updates per coordinate,
/// matching MeZO's record order) this is a *mean* update: one weight-decay
/// term per step, not per seed, which is what the one-sided batched
/// estimator calls for. With n = 1 the computation per coordinate is
/// `θ −= lr·(g·z + wd·θ)` — exactly `sgd_serial` (see tests/properties.rs).
pub(super) fn fzoo_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    lr: f32,
    wd: f32,
) {
    let k = zs.len();
    let n_f = k as f32;
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64);
        }
        for (j, th) in theta[i..i + n].iter_mut().enumerate() {
            let mut g = 0.0f32;
            for (kk, &(_, pg)) in zs.iter().enumerate() {
                g += pg * zb[kk * BLOCK + j];
            }
            *th -= lr * (g / n_f + wd * *th);
        }
        i += n;
    }
}

/// Batched multi-seed axpy: θ[j] += Σᵢ sᵢ·zᵢ(offset + j), the seeds applied
/// per coordinate in slice order — the same operation sequence as k
/// separate `axpy_serial` passes, with θ read and written once. This is the
/// replay kernel for seed-batched (FZOO) trajectories.
pub(super) fn multi_axpy_serial(zs: &[(GaussianStream, f32)], offset: u64, theta: &mut [f32]) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64);
        }
        for (j, th) in theta[i..i + n].iter_mut().enumerate() {
            for (kk, &(_, s)) in zs.iter().enumerate() {
                *th += s * zb[kk * BLOCK + j];
            }
        }
        i += n;
    }
}

// ---------------- masked (SensZOQ) kernel bodies ------------------------
//
// Each masked body walks a sorted, duplicate-free index list instead of
// the whole chunk, computing z for coordinate `idx` at the SAME global
// counter the dense kernel uses — `z(offset + idx)` — so a full mask is
// bit-identical to the dense kernel and sparse results never depend on
// what the mask excludes. `base` is the chunk's first coordinate within
// the tensor (0 when unthreaded); indices are tensor-absolute.
//
// z generation is hybrid: the sorted list is walked in runs that share one
// BLOCK-aligned z-block, and a run dense enough to amortize a block fill
// (>= MASK_FILL_MIN hits) goes through `GaussianStream::fill`; sparser
// runs pay the per-coordinate `z()` dispatch instead of generating 256
// coordinates to use a few. Both paths produce identical bits (`fill` is
// elementwise `z()` — see tests/properties.rs), so the crossover is a pure
// perf knob.

/// Minimum hits in one z-block before the masked kernels fill the whole
/// block instead of calling `z()` per coordinate (~the crossover where
/// 256 blocked generations beat N dispatched ones).
pub(super) const MASK_FILL_MIN: usize = 192;

/// End of the run of `idxs[i..]` sharing `idxs[i]`'s z-block, plus that
/// block's first coordinate.
#[inline]
fn mask_run(idxs: &[u32], i: usize) -> (usize, u64) {
    let first = (idxs[i] as u64 / BLOCK as u64) * BLOCK as u64;
    let end = first + BLOCK as u64;
    let mut j = i + 1;
    while j < idxs.len() && (idxs[j] as u64) < end {
        j += 1;
    }
    (j, first)
}

/// θ[idx] += s · z(offset + idx) over the masked coordinates only.
pub(super) fn masked_axpy_serial(
    stream: GaussianStream,
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &mut [f32],
    s: f32,
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        if j - i >= MASK_FILL_MIN {
            stream.fill(&mut zb, offset + first);
            for &idx in &idxs[i..j] {
                theta[idx as usize - base] += s * zb[(idx as u64 - first) as usize];
            }
        } else {
            for &idx in &idxs[i..j] {
                theta[idx as usize - base] += s * stream.z(offset + idx as u64);
            }
        }
        i = j;
    }
}

/// out[idx] = θ[idx] + s · z(offset + idx) over the masked coordinates;
/// unmasked coordinates of `out` are left untouched.
pub(super) fn masked_perturb_into_serial(
    stream: GaussianStream,
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &[f32],
    s: f32,
    out: &mut [f32],
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        if j - i >= MASK_FILL_MIN {
            stream.fill(&mut zb, offset + first);
            for &idx in &idxs[i..j] {
                let c = idx as usize - base;
                out[c] = theta[c] + s * zb[(idx as u64 - first) as usize];
            }
        } else {
            for &idx in &idxs[i..j] {
                let c = idx as usize - base;
                out[c] = theta[c] + s * stream.z(offset + idx as u64);
            }
        }
        i = j;
    }
}

/// θ[idx] −= lr · (g · z(offset + idx) + wd · θ[idx]) over the masked
/// coordinates only.
pub(super) fn masked_sgd_serial(
    stream: GaussianStream,
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &mut [f32],
    lr: f32,
    g: f32,
    wd: f32,
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        if j - i >= MASK_FILL_MIN {
            stream.fill(&mut zb, offset + first);
            for &idx in &idxs[i..j] {
                let th = &mut theta[idx as usize - base];
                let z = zb[(idx as u64 - first) as usize];
                *th -= lr * (g * z + wd * *th);
            }
        } else {
            for &idx in &idxs[i..j] {
                let th = &mut theta[idx as usize - base];
                let z = stream.z(offset + idx as u64);
                *th -= lr * (g * z + wd * *th);
            }
        }
        i = j;
    }
}

/// Masked n-SPSA: per masked coordinate, the (stream, g) updates apply in
/// slice order — the operation sequence of `masked_sgd_serial` per seed,
/// with θ read and written once.
pub(super) fn masked_multi_sgd_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &mut [f32],
    lr: f32,
    wd: f32,
) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        if j - i >= MASK_FILL_MIN {
            for (kk, &(stream, _)) in zs.iter().enumerate() {
                stream.fill(&mut zb[kk * BLOCK..(kk + 1) * BLOCK], offset + first);
            }
            for &idx in &idxs[i..j] {
                let th = &mut theta[idx as usize - base];
                let jb = (idx as u64 - first) as usize;
                for (kk, &(_, g)) in zs.iter().enumerate() {
                    let z = zb[kk * BLOCK + jb];
                    *th -= lr * (g * z + wd * *th);
                }
            }
        } else {
            for &idx in &idxs[i..j] {
                let th = &mut theta[idx as usize - base];
                for &(stream, g) in zs {
                    let z = stream.z(offset + idx as u64);
                    *th -= lr * (g * z + wd * *th);
                }
            }
        }
        i = j;
    }
}

/// Masked FZOO batched one-sided update: per masked coordinate,
/// g = (Σᵢ gᵢ·zᵢ)/n;  θ −= lr·(g + wd·θ) — `fzoo_serial` restricted to
/// the mask.
pub(super) fn masked_fzoo_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &mut [f32],
    lr: f32,
    wd: f32,
) {
    let k = zs.len();
    let n_f = k as f32;
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        if j - i >= MASK_FILL_MIN {
            for (kk, &(stream, _)) in zs.iter().enumerate() {
                stream.fill(&mut zb[kk * BLOCK..(kk + 1) * BLOCK], offset + first);
            }
            for &idx in &idxs[i..j] {
                let th = &mut theta[idx as usize - base];
                let jb = (idx as u64 - first) as usize;
                let mut g = 0.0f32;
                for (kk, &(_, pg)) in zs.iter().enumerate() {
                    g += pg * zb[kk * BLOCK + jb];
                }
                *th -= lr * (g / n_f + wd * *th);
            }
        } else {
            for &idx in &idxs[i..j] {
                let th = &mut theta[idx as usize - base];
                let mut g = 0.0f32;
                for &(stream, pg) in zs {
                    g += pg * stream.z(offset + idx as u64);
                }
                *th -= lr * (g / n_f + wd * *th);
            }
        }
        i = j;
    }
}

/// Masked batched multi-seed axpy: θ[idx] += Σᵢ sᵢ·zᵢ(offset + idx), seeds
/// in slice order per coordinate — the masked replay kernel.
pub(super) fn masked_multi_axpy_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    idxs: &[u32],
    base: usize,
    theta: &mut [f32],
) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < idxs.len() {
        let (j, first) = mask_run(idxs, i);
        if j - i >= MASK_FILL_MIN {
            for (kk, &(stream, _)) in zs.iter().enumerate() {
                stream.fill(&mut zb[kk * BLOCK..(kk + 1) * BLOCK], offset + first);
            }
            for &idx in &idxs[i..j] {
                let th = &mut theta[idx as usize - base];
                let jb = (idx as u64 - first) as usize;
                for (kk, &(_, s)) in zs.iter().enumerate() {
                    *th += s * zb[kk * BLOCK + jb];
                }
            }
        } else {
            for &idx in &idxs[i..j] {
                let th = &mut theta[idx as usize - base];
                for &(stream, s) in zs {
                    *th += s * stream.z(offset + idx as u64);
                }
            }
        }
        i = j;
    }
}

/// Fused momentum update over a record batch:
/// g = (Σᵢ gᵢ·zᵢ)/n + wd·θ;  m = μ·m + g;  θ −= lr·m
#[allow(clippy::too_many_arguments)]
pub(super) fn momentum_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    m: &mut [f32],
    lr: f32,
    wd: f32,
    momentum: f32,
    n_records: f32,
) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64);
        }
        for j in 0..n {
            let th = &mut theta[i + j];
            let mk = &mut m[i + j];
            let mut g = 0.0f32;
            for (kk, &(_, pg)) in zs.iter().enumerate() {
                g += pg * zb[kk * BLOCK + j];
            }
            g = g / n_records + wd * *th;
            *mk = momentum * *mk + g;
            *th -= lr * *mk;
        }
        i += n;
    }
}

/// Fused Adam update over a record batch (bias-corrected).
pub(super) fn adam_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    p: AdamParams,
) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    // same value per coordinate in the seed loop; hoisted here
    let bc1 = 1.0 - p.beta1.powf(p.t);
    let bc2 = 1.0 - p.beta2.powf(p.t);
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64);
        }
        for j in 0..n {
            let th = &mut theta[i + j];
            let mk = &mut m[i + j];
            let vk = &mut v[i + j];
            let mut g = 0.0f32;
            for (kk, &(_, pg)) in zs.iter().enumerate() {
                g += pg * zb[kk * BLOCK + j];
            }
            g = g / p.n + p.wd * *th;
            *mk = p.beta1 * *mk + (1.0 - p.beta1) * g;
            *vk = p.beta2 * *vk + (1.0 - p.beta2) * g * g;
            let mhat = *mk / bc1;
            let vhat = *vk / bc2;
            *th -= p.lr * mhat / (vhat.sqrt() + p.eps);
        }
        i += n;
    }
}

/// m = β·m + (1−β)·(pgrad·z) (Adam-style) or m = β·m + pgrad·z.
pub(super) fn ema_serial(
    stream: GaussianStream,
    offset: u64,
    m: &mut [f32],
    pgrad: f32,
    beta: f32,
    adam_style: bool,
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < m.len() {
        let n = BLOCK.min(m.len() - i);
        stream.fill(&mut zb[..n], offset + i as u64);
        for (mk, &z) in m[i..i + n].iter_mut().zip(&zb[..n]) {
            let g = pgrad * z;
            *mk = if adam_style { beta * *mk + (1.0 - beta) * g } else { beta * *mk + g };
        }
        i += n;
    }
}

/// out[jj] = base[jj] + scale · Σᵢ z((start+jj)·d_low + i)·v[i]
/// (`start` = chunk offset in rows; each row's z-range is contiguous, so
/// the row fills through the blocked path.)
pub(super) fn project_rows_serial(
    stream: GaussianStream,
    d_low: usize,
    v: &[f32],
    base: &[f32],
    scale: f32,
    out: &mut [f32],
    start: usize,
) {
    let mut zrow = vec![0.0f32; d_low];
    for (jj, (o, &b)) in out.iter_mut().zip(base).enumerate() {
        let row = (start + jj) as u64 * d_low as u64;
        stream.fill(&mut zrow, row);
        let mut acc = 0.0f32;
        for (&zr, &vi) in zrow.iter().zip(v) {
            acc += zr * vi;
        }
        *o = b + scale * acc;
    }
}
