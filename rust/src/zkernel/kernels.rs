//! Serial (per-chunk) bodies of the fused z-kernels.
//!
//! Every function here processes one contiguous chunk whose first
//! coordinate has global z-index `offset`. The pattern is uniform: fill a
//! [`BLOCK`]-sized stack buffer from the counter-based stream (one
//! ziggurat-table resolve per block instead of per coordinate), then run
//! the fused arithmetic over the block in a tight loop the compiler can
//! vectorize.
//!
//! BIT-EXACTNESS CONTRACT: each kernel performs, per coordinate, exactly
//! the floating-point operations (same order, same associativity) as the
//! scalar seed loops it replaced. That is what makes blocked/threaded
//! execution interchangeable with the historical code and with itself at
//! any thread count — see `zkernel::tests`.

use super::{AdamParams, BLOCK};
use crate::rng::GaussianStream;

/// θ[j] += s · z(offset + j)
pub(super) fn axpy_serial(stream: GaussianStream, offset: u64, theta: &mut [f32], s: f32) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        stream.fill(&mut zb[..n], offset + i as u64);
        for (th, &z) in theta[i..i + n].iter_mut().zip(&zb[..n]) {
            *th += s * z;
        }
        i += n;
    }
}

/// out[j] = θ[j] + s · z(offset + j)
pub(super) fn perturb_into_serial(
    stream: GaussianStream,
    offset: u64,
    theta: &[f32],
    s: f32,
    out: &mut [f32],
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < out.len() {
        let n = BLOCK.min(out.len() - i);
        stream.fill(&mut zb[..n], offset + i as u64);
        for ((o, &th), &z) in out[i..i + n].iter_mut().zip(&theta[i..i + n]).zip(&zb[..n]) {
            *o = th + s * z;
        }
        i += n;
    }
}

/// θ[j] −= lr · (g · z(offset + j) + wd · θ[j])
pub(super) fn sgd_serial(
    stream: GaussianStream,
    offset: u64,
    theta: &mut [f32],
    lr: f32,
    g: f32,
    wd: f32,
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        stream.fill(&mut zb[..n], offset + i as u64);
        for (th, &z) in theta[i..i + n].iter_mut().zip(&zb[..n]) {
            *th -= lr * (g * z + wd * *th);
        }
        i += n;
    }
}

/// All n-SPSA updates in one pass: per coordinate, the (stream, g) updates
/// apply in slice order — the same operation sequence as n separate
/// `sgd_serial` passes, with θ read and written once.
pub(super) fn multi_sgd_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    lr: f32,
    wd: f32,
) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64);
        }
        for (j, th) in theta[i..i + n].iter_mut().enumerate() {
            for (kk, &(_, g)) in zs.iter().enumerate() {
                let z = zb[kk * BLOCK + j];
                *th -= lr * (g * z + wd * *th);
            }
        }
        i += n;
    }
}

/// FZOO batched one-sided update: per coordinate the n per-seed projected
/// gradients are averaged first, then applied as one fused subtraction —
///   g = (Σᵢ gᵢ·zᵢ)/n;  θ −= lr·(g + wd·θ).
/// Unlike `multi_sgd_serial` (n sequential SGD updates per coordinate,
/// matching MeZO's record order) this is a *mean* update: one weight-decay
/// term per step, not per seed, which is what the one-sided batched
/// estimator calls for. With n = 1 the computation per coordinate is
/// `θ −= lr·(g·z + wd·θ)` — exactly `sgd_serial` (see tests/properties.rs).
pub(super) fn fzoo_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    lr: f32,
    wd: f32,
) {
    let k = zs.len();
    let n_f = k as f32;
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64);
        }
        for (j, th) in theta[i..i + n].iter_mut().enumerate() {
            let mut g = 0.0f32;
            for (kk, &(_, pg)) in zs.iter().enumerate() {
                g += pg * zb[kk * BLOCK + j];
            }
            *th -= lr * (g / n_f + wd * *th);
        }
        i += n;
    }
}

/// Batched multi-seed axpy: θ[j] += Σᵢ sᵢ·zᵢ(offset + j), the seeds applied
/// per coordinate in slice order — the same operation sequence as k
/// separate `axpy_serial` passes, with θ read and written once. This is the
/// replay kernel for seed-batched (FZOO) trajectories.
pub(super) fn multi_axpy_serial(zs: &[(GaussianStream, f32)], offset: u64, theta: &mut [f32]) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64);
        }
        for (j, th) in theta[i..i + n].iter_mut().enumerate() {
            for (kk, &(_, s)) in zs.iter().enumerate() {
                *th += s * zb[kk * BLOCK + j];
            }
        }
        i += n;
    }
}

/// Fused momentum update over a record batch:
/// g = (Σᵢ gᵢ·zᵢ)/n + wd·θ;  m = μ·m + g;  θ −= lr·m
#[allow(clippy::too_many_arguments)]
pub(super) fn momentum_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    m: &mut [f32],
    lr: f32,
    wd: f32,
    momentum: f32,
    n_records: f32,
) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64);
        }
        for j in 0..n {
            let th = &mut theta[i + j];
            let mk = &mut m[i + j];
            let mut g = 0.0f32;
            for (kk, &(_, pg)) in zs.iter().enumerate() {
                g += pg * zb[kk * BLOCK + j];
            }
            g = g / n_records + wd * *th;
            *mk = momentum * *mk + g;
            *th -= lr * *mk;
        }
        i += n;
    }
}

/// Fused Adam update over a record batch (bias-corrected).
pub(super) fn adam_serial(
    zs: &[(GaussianStream, f32)],
    offset: u64,
    theta: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    p: AdamParams,
) {
    let k = zs.len();
    let mut zb = vec![0.0f32; k * BLOCK];
    // same value per coordinate in the seed loop; hoisted here
    let bc1 = 1.0 - p.beta1.powf(p.t);
    let bc2 = 1.0 - p.beta2.powf(p.t);
    let mut i = 0;
    while i < theta.len() {
        let n = BLOCK.min(theta.len() - i);
        for (kk, &(stream, _)) in zs.iter().enumerate() {
            stream.fill(&mut zb[kk * BLOCK..kk * BLOCK + n], offset + i as u64);
        }
        for j in 0..n {
            let th = &mut theta[i + j];
            let mk = &mut m[i + j];
            let vk = &mut v[i + j];
            let mut g = 0.0f32;
            for (kk, &(_, pg)) in zs.iter().enumerate() {
                g += pg * zb[kk * BLOCK + j];
            }
            g = g / p.n + p.wd * *th;
            *mk = p.beta1 * *mk + (1.0 - p.beta1) * g;
            *vk = p.beta2 * *vk + (1.0 - p.beta2) * g * g;
            let mhat = *mk / bc1;
            let vhat = *vk / bc2;
            *th -= p.lr * mhat / (vhat.sqrt() + p.eps);
        }
        i += n;
    }
}

/// m = β·m + (1−β)·(pgrad·z) (Adam-style) or m = β·m + pgrad·z.
pub(super) fn ema_serial(
    stream: GaussianStream,
    offset: u64,
    m: &mut [f32],
    pgrad: f32,
    beta: f32,
    adam_style: bool,
) {
    let mut zb = [0.0f32; BLOCK];
    let mut i = 0;
    while i < m.len() {
        let n = BLOCK.min(m.len() - i);
        stream.fill(&mut zb[..n], offset + i as u64);
        for (mk, &z) in m[i..i + n].iter_mut().zip(&zb[..n]) {
            let g = pgrad * z;
            *mk = if adam_style { beta * *mk + (1.0 - beta) * g } else { beta * *mk + g };
        }
        i += n;
    }
}

/// out[jj] = base[jj] + scale · Σᵢ z((start+jj)·d_low + i)·v[i]
/// (`start` = chunk offset in rows; each row's z-range is contiguous, so
/// the row fills through the blocked path.)
pub(super) fn project_rows_serial(
    stream: GaussianStream,
    d_low: usize,
    v: &[f32],
    base: &[f32],
    scale: f32,
    out: &mut [f32],
    start: usize,
) {
    let mut zrow = vec![0.0f32; d_low];
    for (jj, (o, &b)) in out.iter_mut().zip(base).enumerate() {
        let row = (start + jj) as u64 * d_low as u64;
        stream.fill(&mut zrow, row);
        let mut acc = 0.0f32;
        for (&zr, &vi) in zrow.iter().zip(v) {
            acc += zr * vi;
        }
        *o = b + scale * acc;
    }
}
