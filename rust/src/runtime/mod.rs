//! Runtime: load AOT artifacts (HLO text) and execute them via PJRT.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`. One compiled executable per artifact, cached for the process
//! lifetime. Python never runs here — the artifacts are self-contained.

use crate::data::batch::Batch;
use crate::model::meta::ArtifactMeta;
use crate::model::params::ParamStore;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// A compiled artifact plus its ABI description.
pub struct Artifact {
    pub meta: ArtifactMeta,
    exe: PjRtLoadedExecutable,
    /// execution counter (perf accounting)
    pub execs: std::cell::Cell<u64>,
}

/// The process-wide runtime: one PJRT CPU client + executable cache.
pub struct Runtime {
    pub client: PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Artifact>>>,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let client = PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: artifact_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Default artifact directory: $MEZO_ARTIFACTS or ./artifacts.
    pub fn from_env() -> Result<Runtime> {
        let dir = std::env::var("MEZO_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Runtime::new(Path::new(&dir))
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn artifact_exists(&self, name: &str) -> bool {
        self.dir.join(format!("{}.hlo.txt", name)).exists()
    }

    /// Load + compile (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let hlo = self.dir.join(format!("{}.hlo.txt", name));
        let meta_path = self.dir.join(format!("{}.meta.json", name));
        let meta = ArtifactMeta::load(&meta_path)
            .map_err(|e| anyhow!("artifact meta {}: {} (run `make artifacts`)", name, e))?;
        let proto = xla::HloModuleProto::from_text_file(&hlo)
            .with_context(|| format!("loading HLO text {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", name))?;
        let art = Rc::new(Artifact { meta, exe, execs: std::cell::Cell::new(0) });
        self.cache.borrow_mut().insert(name.to_string(), art.clone());
        Ok(art)
    }
}

pub fn f32_literal(shape: &[usize], data: &[f32]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len().max(1));
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)?)
}

pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)?)
}

impl Artifact {
    /// Execute with `params` + `batch` (+ extra literals for fused modes).
    /// Returns the output tuple as a Vec<Literal> in meta.outputs order.
    pub fn run(
        &self,
        params: &ParamStore,
        batch: Option<&Batch>,
        extras: &[Literal],
    ) -> Result<Vec<Literal>> {
        let m = &self.meta;
        if params.specs.len() != m.params.len() {
            bail!(
                "artifact {} expects {} param tensors, store has {}",
                m.name,
                m.params.len(),
                params.specs.len()
            );
        }
        let mut inputs: Vec<Literal> =
            Vec::with_capacity(m.params.len() + m.batch_inputs.len());
        for (spec, buf) in params.specs.iter().zip(&params.data) {
            inputs.push(f32_literal(&spec.shape, buf)?);
        }
        let mut extras_it = extras.iter();
        for bi in &m.batch_inputs {
            match bi.name.as_str() {
                "input_ids" | "targets" | "loss_mask" | "attn_mask" => {
                    let b = batch.ok_or_else(|| anyhow!("artifact needs a batch"))?;
                    m.validate_batch(b.b, b.s).map_err(|e| anyhow!("{}", e))?;
                    let lit = match bi.name.as_str() {
                        "input_ids" => i32_literal(&bi.shape, &b.input_ids)?,
                        "targets" => i32_literal(&bi.shape, &b.targets)?,
                        "loss_mask" => f32_literal(&bi.shape, &b.loss_mask)?,
                        _ => f32_literal(&bi.shape, &b.attn_mask)?,
                    };
                    inputs.push(lit);
                }
                _ => {
                    let lit = extras_it
                        .next()
                        .ok_or_else(|| anyhow!("missing extra input '{}'", bi.name))?;
                    inputs.push(clone_literal(lit)?);
                }
            }
        }
        let result = self.exe.execute::<Literal>(&inputs)?;
        self.execs.set(self.execs.get() + 1);
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

impl Artifact {
    /// §Perf L3 iteration 2: execute with the SPSA perturbation applied
    /// **during literal upload** instead of in-place on the ParamStore.
    /// The upload already copies every tensor, so writing θ + scale·z(seed)
    /// into the staging buffer makes the perturbed forward pass cost ONE
    /// extra fused multiply-add per parameter and eliminates Algorithm 1's
    /// separate perturb and restore passes (and their float-rounding drift)
    /// while computing the *identical* loss values.
    pub fn run_perturbed(
        &self,
        params: &ParamStore,
        trainable: &[bool],
        seed: u64,
        scale: f32,
        batch: Option<&Batch>,
        scratch: &mut Vec<f32>,
    ) -> Result<Vec<Literal>> {
        let m = &self.meta;
        let stream = crate::rng::GaussianStream::new(seed);
        let engine = crate::zkernel::ZEngine::default();
        let mut inputs: Vec<Literal> =
            Vec::with_capacity(m.params.len() + m.batch_inputs.len());
        for (ti, (spec, buf)) in params.specs.iter().zip(&params.data).enumerate() {
            if trainable.get(ti).copied().unwrap_or(false) {
                // §Perf L4: θ + scale·z written straight into the staging
                // buffer by the blocked/threaded perturb_into kernel
                // (grow-only resize: the kernel overwrites every element,
                // so no per-call zero-fill of the reused buffer)
                if scratch.len() < buf.len() {
                    scratch.resize(buf.len(), 0.0);
                }
                let dst = &mut scratch[..buf.len()];
                engine.perturb_into(stream, params.offsets[ti], buf, scale, dst);
                inputs.push(f32_literal(&spec.shape, dst)?);
            } else {
                inputs.push(f32_literal(&spec.shape, buf)?);
            }
        }
        if !m.batch_inputs.is_empty() {
            let b = batch.ok_or_else(|| anyhow!("artifact needs a batch"))?;
            // same ABI guard as Artifact::run — the fast path must reject
            // mis-shaped batches instead of uploading garbage
            m.validate_batch(b.b, b.s).map_err(|e| anyhow!("{}", e))?;
            for bi in &m.batch_inputs {
                let lit = match bi.name.as_str() {
                    "input_ids" => i32_literal(&bi.shape, &b.input_ids)?,
                    "targets" => i32_literal(&bi.shape, &b.targets)?,
                    "loss_mask" => f32_literal(&bi.shape, &b.loss_mask)?,
                    "attn_mask" => f32_literal(&bi.shape, &b.attn_mask)?,
                    other => bail!("run_perturbed: unsupported extra input {}", other),
                };
                inputs.push(lit);
            }
        }
        let result = self.exe.execute::<Literal>(&inputs)?;
        self.execs.set(self.execs.get() + 1);
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

/// Literal is not Clone in xla 0.1.6; rebuild from raw data.
fn clone_literal(l: &Literal) -> Result<Literal> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match l.ty()? {
        ElementType::F32 => {
            let v: Vec<f32> = l.to_vec()?;
            f32_literal(&dims, &v)
        }
        ElementType::S32 => {
            let v: Vec<i32> = l.to_vec()?;
            i32_literal(&dims, &v)
        }
        t => bail!("clone_literal: unsupported type {:?}", t),
    }
}

/// Scalar f32 from an output literal.
pub fn scalar_f32(l: &Literal) -> Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}

/// Vec<f32> from an output literal.
pub fn vec_f32(l: &Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}
