//! Offline drop-in subset of the `anyhow` crate.
//!
//! The container image has no crates.io registry, so this vendored crate
//! provides exactly the API surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the
//! [`Context`] extension trait. Like the real crate, `Error` wraps any
//! `std::error::Error + Send + Sync + 'static` (so `?` converts
//! automatically) and deliberately does not implement `std::error::Error`
//! itself, which is what makes the blanket `From` impl legal.

use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamically typed error with an optional chain of causes.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// A plain-message error (what `anyhow!("...")` produces).
struct MessageError(String);

impl Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.0, f)
    }
}
impl Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.0, f)
    }
}
impl StdError for MessageError {}

/// A context layer wrapped around a lower-level cause.
struct ContextError {
    context: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.context, f)
    }
}
impl Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.context, f)
    }
}
impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        let s: &(dyn StdError + 'static) = self.source.as_ref();
        Some(s)
    }
}

impl Error {
    pub fn msg<M: Display>(message: M) -> Error {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { inner: Box::new(error) }
    }

    /// Wrap this error in an additional context message.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error {
            inner: Box::new(ContextError {
                context: context.to_string(),
                source: self.inner,
            }),
        }
    }

    /// View the underlying error as a concrete type, looking through any
    /// `.context(..)` layers — mirrors the real crate's `downcast_ref`,
    /// which is what lets callers match on typed error enums carried
    /// inside an [`Error`].
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        let mut err: Option<&(dyn StdError + 'static)> = Some(self.inner.as_ref());
        while let Some(e) = err {
            if let Some(typed) = e.downcast_ref::<E>() {
                return Some(typed);
            }
            err = e.source();
        }
        None
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.inner, f)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {}", cause)?;
            source = cause.source();
        }
        Ok(())
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options, mirroring the real crate.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_chains_and_debug_prints_causes() {
        let e = io_fail().with_context(|| format!("reading {}", "x.bin")).unwrap_err();
        assert_eq!(e.to_string(), "reading x.bin");
        let dbg = format!("{:?}", e);
        assert!(dbg.contains("Caused by"), "{}", dbg);
        assert!(dbg.contains("disk on fire"), "{}", dbg);
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            bail!("unreachable {}", 1);
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(f(true).unwrap_err().to_string(), "unreachable 1");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn downcast_ref_sees_through_context_layers() {
        let e = io_fail().context("outer").unwrap_err();
        let io = e.downcast_ref::<std::io::Error>().expect("typed error survives context");
        assert_eq!(io.to_string(), "disk on fire");
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
    }
}
