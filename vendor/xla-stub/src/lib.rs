//! Compile-time stub of the `xla` crate (PJRT bindings).
//!
//! The offline image has neither the crates.io registry nor the XLA/PJRT
//! shared libraries, so this path crate stands in for `xla 0.1.6` when the
//! `pjrt` feature of the main crate is enabled. It keeps the whole
//! `runtime` layer type-checking and lets host-side helpers ([`Literal`]
//! construction, byte reinterpretation, shape queries) behave for real;
//! only the device entry point [`PjRtClient::cpu`] reports that no backend
//! is available. Deploying against real XLA means pointing the `xla`
//! dependency in the workspace `Cargo.toml` at the real bindings — the API
//! here is signature-compatible with every call site in `src/runtime`.

use std::fmt;
use std::path::Path;

/// Stub error type, `std::error::Error` so `?` converts into `anyhow`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla-stub: {}", self.0)
    }
}
impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{} requires the real XLA/PJRT runtime; this build uses the offline \
         stub (swap the `xla` path dependency for the real bindings and \
         rebuild with --features pjrt)",
        what
    )))
}

/// Element dtypes of the artifacts we exchange (subset of XLA's set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    F32,
    F64,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::F16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Marker trait mapping rust scalars onto [`ElementType`]s.
pub trait NativeType: Copy {
    const TY: ElementType;
}
impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}
impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}
impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
}
impl NativeType for i64 {
    const TY: ElementType = ElementType::S64;
}

/// Dense array shape (dims in elements, i64 like the real bindings).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side literal: dtype + dims + packed little-endian bytes.
/// Fully functional in the stub (the runtime's staging helpers and their
/// tests use it); only device transfer needs real PJRT.
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product::<usize>().max(1);
        if n * ty.byte_size() != data.len() {
            return Err(Error(format!(
                "literal byte length {} != shape {:?} x {:?}",
                data.len(),
                dims,
                ty
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.iter().map(|&d| d as i64).collect() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!("to_vec: literal is {:?}", self.ty)));
        }
        let n = self.data.len() / std::mem::size_of::<T>();
        let mut out: Vec<T> = Vec::with_capacity(n);
        // SAFETY: length checked at construction; T is a plain scalar.
        // Copy as bytes into the T-aligned destination — the u8 source
        // carries no alignment guarantee for T, so the typed direction
        // of this copy would be UB.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                n * std::mem::size_of::<T>(),
            );
            out.set_len(n);
        }
        Ok(out)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".into()))
    }

    /// Tuple literals only come back from device execution, which the stub
    /// cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple on a device result")
    }
}

/// Parsed HLO module (opaque in the stub; parsing needs XLA).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        unavailable(&format!(
            "parsing HLO text {}",
            path.as_ref().display()
        ))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu()")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_f32_bytes() {
        let v: Vec<f32> = vec![1.0, -2.5, 3.25];
        let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
        let l = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), v);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert_eq!(l.array_shape().unwrap().dims(), &[3i64]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[4],
            &[0u8; 8],
        )
        .is_err());
    }

    #[test]
    fn device_paths_report_stub() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline stub"), "{}", e);
    }
}
