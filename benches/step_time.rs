//! Bench: per-step wall-clock, MeZO vs fused-step vs FT, across the size
//! ladder (regenerates Table 23; `harness = false` — no criterion offline).
//!
//!     cargo bench --bench step_time
use mezo::exp::{tables, Ctx};

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let ctx = Ctx::new(quick).expect("runtime");
    tables::table23(&ctx).expect("table23");
}
