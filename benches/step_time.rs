//! Bench: zkernel microbench (always) + per-step wall-clock table
//! (pjrt builds; regenerates Table 23). `harness = false` — no criterion
//! offline.
//!
//!     cargo bench --bench step_time            # zkernel microbench
//!     cargo bench --bench step_time --features pjrt -- --full
//!
//! The microbench measures coords/sec for the blocked/threaded kernels
//! (fill, axpy_z, sgd_update, and the perturb+update composite a MeZO
//! step's parameter traffic reduces to) against the scalar per-coordinate
//! `z()` path the seed implementation used, at d ∈ {1e5, 1e6, 1e7} and
//! thread counts {1, 2, 4, 8}. A second group compares whole FZOO steps
//! against MezoSgd n-SPSA steps at matched forward-pass budgets (see
//! `fzoo_vs_mezo_bench`); a third sweeps sparse SensZOQ mask densities
//! {1%, 10%, 100%} against the dense composite (`mask_density_bench`);
//! a fourth pins the persistent worker pool against per-call
//! `std::thread::scope` spawns (`pool_vs_spawn_bench`); a fifth measures
//! shard-parallel replay and stepping at shard counts 1/2/4/8
//! (`shard_scaling_bench` — per-shard critical path, scatter/gather
//! overhead); a sixth sweeps the explicit SIMD dispatch tiers against the
//! scalar tier (`simd_dispatch_bench`); a seventh measures the MZW1
//! wire codec (encode/decode throughput of control vs bulk frames) and
//! the per-step overhead of driving a channel-transport worker fleet
//! instead of the dense optimizer (`wire_transport_bench`); an eighth
//! measures the block-quantized SensZOQ store — ns/coord of the
//! dequantize→update→requantize quant kernels against the dense f32
//! kernels at matched thread counts, plus the memory-per-replica table
//! (`quant_kernels_bench`); a ninth times the 4-pass composite at each
//! `MEZO_OBS` level to bound the observability tax — the acceptance gate
//! is < 2% at the default counters level (`obs_overhead_bench`), and the
//! run also drops a `Registry::render_text` Prometheus snapshot into
//! OBS_snapshot.prom. Results land
//! in BENCH_zkernel.json so the perf trajectory is tracked across PRs;
//! `scripts/bench_summary.py` distills per-group medians into the small
//! committed BENCH_summary.json.
//!
//! `MEZO_BENCH_QUICK=1` switches every group to a reduced size/rep grid —
//! the CI bench-smoke mode, which records the trajectory artifact per PR
//! without burning minutes on the d = 1e7 points.

use mezo::rng::GaussianStream;
use mezo::util::json::{obj, Json};
use mezo::zkernel::ZEngine;
use std::time::Instant;

/// Reduced-size quick mode (CI bench-smoke): `MEZO_BENCH_QUICK=1`.
fn quick() -> bool {
    std::env::var("MEZO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// The d grid: full sweeps 1e5..1e7, quick mode stops at 1e6.
fn sizes() -> Vec<usize> {
    if quick() {
        vec![100_000, 1_000_000]
    } else {
        vec![100_000, 1_000_000, 10_000_000]
    }
}

/// Median reps for a given d (halved-ish in quick mode).
fn reps_for(d: usize) -> usize {
    match (d, quick()) {
        (100_000, false) => 9,
        (100_000, true) => 5,
        (1_000_000, false) => 5,
        (1_000_000, true) => 3,
        _ => 3,
    }
}

/// Median-of-reps seconds for one invocation of `f`.
fn time<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// The seed implementation's scalar loops, kept as the baseline.
mod scalar {
    use super::GaussianStream;

    pub fn fill(stream: GaussianStream, theta: &mut [f32]) {
        for (j, o) in theta.iter_mut().enumerate() {
            *o = stream.z(j as u64);
        }
    }
    pub fn axpy(stream: GaussianStream, theta: &mut [f32], s: f32) {
        for (j, th) in theta.iter_mut().enumerate() {
            *th += s * stream.z(j as u64);
        }
    }
    pub fn sgd(stream: GaussianStream, theta: &mut [f32], lr: f32, g: f32, wd: f32) {
        for (j, th) in theta.iter_mut().enumerate() {
            let z = stream.z(j as u64);
            *th -= lr * (g * z + wd * *th);
        }
    }
}

struct Row {
    kernel: &'static str,
    d: usize,
    threads: usize,
    scalar_s: f64,
    kernel_s: f64,
}

impl Row {
    fn json(&self) -> Json {
        let per = |s: f64| if s > 0.0 { self.d as f64 / s } else { 0.0 };
        obj(vec![
            ("kernel", Json::from(self.kernel)),
            ("d", Json::from(self.d as f64)),
            ("threads", Json::from(self.threads as f64)),
            ("scalar_ns_per_coord", Json::from(self.scalar_s * 1e9 / self.d as f64)),
            ("kernel_ns_per_coord", Json::from(self.kernel_s * 1e9 / self.d as f64)),
            ("scalar_coords_per_sec", Json::from(per(self.scalar_s))),
            ("kernel_coords_per_sec", Json::from(per(self.kernel_s))),
            ("speedup", Json::from(self.scalar_s / self.kernel_s)),
        ])
    }
}

fn zkernel_bench() -> Vec<Row> {
    let stream = GaussianStream::new(0xBE7C);
    let (lr, g, wd, eps) = (1e-4f32, 0.37f32, 1e-5f32, 1e-3f32);
    let mut rows = Vec::new();
    for &d in &sizes() {
        let reps = reps_for(d);
        let mut theta = vec![0.01f32; d];
        // scalar baselines (single-threaded per-coordinate z(), pre-refactor)
        let sc_fill = time(reps, || scalar::fill(stream, &mut theta));
        let sc_axpy = time(reps, || scalar::axpy(stream, &mut theta, eps));
        let sc_sgd = time(reps, || scalar::sgd(stream, &mut theta, lr, g, wd));
        // perturb(+ε) + perturb(−2ε) + restore(+ε) + update: the 4 z-passes
        // of one in-place MeZO step
        let sc_step = time(reps, || {
            scalar::axpy(stream, &mut theta, eps);
            scalar::axpy(stream, &mut theta, -2.0 * eps);
            scalar::axpy(stream, &mut theta, eps);
            scalar::sgd(stream, &mut theta, lr, g, wd);
        });
        for &t in &[1usize, 2, 4, 8] {
            let eng = ZEngine::with_threads(t);
            let k_fill = time(reps, || eng.fill_z(stream, 0, &mut theta));
            rows.push(Row { kernel: "fill", d, threads: t, scalar_s: sc_fill, kernel_s: k_fill });
            let k_axpy = time(reps, || eng.axpy_z(stream, 0, &mut theta, eps));
            rows.push(Row { kernel: "axpy_z", d, threads: t, scalar_s: sc_axpy, kernel_s: k_axpy });
            let k_sgd = time(reps, || eng.sgd_update(stream, 0, &mut theta, lr, g, wd));
            rows.push(Row {
                kernel: "sgd_update",
                d,
                threads: t,
                scalar_s: sc_sgd,
                kernel_s: k_sgd,
            });
            let k_step = time(reps, || {
                eng.axpy_z(stream, 0, &mut theta, eps);
                eng.axpy_z(stream, 0, &mut theta, -2.0 * eps);
                eng.axpy_z(stream, 0, &mut theta, eps);
                eng.sgd_update(stream, 0, &mut theta, lr, g, wd);
            });
            rows.push(Row {
                kernel: "perturb+update",
                d,
                threads: t,
                scalar_s: sc_step,
                kernel_s: k_step,
            });
        }
        let best = rows
            .iter()
            .filter(|r| r.d == d && r.kernel == "perturb+update")
            .map(|r| r.scalar_s / r.kernel_s)
            .fold(0.0f64, f64::max);
        println!(
            "d={:>9}: scalar step {:>7.1} ms, best kernel speedup {:.2}x",
            d,
            sc_step * 1e3,
            best
        );
    }
    rows
}

/// FZOO vs MeZO n-SPSA at matched forward-pass budgets B. One FZOO step
/// runs B − 1 one-sided seeds (plus the unperturbed anchor); one MezoSgd
/// step runs B/2 two-point seeds — the same number of loss evaluations.
/// The loss closure is free (one array read), so what's measured is the
/// parameter traffic: FZOO's per-seed `perturb_into` staging + ONE fused
/// batched update, against MeZO's 3 in-place passes per seed + one fused
/// n-SPSA update. Results land in BENCH_zkernel.json under "fzoo_vs_mezo".
fn fzoo_vs_mezo_bench() -> Vec<Json> {
    use mezo::model::meta::TensorDesc;
    use mezo::model::params::ParamStore;
    use mezo::optim::fzoo::{Fzoo, FzooConfig};
    use mezo::optim::mezo::{MezoConfig, MezoSgd};

    let mut out = Vec::new();
    for &d in &sizes() {
        let reps = reps_for(d);
        let specs =
            vec![TensorDesc { name: "w".into(), shape: vec![d], dtype: "f32".into() }];
        let budgets: &[usize] = if quick() { &[8] } else { &[8, 16] };
        for &budget in budgets {
            let mut best = 0.0f64;
            for &t in &[1usize, 2, 4, 8] {
                let mut p = ParamStore::from_specs(specs.clone());
                let cfg = MezoConfig { lr: 1e-4, eps: 1e-3, n: budget / 2, ..Default::default() };
                let mut mz = MezoSgd::new(cfg, vec![0], 1);
                mz.engine = ZEngine::with_threads(t);
                let mezo_s = time(reps, || {
                    mz.step(&mut p, |p| Ok(p.data[0][0])).unwrap();
                });

                let mut p = ParamStore::from_specs(specs.clone());
                let cfg = FzooConfig { lr: 1e-4, eps: 1e-3, n: budget - 1, ..Default::default() };
                let mut fz = Fzoo::new(cfg, vec![0], 1);
                fz.engine = ZEngine::with_threads(t);
                let fzoo_s = time(reps, || {
                    fz.step(&mut p, |p| Ok(p.data[0][0])).unwrap();
                });

                best = best.max(mezo_s / fzoo_s);
                out.push(obj(vec![
                    ("d", Json::from(d as f64)),
                    ("threads", Json::from(t as f64)),
                    ("budget_fwd", Json::from(budget as f64)),
                    ("mezo_seeds", Json::from((budget / 2) as f64)),
                    ("fzoo_seeds", Json::from((budget - 1) as f64)),
                    ("mezo_step_s", Json::from(mezo_s)),
                    ("fzoo_step_s", Json::from(fzoo_s)),
                    ("fzoo_speedup", Json::from(mezo_s / fzoo_s)),
                ]));
            }
            println!(
                "d={:>9} B={:>2}: FZOO vs MeZO n-SPSA best step speedup {:.2}x",
                d, budget, best
            );
        }
    }
    out
}

/// Sparse SensZOQ mask-density sweep: the masked perturb+update composite
/// (3 masked axpy passes + 1 masked SGD update — a sparse in-place MeZO
/// step's parameter traffic) against the dense composite, at density ∈
/// {1%, 10%, 100%} and d ∈ {1e5, 1e6, 1e7}. Evenly-strided masks model a
/// scattered sensitive set (the masked kernels' hybrid z path stays on the
/// per-coordinate side below ~75% block occupancy); density 1.0 is the
/// full mask, whose cost should track the dense kernel. Results land in
/// BENCH_zkernel.json under "mask_density".
fn mask_density_bench() -> Vec<Json> {
    let stream = GaussianStream::new(0x5EED);
    let (lr, g, wd, eps) = (1e-4f32, 0.37f32, 1e-5f32, 1e-3f32);
    let mut out = Vec::new();
    for &d in &sizes() {
        let reps = reps_for(d);
        let mut theta = vec![0.01f32; d];
        for &density in &[0.01f64, 0.1, 1.0] {
            let stride = (1.0 / density).round() as usize;
            let idxs: Vec<u32> = (0..d as u32).step_by(stride).collect();
            let mut best = 0.0f64;
            for &t in &[1usize, 2, 4, 8] {
                let eng = ZEngine::with_threads(t);
                let dense_s = time(reps, || {
                    eng.axpy_z(stream, 0, &mut theta, eps);
                    eng.axpy_z(stream, 0, &mut theta, -2.0 * eps);
                    eng.axpy_z(stream, 0, &mut theta, eps);
                    eng.sgd_update(stream, 0, &mut theta, lr, g, wd);
                });
                let masked_s = time(reps, || {
                    eng.axpy_z_masked(stream, 0, &idxs, &mut theta, eps);
                    eng.axpy_z_masked(stream, 0, &idxs, &mut theta, -2.0 * eps);
                    eng.axpy_z_masked(stream, 0, &idxs, &mut theta, eps);
                    eng.sgd_update_masked(stream, 0, &idxs, &mut theta, lr, g, wd);
                });
                best = best.max(dense_s / masked_s);
                out.push(obj(vec![
                    ("kernel", Json::from("masked perturb+update")),
                    ("d", Json::from(d as f64)),
                    ("density", Json::from(density)),
                    ("masked_coords", Json::from(idxs.len() as f64)),
                    ("threads", Json::from(t as f64)),
                    ("dense_step_s", Json::from(dense_s)),
                    ("masked_step_s", Json::from(masked_s)),
                    ("speedup_vs_dense", Json::from(dense_s / masked_s)),
                ]));
            }
            println!(
                "d={:>9} density={:>4}%: best masked/dense step speedup {:.2}x",
                d,
                (density * 100.0) as u32,
                best
            );
        }
    }
    out
}

/// Persistent-pool vs per-call-spawn dispatch overhead: the same fused
/// axpy_z kernel (and the 4-pass perturb+update composite) driven once by
/// the pool dispatcher (`ZEngine::with_threads`) and once by the retained
/// `std::thread::scope` dispatcher (`ZEngine::with_threads_scoped`). The
/// arithmetic and chunking are identical — the delta IS the per-dispatch
/// cost of spawning + joining OS threads, which dominates at small d
/// (spawn is tens of µs; an axpy over 1e5 coords is comparable) and must
/// wash out at d = 1e7 where the kernel body dominates. Results land in
/// BENCH_zkernel.json under "pool_vs_spawn".
fn pool_vs_spawn_bench() -> Vec<Json> {
    let stream = GaussianStream::new(0xD15);
    let (lr, g, wd, eps) = (1e-4f32, 0.37f32, 1e-5f32, 1e-3f32);
    let mut out = Vec::new();
    for &d in &sizes() {
        // dispatch overhead needs more medians at small d, where one
        // kernel invocation is only ~100µs
        let reps = reps_for(d) * 2 + 1;
        let mut theta = vec![0.01f32; d];
        let mut best = 0.0f64;
        for &t in &[1usize, 2, 4, 8] {
            let pool_eng = ZEngine::with_threads(t);
            let spawn_eng = ZEngine::with_threads_scoped(t);
            // warm the pool so one-time worker growth stays out of the
            // measured reps
            pool_eng.axpy_z(stream, 0, &mut theta, eps);
            let pool_axpy = time(reps, || pool_eng.axpy_z(stream, 0, &mut theta, eps));
            let spawn_axpy = time(reps, || spawn_eng.axpy_z(stream, 0, &mut theta, eps));
            let step = |eng: ZEngine, theta: &mut [f32]| {
                eng.axpy_z(stream, 0, theta, eps);
                eng.axpy_z(stream, 0, theta, -2.0 * eps);
                eng.axpy_z(stream, 0, theta, eps);
                eng.sgd_update(stream, 0, theta, lr, g, wd);
            };
            let pool_step = time(reps, || step(pool_eng, &mut theta));
            let spawn_step = time(reps, || step(spawn_eng, &mut theta));
            best = best.max(spawn_step / pool_step);
            out.push(obj(vec![
                ("d", Json::from(d as f64)),
                ("threads", Json::from(t as f64)),
                ("spawn_axpy_s", Json::from(spawn_axpy)),
                ("pool_axpy_s", Json::from(pool_axpy)),
                ("axpy_dispatch_saved_us", Json::from((spawn_axpy - pool_axpy) * 1e6)),
                ("spawn_step_s", Json::from(spawn_step)),
                ("pool_step_s", Json::from(pool_step)),
                // 4 dispatches per perturb+update composite
                ("step_dispatch_saved_us", Json::from((spawn_step - pool_step) * 1e6)),
                ("pool_step_speedup", Json::from(spawn_step / pool_step)),
            ]));
        }
        println!("d={:>9}: best pool-vs-spawn step speedup {:.2}x", d, best);
    }
    out
}

/// Sharded replay + step scaling: a K-way ShardPlan turns one replay or
/// perturb+update pass into K independent shard-local passes that K
/// workers could own. Measured per (d, shards, threads): dense replay vs
/// the full in-process sharded replay (all K shards — the overhead view:
/// the same arithmetic routed through K× more dispatches), the MAX
/// per-shard time (the critical path a K-worker cluster would see — the
/// multi-node speedup model), scatter/gather cost, and the 4-pass
/// perturb+update composite dense vs sharded. Results land in
/// BENCH_zkernel.json under "shard_scaling".
fn shard_scaling_bench() -> Vec<Json> {
    use mezo::model::meta::TensorDesc;
    use mezo::model::params::ParamStore;
    use mezo::optim::mezo::StepRecord;
    use mezo::shard::{ShardPlan, ShardedStore};
    use mezo::storage::Trajectory;

    let (lr, g, wd, eps) = (1e-4f32, 0.37f32, 1e-5f32, 1e-3f32);
    let n_records = if quick() { 4usize } else { 8 };
    let shard_counts: &[usize] = if quick() { &[1, 4] } else { &[1, 2, 4, 8] };
    let thread_grid: &[usize] = if quick() { &[1, 4] } else { &[1, 4, 8] };
    let mut out = Vec::new();
    for &d in &sizes() {
        let reps = reps_for(d);
        // several tensors so shard cuts can be tensor-aligned
        let specs = vec![
            TensorDesc { name: "w1".into(), shape: vec![d / 2], dtype: "f32".into() },
            TensorDesc { name: "w2".into(), shape: vec![d / 4], dtype: "f32".into() },
            TensorDesc {
                name: "w3".into(),
                shape: vec![d - d / 2 - d / 4],
                dtype: "f32".into(),
            },
        ];
        let mut p0 = ParamStore::from_specs(specs);
        p0.init(1);
        let names = vec!["w1".to_string(), "w2".to_string(), "w3".to_string()];
        let mut traj = Trajectory::new(names);
        for i in 0..n_records as u64 {
            traj.records.push(StepRecord {
                seed: 0x5EED + i,
                pgrad: 0.05 * i as f32 - 0.15,
                lr: 1e-4,
            });
        }
        let stream = GaussianStream::new(0x5CA1E);
        for &t in thread_grid {
            let eng = ZEngine::with_threads(t);
            // dense baselines, shard-count independent
            let mut dense = p0.clone();
            let dense_replay_s = time(reps, || traj.replay_with(&eng, &mut dense));
            let step_dense = |p: &mut ParamStore| {
                let offsets = p.offsets.clone();
                for (buf, &off) in p.data.iter_mut().zip(&offsets) {
                    eng.axpy_z(stream, off, buf, eps);
                    eng.axpy_z(stream, off, buf, -2.0 * eps);
                    eng.axpy_z(stream, off, buf, eps);
                    eng.sgd_update(stream, off, buf, lr, g, wd);
                }
            };
            let mut pd = p0.clone();
            let step_dense_s = time(reps, || step_dense(&mut pd));
            for &k in shard_counts {
                let plan = ShardPlan::new(&p0, k).expect("plan");
                let manifest = plan.manifest();
                let scatter_s = time(reps, || {
                    let _ = ShardedStore::scatter(&plan, &p0).expect("scatter");
                });
                let mut sharded = ShardedStore::scatter(&plan, &p0).expect("scatter");
                let mut gathered = p0.clone();
                let gather_s = time(reps, || sharded.gather_into(&mut gathered).expect("gather"));
                let sharded_replay_s = time(reps, || {
                    traj.replay_sharded_with(&eng, &mut sharded, &manifest).expect("replay")
                });
                let shard_replay_max_s = (0..k)
                    .map(|ki| {
                        time(reps, || {
                            traj.replay_shard_with(&eng, &mut sharded, &manifest, ki)
                                .expect("replay shard")
                        })
                    })
                    .fold(0.0f64, f64::max);
                // the 4-pass in-place composite, shard-segment by segment
                let step_sharded = |p: &mut ParamStore| {
                    for shard in plan.shards() {
                        for seg in &shard.segments {
                            let off = p.offsets[seg.tensor];
                            let buf = &mut p.data[seg.tensor];
                            eng.axpy_z_shard(stream, off, seg.lo, seg.hi, buf, eps);
                            eng.axpy_z_shard(stream, off, seg.lo, seg.hi, buf, -2.0 * eps);
                            eng.axpy_z_shard(stream, off, seg.lo, seg.hi, buf, eps);
                            eng.sgd_update_shard(stream, off, seg.lo, seg.hi, buf, lr, g, wd);
                        }
                    }
                };
                let mut ps = p0.clone();
                let step_sharded_s = time(reps, || step_sharded(&mut ps));
                out.push(obj(vec![
                    ("d", Json::from(d as f64)),
                    ("shards", Json::from(k as f64)),
                    ("threads", Json::from(t as f64)),
                    ("records", Json::from(n_records as f64)),
                    ("dense_replay_s", Json::from(dense_replay_s)),
                    ("sharded_replay_s", Json::from(sharded_replay_s)),
                    ("shard_replay_max_s", Json::from(shard_replay_max_s)),
                    (
                        "critical_path_speedup",
                        Json::from(dense_replay_s / shard_replay_max_s),
                    ),
                    ("scatter_s", Json::from(scatter_s)),
                    ("gather_s", Json::from(gather_s)),
                    ("step_dense_s", Json::from(step_dense_s)),
                    ("step_sharded_s", Json::from(step_sharded_s)),
                ]));
                if t == thread_grid[thread_grid.len() - 1] {
                    println!(
                        "d={:>9} shards={}: critical-path replay speedup {:.2}x (t={})",
                        d,
                        k,
                        dense_replay_s / shard_replay_max_s,
                        t
                    );
                }
            }
        }
    }
    out
}

/// Explicit-SIMD tier sweep: every runnable tier (AVX-512 / AVX2 / NEON)
/// against the Scalar tier — the PR-4 unrolled `block_apply8!` path — on
/// the same engine, same thread count, same buffers. The tiers are pinned
/// bit-identical in the property suite, so the delta here is pure
/// instruction selection: vector width on the update bodies, plus the
/// vectorized splitmix/u-stage of z generation on AVX-512. Measured per
/// (tier, kernel, d, threads) for fill_z, axpy_z, sgd_update and the
/// 4-seed fzoo_update (the batched-update body with the highest arithmetic
/// density). Results land in BENCH_zkernel.json under "simd_dispatch";
/// `scripts/bench_summary.py` distills them into the committed
/// BENCH_summary.json trajectory.
fn simd_dispatch_bench() -> Vec<Json> {
    use mezo::zkernel::Tier;

    let stream = GaussianStream::new(0x51D);
    let (lr, g, wd, eps) = (1e-4f32, 0.37f32, 1e-5f32, 1e-3f32);
    let zs: Vec<(GaussianStream, f32)> =
        (0..4).map(|k| (GaussianStream::new(0x51D + 1 + k), 0.3 - 0.15 * k as f32)).collect();
    let thread_grid: &[usize] = if quick() { &[1, 4] } else { &[1, 4, 8] };
    let tiers: Vec<Tier> = Tier::available();
    let mut out = Vec::new();
    for &d in &sizes() {
        let reps = reps_for(d);
        let mut theta = vec![0.01f32; d];
        let mut best = 0.0f64;
        for &t in thread_grid {
            let base = ZEngine::with_threads_simd(t, Tier::Scalar);
            // warm the pool so one-time worker growth stays out of the reps
            base.axpy_z(stream, 0, &mut theta, eps);
            let sc_fill = time(reps, || base.fill_z(stream, 0, &mut theta));
            let sc_axpy = time(reps, || base.axpy_z(stream, 0, &mut theta, eps));
            let sc_sgd = time(reps, || base.sgd_update(stream, 0, &mut theta, lr, g, wd));
            let sc_fzoo = time(reps, || base.fzoo_update(&zs, 0, &mut theta, lr, wd));
            for &tier in &tiers {
                let eng = ZEngine::with_threads_simd(t, tier);
                for (kernel, scalar_s, tier_s) in [
                    ("fill_z", sc_fill, time(reps, || eng.fill_z(stream, 0, &mut theta))),
                    ("axpy_z", sc_axpy, time(reps, || eng.axpy_z(stream, 0, &mut theta, eps))),
                    (
                        "sgd_update",
                        sc_sgd,
                        time(reps, || eng.sgd_update(stream, 0, &mut theta, lr, g, wd)),
                    ),
                    (
                        "fzoo_update_n4",
                        sc_fzoo,
                        time(reps, || eng.fzoo_update(&zs, 0, &mut theta, lr, wd)),
                    ),
                ] {
                    if tier != Tier::Scalar && kernel != "fill_z" {
                        best = best.max(scalar_s / tier_s);
                    }
                    out.push(obj(vec![
                        ("kernel", Json::from(kernel)),
                        ("tier", Json::from(tier.name())),
                        ("d", Json::from(d as f64)),
                        ("threads", Json::from(t as f64)),
                        ("scalar_tier_s", Json::from(scalar_s)),
                        ("tier_s", Json::from(tier_s)),
                        ("tier_ns_per_coord", Json::from(tier_s * 1e9 / d as f64)),
                        ("speedup_vs_scalar_tier", Json::from(scalar_s / tier_s)),
                    ]));
                }
            }
        }
        let names: Vec<&str> = tiers.iter().map(|t| t.name()).collect();
        println!(
            "d={:>9} tiers={:?}: best SIMD update-body speedup vs scalar tier {:.2}x",
            d, names, best
        );
    }
    out
}

/// Bench 7: the MZW1 wire layer. Frame codec throughput for a tiny
/// control frame vs bulk shard-slice frames, then whole channel-fleet
/// MeZO steps against the dense optimizer at shard counts 1/2/4 — the
/// scatter/perturb/fetch/update round-trip tax the wire adds per step.
/// Results land in BENCH_zkernel.json under "wire_transport".
fn wire_transport_bench() -> Vec<Json> {
    use mezo::model::meta::TensorDesc;
    use mezo::model::params::ParamStore;
    use mezo::optim::mezo::{MezoConfig, MezoSgd};
    use mezo::wire::{channel_spawner, Fleet, FleetConfig, Msg};

    let mut out = Vec::new();

    // codec throughput: median seconds per encode / decode, batched so
    // the timer overhead is amortized over `inner` calls per sample
    let bulk_coords: &[usize] = if quick() { &[1 << 16] } else { &[1 << 16, 1 << 20] };
    let mut frames: Vec<(String, Msg, usize)> = vec![(
        "perturb_control".to_string(),
        Msg::Perturb { plan_digest: 0xD16E57, seed: 42, scale: 1e-3 },
        4096,
    )];
    for &n in bulk_coords {
        frames.push((
            format!("shard_slice_{}c", n),
            Msg::ShardSlice {
                plan_digest: 1,
                shard: 0,
                shard_digest: 2,
                segments: vec![vec![0.5f32; n]],
            },
            if quick() { 8 } else { 16 },
        ));
    }
    for (name, msg, inner) in &frames {
        let bytes = msg.encode();
        let reps = if quick() { 3 } else { 5 };
        let enc_s = time(reps, || {
            for _ in 0..*inner {
                let _ = msg.encode();
            }
        }) / *inner as f64;
        let dec_s = time(reps, || {
            for _ in 0..*inner {
                let _ = Msg::decode(&bytes).expect("decode");
            }
        }) / *inner as f64;
        let mb = bytes.len() as f64 / (1024.0 * 1024.0);
        out.push(obj(vec![
            ("frame", Json::from(name.as_str())),
            ("frame_bytes", Json::from(bytes.len() as f64)),
            ("encode_s", Json::from(enc_s)),
            ("decode_s", Json::from(dec_s)),
            ("encode_mb_per_sec", Json::from(mb / enc_s)),
            ("decode_mb_per_sec", Json::from(mb / dec_s)),
        ]));
    }

    // whole-step wire tax: channel fleet vs dense MezoSgd, same seeds,
    // trivial loss so the measurement is parameter traffic, not forwards
    let d_grid: &[usize] = if quick() { &[100_000] } else { &[100_000, 1_000_000] };
    let shard_counts: &[usize] = if quick() { &[1, 2] } else { &[1, 2, 4] };
    for &d in d_grid {
        let specs = vec![
            TensorDesc { name: "w1".into(), shape: vec![d / 2], dtype: "f32".into() },
            TensorDesc { name: "w2".into(), shape: vec![d / 4], dtype: "f32".into() },
            TensorDesc {
                name: "w3".into(),
                shape: vec![d - d / 2 - d / 4],
                dtype: "f32".into(),
            },
        ];
        let mut p0 = ParamStore::from_specs(specs);
        p0.init(1);
        let names = vec!["w1".to_string(), "w2".to_string(), "w3".to_string()];
        let reps = 3;
        let mcfg = MezoConfig { lr: 1e-4, eps: 1e-3, ..MezoConfig::default() };
        let mut pd = p0.clone();
        let mut opt = MezoSgd::new(mcfg, vec![0, 1, 2], 7);
        let dense_s = time(reps, || {
            opt.step(&mut pd, |p| Ok(p.data[0][0])).expect("dense step");
        });
        for &k in shard_counts {
            let fcfg = FleetConfig {
                lr: 1e-4,
                eps: 1e-3,
                weight_decay: 0.0,
                n: 1,
                max_retries: 3,
            };
            let mut fleet = Fleet::new(&p0, k, names.clone(), 7, fcfg, channel_spawner(None))
                .expect("fleet");
            let fleet_s = time(reps, || {
                fleet.step(|p| Ok(p.data[0][0])).expect("fleet step");
            });
            fleet.shutdown();
            out.push(obj(vec![
                ("d", Json::from(d as f64)),
                ("shards", Json::from(k as f64)),
                ("dense_step_s", Json::from(dense_s)),
                ("fleet_step_s", Json::from(fleet_s)),
                ("wire_overhead_x", Json::from(fleet_s / dense_s)),
            ]));
        }
    }
    out
}

/// Bench 8: the block-quantized SensZOQ store. Each quant kernel
/// invocation dequantizes a block (codes·scale), applies the identical
/// dense update body, and requantizes in place — the measured delta vs
/// the dense f32 kernel at the same thread count IS the quantization
/// tax per coordinate. Measured per (d, bits, threads): axpy_z and
/// sgd_update dense vs quant (ns/coord and the tax ratio), the 4-pass
/// perturb+update composite, and the memory-per-replica table
/// (`QuantStore::bytes()` against 4·n_params — the reason the store
/// exists: int8 holds ~3.8x more tenant replicas per byte, int4 ~7x).
/// Results land in BENCH_zkernel.json under "quant_kernels".
fn quant_kernels_bench() -> Vec<Json> {
    use mezo::model::meta::TensorDesc;
    use mezo::model::params::ParamStore;
    use mezo::model::quant::QuantStore;
    use mezo::zkernel::QBits;

    let stream = GaussianStream::new(0x0B17);
    let (lr, g, wd, eps) = (1e-4f32, 0.37f32, 1e-5f32, 1e-3f32);
    let thread_grid: &[usize] = if quick() { &[1, 4] } else { &[1, 4, 8] };
    let mut out = Vec::new();
    for &d in &sizes() {
        let reps = reps_for(d);
        let specs =
            vec![TensorDesc { name: "w".into(), shape: vec![d], dtype: "f32".into() }];
        let mut p = ParamStore::from_specs(specs);
        p.init(3);
        let dense_bytes = 4 * p.n_params();
        for bits in [QBits::Int8, QBits::Int4] {
            let mut q = QuantStore::quantize(&p, bits, None).expect("quantize");
            let compression = dense_bytes as f64 / q.bytes() as f64;
            let mut best_tax = f64::INFINITY;
            for &t in thread_grid {
                let eng = ZEngine::with_threads(t);
                // warm the pool so one-time worker growth stays out of
                // the measured reps
                eng.axpy_z(stream, 0, &mut p.data[0], eps);
                let dense_axpy = time(reps, || eng.axpy_z(stream, 0, &mut p.data[0], eps));
                let quant_axpy =
                    time(reps, || eng.axpy_z_quant(stream, 0, q.view_mut(0), eps));
                let dense_sgd =
                    time(reps, || eng.sgd_update(stream, 0, &mut p.data[0], lr, g, wd));
                let quant_sgd =
                    time(reps, || eng.sgd_update_quant(stream, 0, q.view_mut(0), lr, g, wd));
                let dense_step = time(reps, || {
                    eng.axpy_z(stream, 0, &mut p.data[0], eps);
                    eng.axpy_z(stream, 0, &mut p.data[0], -2.0 * eps);
                    eng.axpy_z(stream, 0, &mut p.data[0], eps);
                    eng.sgd_update(stream, 0, &mut p.data[0], lr, g, wd);
                });
                let quant_step = time(reps, || {
                    eng.axpy_z_quant(stream, 0, q.view_mut(0), eps);
                    eng.axpy_z_quant(stream, 0, q.view_mut(0), -2.0 * eps);
                    eng.axpy_z_quant(stream, 0, q.view_mut(0), eps);
                    eng.sgd_update_quant(stream, 0, q.view_mut(0), lr, g, wd);
                });
                best_tax = best_tax.min(quant_step / dense_step);
                out.push(obj(vec![
                    ("d", Json::from(d as f64)),
                    (
                        "bits",
                        Json::from(match bits {
                            QBits::Int8 => 8.0,
                            QBits::Int4 => 4.0,
                        }),
                    ),
                    ("threads", Json::from(t as f64)),
                    ("dense_axpy_ns_per_coord", Json::from(dense_axpy * 1e9 / d as f64)),
                    ("quant_axpy_ns_per_coord", Json::from(quant_axpy * 1e9 / d as f64)),
                    ("dense_sgd_ns_per_coord", Json::from(dense_sgd * 1e9 / d as f64)),
                    ("quant_sgd_ns_per_coord", Json::from(quant_sgd * 1e9 / d as f64)),
                    ("dense_step_s", Json::from(dense_step)),
                    ("quant_step_s", Json::from(quant_step)),
                    ("quant_step_tax_x", Json::from(quant_step / dense_step)),
                    ("store_bytes", Json::from(q.bytes() as f64)),
                    ("dense_bytes", Json::from(dense_bytes as f64)),
                    ("replica_compression_x", Json::from(compression)),
                ]));
            }
            println!(
                "d={:>9} {:?}: {:.2}x bytes/replica saved, best quant step tax {:.2}x",
                d, bits, compression, best_tax
            );
        }
    }
    out
}

/// Bench 9: the observability tax. The 4-pass perturb+update composite
/// (the hot path every instrumented kernel entry point rides) timed at
/// each `MEZO_OBS` level via `obs::set_level` — off, counters (the
/// default), spans — with each row reporting percent overhead against
/// the off baseline at the same (d, threads). The acceptance gate is
/// < 2% at the counters level; `scripts/bench_summary.py` folds the
/// per-level medians into the committed trajectory as
/// `obs_overhead_pct`. The process level is restored afterwards.
/// Results land in BENCH_zkernel.json under "obs_overhead".
fn obs_overhead_bench() -> Vec<Json> {
    use mezo::obs::{self, Level};

    let stream = GaussianStream::new(0x0B5);
    let (lr, g, wd, eps) = (1e-4f32, 0.37f32, 1e-5f32, 1e-3f32);
    let thread_grid: &[usize] = if quick() { &[1, 4] } else { &[1, 4, 8] };
    let levels =
        [("off", Level::Off), ("counters", Level::Counters), ("spans", Level::Spans)];
    let prev = obs::level();
    let mut out = Vec::new();
    for &d in &sizes() {
        // the deltas are tiny fractions of a step: extra medians, like
        // the pool-dispatch bench
        let reps = reps_for(d) * 2 + 1;
        let mut theta = vec![0.01f32; d];
        let mut worst = 0.0f64;
        for &t in thread_grid {
            let eng = ZEngine::with_threads(t);
            // warm the pool so one-time worker growth stays out of the reps
            eng.axpy_z(stream, 0, &mut theta, eps);
            let mut level_s = Vec::with_capacity(levels.len());
            for &(_, lv) in &levels {
                obs::set_level(lv);
                level_s.push(time(reps, || {
                    eng.axpy_z(stream, 0, &mut theta, eps);
                    eng.axpy_z(stream, 0, &mut theta, -2.0 * eps);
                    eng.axpy_z(stream, 0, &mut theta, eps);
                    eng.sgd_update(stream, 0, &mut theta, lr, g, wd);
                }));
            }
            let off_s = level_s[0];
            for (&(name, _), &s) in levels.iter().zip(&level_s) {
                let pct = (s / off_s - 1.0) * 100.0;
                if name == "counters" {
                    worst = worst.max(pct);
                }
                out.push(obj(vec![
                    ("d", Json::from(d as f64)),
                    ("threads", Json::from(t as f64)),
                    ("level", Json::from(name)),
                    ("step_s", Json::from(s)),
                    ("off_step_s", Json::from(off_s)),
                    ("overhead_pct", Json::from(pct)),
                ]));
            }
        }
        println!("d={:>9}: worst counters-level obs overhead {:+.2}%", d, worst);
    }
    obs::set_level(prev);
    out
}

fn main() {
    let rows = zkernel_bench();
    let fzoo_rows = fzoo_vs_mezo_bench();
    let mask_rows = mask_density_bench();
    let pool_rows = pool_vs_spawn_bench();
    let shard_rows = shard_scaling_bench();
    let simd_rows = simd_dispatch_bench();
    let wire_rows = wire_transport_bench();
    let quant_rows = quant_kernels_bench();
    let obs_rows = obs_overhead_bench();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let report = obj(vec![
        ("bench", Json::from("zkernel")),
        ("hardware_threads", Json::from(hw as f64)),
        ("quick_mode", Json::from(quick())),
        ("rows", Json::Arr(rows.iter().map(Row::json).collect())),
        ("fzoo_vs_mezo", Json::Arr(fzoo_rows)),
        ("mask_density", Json::Arr(mask_rows)),
        ("pool_vs_spawn", Json::Arr(pool_rows)),
        ("shard_scaling", Json::Arr(shard_rows)),
        ("simd_dispatch", Json::Arr(simd_rows)),
        ("wire_transport", Json::Arr(wire_rows)),
        ("quant_kernels", Json::Arr(quant_rows)),
        ("obs_overhead", Json::Arr(obs_rows)),
    ]);
    std::fs::write("BENCH_zkernel.json", report.to_string()).expect("write BENCH_zkernel.json");
    println!("wrote BENCH_zkernel.json ({} rows)", rows.len());
    // the live-metrics snapshot of everything the bench run just did —
    // CI bench-smoke uploads this alongside the JSON trajectory
    std::fs::write("OBS_snapshot.prom", mezo::obs::Registry::render_text())
        .expect("write OBS_snapshot.prom");
    println!("wrote OBS_snapshot.prom");

    #[cfg(feature = "pjrt")]
    {
        use mezo::exp::{tables, Ctx};
        let quick = !std::env::args().any(|a| a == "--full");
        if std::env::args().any(|a| a == "--zkernel-only") {
            return;
        }
        let ctx = Ctx::new(quick).expect("runtime");
        tables::table23(&ctx).expect("table23");
    }
}
