//! Bench harness: regenerate every paper table/figure end to end.
//!
//!     cargo bench --bench tables                  # quick: memory tables only
//!     cargo bench --bench tables -- table1        # one exhibit
//!     cargo bench --bench tables -- --full all    # full budgets
use mezo::exp::{tables, Ctx};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let id = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "table22".to_string());
    let ctx = Ctx::new(!full).expect("runtime");
    tables::run(&ctx, &id, "ar", "tiny").expect("experiment");
}
