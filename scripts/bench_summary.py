#!/usr/bin/env python3
"""Distill BENCH_zkernel.json into a small committed trajectory summary.

The full microbench report is hundreds of rows (every kernel x d x threads
x tier point). Committing it verbatim would churn on every run; committing
nothing loses the perf trajectory. This script reduces each bench group to
its per-(kernel, tier) median ns/element over the reduced CI grid, so the
committed BENCH_summary.json is a handful of stable, comparable numbers.

Usage:
    python3 scripts/bench_summary.py BENCH_zkernel.json BENCH_summary.json \
        [BENCH_serving.json]

The optional third input is the multi-tenant serving report written by
`examples/serve_scale.rs`; its per-capacity rows fold in as
`serving_*` keys (hit rate, materializations/sec, p50/p99 latency) plus
the run's bitwise gate verdict.

CI (bench-smoke) regenerates the summary from its quick-mode run and diffs
it against the committed file — report-only, because CI runner timings
drift; the diff output is the signal, updating the committed file is a
deliberate act in a PR. Stdlib only; keys sorted; values rounded to 2
decimals so sub-noise drift doesn't show up as churn.
"""

import json
import statistics
import sys


def _median_ns(rows, ns_field, group_keys):
    """Median of `ns_field` per distinct group_keys tuple -> flat dict."""
    buckets = {}
    for row in rows:
        key = "/".join(str(row[k]) for k in group_keys)
        buckets.setdefault(key, []).append(float(row[ns_field]))
    return {k: round(statistics.median(v), 2) for k, v in sorted(buckets.items())}


def summarize(report):
    """Reduce a BENCH_zkernel.json report dict to the committed summary."""
    summary = {
        "source": "scripts/bench_summary.py",
        "quick_mode": report.get("quick_mode"),
        "hardware_threads": report.get("hardware_threads"),
    }
    # main kernel rows: median kernel-path ns/coord per kernel
    if report.get("rows"):
        summary["kernel_ns_per_coord"] = _median_ns(
            report["rows"], "kernel_ns_per_coord", ["kernel"]
        )
    # SIMD tiers: median ns/coord per (kernel, tier) — the trajectory the
    # ISSUE 6 acceptance reads (explicit-SIMD update bodies vs the scalar
    # tier at large d)
    if report.get("simd_dispatch"):
        summary["simd_ns_per_coord"] = _median_ns(
            report["simd_dispatch"], "tier_ns_per_coord", ["kernel", "tier"]
        )
        speedups = _median_ns(
            report["simd_dispatch"], "speedup_vs_scalar_tier", ["kernel", "tier"]
        )
        summary["simd_speedup_vs_scalar_tier"] = speedups
    # pool dispatch: median per-step microseconds saved per thread count
    if report.get("pool_vs_spawn"):
        summary["pool_step_dispatch_saved_us"] = _median_ns(
            report["pool_vs_spawn"], "step_dispatch_saved_us", ["threads"]
        )
    # masked kernels: median speedup vs dense per density
    if report.get("mask_density"):
        summary["masked_speedup_vs_dense"] = _median_ns(
            report["mask_density"], "speedup_vs_dense", ["density"]
        )
    # MZW1 wire layer: median codec throughput per frame shape, and the
    # per-step fleet-vs-dense overhead per (d, shards)
    if report.get("wire_transport"):
        codec = [r for r in report["wire_transport"] if "frame" in r]
        fleet = [r for r in report["wire_transport"] if "wire_overhead_x" in r]
        if codec:
            summary["wire_decode_mb_per_sec"] = _median_ns(
                codec, "decode_mb_per_sec", ["frame"]
            )
        if fleet:
            summary["wire_step_overhead_x"] = _median_ns(
                fleet, "wire_overhead_x", ["d", "shards"]
            )
    # quantized SensZOQ store: median quant-vs-dense step tax per bit
    # width and thread count, and the bytes-per-replica compression the
    # store buys (constant per bit width, medianed for free)
    if report.get("quant_kernels"):
        summary["quant_step_tax_x"] = _median_ns(
            report["quant_kernels"], "quant_step_tax_x", ["bits", "threads"]
        )
        summary["quant_replica_compression_x"] = _median_ns(
            report["quant_kernels"], "replica_compression_x", ["bits"]
        )
    # observability tax: median percent overhead of the 4-pass composite
    # vs the MEZO_OBS=0 baseline, per level — the "counters" entry is the
    # < 2% acceptance number (default-level tax)
    if report.get("obs_overhead"):
        summary["obs_overhead_pct"] = _median_ns(
            report["obs_overhead"], "overhead_pct", ["level"]
        )
    # FZOO vs MeZO at matched budgets: median step speedup per budget
    if report.get("fzoo_vs_mezo"):
        summary["fzoo_speedup_vs_mezo"] = _median_ns(
            report["fzoo_vs_mezo"], "fzoo_speedup", ["budget_fwd"]
        )
    return summary


def fold_serving(summary, serving):
    """Fold a BENCH_serving.json report (examples/serve_scale.rs) into the
    summary: one value per cache capacity for each headline metric, plus
    the bitwise-transparency verdict the run exits on."""
    rows = serving.get("rows") or []
    if rows:
        by_cap = lambda field: {
            str(r["capacity"]): round(float(r[field]), 4) for r in rows
        }
        summary["serving_cache_hit_rate"] = by_cap("hit_rate")
        summary["serving_materializations_per_sec"] = by_cap(
            "materializations_per_sec"
        )
        summary["serving_p50_ms"] = by_cap("p50_ms")
        summary["serving_p99_ms"] = by_cap("p99_ms")
    summary["serving_bitwise_ok"] = serving.get("bitwise_ok")
    summary["serving_n_users"] = serving.get("n_users")
    return summary


def main(argv):
    if len(argv) not in (3, 4):
        print(__doc__.strip().splitlines()[0], file=sys.stderr)
        print(
            "usage: bench_summary.py BENCH_zkernel.json BENCH_summary.json"
            " [BENCH_serving.json]",
            file=sys.stderr,
        )
        return 2
    with open(argv[1]) as f:
        report = json.load(f)
    summary = summarize(report)
    if len(argv) == 4:
        with open(argv[3]) as f:
            fold_serving(summary, json.load(f))
    with open(argv[2], "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
        f.write("\n")
    print("wrote {} ({} groups)".format(argv[2], len(summary)))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
