#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): style gates + build + tests + docs gate,
# then the kernel bit-identity tests re-run under an explicit thread-count
# matrix via the engine's MEZO_THREADS knob. The in-test matrix
# (ZEngine::with_threads at 1/2/8) covers explicitly-constructed engines;
# this loop additionally pins every ZEngine::default() path (optimizers,
# replay, staging) at each process-default thread count, so a determinism
# regression fails the gate rather than only the default configuration.
#
# CI (.github/workflows/ci.yml) runs THIS script — local verify and CI
# stay one script. The fmt/clippy gates run first so style failures fail
# fast, are hard failures wherever the components exist, and skip with a
# notice on the bare offline cargo image, which ships neither. The CI
# verify job sets MEZO_SKIP_LINT=1 because its dedicated lint job is the
# one clippy/fmt run — no duplicated compile.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${MEZO_SKIP_LINT:-0}" = "1" ]; then
    echo "verify: MEZO_SKIP_LINT=1, fmt/clippy enforced elsewhere"
else
    # root package only: the vendored workspace stubs (vendor/anyhow,
    # vendor/xla-stub) mirror upstream layout and are not fmt-gated
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt -- --check
    else
        echo "verify: rustfmt unavailable, skipping format gate"
    fi
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy --all-targets -- -D warnings
    else
        echo "verify: clippy unavailable, skipping lint gate"
    fi
fi

cargo build --release
cargo test -q
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

for t in 1 2 8; do
    echo "== determinism matrix: MEZO_THREADS=$t =="
    MEZO_THREADS=$t cargo test -q --release --lib zkernel
    # shard bit-identity: plan/scatter/gather unit tests plus every
    # *shard* optimizer/storage test, so shard-determinism regressions on
    # the ZEngine::default() paths fail the gate
    MEZO_THREADS=$t cargo test -q --release --lib shard
    MEZO_THREADS=$t cargo test -q --release --test properties
done
echo "verify: OK"
