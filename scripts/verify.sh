#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): build + tests + docs gate, then the kernel
# bit-identity tests re-run under an explicit thread-count matrix via the
# engine's MEZO_THREADS knob. The in-test matrix (ZEngine::with_threads at
# 1/2/8) covers explicitly-constructed engines; this loop additionally
# pins every ZEngine::default() path (optimizers, replay, staging) at each
# process-default thread count, so a determinism regression fails the gate
# rather than only the default configuration.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

for t in 1 2 8; do
    echo "== determinism matrix: MEZO_THREADS=$t =="
    MEZO_THREADS=$t cargo test -q --release --lib zkernel
    MEZO_THREADS=$t cargo test -q --release --test properties
done
echo "verify: OK"
