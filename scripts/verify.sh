#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): style gates + build + tests + docs gate,
# then the kernel bit-identity tests re-run under an explicit
# MEZO_THREADS x MEZO_SIMD matrix. The in-test matrices
# (ZEngine::with_threads at 1/2/8, ZEngine::with_threads_simd over
# Tier::available()) cover explicitly-constructed engines; this loop
# additionally pins every ZEngine::default() path (optimizers, replay,
# staging) at each process-default thread count AND each process-default
# SIMD tier, so a determinism regression fails the gate rather than only
# the default configuration.
#
# SIMD legs are capability-gated: `auto` and `scalar` always run (scalar
# is the always-available fallback tier and MUST stay green everywhere);
# `avx2` runs when the CPU reports it; `avx512` additionally needs
# avx512dq and a toolchain >= 1.89 (the build probe that enables the
# AVX-512 intrinsics); `neon` runs on aarch64. A leg that cannot run on
# this host is skipped with a notice — forcing it would just panic at
# Tier::active() by design (MEZO_SIMD refuses silent fallback).
#
# CI (.github/workflows/ci.yml) runs THIS script — local verify and CI
# stay one script. The fmt/clippy gates run first so style failures fail
# fast, are hard failures wherever the components exist, and skip with a
# notice on the bare offline cargo image, which ships neither. The CI
# verify job sets MEZO_SKIP_LINT=1 because its dedicated lint job is the
# one clippy/fmt run — no duplicated compile.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${MEZO_SKIP_LINT:-0}" = "1" ]; then
    echo "verify: MEZO_SKIP_LINT=1, fmt/clippy enforced elsewhere"
else
    # root package only: the vendored workspace stubs (vendor/anyhow,
    # vendor/xla-stub) mirror upstream layout and are not fmt-gated
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt -- --check
    else
        echo "verify: rustfmt unavailable, skipping format gate"
    fi
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy --all-targets -- -D warnings
    else
        echo "verify: clippy unavailable, skipping lint gate"
    fi
fi

cargo build --release
cargo test -q
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# ---- capability-gated MEZO_SIMD legs -----------------------------------
simd_legs="auto scalar"
arch="$(uname -m)"
cpu_has() { grep -qw "$1" /proc/cpuinfo 2>/dev/null; }
rustc_minor() { rustc --version 2>/dev/null | sed -n 's/^rustc 1\.\([0-9]*\)\..*/\1/p'; }
if [ "$arch" = "x86_64" ]; then
    if cpu_has avx2; then
        simd_legs="$simd_legs avx2"
    else
        echo "verify: CPU lacks avx2, skipping MEZO_SIMD=avx2 leg"
    fi
    minor="$(rustc_minor)"
    if cpu_has avx512f && cpu_has avx512dq && [ -n "$minor" ] && [ "$minor" -ge 89 ]; then
        simd_legs="$simd_legs avx512"
    else
        echo "verify: avx512 leg needs avx512f+avx512dq and rustc >= 1.89, skipping"
    fi
elif [ "$arch" = "aarch64" ]; then
    # NEON is baseline on aarch64
    simd_legs="$simd_legs neon"
fi
echo "verify: MEZO_SIMD legs: $simd_legs"

for t in 1 2 8; do
    for s in $simd_legs; do
        echo "== determinism matrix: MEZO_THREADS=$t MEZO_SIMD=$s =="
        MEZO_THREADS=$t MEZO_SIMD=$s cargo test -q --release --lib zkernel
        # shard bit-identity: plan/scatter/gather unit tests plus every
        # *shard* optimizer/storage test, so shard-determinism regressions
        # on the ZEngine::default() paths fail the gate
        MEZO_THREADS=$t MEZO_SIMD=$s cargo test -q --release --lib shard
        # MZW1 wire layer: frame codec + transports + worker + fleet unit
        # tests, then the full property suite (frame fuzzing included) and
        # the churn/chaos fleet suite — scatter/step/replay/gather must
        # stay bitwise dense at every thread count and SIMD tier, with
        # workers being killed and respawned mid-command
        MEZO_THREADS=$t MEZO_SIMD=$s cargo test -q --release --lib wire
        MEZO_THREADS=$t MEZO_SIMD=$s cargo test -q --release --test properties
        MEZO_THREADS=$t MEZO_SIMD=$s cargo test -q --release --test churn
        # multi-tenant serving cache: bitwise transparency across
        # hit/miss/evict and every replay mode must hold at each
        # process-default thread count and SIMD tier
        MEZO_THREADS=$t MEZO_SIMD=$s cargo test -q --release --test serving
        # quantized (SensZOQ) store: round-trips within the pinned block
        # bound, and masked-coordinate bit-identity with the dense path
        # through kernels, stepping, replay and serving
        MEZO_THREADS=$t MEZO_SIMD=$s cargo test -q --release --test quant
        # observability neutrality: with full span timing enabled,
        # dense/masked/shard/quant stepping, replay and serving must stay
        # to_bits()-identical to MEZO_OBS=0 (the suite flips levels
        # in-process via obs::set_level and compares)
        MEZO_THREADS=$t MEZO_SIMD=$s MEZO_OBS=2 \
            cargo test -q --release --test obs
    done
done

# serving example smoke: tiny Zipf population per thread count; the
# example exits non-zero if any served store drifts bitwise from a fresh
# dense replay, and writes BENCH_serving.json as a side effect
for t in 1 2 8; do
    echo "== serving smoke: MEZO_THREADS=$t =="
    MEZO_THREADS=$t MEZO_SERVE_USERS=64 MEZO_SERVE_REQS=256 MEZO_BENCH_QUICK=1 \
        cargo run -q --release --example serve_scale
done
echo "verify: OK"
