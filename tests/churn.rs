//! Churn/chaos integration suite for the MZW1 shard fleet
//! (`mezo::wire`): the acceptance pin is that scatter → per-worker
//! step/replay over the wire → gather is `to_bits()`-identical to the
//! dense path for shard counts 1/2/4 — *including* while workers are
//! being killed and respawned mid-command.
//!
//! Chaos is injected at the transport layer: a `Chaos` wrapper around
//! the in-process channel transport fails a scripted recv with
//! `Disconnected` (worker "killed") or `Timeout` (coordinator deadline
//! fired, reply discarded), which drives the fleet's respawn +
//! checkpoint/command-log recovery path. One test kills a *real*
//! `mezo-worker` child process mid-run over TCP — that test doubles as
//! the CI fleet leg (coordinator + several worker processes).
//!
//! Run under the usual matrix: `MEZO_THREADS=1/2/8 cargo test --test
//! churn` (scripts/verify.sh does).

use anyhow::Result;
use mezo::model::meta::TensorDesc;
use mezo::model::params::ParamStore;
use mezo::optim::mezo::{MezoConfig, MezoSgd, StepRecord};
use mezo::rng::Pcg;
use mezo::storage::Trajectory;
use mezo::wire::{
    channel_pair, channel_spawner, Fleet, FleetConfig, Msg, ShardWorker, SpawnFn, Transport,
    WireError,
};
use std::sync::{Arc, Mutex};
use std::time::Duration;

// ---------------------------------------------------------------- fixtures

/// A small store with enough tensors that shard cuts land mid-tensor.
fn store(lens: &[usize], seed: u64) -> ParamStore {
    let specs = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| TensorDesc { name: format!("t{}", i), shape: vec![n], dtype: "f32".into() })
        .collect();
    let mut p = ParamStore::from_specs(specs);
    p.init(seed);
    p
}

/// Every value of every tensor, as raw bits — the equality the suite
/// pins is bitwise, not approximate.
fn bits(p: &ParamStore) -> Vec<u32> {
    p.data.iter().flatten().map(|x| x.to_bits()).collect()
}

/// The shared loss closure: deterministic, order-stable summation, so
/// dense and fleet forwards see bit-identical losses on bit-identical
/// parameters.
fn quad(p: &ParamStore) -> f32 {
    p.data.iter().flatten().map(|&x| x * x).sum()
}

/// Dense reference: `MezoSgd` (Sgd flavor) with the hyperparameters the
/// fleet carries, same master seed, same loss.
fn dense_steps(
    p0: &ParamStore,
    trainable: &[usize],
    master_seed: u64,
    cfg: &FleetConfig,
    steps: usize,
) -> (ParamStore, Vec<StepRecord>) {
    let mcfg = MezoConfig {
        lr: cfg.lr,
        eps: cfg.eps,
        weight_decay: cfg.weight_decay,
        n: cfg.n,
        ..MezoConfig::default()
    };
    let mut p = p0.clone();
    let mut opt = MezoSgd::new(mcfg, trainable.to_vec(), master_seed);
    for _ in 0..steps {
        opt.step(&mut p, |p| Ok(quad(p))).expect("dense step");
    }
    (p, opt.history.clone())
}

/// A synthetic but realistic `(seed, pgrad, lr)` log.
fn synth_log(trainable: &[&str], n_records: usize, seed: u64) -> Trajectory {
    let mut rng = Pcg::new(seed);
    let mut log = Trajectory::new(trainable.iter().map(|s| s.to_string()).collect());
    for _ in 0..n_records {
        log.records.push(StepRecord {
            seed: rng.next_u64(),
            pgrad: rng.normal_f32(0.0, 1.0),
            lr: 1e-2,
        });
    }
    log
}

// ------------------------------------------------------------ chaos layer

/// What a scripted fault injects on its chosen recv.
#[derive(Clone, Copy)]
enum Fault {
    /// connection dropped — the worker was killed
    Kill,
    /// coordinator read deadline fired; the reply is discarded with the
    /// transport, exercising the timeout → respawn → retry path
    Timeout,
}

/// A transport wrapper that fails its `at`-th recv (1-based) with the
/// scripted fault. Dropping it (which the fleet's respawn does) drops
/// the inner channel end, so the worker thread behind it really dies.
struct Chaos {
    inner: Box<dyn Transport>,
    fault: Option<(usize, Fault)>,
    recvs: usize,
}

impl Transport for Chaos {
    fn send(&mut self, msg: &Msg) -> Result<(), WireError> {
        self.inner.send(msg)
    }
    fn recv(&mut self) -> Result<Msg, WireError> {
        self.recvs += 1;
        if let Some((at, fault)) = self.fault {
            if self.recvs == at {
                return Err(match fault {
                    Fault::Kill => WireError::Disconnected,
                    Fault::Timeout => WireError::Timeout,
                });
            }
        }
        self.inner.recv()
    }
}

/// A channel spawner with a fault schedule: entry `(k, at, fault)` arms
/// the *next* transport spawned for shard `k` to fail its `at`-th recv.
/// Respawned transports are clean unless the schedule has another entry
/// for that shard, so recovery itself can be made to fail and recover.
fn chaos_spawner(schedule: Vec<(usize, usize, Fault)>) -> SpawnFn {
    let mut base = channel_spawner(Some(Duration::from_secs(30)));
    let pending = Arc::new(Mutex::new(schedule));
    Box::new(move |k| {
        let inner = base(k)?;
        let fault = {
            let mut p = pending.lock().unwrap();
            p.iter().position(|f| f.0 == k).map(|i| {
                let (_, at, fault) = p.remove(i);
                (at, fault)
            })
        };
        Ok(Box::new(Chaos { inner, fault, recvs: 0 }) as Box<dyn Transport>)
    })
}

// ----------------------------------------------------- calm-water pins

/// Scatter → distributed MeZO stepping → gather equals the dense
/// optimizer bit for bit, for 1 / 2 / 4 shards (K=1 is the degenerate
/// single-worker fleet), including the recorded history.
#[test]
fn fleet_stepping_is_bitwise_dense_for_shards_1_2_4() {
    let p0 = store(&[7, 64, 3, 33], 11);
    let cfg = FleetConfig { lr: 1e-2, eps: 1e-3, weight_decay: 0.1, n: 2, max_retries: 3 };
    let (dense, dense_hist) = dense_steps(&p0, &[0, 1, 3], 42, &cfg, 3);
    for k in [1usize, 2, 4] {
        let trainable = vec!["t0".to_string(), "t1".to_string(), "t3".to_string()];
        let mut fleet =
            Fleet::new(&p0, k, trainable, 42, cfg, channel_spawner(Some(Duration::from_secs(30))))
                .expect("fleet construction");
        for _ in 0..3 {
            let info = fleet.step(|p| Ok(quad(p))).expect("fleet step");
            assert_eq!(info.forward_passes, 4, "n=2 SPSA is 4 forwards");
        }
        let mut gathered = ParamStore::from_specs(p0.specs.clone());
        fleet.gather_into(&mut gathered).expect("gather");
        assert_eq!(bits(&gathered), bits(&dense), "K={} stepping diverged from dense", k);
        assert_eq!(fleet.history, dense_hist, "K={} history diverged from dense", k);
        assert_eq!(fleet.respawns, 0, "calm water: no churn expected");
        fleet.shutdown();
    }
}

/// Scatter → distributed trajectory replay → gather equals the dense
/// replay bit for bit, sequential (`seeds_per_step = 0`) and batched,
/// for 1 / 2 / 4 shards.
#[test]
fn fleet_replay_is_bitwise_dense_for_shards_1_2_4() {
    let p0 = store(&[5, 48, 17], 3);
    let log = synth_log(&["t0", "t2"], 12, 99);

    let mut dense_seq = p0.clone();
    log.replay(&mut dense_seq);
    let mut dense_batched = p0.clone();
    log.replay_batched(&mut dense_batched, 4).expect("dense batched replay");

    for k in [1usize, 2, 4] {
        for (seeds_per_step, dense) in [(0usize, &dense_seq), (4, &dense_batched)] {
            let mut fleet = Fleet::new(
                &p0,
                k,
                vec!["t0".to_string(), "t2".to_string()],
                7,
                FleetConfig::default(),
                channel_spawner(Some(Duration::from_secs(30))),
            )
            .expect("fleet construction");
            fleet.replay(&log, seeds_per_step).expect("fleet replay");
            let mut gathered = ParamStore::from_specs(p0.specs.clone());
            fleet.gather_into(&mut gathered).expect("gather");
            assert_eq!(
                bits(&gathered),
                bits(dense),
                "K={} seeds_per_step={} replay diverged from dense",
                k,
                seeds_per_step
            );
            fleet.shutdown();
        }
    }
}

/// More shards than coordinates: the trailing shards are empty, and
/// their (zero-segment) LoadShard / Perturb / FetchShard frames must
/// survive the wire without upsetting the arithmetic.
#[test]
fn empty_trailing_shards_survive_the_wire() {
    let p0 = store(&[2, 1], 5); // 3 coordinates, 8 shards
    let cfg = FleetConfig { lr: 1e-2, eps: 1e-3, weight_decay: 0.0, n: 1, max_retries: 3 };
    let (dense, _) = dense_steps(&p0, &[0, 1], 17, &cfg, 2);
    let mut fleet = Fleet::new(
        &p0,
        8,
        vec!["t0".to_string(), "t1".to_string()],
        17,
        cfg,
        channel_spawner(Some(Duration::from_secs(30))),
    )
    .expect("fleet construction");
    assert!(fleet.plan().shard(7).is_empty(), "trailing shard should be empty");
    for _ in 0..2 {
        fleet.step(|p| Ok(quad(p))).expect("fleet step");
    }
    let mut gathered = ParamStore::from_specs(p0.specs.clone());
    fleet.gather_into(&mut gathered).expect("gather");
    assert_eq!(bits(&gathered), bits(&dense), "empty-shard fleet diverged from dense");
    fleet.shutdown();
}

// ------------------------------------------------------------ churn pins

/// Kill two workers mid-stepping (one during a perturb broadcast, one
/// during a mirror refresh) and kill the first worker's *replacement*
/// too. Recovery must be invisible: the gathered store and the history
/// stay bitwise dense, and the respawn counter proves churn happened.
#[test]
fn worker_kills_mid_stepping_recover_bitwise() {
    let p0 = store(&[9, 40, 21], 23);
    let cfg = FleetConfig { lr: 5e-3, eps: 1e-3, weight_decay: 0.05, n: 2, max_retries: 3 };
    let (dense, dense_hist) = dense_steps(&p0, &[0, 1, 2], 1234, &cfg, 2);
    // recv 1 is the LoadShard ack; faults land on later, mid-step recvs.
    // (0, 4, Kill) dies mid-perturb-sequence; its replacement (second
    // schedule entry for shard 0) dies again during the command-log
    // re-drive; (2, 7, Kill) dies around the fused update.
    let schedule =
        vec![(0usize, 4usize, Fault::Kill), (0, 2, Fault::Kill), (2, 7, Fault::Kill)];
    let trainable = vec!["t0".to_string(), "t1".to_string(), "t2".to_string()];
    let mut fleet =
        Fleet::new(&p0, 3, trainable, 1234, cfg, chaos_spawner(schedule)).expect("fleet");
    for _ in 0..2 {
        fleet.step(|p| Ok(quad(p))).expect("fleet step under churn");
    }
    assert_eq!(fleet.respawns, 3, "all three scheduled kills should have fired");
    let mut gathered = ParamStore::from_specs(p0.specs.clone());
    fleet.gather_into(&mut gathered).expect("gather");
    assert_eq!(bits(&gathered), bits(&dense), "churned stepping diverged from dense");
    assert_eq!(fleet.history, dense_hist, "churned history diverged from dense");
    fleet.shutdown();
}

/// Kill one worker and time out the other mid-replay: the coordinator's
/// deadline path (respawn, checkpoint re-scatter, retry the in-flight
/// Replay) must land bitwise on the dense replay.
#[test]
fn kill_and_timeout_mid_replay_recover_bitwise() {
    let p0 = store(&[31, 14], 8);
    let log = synth_log(&["t0", "t1"], 9, 555);
    let mut dense = p0.clone();
    log.replay(&mut dense);
    // recv 2 is the Replay ack (recv 1 was LoadShard): worker 1 dies
    // mid-replay; worker 0's reply to the checkpoint fetch (recv 3) is
    // lost to a timeout instead.
    let schedule = vec![(1usize, 2usize, Fault::Kill), (0, 3, Fault::Timeout)];
    let mut fleet = Fleet::new(
        &p0,
        2,
        vec!["t0".to_string(), "t1".to_string()],
        9,
        FleetConfig::default(),
        chaos_spawner(schedule),
    )
    .expect("fleet");
    fleet.replay(&log, 0).expect("fleet replay under churn");
    assert_eq!(fleet.respawns, 2, "one kill + one timeout should both respawn");
    let mut gathered = ParamStore::from_specs(p0.specs.clone());
    fleet.gather_into(&mut gathered).expect("gather");
    assert_eq!(bits(&gathered), bits(&dense), "churned replay diverged from dense");
    fleet.shutdown();
}

/// Sweep the kill over every recv position of a one-step run: wherever
/// the worker dies — perturb, fetch, update, checkpoint, gather — the
/// result must stay bitwise dense. (Position 1, the initial scatter,
/// is construction-time and surfaces as an error by design, so the
/// sweep starts at 2.)
#[test]
fn a_kill_at_every_protocol_position_is_survivable() {
    let p0 = store(&[13, 26], 31);
    let cfg = FleetConfig { lr: 1e-2, eps: 1e-3, weight_decay: 0.0, n: 1, max_retries: 3 };
    let (dense, _) = dense_steps(&p0, &[0, 1], 77, &cfg, 1);
    let mut total_respawns = 0usize;
    // one step at K=2 touches ~9 recvs per worker (perturb ×3, fetch
    // ×2, update, checkpoint fetch, gather fetch, after the load ack)
    for pos in 2usize..=9 {
        let schedule = vec![(pos % 2, pos, Fault::Kill)];
        let mut fleet = Fleet::new(
            &p0,
            2,
            vec!["t0".to_string(), "t1".to_string()],
            77,
            cfg,
            chaos_spawner(schedule),
        )
        .expect("fleet");
        fleet.step(|p| Ok(quad(p))).expect("fleet step under churn");
        let mut gathered = ParamStore::from_specs(p0.specs.clone());
        fleet.gather_into(&mut gathered).expect("gather");
        assert_eq!(bits(&gathered), bits(&dense), "kill at recv {} diverged from dense", pos);
        total_respawns += fleet.respawns;
        fleet.shutdown();
    }
    assert!(total_respawns >= 6, "the sweep should actually have killed workers");
}

/// A worker answering with a stale plan digest is a protocol fault, not
/// churn: the refusal must be a loud typed Nack naming the digests, and
/// the worker must stay up (state intact) afterwards.
#[test]
fn stale_plan_digests_are_refused_loudly_over_the_wire() {
    let p0 = store(&[6, 10], 2);
    let plan = mezo::shard::ShardPlan::new(&p0, 2).expect("plan");
    let (mut coord, mut worker_end) = channel_pair(Some(Duration::from_secs(30)));
    let serve = std::thread::spawn(move || {
        let mut w = ShardWorker::new();
        w.serve(&mut worker_end)
    });
    let segments: Vec<Vec<f32>> = plan
        .shard(0)
        .segments
        .iter()
        .map(|seg| p0.data[seg.tensor][seg.lo..seg.hi].to_vec())
        .collect();
    coord
        .send(&Msg::LoadShard {
            plan: Box::new(plan.clone()),
            shard: 0,
            trainable: vec!["t0".to_string(), "t1".to_string()],
            segments,
        })
        .expect("send load");
    assert!(matches!(coord.recv().expect("load ack"), Msg::Ack));
    // a perturb under a digest the worker does not serve must bounce
    coord
        .send(&Msg::Perturb { plan_digest: plan.digest() ^ 1, seed: 4, scale: 1e-3 })
        .expect("send stale perturb");
    match coord.recv().expect("stale perturb reply") {
        Msg::Nack { message } => {
            assert!(
                message.contains("stale plan digest"),
                "refusal should name the fault, got: {}",
                message
            );
        }
        other => panic!("expected Nack, got {}", other.kind_name()),
    }
    // the refusal must not have cost the worker its state
    coord
        .send(&Msg::FetchShard { plan_digest: plan.digest() })
        .expect("send fetch");
    match coord.recv().expect("fetch reply") {
        Msg::ShardSlice { shard_digest, .. } => {
            assert_eq!(shard_digest, plan.shard_digest(0), "state should be intact");
        }
        other => panic!("expected ShardSlice, got {}", other.kind_name()),
    }
    coord.send(&Msg::Shutdown).expect("send shutdown");
    assert!(matches!(coord.recv().expect("shutdown ack"), Msg::Ack));
    serve.join().expect("worker thread").expect("worker serve");
}

// ---------------------------------------------------- real-process fleet

/// The CI fleet leg: a coordinator driving real `mezo-worker` child
/// processes over TCP, one of which is kill(2)-ed between steps. The
/// fleet must respawn a fresh process, re-scatter its shard, and still
/// gather bitwise dense.
#[test]
fn tcp_process_fleet_survives_a_real_worker_kill() {
    use std::net::TcpListener;
    use std::process::{Child, Command, Stdio};

    let p0 = store(&[19, 37, 8], 61);
    let cfg = FleetConfig { lr: 1e-2, eps: 1e-3, weight_decay: 0.1, n: 1, max_retries: 3 };
    let (dense, dense_hist) = dense_steps(&p0, &[0, 1, 2], 2024, &cfg, 3);

    let children: Arc<Mutex<Vec<Child>>> = Arc::new(Mutex::new(Vec::new()));
    let kids = children.clone();
    let spawn: SpawnFn = Box::new(move |_k| {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let child = Command::new(env!("CARGO_BIN_EXE_mezo-worker"))
            .arg("--connect")
            .arg(addr.to_string())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()?;
        kids.lock().unwrap().push(child);
        let (stream, _) = listener.accept()?;
        let t = mezo::wire::TcpTransport::new(stream, Some(Duration::from_secs(30)))?;
        Ok(Box::new(t) as Box<dyn Transport>)
    });

    let trainable = vec!["t0".to_string(), "t1".to_string(), "t2".to_string()];
    let mut fleet = Fleet::new(&p0, 2, trainable, 2024, cfg, spawn).expect("tcp fleet");
    fleet.step(|p| Ok(quad(p))).expect("step 1");
    // kill worker 0's process for real; the next command hits a dead
    // socket and the fleet must respawn a replacement process
    {
        let mut kids = children.lock().unwrap();
        kids[0].kill().expect("kill worker 0");
        kids[0].wait().expect("reap worker 0");
    }
    fleet.step(|p| Ok(quad(p))).expect("step 2 across the kill");
    fleet.step(|p| Ok(quad(p))).expect("step 3");
    assert!(fleet.respawns >= 1, "the kill should have forced a respawn");

    let mut gathered = ParamStore::from_specs(p0.specs.clone());
    fleet.gather_into(&mut gathered).expect("gather");
    assert_eq!(bits(&gathered), bits(&dense), "process fleet diverged from dense");
    assert_eq!(fleet.history, dense_hist, "process fleet history diverged from dense");
    fleet.shutdown();
    for child in children.lock().unwrap().iter_mut() {
        let _ = child.kill();
        let _ = child.wait();
    }
}
