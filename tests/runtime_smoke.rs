//! Integration: AOT artifact round-trip — rust loads the HLO text the
//! python layer lowered, executes it via PJRT, and the numbers make sense.
//! pjrt builds only — needs the compiled artifact runtime.
#![cfg(feature = "pjrt")]
use mezo::data::batch::Batch;
use mezo::model::params::ParamStore;
use mezo::runtime::{scalar_f32, vec_f32, Runtime};
use std::path::Path;

fn runtime() -> Runtime {
    Runtime::new(Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").as_path()).unwrap()
}

#[test]
fn loss_artifact_executes_and_matches_init_entropy() {
    let rt = runtime();
    let art = rt.load("ar_tiny_full_loss_b8_s64").unwrap();
    let mut params = ParamStore::from_meta(&art.meta);
    params.init(0);
    let mut batch = Batch::zeros(8, 64);
    for row in 0..8 {
        let seq: Vec<u32> = (0..40).map(|t| ((row * 40 + t) % 500 + 5) as u32).collect();
        batch.set_row(row, &seq, 1..seq.len(), false);
    }
    let out = art.run(&params, Some(&batch), &[]).unwrap();
    assert_eq!(out.len(), 2);
    let loss = scalar_f32(&out[0]).unwrap();
    let per_ex = vec_f32(&out[1]).unwrap();
    assert_eq!(per_ex.len(), 8);
    // fresh init => loss ~ ln(512) = 6.24
    assert!((loss - 6.24).abs() < 0.8, "loss {}", loss);
    let mean: f32 = per_ex.iter().sum::<f32>() / 8.0;
    assert!((mean - loss).abs() < 1e-3);
}

#[test]
fn pallas_and_ref_artifacts_agree() {
    let rt = runtime();
    let a = rt.load("ar_tiny_full_loss_b8_s64").unwrap();
    let b = rt.load("ar_tiny_full_loss_pallas_b8_s64").unwrap();
    let mut params = ParamStore::from_meta(&a.meta);
    params.init(1);
    let mut batch = Batch::zeros(8, 64);
    for row in 0..8 {
        let seq: Vec<u32> = (0..30).map(|t| ((row * 7 + t * 3) % 500 + 5) as u32).collect();
        batch.set_row(row, &seq, 1..seq.len(), false);
    }
    let la = scalar_f32(&a.run(&params, Some(&batch), &[]).unwrap()[0]).unwrap();
    let lb = scalar_f32(&b.run(&params, Some(&batch), &[]).unwrap()[0]).unwrap();
    assert!((la - lb).abs() < 1e-4, "ref {} vs pallas {}", la, lb);
}

#[test]
fn grad_artifact_output_count_matches_trainables() {
    let rt = runtime();
    let art = rt.load("ar_tiny_full_grad_b8_s64").unwrap();
    let mut params = ParamStore::from_meta(&art.meta);
    params.init(2);
    let mut batch = Batch::zeros(8, 64);
    for row in 0..8 {
        let seq: Vec<u32> = (0..20).map(|t| ((t * 11 + row) % 500 + 5) as u32).collect();
        batch.set_row(row, &seq, 1..seq.len(), false);
    }
    let out = art.run(&params, Some(&batch), &[]).unwrap();
    assert_eq!(out.len(), 1 + art.meta.trainable.len());
    // gradient of embed.tok has same length as the tensor
    let g0 = vec_f32(&out[1]).unwrap();
    assert_eq!(g0.len(), params.get("embed.tok").len());
    assert!(g0.iter().any(|&x| x != 0.0));
}
